"""Pallas render_score kernel vs pure-jnp oracle: shape/dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import handmodel as hm
from repro.core.camera import Camera
from repro.core.objective import CLAMP_T
from repro.kernels import ops, ref


def _assert_scores_close(a, b, mask):
    """Kernel vs oracle comparison that tolerates ONE silhouette-pixel
    hit flip per particle: at grazing rays the sphere discriminant is
    ~0 and f32 accumulation order (dot_general in the kernel vs matmul
    in the oracle) can legitimately flip hit/no-hit, shifting the
    normalized score by at most CLAMP_T / |B|."""
    denom = max(float(np.asarray(mask, dtype=np.float32).sum()), 1.0)
    atol = CLAMP_T / denom + 1e-6
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=atol)


def _inputs(n_particles, w, h, seed=0, dtype=jnp.float32):
    key = jax.random.PRNGKey(seed)
    cam = Camera(width=w, height=h, fx=w * 0.9, fy=w * 0.9,
                 cx=(w - 1) / 2, cy=(h - 1) / 2)
    ks = jax.random.split(key, n_particles)
    hs = jnp.stack([
        hm.default_pose(0.4).at[0].add(0.02 * i).at[7 + i % 20].add(0.1 * i)
        for i in range(n_particles)
    ])
    spheres = jax.vmap(hm.pack_spheres)(hs).astype(dtype)
    rays = cam.rays_flat().astype(dtype)
    from repro.core import objective
    d_o = objective.render_depth(hs[n_particles // 2], cam).reshape(-1)
    mask = (d_o < 5.0)
    return spheres, rays, d_o.astype(dtype), mask


@pytest.mark.parametrize("n", [1, 7, 8, 13, 32])
@pytest.mark.parametrize("wh", [(16, 16), (40, 24), (64, 64)])
def test_kernel_matches_ref_shapes(n, wh):
    spheres, rays, d_o, mask = _inputs(n, *wh)
    a = ops.render_score(spheres, rays, d_o, mask)
    b = ref.render_score(spheres, rays, d_o, mask)
    _assert_scores_close(a, b, mask)


@pytest.mark.parametrize("block_n,block_p", [(2, 128), (8, 512), (4, 256)])
def test_kernel_block_shapes(block_n, block_p):
    spheres, rays, d_o, mask = _inputs(10, 48, 32)
    a = ops.render_score(spheres, rays, d_o, mask,
                         block_n=block_n, block_p=block_p)
    b = ref.render_score(spheres, rays, d_o, mask)
    _assert_scores_close(a, b, mask)


def test_kernel_bf16_spheres_close():
    """bf16 inputs: kernel and oracle agree (both upcast internally)."""
    spheres, rays, d_o, mask = _inputs(8, 32, 32)
    a = ops.render_score(spheres.astype(jnp.bfloat16), rays, d_o, mask)
    b = ref.render_score(spheres.astype(jnp.bfloat16), rays, d_o, mask)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-4)


def test_kernel_empty_mask_zero_scores():
    spheres, rays, d_o, _ = _inputs(4, 24, 24)
    zero_mask = jnp.zeros_like(d_o, dtype=bool)
    a = ops.render_score(spheres, rays, d_o, zero_mask)
    np.testing.assert_allclose(np.asarray(a), np.zeros(4), atol=1e-7)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 24), st.integers(8, 48), st.integers(8, 40))
def test_kernel_matches_ref_property(n, w, h):
    spheres, rays, d_o, mask = _inputs(n, w, h)
    a = ops.render_score(spheres, rays, d_o, mask)
    b = ref.render_score(spheres, rays, d_o, mask)
    _assert_scores_close(a, b, mask)


def test_tracker_kernel_path_matches_reference_path():
    """TrackerConfig(use_kernel=True) must track identically-shaped output
    and closely-matching objective values to the vmapped reference."""
    import jax
    from repro.core import pso, tracker
    cam = Camera(width=32, height=32, fx=30., fy=30., cx=15.5, cy=15.5)
    base = dict(camera=cam, pso=pso.PSOConfig(num_particles=16, num_generations=5))
    from repro.core import objective
    h0 = hm.default_pose(0.45)
    depth = objective.render_depth(h0, cam)
    key = jax.random.PRNGKey(0)
    for use_kernel in (False, True):
        cfg = tracker.TrackerConfig(use_kernel=use_kernel, **base)
        step = tracker.make_track_frame(cfg)
        h1, score = step(key, h0.at[0].add(0.02), depth)
        assert h1.shape == (27,)
        assert not bool(jnp.isnan(score))
