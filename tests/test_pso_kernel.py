"""pso_update Pallas kernel vs oracle, and vs pso.swarm_step math."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import pso_ref, pso_update as kmod

CONSTS = dict(inertia=0.7298, cognitive=1.49618, social=1.49618,
              velocity_clip=0.5)


def _inputs(n, d, seed=0):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 6)
    lo = -jnp.abs(jax.random.normal(ks[0], (d,))) - 0.5
    hi = jnp.abs(jax.random.normal(ks[1], (d,))) + 0.5
    span = hi - lo
    x = lo + jax.random.uniform(ks[2], (n, d)) * span
    v = jax.random.normal(ks[3], (n, d)) * 0.1
    pb = lo + jax.random.uniform(ks[4], (n, d)) * span
    gb = pb[0]
    r1 = jax.random.uniform(ks[5], (n, d))
    r2 = jax.random.uniform(jax.random.PRNGKey(seed + 1), (n, d))
    return x, v, pb, gb, r1, r2, lo, hi


@pytest.mark.parametrize("n,d", [(8, 32), (16, 27 + 5), (32, 64)])
def test_kernel_matches_ref(n, d):
    args = _inputs(n, d)
    kx, kv = kmod.pso_update(*args, **CONSTS)
    rx, rv = pso_ref.pso_update(*args, **CONSTS)
    np.testing.assert_allclose(np.asarray(kx), np.asarray(rx), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(kv), np.asarray(rv), rtol=1e-6, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([8, 16]), st.sampled_from([16, 32]))
def test_kernel_matches_ref_property(seed, n, d):
    args = _inputs(n, d, seed)
    kx, kv = kmod.pso_update(*args, **CONSTS)
    rx, rv = pso_ref.pso_update(*args, **CONSTS)
    np.testing.assert_allclose(np.asarray(kx), np.asarray(rx), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(kv), np.asarray(rv), rtol=1e-6, atol=1e-6)


def test_bounds_respected():
    args = _inputs(16, 32, seed=3)
    kx, kv = kmod.pso_update(*args, **CONSTS)
    lo, hi = args[6], args[7]
    assert bool(jnp.all(kx >= lo[None] - 1e-6))
    assert bool(jnp.all(kx <= hi[None] + 1e-6))
    vmax = CONSTS["velocity_clip"] * (hi - lo)
    assert bool(jnp.all(jnp.abs(kv) <= vmax[None] + 1e-6))


def test_matches_swarm_step_math():
    """The kernel computes exactly pso.swarm_step's update (same formula,
    same clipping) given identical randoms."""
    from repro.core import pso
    n, d = 16, 16
    args = _inputs(n, d, seed=7)
    x, v, pb, gb, r1, r2, lo, hi = args
    kx, kv = kmod.pso_update(*args, **CONSTS)
    cfg = pso.PSOConfig(num_particles=n)
    vel = (
        cfg.inertia * v
        + cfg.cognitive * r1 * (pb - x)
        + cfg.social * r2 * (gb[None] - x)
    )
    span = hi - lo
    vel = jnp.clip(vel, -cfg.velocity_clip * span, cfg.velocity_clip * span)
    pos = jnp.clip(x + vel, lo, hi)
    np.testing.assert_allclose(np.asarray(kx), np.asarray(pos), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(kv), np.asarray(vel), rtol=1e-6, atol=1e-6)
