"""SLO monitor + fleet doctor tests (``repro.cluster.slo``).

Three layers:

* property tests of the streaming estimators against brute-force
  references — the :class:`WindowedQuantile` documented error bound
  (``v <= estimate <= v * growth`` inside the bucket range, clamps at
  both ends) and :class:`BurnGauge` ring sums vs exact sliding-window
  sums (including the ``fast_window == window`` edge);
* unit tests of the attributor on hand-built profiles — category
  ranking, per-edge/per-medium localization, and the common-cause rule
  that pins a broad network excess on the shared cell;
* small end-to-end runs of the doctor scenario asserting the incident
  lifecycle (open on burn, close with hysteresis, attribution at
  close) and the reporting surfaces.

The full fault catalog (every ``FAULTS`` entry on both engines with
byte-equality gates) runs in ``fleet_bench --doctor``; here we keep to
CI-sized slices.
"""

import json
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.cluster import (
    DOCTOR_CLASSES,
    FAULTS,
    MigrationConfig,
    SLOClass,
    SLOMonitor,
    doctor_verdict,
    run_fleet,
    slo_of,
)
from repro.cluster.slo import (
    BEST_EFFORT,
    CATEGORIES,
    INTERACTIVE,
    BurnGauge,
    Cause,
    Incident,
    WindowedQuantile,
    _frame_categories,
    _Profile,
)
from repro.cluster.telemetry import SPAN_ORDER
from repro.codec import CodecConfig, sequence_motion
from repro.core.offload import Policy
from repro.core.workloads import WORKLOAD_SLO, workload_suite
from repro.sim import hardware

# ---------------------------------------------------------------------------
# SLO classes
# ---------------------------------------------------------------------------


def test_slo_class_validation():
    with pytest.raises(ValueError):
        SLOClass("bad", deadline_s=0.1, target=1.0)
    with pytest.raises(ValueError):
        SLOClass("bad", deadline_s=0.0, target=0.9)
    with pytest.raises(ValueError):
        SLOClass("bad", deadline_s=0.1, target=0.9, window=8, fast_window=9)
    c = SLOClass("ok", deadline_s=0.1, target=0.9)
    assert c.budget == pytest.approx(0.1)


def test_slo_of_mapping():
    for name, cls_name in WORKLOAD_SLO.items():
        assert slo_of(name).name == cls_name
    # derived names resolve to their base workload's class
    assert slo_of("full_gesture[fused]") is slo_of("full_gesture")
    assert slo_of("full_gesture").name == "best_effort"
    # unknown pipelines get the strict class, not a free pass
    assert slo_of("mystery_pipeline") is INTERACTIVE
    assert BEST_EFFORT.budget > INTERACTIVE.budget


# ---------------------------------------------------------------------------
# WindowedQuantile: documented error bound, property-tested
# ---------------------------------------------------------------------------


def _exact_ceil_rank(vals, q):
    s = sorted(vals)
    return s[max(1, math.ceil(q * len(s))) - 1]


@st.composite
def _quantile_streams(draw):
    window = draw(st.integers(min_value=1, max_value=48))
    n = draw(st.integers(min_value=1, max_value=96))
    vals = [
        draw(st.floats(min_value=5e-5, max_value=30.0)) for _ in range(n)
    ]
    q = draw(st.sampled_from([0.5, 0.9, 0.99]))
    return window, vals, q


@settings(max_examples=60, deadline=None)
@given(_quantile_streams())
def test_windowed_quantile_error_bound(stream):
    window, vals, q = stream
    wq = WindowedQuantile(window)
    for v in vals:
        wq.observe(v)
    exact = _exact_ceil_rank(vals[-window:], q)
    est = wq.quantile(q)
    lo, top = wq.bounds[0], wq.bounds[-1]
    growth = 2.0 ** 0.25
    if exact <= lo:
        assert est == lo
    elif exact > top:
        assert est == top
    else:
        assert exact <= est <= exact * growth * (1.0 + 1e-12)


def test_windowed_quantile_edges():
    wq = WindowedQuantile(4)
    assert wq.quantile(0.99) == 0.0  # empty
    wq.observe(1e-9)  # below lo clamps to the bottom bucket
    assert wq.quantile(0.5) == wq.bounds[0]
    for _ in range(4):
        wq.observe(1e9)  # far above the top bound clamps to the top
    assert wq.quantile(0.99) == wq.bounds[-1]
    # retirement: the ring now holds only the overflow values, and four
    # small ones push them all back out
    for _ in range(4):
        wq.observe(1e-3)
    assert wq.quantile(0.99) <= 1e-3 * 2.0 ** 0.25
    with pytest.raises(ValueError):
        WindowedQuantile(0)
    with pytest.raises(ValueError):
        WindowedQuantile(4, growth=1.0)


# ---------------------------------------------------------------------------
# BurnGauge vs brute-force sliding windows
# ---------------------------------------------------------------------------


@settings(max_examples=60, deadline=None)
@given(
    st.integers(min_value=1, max_value=12),  # window
    st.integers(min_value=1, max_value=12),  # fast window (clamped)
    st.integers(min_value=0, max_value=(1 << 20) - 1),  # miss bit pattern
    st.integers(min_value=1, max_value=60),  # observations
)
def test_burn_gauge_matches_brute_force(window, fastw, bits, n):
    fastw = min(fastw, window)
    slo = SLOClass(
        "t", deadline_s=0.1, target=0.9, window=window, fast_window=fastw
    )
    g = BurnGauge(slo)
    seq = []
    for i in range(n):
        bit = (bits >> (i % 20)) & 1
        seq.append(bit)
        g.observe(bool(bit))
        assert g.slow_sum == sum(seq[-window:])
        assert g.fast_sum == sum(seq[-fastw:])
        assert g.fast_ready == (len(seq) >= fastw)


def test_burn_gauge_alerting_and_hysteresis():
    slo = SLOClass(
        "t",
        deadline_s=0.1,
        target=0.9,
        window=8,
        fast_window=4,
        fast_burn=2.0,
        slow_burn=2.0,
    )
    g = BurnGauge(slo)
    assert g.fast_burn == 0.0 and g.slow_burn == 0.0  # empty
    g.observe(True)
    # short-run alerting: the slow ratio uses min(n, window), but the
    # fast window must fill before a spike verdict
    assert g.slow_burn == pytest.approx(1.0 / slo.budget)
    assert not g.alerting
    for _ in range(3):
        g.observe(True)
    assert g.alerting  # 4/4 missed: both burns at 10x budget
    for _ in range(8):
        g.observe(False)
    assert g.fast_sum == 0 and g.slow_sum == 0
    assert not g.alerting


# ---------------------------------------------------------------------------
# category folding + attribution
# ---------------------------------------------------------------------------


def _spans(**kw):
    d = {name: 0.0 for name in SPAN_ORDER}
    d.update(kw)
    return tuple(d[name] for name in SPAN_ORDER)


def test_frame_categories_fold():
    spans = _spans(
        client=1.0,
        uplink=5.0,
        downlink=2.0,
        **{"queue-wait": 3.0, "batch-gather": 4.0},
        decode=6.0,
        compute=7.0,
    )
    cat = _frame_categories(spans, link_wait=1.5)
    by_name = dict(zip(CATEGORIES, cat))
    assert by_name["client"] == 1.0
    assert by_name["network"] == pytest.approx(5.0 + 2.0 - 1.5)
    assert by_name["queueing"] == pytest.approx(3.0 + 4.0)
    assert by_name["decode"] == 6.0
    assert by_name["compute"] == 7.0
    assert by_name["cell"] == 1.5
    assert by_name["blackout"] == 0.0


def _baseline_profile(frames=20):
    base = _Profile()
    for _ in range(frames):
        base.add_frame("edge_0", _spans(compute=10e-3), 0.0, 1000)
        base.add_frame("edge_1", _spans(compute=10e-3), 0.0, 1000)
    return base


def test_attributor_localizes_queueing_to_wait_samples():
    mon = SLOMonitor()
    base = _baseline_profile()
    inc = _Profile()
    for _ in range(10):
        inc.add_frame(
            "edge_1", _spans(compute=10e-3, **{"queue-wait": 30e-3}), 0.0, 1000
        )
        inc.add_wait("edge_1", 30e-3)
        inc.add_wait("edge_0", 0.5e-3)
    causes = mon._attribute(base, inc)
    assert causes[0].category == "queueing"
    assert causes[0].label == "queueing@edge_1"
    assert causes[0].excess_s == pytest.approx(30e-3)


def test_attributor_common_cause_pins_the_shared_cell():
    mon = SLOMonitor()
    base = _baseline_profile()
    inc = _Profile()
    # wire time inflated on BOTH edges, one shared medium observed
    for edge in ("edge_0", "edge_1"):
        for _ in range(10):
            inc.add_frame(edge, _spans(compute=10e-3, uplink=40e-3), 0.0, 1000)
            inc.add_media_wait("cell0", 0.0)
    causes = mon._attribute(base, inc)
    assert causes[0].label == "network@cell0"
    # a single-spoke inflation localizes to that edge instead
    lone = _Profile()
    for _ in range(10):
        lone.add_frame("edge_0", _spans(compute=10e-3, uplink=40e-3), 0.0, 1000)
        lone.add_frame("edge_1", _spans(compute=10e-3), 0.0, 1000)
        lone.add_media_wait("cell0", 0.0)
    causes = mon._attribute(base, lone)
    assert causes[0].label == "network@edge_0"


def test_attributor_cell_and_blackout():
    mon = SLOMonitor()
    base = _baseline_profile()
    inc = _Profile()
    for _ in range(10):
        inc.add_frame(
            "edge_0", _spans(compute=10e-3, uplink=25e-3), 20e-3, 1000
        )
        inc.add_media_wait("cell0", 20e-3)
        inc.add_blackout(50e-3)
    causes = mon._attribute(base, inc)
    labels = [c.label for c in causes]
    assert labels[0] == "blackout"  # 50 ms/frame beats everything
    assert "cell@cell0" in labels
    blackout = causes[0]
    assert blackout.locus is None  # downtime has no single edge
    assert blackout.excess_s == pytest.approx(50e-3)
    # only positive excesses rank: the baseline-only categories are out
    assert all(c.excess_s > 0.0 for c in causes)


def test_incident_summary_and_unknown_cause():
    inc = Incident(workload="wl", slo="interactive", t_open=1.0)
    assert inc.top_cause == "unknown"
    inc.causes = (Cause("compute", "edge_2", 5e-3),)
    inc.t_close = 2.0
    s = inc.summary()
    assert s["causes"][0]["label"] == "compute@edge_2"
    assert s["causes"][0]["excess_ms_per_frame"] == pytest.approx(5.0)
    assert json.dumps(s)  # JSON-able


def test_doctor_verdict_weighs_incidents_by_misses():
    mon = SLOMonitor()
    assert doctor_verdict(mon) == (None, {})
    a = Incident(workload="a", slo="interactive", t_open=0.0)
    a.misses = 100
    a.causes = (Cause("queueing", "edge_1", 10e-3),)
    b = Incident(workload="b", slo="interactive", t_open=0.0)
    b.misses = 2
    b.causes = (Cause("network", "edge_0", 20e-3),)
    mon.incidents.extend([a, b])
    top, scores = doctor_verdict(mon)
    assert top == "queueing@edge_1"  # 1.0 vs 0.04 despite smaller excess
    assert scores["queueing@edge_1"] == pytest.approx(1.0)
    assert scores["network@edge_0"] == pytest.approx(0.04)


# ---------------------------------------------------------------------------
# end-to-end: the doctor scenario, CI-sized
# ---------------------------------------------------------------------------


def _doctor_run(monitor, drifts=(), num_frames=120, **overrides):
    topo, classes = hardware.doctor_star()
    kw = dict(
        num_clients=8,
        num_frames=num_frames,
        dispatch="least_queue",
        policy=Policy.AUTO,
        granularity="multi_step",
        client_classes=classes,
        workloads=workload_suite(),
        codec=CodecConfig(
            base=hardware.codec_point(entropy=True),
            motion=sequence_motion(),
            resync_bound=4,
        ),
        camera_fps=12,
        migration=MigrationConfig(),
        gather_window=2e-3,
        drifts=list(drifts),
        slo=monitor,
    )
    kw.update(overrides)
    return run_fleet(topo, hardware.paper_staged(), **kw)


def test_monitor_healthy_run_is_incident_free():
    mon = SLOMonitor(classes=DOCTOR_CLASSES)
    _doctor_run(mon)
    assert mon.incidents == []
    att = mon.attainment()
    assert list(att) == sorted(att)  # deterministic key order
    for wl, a in att.items():
        assert a["observed"] > 0
        assert not a["incident_open"]
        assert a["slo"] in ("interactive", "best_effort")
    assert "no incidents" in mon.format_incident_report()
    # summary_json round-trips and is byte-stable
    doc = json.loads(mon.summary_json())
    assert doc["incidents"] == []
    assert mon.summary_json() == mon.summary_json()


def test_monitor_throttle_opens_and_attributes_incident():
    mon = SLOMonitor(classes=DOCTOR_CLASSES)
    _doctor_run(
        mon,
        drifts=FAULTS["edge_throttle"].drifts,
        num_frames=160,
    )
    assert mon.incidents
    inc = mon.incidents[0]
    assert inc.t_open > 1.5  # after the injected drift
    assert inc.misses > 0 and inc.frames > 0
    assert inc.causes and inc.causes[0].label == "queueing@edge_1"
    assert not math.isnan(inc.t_close)
    assert inc.p99_est_s > DOCTOR_CLASSES["interactive"].deadline_s
    top, _scores = doctor_verdict(mon)
    assert top == "queueing@edge_1"
    report = mon.format_incident_report()
    assert "incident 0:" in report and "queueing@edge_1" in report


def test_monitor_counts_structural_drops_as_misses():
    # at 30 fps the mixed workloads' 50-85 ms loops shed load: holes in
    # the frame-index sequence must burn the SLO budget as misses
    mon = SLOMonitor(classes=DOCTOR_CLASSES)
    r = _doctor_run(mon, num_frames=60, camera_fps=30)
    assert any(c.stats.drop_rate > 0.0 for c in r.clients)
    att = mon.attainment()
    assert sum(a["misses"] for a in att.values()) > 0


def test_slo_and_telemetry_are_mutually_exclusive():
    from repro.cluster import Telemetry

    topo, classes = hardware.doctor_star()
    with pytest.raises(ValueError):
        run_fleet(
            topo,
            hardware.paper_staged(),
            num_clients=2,
            num_frames=5,
            client_classes=classes,
            slo=SLOMonitor(),
            telemetry=Telemetry(),
        )


def test_fault_catalog_is_well_formed():
    assert set(FAULTS) == {
        "edge_throttle",
        "cell_collapse",
        "lossy_keyframe",
        "migration_flap",
    }
    for key, spec in FAULTS.items():
        assert spec.name == key
        assert spec.drifts and spec.expected and spec.summary
        assert not (spec.migration is not None and spec.disable_migration)
    assert FAULTS["lossy_keyframe"].disable_migration
    assert FAULTS["migration_flap"].migration is not None
    assert FAULTS["migration_flap"].migration.state_nbytes == 16_000_000
