"""Offload placement engine: exact cost model properties."""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import offload
from repro.core.offload import Environment, Link, Policy, Tier, WrapperModel
from repro.core.stages import CLIENT, SERVER, DataItem, Stage, StagedComputation


def _comp(n_stages=4, frame_bytes=500_000, flops=5e9):
    sources = (
        DataItem("frame", frame_bytes, CLIENT),
        DataItem("h_prev", 108, CLIENT),
    )
    stages = []
    prev = "frame"
    for i in range(n_stages):
        out = DataItem(f"x{i}", 20_000)
        stages.append(
            Stage(
                name=f"s{i}",
                flops=flops / n_stages,
                inputs=(prev, "h_prev") if i == 0 else (prev,),
                outputs=(out,),
                parallel_fraction=0.95,
            )
        )
        prev = out.name
    return StagedComputation("test", sources, tuple(stages), (prev,))


def _env(lat=0.3e-3, bw=117e6, fast=2e12, slow=0.3e12):
    return Environment(
        client=Tier("client", slow, 30e9),
        server=Tier("server", fast, 60e9),
        link=Link("l", bw, lat),
        wrapper=WrapperModel(),
    )


@settings(max_examples=30, deadline=None)
@given(
    st.floats(1e-4, 50e-3),  # latency
    st.floats(5e6, 200e6),  # bandwidth
    st.floats(0.5e12, 5e12),  # server speed
    st.floats(0.05e12, 1e12),  # client speed
)
def test_auto_is_optimal(lat, bw, fast, slow):
    """AUTO (exhaustive oracle) never loses to LOCAL or FORCED."""
    comp = _comp()
    env = _env(lat, bw, fast, slow)
    t_auto = offload.plan(comp, env, Policy.AUTO).total_time
    t_local = offload.plan(comp, env, Policy.LOCAL).total_time
    t_forced = offload.plan(comp, env, Policy.FORCED).total_time
    assert t_auto <= t_local + 1e-12
    assert t_auto <= t_forced + 1e-12


def test_single_step_uplink_is_sources_only():
    """Fused single-step ships exactly the sources up, results down."""
    comp = _comp().fused()
    env = _env()
    rep = offload.plan(comp, env, Policy.FORCED)
    assert rep.uplink_bytes == 500_000 + 108
    assert rep.downlink_bytes == 20_000


def test_multi_step_pays_more_rpc_envelopes_on_high_latency():
    comp = _comp()
    env = _env(lat=20e-3)  # Wi-Fi-like
    single = offload.plan(comp.fused(), env, Policy.FORCED)
    multi = offload.plan(comp, env, Policy.FORCED)
    # 4 RPC round trips vs 1 -> at least 3*2*20ms more
    assert multi.total_time > single.total_time + 3 * 2 * 20e-3 * 0.9


def test_residency_no_double_upload():
    """An input used by two remote stages is uploaded once."""
    src = DataItem("frame", 1_000_000, CLIENT)
    stages = (
        Stage("a", 1e9, ("frame",), (DataItem("y1", 10),), 0.9),
        Stage("b", 1e9, ("frame", "y1"), (DataItem("y2", 10),), 0.9),
    )
    comp = StagedComputation("t", (src,), stages, ("y2",))
    rep = offload.plan(comp, _env(), Policy.FORCED)
    assert rep.uplink_bytes == 1_000_000


def test_native_cannot_offload():
    comp = _comp()
    env = Environment(
        client=_env().client, server=_env().server, link=_env().link,
        wrapped=False,
    )
    with pytest.raises(ValueError):
        offload.evaluate_plan(comp, (SERVER,) * 4, env)
    # but local native works
    rep = offload.evaluate_plan(comp, (CLIENT,) * 4, env)
    assert rep.wrapper_time == 0.0


def test_fused_preserves_flops_and_interfaces():
    comp = _comp()
    fused = comp.fused()
    assert fused.total_flops() == pytest.approx(comp.total_flops())
    assert len(fused.stages) == 1
    assert fused.sources == comp.sources
    assert fused.results == comp.results
