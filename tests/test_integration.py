"""Cross-substrate integration: training loop, serving engine, edge
planning, checkpoint round trip, data pipelines."""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import io as ckpt_io
from repro.configs import registry
from repro.core.offload import Policy
from repro.data.tokens import TokenPipeline, TokenPipelineConfig
from repro.models import transformer
from repro.optim import adamw
from repro.serving import edge
from repro.serving.engine import Engine, Request
from repro.sim import hardware


def test_training_reduces_loss():
    from repro.launch import train as train_mod

    result = train_mod.run(
        "gemma-2b", steps=40, batch=4, seq=64, reduced=True, lr=1e-3,
        log_every=39,
    )
    assert result["final_loss"] < result["first_loss"]


def test_token_pipeline_deterministic():
    cfg = TokenPipelineConfig(vocab_size=512, seq_len=32, global_batch=2)
    a = next(iter(TokenPipeline(cfg)))
    b = next(iter(TokenPipeline(cfg)))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].shape == (2, 32)
    assert a["targets"].shape == (2, 32)


def test_serving_engine_greedy_deterministic():
    cfg = registry.get("gemma-2b").reduced()
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [
        Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                max_new_tokens=6)
        for i in range(3)
    ]
    eng1 = Engine(cfg, params, max_len=32)
    eng2 = Engine(cfg, params, max_len=32)
    c1 = eng1.generate(reqs)
    c2 = eng2.generate(reqs)
    for a, b in zip(c1, c2):
        np.testing.assert_array_equal(a.tokens, b.tokens)
        assert len(a.tokens) == 6


def test_edge_planner_prefers_offload_for_thin_client():
    env = hardware.edge_tpu_environment()
    cfgs = [registry.get("gemma-2b"), registry.get("mamba2-370m")]
    rows = edge.compare_archs(cfgs, env)
    for name, row in rows.items():
        assert row["forced"] > row["local"]
        assert row["auto"] >= max(row["forced"], row["local"]) - 1e-9


def test_mla_state_smaller_than_gqa_equivalent():
    """DESIGN.md §Arch-applicability: MLA's latent cache delta is far
    smaller than an equivalent GQA cache delta."""
    mini = registry.get("minicpm3-4b")
    gqa_equiv_bytes = mini.num_layers * 2 * mini.num_kv_heads * 64 * 2
    mla_bytes = edge.cache_delta_bytes(mini, 1)
    assert mla_bytes < gqa_equiv_bytes / 10


def test_checkpoint_roundtrip(tmp_path):
    cfg = registry.get("mamba2-370m").reduced()
    params = transformer.init_params(cfg, jax.random.PRNGKey(3))
    opt = adamw.init(params)
    path = ckpt_io.save(str(tmp_path), 7, {"params": params, "opt": opt})
    assert os.path.exists(path)
    assert ckpt_io.latest_step(str(tmp_path)) == 7
    restored = ckpt_io.restore(str(tmp_path), 7, {"params": params, "opt": opt})
    for orig, back in ((params, restored["params"]), (opt, restored["opt"])):
        ol = jax.tree_util.tree_leaves(orig)
        bl = jax.tree_util.tree_leaves(back)
        assert len(ol) == len(bl)
        for a, b in zip(ol, bl):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_rgbd_sequence_properties():
    from repro.core.camera import Camera
    from repro.data import rgbd

    cam = Camera(width=32, height=32, fx=30.0, fy=30.0, cx=15.5, cy=15.5)
    cfg = rgbd.SequenceConfig(num_frames=8, camera=cam)
    frames, truth = rgbd.render_sequence(cfg)
    assert frames.shape == (8, 32, 32)
    assert truth.shape == (8, 27)
    # hand visible in every frame
    for i in range(8):
        assert int((frames[i] < 5.0).sum()) > 4
    # quaternions normalized
    norms = np.linalg.norm(np.asarray(truth[:, 3:7]), axis=-1)
    np.testing.assert_allclose(norms, 1.0, atol=1e-5)


def test_decode_staged_llm_structure():
    cfg = registry.get("gemma-2b")
    comp = edge.build_decode_staged(cfg, batch=1)
    comp.validate()
    names = [s.name for s in comp.stages]
    assert names[0] == "embed" and names[-1] == "head_sample"
    fused = comp.fused()
    assert fused.total_flops() == pytest.approx(comp.total_flops())
