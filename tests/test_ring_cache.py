"""§Perf iteration 3: ring-buffer KV caches for sliding-window layers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import transformer

WINDOWED = ["gemma3-4b", "starcoder2-3b", "mixtral-8x7b"]


@pytest.mark.parametrize("arch", WINDOWED)
def test_ring_decode_matches_forward(arch):
    """Ring decode == parallel forward, including after the ring wraps
    (S > window for the reduced configs, window=64 > S here tests the
    warm-up path; the wrap path is covered by the long test below)."""
    cfg = registry.get(arch).reduced()
    key = jax.random.PRNGKey(1)
    B, S = 2, 24
    params = transformer.init_params(cfg, key)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    logits_full, _ = transformer.forward(cfg, params, {"tokens": tokens})
    cache = transformer.init_cache(cfg, B, S + 4, ring=True)
    errs = []
    for t in range(S):
        ld, cache = transformer.decode_step(cfg, params, cache, tokens[:, t:t+1])
        errs.append(float(jnp.max(jnp.abs(ld[:, 0] - logits_full[:, t]))))
    assert max(errs) < 5e-5


def test_ring_decode_after_wraparound():
    """Past the window, ring slots are overwritten; results must still
    match the full-cache decode exactly."""
    import dataclasses
    cfg = registry.get("starcoder2-3b").reduced()
    cfg = dataclasses.replace(cfg, sliding_window=8)  # tiny window
    key = jax.random.PRNGKey(2)
    B, S = 1, 30  # S >> window: the ring wraps ~4x
    params = transformer.init_params(cfg, key)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    full_cache = transformer.init_cache(cfg, B, S + 2)
    ring_cache = transformer.init_cache(cfg, B, S + 2, ring=True)
    assert ring_cache.local_k.shape[2] == 8  # ring length == window
    errs = []
    for t in range(S):
        lf, full_cache = transformer.decode_step(
            cfg, params, full_cache, tokens[:, t:t+1])
        lr, ring_cache = transformer.decode_step(
            cfg, params, ring_cache, tokens[:, t:t+1])
        errs.append(float(jnp.max(jnp.abs(lf - lr))))
    assert max(errs) < 5e-5


def test_ring_cache_memory_footprint():
    """The whole point: windowed layers store W, not S."""
    cfg = registry.get("gemma3-4b")  # full config, shapes only
    S = 524288
    shapes = transformer.cache_shapes(cfg, 1, S, ring=True)
    assert shapes.local_k.shape[2] == cfg.sliding_window  # 1024
    assert shapes.attn_k.shape[2] == S  # global layers keep full length
    n_local = shapes.local_k.shape[0]
    n_global = shapes.attn_k.shape[0]
    assert n_local + n_global == cfg.num_layers
    assert n_global == 5  # 5:1 pattern over 34 layers

    full = transformer.cache_shapes(cfg, 1, S, ring=False)
    def nbytes(x):
        return np.prod(x.shape) * x.dtype.itemsize
    ring_total = nbytes(shapes.local_k) * 2 + nbytes(shapes.attn_k) * 2
    full_total = nbytes(full.attn_k) * 2
    assert ring_total < full_total * 0.2  # >5x smaller
