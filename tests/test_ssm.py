"""Mamba2/SSD invariants: chunked scan == naive recurrence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import registry
from repro.models import ssm


def naive_ssd(x, dt, a, b, c):
    """Direct per-step recurrence oracle: h = h*exp(dt a) + dt B x."""
    bs, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    rep = h // g
    bb = jnp.repeat(b, rep, axis=2)
    cc = jnp.repeat(c, rep, axis=2)
    state = jnp.zeros((bs, h, p, n))
    ys = []
    for t in range(s):
        decay = jnp.exp(dt[:, t] * a[None])  # (B, H)
        xt = x[:, t] * dt[:, t][..., None]
        state = state * decay[..., None, None] + jnp.einsum(
            "bhp,bhn->bhpn", xt, bb[:, t]
        )
        ys.append(jnp.einsum("bhpn,bhn->bhp", state, cc[:, t]))
    return jnp.stack(ys, axis=1), state


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 1000), st.sampled_from([4, 8]), st.sampled_from([8, 16]))
def test_chunked_ssd_equals_naive(seed, chunk, seqlen):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 5)
    bs, h, p, g, n = 2, 4, 8, 2, 8
    x = jax.random.normal(ks[0], (bs, seqlen, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bs, seqlen, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    b = jax.random.normal(ks[3], (bs, seqlen, g, n)) * 0.5
    c = jax.random.normal(ks[4], (bs, seqlen, g, n)) * 0.5
    y_chunk, final_chunk = ssm.ssd_chunked(x, dt, a, b, c, chunk)
    y_naive, final_naive = naive_ssd(x, dt, a, b, c)
    np.testing.assert_allclose(
        np.asarray(y_chunk), np.asarray(y_naive), rtol=1e-4, atol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(final_chunk), np.asarray(final_naive), rtol=1e-4, atol=1e-4
    )


def test_initial_state_carries():
    """ssd(x, h0) == ssd over a longer sequence split at the boundary."""
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    bs, s1, s2, h, p, g, n = 1, 16, 16, 2, 4, 1, 4
    s = s1 + s2
    x = jax.random.normal(ks[0], (bs, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bs, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    b = jax.random.normal(ks[3], (bs, s, g, n)) * 0.5
    c = jax.random.normal(ks[4], (bs, s, g, n)) * 0.5
    y_full, final_full = ssm.ssd_chunked(x, dt, a, b, c, 8)
    y1, h1 = ssm.ssd_chunked(x[:, :s1], dt[:, :s1], a, b[:, :s1], c[:, :s1], 8)
    y2, h2 = ssm.ssd_chunked(x[:, s1:], dt[:, s1:], a, b[:, s1:], c[:, s1:], 8, h0=h1)
    np.testing.assert_allclose(np.asarray(y_full[:, s1:]), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(final_full), np.asarray(h2),
                               rtol=1e-4, atol=1e-4)


def test_block_forward_decode_equivalence():
    """Full block: prefill then per-token decode == one long forward."""
    cfg = registry.get("mamba2-370m").reduced()
    params = ssm.init_ssm_block(jax.random.PRNGKey(0), cfg)
    B, S = 2, 12
    u = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) * 0.5
    y_full, state_full = ssm.ssm_forward(params, cfg, u)
    state = ssm.init_state(cfg, B)
    ys = []
    for t in range(S):
        y_t, state = ssm.ssm_decode(params, cfg, u[:, t : t + 1], state)
        ys.append(y_t)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(
        np.asarray(y_full), np.asarray(y_seq), rtol=2e-4, atol=2e-4
    )
    np.testing.assert_allclose(
        np.asarray(state_full.ssd), np.asarray(state.ssd), rtol=2e-4, atol=2e-4
    )
