"""Payload codec: kernel/oracle equivalence, roundtrip bounds, wire
accounting, cost-engine pricing, rate control and fleet integration.

The acceptance contracts:
* the delta codec roundtrips *bit-for-bit* at threshold 0 (XOR bit
  deltas invert exactly), and under a threshold reconstructs within it;
* quantize/pack roundtrips within the advertised half-step bound and
  the packed words are exactly ``bits/32`` of the raw size;
* exact encoded bytes never exceed raw bytes + the fixed header, and
  the analytic ``CodecModel`` estimator respects the same bound;
* batched kernels at B=1 are bit-for-bit the unbatched kernels;
* an engine armed with the identity codec is bit-for-bit the raw
  engine, and a fleet armed with it is event-for-event the raw fleet
  (the golden off-switch);
* a compressing codec strictly shrinks wire bytes and plan totals on
  the 5G star, charges encode at the payload source and decode at the
  destination, and prices migration state at keyframe (delta-free)
  rates;
* the rate controller walks its ladders deterministically — coarser
  bits under sustained link pressure, shorter keyframe intervals under
  scene motion — and re-plans through the shared cache.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster import LinkDrift, PlanCache, run_fleet
from repro.cluster.dispatch import edge_subtopology
from repro.codec import (
    BITS_RAW,
    CodecConfig,
    CodecModel,
    IDENTITY,
    RateController,
    identity_config,
)
from repro.codec import kernels as ck, ref as cr
from repro.core.costengine import CostEngine
from repro.core.offload import Policy, plan
from repro.sim import hardware


def _frames(h=48, w=256, seed=0, step=0.05):
    """A frame pair differing on a localized region (one tile block)."""
    rng = np.random.default_rng(seed)
    ref_f = jnp.asarray(rng.normal(0.5, 0.1, (h, w)).astype(np.float32))
    frame = ref_f.at[8:16, 0:128].add(step)
    return frame, ref_f


# ---------------------------------------------------------------------------
# delta codec: lossless + thresholded roundtrips
# ---------------------------------------------------------------------------


def test_delta_roundtrip_lossless_bit_exact():
    """threshold=0: every changed tile ships its XOR bit delta, so the
    reconstruction is the input, bit for bit."""
    frame, ref_f = _frames()
    for enc, dec in ((cr.delta_encode, cr.delta_decode),
                     (ck.delta_encode, ck.delta_decode)):
        delta, mask = enc(frame, ref_f, threshold=0.0)
        recon = dec(delta, ref_f)
        assert np.array_equal(
            np.asarray(recon, np.float32).view(np.int32),
            np.asarray(frame, np.float32).view(np.int32),
        )
        # only the touched tile rows are marked changed
        assert 0.0 < float(jnp.mean(mask)) < 1.0


def test_delta_kernel_matches_ref_and_threshold_bounds_error():
    frame, ref_f = _frames(step=0.05)
    dk, mk = ck.delta_encode(frame, ref_f, threshold=0.0)
    dr, mr = cr.delta_encode(frame, ref_f, threshold=0.0)
    assert np.array_equal(np.asarray(dk), np.asarray(dr))
    assert np.array_equal(np.asarray(mk), np.asarray(mr))
    # a threshold above the change suppresses the tiles entirely; the
    # reconstruction falls back to the reference, within the threshold
    thr = 0.1
    d2, m2 = ck.delta_encode(frame, ref_f, threshold=thr)
    assert float(jnp.sum(m2)) == 0.0
    recon = ck.delta_decode(d2, ref_f)
    assert float(jnp.max(jnp.abs(recon - frame))) <= thr + 1e-7


def test_delta_encode_batched_b1_bit_for_bit_and_vmap_agrees():
    frame, ref_f = _frames(seed=3)
    dk, mk = ck.delta_encode(frame, ref_f)
    db, mb = ck.delta_encode_batched(frame[None], ref_f[None])
    assert np.array_equal(np.asarray(db[0]), np.asarray(dk))
    assert np.array_equal(np.asarray(mb[0]), np.asarray(mk))
    stack_f = jnp.stack([frame, ref_f])
    stack_r = jnp.stack([ref_f, frame])
    grid = ck.delta_encode_batched(stack_f, stack_r)
    vmap = ck.delta_encode_batched(stack_f, stack_r, path="vmap")
    assert np.array_equal(np.asarray(grid[0]), np.asarray(vmap[0]))
    assert np.array_equal(np.asarray(grid[1]), np.asarray(vmap[1]))
    with pytest.raises(ValueError):
        ck.delta_encode_batched(stack_f, stack_r, path="nope")


def test_delta_unaligned_shapes_pad_and_crop():
    """The paper depth plane (240 x 320) is not tile-aligned; the
    wrapper pads, the kernel stays exact on the cropped output."""
    rng = np.random.default_rng(7)
    frame = jnp.asarray(rng.normal(0.5, 0.1, (240, 320)).astype(np.float32))
    ref_f = frame.at[100:120, 200:240].add(0.02)
    delta, mask = ck.delta_encode(frame, ref_f)
    assert delta.shape == frame.shape
    recon = ck.delta_decode(delta, ref_f)
    assert np.array_equal(np.asarray(recon), np.asarray(frame))


# ---------------------------------------------------------------------------
# quantize + pack
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits", [2, 4, 8, 16])
def test_quantize_roundtrip_error_within_advertised_step(bits):
    frame, _ = _frames(seed=bits)
    lo, hi = 0.0, 1.0
    words = ck.quantize_pack(frame, lo, hi, bits=bits)
    ref_words = cr.quantize_pack(frame, lo, hi, bits=bits)
    assert np.array_equal(np.asarray(words), np.asarray(ref_words))
    # packing is exact: 32/bits codes per int32 word
    assert words.shape == (frame.shape[0], frame.shape[1] * bits // 32)
    recon = ck.unpack_dequantize(words, lo, hi, bits=bits)
    step = cr.quant_step(lo, hi, bits)
    clipped = jnp.clip(frame, lo, hi)
    assert float(jnp.max(jnp.abs(recon - clipped))) <= step / 2 + 1e-7


def test_quantize_pack_batched_b1_golden_and_bits_validated():
    frame, other = _frames(seed=11)
    solo = ck.quantize_pack(frame, 0.0, 1.0, bits=8)
    batched = ck.quantize_pack_batched(
        jnp.stack([frame, other]), 0.0, 1.0, bits=8
    )
    assert np.array_equal(np.asarray(batched[0]), np.asarray(solo))
    vmap = ck.quantize_pack_batched(
        jnp.stack([frame, other]), 0.0, 1.0, bits=8, path="vmap"
    )
    assert np.array_equal(np.asarray(batched), np.asarray(vmap))
    with pytest.raises(ValueError):
        ck.quantize_pack(frame, 0.0, 1.0, bits=3)
    with pytest.raises(ValueError):
        cr.quantize_pack(frame, 0.0, 1.0, bits=32)


# ---------------------------------------------------------------------------
# wire accounting
# ---------------------------------------------------------------------------


def test_encoded_bytes_bounded_by_raw_plus_header():
    frame, ref_f = _frames()
    raw = frame.size * 4
    header = 64
    for thr in (0.0, 0.01, 1e9):
        _, mask = ck.delta_encode(frame, ref_f, threshold=thr)
        for bits in (8, 32):
            n = cr.encoded_nbytes_exact(
                mask, bits=bits, header_nbytes=header
            )
            assert n <= raw + header
            assert n >= header  # the mask + header always ship


def test_composed_quantized_delta_realizes_the_model_ratio():
    """The format the analytic model prices: delta over *quantized
    codes* (ref.encode_frame).  Exact wire bytes of a delta frame must
    land at change_density * bits/32 of the raw size (plus mask +
    header), and the roundtrip stays inside the quantizer's half-step
    bound everywhere — changed tiles from their shipped codes,
    unchanged tiles from the reference."""
    frame, ref_f = _frames(step=0.05)
    lo, hi, bits = 0.0, 1.0, 8
    words, mask = cr.encode_frame(frame, ref_f, lo, hi, bits=bits)
    recon = cr.decode_frame(words, mask, ref_f, lo, hi, bits=bits)
    step = cr.quant_step(lo, hi, bits)
    assert float(jnp.max(jnp.abs(recon - jnp.clip(frame, lo, hi)))) <= (
        step / 2 + 1e-7
    )
    raw = frame.size * 4
    density = float(jnp.mean(mask))
    exact = cr.encoded_nbytes_exact(mask, bits=bits, header_nbytes=64)
    modeled = 64 + raw * density * bits / 32
    # exact count = modeled delta bytes + the mask bits (one per tile)
    assert exact == pytest.approx(modeled + mask.size / 8, abs=8)
    # and an identical frame ships nothing but mask + header
    w2, m2 = cr.encode_frame(frame, frame, lo, hi, bits=bits)
    assert float(jnp.sum(m2)) == 0.0
    assert np.array_equal(
        np.asarray(cr.decode_frame(w2, m2, ref_f, lo, hi, bits=bits)),
        np.asarray(ref_f, np.float32),
    )


def test_change_density_measures_the_touched_region():
    frame, ref_f = _frames()
    dens = cr.change_density(jnp.stack([ref_f, frame, frame]))
    # transition 0: one (8, 128)-tile region of a (48, 256) plane = 1/12
    assert float(dens[0]) == pytest.approx(1.0 / 12.0)
    assert float(dens[1]) == 0.0  # identical frames: nothing ships


# ---------------------------------------------------------------------------
# the analytic model + cost-engine pricing
# ---------------------------------------------------------------------------


def _point(bits=8, interval=8, density=0.2):
    return CodecModel(
        name="dq",
        quant_bits=bits,
        keyframe_interval=interval,
        change_density=density,
        header_nbytes=64,
        encode_flops_per_byte=3.0,
        decode_flops_per_byte=19.0,
    )


def test_codec_model_ratios_and_bounds():
    m = _point()
    assert m.keyframe_ratio == 0.25
    assert m.delta_ratio == pytest.approx(0.05)
    assert 0.0 < m.ratio < m.keyframe_ratio
    raw = 537_600
    assert m.wire_nbytes(raw) <= raw + m.header_nbytes
    assert m.wire_nbytes(raw) < raw
    # below the payload gate nothing is transformed
    assert m.wire_nbytes(108) == 108
    assert m.encode_time(108, hardware.EDGE_GPU) == 0.0
    # the identity codec never applies
    assert IDENTITY.ratio == 1.0
    assert not IDENTITY.applies(raw)
    assert IDENTITY.wire_nbytes(raw) == raw
    with pytest.raises(ValueError):
        CodecModel(name="bad", quant_bits=0)
    with pytest.raises(ValueError):
        CodecModel(name="bad", change_density=1.5)
    with pytest.raises(ValueError):
        CodecModel(name="bad", keyframe_interval=0)


def test_identity_codec_is_bit_for_bit_the_raw_engine():
    comp = hardware.paper_staged().fused()
    topo = hardware.fleet_star(num_edges=2, edge_capacity=4)
    sub = edge_subtopology(topo, "edge_0")
    raw = CostEngine(sub).evaluate(comp, ("edge_0",))
    ident = CostEngine(sub, codec=IDENTITY).evaluate(comp, ("edge_0",))
    assert raw == ident  # full PlanReport equality, legs and all


def test_codec_prices_encode_at_source_decode_at_destination():
    comp = hardware.paper_staged().fused()
    topo = hardware.fleet_star(num_edges=2, edge_capacity=4)
    sub = edge_subtopology(topo, "edge_0")
    m = _point()
    raw = CostEngine(sub).evaluate(comp, ("edge_0",))
    enc = CostEngine(sub, codec=m).evaluate(comp, ("edge_0",))
    assert enc.uplink_bytes < raw.uplink_bytes
    assert enc.total_time < raw.total_time
    by_tier_raw = dict(raw.compute_by_tier)
    by_tier = dict(enc.compute_by_tier)
    # encode appears at home (absent in the raw plan), decode inflates
    # the edge's entry (slot work in the fleet)
    assert "client" not in by_tier_raw and by_tier["client"] > 0.0
    assert by_tier["edge_0"] > by_tier_raw["edge_0"]
    # planner scalars agree with evaluate: AUTO picks the same plan and
    # reports the same total under the codec
    auto = plan(comp, sub, Policy.AUTO, codec=m)
    assert auto.total_time == enc.total_time


def test_migration_state_prices_at_keyframe_rates():
    topo = hardware.fleet_star(num_edges=2, edge_capacity=4)
    nbytes = 21_000
    m = _point()
    raw_t = CostEngine(topo).migration_time(nbytes, "edge_0", "edge_1")
    codec_t = CostEngine(topo, codec=m).migration_time(
        nbytes, "edge_0", "edge_1"
    )
    assert codec_t < raw_t  # quantized state is cheaper to move
    # but never priced at the (cheaper still) amortized delta ratio:
    # the destination holds no reference frame
    assert m.state_wire_nbytes(nbytes) > m.wire_nbytes(nbytes)
    # identity codec: exactly the raw transfer
    assert CostEngine(topo, codec=IDENTITY).migration_time(
        nbytes, "edge_0", "edge_1"
    ) == raw_t


def test_codec_point_is_roofline_calibrated():
    m = hardware.codec_point()
    # decode on the edge GPU is bandwidth-bound: its per-byte cost must
    # sit at the streaming floor, above the raw kernel arithmetic
    from repro.codec.model import DECODE_OPS_PER_BYTE, ENCODE_OPS_PER_BYTE

    assert m.decode_flops_per_byte > DECODE_OPS_PER_BYTE
    # encode on the thin client is compute-bound: kernel arithmetic
    assert m.encode_flops_per_byte == ENCODE_OPS_PER_BYTE
    assert m.applies(hardware.PAPER_FRAME_BYTES)


# ---------------------------------------------------------------------------
# rate control
# ---------------------------------------------------------------------------


def _legs(plan_rep):
    """Observed draws exactly at the plan's charged latencies."""
    return tuple((leg.link, leg.latency) for leg in plan_rep.legs)


def _pressured(plan_rep, factor):
    return tuple((leg.link, leg.latency * factor) for leg in plan_rep.legs)


def _plan_for(topo, edge="edge_0"):
    comp = hardware.paper_staged().fused()
    return plan(comp, edge_subtopology(topo, edge), Policy.AUTO)


def test_rate_controller_drops_bits_under_link_pressure():
    topo = hardware.fleet_star()
    rep = _plan_for(topo)
    cfg = CodecConfig(base=_point(), min_dwell_frames=4)
    rc = RateController(cfg)
    assert rc.model.quant_bits == cfg.bits_ladder[0]
    switched = None
    for i in range(40):
        switched = rc.observe(i, _pressured(rep, 2.0), rep) or switched
    assert switched is not None
    assert rc.model.quant_bits == cfg.bits_ladder[-1]
    # pressure relaxes -> the controller walks back up, but only after
    # the dwell (hysteresis)
    for i in range(40, 80):
        rc.observe(i, _legs(rep), rep)
    assert rc.model.quant_bits == cfg.bits_ladder[0]


def test_rate_controller_shortens_keyframes_under_motion():
    cfg = CodecConfig(
        base=_point(),
        min_dwell_frames=0,
        motion=(0.0,) * 30 + (0.1,) * 30,  # still, then a fast burst
        # explicit density map so the cut crossings are unambiguous: at
        # rest the estimate (0.05) sits under every cut, the burst
        # (0.45) clears them all
        density_gain=4.0,
        density_floor=0.05,
    )
    rc = RateController(cfg)
    topo = hardware.fleet_star()
    rep = _plan_for(topo)
    assert rc.model.keyframe_interval == cfg.interval_ladder[-1]  # still
    for i in range(60):
        rc.observe(i, _legs(rep), rep)
        if i < 29:
            assert rc.model.keyframe_interval == cfg.interval_ladder[-1]
    # the burst's density estimate crosses every cut: shortest interval
    assert rc.model.keyframe_interval == cfg.interval_ladder[0]
    assert rc.switches >= 1


def test_rate_controller_dwell_bounds_switches():
    """Alternating motion that proposes a different point every frame
    can only switch once per dwell window."""
    frames = 120
    dwell = 20
    motion = tuple(0.1 * (i % 2) for i in range(frames))
    cfg = CodecConfig(base=_point(), min_dwell_frames=dwell, motion=motion)
    rc = RateController(cfg)
    topo = hardware.fleet_star()
    rep = _plan_for(topo)
    for i in range(frames):
        rc.observe(i, _legs(rep), rep)
    assert rc.switches <= frames // dwell + 1


def test_codec_config_validates():
    with pytest.raises(ValueError):
        CodecConfig(base=_point(), bits_ladder=())
    with pytest.raises(ValueError):
        CodecConfig(base=_point(), bits_ladder=(16, 3))
    with pytest.raises(ValueError):
        CodecConfig(base=_point(), density_cuts=(0.1, 0.2, 0.3))
    with pytest.raises(ValueError):
        CodecConfig(base=_point(), density_bins=())
    with pytest.raises(ValueError):
        # a bin ladder that stops short of 1.0 would snap high
        # densities DOWN and underprice the wire
        CodecConfig(base=_point(), density_bins=(0.05, 0.1))
    with pytest.raises(ValueError):
        CodecConfig(base=_point(), pressure_alpha=0.0)
    assert CodecConfig(base=_point(), bits_ladder=(BITS_RAW, 8))


def test_density_calibration_has_positive_motion_gain():
    """The stock sequence's measured tile densities rise with wrist
    translation — the sign the controller's density map relies on."""
    from repro.codec import calibrate_density_map
    from repro.data import rgbd

    gain, floor = calibrate_density_map(
        rgbd.SequenceConfig(num_frames=30, noise_std=0.0)
    )
    assert gain > 0.0
    assert 0.0 < floor < 1.0
    # the fleet-facing motion signal: one entry per frame transition
    from repro.codec import sequence_motion

    motion = sequence_motion(rgbd.SequenceConfig(num_frames=10))
    assert len(motion) == 9 and all(m >= 0.0 for m in motion)


# ---------------------------------------------------------------------------
# fleet integration
# ---------------------------------------------------------------------------


def _codec_cfg(**kwargs):
    kwargs.setdefault("base", _point())
    return CodecConfig(**kwargs)


@pytest.mark.parametrize("batching", [False, True])
def test_identity_codec_fleet_is_event_for_event_the_raw_fleet(batching):
    """The golden off-switch at fleet scale, FIFO and fused serving."""
    comp = hardware.paper_staged()
    topo = hardware.fleet_star(
        num_edges=2, edge_capacity=2, batching=batching
    )
    kwargs = dict(num_frames=60, seed=2, gather_window=1.25e-3)
    raw = run_fleet(topo, comp, 6, **kwargs)
    ident = run_fleet(topo, comp, 6, codec=identity_config(), **kwargs)
    for a, b in zip(raw.clients, ident.clients):
        assert a.stats.processed == b.stats.processed
        assert a.stats.duration == b.stats.duration
        assert a.total_wait == b.total_wait
        assert a.plan.total_time == b.plan.total_time
        assert b.rate_changes == 0  # the identity config never adapts
    assert [e.admitted for e in raw.edges] == [e.admitted for e in ident.edges]


def test_codec_fleet_ships_fewer_bytes_and_more_fps():
    comp = hardware.paper_staged()
    topo = hardware.fleet_star(num_edges=2, edge_capacity=2)
    raw = run_fleet(topo, comp, 6, num_frames=60, seed=0)
    enc = run_fleet(
        topo, comp, 6, num_frames=60, seed=0, codec=_codec_cfg(adapt=False)
    )
    assert enc.mean_uplink_bytes < 0.25 * raw.mean_uplink_bytes
    assert enc.mean_achieved_fps > raw.mean_achieved_fps
    assert enc.drop_rate <= raw.drop_rate
    for c in enc.clients:
        assert c.codec is not None and c.codec.quant_bits == 8


def test_rate_switches_replan_through_the_shared_cache():
    """An operating-point switch is a cache miss the first time and a
    hit for every client thereafter: N identical clients cost
    O(edges x operating points) plans, not O(N)."""
    comp = hardware.paper_staged()
    topo = hardware.fleet_star(num_edges=2, edge_capacity=2)
    motion = (0.0,) * 25 + (0.1,) * 50  # one fleet-wide burst
    cache = PlanCache()
    res = run_fleet(
        topo,
        comp,
        8,
        num_frames=75,
        seed=0,
        cache=cache,
        codec=_codec_cfg(min_dwell_frames=5, motion=motion),
    )
    assert res.total_rate_changes >= 8  # every client switched at least once
    # distinct plans: 2 edges x operating points actually visited —
    # far fewer than clients x switches
    assert len(cache._plans) <= 2 * 4
    assert cache.stats.hit_rate > 0.5


def test_codec_fleet_with_link_drift_still_replans():
    """Link drift and rate control compose: the drifted client re-plans
    (codec-keyed) and both counters advance independently."""
    comp = hardware.paper_staged()
    topo = hardware.fleet_star(num_edges=2, edge_capacity=2)
    res = run_fleet(
        topo,
        comp,
        2,
        num_frames=120,
        seed=0,
        codec=_codec_cfg(adapt=False),
        drifts=[LinkDrift(time=1.0, link="5g_edge_0", latency=30e-3)],
        drift_threshold=0.3,
    )
    drifted = [c for c in res.clients if c.replans > 0]
    assert drifted  # the edge_0 client noticed its link move
    for c in res.clients:
        assert c.codec is not None  # codec survives the re-plan


def test_codec_fleet_is_seed_deterministic():
    comp = hardware.paper_staged()
    topo = hardware.fleet_star(num_edges=2, edge_capacity=2)
    cfg = _codec_cfg()
    a = run_fleet(topo, comp, 6, num_frames=60, seed=5, codec=cfg)
    b = run_fleet(topo, comp, 6, num_frames=60, seed=5, codec=cfg)
    assert a.clients == b.clients
    assert a.edges == b.edges


# ---------------------------------------------------------------------------
# entropy stage: width coding of the XOR residuals (codec v2)
# ---------------------------------------------------------------------------


def test_entropy_roundtrip_bit_exact_on_real_residuals():
    """threshold=0 delta residuals roundtrip through the width coder
    bit for bit — the stage is lossless by construction."""
    frame, ref_f = _frames()
    delta, _ = cr.delta_encode(frame, ref_f, threshold=0.0)
    words = np.asarray(delta, dtype=np.int32)
    data = cr.entropy_encode_words(words)
    back = cr.entropy_decode_words(data, words.size)
    assert np.array_equal(back, words.ravel())
    # sparse residuals compress hard: most tiles are all-zero (width 0)
    assert len(data) < words.size * 4 / 4


def test_entropy_encoded_never_exceeds_raw_plus_flag():
    """The raw fallback bounds EVERY input — including adversarial
    dense random words where width coding cannot win — at raw + 1."""
    rng = np.random.default_rng(11)
    cases = [
        np.zeros(256, np.int32),
        np.full(513, -1, np.int32),  # all bits set, odd length
        rng.integers(-(2**31), 2**31, 1000).astype(np.int32),  # dense
        rng.integers(0, 4, 333).astype(np.int32),  # narrow widths
        np.array([], np.int32),
        np.array([7], np.int32),
    ]
    for words in cases:
        data = cr.entropy_encode_words(words)
        assert len(data) <= words.size * 4 + 1, words.size
        back = cr.entropy_decode_words(data, words.size)
        assert np.array_equal(back, words.ravel())
        assert cr.entropy_encoded_nbytes(words) == len(data)


def test_entropy_decode_rejects_garbage():
    with pytest.raises(ValueError):
        cr.entropy_decode_words(b"", 4)
    with pytest.raises(ValueError):
        cr.entropy_decode_words(bytes([9, 0, 0]), 2)  # unknown flag
    with pytest.raises(ValueError):
        cr.entropy_encode_words(np.zeros(8, np.int32), tile=0)


def test_significant_bit_widths_kernel_matches_oracle():
    """The Pallas per-tile width kernel == Python int.bit_length on the
    tile max, on real residuals and on adversarial extremes (sign bit
    set -> width 32; all zero -> width 0)."""
    frame, ref_f = _frames(seed=5)
    delta, _ = cr.delta_encode(frame, ref_f, threshold=0.0)
    words = np.asarray(delta, np.int32)
    bh, bw = 8, 32
    got = np.asarray(ck.significant_bit_widths(delta, block_h=bh, block_w=bw))
    h, w = words.shape
    for i in range(got.shape[0]):
        for j in range(got.shape[1]):
            tile = words[i * bh : (i + 1) * bh, j * bw : (j + 1) * bw]
            expect = int(tile.view(np.uint32).max()).bit_length()
            assert got[i, j] == expect, (i, j)
    extremes = jnp.asarray(
        np.array([[0, 0], [-1, 0]], np.int32).repeat(8, 0).repeat(32, 1)
    )
    ext = np.asarray(ck.significant_bit_widths(extremes, block_h=8, block_w=32))
    assert ext[0, 0] == 0 and ext[0, 1] == 0
    assert ext[1, 0] == 32  # sign bit set reads as uint32 max width


def test_significant_bit_widths_batched_b1_bit_for_bit():
    frame, ref_f = _frames(seed=9)
    delta, _ = cr.delta_encode(frame, ref_f, threshold=0.0)
    single = ck.significant_bit_widths(delta)
    grid = ck.significant_bit_widths_batched(delta[None])
    vmap = ck.significant_bit_widths_batched(delta[None], path="vmap")
    assert np.array_equal(np.asarray(grid[0]), np.asarray(single))
    assert np.array_equal(np.asarray(vmap[0]), np.asarray(single))
    with pytest.raises(ValueError):
        ck.significant_bit_widths_batched(delta[None], path="nope")


def test_entropy_model_pricing_identities():
    """CodecModel with the entropy stage OFF is byte- and time-identical
    to the historical model (the off-switch); ON shrinks only the delta
    ratio and adds the stage's per-byte compute on both sides."""
    base = hardware.codec_point()
    v2 = hardware.codec_point(entropy=True)
    off = dataclasses.replace(
        v2, entropy_coding=False, entropy_ratio=1.0,
        entropy_flops_per_byte=0.0, name=base.name,
    )
    tier = hardware.THIN_CLIENT_NO_GPU
    n = 537_600
    assert off == base
    assert v2.entropy_coding and v2.entropy_ratio < 1.0
    assert v2.keyframe_ratio == base.keyframe_ratio  # keyframes dense
    assert v2.delta_ratio == base.delta_ratio * v2.entropy_ratio
    assert v2.wire_nbytes(n) < base.wire_nbytes(n)
    assert v2.encode_time(n, tier) > base.encode_time(n, tier)
    assert v2.decode_time(n, tier) > base.decode_time(n, tier)
    with pytest.raises(ValueError):
        dataclasses.replace(base, entropy_ratio=0.0)
    with pytest.raises(ValueError):
        dataclasses.replace(base, entropy_flops_per_byte=-1.0)


# ---------------------------------------------------------------------------
# keyframe loss + resync (fault injection)
# ---------------------------------------------------------------------------


def _sequence(n=20, h=32, w=128, seed=2):
    rng = np.random.default_rng(seed)
    base = rng.normal(0.5, 0.1, (h, w)).astype(np.float32)
    frames = []
    for t in range(n):
        f = base.copy()
        f[(t * 3) % h : (t * 3) % h + 4, :16] += 0.05
        frames.append(jnp.asarray(f))
    return frames


def test_stream_resync_bounds_stale_decodes():
    """Fault injection: drop one delta packet mid-stream.  The decoder
    must NACK every packet whose reference chain is broken (never
    decode against a stale reference) and the encoder must deliver a
    fresh keyframe within resync_bound packets of the loss report."""
    frames = _sequence()
    enc = cr.DeltaStreamEncoder(keyframe_interval=16, resync_bound=3)
    dec = cr.DeltaStreamDecoder()
    lost_seq = 4
    stale = 0
    for i, f in enumerate(frames):
        pkt = enc.encode(f)
        if pkt.seq == lost_seq:
            enc.report_loss(lost_seq)  # transport NACK, packet dropped
            continue
        out = dec.decode(pkt)
        if out is None:
            stale += 1
            assert pkt.kind == "delta"  # keyframes always decode
            assert stale <= enc.resync_bound  # bounded outage
        else:
            # everything that DOES decode is bit-exact
            assert np.array_equal(
                np.asarray(out, np.float32).view(np.int32),
                np.asarray(f, np.float32).view(np.int32),
            )
    assert stale > 0  # the fault was injected on a delta
    assert enc.forced_keyframes >= 1
    assert dec.nacks == stale
    # after resync the tail decoded clean: the LAST frame came through
    assert dec.decoded >= len(frames) - 1 - enc.resync_bound - 1


def test_stream_without_loss_never_forces_keyframes():
    frames = _sequence(n=12)
    enc = cr.DeltaStreamEncoder(keyframe_interval=4, resync_bound=2)
    dec = cr.DeltaStreamDecoder()
    kinds = []
    for f in frames:
        pkt = enc.encode(f)
        kinds.append(pkt.kind)
        out = dec.decode(pkt)
        assert out is not None
        assert np.array_equal(
            np.asarray(out, np.float32).view(np.int32),
            np.asarray(f, np.float32).view(np.int32),
        )
    assert enc.forced_keyframes == 0 and dec.nacks == 0
    # the schedule is exactly the keyframe interval
    assert kinds == (["key"] + ["delta"] * 3) * 3


def test_stream_encoder_validates_config():
    with pytest.raises(ValueError):
        cr.DeltaStreamEncoder(keyframe_interval=0)
    with pytest.raises(ValueError):
        cr.DeltaStreamEncoder(resync_bound=0)
