"""Object vs vectorized fleet engine: event-for-event equivalence.

The vectorized engine (``repro.cluster.fastfleet``) is a performance
rewrite of ``fleet.run_fleet``'s object event loop — packed-payload
heap, struct-of-arrays client state, inline FIFO admission, block-drawn
RNG, precomputed drift decisions.  None of that is allowed to change a
single simulated event: these tests assert the two engines produce
identical results — full ``FrameEvent`` streams, per-edge admission and
wait stats, plan-cache counters, migration records, codec operating
points, and the total processed-event count — on every feature
combination (golden configs) and on randomized small fleets with
batching + migration + codec armed at once (property tests via
hypothesis, or the deterministic conftest shim when it is absent).

Float equality throughout is EXACT (``==``, not approx): the vectorized
engine is built from value-equivalent transformations (heapreplace for
pop+push, block-transformed normals, margin-guarded prefix-sum drift
decisions with exact fallback), so bit-for-bit agreement is the
contract, not a lucky outcome.
"""

import pytest
from hypothesis import given, settings, strategies as st

from repro.cluster import MigrationConfig, PlanCache, run_fleet
from repro.cluster.events import AdaptiveWindow
from repro.cluster.fastfleet import ArrayLoopStats
from repro.cluster.fleet import LinkDrift, ServiceDrift
from repro.codec import rate as crate
from repro.core.workloads import workload_suite
from repro.sim import hardware
from repro.sim.clock import FrameEvent


def _run_both(**kwargs):
    ro = run_fleet(engine="object", cache=PlanCache(), **kwargs)
    rv = run_fleet(engine="vector", cache=PlanCache(), **kwargs)
    return ro, rv


def _assert_equivalent(ro, rv):
    assert ro.events == rv.events
    assert ro.duration == rv.duration
    assert len(ro.clients) == len(rv.clients)
    for co, cv in zip(ro.clients, rv.clients):
        assert co.edge == cv.edge
        assert co.replans == cv.replans
        assert co.migrations == cv.migrations
        assert co.total_wait == cv.total_wait
        assert co.rate_changes == cv.rate_changes
        eo, ev = co.stats.processed, cv.stats.processed
        assert len(eo) == len(ev)
        for a, b in zip(eo, ev):
            assert (a.index, a.arrival, a.start, a.finish, a.gap) == (
                b.index, b.arrival, b.start, b.finish, b.gap,
            )
        assert co.stats.duration == cv.stats.duration
        if co.codec is not None or cv.codec is not None:
            assert co.codec == cv.codec
    for lo, lv in zip(ro.edges, rv.edges):
        for f in (
            "name", "capacity", "clients", "admitted", "busy_time",
            "mean_wait", "batches", "mean_batch_size", "peak_load",
        ):
            assert getattr(lo, f) == getattr(lv, f), (lo.name, f)
    # shared-medium occupancy counters: LinkLoad dataclass equality
    # (admitted/contended counts, busy_time and total_wait floats all
    # exact) — empty on private-spoke topologies, on BOTH engines
    assert ro.links == rv.links
    for f in ("hits", "misses", "invalidations"):
        assert getattr(ro.cache.stats, f) == getattr(rv.cache.stats, f), f
    assert (ro.migration is None) == (rv.migration is None)
    if ro.migration is not None:
        assert ro.migration.count == rv.migration.count
        assert ro.migration.considered == rv.migration.considered
        assert ro.migration.rejected_dwell == rv.migration.rejected_dwell
        assert (
            ro.migration.rejected_threshold
            == rv.migration.rejected_threshold
        )
        assert [
            (r.client, r.src, r.dst, r.time) for r in ro.migration.records
        ] == [(r.client, r.src, r.dst, r.time) for r in rv.migration.records]


_COMP = hardware.paper_staged()
_DRIFTS = (
    LinkDrift(time=0.3, link="5g_edge_0", latency=0.05, jitter=0.01),
    ServiceDrift(time=0.6, edge="edge_1", factor=2.5),
    LinkDrift(time=0.9, link="5g_edge_0", latency=0.004, jitter=0.0015),
)


def _golden_configs():
    import dataclasses

    from repro.net import links

    # a narrow shared cell: every spoke contends for one transmission
    # slot, so the contended/keyframe-loss arms exercise real queueing
    # (and real drops) rather than an idle medium
    _cell_topo = hardware.shared_cell_star(
        num_edges=3,
        edge_capacity=2,
        base_link=dataclasses.replace(links.FIVE_G_EDGE, bandwidth=15e6),
        cell_capacity=1,
    )
    topo = hardware.fleet_star(num_edges=3, edge_capacity=2)
    btopo = hardware.fleet_star(num_edges=3, edge_capacity=2, batching=True)
    het_topo, het_classes = hardware.hetero_fleet_star(
        num_edges=3, edge_capacity=2
    )
    return {
        "plain": dict(topo=topo, comp=_COMP, num_clients=9, num_frames=40),
        "batching": dict(
            topo=btopo, comp=_COMP, num_clients=9, num_frames=40,
            gather_window=3e-3,
        ),
        "adaptive": dict(
            topo=btopo, comp=_COMP, num_clients=7, num_frames=40,
            gather_window=3e-3,
            adaptive_window=AdaptiveWindow(alpha=0.3, idle_factor=1.5),
        ),
        "migration": dict(
            topo=hardware.hotspot_star(), comp=_COMP, num_clients=8,
            num_frames=45, dispatch="least_queue",
            migration=MigrationConfig(),
        ),
        "codec": dict(
            topo=topo, comp=_COMP, num_clients=6, num_frames=40,
            codec=crate.CodecConfig(base=hardware.codec_point()),
        ),
        "drift": dict(
            topo=topo, comp=_COMP, num_clients=8, num_frames=60,
            drifts=list(_DRIFTS), drift_window=12, drift_min_samples=5,
        ),
        "entropy_codec": dict(
            topo=topo, comp=_COMP, num_clients=6, num_frames=40,
            codec=crate.CodecConfig(
                base=hardware.codec_point(entropy=True)
            ),
        ),
        "contended": dict(
            topo=_cell_topo, comp=_COMP, num_clients=8, num_frames=40,
            dispatch="latency_weighted",
            codec=crate.CodecConfig(
                base=hardware.codec_point(entropy=True),
                bits_ladder=(16, 8, 4, 2),
                cell_threshold=0.1e-3, cell_stagger=0.05,
            ),
        ),
        "keyframe_loss": dict(
            topo=_cell_topo, comp=_COMP, num_clients=10, num_frames=50,
            dispatch="latency_weighted",
            codec=crate.CodecConfig(
                base=hardware.codec_point(entropy=True),
                cell_threshold=0.1e-3, resync_bound=4,
                drop_threshold=0.2,
            ),
        ),
        "hetero": dict(
            topo=het_topo, comp=_COMP, num_clients=9, num_frames=40,
            client_classes=het_classes,
        ),
        "everything": dict(
            topo=het_topo, comp=_COMP, num_clients=10, num_frames=50,
            dispatch="least_queue", client_classes=het_classes,
            batching=True, gather_window=2e-3,
            migration=MigrationConfig(),
            codec=crate.CodecConfig(base=hardware.codec_point()),
            drifts=[LinkDrift(
                time=0.4, link="5g_edge_0", latency=0.06, jitter=0.012
            )],
        ),
        # mixed multi-model traffic: clients cycle the workload registry
        # (chain / out-tree / gesture tree / RGBD DAG), multi_step so
        # the branching structure reaches the planner — the probability-
        # weighted legs and per-workload batch keys must agree exactly
        "mixed": dict(
            topo=topo, comp=_COMP, num_clients=9, num_frames=40,
            granularity="multi_step", workloads=workload_suite(),
        ),
        "mixed_everything": dict(
            topo=btopo, comp=_COMP, num_clients=10, num_frames=50,
            dispatch="least_queue", granularity="multi_step",
            workloads=workload_suite(), gather_window=2e-3,
            migration=MigrationConfig(min_dwell_frames=10),
            drifts=[LinkDrift(
                time=0.4, link="5g_edge_0", latency=0.06, jitter=0.012
            )],
        ),
    }


_CONFIGS = _golden_configs()


@pytest.mark.parametrize("name", sorted(_CONFIGS))
def test_engines_identical_on_golden_config(name):
    ro, rv = _run_both(**_CONFIGS[name])
    _assert_equivalent(ro, rv)
    assert ro.events > 0  # the golden is not vacuous


@pytest.mark.parametrize("name", sorted(_CONFIGS))
def test_edge_load_parity_audit(name):
    """Dedicated EdgeLoad audit: dataclass equality across engines
    (every field, including the fused-batch and peak-load accounting)
    plus the internal-consistency invariants any report must satisfy —
    a field added to EdgeLoad without vectorized support fails here
    even if the aggregate fps/drop numbers still agree."""
    kw = _CONFIGS[name]
    ro, rv = _run_both(**kw)
    assert ro.edges == rv.edges  # dataclass __eq__: field-by-field
    assert sum(load.clients for load in ro.edges) == kw["num_clients"]
    assert sum(load.admitted for load in ro.edges) > 0
    for load in ro.edges:
        assert load.capacity > 0 and load.admitted >= 0
        assert load.busy_time >= 0.0 and load.mean_wait >= 0.0
        assert 0 <= load.peak_load <= load.admitted
        if load.batches:
            assert load.mean_batch_size == load.admitted / load.batches
        else:
            assert load.mean_batch_size == 0.0


def test_workloads_off_switch_is_bit_for_bit():
    """``workloads=(comp,)`` must reproduce ``workloads=None`` exactly,
    on BOTH engines: the mixed-traffic axis has a golden off position
    like every other fleet feature."""
    topo = hardware.fleet_star(num_edges=3, edge_capacity=2)
    kw = dict(
        topo=topo, comp=_COMP, num_clients=6, num_frames=40,
        granularity="multi_step",
    )
    for eng in ("object", "vector"):
        on = run_fleet(
            engine=eng, cache=PlanCache(), workloads=(_COMP,), **kw
        )
        off = run_fleet(engine=eng, cache=PlanCache(), **kw)
        _assert_equivalent(on, off)


def test_vector_engine_is_seed_stable():
    kw = _CONFIGS["everything"]
    a = run_fleet(engine="vector", cache=PlanCache(), **kw)
    b = run_fleet(engine="vector", cache=PlanCache(), **kw)
    for ca, cb in zip(a.clients, b.clients):
        assert ca.stats.processed == cb.stats.processed
    assert a.events == b.events


@settings(max_examples=8, deadline=None)
@given(
    st.integers(min_value=4, max_value=9),  # num_clients
    st.integers(min_value=25, max_value=45),  # num_frames
    st.integers(min_value=0, max_value=5),  # seed
    st.sampled_from([1e-3, 2e-3, 3e-3]),  # gather_window
    st.sampled_from([False, True]),  # with_drift
)
def test_engines_identical_on_random_fleets_with_everything_armed(
    num_clients, num_frames, seed, gather_window, with_drift
):
    """Randomized small fleets with batching + migration + codec armed
    simultaneously (plus sometimes mid-run drift): the regime where the
    vectorized fast paths interleave with every object subsystem."""
    het_topo, het_classes = hardware.hetero_fleet_star(
        num_edges=3, edge_capacity=2
    )
    ro, rv = _run_both(
        topo=het_topo,
        comp=_COMP,
        num_clients=num_clients,
        num_frames=num_frames,
        seed=seed,
        dispatch="least_queue",
        client_classes=het_classes,
        batching=True,
        gather_window=gather_window,
        migration=MigrationConfig(),
        codec=crate.CodecConfig(base=hardware.codec_point()),
        drifts=list(_DRIFTS) if with_drift else (),
        drift_window=10,
        drift_min_samples=4,
    )
    _assert_equivalent(ro, rv)


@settings(max_examples=6, deadline=None)
@given(
    st.integers(min_value=4, max_value=10),  # num_clients
    st.integers(min_value=3, max_value=20),  # drift_window
    st.integers(min_value=0, max_value=3),  # seed
)
def test_engines_identical_under_randomized_drift_detection(
    num_clients, drift_window, seed
):
    """Drift detection is the one subsystem the vectorized engine
    *re-implements* (prefix-sum decisions + margin-guarded exact
    fallback) rather than reuses — hammer it across window lengths."""
    topo = hardware.fleet_star(num_edges=3, edge_capacity=2)
    ro, rv = _run_both(
        topo=topo,
        comp=_COMP,
        num_clients=num_clients,
        num_frames=50,
        seed=seed,
        drifts=list(_DRIFTS),
        drift_window=drift_window,
        drift_min_samples=max(2, drift_window // 3),
    )
    _assert_equivalent(ro, rv)


# ---------------------------------------------------------------------------
# SLO monitor: golden off-switch + engine-independent incident log
# ---------------------------------------------------------------------------


def _doctor_kwargs(num_frames=80):
    from repro.codec import sequence_motion
    from repro.core.offload import Policy

    topo, classes = hardware.doctor_star()
    return dict(
        topo=topo,
        comp=_COMP,
        num_clients=8,
        num_frames=num_frames,
        dispatch="least_queue",
        policy=Policy.AUTO,
        granularity="multi_step",
        client_classes=classes,
        workloads=workload_suite(),
        codec=crate.CodecConfig(
            base=hardware.codec_point(entropy=True),
            motion=sequence_motion(),
            resync_bound=4,
        ),
        camera_fps=12,
        migration=MigrationConfig(),
        gather_window=2e-3,
        drifts=[ServiceDrift(time=1.5, edge="edge_1", factor=8.0)],
    )


def test_slo_none_is_bit_for_bit_golden():
    """Arming the SLO monitor must not perturb the simulation: the
    armed run reproduces the ``slo=None`` run event-for-event, on BOTH
    engines — every hook site sits behind a guard, and the monitor only
    *observes*."""
    from repro.cluster import DOCTOR_CLASSES, SLOMonitor

    kw = _doctor_kwargs()
    for eng in ("object", "vector"):
        armed = run_fleet(
            engine=eng,
            cache=PlanCache(),
            slo=SLOMonitor(classes=DOCTOR_CLASSES),
            **kw,
        )
        plain = run_fleet(engine=eng, cache=PlanCache(), **kw)
        _assert_equivalent(armed, plain)


def test_slo_armed_engines_byte_identical():
    """Both engines call the monitor hooks with bit-identical inputs in
    the same order, so the full doctor output — telemetry frames, the
    JSON rollup, the rendered incident report — is byte-equal across
    engines, incidents included (the throttle drift guarantees at least
    one opens)."""
    from repro.cluster import DOCTOR_CLASSES, SLOMonitor, doctor_verdict

    kw = _doctor_kwargs(num_frames=120)
    monitors = {}
    for eng in ("object", "vector"):
        mon = SLOMonitor(classes=DOCTOR_CLASSES)
        run_fleet(engine=eng, cache=PlanCache(), slo=mon, **kw)
        monitors[eng] = mon
    mo, mv = monitors["object"], monitors["vector"]
    assert mo.frames == mv.frames  # full telemetry trace, spans included
    assert mo.summary_json() == mv.summary_json()
    assert mo.format_incident_report() == mv.format_incident_report()
    assert mo.incidents  # the drift actually breached the SLO
    assert doctor_verdict(mo) == doctor_verdict(mv)


# ---------------------------------------------------------------------------
# ArrayLoopStats: the vectorized engine's lazy LoopStats stand-in
# ---------------------------------------------------------------------------


def test_array_loop_stats_materializes_lazily_and_exactly():
    from array import array

    period = 1.0 / 30.0
    idx = array("q", [0, 1, 3, 4])
    start = array("d", [0.0, 0.04, 0.11, 0.15])
    finish = array("d", [0.035, 0.10, 0.145, 0.19])
    stats = ArrayLoopStats(idx, start, finish, total_frames=6, period=period)
    assert stats._events is None  # nothing materialized yet
    assert stats.duration == finish[-1]
    assert stats.dropped == 2
    assert stats.drop_rate == 2 / 6
    assert stats.loop_times() == [f - s for s, f in zip(start, finish)]
    events = stats.processed
    assert stats._events is events  # cached after first read
    assert events == [
        FrameEvent(0, 0 * period, 0.0, 0.035, 1),
        FrameEvent(1, 1 * period, 0.04, 0.10, 1),
        FrameEvent(3, 3 * period, 0.11, 0.145, 2),
        FrameEvent(4, 4 * period, 0.15, 0.19, 1),
    ]
    # telescoped mean gap == naive mean over per-event gaps
    assert stats.mean_gap == sum(e.gap for e in events[1:]) / 3


def test_array_loop_stats_empty_run():
    from array import array

    stats = ArrayLoopStats(
        array("q"), array("d"), array("d"), total_frames=0, period=1 / 30
    )
    assert stats.processed == []
    assert stats.duration == 0.0
    assert stats.achieved_fps == 0.0
    assert stats.mean_gap == 1.0
    assert stats.mean_loop_time == 0.0
