"""End-to-end tracker behaviour on synthetic sequences."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import handmodel as hm
from repro.core import objective, pso, tracker
from repro.core.camera import Camera
from repro.data import rgbd

CAM = Camera(width=64, height=64, fx=60.0, fy=60.0, cx=31.5, cy=31.5)


@pytest.fixture(scope="module")
def short_sequence():
    cfg = rgbd.SequenceConfig(
        num_frames=12, camera=CAM, noise_std=0.001,
        fast_burst=(100, 101),  # no burst in this short clip
        position_amplitude=0.04, curl_amplitude=0.5,
    )
    return rgbd.render_sequence(cfg)


def test_tracks_synthetic_sequence(short_sequence):
    frames, truth = short_sequence
    cfg = tracker.TrackerConfig(
        camera=CAM, pso=pso.PSOConfig(num_particles=48, num_generations=20),
        smoothing=0.0,
    )
    t = tracker.Tracker(cfg, h0=truth[0])
    errs = []
    for i in range(1, frames.shape[0]):
        h, score = t.step(frames[i])
        errs.append(float(jnp.linalg.norm(h[:3] - truth[i][:3])))
    assert np.mean(errs) < 0.03, errs  # < 3 cm mean position error


def test_stage_composition_matches_fused(short_sequence):
    """Running the 4 stages separately == the fused track_frame (the
    Single-Step / Multi-Step implementations are the same math)."""
    frames, truth = short_sequence
    cfg = tracker.TrackerConfig(
        camera=CAM, pso=pso.PSOConfig(num_particles=16, num_generations=5)
    )
    key = jax.random.PRNGKey(0)
    h_prev = truth[0]
    depth = frames[1]
    fused = tracker.make_track_frame(cfg)
    h_fused, score_fused = fused(key, h_prev, depth)

    d_o, mask = tracker.stage_preprocess(cfg, h_prev, depth)
    eval_fn = tracker._make_eval_fn(cfg, d_o, mask)
    state, lo, hi = tracker.stage_spawn(cfg, key, h_prev, eval_fn)
    state = tracker.stage_optimize(cfg, state, lo, hi, eval_fn)
    h_multi, score_multi = tracker.stage_refine(cfg, state, h_prev)
    np.testing.assert_allclose(
        np.asarray(h_fused), np.asarray(h_multi), atol=1e-5
    )
    assert float(score_fused) == pytest.approx(float(score_multi), abs=1e-6)


def test_staged_description_is_valid():
    cfg = tracker.TrackerConfig(camera=CAM)
    comp = tracker.build_staged(cfg)
    comp.validate()
    assert [s.name for s in comp.stages] == [
        "preprocess", "spawn", "optimize", "refine",
    ]
    # the GPGPU stage dominates the FLOP budget (that is what's offloaded)
    flops = {s.name: s.flops for s in comp.stages}
    assert flops["optimize"] > 0.9 * comp.total_flops()


def test_executed_simulation_couples_drops_to_quality():
    """Slower deployments process fewer frames; with a fast burst in the
    clip, the local-slow run must not beat the fast run on error."""
    from repro.core.offload import Environment, Link, Policy, Tier, WrapperModel
    from repro.sim import runtime

    cfg = rgbd.SequenceConfig(num_frames=20, camera=CAM, fast_burst=(8, 14))
    frames, truth = cfg, None
    frames, truth = rgbd.render_sequence(cfg)
    tcfg = tracker.TrackerConfig(
        camera=CAM, pso=pso.PSOConfig(num_particles=32, num_generations=10),
        smoothing=0.0,
    )
    comp_flops = tracker.build_staged(tcfg).total_flops()
    fast = Tier("fast", comp_flops * 60, 50e9)  # 60 fps-capable
    slow = Tier("slow", comp_flops * 5, 20e9)  # 5 fps-capable
    link = Link("eth", 117e6, 0.3e-3)
    env_fast = Environment(client=fast, server=fast, link=link, wrapped=False)
    env_slow = Environment(client=slow, server=slow, link=link, wrapped=False)
    r_fast = runtime.executed_run(tcfg, env_fast, Policy.LOCAL, frames, truth)
    r_slow = runtime.executed_run(tcfg, env_slow, Policy.LOCAL, frames, truth)
    assert r_slow.sim.stats.dropped > r_fast.sim.stats.dropped
    assert len(r_fast.sim.stats.processed) > len(r_slow.sim.stats.processed)
