"""Property-based hardening of the cost/event stack.

Invariants the rest of the system leans on, sampled over randomized
inputs (hypothesis, or the deterministic conftest shim when it is not
installed):

* plan cost is monotone in link latency — a slower link can never make
  an offloaded plan cheaper;
* ``PlanReport.compute_by_tier`` partitions ``compute_time`` exactly;
* ``PlanReport.jittered_total`` is exactly the plan total with every
  recorded leg re-drawn — value AND rng-consumption order;
* ``BatchServiceModel`` service times are >= the largest member's solo
  time, never worse than serializing the launches, and amortize
  monotonically once a real batch forms (per-item time non-increasing
  for B >= 2; the 1 -> 2 step additionally needs the fusion overhead to
  be amortizable, since a batch of one pays no overhead at all);
* ``CodecModel`` wire estimates never exceed raw + header, shrink
  monotonically with fewer quantizer bits and sparser change masks,
  and the quantizer's reference roundtrip stays inside the advertised
  half-step bound for every packable width;
* an engine armed with the identity codec prices every plan
  bit-for-bit like the raw engine, for any link conditions.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.codec import CodecModel, IDENTITY
from repro.codec import ref as codec_ref
from repro.core.costengine import BatchServiceModel, CostEngine
from repro.core.stages import CLIENT, DataItem, Stage, StagedComputation
from repro.core.topology import Link, Tier, Topology, WrapperModel, sample_latency


def _comp(n_stages=3, frame_bytes=400_000, flops=4e9):
    sources = (DataItem("frame", frame_bytes, CLIENT),)
    stages = []
    prev = "frame"
    for i in range(n_stages):
        out = DataItem(f"x{i}", 15_000)
        stages.append(
            Stage(
                name=f"s{i}",
                flops=flops / n_stages,
                inputs=(prev,),
                outputs=(out,),
                parallel_fraction=0.9,
            )
        )
        prev = out.name
    return StagedComputation("prop", sources, tuple(stages), (prev,))


def _two_tier(latency, jitter=0.0, bandwidth=100e6):
    client = Tier("client", 30e9, 20e9, has_accelerator=False)
    server = Tier("server", 1e12, 40e9)
    link = Link("uplink", bandwidth, latency, jitter)
    return Topology.two_tier(client, server, link, wrapper=WrapperModel())


# ---------------------------------------------------------------------------
# cost-engine invariants
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    st.floats(min_value=1e-5, max_value=50e-3),
    st.floats(min_value=1e-5, max_value=50e-3),
    st.integers(min_value=1, max_value=4),
)
def test_plan_cost_monotone_in_link_latency(lat_a, lat_b, n_remote):
    """Same placements, slower link => total cost can only grow."""
    comp = _comp(n_stages=4)
    lo, hi = sorted((lat_a, lat_b))
    placements = tuple(
        "server" if i < n_remote else "client" for i in range(4)
    )
    cheap = CostEngine(_two_tier(lo)).evaluate(comp, placements)
    dear = CostEngine(_two_tier(hi)).evaluate(comp, placements)
    assert dear.total_time >= cheap.total_time
    assert dear.network_time >= cheap.network_time
    # compute and wrapper terms never depend on the link's latency
    assert dear.compute_time == cheap.compute_time
    assert dear.wrapper_time == cheap.wrapper_time


@settings(max_examples=25, deadline=None)
@given(
    st.integers(min_value=0, max_value=2 ** 16 - 1),
    st.integers(min_value=1, max_value=5),
)
def test_compute_by_tier_partitions_compute_time(seed, n_stages):
    """The per-tier breakdown sums to the total compute term exactly
    (same additions, so approx only up to float re-association)."""
    rng = np.random.default_rng(seed)
    comp = _comp(n_stages=n_stages)
    topo = _two_tier(5e-3)
    placements = tuple(
        rng.choice(["client", "server"]) for _ in range(n_stages)
    )
    rep = CostEngine(topo).evaluate(comp, placements)
    by_tier = dict(rep.compute_by_tier)
    assert set(by_tier) <= {"client", "server"}
    assert sum(by_tier.values()) == pytest.approx(rep.compute_time, rel=1e-12)
    assert all(t >= 0.0 for t in by_tier.values())


@settings(max_examples=25, deadline=None)
@given(
    st.integers(min_value=0, max_value=2 ** 16 - 1),
    st.floats(min_value=0.0, max_value=10e-3),
)
def test_jittered_total_is_exact_leg_resampling(seed, jitter):
    """jittered_total == plan total with each recorded leg re-drawn, leg
    by leg in record order — bit-for-bit, including rng consumption."""
    comp = _comp(n_stages=4)
    topo = _two_tier(8e-3, jitter=jitter)
    rep = CostEngine(topo).evaluate(
        comp, ("server", "server", "client", "server")
    )
    assert rep.legs  # remote placements must record latency legs
    got = rep.jittered_total(np.random.default_rng(seed))
    rng = np.random.default_rng(seed)
    expect = rep.total_time
    for leg in rep.legs:
        expect -= leg.latency
        expect += sample_latency(leg.latency, leg.jitter, rng)
    assert got == expect  # exact: same ops in the same order
    if jitter == 0.0:
        assert got == rep.total_time


# ---------------------------------------------------------------------------
# batch service model invariants
# ---------------------------------------------------------------------------


def _times(draw_ms, count):
    return [t * 1e-3 for t in draw_ms[:count]]


@settings(max_examples=40, deadline=None)
@given(
    st.floats(min_value=0.0, max_value=1e-3),
    st.floats(min_value=0.0, max_value=1.0),
    st.integers(min_value=0, max_value=2 ** 16 - 1),
    st.integers(min_value=1, max_value=12),
)
def test_batch_time_bounds_and_monotonicity(overhead, marginal, seed, n):
    model = BatchServiceModel(
        launch_overhead=overhead, marginal_fraction=marginal
    )
    rng = np.random.default_rng(seed)
    ts = list(rng.uniform(0.1e-3, 20e-3, size=n))
    t = model.batch_time(ts)
    # a fused batch finishes no earlier than its largest member alone
    assert t >= max(ts)
    # and never costs more than one launch overhead plus serial service
    assert t <= overhead + sum(ts) + 1e-15
    # growing the batch can only lengthen the fused launch
    assert model.batch_time(ts + [5e-3]) >= t
    # a batch of one IS the unbatched launch (golden B=1 anchor)
    assert model.batch_time(ts[:1]) == ts[0]


@settings(max_examples=40, deadline=None)
@given(
    st.floats(min_value=0.0, max_value=1e-3),
    st.floats(min_value=0.0, max_value=1.0),
    st.floats(min_value=0.1e-3, max_value=20e-3),
    st.integers(min_value=2, max_value=31),
)
def test_batch_amortization_is_sublinear(overhead, marginal, solo, b):
    """Per-item time of a homogeneous batch is non-increasing in batch
    size for every B >= 2 — the sublinearity the capacity-knee shift
    rests on.  (The 1 -> 2 step is NOT unconditional: a batch of one
    pays no fusion overhead, so fusing a pair only amortizes when
    ``overhead <= (1 - marginal) * solo`` — asserted separately.)"""
    model = BatchServiceModel(
        launch_overhead=overhead, marginal_fraction=marginal
    )
    assert model.per_item_time(solo, b + 1) <= model.per_item_time(solo, b)
    # the 1 -> 2 boundary, exactly at its amortizability condition
    pair, one = model.per_item_time(solo, 2), model.per_item_time(solo, 1)
    if overhead <= (1.0 - marginal) * solo:
        assert pair <= one * (1 + 1e-12)
    else:
        assert pair > one * (1 - 1e-12)
    # with no fixed overhead the whole batch is strictly sublinear in B
    # for any real amortization (marginal < 1)
    free = BatchServiceModel(launch_overhead=0.0, marginal_fraction=marginal)
    if marginal < 1.0:
        assert free.batch_time([solo] * b) < b * solo


def test_batch_model_validates_parameters():
    with pytest.raises(ValueError):
        BatchServiceModel(launch_overhead=-1e-6)
    with pytest.raises(ValueError):
        BatchServiceModel(marginal_fraction=1.5)
    assert BatchServiceModel().batch_time([]) == 0.0


# ---------------------------------------------------------------------------
# payload codec invariants
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(
    st.sampled_from([1, 2, 4, 8, 16, 32]),
    st.integers(min_value=1, max_value=30),
    st.floats(min_value=0.0, max_value=1.0),
    st.integers(min_value=4096, max_value=2_000_000),
)
def test_codec_wire_bytes_bounded_and_monotone(bits, interval, density, nbytes):
    m = CodecModel(
        name="prop",
        quant_bits=bits,
        keyframe_interval=interval,
        change_density=density,
        header_nbytes=64,
    )
    wire = m.wire_nbytes(nbytes)
    # the raw + header bound, and the clamp to never exceed raw
    assert wire <= nbytes + m.header_nbytes
    assert wire <= nbytes
    assert wire >= 0
    # fewer bits can only shrink the estimate (same delta structure)
    if bits > 1:
        finer = CodecModel(
            name="prop",
            quant_bits=max(1, bits // 2),
            keyframe_interval=interval,
            change_density=density,
            header_nbytes=64,
        )
        assert finer.wire_nbytes(nbytes) <= wire
    # sparser change masks can only shrink a delta-bearing stream
    sparser = CodecModel(
        name="prop",
        quant_bits=bits,
        keyframe_interval=interval,
        change_density=density / 2,
        header_nbytes=64,
    )
    assert sparser.wire_nbytes(nbytes) <= wire
    # state (keyframe) pricing never undercuts the amortized stream
    assert m.state_wire_nbytes(nbytes) >= wire


@settings(max_examples=10, deadline=None)
@given(
    st.sampled_from([2, 4, 8, 16]),
    st.integers(min_value=0, max_value=2 ** 16 - 1),
    st.floats(min_value=0.05, max_value=2.0),
)
def test_quantizer_roundtrip_stays_inside_half_step(bits, seed, span):
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    lo, hi = 0.1, 0.1 + span
    frame = jnp.asarray(
        rng.uniform(lo - 0.2, hi + 0.2, size=(16, 32)).astype(np.float32)
    )
    words = codec_ref.quantize_pack(frame, lo, hi, bits=bits, block_w=32)
    recon = codec_ref.unpack_dequantize(words, lo, hi, bits=bits)
    step = codec_ref.quant_step(lo, hi, bits)
    err = float(jnp.max(jnp.abs(recon - jnp.clip(frame, lo, hi))))
    assert err <= step / 2 + 1e-6 * span


@settings(max_examples=15, deadline=None)
@given(
    st.floats(min_value=1e-4, max_value=40e-3),
    st.integers(min_value=1, max_value=4),
)
def test_identity_codec_engine_equals_raw_engine(latency, n_remote):
    comp = _comp(n_stages=4)
    topo = _two_tier(latency)
    placements = tuple(
        "server" if i < n_remote else "client" for i in range(4)
    )
    raw = CostEngine(topo).evaluate(comp, placements)
    ident = CostEngine(topo, codec=IDENTITY).evaluate(comp, placements)
    assert raw == ident  # bit-for-bit, legs and byte counters included
    assert CostEngine(topo, codec=IDENTITY).transfer_scalar(
        400_000, "client", "server"
    ) == CostEngine(topo).transfer_scalar(400_000, "client", "server")
