"""Fleet simulator: golden single-client limit, exact queueing, dispatch,
plan-cache correctness, drift-scoped re-planning, determinism.

The acceptance contracts:
* 1 client + capacity-1 edge == ``sim.runtime.analytic_run`` bit-for-bit
  (same plan, same per-frame events, same duration — not approx);
* drop rate is monotonically non-decreasing in fleet size under
  contention;
* a plan-cache hit returns a bit-identical ``PlanReport``;
* injected link drift triggers re-planning for exactly the affected
  clients;
* a fixed seed reproduces the fleet run exactly.
"""

import dataclasses

import pytest

from repro.cluster import (
    LinkDrift,
    PlanCache,
    capacity_sweep,
    edge_subtopology,
    run_fleet,
)
from repro.cluster.events import EventQueue, SlotServer
from repro.cluster.plancache import comp_signature, topology_fingerprint
from repro.core.costengine import CostEngine
from repro.core.offload import (
    Environment,
    Link,
    Policy,
    Tier,
    Topology,
    WrapperModel,
)
from repro.core.stages import CLIENT, DataItem, Stage, StagedComputation
from repro.sim import hardware, runtime


# ---------------------------------------------------------------------------
# builders
# ---------------------------------------------------------------------------


def _comp(n_stages=4, frame_bytes=500_000, flops=5e9):
    sources = (
        DataItem("frame", frame_bytes, CLIENT),
        DataItem("h_prev", 108, CLIENT),
    )
    stages = []
    prev = "frame"
    for i in range(n_stages):
        out = DataItem(f"x{i}", 20_000)
        stages.append(
            Stage(
                name=f"s{i}",
                flops=flops / n_stages,
                inputs=(prev, "h_prev") if i == 0 else (prev,),
                outputs=(out,),
                parallel_fraction=0.95,
            )
        )
        prev = out.name
    return StagedComputation("test", sources, tuple(stages), (prev,))


def _star(num_edges=2, capacity=1, latency=2e-3, jitter=0.0, accel=0.5e12):
    """A jitter-free (by default) star: weak hub, `num_edges` edge boxes."""
    hub = Tier("hub", 20e9, 20e9, has_accelerator=False)
    spokes = [
        (
            f"edge_{i}",
            Tier(f"edge_{i}", accel, 40e9, capacity=capacity),
            Link(f"link_{i}", 117e6, latency * (1 + 0.1 * i), jitter),
        )
        for i in range(num_edges)
    ]
    return Topology.star(("hub", hub), spokes, wrapper=WrapperModel())


# ---------------------------------------------------------------------------
# golden: the single-client limit reproduces the analytic simulator
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("net", ["gigabit_ethernet", "wifi_802.11"])
@pytest.mark.parametrize("granularity", ["single_step", "multi_step"])
def test_single_client_matches_analytic_run_bit_for_bit(net, granularity):
    """1 client vs a capacity-1 edge that is exactly the paper's server:
    identical PlanReport, identical frame events, identical duration.
    The Wi-Fi case exercises jittered legs, so rng consumption must
    match draw-for-draw too."""
    from repro.core.wrapper import paper_wrapper
    from repro.net import links

    comp = hardware.paper_staged()
    tiers = hardware.paper_tiers()
    link = links.ALL_LINKS[net]
    env = Environment(
        client=tiers["laptop"],
        server=tiers["server"],
        link=link,
        wrapper=paper_wrapper(),
    )
    star = Topology.star(
        ("client", tiers["laptop"]),
        [("server", tiers["server"], link)],
        wrapper=paper_wrapper(),
    )
    for seed in (0, 7):
        ref = runtime.analytic_run(
            comp, env, Policy.AUTO, granularity, num_frames=300, seed=seed
        )
        (point,) = capacity_sweep(
            star,
            comp,
            client_counts=(1,),
            num_frames=300,
            policy=Policy.AUTO,
            granularity=granularity,
            seed=seed,
        )
        res = point.result
        c = res.clients[0]
        assert c.plan == ref.plan  # dataclass equality: every field exact
        assert c.stats.processed == ref.stats.processed
        assert c.stats.duration == ref.stats.duration
        assert c.stats.dropped == ref.stats.dropped
        assert c.total_wait == 0.0


def test_single_client_two_tier_plan_matches_plan_report():
    """The fleet's cached plan for a 1-edge star equals the two-tier
    PlanReport totals from the offload engine directly."""
    from repro.core import offload

    comp = _comp()
    star = _star(num_edges=1)
    sub = edge_subtopology(star, "edge_0")
    direct = offload.plan(comp.fused(), sub, Policy.AUTO)
    res = run_fleet(star, comp, num_clients=1, num_frames=30)
    assert res.clients[0].plan == direct


# ---------------------------------------------------------------------------
# contention: exact FIFO queueing, monotone degradation
# ---------------------------------------------------------------------------


def test_slot_server_fifo_exactness():
    srv = SlotServer("e", capacity=2)
    # three simultaneous arrivals, two slots: third waits for the first
    assert srv.admit(0.0, 1.0) == (0.0, 1.0)
    assert srv.admit(0.0, 1.0) == (0.0, 1.0)
    assert srv.admit(0.0, 0.5) == (1.0, 1.5)
    assert srv.load(0.5) == 3
    assert srv.load(1.2) == 1
    assert srv.total_wait == pytest.approx(1.0)
    srv.admit(2.0, 1.0)
    with pytest.raises(ValueError):
        srv.admit(1.5, 1.0)  # admissions must be time-ordered


def test_event_queue_orders_ties_by_schedule_order():
    q = EventQueue()
    out = []
    q.schedule(1.0, lambda: out.append("a"))
    q.schedule(0.5, lambda: out.append("b"))
    q.schedule(1.0, lambda: out.append("c"))
    q.run()
    assert out == ["b", "a", "c"]


def test_capacity_sweep_drop_rate_monotone():
    """More clients on a saturated capacity-1 edge can only drop more
    frames (deterministic: jitter-free links)."""
    comp = _comp(flops=40e9)  # ~80 ms of edge service per frame
    topo = _star(num_edges=1, capacity=1)
    pts = capacity_sweep(
        topo, comp, (1, 2, 4, 8), num_frames=120, policy=Policy.FORCED
    )
    drops = [p.drop_rate for p in pts]
    assert drops == sorted(drops)
    assert drops[-1] > drops[0]  # contention actually bites
    # queue waits appear as soon as clients share the slot
    assert pts[0].result.clients[0].total_wait == 0.0
    assert pts[-1].result.clients[-1].total_wait > 0.0
    # p99 tail degrades with the queue too
    assert pts[-1].p99 >= pts[0].p99


def test_capacity_relieves_contention():
    """Same fleet, wider edge: drops cannot get worse."""
    comp = _comp(flops=40e9)
    slim = run_fleet(
        _star(num_edges=1, capacity=1), comp, 8, num_frames=120,
        policy=Policy.FORCED,
    )
    wide = run_fleet(
        _star(num_edges=1, capacity=8), comp, 8, num_frames=120,
        policy=Policy.FORCED,
    )
    assert wide.drop_rate <= slim.drop_rate
    assert wide.p99_loop_time <= slim.p99_loop_time


def test_occupancy_aware_cost_engine():
    """Queueing inflation: (q+1)/capacity beyond capacity, identity
    otherwise, and the default engine stays bit-for-bit uncontended."""
    topo = _star(num_edges=1, capacity=2)
    comp = _comp().fused()
    base = CostEngine(topo)
    stage = comp.stages[0]
    t0 = base.compute_time(stage, "edge_0")
    # one other request on a 2-slot tier: still full speed
    assert CostEngine(topo, {"edge_0": 1}).compute_time(stage, "edge_0") == t0
    # three others on 2 slots: 2x inflation
    assert CostEngine(topo, {"edge_0": 3}).compute_time(
        stage, "edge_0"
    ) == pytest.approx(2.0 * t0)
    # occupancy on another tier does not leak
    assert CostEngine(topo, {"hub": 9}).compute_time(stage, "edge_0") == t0
    rep0 = base.evaluate(comp, ("edge_0",))
    rep1 = CostEngine(topo, {"edge_0": 3}).evaluate(comp, ("edge_0",))
    assert rep1.compute_time == pytest.approx(2.0 * rep0.compute_time)
    assert rep1.network_time == rep0.network_time  # wire unaffected


def test_occupancy_on_batching_tier_prices_fused_launch():
    """A batching tier under occupancy q prices service as the fused
    batch time of q+1 items — sublinear — instead of processor sharing,
    and stays bit-for-bit uncontended at zero occupancy."""
    from repro.core.costengine import BatchServiceModel

    comp = _comp().fused()
    stage = comp.stages[0]
    plain = _star(num_edges=1, capacity=1)
    t0 = CostEngine(plain).compute_time(stage, "edge_0")
    batched = _star(num_edges=1, capacity=1)
    batched = Topology(
        tiers={
            "hub": batched.tier("hub"),
            "edge_0": dataclasses.replace(
                batched.tier("edge_0"), batching=True,
                batch_overhead=1e-4, batch_marginal=0.25,
            ),
        },
        links=dict(batched.links),
        home="hub",
        wrapper=batched.wrapper,
    )
    # zero occupancy: identical to the dedicated-machine price
    assert CostEngine(batched).compute_time(stage, "edge_0") == t0
    # q=3 others: fused launch of 4, NOT 4x processor sharing
    got = CostEngine(batched, {"edge_0": 3}).compute_time(stage, "edge_0")
    model = BatchServiceModel(launch_overhead=1e-4, marginal_fraction=0.25)
    assert got == model.batch_time([t0] * 4)
    assert got < CostEngine(plain, {"edge_0": 3}).compute_time(stage, "edge_0")


def test_plan_report_compute_by_tier_breakdown():
    comp = _comp()
    topo = _star(num_edges=1)
    rep = CostEngine(topo).evaluate(comp, ("hub", "edge_0", "edge_0", "hub"))
    by_tier = dict(rep.compute_by_tier)
    assert set(by_tier) == {"hub", "edge_0"}
    assert sum(by_tier.values()) == pytest.approx(rep.compute_time, rel=1e-12)


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------


def test_dispatch_policies_spread_and_prefer_cheap_spokes():
    comp = _comp()
    topo = _star(num_edges=3)
    rr = run_fleet(topo, comp, 6, num_frames=10, dispatch="round_robin")
    assert [e.clients for e in rr.edges] == [2, 2, 2]
    lq = run_fleet(topo, comp, 6, num_frames=10, dispatch="least_queue")
    assert [e.clients for e in lq.edges] == [2, 2, 2]
    # latency-weighted sends the first client to the lowest-latency spoke
    lw = run_fleet(topo, comp, 1, num_frames=10, dispatch="latency_weighted")
    assert lw.clients[0].edge == "edge_0"
    # with no open batches (admission-time dispatch), batch affinity
    # reduces to join-the-shortest-queue striping
    ba = run_fleet(topo, comp, 6, num_frames=10, dispatch="batch_affinity")
    assert [e.clients for e in ba.edges] == [2, 2, 2]
    with pytest.raises(ValueError):
        run_fleet(topo, comp, 1, num_frames=10, dispatch="nope")


def test_fleet_rejects_non_star_topologies():
    chain = hardware.three_tier_environment()
    with pytest.raises(ValueError):
        run_fleet(chain, _comp(), 2, num_frames=10)


# ---------------------------------------------------------------------------
# plan cache
# ---------------------------------------------------------------------------


def test_plan_cache_hit_is_bit_identical():
    comp = _comp().fused()
    topo = edge_subtopology(_star(), "edge_0")
    cache = PlanCache()
    first, hit0 = cache.get_or_plan(comp, topo, Policy.AUTO)
    again, hit1 = cache.get_or_plan(comp, topo, Policy.AUTO)
    assert (hit0, hit1) == (False, True)
    assert again is first  # the stored object itself: bit-identical
    # an equal-but-distinct topology object still hits (keyed by content)
    clone = edge_subtopology(_star(), "edge_0")
    rep, hit2 = cache.get_or_plan(comp, clone, Policy.AUTO)
    assert hit2 and rep is first
    assert cache.stats.hits == 2 and cache.stats.misses == 1
    assert len(cache) == 1


def test_plan_cache_keys_discriminate():
    comp = _comp().fused()
    star = _star()
    t0 = edge_subtopology(star, "edge_0")
    t1 = edge_subtopology(star, "edge_1")
    assert topology_fingerprint(t0) != topology_fingerprint(t1)
    assert comp_signature(comp) != comp_signature(_comp())
    cache = PlanCache()
    cache.get_or_plan(comp, t0, Policy.AUTO)
    _, hit = cache.get_or_plan(comp, t1, Policy.AUTO)
    assert not hit
    _, hit = cache.get_or_plan(comp, t0, Policy.FORCED)
    assert not hit
    assert len(cache) == 3
    # invalidation by link name drops exactly the matching entries
    assert cache.invalidate_link("link_0") == 2
    assert len(cache) == 1


def test_plan_cache_hit_rate_in_steady_state_32_client_sweep():
    """>= 90% of plan lookups in a 32-client fleet are cache hits — N
    identical clients cost O(num_edges) plans."""
    comp = hardware.paper_staged()
    topo = hardware.fleet_star(num_edges=2, edge_capacity=4)
    res = run_fleet(topo, comp, num_clients=32, num_frames=60)
    stats = res.cache.stats
    assert stats.lookups >= 32
    assert stats.misses == 2  # one plan per edge
    assert stats.hit_rate >= 0.90


def test_capacity_sweep_points_share_one_plan_cache():
    """The sweep hoists a single PlanCache across its points: every
    point past the first hits the plans the first one created, so the
    whole sweep costs O(num_edges) plans, not O(points * edges)."""
    comp = _comp()
    topo = _star(num_edges=2)
    pts = capacity_sweep(topo, comp, (1, 2, 4, 8), num_frames=20)
    caches = {id(p.result.cache) for p in pts}
    assert len(caches) == 1  # one shared cache object
    stats = pts[-1].result.cache.stats
    assert stats.misses == 2  # one plan per edge for the WHOLE sweep
    assert stats.lookups == 1 + 2 + 4 + 8
    assert stats.hit_rate == (stats.lookups - 2) / stats.lookups
    # a caller-provided cache is respected, not replaced
    mine = PlanCache()
    pts2 = capacity_sweep(topo, comp, (1, 2), num_frames=10, cache=mine)
    assert all(p.result.cache is mine for p in pts2)
    assert mine.stats.misses == 2 and mine.stats.lookups == 3


def test_capacity_sweep_surfaces_migration_stats_per_point():
    """Regression: the sweep must pass each point's migration stats
    (count, mean migration latency) through to its report row instead
    of dropping the controller state between points."""
    from repro.cluster import MigrationConfig

    comp = hardware.paper_staged()
    topo = hardware.hotspot_star(num_edges=3, edge_capacity=2)
    pts = capacity_sweep(
        topo,
        comp,
        (3, 9),
        num_frames=90,
        dispatch="least_queue",
        migration=MigrationConfig(min_dwell_frames=10),
    )
    for p in pts:
        assert p.result.migration is not None
        assert p.migrations == p.result.migration.count
        assert p.mean_migration_latency == p.result.migration.mean_latency
    # the hotspot actually forces moves at fleet scale, and the priced
    # state transfer shows up as a nonzero mean latency
    assert pts[-1].migrations >= 1
    assert pts[-1].mean_migration_latency > 0.0
    # migration-off sweeps report zeros, not crashes
    off = capacity_sweep(topo, comp, (2,), num_frames=20)
    assert off[0].migrations == 0
    assert off[0].mean_migration_latency == 0.0


# ---------------------------------------------------------------------------
# drift: incremental re-planning scoped to affected clients
# ---------------------------------------------------------------------------


def test_drift_triggers_replanning_only_for_affected_clients():
    comp = _comp()
    topo = _star(num_edges=2)  # jitter-free: no false positives
    drift = LinkDrift(time=1.0, link="link_0", latency=30e-3)
    res = run_fleet(
        topo,
        comp,
        num_clients=8,
        num_frames=150,
        drifts=[drift],
        drift_min_samples=4,
    )
    affected = [c for c in res.clients if c.edge == "edge_0"]
    untouched = [c for c in res.clients if c.edge == "edge_1"]
    assert affected and untouched
    assert all(c.replans == 1 for c in affected)
    assert all(c.replans == 0 for c in untouched)
    # the re-planned clients now carry a plan calibrated to the drifted
    # link; the others keep the original shared plan
    for c in affected:
        legs = {leg.link: leg.latency for leg in c.plan.legs}
        if "link_0" in legs:  # plan may have gone fully local instead
            assert legs["link_0"] == pytest.approx(30e-3)
    assert res.cache.stats.misses == 3  # 2 initial + 1 drifted re-plan


def test_local_fallback_recovers_when_link_heals():
    """A drift bad enough that AUTO retreats to a fully-local plan must
    not strand the client there: leg-less plans probe the link, so when
    it recovers the client re-plans back onto the edge."""
    comp = _comp(flops=40e9)  # heavy enough that offloading clearly wins
    topo = _star(num_edges=2, capacity=8)
    res = run_fleet(
        topo,
        comp,
        num_clients=4,
        num_frames=400,
        drifts=[
            LinkDrift(time=1.0, link="link_0", latency=0.5),  # catastrophic
            LinkDrift(time=6.0, link="link_0", latency=2e-3),  # healed
        ],
        drift_min_samples=4,
        probe_every=10,
    )
    affected = [c for c in res.clients if c.edge == "edge_0"]
    untouched = [c for c in res.clients if c.edge == "edge_1"]
    assert all(c.replans >= 2 for c in affected)  # retreat, then return
    # final plan offloads again (has latency legs on the healed link)
    for c in affected:
        assert c.plan.legs and all(
            leg.latency == pytest.approx(2e-3) for leg in c.plan.legs
        )
    assert all(c.replans == 0 for c in untouched)


def test_drift_below_threshold_does_not_replan():
    comp = _comp()
    topo = _star(num_edges=2)
    # +20% latency is inside the 50% default threshold
    drift = LinkDrift(time=1.0, link="link_0", latency=2.4e-3)
    res = run_fleet(
        topo, comp, num_clients=4, num_frames=100, drifts=[drift],
        drift_min_samples=4,
    )
    assert res.total_replans == 0


# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------


def test_fleet_run_is_deterministic_under_fixed_seed():
    comp = hardware.paper_staged()
    topo = hardware.fleet_star(num_edges=2, edge_capacity=2)
    a = run_fleet(topo, comp, 8, num_frames=80, seed=3)
    b = run_fleet(topo, comp, 8, num_frames=80, seed=3)
    assert a.clients == b.clients  # events, plans, waits — all exact
    assert a.edges == b.edges
    c = run_fleet(topo, comp, 8, num_frames=80, seed=4)
    assert a.clients != c.clients  # the seed actually matters (jittered)


def test_adding_clients_preserves_existing_draws():
    """Client i's rng stream depends only on (seed, i): growing the
    fleet never perturbs the smaller clients' latency draws."""
    comp = hardware.paper_staged()
    topo = hardware.fleet_star(num_edges=2, edge_capacity=64)
    # capacity ample => no queueing => loop times must match exactly
    small = run_fleet(topo, comp, 2, num_frames=40, seed=0)
    large = run_fleet(topo, comp, 4, num_frames=40, seed=0)
    for i in range(2):
        assert (
            small.clients[i].stats.processed == large.clients[i].stats.processed
        )
