"""Fig. 3 frame-drop accounting."""

import pytest

from repro.sim.clock import FRAME_PERIOD, FrameLoop, LoopStats


def test_fast_loop_processes_every_frame():
    loop = FrameLoop()
    stats = loop.run(lambda i, gap: 0.010, 90)
    assert stats.dropped == 0
    assert stats.mean_gap == 1.0
    assert stats.realtime


def test_paper_150ms_example_drops_two_of_three():
    """Paper Fig. 3A: 'for a hypothetical slower 150 ms processing loop
    time, the system must skip processing two consecutive frames for each
    received frame' — wait: 150 ms spans 4.5 periods; the tracker
    processes every 5th frame on average."""
    loop = FrameLoop()
    stats = loop.run(lambda i, gap: 0.150, 300)
    assert stats.mean_gap == pytest.approx(5.0, abs=0.6)
    assert stats.drop_rate > 0.7
    assert not stats.realtime


def test_33ms_budget_boundary():
    loop = FrameLoop()
    stats = loop.run(lambda i, gap: FRAME_PERIOD * 0.999, 100)
    assert stats.dropped == 0


def test_drops_scale_with_loop_time():
    loop = FrameLoop()
    slow = loop.run(lambda i, gap: 0.100, 200)
    slower = loop.run(lambda i, gap: 0.200, 200)
    assert slower.dropped > slow.dropped
    assert slower.mean_gap > slow.mean_gap
