"""The paper's GPGPU axis mapped onto the TPU mesh: particle-parallel
PSO evaluation via shard_map, and the sharded tracker lowering."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# The subprocess compiles the full sharded tracker step on 8 fake CPU
# devices, which can take minutes on a loaded CI container.  The
# workload below is the smallest that still exercises every contract
# (sharded eval parity, collective lowering, execution); the timeout is
# env-tunable for slow runners.
SUBPROC_TIMEOUT = int(os.environ.get("REPRO_SUBPROC_TIMEOUT", "600"))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import numpy as np
from repro.core import handmodel, objective, pso, tracker
from repro.core.camera import Camera

mesh = jax.make_mesh((2, 4), ("data", "model"), devices=jax.devices())
cam = Camera(width=24, height=24, fx=22.0, fy=22.0, cx=11.5, cy=11.5)
h0 = handmodel.default_pose(0.45)
depth = objective.render_depth(h0, cam)

# 1) sharded population eval == local eval
def eval_local(hs):
    return objective.batched_objective(hs, depth, cam)

key = jax.random.PRNGKey(0)
lo = handmodel.parameter_lower_bounds(h0)
hi = handmodel.parameter_upper_bounds(h0)
hs = lo + jax.random.uniform(key, (8, 27)) * (hi - lo)
with mesh:
    sharded = pso.sharded_eval(eval_local, mesh, "model")
    a = jax.jit(sharded)(hs)
b = eval_local(hs)
np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=1e-6)
print("SHARDED_EVAL_OK")

# 2) the full sharded tracker step lowers + compiles on the mesh
cfg = tracker.TrackerConfig(
    camera=cam, pso=pso.PSOConfig(num_particles=8, num_generations=2)
)
with mesh:
    step = tracker.make_track_frame_sharded(cfg, mesh, "model")
    lowered = step.lower(key, h0, depth)
    compiled = lowered.compile()
    txt = compiled.as_text()
# particles are sharded -> the swarm argmin/gather needs collectives
has_coll = any(k in txt for k in ("all-gather", "all-reduce", "collective-permute", "all-to-all"))
print("LOWERED_OK collectives=%s" % has_coll)
h1, score = step(key, h0.at[0].add(0.02), depth)
assert h1.shape == (27,) and not bool(jnp.isnan(score))
print("EXECUTED_OK")
"""


def test_sharded_tracker_on_8_fake_devices():
    """Runs in a subprocess: needs its own XLA device-count flag.

    A compile that outlives ``REPRO_SUBPROC_TIMEOUT`` is a slow runner,
    not a product regression — skip (with the knob named in the reason,
    so it is actionable in the CI log) instead of erroring the tier-1
    run.  A nonzero exit or missing marker still FAILS: only the
    timeout is environmental."""
    try:
        proc = subprocess.run(
            [sys.executable, "-c", SCRIPT],
            capture_output=True, text=True, timeout=SUBPROC_TIMEOUT,
            env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"},
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        )
    except subprocess.TimeoutExpired:
        pytest.skip(
            f"sharded-tracker subprocess exceeded REPRO_SUBPROC_TIMEOUT="
            f"{SUBPROC_TIMEOUT}s (slow runner; raise the env var to "
            f"run it to completion)"
        )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "SHARDED_EVAL_OK" in proc.stdout
    assert "LOWERED_OK collectives=True" in proc.stdout
    assert "EXECUTED_OK" in proc.stdout
