"""Properties of the paper's E_D objective (Eq. 2)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import handmodel as hm
from repro.core import objective as obj
from repro.core.camera import BACKGROUND_DEPTH, Camera

CAM = Camera(width=48, height=48, fx=45.0, fy=45.0, cx=23.5, cy=23.5)


def test_perfect_hypothesis_scores_zero():
    h = hm.default_pose(0.45)
    d = obj.render_depth(h, CAM)
    assert float(obj.objective(h, d, CAM)) == pytest.approx(0.0, abs=1e-6)


def test_clamp_bounds_objective():
    """E_D <= T by construction (mean of clamped values)."""
    h = hm.default_pose(0.45)
    d_far = jnp.full((CAM.height, CAM.width), BACKGROUND_DEPTH)
    e = float(obj.objective(h, d_far, CAM))
    assert 0.0 <= e <= obj.CLAMP_T + 1e-6


@settings(max_examples=20, deadline=None)
@given(st.floats(0.005, 0.08))
def test_larger_offset_scores_worse(dx):
    """Monotone degradation along a translation ray."""
    h = hm.default_pose(0.45)
    d = obj.render_depth(h, CAM)
    mask = obj.bounding_box_mask(d, h[2])
    e_small = float(obj.objective(h.at[0].add(dx / 2), d, CAM, mask))
    e_large = float(obj.objective(h.at[0].add(dx * 2), d, CAM, mask))
    e_true = float(obj.objective(h, d, CAM, mask))
    assert e_true <= e_small <= e_large + 1e-4


def test_sphere_depth_matches_analytic_center_ray():
    """A sphere dead ahead: depth along the central ray = c_z - r."""
    spheres = jnp.asarray([[0.0, 0.0, 0.5, 0.1]])
    rays = jnp.asarray([[0.0, 0.0, 1.0]])
    d = obj.sphere_depth(rays, spheres)
    np.testing.assert_allclose(np.asarray(d), [0.4], atol=1e-6)


def test_zero_radius_padding_never_hits():
    spheres = jnp.asarray([[0.0, 0.0, 0.0, 0.0]])
    rays = CAM.rays_flat()
    d = obj.sphere_depth(rays, spheres)
    assert float(d.min()) == BACKGROUND_DEPTH


def test_bbox_mask_selects_hand_depth_band():
    h = hm.default_pose(0.45)
    d = obj.render_depth(h, CAM)
    mask = obj.bounding_box_mask(d, h[2], half_width=0.25)
    hand_pixels = d < BACKGROUND_DEPTH - 1
    # every rendered hand pixel near the expected depth is inside B
    assert bool(jnp.all(mask[hand_pixels]))
    # far background is outside B
    assert not bool(jnp.any(mask & (d > 5.0)))
