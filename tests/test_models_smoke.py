"""Mandated per-arch smoke tests: reduced variant, one forward/train step
on CPU, asserting output shapes + no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import multimodal, transformer
from repro.optim import adamw

ARCHS = registry.list_archs()


def _batch(cfg, B=2, S=32):
    key = jax.random.PRNGKey(0)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {
        "tokens": tokens,
        "targets": jnp.roll(tokens, -1, axis=1),
        "loss_mask": jnp.ones((B, S), jnp.float32),
    }
    if cfg.mrope:
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S + cfg.frontend_tokens, dtype=jnp.int32)[None, None],
            (3, B, S + cfg.frontend_tokens),
        )
        batch["frontend_embeds"] = multimodal.fake_frontend_embeds(cfg, B)
    elif cfg.modality == "vision":
        batch["frontend_embeds"] = multimodal.fake_frontend_embeds(cfg, B)
    if cfg.encoder_layers:
        batch["encoder_tokens"] = multimodal.fake_frontend_embeds(cfg, B)
        batch.pop("frontend_embeds", None)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_forward_shapes_and_no_nan(arch):
    cfg = registry.get(arch).reduced()
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 32
    batch = _batch(cfg, B, S)
    logits, aux = transformer.forward(cfg, params, batch)
    expect_s = S + (cfg.frontend_tokens if cfg.modality == "vision" else 0)
    assert logits.shape == (B, expect_s, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_train_step_no_nan(arch):
    cfg = registry.get(arch).reduced()
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    opt_state = adamw.init(params)
    batch = _batch(cfg)

    @jax.jit
    def step(p, o, b):
        (loss, m), grads = jax.value_and_grad(
            lambda q: transformer.loss_fn(cfg, q, b), has_aux=True
        )(p)
        p2, o2, mm = adamw.update(adamw.AdamWConfig(), grads, o, p)
        return p2, o2, loss, mm["grad_norm"]

    params2, _, loss, gnorm = step(params, opt_state, batch)
    assert not bool(jnp.isnan(loss))
    assert float(gnorm) > 0.0 and np.isfinite(float(gnorm))
    # parameters actually moved
    delta = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
        params, params2,
    )
    assert max(jax.tree_util.tree_leaves(delta)) > 0.0


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_decode_step_shapes(arch):
    cfg = registry.get(arch).reduced()
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    B = 2
    cache = transformer.init_cache(cfg, B, 64)
    toks = jnp.zeros((B, 1), jnp.int32)
    pos = None
    if cfg.mrope:
        pos = jnp.zeros((3, B, 1), jnp.int32)
    logits, cache2 = transformer.decode_step(cfg, params, cache, toks, positions=pos)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert int(cache2.position[0]) == 1
