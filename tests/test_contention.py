"""Shared-uplink contention: the SharedLink property battery.

The acceptance contracts for the contended-cell subsystem:

* **off-switch golden** — a shared cell with unlimited capacity
  (``medium_capacity=0``) is *bit-for-bit* the private-spoke fleet, on
  BOTH engines, across randomized fleet shapes (property-tested);
* **wire-time conservation** — contention moves transmissions in time
  but never creates or destroys wire seconds: the cell's ``busy_time``
  equals the per-plan wire seconds times the frames that actually
  shipped, at any capacity;
* **knee monotonicity** — the 25 fps capacity knee is non-increasing
  as the cell's bandwidth shrinks (a narrower cell can never serve
  MORE clients);
* **fairness invariant** — under equal client classes on a congested
  cell, the slotted FIFO + fair rate control keeps served-frame counts
  balanced (max/min bounded), with no starved client.
"""

import dataclasses
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.cluster import PlanCache, capacity_sweep, run_fleet
from repro.cluster.events import SharedLink, build_media
from repro.codec import CodecConfig
from repro.net import links
from repro.sim import hardware

_COMP = hardware.paper_staged()
KNEE_FPS = 25.0


def _fair_codec(**over):
    kw = dict(
        base=hardware.codec_point(entropy=True),
        bits_ladder=(16, 8, 4, 2),
        cell_threshold=0.1e-3,
        cell_stagger=0.05,
        resync_bound=4,
    )
    kw.update(over)
    return CodecConfig(**kw)


def _narrow_cell(bandwidth, cell_capacity=1, num_edges=2):
    return hardware.shared_cell_star(
        num_edges=num_edges,
        edge_capacity=4,
        base_link=dataclasses.replace(links.FIVE_G_EDGE, bandwidth=bandwidth),
        cell_capacity=cell_capacity,
    )


# ---------------------------------------------------------------------------
# the off-switch golden: unlimited cell == private spokes, both engines
# ---------------------------------------------------------------------------


def _assert_same_fleet(a, b, ctx):
    assert a.events == b.events, ctx
    assert a.duration == b.duration, ctx
    for ca, cb in zip(a.clients, b.clients):
        assert ca.edge == cb.edge, ctx
        assert ca.total_wait == cb.total_wait, ctx
        assert ca.stats.processed == cb.stats.processed, ctx
        assert ca.stats.duration == cb.stats.duration, ctx
    assert [e.admitted for e in a.edges] == [e.admitted for e in b.edges]
    assert [e.busy_time for e in a.edges] == [e.busy_time for e in b.edges]


@settings(max_examples=6, deadline=None)
@given(
    st.integers(min_value=2, max_value=8),  # num_clients
    st.integers(min_value=20, max_value=40),  # num_frames
    st.integers(min_value=0, max_value=3),  # seed
)
def test_unlimited_cell_is_private_fleet_bit_for_bit(
    num_clients, num_frames, seed
):
    """``cell_capacity=0`` admits everything with literal-0.0 waits, so
    every float in the run must be untouched — the contention machinery
    proves itself absent."""
    private = hardware.fleet_star(num_edges=2, edge_capacity=4)
    unlimited = hardware.shared_cell_star(
        num_edges=2, edge_capacity=4, cell_capacity=0
    )
    kw = dict(
        comp=_COMP,
        num_clients=num_clients,
        num_frames=num_frames,
        seed=seed,
        dispatch="latency_weighted",
    )
    for eng in ("object", "vector"):
        a = run_fleet(private, engine=eng, cache=PlanCache(), **kw)
        b = run_fleet(unlimited, engine=eng, cache=PlanCache(), **kw)
        _assert_same_fleet(a, b, ctx=eng)
        # the unlimited cell still COUNTS traffic — it just never queues
        (cell,) = b.links
        assert cell.capacity == 0
        assert cell.admitted > 0 and cell.busy_time > 0.0
        assert cell.contended == 0 and cell.total_wait == 0.0
    assert a.events > 0  # the golden is not vacuous


def test_contended_cell_engines_identical():
    """Contention ARMED (capacity 1, narrow cell): both engines must
    still agree on everything, including the cell's own counters."""
    topo = _narrow_cell(15e6)
    kw = dict(
        comp=_COMP,
        num_clients=8,
        num_frames=40,
        seed=7,
        dispatch="latency_weighted",
        codec=_fair_codec(),
    )
    ro = run_fleet(topo, engine="object", cache=PlanCache(), **kw)
    rv = run_fleet(topo, engine="vector", cache=PlanCache(), **kw)
    _assert_same_fleet(ro, rv, ctx="contended")
    assert ro.links == rv.links  # LinkLoad dataclass equality
    (cell,) = ro.links
    assert cell.contended > 0 and cell.total_wait > 0.0


# ---------------------------------------------------------------------------
# wire-time conservation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cell_capacity", [0, 1, 2])
def test_wire_time_conserved_under_contention(cell_capacity):
    """Queueing delays transmissions; it never changes their service
    time.  With a fixed codec (no adaptation, so every client's plan is
    pinned) the cell's busy_time must equal each client's per-frame
    wire seconds times the frames it actually shipped — at ANY
    capacity, congested or not."""
    topo = _narrow_cell(15e6, cell_capacity=cell_capacity)
    r = run_fleet(
        topo,
        comp=_COMP,
        num_clients=6,
        num_frames=40,
        seed=1,
        dispatch="latency_weighted",
    )
    (cell,) = r.links
    expected = 0.0
    for c in r.clients:
        per_frame = sum(w for _, _, w in c.plan.wire_by_link)
        expected += per_frame * len(c.stats.processed)
    assert cell.busy_time == pytest.approx(expected, rel=1e-9)
    # every processed frame admits one aggregated transmission per
    # direction that crosses the medium (plan_media groups hops)
    admits = 0
    for c in r.clients:
        dirs = {dwn for _, dwn, w in c.plan.wire_by_link if w > 0.0}
        admits += len(dirs) * len(c.stats.processed)
    assert cell.admitted == admits


def test_shared_link_admit_semantics():
    """The slot algebra itself: uncontended admits return literal 0.0
    (not a float round-trip), contended admits return the exact extra
    delay, and capacity 0 never queues."""
    free = SharedLink(name="cell", capacity=1)
    # due covers the service: free slot, no wait, stats still counted
    assert free.admit(due=1.0, service=0.25) == 0.0
    # a second admit due at the same time must queue behind the first
    w = free.admit(due=1.0, service=0.25)
    assert w == pytest.approx(0.25)
    assert free.admitted == 2 and free.contended == 1
    assert free.busy_time == pytest.approx(0.5)
    unlimited = SharedLink(name="cell", capacity=0)
    for _ in range(16):
        assert unlimited.admit(due=1.0, service=0.5) == 0.0
    assert unlimited.contended == 0 and unlimited.admitted == 16


def test_build_media_groups_links_by_medium():
    topo = hardware.shared_cell_star(num_edges=3, cell_capacity=2)
    media = build_media(topo)
    assert set(media) == {"cell0"}
    assert media["cell0"].capacity == 2
    assert not build_media(hardware.fleet_star(num_edges=3))


# ---------------------------------------------------------------------------
# knee monotonicity in cell bandwidth
# ---------------------------------------------------------------------------


def _knee(points, threshold=KNEE_FPS):
    knee = 0
    for p in points:
        if p.fps >= threshold:
            knee = max(knee, p.num_clients)
    return knee


def test_capacity_knee_monotone_in_cell_bandwidth():
    """A narrower cell can never sustain more clients: the 25 fps knee
    is non-increasing as bandwidth shrinks.  Fixed codec so the only
    moving part is the wire."""
    cfg = CodecConfig(base=hardware.codec_point(), adapt=False)
    knees = []
    for bw in (60e6, 6e6, 3e6):
        pts = capacity_sweep(
            _narrow_cell(bw),
            _COMP,
            (1, 2, 4, 6),
            num_frames=40,
            dispatch="latency_weighted",
            codec=cfg,
        )
        knees.append(_knee(pts))
    assert knees == sorted(knees, reverse=True), knees
    # the sweep spans both regimes: uncontended at the top, saturated
    # at the bottom — otherwise monotonicity is vacuous
    assert knees[0] > knees[-1]


# ---------------------------------------------------------------------------
# fairness under equal classes
# ---------------------------------------------------------------------------


def test_fair_rate_control_bounds_served_frame_spread():
    """Equal clients on a congested cell: slotted FIFO admission plus
    the fair rate ladder must keep served-frame counts balanced — no
    client starves to feed another."""
    r = run_fleet(
        _narrow_cell(15e6),
        comp=_COMP,
        num_clients=10,
        num_frames=60,
        seed=3,
        dispatch="latency_weighted",
        codec=_fair_codec(),
    )
    served = [len(c.stats.processed) for c in r.clients]
    assert min(served) > 0  # nobody starved
    assert max(served) / min(served) <= 1.5, served
    # the run is genuinely congested, or the bound proves nothing
    (cell,) = r.links
    assert cell.contended > 0 and r.drop_rate > 0.0


def test_fairness_heaviest_payload_backs_off_first():
    """The cell EWMA weights waits by the client's wire ratio, so on a
    mixed cell the heavy (raw-leaning) operating points shed first:
    with fair control armed, the mean final payload must come DOWN vs
    the fairness-off run on the same congested cell."""
    kw = dict(
        comp=_COMP,
        num_clients=8,
        num_frames=60,
        seed=2,
        dispatch="latency_weighted",
    )
    blind = run_fleet(
        _narrow_cell(15e6),
        codec=_fair_codec(cell_threshold=float("inf")),
        cache=PlanCache(),
        **kw,
    )
    fair = run_fleet(
        _narrow_cell(15e6),
        codec=_fair_codec(),
        cache=PlanCache(),
        **kw,
    )
    assert fair.mean_uplink_bytes < blind.mean_uplink_bytes
    # and the payload cut buys real time: less cell queueing overall
    assert fair.links[0].total_wait < blind.links[0].total_wait
    assert math.isfinite(fair.mean_loop_time)
