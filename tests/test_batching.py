"""Edge batching: golden batch-size-1 equivalences, fused-batch event
mechanics, determinism under gather windows, and the capacity shift.

The acceptance contracts:
* ``BatchingSlotServer`` with batches of one (zero gather window)
  reproduces ``SlotServer`` event for event, and a batching fleet with a
  zero window reproduces the unbatched fleet frame for frame;
* the batched Pallas kernels at B=1 match the unbatched kernels
  bit-for-bit, and match their pure-jnp oracles;
* a gathering window actually fuses synchronized clients, and the fused
  service time follows ``BatchServiceModel.batch_time`` exactly;
* batching runs are pure functions of their seed for any gather window.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster import run_fleet
from repro.cluster.dispatch import DispatchContext, make_dispatch
from repro.cluster.events import (
    AdaptiveWindow,
    BatchingSlotServer,
    EventQueue,
    LinkTable,
    SlotServer,
)
from repro.core.costengine import BatchServiceModel
from repro.core.offload import Link, Policy, Tier, Topology, WrapperModel
from repro.core.stages import CLIENT, DataItem, Stage, StagedComputation
from repro.kernels import ops, pso_ref, pso_update as kmod, ref
from repro.kernels import render_score as rs_kernel
from repro.sim import hardware


def _comp(n_stages=4, frame_bytes=500_000, flops=5e9):
    sources = (
        DataItem("frame", frame_bytes, CLIENT),
        DataItem("h_prev", 108, CLIENT),
    )
    stages = []
    prev = "frame"
    for i in range(n_stages):
        out = DataItem(f"x{i}", 20_000)
        stages.append(
            Stage(
                name=f"s{i}",
                flops=flops / n_stages,
                inputs=(prev, "h_prev") if i == 0 else (prev,),
                outputs=(out,),
                parallel_fraction=0.95,
            )
        )
        prev = out.name
    return StagedComputation("test", sources, tuple(stages), (prev,))


def _star(num_edges=2, capacity=1, latency=2e-3, jitter=0.0, accel=0.5e12,
          batching=False, batch_overhead=0.0, batch_marginal=0.2):
    hub = Tier("hub", 20e9, 20e9, has_accelerator=False)
    spokes = [
        (
            f"edge_{i}",
            Tier(
                f"edge_{i}",
                accel,
                40e9,
                capacity=capacity,
                batching=batching,
                batch_overhead=batch_overhead,
                batch_marginal=batch_marginal,
            ),
            Link(f"link_{i}", 117e6, latency * (1 + 0.1 * i), jitter),
        )
        for i in range(num_edges)
    ]
    return Topology.star(("hub", hub), spokes, wrapper=WrapperModel())


# ---------------------------------------------------------------------------
# golden: batch size 1 == the unbatched server / kernel / fleet
# ---------------------------------------------------------------------------


def test_batching_server_with_batches_of_one_matches_slot_server():
    """Zero gather window: every submission is its own batch, served
    synchronously — (start, finish) pairs and stats identical to the
    FIFO SlotServer for the same admission sequence."""
    q = EventQueue()
    plain = SlotServer("e", capacity=2)
    fused = BatchingSlotServer(
        "e", capacity=2, queue=q, model=BatchServiceModel(), gather_window=0.0
    )
    schedule = [(0.0, 1.0), (0.0, 1.0), (0.0, 0.5), (1.2, 0.3), (2.0, 1.0)]
    got_plain, got_fused = [], []
    for arrival, service in schedule:
        plain.submit(arrival, service, lambda s, f: got_plain.append((s, f)))
        fused.submit(arrival, service, lambda s, f: got_fused.append((s, f)))
    assert got_fused == got_plain
    assert fused.admitted == plain.admitted
    assert fused.busy_time == plain.busy_time
    assert fused.total_wait == plain.total_wait
    assert fused.mean_wait == plain.mean_wait
    assert fused.batches == len(schedule)  # one per request
    assert fused.mean_batch_size == 1.0
    # both enforce time-ordered admissions
    with pytest.raises(ValueError):
        fused.submit(0.5, 1.0, lambda s, f: None)


@pytest.mark.parametrize("seed", [0, 7])
def test_fleet_with_zero_gather_window_matches_unbatched_fleet(seed):
    """batching=True + zero window must reproduce the plain fleet frame
    for frame (jittered links, so rng consumption must line up too)."""
    comp = hardware.paper_staged()
    topo = hardware.fleet_star(num_edges=2, edge_capacity=2)
    plain = run_fleet(topo, comp, 6, num_frames=60, seed=seed, batching=False)
    fused = run_fleet(
        topo, comp, 6, num_frames=60, seed=seed, batching=True,
        gather_window=0.0,
    )
    for a, b in zip(plain.clients, fused.clients):
        assert a.stats.processed == b.stats.processed
        assert a.stats.duration == b.stats.duration
        assert a.total_wait == b.total_wait
        assert a.plan.total_time == b.plan.total_time
    assert [e.admitted for e in plain.edges] == [e.admitted for e in fused.edges]


CONSTS = dict(inertia=0.7298, cognitive=1.49618, social=1.49618,
              velocity_clip=0.5)


def _pso_inputs(b, n, d, seed=0):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 7)
    lo = -jnp.abs(jax.random.normal(ks[0], (d,))) - 0.5
    hi = jnp.abs(jax.random.normal(ks[1], (d,))) + 0.5
    span = hi - lo
    x = lo + jax.random.uniform(ks[2], (b, n, d)) * span
    v = jax.random.normal(ks[3], (b, n, d)) * 0.1
    pb = lo + jax.random.uniform(ks[4], (b, n, d)) * span
    gb = pb[:, 0]
    r1 = jax.random.uniform(ks[5], (b, n, d))
    r2 = jax.random.uniform(ks[6], (b, n, d))
    return x, v, pb, gb, r1, r2, lo, hi


def test_batched_pso_update_b1_bit_for_bit_and_matches_ref():
    """The B=1 slice of the fused kernel IS the unbatched kernel — exact
    array equality, not allclose — and both match the pso_ref oracle."""
    args = _pso_inputs(1, 16, 32, seed=3)
    bx, bv = kmod.pso_update_batched(*args, **CONSTS)
    x, v, pb, gb, r1, r2, lo, hi = args
    ux, uv = kmod.pso_update(x[0], v[0], pb[0], gb[0], r1[0], r2[0], lo, hi,
                             **CONSTS)
    assert np.array_equal(np.asarray(bx[0]), np.asarray(ux))
    assert np.array_equal(np.asarray(bv[0]), np.asarray(uv))
    rx, rv = pso_ref.pso_update(x[0], v[0], pb[0], gb[0], r1[0], r2[0], lo, hi,
                                **CONSTS)
    np.testing.assert_allclose(np.asarray(bx[0]), np.asarray(rx),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(bv[0]), np.asarray(rv),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("b,n,d", [(2, 8, 32), (3, 16, 16)])
def test_batched_pso_update_matches_batched_oracle_and_vmap(b, n, d):
    args = _pso_inputs(b, n, d, seed=b)
    gx, gv = kmod.pso_update_batched(*args, **CONSTS)
    rx, rv = pso_ref.pso_update_batched(*args, **CONSTS)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(rx),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(gv), np.asarray(rv),
                               rtol=1e-6, atol=1e-6)
    vx, vv = kmod.pso_update_batched(*args, path="vmap", **CONSTS)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(vx),
                               rtol=1e-6, atol=1e-6)
    with pytest.raises(ValueError):
        kmod.pso_update_batched(*args, path="nope", **CONSTS)
    # every slice of the fused launch equals that swarm run alone
    x, v, pb, gb, r1, r2, lo, hi = args
    for i in range(b):
        ux, _ = kmod.pso_update(x[i], v[i], pb[i], gb[i], r1[i], r2[i],
                                lo, hi, **CONSTS)
        assert np.array_equal(np.asarray(gx[i]), np.asarray(ux))


def _render_inputs(b, n, s, p, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    centers = jax.random.normal(ks[0], (b, n, s, 3)) * 0.1 + jnp.array(
        [0.0, 0.0, 0.5]
    )
    radii = jnp.abs(jax.random.normal(ks[1], (b, n, s, 1))) * 0.05 + 0.02
    spheres = jnp.concatenate([centers, radii], axis=-1)
    rays = jnp.concatenate(
        [jax.random.normal(ks[2], (b, p, 2)) * 0.2, jnp.ones((b, p, 1))],
        axis=-1,
    )
    depth = jnp.abs(jax.random.normal(ks[3], (b, p))) * 0.3 + 0.3
    mask = (jax.random.uniform(ks[4], (b, p)) > 0.3).astype(jnp.float32)
    return spheres, rays, depth, mask


@pytest.mark.parametrize("b", [1, 3])
def test_batched_render_score_slices_bit_for_bit(b):
    """Each client's row of the fused evaluation equals the unbatched
    kernel on that client alone (exact), and matches the jnp oracle."""
    spheres, rays, depth, mask = _render_inputs(b, 16, 8, 600, seed=b)
    out = ops.render_score_batched(spheres, rays, depth, mask)
    assert out.shape == (b, 16)
    for i in range(b):
        one = ops.render_score(spheres[i], rays[i], depth[i], mask[i])
        assert np.array_equal(np.asarray(out[i]), np.asarray(one))
        oracle = ref.render_score(spheres[i], rays[i], depth[i], mask[i])
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(oracle),
                                   rtol=1e-5, atol=1e-5)


def test_batched_render_score_sums_padded_grid():
    """The raw batched kernel on already-padded shapes: B=1 equals the
    unbatched kernel's sums exactly."""
    spheres, rays, depth, mask = _render_inputs(1, 8, 8, 1024, seed=9)
    fused = rs_kernel.render_score_sums_batched(
        spheres, rays, depth, mask, block_n=8, block_p=512
    )
    solo = rs_kernel.render_score_sums(
        spheres[0], rays[0], depth[0], mask[0], block_n=8, block_p=512
    )
    assert np.array_equal(np.asarray(fused[0]), np.asarray(solo))


# ---------------------------------------------------------------------------
# fused-batch mechanics
# ---------------------------------------------------------------------------


def test_gather_window_fuses_and_prices_batch_time_exactly():
    """Three requests inside one window become ONE batch on one slot,
    finishing together at exactly model.batch_time; a request outside
    the window starts a fresh batch."""
    q = EventQueue()
    model = BatchServiceModel(launch_overhead=1e-3, marginal_fraction=0.25)
    srv = BatchingSlotServer(
        "e", capacity=4, queue=q, model=model, gather_window=10e-3
    )
    got = []
    for arrival, service in [(0.0, 8e-3), (4e-3, 12e-3), (9e-3, 4e-3)]:
        q.schedule(
            arrival,
            lambda a=arrival, s=service: srv.submit(
                a, s, lambda st, fi: got.append((st, fi))
            ),
        )
    # outside the first window: gathers alone, serves on a free slot
    q.schedule(30e-3, lambda: srv.submit(
        30e-3, 5e-3, lambda st, fi: got.append((st, fi))))
    q.run()
    t_batch = model.batch_time([8e-3, 12e-3, 4e-3])
    assert t_batch == pytest.approx(1e-3 + 12e-3 + 0.25 * 12e-3)
    assert got[0] == got[1] == got[2]  # one fused launch
    start, finish = got[0]
    assert start == pytest.approx(10e-3)  # window close
    assert finish == pytest.approx(10e-3 + t_batch)
    # the straggler forms its own batch of one: solo time, no overhead
    start2, finish2 = got[3]
    assert start2 == pytest.approx(40e-3)
    assert finish2 == pytest.approx(45e-3)
    assert srv.batches == 2
    assert srv.mean_batch_size == 2.0
    assert srv.busy_time == pytest.approx(t_batch + 5e-3)


def test_incompatible_keys_do_not_fuse():
    q = EventQueue()
    srv = BatchingSlotServer(
        "e", capacity=2, queue=q,
        model=BatchServiceModel(marginal_fraction=0.0), gather_window=5e-3,
    )
    got = {}
    srv.submit(0.0, 2e-3, lambda s, f: got.setdefault("a", (s, f)), key="a")
    srv.submit(1e-3, 2e-3, lambda s, f: got.setdefault("b", (s, f)), key="b")
    assert srv.open_batch_size() == 2
    assert srv.open_batch_size("a") == 1 and srv.open_batch_size("b") == 1
    assert srv.load(1e-3) == 2  # gathering requests count as in flight

    q.run()
    assert srv.batches == 2  # one per key: different kernels cannot fuse
    assert got["a"] == (5e-3, 7e-3)
    assert got["b"] == (6e-3, 8e-3)


def test_batch_affinity_prefers_open_batches_over_shorter_queues():
    """The mid-run (re)dispatch contract, exercised directly: while a
    batch is actually gathering, affinity overrides join-the-shortest-
    queue; with no batch open it IS least_queue (which is all t=0
    admission-time placement in ``run_fleet`` ever sees)."""
    topo = _star(num_edges=2, batching=True)
    comp = _comp()
    q = EventQueue()
    servers = {
        e: BatchingSlotServer(
            e, capacity=2, queue=q, model=BatchServiceModel(),
            gather_window=5e-3,
        )
        for e in ("edge_0", "edge_1")
    }
    ctx = DispatchContext(
        topo=topo,
        comp=comp,
        policy=Policy.AUTO,
        edges=["edge_0", "edge_1"],
        servers=servers,
        link_table=LinkTable(topo),
        assignments={"edge_0": 0, "edge_1": 2},
    )
    disp = make_dispatch("batch_affinity")
    # no batch open anywhere: exact least_queue fallback
    assert disp.assign(0, ctx) == "edge_0"
    # a COMPATIBLE batch gathering on the *busier* edge beats the
    # shorter queue (run_fleet submits under key=comp.name)
    servers["edge_1"].submit(0.0, 2e-3, lambda s, f: None, key=comp.name)
    assert servers["edge_1"].open_batch_size(comp.name) == 1
    assert disp.assign(1, ctx) == "edge_1"
    # a foreign-key batch cannot be joined — it is just queue ahead of
    # us, so it must NOT attract this client's computation
    servers["edge_0"].submit(1e-3, 2e-3, lambda s, f: None, key="other")
    assert servers["edge_0"].open_batch_size(comp.name) == 0
    assert disp.assign(2, ctx) == "edge_1"
    # windows close and the batches drain: back to least_queue
    q.run()
    ctx.now = 1.0
    assert disp.assign(3, ctx) == "edge_0"


def test_batching_shifts_the_capacity_knee():
    """The acceptance shape at test scale: a saturating unbatched star
    vs the same star with fused serving — batching must strictly reduce
    drops and keep per-frame latency at the batch-amortized level."""
    comp = _comp(flops=40e9)  # ~80 ms of edge service: saturates fast
    plain = run_fleet(
        _star(num_edges=1, capacity=1), comp, 8, num_frames=120,
    )
    fused = run_fleet(
        _star(num_edges=1, capacity=1, batching=True), comp, 8,
        num_frames=120, gather_window=5e-3,
    )
    assert fused.drop_rate < plain.drop_rate
    assert fused.mean_achieved_fps > plain.mean_achieved_fps
    assert fused.p99_loop_time < plain.p99_loop_time
    assert any(e.mean_batch_size > 1.5 for e in fused.edges)


def test_run_fleet_batching_override_and_tier_declaration_agree():
    """batching=True on a plain topology == the same topology whose
    tiers declare batching (the override just bakes the flag in)."""
    comp = _comp(flops=40e9)
    declared = run_fleet(
        _star(num_edges=1, capacity=1, batching=True, batch_marginal=0.35),
        comp, 6, num_frames=60, gather_window=5e-3,
    )
    forced = run_fleet(
        _star(num_edges=1, capacity=1, batch_marginal=0.35), comp, 6,
        num_frames=60, gather_window=5e-3, batching=True,
    )
    for a, b in zip(declared.clients, forced.clients):
        assert a.stats.processed == b.stats.processed
    assert [dataclasses.astuple(e) for e in declared.edges] == [
        dataclasses.astuple(e) for e in forced.edges
    ]


# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("window", [1e-3, 8e-3])
def test_batching_fleet_is_seed_stable_per_gather_window(window):
    """Same seed => identical FleetResult, for every gather window; a
    different seed must actually change the (jittered) run."""
    comp = hardware.paper_staged()
    topo = hardware.fleet_star(num_edges=2, edge_capacity=2, batching=True)
    a = run_fleet(topo, comp, 8, num_frames=80, seed=3, gather_window=window)
    b = run_fleet(topo, comp, 8, num_frames=80, seed=3, gather_window=window)
    assert a.clients == b.clients
    assert a.edges == b.edges
    c = run_fleet(topo, comp, 8, num_frames=80, seed=4, gather_window=window)
    assert a.clients != c.clients


def test_gather_window_changes_events_but_not_determinism():
    comp = hardware.paper_staged()
    topo = hardware.fleet_star(num_edges=2, edge_capacity=2, batching=True)
    narrow = run_fleet(topo, comp, 8, num_frames=80, seed=3, gather_window=1e-3)
    wide = run_fleet(topo, comp, 8, num_frames=80, seed=3, gather_window=8e-3)
    # the window is a real modeling knob: the event history must differ
    assert narrow.clients != wide.clients


def test_event_queue_breaks_ties_by_schedule_order_even_when_nested():
    """Direct tie-breaking contract: same-time events run in scheduling
    order, including events scheduled *during* a tied event at the same
    timestamp (they run after the already-queued ties)."""
    q = EventQueue()
    out = []
    q.schedule(1.0, lambda: (out.append("a"),
                             q.schedule(1.0, lambda: out.append("a.child"))))
    q.schedule(1.0, lambda: out.append("b"))
    q.schedule(0.5, lambda: out.append("early"))
    q.run()
    assert out == ["early", "a", "b", "a.child"]
    assert q.now == 1.0
    # scheduling into the past clamps to `now` instead of time-travel
    q.schedule(0.25, lambda: out.append("late"))
    q.run()
    assert out[-1] == "late" and q.now == 1.0


# ---------------------------------------------------------------------------
# adaptive gather windows (AdaptiveWindow)
# ---------------------------------------------------------------------------


def test_adaptive_window_validates_its_parameters():
    with pytest.raises(ValueError):
        AdaptiveWindow(alpha=0.0)
    with pytest.raises(ValueError):
        AdaptiveWindow(alpha=1.2)
    with pytest.raises(ValueError):
        AdaptiveWindow(idle_factor=0.0)
    AdaptiveWindow(alpha=1.0, idle_factor=2.5)  # boundary values are legal


def test_adaptive_dense_arrivals_reproduce_the_fixed_window():
    """Arrivals landing well inside one window keep the EWMA below the
    idle threshold, so the adaptive server gathers exactly like the
    fixed-window one — event for event, stat for stat."""
    window = 10e-3
    qa, qf = EventQueue(), EventQueue()
    model = BatchServiceModel(launch_overhead=1e-3, marginal_fraction=0.25)
    fixed = BatchingSlotServer(
        "e", capacity=2, queue=qf, model=model, gather_window=window
    )
    adapt = BatchingSlotServer(
        "e", capacity=2, queue=qa, model=model, gather_window=window,
        adaptive=AdaptiveWindow(alpha=0.25, idle_factor=1.0),
    )
    schedule = [(i * 2e-3, 5e-3) for i in range(12)]  # 2 ms apart
    got_f, got_a = [], []
    for srv, q, got in ((fixed, qf, got_f), (adapt, qa, got_a)):
        for arrival, service in schedule:
            q.schedule(
                arrival,
                lambda a=arrival, s=service, sv=srv, g=got: sv.submit(
                    a, s, lambda st, fi, g=g: g.append((st, fi))
                ),
            )
        q.run()
    assert got_a == got_f
    assert adapt.batches == fixed.batches
    assert adapt.busy_time == fixed.busy_time
    assert adapt.total_wait == fixed.total_wait


def test_adaptive_sparse_arrivals_serve_immediately():
    """Arrivals far sparser than the window drive the EWMA over the
    idle threshold: new batches serve as batches of one with NO window
    dwell, so every member finishes earlier than under the fixed
    window, and no fusing ever happens."""
    window = 10e-3
    gap = 100e-3  # 10x the window: unambiguously idle
    qa, qf = EventQueue(), EventQueue()
    model = BatchServiceModel(launch_overhead=1e-3, marginal_fraction=0.25)
    fixed = BatchingSlotServer(
        "e", capacity=2, queue=qf, model=model, gather_window=window
    )
    adapt = BatchingSlotServer(
        "e", capacity=2, queue=qa, model=model, gather_window=window,
        adaptive=AdaptiveWindow(alpha=0.25, idle_factor=1.0),
    )
    schedule = [(i * gap, 5e-3) for i in range(6)]
    got_f, got_a = [], []
    for srv, q, got in ((fixed, qf, got_f), (adapt, qa, got_a)):
        for arrival, service in schedule:
            q.schedule(
                arrival,
                lambda a=arrival, s=service, sv=srv, g=got: sv.submit(
                    a, s, lambda st, fi, g=g: g.append((st, fi))
                ),
            )
        q.run()
    assert adapt.batches == fixed.batches == len(schedule)
    # the very first submission has no inter-arrival sample yet, so it
    # still gathers the full window; every later one serves on arrival
    assert got_a[0] == got_f[0]
    for (sa, fa), (sf, ff), (arrival, _svc) in zip(
        got_a[1:], got_f[1:], schedule[1:]
    ):
        assert sa == arrival  # no dwell
        assert sf == arrival + window  # fixed window always dwells
        assert fa < ff


def test_adaptive_none_is_the_exact_off_switch():
    """``adaptive_window=None`` at the fleet level must reproduce the
    fixed-window batching fleet bit for bit — the golden off-switch —
    while an armed AdaptiveWindow on the same sparse-ish fleet is a
    real knob (it changes the event history)."""
    comp = hardware.paper_staged()
    topo = hardware.fleet_star(num_edges=2, edge_capacity=2, batching=True)
    kwargs = dict(num_frames=60, seed=5, gather_window=2e-3)
    base = run_fleet(topo, comp, 6, **kwargs)
    off = run_fleet(topo, comp, 6, adaptive_window=None, **kwargs)
    for a, b in zip(base.clients, off.clients):
        assert a.stats.processed == b.stats.processed
        assert a.stats.duration == b.stats.duration
        assert a.total_wait == b.total_wait
    assert [e.admitted for e in base.edges] == [e.admitted for e in off.edges]
    assert [e.batches for e in base.edges] == [e.batches for e in off.edges]
    armed = run_fleet(
        topo, comp, 6,
        adaptive_window=AdaptiveWindow(alpha=0.25, idle_factor=1.0),
        **kwargs,
    )
    assert armed.clients != base.clients
