"""Topology/cost-engine/planner stack: N-tier generalization.

Covers the refactor's contracts:
* the two-tier ``Environment`` shim reproduces the original hard-wired
  client/server arithmetic bit-for-bit (a literal replica of the seed
  ``evaluate_plan`` is kept here as the reference);
* the chain-DP planner matches exhaustive search on every small
  topology/chain it claims to handle exactly;
* 3-tier chains plan end-to-end through Policy.AUTO;
* per-leg latency records make jitter resampling exact.
"""

import itertools
import random

import pytest

from repro.core import offload
from repro.core.costengine import CostEngine
from repro.core.offload import (
    Environment,
    Link,
    Policy,
    Tier,
    Topology,
    WrapperModel,
)
from repro.core.planners import PLANNERS, ChainDPPlanner
from repro.core.stages import CLIENT, SERVER, DataItem, Stage, StagedComputation
from repro.net.transport import Transport


# ---------------------------------------------------------------------------
# fixtures / builders
# ---------------------------------------------------------------------------


def _comp(n_stages=4, frame_bytes=500_000, flops=5e9):
    """The seed test_offload.py computation, verbatim."""
    sources = (
        DataItem("frame", frame_bytes, CLIENT),
        DataItem("h_prev", 108, CLIENT),
    )
    stages = []
    prev = "frame"
    for i in range(n_stages):
        out = DataItem(f"x{i}", 20_000)
        stages.append(
            Stage(
                name=f"s{i}",
                flops=flops / n_stages,
                inputs=(prev, "h_prev") if i == 0 else (prev,),
                outputs=(out,),
                parallel_fraction=0.95,
            )
        )
        prev = out.name
    return StagedComputation("test", sources, tuple(stages), (prev,))


def _env(lat=0.3e-3, bw=117e6, fast=2e12, slow=0.3e12):
    return Environment(
        client=Tier("client", slow, 30e9),
        server=Tier("server", fast, 60e9),
        link=Link("l", bw, lat),
        wrapper=WrapperModel(),
    )


def _chain_comp(n_stages, rng=None, tail_source=True):
    """A linear chain: frame -> s0 -> ... -> s{n-1}, optional late source."""
    rnd = rng or random.Random(0)
    sources = [DataItem("frame", rnd.randrange(1_000, 800_000), CLIENT)]
    if tail_source:
        sources.append(DataItem("seed", rnd.randrange(8, 256), CLIENT))
    stages = []
    prev = "frame"
    for i in range(n_stages):
        out = DataItem(f"x{i}", rnd.randrange(64, 120_000))
        inputs = (prev,)
        if tail_source and i == n_stages - 1:
            inputs = (prev, "seed")
        stages.append(
            Stage(
                name=f"s{i}",
                flops=rnd.uniform(1e8, 4e9),
                inputs=inputs,
                outputs=(out,),
                parallel_fraction=rnd.uniform(0.8, 1.0),
            )
        )
        prev = out.name
    return StagedComputation("chain", tuple(sources), tuple(stages), (prev,))


def _rand_tier(name, rnd):
    return Tier(
        name,
        accel_flops=rnd.uniform(0.05e12, 5e12),
        scalar_flops=rnd.uniform(10e9, 80e9),
        dispatch_overhead=rnd.uniform(10e-6, 200e-6),
    )


def _rand_link(name, rnd):
    return Link(
        name,
        bandwidth=rnd.uniform(5e6, 1e9),
        latency=rnd.uniform(1e-4, 40e-3),
    )


def _rand_topology(k, rnd, shape="chain"):
    tiers = [(f"t{i}", _rand_tier(f"t{i}", rnd)) for i in range(k)]
    if shape == "chain" or k == 2:
        return Topology.chain(
            tiers,
            [_rand_link(f"l{i}", rnd) for i in range(k - 1)],
            wrapper=WrapperModel(),
        )
    return Topology.star(
        tiers[0],
        [(n, t, _rand_link(f"l{n}", rnd)) for n, t in tiers[1:]],
        wrapper=WrapperModel(),
    )


# ---------------------------------------------------------------------------
# bit-for-bit compatibility with the seed two-tier arithmetic
# ---------------------------------------------------------------------------


def _seed_evaluate_plan(comp, placements, env):
    """Literal replica of the pre-refactor evaluate_plan (hard-wired
    client/server), kept as the golden reference."""
    comp.validate()
    table = comp.item_table()
    residency = {i.name: {i.origin} for i in comp.sources}

    compute_t = 0.0
    wrapper_t = 0.0
    network_t = 0.0
    up_bytes = 0
    down_bytes = 0

    if not env.wrapped and any(p == SERVER for p in placements):
        raise ValueError("native cannot offload")

    def _stage_compute_time(stage, tier):
        par = stage.flops * stage.parallel_fraction
        ser = stage.flops - par
        accel = tier.accel_flops if tier.has_accelerator else tier.scalar_flops
        return par / accel + ser / tier.scalar_flops + tier.dispatch_overhead

    def _ship(nbytes, to_server):
        nonlocal wrapper_t, network_t, up_bytes, down_bytes
        wrapper_t += 2 * (nbytes / env.wrapper.serialization_bandwidth)
        network_t += nbytes / env.link.bandwidth
        if to_server:
            up_bytes += nbytes
        else:
            down_bytes += nbytes

    for stage, side in zip(comp.stages, placements):
        tier = env.server if side == SERVER else env.client
        if env.wrapped:
            if side == SERVER:
                wrapper_t += 2 * env.wrapper.call_overhead
                network_t += 2 * env.link.latency
            else:
                wrapper_t += env.wrapper.call_overhead
        for name in stage.inputs:
            if side not in residency[name]:
                item = table[name]
                if side == CLIENT:
                    network_t += env.link.latency
                _ship(item.nbytes, to_server=(side == SERVER))
                residency[name].add(side)
            elif env.wrapped and side == CLIENT:
                wrapper_t += table[name].nbytes / env.wrapper.jni_bandwidth
        compute_t += _stage_compute_time(stage, tier)
        for o in stage.outputs:
            residency[o.name] = {side}

    for rname in comp.results:
        if CLIENT not in residency[rname]:
            _ship(table[rname].nbytes, to_server=False)
            residency[rname].add(CLIENT)

    total = compute_t + wrapper_t + network_t
    return (total, compute_t, wrapper_t, network_t, up_bytes, down_bytes)


@pytest.mark.parametrize("lat,bw", [(0.3e-3, 117e6), (20e-3, 6e6)])
def test_two_tier_shim_bit_for_bit(lat, bw):
    """Every plan of the seed 4-stage lattice prices identically (==,
    not approx) through the topology engine."""
    comp = _comp()
    env = _env(lat=lat, bw=bw)
    for placements in itertools.product((CLIENT, SERVER), repeat=4):
        rep = offload.evaluate_plan(comp, placements, env)
        ref = _seed_evaluate_plan(comp, placements, env)
        assert (
            rep.total_time,
            rep.compute_time,
            rep.wrapper_time,
            rep.network_time,
            rep.uplink_bytes,
            rep.downlink_bytes,
        ) == ref


def test_two_tier_shim_bit_for_bit_fused():
    comp = _comp().fused()
    env = _env()
    for placements in ((CLIENT,), (SERVER,)):
        rep = offload.evaluate_plan(comp, placements, env)
        assert rep.total_time == _seed_evaluate_plan(comp, placements, env)[0]


# ---------------------------------------------------------------------------
# chain-DP vs exhaustive
# ---------------------------------------------------------------------------


def test_chain_dp_matches_exhaustive_small_topologies():
    """Property: on every <=4-stage chain over <=3-tier topologies
    (lattice <= 3^4 = 81 <= 2^12 plans) the DP optimum equals the
    exhaustive optimum."""
    rnd = random.Random(0xC0FFEE)
    cases = 0
    for _ in range(40):
        k = rnd.choice((2, 2, 3, 3))
        shape = rnd.choice(("chain", "star"))
        n = rnd.randrange(2, 5)
        topo = _rand_topology(k, rnd, shape)
        comp = _chain_comp(n, rnd, tail_source=rnd.random() < 0.5)
        assert ChainDPPlanner.applicable(comp)
        engine = CostEngine(topo)
        ex = PLANNERS["exhaustive"].plan(comp, engine)
        dp = PLANNERS["chain_dp"].plan(comp, engine)
        assert dp.total_time <= ex.total_time * (1 + 1e-9) + 1e-15
        assert ex.total_time <= dp.total_time * (1 + 1e-9) + 1e-15
        cases += 1
    assert cases == 40


def test_chain_dp_matches_exhaustive_deterministic_plan():
    """On a clearly non-degenerate case the DP returns the same argmin
    placements, not just the same cost."""
    topo = Topology.chain(
        (
            ("device", Tier("device", 8e9, 8e9, has_accelerator=False)),
            ("edge", Tier("edge", 1e12, 40e9)),
            ("cloud", Tier("cloud", 5e12, 60e9)),
        ),
        (Link("5g", 60e6, 8e-3), Link("dcn", 25e9, 10e-6)),
        wrapper=WrapperModel(),
    )
    comp = _chain_comp(4, random.Random(7))
    engine = CostEngine(topo)
    ex = PLANNERS["exhaustive"].plan(comp, engine)
    dp = PLANNERS["chain_dp"].plan(comp, engine)
    assert dp.placements == ex.placements
    assert dp.total_time == ex.total_time


def test_chain_dp_admits_shared_sources_exactly():
    """A source consumed by several stages (the tracker's ``h_prev``
    pattern) used to trip the ``consumed > 1`` guard and silently demote
    to single-crossing.  The residency-augmented DP now admits it AND
    matches exhaustive exactly (the admit side was right; the naive
    per-consumer transfer pricing was what had to go)."""
    src = DataItem("frame", 1_000_000, CLIENT)
    stages = (
        Stage("a", 1e9, ("frame",), (DataItem("y1", 10),), 0.9),
        Stage("b", 1e9, ("frame", "y1"), (DataItem("y2", 10),), 0.9),
    )
    comp = StagedComputation("t", (src,), stages, ("y2",))
    assert ChainDPPlanner.applicable(comp)
    rnd = random.Random(0xBEEF)
    for _ in range(12):
        k = rnd.choice((2, 3))
        topo = _rand_topology(k, rnd, rnd.choice(("chain", "star")))
        engine = CostEngine(topo)
        ex = PLANNERS["exhaustive"].plan(comp, engine)
        dp = PLANNERS["chain_dp"].plan(comp, engine)
        assert dp.total_time == ex.total_time
    # randomized longer chains with a shared early source
    for trial in range(12):
        r2 = random.Random(1000 + trial)
        n = r2.randrange(2, 5)
        sources = (
            DataItem("frame", r2.randrange(1_000, 800_000), CLIENT),
            DataItem("h_prev", r2.randrange(64, 4096), CLIENT),
        )
        sts = []
        prev = "frame"
        for i in range(n):
            out = DataItem(f"x{i}", r2.randrange(64, 120_000))
            inputs = (prev, "h_prev") if i in (0, n - 1) else (prev,)
            sts.append(
                Stage(f"s{i}", r2.uniform(1e8, 4e9), inputs, (out,),
                      r2.uniform(0.8, 1.0))
            )
            prev = out.name
        shared_comp = StagedComputation(
            "shared", sources, tuple(sts), (prev,)
        )
        assert ChainDPPlanner.applicable(shared_comp)
        topo = _rand_topology(r2.choice((2, 3)), r2, "star")
        engine = CostEngine(topo)
        ex = PLANNERS["exhaustive"].plan(shared_comp, engine)
        dp = PLANNERS["chain_dp"].plan(shared_comp, engine)
        assert dp.total_time == ex.total_time


def test_chain_dp_rejects_non_chains():
    """Computations that re-consume a *stage output* (not a source) or
    skip stages still fall outside the DP's domain."""
    src = DataItem("frame", 1_000_000, CLIENT)
    mid = DataItem("y1", 50_000)
    stages = (
        Stage("a", 1e9, ("frame",), (mid,), 0.9),
        Stage("b", 1e9, ("y1",), (DataItem("y2", 10),), 0.9),
        Stage("c", 1e9, ("y1", "y2"), (DataItem("y3", 10),), 0.9),
    )
    comp = StagedComputation("t", (src,), stages, ("y3",))
    assert not ChainDPPlanner.applicable(comp)
    with pytest.raises(ValueError):
        PLANNERS["chain_dp"].plan(comp, CostEngine(_env().as_topology()))


def test_chain_dp_handles_24_stage_chain():
    """The long-pipeline case exhaustive search cannot touch (2^24
    plans): DP plans it and never loses to the single-crossing family."""
    comp = _chain_comp(24, random.Random(3))
    engine = CostEngine(_env().as_topology())
    dp = PLANNERS["chain_dp"].plan(comp, engine)
    sc = PLANNERS["single_crossing"].plan(comp, engine)
    assert dp.total_time <= sc.total_time + 1e-12
    # AUTO dispatch at n=24 routes through the DP (lattice 2^24 > 2^20)
    auto = offload.plan(comp, _env(), Policy.AUTO)
    assert auto.total_time == dp.total_time


# ---------------------------------------------------------------------------
# 3-tier end-to-end
# ---------------------------------------------------------------------------


def test_three_tier_chain_plans_via_auto():
    from repro.sim import hardware

    topo = hardware.three_tier_environment()
    comp = _chain_comp(6, random.Random(11))
    rep = offload.plan(comp, topo, Policy.AUTO)
    assert set(rep.placements) <= {"device", "edge", "cloud"}
    local = offload.plan(comp, topo, Policy.LOCAL)
    forced = offload.plan(comp, topo, Policy.FORCED)
    assert rep.total_time <= local.total_time + 1e-12
    assert rep.total_time <= forced.total_time + 1e-12
    # FORCED targets the fastest remote tier of the chain
    assert set(forced.placements) == {"cloud"}


def test_three_tier_llm_decode_deep_pipeline():
    """serving/edge.py's long decode pipeline is tractable at k=3 via
    the chain DP (3^18 candidate plans — far beyond exhaustive)."""
    from repro.configs import registry
    from repro.serving import edge
    from repro.sim import hardware

    topo = hardware.three_tier_environment()
    ep = edge.plan_decode(
        registry.get("gemma-2b"),
        topo,
        Policy.AUTO,
        granularity="multi_step",
        num_stage_groups=16,
    )
    assert len(ep.report.placements) == 18  # embed + 16 groups + head
    assert set(ep.report.placements) <= {"device", "edge", "cloud"}
    assert ep.tokens_per_second > 0


def test_multi_hop_transfer_charges_every_leg():
    """Shipping device->cloud crosses both links: wire time on each leg,
    envelope latency on each leg, serialization at the ends only."""
    wrapper = WrapperModel()
    l1 = Link("hop1", 10e6, 5e-3)
    l2 = Link("hop2", 100e6, 1e-3)
    topo = Topology.chain(
        (
            ("device", Tier("device", 1e9, 1e9, 0.0, has_accelerator=False)),
            ("edge", Tier("edge", 1e12, 40e9, 0.0)),
            ("cloud", Tier("cloud", 5e12, 60e9, 0.0)),
        ),
        (l1, l2),
        wrapper=wrapper,
    )
    nb = 1_000_000
    comp = StagedComputation(
        "hop",
        (DataItem("x", nb, "device"),),
        (Stage("s0", 1e6, ("x",), (DataItem("y", 10),), 1.0),),
        ("y",),
    )
    rep = CostEngine(topo).evaluate(comp, ("cloud",))
    # envelope: 2 legs per link; payload piggybacks (no extra latency)
    assert [l.link for l in rep.legs] == ["hop1", "hop1", "hop2", "hop2"]
    expected_net = (
        2 * l1.latency + 2 * l2.latency  # envelope
        + nb / l1.bandwidth + nb / l2.bandwidth  # frame up, both legs
        + 10 / l1.bandwidth + 10 / l2.bandwidth  # result down, both legs
    )
    assert rep.network_time == pytest.approx(expected_net, rel=1e-12)
    # serialization: both ends, both transfers; envelope: 2 call overheads
    assert rep.wrapper_time == pytest.approx(
        2 * wrapper.call_overhead + 2 * (nb + 10) / wrapper.serialization_bandwidth,
        rel=1e-12,
    )
    # bytes are accounted per wire hop: the frame crosses two legs away
    # from home, the result two legs toward it
    assert rep.uplink_bytes == 2 * nb and rep.downlink_bytes == 2 * 10

    # an inter-remote hop moving toward home (cloud -> edge) is downlink
    comp2 = StagedComputation(
        "hop2",
        (DataItem("x", nb, "device"),),
        (
            Stage("s0", 1e6, ("x",), (DataItem("y", 50_000),), 1.0),
            Stage("s1", 1e6, ("y",), (DataItem("z", 10),), 1.0),
        ),
        ("z",),
    )
    rep2 = CostEngine(topo).evaluate(comp2, ("cloud", "edge"))
    assert rep2.uplink_bytes == 2 * nb  # device -> cloud, two hops up
    assert rep2.downlink_bytes == 50_000 + 10  # cloud -> edge, edge -> device


# ---------------------------------------------------------------------------
# exact jitter resampling from per-leg records
# ---------------------------------------------------------------------------


def test_legs_account_for_all_latency():
    comp = _comp()
    env = _env(lat=20e-3)
    rep = offload.plan(comp, env, Policy.FORCED)
    # 4 remote invocations x 2 envelope legs; payloads piggyback
    assert len(rep.legs) == 8
    bytes_time = (rep.uplink_bytes + rep.downlink_bytes) / env.link.bandwidth
    assert sum(l.latency for l in rep.legs) + bytes_time == pytest.approx(
        rep.network_time, rel=1e-12
    )


def test_jittered_total_exact_and_deterministic():
    import numpy as np

    comp = _comp()
    # zero jitter: resampling is the identity
    rep = offload.plan(comp, _env(), Policy.FORCED)
    assert rep.jittered_total(np.random.default_rng(0)) == rep.total_time

    # jittered link: resampling replaces exactly the latency legs
    env = Environment(
        client=_env().client,
        server=_env().server,
        link=Link("wifi", 6e6, 20e-3, jitter=12e-3),
        wrapper=WrapperModel(),
    )
    rep = offload.plan(comp, env, Policy.FORCED)
    rng = np.random.default_rng(1)
    draws = [rep.jittered_total(rng) for _ in range(200)]
    floor = rep.total_time - sum(l.latency for l in rep.legs)
    assert all(d >= floor - 1e-12 for d in draws)
    mean = sum(draws) / len(draws)
    assert mean == pytest.approx(rep.total_time, rel=0.15)

    # all-local plan records no legs => identity
    local = offload.plan(comp, env, Policy.LOCAL)
    assert local.legs == ()
    assert local.jittered_total(rng) == local.total_time


def test_link_transfer_time_rng_is_wired():
    import numpy as np

    link = Link("wifi", 6e6, 20e-3, jitter=12e-3)
    det = link.transfer_time(6_000_000)
    assert det == pytest.approx(20e-3 + 1.0)
    rng = np.random.default_rng(0)
    samples = {link.transfer_time(6_000_000, rng) for _ in range(8)}
    assert len(samples) > 1  # actually jittered
    # Transport draws its envelope latency through the same path
    tr = Transport(link, WrapperModel(), seed=0)
    envs = {tr.rpc_envelope_time() for _ in range(8)}
    assert len(envs) > 1


# ---------------------------------------------------------------------------
# topology validation
# ---------------------------------------------------------------------------


def test_topology_rejects_bad_graphs():
    t = Tier("t", 1e12, 40e9)
    with pytest.raises(ValueError):
        Topology(tiers={"a": t}, links={}, home="missing")
    with pytest.raises(ValueError):
        Topology(
            tiers={"a": t, "b": t},
            links={("a", "zz"): Link("l", 1e6, 1e-3)},
            home="a",
        )
    with pytest.raises(ValueError):  # disconnected
        Topology(tiers={"a": t, "b": t}, links={}, home="a")
    topo = Topology.two_tier(t, t, Link("l", 1e6, 1e-3))
    with pytest.raises(ValueError):  # unknown placement tier
        CostEngine(topo).evaluate(_comp(1), ("nowhere",))


def test_star_topology_routes_leaf_to_leaf_through_hub():
    hub = ("dev", Tier("dev", 8e9, 8e9, has_accelerator=False))
    spokes = [
        ("edge_a", Tier("edge_a", 1e12, 40e9), Link("la", 50e6, 4e-3)),
        ("edge_b", Tier("edge_b", 2e12, 40e9), Link("lb", 30e6, 9e-3)),
    ]
    topo = Topology.star(hub, spokes)
    assert topo.path_tiers("edge_a", "edge_b") == ("edge_a", "dev", "edge_b")
    assert [l.name for l in topo.path_links("edge_a", "edge_b")] == ["la", "lb"]
    assert topo.primary_remote() == "edge_b"
