"""Branching-DAG planner correctness (PR 9).

Covers the planner sweep's contracts:

* ``TreeDPPlanner`` matches ``ExhaustivePlanner`` bit-for-bit on every
  randomized out-tree whose plan lattice fits in 512 candidates
  (property-tested — the DP returns ``engine.evaluate`` of its argmin,
  so agreement is exact equality of ``total_time``, not approx);
* on linear chains the tree DP reproduces ``chain_dp`` (a chain is the
  degenerate out-tree) and both match exhaustive;
* the general-DAG fallback (multi-seed exact-cost coordinate descent)
  finds the exhaustive optimum on the registry's true-DAG workload
  over randomized topologies;
* ``SingleCrossingPlanner`` prices the all-home degenerate window
  exactly once (the historical duplicate-evaluation bug);
* ``fused()`` edge cases: passthrough results are not re-emitted,
  zero-flops pipelines fuse with ``parallel_fraction = 0.0``, fusing
  an empty pipeline raises, conditional stages fuse at expected cost;
* ``exec_prob`` validation and expected-cost pricing semantics;
* the workload registry's planner-applicability matrix.
"""

import random

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.costengine import CostEngine
from repro.core.offload import Link, Tier, Topology, WrapperModel
from repro.core.planners import (
    PLANNERS,
    ChainDPPlanner,
    TreeDPPlanner,
    auto_planner,
)
from repro.core.stages import CLIENT, DataItem, Stage, StagedComputation
from repro.core.workloads import (
    WORKLOADS,
    full_gesture,
    multi_hand,
    rgbd_tracking,
    solo_landmark,
    workload_suite,
)

# ---------------------------------------------------------------------------
# randomized builders
# ---------------------------------------------------------------------------


def _rand_tier(name, rnd):
    return Tier(
        name,
        accel_flops=rnd.uniform(0.05e12, 5e12),
        scalar_flops=rnd.uniform(10e9, 80e9),
        dispatch_overhead=rnd.uniform(10e-6, 200e-6),
    )


def _rand_link(name, rnd):
    return Link(
        name,
        bandwidth=rnd.uniform(5e6, 1e9),
        latency=rnd.uniform(1e-4, 40e-3),
    )


def _rand_topology(k, rnd, shape="chain"):
    tiers = [(f"t{i}", _rand_tier(f"t{i}", rnd)) for i in range(k)]
    if shape == "chain" or k == 2:
        return Topology.chain(
            tiers,
            [_rand_link(f"l{i}", rnd) for i in range(k - 1)],
            wrapper=WrapperModel(),
        )
    return Topology.star(
        tiers[0],
        [(n, t, _rand_link(f"l{n}", rnd)) for n, t in tiers[1:]],
        wrapper=WrapperModel(),
    )


def _tree_comp(n, rnd):
    """A random out-forest: every item consumed at most once, every
    stage fed by at most one producer, results pure sinks — exactly
    ``TreeDPPlanner.applicable``'s domain.  Conditional branches get
    ``exec_prob`` below their parent's (validate()'s coherence rule)."""
    sources = [DataItem("frame", rnd.randrange(1_000, 600_000), CLIENT)]
    # unconsumed stage outputs: (item name, producing stage index)
    open_outputs = []
    stage_prob = []
    stages = []
    for i in range(n):
        if i == 0 or (not open_outputs) or rnd.random() < 0.25:
            # a new root: feeds off a fresh source (consumed once)
            src = DataItem(f"src{i}", rnd.randrange(64, 200_000), CLIENT)
            sources.append(src)
            inputs = [src.name]
            parent_prob = 1.0
        else:
            name, pi = open_outputs.pop(rnd.randrange(len(open_outputs)))
            inputs = [name]
            parent_prob = stage_prob[pi]
            if rnd.random() < 0.3:  # optional fresh side source
                src = DataItem(f"side{i}", rnd.randrange(16, 4_096), CLIENT)
                sources.append(src)
                inputs.append(src.name)
        p = parent_prob if rnd.random() < 0.6 else parent_prob * rnd.uniform(
            0.2, 1.0
        )
        outs = tuple(
            DataItem(f"x{i}_{j}", rnd.randrange(64, 120_000))
            for j in range(rnd.choice((1, 1, 2)))
        )
        stages.append(
            Stage(
                name=f"s{i}",
                flops=rnd.uniform(1e8, 4e9),
                inputs=tuple(inputs),
                outputs=outs,
                parallel_fraction=rnd.uniform(0.7, 1.0),
                exec_prob=p,
            )
        )
        stage_prob.append(p)
        for o in outs:
            open_outputs.append((o.name, i))
    # results: the leftover unconsumed outputs (pure sinks), at least one
    results = tuple(name for name, _ in open_outputs) or (
        stages[-1].outputs[0].name,
    )
    comp = StagedComputation("rand_tree", tuple(sources), tuple(stages), results)
    comp.validate()
    return comp


def _chain_comp(n, rnd, shared_source=False):
    """A linear chain, optionally with a source consumed by several
    stages (the ``h_prev`` pattern chain_dp's holder-set DP prices)."""
    sources = [DataItem("frame", rnd.randrange(1_000, 600_000), CLIENT)]
    if shared_source:
        sources.append(DataItem("h_prev", rnd.randrange(64, 2_048), CLIENT))
    stages = []
    prev = "frame"
    p = 1.0
    for i in range(n):
        out = DataItem(f"x{i}", rnd.randrange(64, 120_000))
        inputs = [prev]
        if shared_source and (i == 0 or i == n - 1):
            inputs.append("h_prev")
        if rnd.random() < 0.3:
            p *= rnd.uniform(0.3, 1.0)
        stages.append(
            Stage(
                name=f"s{i}",
                flops=rnd.uniform(1e8, 4e9),
                inputs=tuple(inputs),
                outputs=(out,),
                parallel_fraction=rnd.uniform(0.7, 1.0),
                exec_prob=p,
            )
        )
        prev = out.name
    comp = StagedComputation("rand_chain", tuple(sources), tuple(stages), (prev,))
    comp.validate()
    return comp


def _case_dims(rnd):
    """(k tiers, n stages) with the plan lattice capped at 512."""
    k = rnd.choice((2, 2, 3))
    n = rnd.randrange(2, 10) if k == 2 else rnd.randrange(2, 6)
    assert k**n <= 512
    return k, n


# ---------------------------------------------------------------------------
# property tests: DP vs exhaustive, bit-for-bit
# ---------------------------------------------------------------------------


@settings(max_examples=60, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_tree_dp_matches_exhaustive_on_random_trees(seed):
    """On every randomized out-tree with lattice <= 512 the tree DP's
    plan prices *exactly* (==) what exhaustive search finds — both
    planners return ``engine.evaluate`` reports, so any argmin
    disagreement would surface as a total_time difference."""
    rnd = random.Random(seed)
    k, n = _case_dims(rnd)
    topo = _rand_topology(k, rnd, rnd.choice(("chain", "star")))
    comp = _tree_comp(n, rnd)
    assert TreeDPPlanner.applicable(comp)
    engine = CostEngine(topo)
    ex = PLANNERS["exhaustive"].plan(comp, engine)
    dp = PLANNERS["tree_dp"].plan(comp, engine)
    assert dp.total_time == ex.total_time


@settings(max_examples=40, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_chain_dp_and_tree_dp_agree_on_linear_chains(seed):
    """A chain is the degenerate out-tree: chain_dp, tree_dp and
    exhaustive must all price the optimum identically, and the two DPs
    must pick the same placements.  Chains with a shared source go
    through chain_dp's holder-set state (tree_dp rejects them)."""
    rnd = random.Random(seed)
    k, n = _case_dims(rnd)
    shared = rnd.random() < 0.4
    topo = _rand_topology(k, rnd, rnd.choice(("chain", "star")))
    comp = _chain_comp(n, rnd, shared_source=shared)
    assert ChainDPPlanner.applicable(comp)
    engine = CostEngine(topo)
    ex = PLANNERS["exhaustive"].plan(comp, engine)
    chain = PLANNERS["chain_dp"].plan(comp, engine)
    assert chain.total_time == ex.total_time
    if shared:
        # h_prev consumed twice: residency coupling, out-tree DP exits
        assert not TreeDPPlanner.applicable(comp)
    else:
        assert TreeDPPlanner.applicable(comp)
        tree = PLANNERS["tree_dp"].plan(comp, engine)
        assert tree.total_time == ex.total_time
        assert tree.placements == chain.placements


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_dag_fallback_matches_exhaustive_on_rgbd_tracking(seed):
    """The registry's true DAG (shared h_prev + reseed join) is outside
    every exact DP's domain; the multi-seed coordinate descent still
    finds the exhaustive optimum on randomized <=3-tier topologies
    (lattice <= 3^4 = 81)."""
    rnd = random.Random(seed)
    comp = rgbd_tracking()
    assert not TreeDPPlanner.applicable(comp)
    assert not ChainDPPlanner.applicable(comp)
    assert TreeDPPlanner.dag_applicable(comp)
    topo = _rand_topology(rnd.choice((2, 3)), rnd, rnd.choice(("chain", "star")))
    engine = CostEngine(topo)
    ex = PLANNERS["exhaustive"].plan(comp, engine)
    dag = PLANNERS["tree_dp"].plan(comp, engine)
    assert dag.total_time == ex.total_time


# ---------------------------------------------------------------------------
# single-crossing dedupe (satellite 1)
# ---------------------------------------------------------------------------


def test_single_crossing_prices_all_home_once():
    """The degenerate lo == hi window (all stages at home) used to be
    re-evaluated for every (remote, window) pair; now it is priced
    exactly once and the planner issues exactly
    1 + (k-1) * n*(n+1)/2 evaluate calls."""
    rnd = random.Random(0x51C)
    n = 4
    comp = _chain_comp(n, rnd)
    topo = _rand_topology(3, rnd, "star")
    engine = CostEngine(topo)
    k = len(engine.placement_tiers())

    calls = []
    real_evaluate = engine.evaluate
    engine.evaluate = lambda c, p: calls.append(tuple(p)) or real_evaluate(c, p)

    rep = PLANNERS["single_crossing"].plan(comp, engine)
    home = engine.topology.home
    all_home = tuple(home for _ in range(n))
    assert calls.count(all_home) == 1
    assert len(calls) == 1 + (k - 1) * n * (n + 1) // 2
    # and the dedupe did not change the answer
    engine.evaluate = real_evaluate
    ex = PLANNERS["exhaustive"].plan(comp, engine)
    windows = {
        tuple(r if lo <= i < hi else home for i in range(n))
        for r in engine.placement_tiers()
        for lo in range(n)
        for hi in range(lo, n + 1)
    }
    best_window = min(
        (real_evaluate(comp, p) for p in windows), key=lambda r: r.total_time
    )
    assert rep.total_time == best_window.total_time
    assert rep.total_time >= ex.total_time


# ---------------------------------------------------------------------------
# auto_planner dispatch
# ---------------------------------------------------------------------------


def test_auto_planner_dispatch_order():
    rnd = random.Random(7)
    eng2 = CostEngine(_rand_topology(2, rnd, "chain"))
    eng3 = CostEngine(_rand_topology(3, rnd, "star"))
    # tiny lattice: exhaustive regardless of structure
    small = _chain_comp(3, rnd)
    assert auto_planner(small, eng2, 4096).name == "exhaustive"
    # long chain: lattice 3^12 blows the 512 preference -> chain DP
    long_chain = _chain_comp(12, rnd)
    assert auto_planner(long_chain, eng3, 4096).name == "chain_dp"
    # branching tree of the same size: tree DP
    long_tree = _tree_comp(12, rnd)
    while ChainDPPlanner.applicable(long_tree):  # ensure it truly branches
        long_tree = _tree_comp(12, rnd)
    assert auto_planner(long_tree, eng3, 4096).name == "tree_dp"
    # true DAG, lattice within budget: exhaustive; beyond it: crossing
    dag = rgbd_tracking()
    assert auto_planner(dag, eng3, 4096).name == "exhaustive"
    wide = StagedComputation(
        "wide",
        dag.sources,
        dag.stages * 3,
        dag.results,
    )
    assert auto_planner(wide, eng3, 4096).name == "single_crossing"


# ---------------------------------------------------------------------------
# fused() edge cases (satellite 3)
# ---------------------------------------------------------------------------


def test_fused_passthrough_result_not_reemitted():
    """A source listed in results already resides at its origin; the
    fused stage must not re-produce it (that would charge a bogus
    ship-home from the fused stage's tier)."""
    comp = StagedComputation(
        "pt",
        sources=(
            DataItem("frame", 100_000, CLIENT),
            DataItem("h_prev", 108, CLIENT),
        ),
        stages=(
            Stage("s0", 1e9, ("frame", "h_prev"), (DataItem("h_next", 108),)),
        ),
        results=("h_next", "h_prev"),
    )
    fused = comp.fused()
    out_names = {o.name for o in fused.stages[0].outputs}
    assert out_names == {"h_next"}
    assert fused.results == ("h_next", "h_prev")
    fused.validate()
    rnd = random.Random(3)
    engine = CostEngine(_rand_topology(2, rnd, "chain"))
    for t in engine.placement_tiers():
        rep = engine.evaluate(fused, (t,))
        assert rep.total_time > 0.0


def test_fused_zero_flops_has_zero_parallel_fraction():
    comp = StagedComputation(
        "zero",
        sources=(DataItem("a", 64, CLIENT),),
        stages=(
            Stage("s0", 0.0, ("a",), (DataItem("b", 64),)),
            Stage("s1", 0.0, ("b",), (DataItem("c", 64),)),
        ),
        results=("c",),
    )
    fused = comp.fused()
    assert fused.stages[0].flops == 0.0
    assert fused.stages[0].parallel_fraction == 0.0


def test_fused_empty_pipeline_raises():
    comp = StagedComputation(
        "empty", sources=(DataItem("a", 64, CLIENT),), stages=(), results=()
    )
    with pytest.raises(ValueError, match="no stages"):
        comp.fused()


def test_fused_weights_flops_by_exec_prob():
    comp = StagedComputation(
        "cond",
        sources=(DataItem("a", 64, CLIENT),),
        stages=(
            Stage(
                "always",
                4e9,
                ("a",),
                (DataItem("b", 64),),
                parallel_fraction=1.0,
            ),
            Stage(
                "rare",
                6e9,
                ("b",),
                (DataItem("c", 64),),
                parallel_fraction=0.5,
                exec_prob=0.25,
            ),
        ),
        results=("c",),
    )
    fused = comp.fused()
    assert fused.stages[0].flops == 4e9 + 0.25 * 6e9
    expected_pfrac = (4e9 * 1.0 + 0.25 * 6e9 * 0.5) / (4e9 + 0.25 * 6e9)
    assert fused.stages[0].parallel_fraction == expected_pfrac


# ---------------------------------------------------------------------------
# exec_prob semantics (tentpole a)
# ---------------------------------------------------------------------------


def test_validate_rejects_incoherent_exec_prob():
    src = (DataItem("a", 64, CLIENT),)
    for bad in (0.0, -0.5, 1.5):
        comp = StagedComputation(
            "bad",
            src,
            (Stage("s", 1e9, ("a",), (DataItem("b", 64),), exec_prob=bad),),
            ("b",),
        )
        with pytest.raises(ValueError, match="exec_prob"):
            comp.validate()
    # a stage cannot run more often than the branch feeding it
    comp = StagedComputation(
        "incoherent",
        src,
        (
            Stage("s0", 1e9, ("a",), (DataItem("b", 64),), exec_prob=0.3),
            Stage("s1", 1e9, ("b",), (DataItem("c", 64),), exec_prob=0.9),
        ),
        ("c",),
    )
    with pytest.raises(ValueError, match="exceeds"):
        comp.validate()


def test_expected_cost_pricing_and_linearized():
    """A conditional branch prices strictly below its forced-
    unconditional variant on any placement; at exec_prob = 1.0 the
    computation and its linearized() are the same object and price
    identically."""
    rnd = random.Random(11)
    topo = _rand_topology(2, rnd, "chain")
    engine = CostEngine(topo)
    comp = multi_hand()
    lin = comp.linearized()
    assert lin is not comp
    assert all(s.exec_prob == 1.0 for s in lin.stages)
    n = len(comp.stages)
    for t in engine.placement_tiers():
        placements = tuple(t for _ in range(n))
        assert (
            engine.evaluate(comp, placements).total_time
            < engine.evaluate(lin, placements).total_time
        )
    uncond = solo_landmark()
    assert uncond.linearized() is uncond


# ---------------------------------------------------------------------------
# workload registry (tentpole c)
# ---------------------------------------------------------------------------


def test_workload_registry_applicability_matrix():
    """Each registry entry exercises a distinct planner domain — the
    whole point of mixing them in one fleet."""
    suite = workload_suite()
    assert tuple(c.name for c in suite) == tuple(WORKLOADS)
    matrix = {
        "solo_landmark": (True, True),  # (chain_dp, tree_dp)
        "multi_hand": (False, True),
        "full_gesture": (False, True),
        "rgbd_tracking": (False, False),
    }
    for comp in suite:
        chain_ok, tree_ok = matrix[comp.name]
        assert ChainDPPlanner.applicable(comp) == chain_ok, comp.name
        assert TreeDPPlanner.applicable(comp) == tree_ok, comp.name
        assert TreeDPPlanner.dag_applicable(comp)
        comp.validate()
        comp.fused().validate()
        comp.linearized().validate()


def test_workload_suite_subset_and_hardware_alias():
    from repro.sim import hardware

    sub = workload_suite(("multi_hand", "solo_landmark"))
    assert tuple(c.name for c in sub) == ("multi_hand", "solo_landmark")
    mix = hardware.mixed_workloads()
    assert tuple(c.name for c in mix) == tuple(WORKLOADS)
    named = hardware.mixed_workloads(["rgbd_tracking"])
    assert tuple(c.name for c in named) == ("rgbd_tracking",)


def test_workload_dags_plan_end_to_end():
    """Every registry workload plans on a realistic 3-tier chain via
    every applicable planner, and the conditional pipelines plan
    cheaper than their linearized variants (the fleet_bench --mixed
    effect, at the single-plan level)."""
    from repro.sim import hardware

    topo = hardware.three_tier_environment()
    engine = CostEngine(topo)
    for comp in workload_suite():
        rep = PLANNERS["tree_dp"].plan(comp, engine)
        assert rep.total_time > 0.0
        if any(s.exec_prob < 1.0 for s in comp.stages):
            lin = PLANNERS["tree_dp"].plan(comp.linearized(), engine)
            assert rep.total_time < lin.total_time
