"""Prefill+decode must equal the parallel forward — validates every cache
type: GQA (windowed), MLA absorbed decode, SSD recurrence, the hybrid
shared-attention cache, M-RoPE and enc-dec cross-attention."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.models import multimodal, transformer

TEXT_ARCHS = [
    "gemma-2b", "gemma3-4b", "mamba2-370m", "minicpm3-4b", "mixtral-8x7b",
    "qwen3-moe-30b-a3b", "starcoder2-3b", "zamba2-2.7b",
]


def _roundtrip_error(cfg, batch_builder, S=20):
    key = jax.random.PRNGKey(1)
    B = 2
    params = transformer.init_params(cfg, key)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    full_batch, prefill_kwargs, decode_pos = batch_builder(cfg, tokens)
    logits_full, _ = transformer.forward(cfg, params, full_batch)
    P = S // 2
    offset = logits_full.shape[1] - S  # frontend positions, if any
    lp, cache = transformer.prefill(
        cfg, params, tokens[:, :P], max_len=offset + S + 4, **prefill_kwargs
    )
    errs = [float(jnp.max(jnp.abs(lp - logits_full[:, offset + P - 1])))]
    for t in range(P, S):
        pos = decode_pos(t) if decode_pos else None
        ld, cache = transformer.decode_step(
            cfg, params, cache, tokens[:, t : t + 1], positions=pos
        )
        errs.append(float(jnp.max(jnp.abs(ld[:, 0] - logits_full[:, offset + t]))))
    return max(errs)


def _text_builder(cfg, tokens):
    return {"tokens": tokens}, {}, None


@pytest.mark.parametrize("arch", TEXT_ARCHS)
def test_text_arch_decode_matches_forward(arch):
    cfg = registry.get(arch).reduced()
    assert _roundtrip_error(cfg, _text_builder) < 5e-5


def test_qwen2vl_mrope_decode_matches_forward():
    cfg = registry.get("qwen2-vl-7b").reduced()
    S, B = 16, 2
    F = cfg.frontend_tokens
    fe = multimodal.fake_frontend_embeds(cfg, B)
    pos_full = multimodal.mrope_positions(B, S, image_grid=(4, 4))

    def builder(cfg, tokens):
        batch = {"tokens": tokens, "positions": pos_full, "frontend_embeds": fe}
        prefill_kwargs = {
            "positions": pos_full[:, :, : F + S // 2],
            "frontend_embeds": fe,
        }
        decode_pos = lambda t: pos_full[:, :, F + t : F + t + 1]
        return batch, prefill_kwargs, decode_pos

    assert _roundtrip_error(cfg, builder, S=S) < 5e-5


def test_seamless_encdec_decode_matches_forward():
    cfg = registry.get("seamless-m4t-large-v2").reduced()
    B = 2
    enc = multimodal.fake_frontend_embeds(cfg, B)

    def builder(cfg, tokens):
        return (
            {"tokens": tokens, "encoder_tokens": enc},
            {"encoder_tokens": enc},
            None,
        )

    assert _roundtrip_error(cfg, builder) < 5e-5
