import os

# Tests must see the real single CPU device; the 512-device override is
# exclusively dryrun.py's (the mandate forbids setting it globally).
assert "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", "")
