import os
import sys

# Tests must see the real single CPU device; the 512-device override is
# exclusively dryrun.py's (the mandate forbids setting it globally).
assert "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", "")

# ---------------------------------------------------------------------------
# hypothesis fallback shim
#
# The property tests are written against hypothesis, but the bare CI
# interpreter does not ship it and the mandate forbids installing it.
# When the real library is absent we register a tiny deterministic stand-in
# that samples each strategy pseudo-randomly (seeded, so runs are
# reproducible) for ``max_examples`` iterations.  It covers exactly the
# API surface the suite uses: ``given``, ``settings``, ``strategies.floats
# / integers / sampled_from / composite``.
# ---------------------------------------------------------------------------

try:  # pragma: no cover - exercised only when hypothesis is installed
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import random
    import types

    class _Strategy:
        def __init__(self, sample_fn):
            self._sample_fn = sample_fn

        def sample(self, rng):
            return self._sample_fn(rng)

    def _floats(min_value=0.0, max_value=1.0, **_kwargs):
        lo, hi = float(min_value), float(max_value)
        return _Strategy(lambda rng: rng.uniform(lo, hi))

    def _integers(min_value=0, max_value=1 << 30):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _sampled_from(elements):
        pool = list(elements)
        return _Strategy(lambda rng: pool[rng.randrange(len(pool))])

    def _composite(fn):
        def build(*args, **kwargs):
            return _Strategy(
                lambda rng: fn(lambda s: s.sample(rng), *args, **kwargs)
            )

        return build

    def _given(*strategies):
        def deco(fn):
            def wrapper(*args, **kwargs):
                rng = random.Random(0x5EED)
                # @settings may sit above @given (stamps the wrapper) or
                # below it (stamps the inner fn) — honor both orders
                n = getattr(
                    wrapper,
                    "_shim_max_examples",
                    getattr(fn, "_shim_max_examples", 10),
                )
                for _ in range(n):
                    drawn = tuple(s.sample(rng) for s in strategies)
                    fn(*args, *drawn, **kwargs)

            # NOTE: no functools.wraps — pytest would follow __wrapped__
            # and demand fixtures for the strategy-supplied parameters.
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper

        return deco

    def _settings(max_examples=10, **_kwargs):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn

        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.floats = _floats
    _st.integers = _integers
    _st.sampled_from = _sampled_from
    _st.composite = _composite

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
