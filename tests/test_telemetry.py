"""Telemetry layer: exact span sums, off-switch golden, engine parity.

Three contracts, each asserted with EXACT equality (``==`` on floats):

1. **Span exactness** — every processed frame's span tuple folds
   left-to-right to its recorded loop time bit for bit, on BOTH
   engines, with batching + migration + codec + drift armed at once
   (the hypothesis property test; the conftest shim stands in when
   hypothesis is absent).
2. **Off-switch golden** — ``telemetry=None`` is the default and an
   armed ``Telemetry`` must not perturb the simulation: event counts,
   frame streams, and loop times are identical with and without it.
3. **Engine parity** — the object and vectorized engines feed the
   hooks identical inputs, so two ``Telemetry`` instances observing
   the same workload on different engines are byte-identical: frames,
   blackouts, occupancy timelines, and full metric snapshots.

Plus unit coverage of the registry instruments, the Chrome trace
export, the attribution report, and the bench-artifact validator.
"""

import json

import pytest
from hypothesis import given, settings, strategies as st

from repro.cluster import (
    MigrationConfig,
    PlanCache,
    SPAN_ORDER,
    Telemetry,
    run_fleet,
)
from repro.cluster.fleet import LinkDrift
from repro.cluster.telemetry import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    exact_spans,
)
from repro.codec import CodecConfig
from repro.sim import hardware

_COMP = hardware.paper_staged()


def _everything_kwargs(num_clients=8, num_frames=40, seed=0,
                       gather_window=2e-3, with_drift=True):
    """Hetero star with batching + migration + codec (+ drift) armed —
    the config where every span source is live at once."""
    topo, classes = hardware.hetero_fleet_star(num_edges=3, edge_capacity=2)
    kw = dict(
        topo=topo,
        comp=_COMP,
        num_clients=num_clients,
        num_frames=num_frames,
        seed=seed,
        dispatch="least_queue",
        client_classes=classes,
        batching=True,
        gather_window=gather_window,
        migration=MigrationConfig(),
        codec=CodecConfig(base=hardware.codec_point()),
    )
    if with_drift:
        kw["drifts"] = [
            LinkDrift(time=0.4, link="5g_edge_0", latency=0.06, jitter=0.012)
        ]
    return kw


def _assert_spans_match_loops(result, tel):
    """Every frame's span fold == its ClientResult loop time, exactly."""
    by_client = {}
    for client, _cls, _wl, _edge, idx, start, fin, spans in tel.frames:
        by_client.setdefault(client, {})[idx] = (start, fin, spans)
    checked = 0
    for c in result.clients:
        frames = by_client.get(c.client, {})
        assert len(frames) == len(c.stats.processed)
        for ev in c.stats.processed:
            start, fin, spans = frames[ev.index]
            assert start == ev.start and fin == ev.finish
            fold = 0.0
            for d in spans:
                fold += d
            assert fold == ev.finish - ev.start  # exact, not approx
            checked += 1
    assert checked == len(tel.frames)
    assert tel.verify_exact() == checked


# ---------------------------------------------------------------------------
# contract 1: exact span sums (property test, everything armed)
# ---------------------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(
    st.integers(min_value=4, max_value=9),  # num_clients
    st.integers(min_value=25, max_value=45),  # num_frames
    st.integers(min_value=0, max_value=5),  # seed
    st.sampled_from([1e-3, 2e-3, 3e-3]),  # gather_window
    st.sampled_from([False, True]),  # with_drift
)
def test_span_sums_exact_on_random_everything_fleets(
    num_clients, num_frames, seed, gather_window, with_drift
):
    kw = _everything_kwargs(
        num_clients, num_frames, seed, gather_window, with_drift
    )
    for engine in ("object", "vector"):
        tel = Telemetry()
        r = run_fleet(engine=engine, cache=PlanCache(), telemetry=tel, **kw)
        assert r.events > 0 and tel.frames
        _assert_spans_match_loops(r, tel)


def test_span_order_matches_trace_tuples():
    tel = Telemetry()
    run_fleet(
        engine="object", cache=PlanCache(), telemetry=tel,
        **_everything_kwargs(num_clients=5, num_frames=20),
    )
    for *_ignored, spans in tel.frames:
        assert len(spans) == len(SPAN_ORDER)


# ---------------------------------------------------------------------------
# contract 2: the off-switch is golden
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ["object", "vector"])
def test_telemetry_off_switch_is_bit_identical(engine):
    kw = _everything_kwargs(num_clients=7, num_frames=35)
    bare = run_fleet(engine=engine, cache=PlanCache(), **kw)
    tel = Telemetry()
    armed = run_fleet(engine=engine, cache=PlanCache(), telemetry=tel, **kw)
    assert bare.events == armed.events
    assert bare.duration == armed.duration
    for cb, ca in zip(bare.clients, armed.clients):
        assert cb.stats.loop_times() == ca.stats.loop_times()
        assert cb.edge == ca.edge
        assert cb.total_wait == ca.total_wait
    for lb, la in zip(bare.edges, armed.edges):
        assert (lb.admitted, lb.busy_time, lb.peak_load) == (
            la.admitted, la.busy_time, la.peak_load
        )


# ---------------------------------------------------------------------------
# contract 3: both engines emit byte-identical telemetry
# ---------------------------------------------------------------------------


def test_engines_emit_identical_telemetry():
    kw = _everything_kwargs(num_clients=9, num_frames=45)
    tels = {}
    for engine in ("object", "vector"):
        tel = Telemetry()
        run_fleet(engine=engine, cache=PlanCache(), telemetry=tel, **kw)
        tels[engine] = tel
    to, tv = tels["object"], tels["vector"]
    assert to.frames == tv.frames
    assert to.blackouts == tv.blackouts
    assert to.occupancy == tv.occupancy
    assert to.metrics.snapshot() == tv.metrics.snapshot()


def test_metrics_cover_every_armed_subsystem():
    tel = Telemetry()
    r = run_fleet(
        engine="vector", cache=PlanCache(), telemetry=tel,
        **_everything_kwargs(num_clients=8, num_frames=45),
    )
    snap = tel.metrics.snapshot()
    counters, gauges, hists = (
        snap["counters"], snap["gauges"], snap["histograms"]
    )
    # plan cache + migration decision accounting
    assert counters["plancache.miss"] == r.cache.stats.misses
    assert counters["plancache.hit"] == r.cache.stats.hits
    assert counters["migration.considered"] == r.migration.considered
    assert counters["migration.accepted"] == r.migration.count
    # codec byte accounting: compressed never exceeds raw
    assert 0 < counters["codec.uplink_wire_bytes"] <= (
        counters["codec.uplink_raw_bytes"]
    )
    # per-edge gauges mirror the EdgeLoad report
    for e in r.edges:
        assert gauges[f"edge.peak_load.{e.name}"] == e.peak_load
        assert gauges[f"edge.busy_s.{e.name}"] == e.busy_time
        assert gauges[f"edge.admitted.{e.name}"] == e.admitted
    # batching edges feed the batch-size histogram
    assert hists["batch.size"]["count"] == sum(e.batches for e in r.edges)
    assert hists["frame.loop_s"]["count"] == len(tel.frames)


# ---------------------------------------------------------------------------
# exports: chrome trace + attribution table
# ---------------------------------------------------------------------------


def _small_run():
    tel = Telemetry()
    run_fleet(
        engine="vector", cache=PlanCache(), telemetry=tel,
        **_everything_kwargs(num_clients=6, num_frames=30),
    )
    return tel


def test_chrome_trace_export_shape(tmp_path):
    tel = _small_run()
    path = tmp_path / "trace.json"
    doc = tel.export_chrome_trace(str(path))
    ondisk = json.loads(path.read_text())
    assert ondisk == doc
    assert doc["displayTimeUnit"] == "ms"
    events = doc["traceEvents"]
    phases = {e["ph"] for e in events}
    assert {"M", "X", "C"} <= phases
    for e in events:
        if e["ph"] == "X":
            assert e["dur"] > 0.0  # non-positive spans are display-skipped
            assert e["ts"] >= 0.0
    names = {e["name"] for e in events if e["ph"] == "X"}
    assert "compute" in names and "uplink" in names
    if tel.blackouts:
        assert "migration-blackout" in names


def test_attribution_report_and_table():
    tel = _small_run()
    att = tel.attribution()
    assert "all" in att
    assert len(att) > 1  # hetero classes present alongside "all"
    for rep in att.values():
        shares = [s["share"] for s in rep["spans"].values()]
        assert abs(sum(shares) - 1.0) < 1e-9
        assert rep["loop_p99_ms"] >= rep["loop_p50_ms"]
    table = tel.format_attribution_table()
    assert "latency attribution [all]" in table
    for name in SPAN_ORDER:
        assert name in table


def test_attribution_collapses_single_class():
    tel = Telemetry()
    topo = hardware.fleet_star(num_edges=2, edge_capacity=2)
    run_fleet(
        topo=topo, comp=_COMP, num_clients=4, num_frames=20,
        engine="object", cache=PlanCache(), telemetry=tel,
    )
    assert list(tel.attribution()) == ["all"]


# ---------------------------------------------------------------------------
# instrument unit tests
# ---------------------------------------------------------------------------


def test_exact_spans_identity_and_fallback():
    parts = (0.1, 0.2, 0.3)
    loop = 0.0
    for d in parts:
        loop += d
    spans = exact_spans(parts, loop)
    assert spans[:-1] == parts
    fold = 0.0
    for d in spans:
        fold += d
    assert fold == loop
    # degenerate target: fold must still hit it exactly
    spans = exact_spans((1e300, -1e300, 1e300), 42.0)
    fold = 0.0
    for d in spans:
        fold += d
    assert fold == 42.0


def test_counter_gauge_histogram():
    c = Counter()
    c.inc()
    c.inc(4)
    assert c.value == 5
    g = Gauge()
    g.set(3.5)
    g.set(1.25)
    assert g.value == 1.25
    h = Histogram(lo=1.0, growth=2.0, nbuckets=4)
    for v in (0.5, 1.0, 3.0, 9.0, 100.0):
        h.observe(v)
    assert h.count == 5
    assert h.vmin == 0.5 and h.vmax == 100.0
    assert h.mean == pytest.approx(113.5 / 5)
    assert h.percentile(0.0) == 1.0  # rank clamps to 1
    assert h.percentile(1.0) == 8.0  # overflow reports the last bound
    snap = h.snapshot()
    assert snap["count"] == 5 and snap["p50"] <= snap["p99"]
    with pytest.raises(ValueError):
        Histogram(lo=0.0)


def test_histogram_empty_snapshot():
    h = Histogram()
    assert h.percentile(0.99) == 0.0
    snap = h.snapshot()
    assert snap == {
        "count": 0, "mean": 0.0, "min": 0.0, "max": 0.0, "p50": 0.0,
        "p99": 0.0,
    }


def test_registry_create_on_touch_and_snapshot():
    reg = MetricsRegistry()
    assert reg.counter("a") is reg.counter("a")
    assert reg.gauge("g") is reg.gauge("g")
    assert reg.histogram("h") is reg.histogram("h")
    reg.counter("a").inc(2)
    snap = reg.snapshot()
    assert snap["counters"] == {"a": 2}
    assert list(snap["gauges"]) == ["g"]
    assert snap["histograms"]["h"]["count"] == 0


def test_verify_exact_raises_on_corruption():
    tel = _small_run()
    client, cls, wl, edge, idx, start, fin, spans = tel.frames[0]
    tel.frames[0] = (client, cls, wl, edge, idx, start, fin + 1.0, spans)
    with pytest.raises(AssertionError):
        tel.verify_exact()


# ---------------------------------------------------------------------------
# bench-artifact schema: stamping + validation
# ---------------------------------------------------------------------------


def _bench_modules():
    import pathlib
    import sys

    bench_dir = str(pathlib.Path(__file__).resolve().parent.parent / "benchmarks")
    if bench_dir not in sys.path:
        sys.path.insert(0, bench_dir)
    import common
    import validate_bench

    return common, validate_bench


def test_write_bench_json_stamps_envelope(tmp_path, monkeypatch):
    common, validate_bench = _bench_modules()
    monkeypatch.setattr(common, "REPO_ROOT", tmp_path)
    path = common.write_bench_json("fleet_codec", {
        "knee_fps": 25.0, "knee_shift": 2.0,
        "knees": {"raw": 4, "codec": 8}, "smoke": True,
    })
    doc = json.loads(path.read_text())
    assert doc["schema_version"] == common.SCHEMA_VERSION
    assert isinstance(doc["git_rev"], str) and doc["git_rev"]
    schema = json.loads(validate_bench.SCHEMA_PATH.read_text())
    assert validate_bench.validate_file(path, schema) == []


def test_validator_flags_missing_and_mistyped_keys(tmp_path):
    _common, validate_bench = _bench_modules()
    schema = json.loads(validate_bench.SCHEMA_PATH.read_text())
    bad = tmp_path / "BENCH_fleet_codec.json"
    bad.write_text(json.dumps({
        "schema_version": "one",  # mistyped
        "git_rev": "abc",
        "knee_fps": 25.0,
        # knee_shift missing
        "knees": {"raw": 4},
        "smoke": True,
    }))
    errors = validate_bench.validate_file(bad, schema)
    assert any("schema_version" in e and "expected int" in e for e in errors)
    assert any("knee_shift" in e and "missing" in e for e in errors)


def test_validator_optional_and_list_specs(tmp_path):
    _common, validate_bench = _bench_modules()
    schema = json.loads(validate_bench.SCHEMA_PATH.read_text())
    doc = {
        "schema_version": 1,
        "git_rev": "abc",
        "gate_min_speedup": 2.0,
        "reps": 3,
        "smoke": True,
        "points": [{
            "clients": 256, "edges": 16, "frames": 120, "events": 100,
            "object_events_per_s": 1.0, "vector_events_per_s": 3.0,
            "speedup": 3.0,
            # optional telemetry fields absent: still valid
        }],
    }
    good = tmp_path / "BENCH_fleet_events.json"
    good.write_text(json.dumps(doc))
    assert validate_bench.validate_file(good, schema) == []
    doc["points"][0]["telemetry_overhead_pct"] = "high"  # optional but typed
    bad = tmp_path / "BENCH_fleet_events.json"
    bad.write_text(json.dumps(doc))
    errors = validate_bench.validate_file(bad, schema)
    assert any("telemetry_overhead_pct" in e for e in errors)
    # bools are not ints
    doc["points"][0]["telemetry_overhead_pct"] = 1.0
    doc["points"][0]["events"] = True
    bad.write_text(json.dumps(doc))
    assert any(
        "events" in e for e in validate_bench.validate_file(bad, schema)
    )


def test_validator_main_passes_on_valid_artifact(tmp_path, capsys):
    common, validate_bench = _bench_modules()
    path = tmp_path / "BENCH_custom.json"  # unknown name: common spec only
    path.write_text(json.dumps({"schema_version": 1, "git_rev": "abc"}))
    assert validate_bench.main([str(path)]) == 0
    assert "ok" in capsys.readouterr().out
    path.write_text(json.dumps({"git_rev": "abc"}))
    assert validate_bench.main([str(path)]) == 1
