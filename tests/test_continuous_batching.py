"""Continuous batching: mixed-progress decode slots produce the same
greedy continuations as isolated decoding."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import transformer
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import Request


@pytest.fixture(scope="module")
def setup():
    cfg = registry.get("gemma-2b").reduced()
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _isolated_greedy(cfg, params, prompt, n_new):
    toks = jnp.asarray(prompt, jnp.int32)[None, :]
    logits, cache = transformer.prefill(cfg, params, toks, max_len=96)
    cur = int(jnp.argmax(logits[0]))
    out = [cur]
    for _ in range(n_new - 1):
        l, cache = transformer.decode_step(
            cfg, params, cache, jnp.asarray([[cur]], jnp.int32)
        )
        cur = int(jnp.argmax(l[0, 0]))
        out.append(cur)
    return np.asarray(out, np.int32)


def test_continuous_matches_isolated(setup):
    cfg, params = setup
    rng = np.random.default_rng(0)
    # different lengths + counts force slot reuse at different positions
    requests = [
        Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, 4 + 3 * i).astype(np.int32),
                max_new_tokens=3 + (i % 4))
        for i in range(6)
    ]
    eng = ContinuousEngine(cfg, params, num_slots=2, max_len=96)
    for r in requests:
        eng.submit(r)
    completions = eng.run_to_completion()
    assert [c.uid for c in completions] == list(range(6))
    for r, c in zip(requests, completions):
        expect = _isolated_greedy(cfg, params, r.prompt, r.max_new_tokens)
        np.testing.assert_array_equal(c.tokens, expect)


def test_slot_reuse_count(setup):
    cfg, params = setup
    rng = np.random.default_rng(1)
    reqs = [
        Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, 5).astype(np.int32),
                max_new_tokens=2)
        for i in range(5)
    ]
    eng = ContinuousEngine(cfg, params, num_slots=2, max_len=64)
    for r in reqs:
        eng.submit(r)
    out = eng.run_to_completion()
    assert len(out) == 5
    assert all(len(c.tokens) == 2 for c in out)
