"""Kinematics invariants of the 27-DoF hand model."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import handmodel as hm

finite_floats = st.floats(-1.0, 1.0, allow_nan=False, width=32)


@st.composite
def configurations(draw):
    pos = [draw(st.floats(-0.3, 0.3)) for _ in range(3)]
    pos[2] = draw(st.floats(0.3, 1.0))  # in front of the camera
    quat = [draw(st.floats(-1.0, 1.0)) for _ in range(4)]
    if all(abs(q) < 1e-3 for q in quat):
        quat = [1.0, 0.0, 0.0, 0.0]
    angles = [draw(st.floats(-2.0, 2.5)) for _ in range(20)]
    return jnp.asarray(pos + quat + angles, dtype=jnp.float32)


def test_sphere_count_and_padding():
    h = hm.default_pose()
    c, r = hm.hand_spheres_world(h)
    assert c.shape == (hm.NUM_SPHERES, 3)
    assert r.shape == (hm.NUM_SPHERES,)
    assert hm.NUM_SPHERES % 8 == 0
    # padding spheres have zero radius
    assert float(r[hm.NUM_SPHERES_RAW:].max(initial=0.0)) == 0.0
    assert float(r[: hm.NUM_SPHERES_RAW].min()) > 0.0


@settings(max_examples=25, deadline=None)
@given(configurations())
def test_rigid_transform_preserves_distances(h):
    """Rotation+translation must not change inter-sphere distances."""
    angles = h[hm.ANGLES_SLICE]
    local_c, _ = hm.hand_spheres_local(angles)
    world_c, _ = hm.hand_spheres_world(h)
    d_local = jnp.linalg.norm(local_c[0] - local_c[10])
    d_world = jnp.linalg.norm(world_c[0] - world_c[10])
    np.testing.assert_allclose(float(d_local), float(d_world), atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(configurations())
def test_quaternion_normalization_invariance(h):
    """Scaling the quaternion must not change geometry (normalized)."""
    h2 = h.at[hm.QUAT_SLICE].multiply(2.5)
    c1, _ = hm.hand_spheres_world(h)
    c2, _ = hm.hand_spheres_world(h2)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), atol=1e-4)


def test_angle_bounds_clip():
    """Angles beyond anatomical limits are clipped: geometry saturates."""
    h = hm.default_pose()
    h_extreme = h.at[hm.ANGLES_SLICE].set(100.0)
    h_limit = h.at[hm.ANGLES_SLICE].set(hm.angle_upper_bounds())
    c1, _ = hm.hand_spheres_world(h_extreme)
    c2, _ = hm.hand_spheres_world(h_limit)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), atol=1e-5)


def test_bounds_contain_center():
    h = hm.default_pose()
    lo = hm.parameter_lower_bounds(h)
    hi = hm.parameter_upper_bounds(h)
    assert bool(jnp.all(lo <= h)) and bool(jnp.all(h <= hi))


def test_fingers_curl_towards_palm():
    """Flexing all fingers moves fingertips towards -z (palm side)."""
    open_h = hm.default_pose()
    curled = open_h.at[hm.ANGLES_SLICE].set(
        jnp.tile(jnp.asarray([0.0, 1.2, 1.2, 1.0]), 5)
    )
    c_open, _ = hm.hand_spheres_local(open_h[hm.ANGLES_SLICE])
    c_curl, _ = hm.hand_spheres_local(curled[hm.ANGLES_SLICE])
    spheres_per_finger = hm.NUM_BONES_PER_FINGER * hm.SPHERES_PER_BONE + 1
    # index fingertip: palm spheres + thumb block + index bones
    tip = hm.NUM_PALM_SPHERES + 2 * spheres_per_finger - 1
    assert float(c_curl[tip, 2]) < float(c_open[tip, 2])
