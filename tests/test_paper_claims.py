"""Validation of the paper's experimental claims (Figs. 4 and 5).

The two *native* fps anchors are calibrated (sim/hardware.py documents
this); every assertion below is a PREDICTION of the cost model that the
paper's measurements corroborate — orderings, adaptation behaviour, and
approximate magnitudes.
"""

import pytest

from repro.core import offload
from repro.core.offload import Policy
from repro.sim import hardware, runtime


@pytest.fixture(scope="module")
def comp():
    return hardware.paper_staged()


@pytest.fixture(scope="module")
def tiers():
    return hardware.paper_tiers()


def _fps(comp, env, policy, gran):
    return runtime.analytic_run(comp, env, policy, gran, 200).fps


def _local_env(tiers, machine, wrapped):
    return offload.Environment(
        client=tiers[machine], server=tiers["server"],
        link=hardware.links.GIGABIT_ETHERNET,
        wrapper=hardware.paper_wrapper(), wrapped=wrapped,
    )


# ------------------------- Fig. 4 -------------------------


def test_server_native_exceeds_40fps(comp, tiers):
    fps = _fps(comp, _local_env(tiers, "server", False), Policy.LOCAL, "single_step")
    assert fps > 40.0


def test_laptop_native_about_13fps(comp, tiers):
    fps = _fps(comp, _local_env(tiers, "laptop", False), Policy.LOCAL, "single_step")
    assert fps == pytest.approx(13.0, abs=0.5)


def test_wrapper_reduces_performance_everywhere(comp, tiers):
    for machine in ("server", "laptop"):
        native = _fps(comp, _local_env(tiers, machine, False), Policy.LOCAL, "single_step")
        wrapped = _fps(comp, _local_env(tiers, machine, True), Policy.LOCAL, "single_step")
        assert wrapped < native


def test_wrapper_overhead_less_pronounced_on_laptop(comp, tiers):
    """Paper: 'The overhead added by the offloading framework is less
    pronounced in the laptop, due to the overall slower framerate.'"""
    rel = {}
    for machine in ("server", "laptop"):
        native = _fps(comp, _local_env(tiers, machine, False), Policy.LOCAL, "single_step")
        wrapped = _fps(comp, _local_env(tiers, machine, True), Policy.LOCAL, "single_step")
        rel[machine] = (native - wrapped) / native
    assert rel["laptop"] < rel["server"]


def test_multi_step_overhead_more_visible_than_single(comp, tiers):
    """Paper: wrapping each step individually makes the overhead 'more
    visible compared to having all the steps in a single Java method'."""
    for machine in ("server", "laptop"):
        env = _local_env(tiers, machine, True)
        single = _fps(comp, env, Policy.LOCAL, "single_step")
        multi = _fps(comp, env, Policy.LOCAL, "multi_step")
        assert multi < single


# ------------------------- Fig. 5 -------------------------


def test_forced_single_ethernet_around_10fps(comp):
    env = hardware.paper_environment("gigabit_ethernet")
    fps = _fps(comp, env, Policy.FORCED, "single_step")
    assert 8.0 <= fps <= 14.0  # paper: 'around 10 fps'


def test_forced_offload_single_beats_multi(comp):
    for net in ("gigabit_ethernet", "wifi_802.11"):
        env = hardware.paper_environment(net)
        single = _fps(comp, env, Policy.FORCED, "single_step")
        multi = _fps(comp, env, Policy.FORCED, "multi_step")
        assert single > multi


def test_ethernet_beats_wifi_when_forced(comp):
    eth = _fps(comp, hardware.paper_environment("gigabit_ethernet"),
               Policy.FORCED, "single_step")
    wifi = _fps(comp, hardware.paper_environment("wifi_802.11"),
                Policy.FORCED, "single_step")
    assert eth > wifi * 1.5


def test_auto_adapts_to_both_networks(comp):
    """Paper: 'RAPID is able to adapt in all situations and yield the best
    possible performance even if the connection is Wi-Fi rather than
    Ethernet... around 10-11 fps.'"""
    for net in ("gigabit_ethernet", "wifi_802.11"):
        env = hardware.paper_environment(net)
        fps = _fps(comp, env, Policy.AUTO, "single_step")
        assert 9.0 <= fps <= 13.0, (net, fps)


def test_auto_never_below_forced_or_local(comp):
    for net in ("gigabit_ethernet", "wifi_802.11"):
        env = hardware.paper_environment(net)
        for gran in ("single_step", "multi_step"):
            auto = _fps(comp, env, Policy.AUTO, gran)
            forced = _fps(comp, env, Policy.FORCED, gran)
            local = _fps(comp, env, Policy.LOCAL, gran)
            assert auto >= max(forced, local) - 1e-6


def test_auto_chooses_local_on_wifi(comp):
    """The adaptation mechanism: on Wi-Fi the offload is not worth it."""
    env = hardware.paper_environment("wifi_802.11")
    rep = runtime.analytic_run(comp, env, Policy.AUTO, "single_step", 100)
    assert all(p == "client" for p in rep.plan.placements)


def test_gpu_less_client_runs_via_offload():
    """Paper conclusion: 'a machine without a GPU is possible to run the
    real-time 3D hand tracking with 1/3 of the desired framerate'."""
    comp = hardware.paper_staged()
    tiers = hardware.paper_tiers()
    env = offload.Environment(
        client=hardware.THIN_CLIENT_NO_GPU, server=tiers["server"],
        link=hardware.links.GIGABIT_ETHERNET,
        wrapper=hardware.paper_wrapper(),
    )
    local = _fps(comp, env, Policy.LOCAL, "single_step")
    forced = _fps(comp, env, Policy.FORCED, "single_step")
    assert local < 2.0  # unusable locally
    assert forced > 8.0  # ~1/3 of 30 fps via offload
