"""MoE router + dispatch invariants."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import moe


def _cfg(impl="dense", capacity=8.0):
    cfg = registry.get("mixtral-8x7b").reduced()
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, impl=impl, capacity_factor=capacity)
    )


def test_dropping_matches_dense_when_capacity_ample():
    """With capacity_factor high enough that nothing drops, the sorted
    scatter dispatch computes exactly the dense top-k combine."""
    cfg_dense = _cfg("dense")
    cfg_drop = _cfg("dropping", capacity=16.0)
    params = moe.init_moe(jax.random.PRNGKey(0), cfg_dense)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg_dense.d_model))
    y_dense, aux_d = moe.moe_forward(params, cfg_dense, x)
    y_drop, aux_s = moe.moe_forward(params, cfg_drop, x)
    np.testing.assert_allclose(
        np.asarray(y_dense), np.asarray(y_drop), rtol=2e-4, atol=2e-5
    )
    np.testing.assert_allclose(float(aux_d), float(aux_s), rtol=1e-5)


def test_capacity_drops_tokens_gracefully():
    """With capacity 0+epsilon most tokens drop: output ~ 0 (residual
    passthrough), never NaN."""
    cfg = _cfg("dropping", capacity=0.01)
    params = moe.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y, aux = moe.moe_forward(params, cfg, x)
    assert not bool(jnp.any(jnp.isnan(y)))
    assert float(jnp.abs(y).mean()) < float(jnp.abs(x).mean())


def test_router_aux_loss_uniform_is_one():
    """Switch aux loss == 1.0 exactly for a perfectly uniform router (its
    minimum); worse-balanced routers score higher."""
    cfg = _cfg("dense")
    m = cfg.moe
    t, e = 4096, m.num_experts
    key = jax.random.PRNGKey(0)
    params = {"router": jnp.zeros((cfg.d_model, e))}  # uniform probs
    x = jax.random.normal(key, (t, cfg.d_model))
    gates, idx, aux = moe._router(params, m, x)
    # uniform probs -> p_e = 1/E; f depends on top-1 tie-breaking but
    # E * sum(f*p) = E * (1/E) * sum(f) = 1
    assert float(aux) == pytest.approx(1.0, rel=1e-3)


def test_gates_normalized():
    cfg = _cfg("dense")
    params = moe.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (64, cfg.d_model))
    gates, idx, aux = moe._router(params, cfg.moe, x)
    np.testing.assert_allclose(
        np.asarray(gates.sum(-1)), np.ones(64), rtol=1e-5
    )
