"""PSO optimizer invariants (paper §3.1 'PSO')."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import pso


def quad_eval(target):
    def eval_fn(xs):
        return jnp.sum((xs - target) ** 2, axis=-1)
    return eval_fn


def test_converges_on_quadratic():
    d = 8
    target = jnp.linspace(-0.5, 0.5, d)
    cfg = pso.PSOConfig(num_particles=48, num_generations=60)
    lo, hi = jnp.full((d,), -1.0), jnp.full((d,), 1.0)
    best, score = pso.run(
        jax.random.PRNGKey(0), jnp.zeros((d,)), lo, hi, quad_eval(target), cfg
    )
    assert float(score) < 1e-3
    np.testing.assert_allclose(np.asarray(best), np.asarray(target), atol=0.05)


def test_center_particle_guarantees_no_regression():
    """Particle 0 is pinned to the previous solution: the result can never
    be worse than the motion-continuity prior (key tracking property)."""
    d = 6
    cfg = pso.PSOConfig(num_particles=8, num_generations=3)
    center = jnp.zeros((d,))
    eval_fn = quad_eval(jnp.zeros((d,)))  # center IS the optimum
    best, score = pso.run(
        jax.random.PRNGKey(1), center, jnp.full((d,), -1.0), jnp.full((d,), 1.0),
        eval_fn, cfg,
    )
    assert float(score) <= float(eval_fn(center[None])[0]) + 1e-9


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_gbest_monotone_nonincreasing(seed):
    """The global best score never increases across generations."""
    d = 5
    cfg = pso.PSOConfig(num_particles=16, num_generations=1)
    key = jax.random.PRNGKey(seed)
    lo, hi = jnp.full((d,), -2.0), jnp.full((d,), 2.0)
    eval_fn = quad_eval(jnp.ones((d,)) * 0.3)
    state = pso.init_swarm(key, jnp.zeros((d,)), lo, hi, eval_fn, cfg)
    prev = float(state.global_best_score)
    for _ in range(5):
        state = pso.swarm_step(state, lo, hi, eval_fn, cfg)
        cur = float(state.global_best_score)
        assert cur <= prev + 1e-9
        prev = cur


def test_positions_respect_bounds():
    d = 4
    cfg = pso.PSOConfig(num_particles=32, num_generations=10)
    lo, hi = jnp.full((d,), -0.5), jnp.full((d,), 0.25)
    eval_fn = quad_eval(jnp.full((d,), 5.0))  # optimum outside the box
    key = jax.random.PRNGKey(2)
    state = pso.init_swarm(key, jnp.zeros((d,)), lo, hi, eval_fn, cfg)
    for _ in range(10):
        state = pso.swarm_step(state, lo, hi, eval_fn, cfg)
    assert bool(jnp.all(state.positions >= lo - 1e-6))
    assert bool(jnp.all(state.positions <= hi + 1e-6))


def test_deterministic_given_key():
    d = 4
    cfg = pso.PSOConfig(num_particles=16, num_generations=8)
    lo, hi = jnp.full((d,), -1.0), jnp.full((d,), 1.0)
    eval_fn = quad_eval(jnp.zeros((d,)))
    a = pso.run(jax.random.PRNGKey(7), jnp.zeros((d,)), lo, hi, eval_fn, cfg)
    b = pso.run(jax.random.PRNGKey(7), jnp.zeros((d,)), lo, hi, eval_fn, cfg)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))


def test_chunked_equals_more_generations():
    """run_chunked executes the same total number of generations."""
    d = 4
    cfg = pso.PSOConfig(num_particles=16, num_generations=8)
    lo, hi = jnp.full((d,), -1.0), jnp.full((d,), 1.0)
    eval_fn = quad_eval(jnp.zeros((d,)))
    best, score, states = pso.run_chunked(
        jax.random.PRNGKey(3), jnp.ones((d,)) * 0.5, lo, hi, eval_fn, cfg,
        num_chunks=4,
    )
    assert len(states) == 4
    assert float(score) < float(eval_fn(jnp.ones((1, d)) * 0.5)[0])
