"""Live migration: golden off-switch equivalence, migration invariants,
hysteresis flap bounds, live batch affinity, and state-transfer pricing.

The acceptance contracts:
* hysteresis thresholds at infinity (or an astronomically large dwell)
  make ``run_fleet(migration=...)`` event-for-event identical to the
  static fleet — bit-for-bit on fps/drops/waits, not approx;
* no frame is ever double-served or lost across a migration, and
  migration count is monotone non-increasing in the min-dwell;
* an adversarial alternating-load scenario makes naive greedy
  re-dispatch (zero dwell, zero threshold) oscillate every frame, while
  the hysteresis controller moves a bounded number of times and ships a
  bounded number of state bytes;
* ``batch_affinity`` is live at re-dispatch time: an edge gathering a
  compatible open batch attracts the migrating client over an
  equally-loaded empty edge, and a migrating fleet's mean batch size
  rises over static striping;
* state transfer is priced with the cost engine's own leg primitives
  (envelope + serialization + wire over the current links).
"""

import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.cluster import (
    LinkDrift,
    MigrationConfig,
    MigrationController,
    run_fleet,
    tracker_state_nbytes,
)
from repro.cluster.events import BatchingSlotServer, EventQueue
from repro.core.costengine import BatchServiceModel, CostEngine
from repro.core.offload import Link, Tier, Topology, WrapperModel
from repro.core.stages import CLIENT, DataItem, Stage, StagedComputation
from repro.sim import hardware


def _comp(n_stages=4, frame_bytes=500_000, flops=5e9):
    sources = (
        DataItem("frame", frame_bytes, CLIENT),
        DataItem("h_prev", 108, CLIENT),
    )
    stages = []
    prev = "frame"
    for i in range(n_stages):
        out = DataItem(f"x{i}", 20_000)
        stages.append(
            Stage(
                name=f"s{i}",
                flops=flops / n_stages,
                inputs=(prev, "h_prev") if i == 0 else (prev,),
                outputs=(out,),
                parallel_fraction=0.95,
            )
        )
        prev = out.name
    return StagedComputation("test", sources, tuple(stages), (prev,))


def _star(num_edges=2, capacity=1, latency=2e-3, stagger=0.1, jitter=0.0,
          accel=0.5e12, batching=False, batch_marginal=0.2):
    hub = Tier("hub", 20e9, 20e9, has_accelerator=False)
    spokes = [
        (
            f"edge_{i}",
            Tier(
                f"edge_{i}",
                accel,
                40e9,
                capacity=capacity,
                batching=batching,
                batch_marginal=batch_marginal,
            ),
            Link(f"link_{i}", 117e6, latency * (1 + stagger * i), jitter),
        )
        for i in range(num_edges)
    ]
    return Topology.star(("hub", hub), spokes, wrapper=WrapperModel())


class _FakeServer:
    """Minimal live-signal surface the controller reads, with externally
    scripted queue depth / open batches — the adversarial driver."""

    def __init__(self, capacity=1, gather_window=0.0):
        self.capacity = capacity
        self.gather_window = gather_window
        self.queue_depth = 0
        self.open_batch = 0

    def load(self, now):
        return self.queue_depth

    def open_batch_size(self, key=None):
        return self.open_batch


def _controller(config, topo, comp, servers, start_edge="edge_0"):
    edges = [n for n in topo.tier_names() if n != topo.home]
    assignments = {e: 0 for e in edges}
    assignments[start_edge] = 1
    return MigrationController(
        config,
        topo=topo,
        comp=comp.fused(),
        servers=servers,
        edges=edges,
        assignments=assignments,
    )


def _drive_adversarial(config, frames=120, period=1.0 / 30.0):
    """Adaptive adversary: whichever edge the client sits on is flooded
    (deep queue) while the other is emptied, every frame — the shape
    that makes naive greedy re-dispatch flap forever."""
    comp = _comp(flops=40e9)  # heavy service: the load term dominates
    topo = _star(num_edges=2)
    servers = {"edge_0": _FakeServer(), "edge_1": _FakeServer()}
    ctl = _controller(config, topo, comp, servers)
    current = "edge_0"
    for k in range(frames):
        servers[current].queue_depth = 10
        other = "edge_1" if current == "edge_0" else "edge_0"
        servers[other].queue_depth = 0
        ctl.frame_done(0)
        move = ctl.consider(0, current, now=k * period, state_src=current)
        if move is not None:
            current = move[0]
    return ctl.stats


# ---------------------------------------------------------------------------
# golden: infinite hysteresis == the static fleet, bit for bit
# ---------------------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 16 - 1))
def test_infinite_hysteresis_is_the_static_fleet_bit_for_bit(seed):
    """Both off-switches — astronomically large dwell, infinite
    improvement threshold — reproduce the migration-free run exactly:
    identical frame events, waits, plans and edge loads."""
    comp = hardware.paper_staged()
    topo = hardware.hotspot_star(num_edges=3, edge_capacity=2)
    static = run_fleet(topo, comp, 6, num_frames=60, seed=seed)
    for off in (
        MigrationConfig(min_dwell_frames=10 ** 9),
        MigrationConfig(min_dwell_frames=1, improvement_threshold=math.inf),
    ):
        frozen = run_fleet(topo, comp, 6, num_frames=60, seed=seed, migration=off)
        assert frozen.clients == static.clients  # events/waits/plans exact
        assert frozen.edges == static.edges
        assert frozen.migration is not None and frozen.migration.count == 0


def test_migration_disabled_returns_no_stats():
    comp = _comp()
    res = run_fleet(_star(), comp, 2, num_frames=10)
    assert res.migration is None
    assert res.total_migrations == 0
    assert all(c.migrations == 0 for c in res.clients)


# ---------------------------------------------------------------------------
# invariants: nothing lost, nothing double-served, dwell monotonicity
# ---------------------------------------------------------------------------


@settings(max_examples=5, deadline=None)
@given(
    st.integers(min_value=0, max_value=2 ** 16 - 1),
    st.integers(min_value=6, max_value=10),
)
def test_no_frame_lost_or_double_served_across_migrations(seed, clients):
    """Across an actively migrating hotspot run: every client's
    processed frame indices are unique and strictly increasing, drops
    account for exactly the remainder, and each processed frame was
    admitted to exactly one edge server."""
    comp = hardware.paper_staged()
    topo = hardware.hotspot_star(num_edges=3, edge_capacity=2)
    res = run_fleet(
        topo, comp, clients, num_frames=90, seed=seed,
        dispatch="least_queue",
        migration=MigrationConfig(min_dwell_frames=5),
    )
    assert res.migration is not None and res.migration.count >= 1
    processed_total = 0
    for c in res.clients:
        idxs = [ev.index for ev in c.stats.processed]
        assert idxs == sorted(set(idxs))  # unique, strictly increasing
        assert all(0 <= i < res.num_frames for i in idxs)
        assert c.stats.dropped == res.num_frames - len(idxs)
        processed_total += len(idxs)
    # every processed frame offloaded its single fused stage exactly once
    assert all(c.plan.compute_by_tier for c in res.clients)
    assert sum(e.admitted for e in res.edges) == processed_total
    # every migration is followed by at least one frame on the new edge
    # — no phantom moves recorded at a client's final frame finish
    for rec in res.migration.records:
        after = [
            ev for ev in res.clients[rec.client].stats.processed
            if ev.start >= rec.time
        ]
        assert after


def test_no_phantom_migration_at_the_final_frame_finish():
    """Regression: a client that just served its last frame has nothing
    left to move — the controller must not record (and price, and count
    against the flap bound) a migration it can never act on."""
    comp = hardware.paper_staged()
    topo = hardware.hotspot_star(num_edges=3, edge_capacity=2)
    res = run_fleet(
        topo, comp, 9, num_frames=11, dispatch="least_queue",
        migration=MigrationConfig(min_dwell_frames=10),
    )
    for rec in res.migration.records:
        after = [
            ev for ev in res.clients[rec.client].stats.processed
            if ev.start >= rec.time
        ]
        assert after, "migration recorded after the client's final frame"


@settings(max_examples=8, deadline=None)
@given(
    st.integers(min_value=1, max_value=20),
    st.integers(min_value=1, max_value=20),
)
def test_migration_count_monotone_nonincreasing_in_min_dwell(d1, d2):
    """Same adversarial observation stream, larger min-dwell => no more
    migrations (and a zero-dwell naive config is the worst case)."""
    lo, hi = sorted((d1, d2))
    cfg = lambda d: MigrationConfig(min_dwell_frames=d, improvement_threshold=0.2)
    count_lo = _drive_adversarial(cfg(lo)).count
    count_hi = _drive_adversarial(cfg(hi)).count
    assert count_lo >= count_hi
    assert _drive_adversarial(cfg(0)).count >= count_lo


# ---------------------------------------------------------------------------
# the flap test: naive greedy oscillates, hysteresis is bounded
# ---------------------------------------------------------------------------


def test_hysteresis_bounds_flapping_under_adversarial_load():
    """The adversary floods whichever edge the client occupies.  Naive
    greedy (zero dwell, zero threshold) migrates every single frame;
    the hysteresis controller's moves — and therefore the state bytes
    it ships — are bounded by frames/min_dwell."""
    frames = 120
    naive = _drive_adversarial(
        MigrationConfig(min_dwell_frames=0, improvement_threshold=0.0),
        frames=frames,
    )
    assert naive.count == frames  # oscillates on EVERY frame
    damped = _drive_adversarial(
        MigrationConfig(min_dwell_frames=30, improvement_threshold=0.2),
        frames=frames,
    )
    assert damped.count <= frames // 30  # <= 4 moves in 120 frames
    assert damped.total_bytes <= (frames // 30) * damped.records[0].nbytes
    assert damped.total_bytes < naive.total_bytes / 20


def test_improvement_threshold_blocks_marginal_moves():
    """A small load imbalance that clears a zero threshold must not
    clear a large one — the second half of the hysteresis."""
    comp = _comp(flops=40e9)
    topo = _star(num_edges=2, stagger=0.0)
    servers = {"edge_0": _FakeServer(), "edge_1": _FakeServer()}
    servers["edge_0"].queue_depth = 1  # mild pressure on the current edge
    greedy = _controller(
        MigrationConfig(min_dwell_frames=0, improvement_threshold=0.0),
        topo, comp, servers,
    )
    greedy.frame_done(0)
    assert greedy.consider(0, "edge_0", now=0.0, state_src="edge_0") is not None
    picky = _controller(
        MigrationConfig(min_dwell_frames=0, improvement_threshold=0.9),
        topo, comp, servers,
    )
    picky.frame_done(0)
    assert picky.consider(0, "edge_0", now=0.0, state_src="edge_0") is None
    assert picky.stats.count == 0 and picky.stats.considered == 1


def test_drift_forces_consideration_but_not_the_threshold():
    """force=True (the fleet's drift signal) waives the dwell gate only:
    an un-dwelled client is considered, but a threshold it cannot clear
    still pins it in place."""
    comp = _comp(flops=40e9)
    topo = _star(num_edges=2, stagger=0.0)
    servers = {"edge_0": _FakeServer(), "edge_1": _FakeServer()}
    servers["edge_0"].queue_depth = 10
    ctl = _controller(
        MigrationConfig(min_dwell_frames=50, improvement_threshold=0.2),
        topo, comp, servers,
    )
    # zero dwell: gated without force, considered and moved with it
    assert ctl.consider(0, "edge_0", now=0.0, state_src="edge_0") is None
    assert ctl.stats.considered == 0
    move = ctl.consider(0, "edge_0", now=0.0, state_src="edge_0", force=True)
    assert move is not None and move[0] == "edge_1"
    # but force never overrides the improvement threshold
    ctl2 = _controller(
        MigrationConfig(min_dwell_frames=50, improvement_threshold=math.inf),
        topo, comp, servers,
    )
    assert ctl2.consider(0, "edge_0", now=0.0, state_src="edge_0", force=True) is None


# ---------------------------------------------------------------------------
# batch_affinity live: open batches attract migrating clients
# ---------------------------------------------------------------------------


def _batching_servers(comp, queue, window=5e-3):
    return {
        e: BatchingSlotServer(
            e, capacity=2, queue=queue, model=BatchServiceModel(),
            gather_window=window,
        )
        for e in ("edge_0", "edge_1")
    }


@pytest.mark.parametrize("target_policy", ["predicted", "batch_affinity"])
def test_open_batch_attracts_migrating_client_over_equal_empty_edge(
    target_policy,
):
    """Two equally-loaded batching edges — one in-flight request each —
    but only edge_1's is an open batch under the client's computation
    key.  Both target modes must steer the migrating client there: the
    PR 3 review note (admission-time affinity never sees open batches)
    exercised for real."""
    comp = _comp()
    topo = _star(num_edges=2, stagger=0.0, batching=True)
    q = EventQueue()
    servers = _batching_servers(comp, q)
    ctl = _controller(
        MigrationConfig(
            min_dwell_frames=0,
            improvement_threshold=0.0,
            target_policy=target_policy,
        ),
        topo, comp, servers,
    )
    ctl.frame_done(0)
    # no batch open anywhere: equally-loaded edges, no reason to move
    assert ctl.consider(0, "edge_0", now=0.0, state_src="edge_0") is None
    # equal load (one request each), but edge_1's batch is COMPATIBLE
    servers["edge_0"].submit(0.0, 2e-3, lambda s, f: None, key="other_kernel")
    servers["edge_1"].submit(0.0, 2e-3, lambda s, f: None, key=comp.fused().name)
    assert servers["edge_0"].load(1e-3) == servers["edge_1"].load(1e-3) == 1
    move = ctl.consider(0, "edge_0", now=1e-3, state_src="edge_0")
    assert move is not None and move[0] == "edge_1"
    assert move[1] > 0.0  # the state transfer is still priced


def test_foreign_key_batch_does_not_attract():
    comp = _comp()
    topo = _star(num_edges=2, stagger=0.0, batching=True)
    q = EventQueue()
    servers = _batching_servers(comp, q)
    servers["edge_1"].submit(0.0, 2e-3, lambda s, f: None, key="other_kernel")
    ctl = _controller(
        MigrationConfig(min_dwell_frames=0, improvement_threshold=0.0),
        topo, comp, servers,
    )
    ctl.frame_done(0)
    assert ctl.consider(0, "edge_0", now=1e-3, state_src="edge_0") is None


def test_migrating_fleet_raises_mean_batch_size_over_static_striping():
    """A batching hotspot star: static striping pins batches at the
    stripe width; migration drains the weak edge into the strong edges'
    forming batches, so the biggest mean batch grows and drops fall."""
    comp = hardware.paper_staged()
    topo = hardware.hotspot_star(num_edges=3, edge_capacity=1, batching=True)
    static = run_fleet(
        topo, comp, 9, num_frames=150, dispatch="least_queue",
        gather_window=5e-3,
    )
    mig = run_fleet(
        topo, comp, 9, num_frames=150, dispatch="least_queue",
        gather_window=5e-3, migration=MigrationConfig(min_dwell_frames=10),
    )
    assert mig.migration is not None and mig.migration.count >= 1
    assert max(e.mean_batch_size for e in mig.edges) > max(
        e.mean_batch_size for e in static.edges
    )
    assert mig.drop_rate < static.drop_rate


# ---------------------------------------------------------------------------
# the hotspot acceptance shape, at test scale
# ---------------------------------------------------------------------------


def test_migration_beats_static_dispatch_on_the_hotspot_star():
    """One weak edge saturates under load-blind striping; live migration
    must strictly improve BOTH the drop rate and the p99 frame latency,
    with a bounded number of moves per client."""
    comp = hardware.paper_staged()
    topo = hardware.hotspot_star(num_edges=3, edge_capacity=2)
    static = run_fleet(topo, comp, 9, num_frames=300, dispatch="least_queue")
    mig = run_fleet(
        topo, comp, 9, num_frames=300, dispatch="least_queue",
        migration=MigrationConfig(min_dwell_frames=10),
    )
    assert mig.drop_rate < static.drop_rate
    assert mig.p99_loop_time < static.p99_loop_time
    per_client = mig.migration.per_client()
    assert per_client and max(per_client.values()) <= 3
    # the weak edge drains; the strong edges absorb the hotspot clients
    weak_static = next(e for e in static.edges if e.name == "edge_0")
    weak_mig = next(e for e in mig.edges if e.name == "edge_0")
    assert weak_mig.clients < weak_static.clients


def test_drift_triggers_migration_instead_of_local_retreat():
    """When a spoke's link degrades, static clients can only re-plan in
    place (often retreating to the slow local plan); migrating clients
    re-home to the healthy spoke, carrying their state across."""
    comp = hardware.paper_staged()
    topo = hardware.fleet_star(num_edges=2, edge_capacity=4)
    drifts = [LinkDrift(time=2.0, link="5g_edge_0", latency=40e-3)]
    static = run_fleet(topo, comp, 8, num_frames=200, drifts=drifts)
    mig = run_fleet(
        topo, comp, 8, num_frames=200, drifts=drifts,
        migration=MigrationConfig(min_dwell_frames=10),
    )
    assert mig.migration is not None and mig.migration.count >= 1
    for rec in mig.migration.records:
        assert rec.src == "edge_0" and rec.dst == "edge_1"
        assert rec.state_src == "edge_0"
        assert rec.latency > 0.0
        assert rec.nbytes == tracker_state_nbytes()
    # every migrated client now lives on the healthy spoke
    moved = {rec.client for rec in mig.migration.records}
    for c in mig.clients:
        if c.client in moved:
            assert c.edge == "edge_1" and c.migrations >= 1
    assert mig.drop_rate < static.drop_rate


# ---------------------------------------------------------------------------
# state-transfer pricing
# ---------------------------------------------------------------------------


def test_migration_time_is_the_cost_engine_leg_arithmetic():
    """Edge-to-edge state transfer = RPC envelope (2 latencies per leg +
    wrapped call overhead) + serialize/deserialize + wire time per leg,
    composed from the same primitives plans are priced with."""
    topo = _star(num_edges=2)  # link_0: 2.0ms, link_1: 2.2ms, 117 MB/s
    eng = CostEngine(topo)
    n = tracker_state_nbytes()
    w = topo.wrapper
    expect = (
        2 * w.call_overhead
        + 2 * 2.0e-3 + 2 * 2.2e-3  # request+response latency, both legs
        + 2 * (n / w.serialization_bandwidth)
        + n / 117e6 + n / 117e6  # wire time on both legs
    )
    assert eng.migration_time(n, "edge_0", "edge_1") == pytest.approx(expect)
    # home -> edge crosses one leg
    one = (
        2 * w.call_overhead + 2 * 2.0e-3
        + 2 * (n / w.serialization_bandwidth) + n / 117e6
    )
    assert eng.migration_time(n, "hub", "edge_0") == pytest.approx(one)
    # no-op and monotonicity
    assert eng.migration_time(n, "edge_0", "edge_0") == 0.0
    assert eng.migration_time(2 * n, "edge_0", "edge_1") > eng.migration_time(
        n, "edge_0", "edge_1"
    )
    # unwrapped topologies pay no RPC envelope, but the transfer is
    # still an explicit fetch: one propagation latency per leg plus
    # serialization and wire — transfer_scalar's piggyback=False price
    raw = Topology(
        tiers=dict(topo.tiers), links=dict(topo.links), home=topo.home,
        wrapper=topo.wrapper, wrapped=False,
    )
    raw_eng = CostEngine(raw)
    got = raw_eng.migration_time(n, "edge_0", "edge_1")
    assert got == pytest.approx(
        2.0e-3 + 2.2e-3
        + 2 * (n / w.serialization_bandwidth) + 2 * (n / 117e6)
    )
    assert got == pytest.approx(
        raw_eng.transfer_scalar(n, "edge_0", "edge_1", piggyback=False)
    )


def test_migration_pricing_uses_current_link_conditions():
    """A drifted link must charge its drifted latency to the transfer —
    the controller prices against the live table, not the seed topo."""
    comp = _comp()
    topo = _star(num_edges=2, stagger=0.0)
    servers = {"edge_0": _FakeServer(), "edge_1": _FakeServer()}
    ctl = _controller(MigrationConfig(), topo, comp, servers)
    before = ctl.migration_time("edge_0", "edge_1")
    ctl.link_table.set("link_0", latency=50e-3)
    after = ctl.migration_time("edge_0", "edge_1")
    assert after == pytest.approx(before + 2 * (50e-3 - 2e-3))


def test_tracker_state_nbytes_and_config_validation():
    # 27-dim pose (108 bytes / f32) + 64 particles x (pos, vel, pbest)
    # + the swarm's global best
    assert tracker_state_nbytes() == 4 * (27 + 64 * 3 * 27 + 27)
    assert tracker_state_nbytes(num_particles=1, pose_dims=1) == 4 * (1 + 3 + 1)
    with pytest.raises(ValueError):
        MigrationConfig(min_dwell_frames=-1)
    with pytest.raises(ValueError):
        MigrationConfig(improvement_threshold=-0.1)
    with pytest.raises(ValueError):
        MigrationConfig(state_nbytes=-1)
    with pytest.raises(ValueError):
        MigrationConfig(target_policy="nope")
    with pytest.raises(ValueError):
        # blind rotation carries no load signal for live re-dispatch
        MigrationConfig(target_policy="round_robin")
    MigrationConfig(target_policy="least_queue")  # load-aware: accepted


# ---------------------------------------------------------------------------
# predictor calibration: measured-wait EWMA vs plan-total misprediction
# ---------------------------------------------------------------------------


def test_wait_ewma_smooths_and_defaults_to_off():
    cfg = MigrationConfig(wait_ewma_alpha=0.5)
    ctrl = _controller(cfg, _star(), _comp(), {"edge_0": _FakeServer(),
                                               "edge_1": _FakeServer()})
    assert ctrl.wait_ewma("edge_0") == 0.0  # no samples yet
    ctrl.observe_wait("edge_0", 0.1)
    assert ctrl.wait_ewma("edge_0") == 0.1  # first sample seeds the EWMA
    ctrl.observe_wait("edge_0", 0.3)
    assert ctrl.wait_ewma("edge_0") == pytest.approx(0.2)
    with pytest.raises(ValueError):
        MigrationConfig(wait_ewma_blend=1.5)
    with pytest.raises(ValueError):
        MigrationConfig(wait_ewma_alpha=0.0)


def test_throttled_edge_mispredicts_without_wait_ewma():
    """The calibration contract, at the controller level: an empty but
    *throttled* edge looks ideal to plan totals + live queue depth (the
    historical predictor), so the client walks into it; blending the
    measured-wait EWMA keeps it out.  Same topology, same live signals,
    same measured evidence — only the blend differs."""
    comp = _comp(flops=40e9)  # ~80 ms edge service: occupancy dominates
    topo = _star(num_edges=2)
    for blend, expect_move in ((0.0, True), (0.7, False)):
        servers = {"edge_0": _FakeServer(), "edge_1": _FakeServer()}
        cfg = MigrationConfig(
            min_dwell_frames=0,
            improvement_threshold=0.05,
            wait_ewma_blend=blend,
        )
        ctrl = _controller(cfg, topo, comp, servers, start_edge="edge_1")
        # a second client is committed to edge_1; edge_0 sits empty
        ctrl.assignments["edge_1"] = 2
        # measured evidence: edge_0 is thermally throttled (its recent
        # frames waited ~200 ms), edge_1 waits are mild
        for _ in range(4):
            ctrl.observe_wait("edge_0", 0.2)
            ctrl.observe_wait("edge_1", 0.02)
        move = ctrl.consider(0, "edge_1", now=1.0, state_src="edge_1")
        if expect_move:
            assert move is not None and move[0] == "edge_0"  # mispredicts
        else:
            assert move is None  # the measured waits expose the throttle


def test_service_drift_throttle_is_invisible_to_plans_but_not_waits():
    """Fleet-level ServiceDrift mechanics: a throttle factor of 1.0 is
    bit-for-bit no drift; a real throttle inflates only measured waits
    (plans and link observations are untouched), so drop rate rises
    with no re-plans."""
    from repro.cluster import ServiceDrift
    from repro.net import links

    comp = hardware.paper_staged()
    topo = hardware.fleet_star(num_edges=2, edge_capacity=2,
                               base_link=links.GIGABIT_ETHERNET)
    base = run_fleet(topo, comp, 4, num_frames=120, seed=0)
    noop = run_fleet(topo, comp, 4, num_frames=120, seed=0,
                     drifts=[ServiceDrift(time=1.0, edge="edge_0", factor=1.0)])
    for a, b in zip(base.clients, noop.clients):
        assert a.stats.processed == b.stats.processed
        assert a.total_wait == b.total_wait
    hot = run_fleet(topo, comp, 4, num_frames=120, seed=0,
                    drifts=[ServiceDrift(time=1.0, edge="edge_0", factor=8.0)])
    assert hot.drop_rate > base.drop_rate
    assert hot.total_replans == 0  # nothing crossed the wire differently
    with pytest.raises(ValueError):
        run_fleet(topo, comp, 2, num_frames=10,
                  drifts=[ServiceDrift(time=0.0, edge="nope", factor=2.0)])


def test_wait_ewma_blend_evacuates_a_throttled_edge():
    """End to end: a mid-run thermal throttle on one edge.  The plain
    predictor (blend 0) never moves — plan totals cannot see the
    throttle and the queue-depth signal at decision time is ambiguous —
    while the blended predictor drains the throttled edge and recovers
    most of the dropped frames."""
    from repro.cluster import ServiceDrift
    from repro.net import links

    comp = hardware.paper_staged()
    topo = hardware.fleet_star(num_edges=2, edge_capacity=2,
                               base_link=links.GIGABIT_ETHERNET)
    drifts = [ServiceDrift(time=1.0, edge="edge_0", factor=8.0)]
    kwargs = dict(num_frames=240, seed=0, dispatch="least_queue",
                  drifts=drifts)
    plain = run_fleet(topo, comp, 6,
                      migration=MigrationConfig(min_dwell_frames=10),
                      **kwargs)
    blended = run_fleet(
        topo, comp, 6,
        migration=MigrationConfig(min_dwell_frames=10, wait_ewma_blend=0.6),
        **kwargs,
    )
    assert plain.total_migrations == 0  # the misprediction, fleet-scale
    assert blended.total_migrations > 0
    assert all(c.edge != "edge_0" for c in blended.clients)
    assert blended.drop_rate < 0.5 * plain.drop_rate
    assert blended.p99_loop_time < plain.p99_loop_time


def test_wait_ewma_evidence_decays_with_age():
    """Measured evidence ages: right after the samples the throttled
    edge repels the client, but long after anyone last visited it the
    blend weight has halved away and the model (which sees an empty
    edge) wins again — the re-probe that stops a stale measurement
    pinning the fleet off a recovered edge forever."""
    comp = _comp(flops=40e9)
    topo = _star(num_edges=2)
    servers = {"edge_0": _FakeServer(), "edge_1": _FakeServer()}
    cfg = MigrationConfig(
        min_dwell_frames=0,
        improvement_threshold=0.05,
        wait_ewma_blend=0.7,
        wait_ewma_half_life=3.0,
    )
    ctrl = _controller(cfg, topo, comp, servers, start_edge="edge_1")
    ctrl.assignments["edge_1"] = 2
    for _ in range(4):
        ctrl.observe_wait("edge_0", 0.2, now=0.5)
        ctrl.observe_wait("edge_1", 0.02, now=0.5)
    # fresh evidence: the throttled edge is out
    assert ctrl.consider(0, "edge_1", now=1.0, state_src="edge_1") is None
    # ~20 half-lives later the stale sample carries no weight
    move = ctrl.consider(0, "edge_1", now=60.0, state_src="edge_1")
    assert move is not None and move[0] == "edge_0"
    with pytest.raises(ValueError):
        MigrationConfig(wait_ewma_half_life=0.0)
