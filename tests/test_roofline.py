"""HLO cost walker: loop scaling, dot flops, collective census."""

import pytest

from repro.roofline import hlo_cost

MINI_HLO = """
HloModule test

%inner_body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %a = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %w = f32[16,16]{1,0} constant({...})
  %d = f32[8,16]{1,0} dot(%a, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%d), replica_groups={}, to_apply=%sum
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[8,16]) tuple(%i, %ar)
}

%cond (q: (s32[], f32[8,16])) -> pred[] {
  %q = (s32[], f32[8,16]) parameter(0)
  ROOT %lt = pred[] constant(false)
}

%sum (x: f32[], y: f32[]) -> f32[] {
  %x = f32[] parameter(0)
  %y = f32[] parameter(1)
  ROOT %add = f32[] add(%x, %y)
}

ENTRY %main (in: f32[8,16]) -> f32[8,16] {
  %in = f32[8,16]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %tup = (s32[], f32[8,16]) tuple(%zero, %in)
  %w = (s32[], f32[8,16]) while(%tup), condition=%cond, body=%inner_body, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%w), index=1
}
"""


def test_loop_scaled_dot_flops():
    cost = hlo_cost.analyze_hlo(MINI_HLO)
    # one dot: 2 * 8*16 out * 16 contract = 4096 flops, x10 trips
    assert cost.flops == pytest.approx(4096 * 10)


def test_loop_scaled_collective_bytes():
    cost = hlo_cost.analyze_hlo(MINI_HLO)
    # all-reduce of f32[8,16] = 512 B, x10 trips
    assert cost.coll_bytes == pytest.approx(512 * 10)
    assert cost.coll_by_kind["all-reduce"] == pytest.approx(5120)


def test_shape_parse():
    dims, nbytes = hlo_cost._shape_dims_bytes("bf16[4,128]{1,0}")
    assert dims == [[4, 128]]
    assert nbytes == 4 * 128 * 2


@pytest.fixture(scope="session")
def dryrun_dir(tmp_path_factory):
    """Synthesize the experiments/dryrun artifact set the report loader
    consumes: one JSON per (arch x shape x mesh) combo, with the same
    schema ``repro.launch.dryrun.run_one`` writes.  Compiling the real
    grid needs 512 fake XLA devices and ~hours; the loader's contract is
    the record shape, which this fixture pins down instead."""
    import json

    from repro.configs import registry
    from repro.configs import shapes as shp

    out = tmp_path_factory.mktemp("dryrun")
    for arch in registry.list_archs():
        cfg = registry.get(arch)
        for shape_name, shape in shp.ALL_SHAPES.items():
            for mesh in ("pod16x16", "pod2x16x16"):
                rec = {"arch": arch, "shape": shape_name, "mesh": mesh}
                if not shp.applicable(cfg, shape):
                    rec.update(
                        status="skipped",
                        reason="long_500k skipped: pure full-attention arch",
                    )
                else:
                    chips = 256 if mesh == "pod16x16" else 512
                    flops = 2.0 * cfg.active_param_count() * 1024
                    rec.update(
                        status="ok",
                        chips=chips,
                        lower_s=1.0,
                        compile_s=30.0,
                        cost={"flops": flops},
                        memory={"bytes_per_chip": 8 * 2**30},
                        roofline={
                            "compute_s": 2e-3,
                            "memory_s": 1e-3,
                            "collective_s": 5e-4,
                            "dominant": "compute",
                            "model_flops": flops,
                            "useful_ratio": 0.5,
                            "coll_bytes": 1e8,
                            "coll_by_kind": {"all-reduce": 1e8},
                        },
                        hlo_bytes_len=1000,
                    )
                path = out / f"{arch}__{shape_name}__{mesh}.json"
                path.write_text(json.dumps(rec, indent=1))
    return str(out)


def test_report_loader(dryrun_dir):
    from repro.roofline import report

    recs = report.load_records(dryrun_dir)
    s = report.summary(recs)
    assert s["error"] == 0
    assert s["ok"] >= 60  # 35 combos x 2 meshes, minus nothing
    table = report.roofline_table(recs)
    assert table.startswith("| arch | shape |")
    assert "mixtral-8x7b" in table


def test_report_dryrun_table(dryrun_dir):
    from repro.roofline import report

    recs = report.load_records(dryrun_dir)
    table = report.dryrun_table(recs)
    assert "| ok |" in table and "| skipped |" in table
    assert "all-reduce" in table
