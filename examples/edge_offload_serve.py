"""END-TO-END DRIVER — the paper's main scenario, served.

    PYTHONPATH=src python examples/edge_offload_serve.py

A weak laptop client receives 30 fps RGBD frames and must hand-track in
real time. We *execute* the tracker (bit-exact JAX computation) for every
deployment the paper evaluates — native on both machines, wrapped, and
offloaded over Ethernet/Wi-Fi with Forced/Auto policies — while a
simulated clock charges network/wrapper/compute time and applies the
Fig. 3 frame-drop rule. Reproduces Figs. 4 and 5 and couples deployment
speed to tracking quality (dropped frames => wider search => worse
tracking), which the paper describes but could not quantify.
"""

import numpy as np

from repro.core import offload, pso, tracker
from repro.core.camera import Camera
from repro.core.offload import Policy
from repro.data import rgbd
from repro.sim import hardware, runtime


def main() -> None:
    # Working resolution/budget trimmed so the full 12-deployment grid
    # executes in minutes on a laptop-class CPU; the *simulated* tiers
    # still model the paper's hardware (sim/hardware.py anchors).
    cam = Camera(width=48, height=48, fx=45.0, fy=45.0, cx=23.5, cy=23.5)
    seq_cfg = rgbd.SequenceConfig(num_frames=36, camera=cam, fast_burst=(18, 26))
    frames, truth = rgbd.render_sequence(seq_cfg)
    tcfg = tracker.TrackerConfig(
        camera=cam, pso=pso.PSOConfig(num_particles=32, num_generations=10),
        smoothing=0.0,
    )
    tiers = hardware.paper_tiers()

    print(f"{'deployment':44s} {'fps':>6s} {'drop%':>6s} {'pos_err_cm':>10s}")

    # clock charges the PAPER-scale workload; the reduced tracker runs
    # for quality measurement (see executed_run's timing_comp)
    paper_comp = hardware.paper_staged()

    def report(name, env, policy, gran):
        res = runtime.executed_run(
            tcfg, env, policy, frames, truth, gran, timing_comp=paper_comp
        )
        print(f"{name:44s} {res.sim.fps:6.1f} "
              f"{res.sim.stats.drop_rate * 100:6.1f} "
              f"{res.mean_pos_error * 100:10.2f}")

    # Fig. 4: local deployments
    for machine in ("server", "laptop"):
        for wrapped in (False, True):
            env = offload.Environment(
                client=tiers[machine], server=tiers["server"],
                link=hardware.links.GIGABIT_ETHERNET,
                wrapper=hardware.paper_wrapper(), wrapped=wrapped,
            )
            tag = "wrapped" if wrapped else "native"
            report(f"local/{machine}/{tag}", env, Policy.LOCAL, "single_step")

    # Fig. 5: offloaded deployments
    for net in ("gigabit_ethernet", "wifi_802.11"):
        env = hardware.paper_environment(net)
        for pol in (Policy.FORCED, Policy.AUTO):
            for gran in ("single_step", "multi_step"):
                report(f"offload/{net}/{pol.value}/{gran}", env, pol, gran)

    print("\npaper anchors: server native >40fps; laptop native ~13fps;"
          " forced+single+ethernet ~10fps; auto ~10-11fps everywhere")


if __name__ == "__main__":
    main()
