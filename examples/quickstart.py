"""Quickstart: track a synthetic hand sequence end to end on CPU.

    PYTHONPATH=src python examples/quickstart.py

Builds the 27-DoF generative tracker (paper §3.1), renders a synthetic
RGBD sequence with known ground truth, tracks it frame by frame with PSO,
and reports position/articulation error — the core loop the paper runs
natively on its server/laptop.
"""

import time

import jax.numpy as jnp
import numpy as np

from repro.core import pso, tracker
from repro.core.camera import Camera
from repro.data import rgbd


def main() -> None:
    cam = Camera(width=64, height=64, fx=60.0, fy=60.0, cx=31.5, cy=31.5)
    seq_cfg = rgbd.SequenceConfig(
        num_frames=45, camera=cam, fast_burst=(25, 32),
        position_amplitude=0.05, curl_amplitude=0.7,
    )
    print("rendering synthetic RGBD sequence (the 'pre-recorded video')...")
    frames, truth = rgbd.render_sequence(seq_cfg)

    cfg = tracker.TrackerConfig(
        camera=cam,
        pso=pso.PSOConfig(num_particles=48, num_generations=20),
        smoothing=0.1,
    )
    t = tracker.Tracker(cfg, h0=truth[0])

    print(f"tracking {frames.shape[0]} frames "
          f"({cfg.pso.num_particles} particles x {cfg.pso.num_generations} generations)...")
    pos_errs, ang_errs, times = [], [], []
    for i in range(1, frames.shape[0]):
        t0 = time.perf_counter()
        h, score = t.step(frames[i])
        times.append(time.perf_counter() - t0)
        pos_errs.append(float(jnp.linalg.norm(h[:3] - truth[i][:3])))
        ang_errs.append(float(jnp.mean(jnp.abs(h[7:] - truth[i][7:]))))
        if i % 10 == 0:
            print(f"  frame {i:3d}: E_D={score:.4f} "
                  f"pos_err={pos_errs[-1] * 100:.2f}cm")

    print("\nresults:")
    print(f"  mean position error : {np.mean(pos_errs) * 100:.2f} cm")
    print(f"  mean angle error    : {np.degrees(np.mean(ang_errs)):.2f} deg")
    print(f"  mean loop time      : {np.mean(times[2:]) * 1e3:.1f} ms "
          f"({1 / np.mean(times[2:]):.1f} fps on this CPU)")
    print("  (the paper's GTX 1080M server runs the equivalent loop at >40 fps)")


if __name__ == "__main__":
    main()
