"""Train a ~100M-parameter LM for a few hundred steps (loss must drop).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse

from repro.launch import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument(
        "--big", action="store_true",
        help="~130M-param configuration (use on a TPU/GPU host; the "
        "2-core CPU container default is an 8.7M reduced variant)",
    )
    args = ap.parse_args()
    if args.big:
        result = train.run(
            args.arch, steps=args.steps, batch=32, seq=1024,
            reduced=True, lr=3e-4, big=True,
        )
    else:
        result = train.run(
            args.arch, steps=args.steps, batch=8, seq=256, reduced=True, lr=6e-4
        )
    print(f"\narch={result['arch']} params={result['params'] / 1e6:.1f}M")
    print(f"loss {result['first_loss']:.3f} -> {result['final_loss']:.3f} "
          f"({'improved' if result['improved'] else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
