"""Fleet simulation walkthrough: from the paper's one client to a city.

Runs a capacity sweep of paper-style thin clients against two shared
metro-edge GPU boxes, compares dispatch policies, injects Wi-Fi-grade
latency drift on one spoke mid-run and shows that only the affected
clients re-plan (the RAPID adaptive loop at fleet scale), turns on
edge batching and shows the fused-launch capacity lift on a wired star,
arms live migration on a hotspot star — clients drain off the
saturated weak edge mid-run, carrying their pose + swarm state — and
finally arms the payload codec on the network-bound 5G star: the
rate-controlled delta+quantize stream cuts the 537.6 kB frame to tens
of kB and lifts every client back to camera rate.  Then the spokes
stop being private: every client's wire legs contend for one shared
5G cell (``hardware.shared_cell_star``), and the same codec is run
blind vs with the cell-fairness loop — the fair fleet backs off down
the bits ladder (heaviest payload first) and buys back the queueing
the blind fleet drowns in.  The fleet then stops being single-model:
clients cycle across the multi-model workload registry (solo landmark
chain, branching multi-hand tree, gesture head, RGBD DAG) and the
DAG-aware planner — pricing conditional branches at expected cost —
is raced against forced linearization.  A final pass reruns
the codec fleet with telemetry armed: per-frame span traces exported as
Chrome trace-event JSON (load ``fleet_trace.json`` in Perfetto or
``chrome://tracing``) and the latency-attribution table showing where
each millisecond of p50/p99 loop time went.  The closing act arms the
online SLO monitor on the doctor star and throttles one edge mid-run:
the burn-rate windows open a timestamped incident, the root-cause
attributor diffs the incident window against the healthy baseline, and
the printed report names the throttled edge's queue as the culprit.

  PYTHONPATH=src python examples/fleet_sim.py
"""

from __future__ import annotations

import dataclasses

from repro.cluster import (
    DOCTOR_CLASSES,
    LinkDrift,
    MigrationConfig,
    SLOMonitor,
    Telemetry,
    capacity_sweep,
    doctor_verdict,
    run_fleet,
)
from repro.cluster.fleet import ServiceDrift
from repro.codec import CodecConfig, sequence_motion
from repro.core.offload import Policy
from repro.net import links
from repro.sim import hardware


def main() -> None:
    comp = hardware.paper_staged()
    topo = hardware.fleet_star(num_edges=2, edge_capacity=4)

    print("== capacity sweep (round_robin) ==")
    print("clients  fps    drop    p99_ms  cache_hit")
    for p in capacity_sweep(topo, comp, (1, 2, 4, 8, 16, 32), num_frames=150):
        print(
            f"{p.num_clients:7d}  {p.fps:5.1f}  {p.drop_rate:6.3f}  "
            f"{p.p99 * 1e3:6.1f}  {p.result.cache.stats.hit_rate:9.2f}"
        )

    print("\n== dispatch policies at 16 clients ==")
    for dispatch in ("round_robin", "least_queue", "latency_weighted"):
        r = run_fleet(
            topo, comp, num_clients=16, num_frames=150, dispatch=dispatch
        )
        loads = ", ".join(f"{e.name}:{e.clients}" for e in r.edges)
        print(
            f"{dispatch:17s} fps={r.mean_achieved_fps:5.1f} "
            f"drop={r.drop_rate:.3f} p99={r.p99_loop_time * 1e3:6.1f}ms "
            f"assignment [{loads}]"
        )

    print("\n== drift: spoke 0 degrades to Wi-Fi latency at t=2s ==")
    r = run_fleet(
        topo,
        comp,
        num_clients=8,
        num_frames=200,
        policy=Policy.AUTO,
        drifts=[LinkDrift(time=2.0, link="5g_edge_0", latency=40e-3)],
    )
    for c in r.clients:
        print(
            f"client {c.client} on {c.edge}: replans={c.replans} "
            f"drop={c.stats.drop_rate:.3f} mean_wait={c.mean_wait * 1e3:.2f}ms"
        )
    s = r.cache.stats
    print(f"plan cache: {s.hits} hits / {s.misses} misses ({s.hit_rate:.0%})")

    print("\n== edge batching: FIFO vs fused launches (wired star) ==")
    print("clients  mode       fps    drop    mean_batch")
    for batching in (False, True):
        wired = hardware.fleet_star(
            num_edges=2,
            edge_capacity=1,
            base_link=links.GIGABIT_ETHERNET,
            batching=batching,
        )
        mode = "batched" if batching else "unbatched"
        for n in (8, 16, 32):
            r = run_fleet(wired, comp, num_clients=n, num_frames=150)
            mbs = max((e.mean_batch_size for e in r.edges), default=0.0)
            print(
                f"{n:7d}  {mode:9s}  {r.mean_achieved_fps:5.1f}  "
                f"{r.drop_rate:6.3f}  {mbs:10.1f}"
            )

    print("\n== live migration: hotspot star (edge_0 is 8x slower) ==")
    hotspot = hardware.hotspot_star(num_edges=3, edge_capacity=2)
    for mode, mig in (
        ("static", None),
        ("migrate", MigrationConfig(min_dwell_frames=10)),
    ):
        r = run_fleet(
            hotspot, comp, num_clients=9, num_frames=300,
            dispatch="least_queue", migration=mig,
        )
        loads = ", ".join(
            f"{e.name}:{e.clients}(peak {e.peak_load})" for e in r.edges
        )
        print(
            f"{mode:8s} fps={r.mean_achieved_fps:5.1f} "
            f"drop={r.drop_rate:.3f} p99={r.p99_loop_time * 1e3:6.1f}ms "
            f"[{loads}]"
        )
        if r.migration is not None:
            for rec in r.migration.records:
                print(
                    f"  client {rec.client}: {rec.src} -> {rec.dst} at "
                    f"t={rec.time:.2f}s, {rec.nbytes / 1e3:.1f} kB of "
                    f"state in {rec.latency * 1e3:.2f} ms"
                )

    print("\n== payload codec: raw vs delta+quantize on the 5G star ==")
    cfg = CodecConfig(base=hardware.codec_point(), motion=sequence_motion())
    for mode, codec in (("raw", None), ("codec", cfg)):
        r = run_fleet(topo, comp, num_clients=8, num_frames=150, codec=codec)
        point = r.clients[0].codec
        knobs = (
            f" [{point.quant_bits}-bit depth, keyframe every "
            f"{point.keyframe_interval}]" if point is not None else ""
        )
        print(
            f"{mode:6s} fps={r.mean_achieved_fps:5.1f} "
            f"drop={r.drop_rate:.3f} "
            f"uplink={r.mean_uplink_bytes / 1e3:6.1f} kB/frame "
            f"rate_changes={r.total_rate_changes}{knobs}"
        )

    print("\n== shared 5G cell: blind vs fair rate control ==")
    # one narrow radio cell, one transmission slot, 12 equal clients
    cell = hardware.shared_cell_star(
        num_edges=2,
        edge_capacity=4,
        base_link=dataclasses.replace(links.FIVE_G_EDGE, bandwidth=15e6),
        cell_capacity=1,
    )
    fair_cfg = CodecConfig(
        base=hardware.codec_point(entropy=True),  # entropy codec v2
        motion=sequence_motion(),
        bits_ladder=(16, 8, 4, 2),
        cell_threshold=0.1e-3,  # smoothed ratio-weighted wait per rung
        cell_stagger=0.05,  # deterministic shed order
        resync_bound=4,  # drops clamp keyframe spacing
    )
    blind_cfg = dataclasses.replace(fair_cfg, cell_threshold=float("inf"))
    for mode, codec in (("blind", blind_cfg), ("fair", fair_cfg)):
        r = run_fleet(
            cell, comp, num_clients=12, num_frames=150,
            dispatch="latency_weighted", codec=codec,
        )
        lk = r.links[0]
        served = [len(c.stats.processed) for c in r.clients]
        print(
            f"{mode:6s} fps={r.mean_achieved_fps:5.1f} "
            f"drop={r.drop_rate:.3f} "
            f"uplink={r.mean_uplink_bytes / 1e3:6.1f} kB/frame "
            f"cell wait={lk.mean_wait * 1e3:5.2f}ms/txn "
            f"served spread={max(served) / min(served):.2f}x"
        )

    print("\n== mixed multi-model traffic: DAG-aware vs linearized ==")
    # client c runs mix[c % 4]: chain / out-tree / gesture head / RGBD
    # DAG.  The linearized arm forces every conditional branch (second
    # hand, re-detect, re-seed) to run on every frame — what a
    # DAG-blind planner must assume; expected-cost pricing stops
    # paying for branches that rarely fire.
    mix = hardware.mixed_workloads()
    wired = hardware.fleet_star(
        num_edges=2, edge_capacity=2, base_link=links.GIGABIT_ETHERNET
    )
    for mode, suite in (
        ("linearized", tuple(w.linearized() for w in mix)),
        ("dag-aware", mix),
    ):
        r = run_fleet(
            wired, comp, num_clients=12, num_frames=150,
            policy=Policy.AUTO, dispatch="least_queue",
            granularity="multi_step", workloads=suite, engine="vector",
        )
        print(
            f"{mode:10s} fps={r.mean_achieved_fps:5.1f} "
            f"drop={r.drop_rate:.3f} p99={r.p99_loop_time * 1e3:6.1f}ms"
        )

    print("\n== telemetry: span traces + latency attribution ==")
    tel = Telemetry()
    run_fleet(
        topo, comp, num_clients=8, num_frames=150, codec=cfg, telemetry=tel,
    )
    # every frame's spans sum bit-for-bit to its loop time — the trace
    # is an exact decomposition, not a sampled approximation
    print(f"verified {tel.verify_exact()} frames span-exact")
    doc = tel.export_chrome_trace("fleet_trace.json")
    print(
        f"wrote fleet_trace.json ({len(doc['traceEvents'])} events) — "
        "open in Perfetto / chrome://tracing"
    )
    print(tel.format_attribution_table())

    print("\n== SLO doctor: edge_1 thermally throttles 8x at t=1.5s ==")
    # the canonical doctor star: 3 hetero edges behind one shared cell,
    # mixed registry workloads at a 12 fps camera — the scenario the
    # fault-injection gate (fleet_bench --doctor) certifies on both
    # engines.  The monitor rides along as a Telemetry subclass; the
    # burn-rate windows open incidents online and the attributor
    # explains them against the rolling healthy baseline.
    dtopo, dclasses = hardware.doctor_star()
    mon = SLOMonitor(classes=DOCTOR_CLASSES)
    run_fleet(
        dtopo, comp, num_clients=8, num_frames=200,
        dispatch="least_queue", policy=Policy.AUTO,
        granularity="multi_step", client_classes=dclasses,
        workloads=hardware.mixed_workloads(),
        codec=CodecConfig(
            base=hardware.codec_point(entropy=True),
            motion=sequence_motion(), resync_bound=4,
        ),
        camera_fps=12, migration=MigrationConfig(), gather_window=2e-3,
        drifts=[ServiceDrift(time=1.5, edge="edge_1", factor=8.0)],
        slo=mon,
    )
    for wl, a in mon.attainment().items():
        print(
            f"  {wl:15s} [{a['slo']:11s}] observed={a['observed']:4d} "
            f"missed={a['misses']:3d} p99~{a['p99_est_ms']:6.1f}ms "
            f"slow_burn={a['slow_burn']:.2f}"
        )
    print(mon.format_incident_report())
    top, _scores = doctor_verdict(mon)
    print(f"doctor verdict: {top}")


if __name__ == "__main__":
    main()
