"""The paper's technique generalized: tiered edge serving of LLM decode.

    PYTHONPATH=src python examples/llm_edge_decode.py

Autoregressive decode has the hand tracker's exact structure (Fig. 3
category A: serial steps, small recurrent payload, heavy compute core).
This example (1) REALLY serves a reduced gemma-2b with the batched
engine, then (2) plans client/edge placement for all ten assigned
architectures with the Local/Forced/Auto policies, showing how the
per-step state payload (SSM constant state, MLA latent cache, MQA single
head) decides offloadability — see DESIGN.md §Arch-applicability.
"""

import time

import jax
import numpy as np

from repro.configs import registry
from repro.core.offload import Policy
from repro.models import transformer
from repro.serving import edge
from repro.serving.engine import Engine, Request
from repro.sim import hardware


def main() -> None:
    # --- part 1: real batched serving of a reduced model ---
    cfg = registry.get("gemma-2b").reduced()
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    requests = [
        Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, 16).astype(np.int32),
                max_new_tokens=24)
        for i in range(8)
    ]
    engine = Engine(cfg, params, max_len=64)
    t0 = time.perf_counter()
    completions = engine.generate(requests)
    dt = time.perf_counter() - t0
    total = sum(len(c.tokens) for c in completions)
    print(f"served {len(requests)} requests, {total} tokens "
          f"in {dt:.2f}s ({total / dt:.1f} tok/s on CPU)")
    print(f"sample completion: {completions[0].tokens[:12].tolist()}\n")

    # --- part 2: edge placement across the assigned architectures ---
    env = hardware.edge_tpu_environment()
    print(f"thin client ({env.client.name}) -> edge TPU over {env.link.name}")
    print(f"{'arch':24s} {'local':>9s} {'forced':>9s} {'auto':>9s} "
          f"{'state/tok':>10s}  policy_choice")
    rows = edge.compare_archs([registry.get(a) for a in registry.list_archs()], env)
    for name, r in rows.items():
        choice = "offload" if r["forced"] >= r["local"] else "local"
        print(f"{name:24s} {r['local']:9.2f} {r['forced']:9.2f} "
              f"{r['auto']:9.2f} {r['state_bytes'] / 1024:9.1f}K  {choice}")
    print("\ntok/s per policy; Auto always matches the best (paper's claim).")

    # --- part 3: device -> edge -> cloud chain (the multi-machine scaling
    # the paper flags as future work). 18 stages x 3 tiers = 3^18 candidate
    # plans — AUTO routes through the exact O(n*k^2) chain-DP planner.
    topo = hardware.three_tier_environment()
    print(f"\n3-tier chain: {' -> '.join(topo.tier_names())} "
          f"({' + '.join(l.name for l in topo.links.values())})")
    print(f"{'arch':24s} {'auto tok/s':>10s}  placement (embed..head)")
    for arch in ("gemma-2b", "mamba2-370m", "mixtral-8x7b"):
        ep = edge.plan_decode(
            registry.get(arch), topo, Policy.AUTO,
            granularity="multi_step", num_stage_groups=16,
        )
        tags = "".join(p[0].upper() for p in ep.report.placements)
        print(f"{arch:24s} {ep.tokens_per_second:10.2f}  {tags}")
    print("\nD=device, E=edge, C=cloud per stage; the DP prices every "
          "hop of the chain.")


if __name__ == "__main__":
    main()
