"""Particle Swarm Optimization (paper §3.1, "PSO").

Canonical Clerc–Kennedy constriction PSO [21 in the paper]: particles keep
a position and velocity; each is pulled towards its personal best and the
swarm's global best. "PSO does not require training and does not need to
compute the gradient" — the objective is consumed as a black box
``(N, D) -> (N,)`` population evaluator, which is exactly the part the
paper runs on the GPGPU (and the part this framework offloads / shards).

The whole optimization is a single ``jax.lax.fori_loop`` over generations,
so one jit'd call performs the full per-frame search — this is the paper's
"Single-Step" granularity. The tracker can also drive generations in
chunks from the host for "Multi-Step" offload experiments.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

EvalFn = Callable[[jnp.ndarray], jnp.ndarray]  # (N, D) -> (N,)


@dataclasses.dataclass(frozen=True)
class PSOConfig:
    num_particles: int = 64
    num_generations: int = 30
    # Clerc-Kennedy constriction coefficients (paper ref [21]).
    inertia: float = 0.7298
    cognitive: float = 1.49618
    social: float = 1.49618
    # Fraction of the search-box size used to cap |velocity|.
    velocity_clip: float = 0.5
    # Re-randomize this fraction of the worst particles each generation
    # (stochastic restart — keeps the swarm exploring under fast motion).
    restart_fraction: float = 0.0


class SwarmState(NamedTuple):
    positions: jnp.ndarray  # (N, D)
    velocities: jnp.ndarray  # (N, D)
    personal_best: jnp.ndarray  # (N, D)
    personal_best_score: jnp.ndarray  # (N,)
    global_best: jnp.ndarray  # (D,)
    global_best_score: jnp.ndarray  # ()
    key: jax.Array


def init_swarm(
    key: jax.Array,
    center: jnp.ndarray,
    lo: jnp.ndarray,
    hi: jnp.ndarray,
    eval_fn: EvalFn,
    config: PSOConfig,
) -> SwarmState:
    """Particles initialized uniformly in [lo, hi] around `center`; particle
    0 is pinned to `center` itself (the previous frame's solution), which
    guarantees tracking never regresses below the motion-continuity prior.
    """
    n = config.num_particles
    d = center.shape[-1]
    key, kpos, kvel = jax.random.split(key, 3)
    span = hi - lo
    positions = lo + jax.random.uniform(kpos, (n, d), dtype=center.dtype) * span
    positions = positions.at[0].set(center)
    velocities = (
        jax.random.uniform(kvel, (n, d), dtype=center.dtype) - 0.5
    ) * span * 0.1
    scores = eval_fn(positions)
    best_idx = jnp.argmin(scores)
    return SwarmState(
        positions=positions,
        velocities=velocities,
        personal_best=positions,
        personal_best_score=scores,
        global_best=positions[best_idx],
        global_best_score=scores[best_idx],
        key=key,
    )


def swarm_step(
    state: SwarmState,
    lo: jnp.ndarray,
    hi: jnp.ndarray,
    eval_fn: EvalFn,
    config: PSOConfig,
    project_fn: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None,
) -> SwarmState:
    """One PSO generation: velocity update, move, clamp, evaluate, rebest."""
    key, k1, k2, k3 = jax.random.split(state.key, 4)
    n, d = state.positions.shape
    r1 = jax.random.uniform(k1, (n, d), dtype=state.positions.dtype)
    r2 = jax.random.uniform(k2, (n, d), dtype=state.positions.dtype)
    vel = (
        config.inertia * state.velocities
        + config.cognitive * r1 * (state.personal_best - state.positions)
        + config.social * r2 * (state.global_best[None, :] - state.positions)
    )
    span = hi - lo
    vmax = config.velocity_clip * span
    vel = jnp.clip(vel, -vmax, vmax)
    pos = jnp.clip(state.positions + vel, lo, hi)
    if project_fn is not None:
        pos = project_fn(pos)

    if config.restart_fraction > 0.0:
        n_restart = max(1, int(n * config.restart_fraction))
        worst = jnp.argsort(state.personal_best_score)[-n_restart:]
        fresh = lo + jax.random.uniform(k3, (n_restart, d), dtype=pos.dtype) * span
        pos = pos.at[worst].set(fresh)

    scores = eval_fn(pos)
    improved = scores < state.personal_best_score
    pbest = jnp.where(improved[:, None], pos, state.personal_best)
    pbest_score = jnp.where(improved, scores, state.personal_best_score)
    gidx = jnp.argmin(pbest_score)
    gbest_score = pbest_score[gidx]
    gbest = pbest[gidx]
    return SwarmState(pos, vel, pbest, pbest_score, gbest, gbest_score, key)


def run(
    key: jax.Array,
    center: jnp.ndarray,
    lo: jnp.ndarray,
    hi: jnp.ndarray,
    eval_fn: EvalFn,
    config: PSOConfig,
    project_fn: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full PSO search. Returns (best_position (D,), best_score ())."""
    state = init_swarm(key, center, lo, hi, eval_fn, config)

    def body(_, st):
        return swarm_step(st, lo, hi, eval_fn, config, project_fn)

    state = jax.lax.fori_loop(0, config.num_generations, body, state)
    return state.global_best, state.global_best_score


def run_chunked(
    key: jax.Array,
    center: jnp.ndarray,
    lo: jnp.ndarray,
    hi: jnp.ndarray,
    eval_fn: EvalFn,
    config: PSOConfig,
    num_chunks: int,
    project_fn: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, Tuple[SwarmState, ...]]:
    """PSO split into `num_chunks` host-visible pieces (Multi-Step offload:
    each chunk is a separately offloadable method whose swarm state crosses
    the client<->server boundary). Returns intermediate states for byte
    accounting by the offload engine."""
    gens = config.num_generations
    per = max(1, gens // num_chunks)
    state = init_swarm(key, center, lo, hi, eval_fn, config)
    states = []

    @jax.jit
    def chunk(st):
        def body(_, s):
            return swarm_step(s, lo, hi, eval_fn, config, project_fn)

        return jax.lax.fori_loop(0, per, body, st)

    for _ in range(num_chunks):
        state = chunk(state)
        states.append(state)
    return state.global_best, state.global_best_score, tuple(states)


def sharded_eval(
    eval_fn: EvalFn, mesh: jax.sharding.Mesh, axis: str = "model"
) -> EvalFn:
    """Wrap a population evaluator so particles are sharded over a mesh
    axis — the paper's GPGPU parallelism mapped onto the TPU mesh. Each
    device evaluates N/devices particles; scores are all-gathered (tiny:
    N floats), so the only collective in the PSO loop is O(N) bytes.
    """
    import functools

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(axis, None),),
        out_specs=P(axis),
        check_rep=False,
    )
    def _eval(chunk):
        return eval_fn(chunk)

    return _eval
