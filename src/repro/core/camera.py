"""Pinhole RGBD camera model (paper §3.1: camera calibration parameters).

The tracker renders hand hypotheses "to the camera viewport, obtaining
color and depth maps directly comparable to the observations". We only need
the depth channel for Eq. (2); rays are precomputed once per camera and
reused for every particle and every frame.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

# Far-plane depth used for "no hit" pixels, meters. Matches typical RGBD
# sensor max range and keeps |d_h - d_o| saturated at the clamp T for
# misrendered pixels.
BACKGROUND_DEPTH = 10.0


@dataclasses.dataclass(frozen=True)
class Camera:
    """Intrinsics of the RGBD sensor. Defaults approximate a Kinect-class
    sensor downsampled to the tracker's working resolution."""

    width: int = 128
    height: int = 128
    fx: float = 110.0
    fy: float = 110.0
    cx: float = 63.5
    cy: float = 63.5

    def rays(self) -> jnp.ndarray:
        """Unnormalized ray directions d with d_z == 1, shape (H, W, 3).

        With d_z == 1 the ray parameter t *is* the metric depth z, which
        keeps the per-sphere hit test to one sqrt (see objective.py).
        """
        u = (jnp.arange(self.width, dtype=jnp.float32) - self.cx) / self.fx
        v = (jnp.arange(self.height, dtype=jnp.float32) - self.cy) / self.fy
        gu, gv = jnp.meshgrid(u, v, indexing="xy")
        ones = jnp.ones_like(gu)
        return jnp.stack([gu, gv, ones], axis=-1)

    def rays_flat(self) -> jnp.ndarray:
        """(H*W, 3) flattened rays — the kernel-facing layout."""
        return self.rays().reshape(-1, 3)

    @property
    def num_pixels(self) -> int:
        return self.width * self.height


def crop_camera(cam: Camera, scale: int) -> Camera:
    """A reduced-resolution camera (used by smoke tests)."""
    return Camera(
        width=cam.width // scale,
        height=cam.height // scale,
        fx=cam.fx / scale,
        fy=cam.fy / scale,
        cx=(cam.cx + 0.5) / scale - 0.5,
        cy=(cam.cy + 0.5) / scale - 0.5,
    )
