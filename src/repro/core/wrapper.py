"""Container ("wrapper") overhead — the JNI/JVM analogue (paper Fig. 4).

The paper wraps the native C++ tracker in a Java container via JNI and
finds the wrapper overhead "is not negligible, and it considerably reduced
the performance": data serialization, synchronization and JVM costs taxed
every call, hurting the fast server proportionally more than the slow
laptop.

The JAX-land analogue of that per-call marshalling tax is host<->device
staging: flattening a pytree, converting dtypes, and crossing the
host/device boundary outside of jit. This module *measures* that tax on
the running host and produces a calibrated ``WrapperModel`` for the
offload cost model, so Fig. 4's overhead study is grounded in a real
measurement rather than an invented constant.
"""

from __future__ import annotations

import time
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.offload import WrapperModel


def _roundtrip_once(arr: np.ndarray) -> float:
    """One host->device->host staging round trip, seconds."""
    t0 = time.perf_counter()
    dev = jax.device_put(arr)
    dev.block_until_ready()
    out = np.asarray(dev)
    t1 = time.perf_counter()
    del out
    return t1 - t0


def measure_wrapper(
    small_bytes: int = 1024,
    large_bytes: int = 4 << 20,
    repeats: int = 5,
) -> WrapperModel:
    """Fit (call_overhead, serialization_bandwidth) from two staging sizes.

    time(n) ~= call_overhead + n / bw  — solve from the small/large pair,
    taking the min over repeats to strip scheduler noise.
    """
    small = np.zeros(small_bytes // 4, dtype=np.float32)
    large = np.zeros(large_bytes // 4, dtype=np.float32)
    # warmup
    _roundtrip_once(small)
    _roundtrip_once(large)
    t_small = min(_roundtrip_once(small) for _ in range(repeats))
    t_large = min(_roundtrip_once(large) for _ in range(repeats))
    dt = max(t_large - t_small, 1e-9)
    bw = (large_bytes - small_bytes) / dt
    overhead = max(t_small - small_bytes / bw, 1e-6)
    return WrapperModel(call_overhead=overhead, serialization_bandwidth=bw)


def paper_wrapper() -> WrapperModel:
    """The Java/JNI wrapper constants calibrated against the paper's own
    Fig. 4/5 numbers (see benchmarks/calibrate.py for the derivation):

    * server native ~42 fps (23.8 ms) vs wrapped ~30 fps (33 ms) =>
      ~9 ms/frame single-step wrapper tax, mostly fixed + frame staging.
    * Multi-Step visibly worse than Single-Step => a per-call fixed cost
      of a few ms (JNI transition + JVM sync), times 4 calls.
    * Forced+Single-Step+Ethernet ~= 10 fps with ~24 ms of server compute
      => ~65 ms of per-frame container cost for a 537 KB RGBD frame
      crossing twice through Java object streams: ~20 MB/s effective —
      consistent with 2018-era JVM serialization of non-primitive buffers.
    * Fig. 4's *local* wrapped runs only cross JNI (pinned buffers):
      ~60 MB/s effective including synchronization — visible on the fast
      server, "much less evident" on the slow laptop, as the paper finds.
    """
    return WrapperModel(
        call_overhead=2.0e-3,
        serialization_bandwidth=20e6,
        jni_bandwidth=60e6,
    )
