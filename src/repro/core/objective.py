"""The paper's objective function E_D (Eq. 2) and depth rendering.

    E_D(h, d^o) = (1 / N_P) * sum_{p in B} C(|d_p^h - d_p^o|, T)

where C(x, T) clamps at T = 30 cm to keep outliers from dominating, and B
is a bounding box containing the hand. The render is analytic sphere
ray-casting (DESIGN.md §2 explains why this replaces the paper's CUDA
rasterizer on TPU).

This module is the *reference* (pure jnp) implementation; the Pallas
kernel in ``repro.kernels.render_score`` computes the same quantity with
explicit VMEM tiling, and ``repro.kernels.ref`` re-exports these functions
as the kernel oracle.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import handmodel
from repro.core.camera import BACKGROUND_DEPTH, Camera

CLAMP_T = 0.30  # meters — the paper sets T = 30 cm.


def sphere_depth(rays: jnp.ndarray, spheres: jnp.ndarray) -> jnp.ndarray:
    """Analytic depth of the nearest sphere along each ray.

    Args:
      rays: (P, 3) ray directions with d_z == 1 (so t == metric depth).
      spheres: (S, 4) packed [cx, cy, cz, r].

    Returns:
      (P,) depth map; BACKGROUND_DEPTH where no sphere is hit.

    Math: for ray x = t*d and sphere (c, r):
      |t d - c|^2 = r^2
      t^2 |d|^2 - 2 t (d.c) + |c|^2 - r^2 = 0
      t = [ (d.c) - sqrt((d.c)^2 - |d|^2 (|c|^2 - r^2)) ] / |d|^2
    We take the near root; a negative discriminant or a behind-camera hit
    maps to BACKGROUND_DEPTH. Zero-radius padding spheres never hit because
    their discriminant is  (d.c)^2 - |d|^2 |c|^2 <= 0 (Cauchy-Schwarz),
    with equality only for rays through the center — give them |c|=0 and
    the near root is t=0, rejected by the t>eps test.
    """
    d2 = jnp.sum(rays * rays, axis=-1)  # (P,)
    c = spheres[:, :3]  # (S, 3)
    r = spheres[:, 3]  # (S,)
    dc = rays @ c.T  # (P, S)
    c2r2 = jnp.sum(c * c, axis=-1) - r * r  # (S,)
    disc = dc * dc - d2[:, None] * c2r2[None, :]  # (P, S)
    safe_disc = jnp.maximum(disc, 0.0)
    t = (dc - jnp.sqrt(safe_disc)) / d2[:, None]  # (P, S)
    hit = (disc >= 0.0) & (t > 1e-4)
    t = jnp.where(hit, t, BACKGROUND_DEPTH)
    return jnp.min(t, axis=-1)


def render_depth(h: jnp.ndarray, camera: Camera) -> jnp.ndarray:
    """Depth map (H, W) of hand configuration h."""
    spheres = handmodel.pack_spheres(h)
    depth = sphere_depth(camera.rays_flat(), spheres)
    return depth.reshape(camera.height, camera.width)


def clamped_l1(d_h: jnp.ndarray, d_o: jnp.ndarray, t: float = CLAMP_T) -> jnp.ndarray:
    """C(|d_h - d_o|, T) elementwise."""
    return jnp.minimum(jnp.abs(d_h - d_o), t)


def discrepancy(
    d_h: jnp.ndarray,
    d_o: jnp.ndarray,
    mask: Optional[jnp.ndarray] = None,
    t: float = CLAMP_T,
) -> jnp.ndarray:
    """E_D for rendered depth d_h against observed depth d_o.

    Args:
      d_h, d_o: (...,) depth maps (flattened or 2D, matching shapes).
      mask: optional boolean bounding-box mask B; True = inside B. When
        None, the whole frame is B (the ROI crop already applied).

    Returns:
      scalar E_D = mean over B of clamped absolute differences.
    """
    err = clamped_l1(d_h, d_o, t)
    if mask is None:
        return jnp.mean(err)
    msk = mask.astype(err.dtype)
    return jnp.sum(err * msk) / jnp.maximum(jnp.sum(msk), 1.0)


def objective(
    h: jnp.ndarray,
    d_o: jnp.ndarray,
    camera: Camera,
    mask: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """E_D(h, o): render h and score against the observation. Scalar."""
    d_h = render_depth(h, camera)
    return discrepancy(d_h, d_o, mask)


def batched_objective(
    hs: jnp.ndarray,
    d_o: jnp.ndarray,
    camera: Camera,
    mask: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Vectorized E_D over a particle population. hs: (N, 27) -> (N,).

    This is the GPGPU-parallel evaluation the paper offloads; the Pallas
    kernel path (repro.kernels.ops.render_score) computes the same thing
    with explicit tiling and is swapped in by the tracker when enabled.
    """
    return jax.vmap(lambda h: objective(h, d_o, camera, mask))(hs)


def bounding_box_mask(
    d_o: jnp.ndarray, center_depth: jnp.ndarray, half_width: float = 0.25
) -> jnp.ndarray:
    """Bounding-box B extraction: pixels whose observed depth lies within
    ``half_width`` meters of the previous solution's depth. This is the
    cheap 'segmentation' stage-1 uses; background (far) pixels drop out."""
    return jnp.abs(d_o - center_depth) < half_width
