"""Placement policies for staged computations across compute tiers.

This is the RAPID decision engine (paper §3.2) rebuilt analytically:
given a ``StagedComputation``, two tiers (client/server) and the link
between them, choose for each stage whether to run it locally or remotely.

Policies (paper Table 1):
  * LOCAL  — never offload (the "RAPID-enabled, no offloading" rows of
    Fig. 4).
  * FORCED — always offload every offloadable stage (models a client with
    no GPU).
  * AUTO   — per-stage argmin of expected step latency under the cost
    model; with 4 stages the plan space is 2^4 = 16 and we search it
    exhaustively with exact residency tracking, so AUTO here is the
    *oracle* version of RAPID's adaptive heuristic.

Cost model per plan (all times in seconds):
  compute  : Amdahl split — parallel_fraction at tier.accel_flops, the
             rest at tier.scalar_flops — plus tier.dispatch_overhead.
  wrapper  : the Java/JNI "container" analogue (core.wrapper): a fixed
             per-offloadable-call cost plus bytes / serialization
             bandwidth, paid on BOTH ends of every remote invocation and
             once locally per wrapped call (Fig. 4's overhead study).
  network  : RPC semantics — every *remote stage invocation* pays a
             request/response envelope of 2 x link.latency plus wrapper
             call costs on both ends; item payloads piggyback on the RPC
             message and pay serialization (both ends) + bandwidth. Item
             residency is tracked so a frame uploaded once is not re-sent
             (RAPID caches registered data the same way). This is why the
             paper's Multi-Step loses to Single-Step — 4 RPC envelopes vs
             1 — and why Wi-Fi (10-60 ms latency) is so punishing.
"""

from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.stages import CLIENT, SERVER, DataItem, Stage, StagedComputation


class Policy(enum.Enum):
    LOCAL = "local"
    FORCED = "forced"
    AUTO = "auto"


@dataclasses.dataclass(frozen=True)
class Tier:
    """A compute tier (the paper's "server" / "laptop", or a TPU pod)."""

    name: str
    accel_flops: float  # effective accelerator FLOP/s for this workload
    scalar_flops: float  # serial/CPU FLOP/s (the non-parallel fraction)
    dispatch_overhead: float = 50e-6  # per-stage launch cost, seconds
    has_accelerator: bool = True


@dataclasses.dataclass(frozen=True)
class Link:
    """A network link between tiers."""

    name: str
    bandwidth: float  # bytes / second
    latency: float  # one-way, seconds
    jitter: float = 0.0  # stddev of latency, seconds (Wi-Fi interference)

    def transfer_time(self, nbytes: int, rng=None) -> float:
        lat = self.latency
        if rng is not None and self.jitter > 0.0:
            lat = max(0.0, float(rng.normal(self.latency, self.jitter)))
        return lat + nbytes / self.bandwidth


@dataclasses.dataclass(frozen=True)
class WrapperModel:
    """Container ("JNI/JVM") overhead model — see core/wrapper.py for the
    calibration of these constants.

    Two distinct marshalling paths, matching the Java stack the paper
    uses: a *local* wrapped call crosses JNI with pinned/direct buffers
    (fast), while a *remote* call must push the payload through Java
    object-stream serialization (slow). Conflating the two cannot
    reconcile Fig. 4 (modest local wrapper tax) with Fig. 5 (~10 fps
    offloaded => tens of ms of serialization per frame)."""

    call_overhead: float = 1.2e-3  # fixed cost per wrapped method call
    serialization_bandwidth: float = 20e6  # remote path, bytes/s
    jni_bandwidth: float = 60e6  # local JNI marshal path, bytes/s

    def cost(self, nbytes: int) -> float:
        return self.call_overhead + nbytes / self.serialization_bandwidth


@dataclasses.dataclass(frozen=True)
class Environment:
    client: Tier
    server: Tier
    link: Link
    wrapper: WrapperModel = dataclasses.field(default_factory=WrapperModel)
    # Native mode: no container at all (the C++ baseline of Fig. 4).
    wrapped: bool = True


@dataclasses.dataclass(frozen=True)
class PlanReport:
    placements: Tuple[str, ...]
    total_time: float
    compute_time: float
    wrapper_time: float
    network_time: float
    uplink_bytes: int
    downlink_bytes: int

    @property
    def fps(self) -> float:
        return 1.0 / self.total_time if self.total_time > 0 else float("inf")


def _stage_compute_time(stage: Stage, tier: Tier) -> float:
    par = stage.flops * stage.parallel_fraction
    ser = stage.flops - par
    accel = tier.accel_flops if tier.has_accelerator else tier.scalar_flops
    return par / accel + ser / tier.scalar_flops + tier.dispatch_overhead


def evaluate_plan(
    comp: StagedComputation,
    placements: Sequence[str],
    env: Environment,
) -> PlanReport:
    """Exact cost of one placement vector with residency tracking."""
    comp.validate()
    table = comp.item_table()
    # residency[name] -> set of sides currently holding the item
    residency: Dict[str, set] = {i.name: {i.origin} for i in comp.sources}

    compute_t = 0.0
    wrapper_t = 0.0
    network_t = 0.0
    up_bytes = 0
    down_bytes = 0

    if not env.wrapped and any(p == SERVER for p in placements):
        raise ValueError(
            "native (unwrapped) execution cannot offload — the paper's "
            "C++ baseline runs purely locally"
        )

    def _ship(nbytes: int, to_server: bool) -> None:
        """Payload cost: serialize out + deserialize in + wire time."""
        nonlocal wrapper_t, network_t, up_bytes, down_bytes
        wrapper_t += 2 * (nbytes / env.wrapper.serialization_bandwidth)
        network_t += nbytes / env.link.bandwidth
        if to_server:
            up_bytes += nbytes
        else:
            down_bytes += nbytes

    for stage, side in zip(comp.stages, placements):
        tier = env.server if side == SERVER else env.client
        if env.wrapped:
            if side == SERVER:
                # RPC envelope: proxy + skeleton call costs, request +
                # response wire latency.
                wrapper_t += 2 * env.wrapper.call_overhead
                network_t += 2 * env.link.latency
            else:
                # Local wrapped invocation still crosses the JNI boundary.
                wrapper_t += env.wrapper.call_overhead
        # --- move inputs to `side` (piggybacked on the invocation) ---
        for name in stage.inputs:
            if side not in residency[name]:
                item = table[name]
                if side == CLIENT:
                    network_t += env.link.latency  # explicit fetch leg
                _ship(item.nbytes, to_server=(side == SERVER))
                residency[name].add(side)
            elif env.wrapped and side == CLIENT:
                # Local wrapped call marshals its (local) inputs across
                # the JNI boundary once — the Fig. 4 tax (fast path:
                # pinned arrays, no object-stream serialization).
                wrapper_t += table[name].nbytes / env.wrapper.jni_bandwidth
        # --- compute ---
        compute_t += _stage_compute_time(stage, tier)
        for o in stage.outputs:
            residency[o.name] = {side}

    # --- results must land back at the client (Fig. 3 category A). If the
    # last producing stage was remote, this is the RPC response payload
    # (no extra envelope); residency tracking keeps it exact either way.
    for rname in comp.results:
        if CLIENT not in residency[rname]:
            item = table[rname]
            _ship(item.nbytes, to_server=False)
            residency[rname].add(CLIENT)

    total = compute_t + wrapper_t + network_t
    return PlanReport(
        placements=tuple(placements),
        total_time=total,
        compute_time=compute_t,
        wrapper_time=wrapper_t,
        network_time=network_t,
        uplink_bytes=up_bytes,
        downlink_bytes=down_bytes,
    )


def plan(
    comp: StagedComputation,
    env: Environment,
    policy: Policy,
    max_exhaustive: int = 20,
) -> PlanReport:
    """Choose placements under a policy and return the cost report."""
    n = len(comp.stages)
    if policy is Policy.LOCAL:
        return evaluate_plan(comp, (CLIENT,) * n, env)
    if policy is Policy.FORCED:
        return evaluate_plan(comp, (SERVER,) * n, env)

    # AUTO — exhaustive over the plan lattice (2^n); for long pipelines
    # (LLM serve steps with per-layer stages) fall back to a boundary
    # search: optimal plans for pipelines whose transfer costs are
    # monotone along the chain are single-crossing (client prefix, server
    # middle, client suffix), an O(n^2) family.
    best: Optional[PlanReport] = None
    if n <= max_exhaustive:
        candidates = itertools.product((CLIENT, SERVER), repeat=n)
    else:
        candidates = _single_crossing_plans(n)
    for placements in candidates:
        rep = evaluate_plan(comp, placements, env)
        if best is None or rep.total_time < best.total_time:
            best = rep
    assert best is not None
    return best


def _single_crossing_plans(n: int):
    for lo in range(n + 1):
        for hi in range(lo, n + 1):
            yield tuple(
                SERVER if lo <= i < hi else CLIENT for i in range(n)
            )


def compare_granularities(
    comp: StagedComputation, env: Environment, policy: Policy
) -> Dict[str, PlanReport]:
    """The paper's Single-Step vs Multi-Step comparison for one setup."""
    return {
        "multi_step": plan(comp, env, policy),
        "single_step": plan(comp.fused(), env, policy),
    }
