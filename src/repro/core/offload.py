"""Placement policies for staged computations across compute tiers.

This is the RAPID decision engine (paper §3.2) generalized from the
paper's hard-wired client/server pair to arbitrary N-tier topologies:
a :class:`~repro.core.topology.Topology` names its tiers ("device",
"edge", "cloud", ...) and joins them with links; placements are tier
names; and every cost — compute, wrapper/serialization, per-leg network
latency and wire time — is priced by the single
:class:`~repro.core.costengine.CostEngine` that ``net.transport`` and
``sim.runtime`` also delegate to.

Policies (paper Table 1, unchanged semantics):
  * LOCAL  — never offload: every stage at the topology's home tier
    (the "RAPID-enabled, no offloading" rows of Fig. 4).
  * FORCED — every stage on the fastest remote tier (models a client
    with no GPU).
  * AUTO   — argmin of expected step latency under the cost model,
    via a pluggable planner (``core.planners``): exhaustive search for
    small plan lattices (the oracle version of RAPID's heuristic), an
    exact O(n*k^2) dynamic program for long linear chains (per-layer
    LLM decode pipelines at 3+ tiers), and the single-crossing family
    as the general fallback.

The two-tier :class:`Environment` of the original implementation
survives as a thin shim over ``Topology.two_tier`` — placements keep the
historical ``"client"`` / ``"server"`` literals, and existing callers
(sim, serving, benchmarks, examples) work unchanged while new code
passes a ``Topology`` directly.  See ``core/costengine.py`` for the full
cost semantics (RPC envelopes, piggybacked payloads, residency
tracking, per-leg jitter records).
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Dict, Optional, Sequence, Union

from repro.core.costengine import (  # noqa: F401  (re-exported API)
    BatchServiceModel,
    CostEngine,
    LatencyLeg,
    PlanReport,
)
from repro.core.planners import PLANNERS, auto_planner
from repro.core.stages import StagedComputation
from repro.core.topology import (  # noqa: F401  (re-exported API)
    Link,
    Tier,
    Topology,
    WrapperModel,
)


class Policy(enum.Enum):
    LOCAL = "local"
    FORCED = "forced"
    AUTO = "auto"


@dataclasses.dataclass(frozen=True)
class Environment:
    """Two-tier compatibility shim over :class:`Topology`.

    The paper's deployment shape: one client, one server, one link.
    ``as_topology()`` maps it onto the graph model with placement names
    "client" (home) and "server"."""

    client: Tier
    server: Tier
    link: Link
    wrapper: WrapperModel = dataclasses.field(default_factory=WrapperModel)
    # Native mode: no container at all (the C++ baseline of Fig. 4).
    wrapped: bool = True

    def as_topology(self) -> Topology:
        return Topology.two_tier(
            self.client, self.server, self.link, self.wrapper, self.wrapped
        )


EnvironmentLike = Union[Environment, Topology]


def as_topology(env: EnvironmentLike) -> Topology:
    if isinstance(env, Topology):
        return env
    return env.as_topology()


def evaluate_plan(
    comp: StagedComputation,
    placements: Sequence[str],
    env: EnvironmentLike,
    codec=None,
) -> PlanReport:
    """Exact cost of one placement vector with residency tracking."""
    return CostEngine(as_topology(env), codec=codec).evaluate(comp, placements)


def plan(
    comp: StagedComputation,
    env: EnvironmentLike,
    policy: Policy,
    max_exhaustive: int = 20,
    planner: Optional[str] = None,
    occupancy: Optional[Dict[str, int]] = None,
    codec=None,
    link_backlog: Optional[Dict[str, float]] = None,
) -> PlanReport:
    """Choose placements under a policy and return the cost report.

    ``max_exhaustive`` bounds the lattice AUTO may search exhaustively
    (k_tiers ** n_stages <= 2 ** max_exhaustive), but linear chains
    switch to the equally-exact O(n*k^2) DP once the lattice outgrows a
    few hundred plans — see ``planners.auto_planner``.  Pass
    ``planner`` ("exhaustive" | "single_crossing" | "chain_dp") to force
    a specific AUTO strategy.  ``occupancy`` (tier name -> concurrent
    requests already there) makes the engine charge queueing inflation
    on contended tiers — how a fleet dispatcher prices a loaded edge.
    ``codec`` (a ``repro.codec.CodecModel``) makes every transfer leg
    codec-aware: compressed wire bytes plus encode/decode compute at
    the payload's endpoints — which can flip AUTO's decision on links
    where raw payloads drowned the offload win.  ``link_backlog``
    (shared-medium name -> seconds of live queue delay) prices wire
    legs against current link occupancy the same way ``occupancy``
    prices contended tiers; both are probe-side knobs — the plan cache
    never keys on them, so dispatchers pass them only on uncached
    probes.
    """
    topo = as_topology(env)
    engine = CostEngine(
        topo, occupancy=occupancy, codec=codec, link_backlog=link_backlog
    )
    n = len(comp.stages)
    if policy is Policy.LOCAL:
        return engine.evaluate(comp, (topo.home,) * n)
    if policy is Policy.FORCED:
        return engine.evaluate(comp, (topo.primary_remote(),) * n)

    if planner is not None:
        if planner not in PLANNERS:
            raise ValueError(
                f"unknown planner {planner!r}; choose from {sorted(PLANNERS)}"
            )
        chosen = PLANNERS[planner]
    else:
        chosen = auto_planner(comp, engine, max_candidates=2**max_exhaustive)
    return chosen.plan(comp, engine)


def compare_granularities(
    comp: StagedComputation, env: EnvironmentLike, policy: Policy
) -> Dict[str, PlanReport]:
    """The paper's Single-Step vs Multi-Step comparison for one setup."""
    return {
        "multi_step": plan(comp, env, policy),
        "single_step": plan(comp.fused(), env, policy),
    }
