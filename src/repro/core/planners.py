"""Pluggable placement planners over the topology plan lattice.

Four strategies, all pricing candidates through the same
:class:`~repro.core.costengine.CostEngine` so they agree exactly:

* ``ExhaustivePlanner``      — every tier^n assignment; the oracle for
  small lattices (the paper's 4-stage pipeline is 2^4 = 16 plans).
* ``SingleCrossingPlanner``  — home-prefix / remote-middle / home-suffix
  plans per remote tier, O(n^2 * k); the optimal family for pipelines
  whose transfer costs are monotone along the chain.
* ``ChainDPPlanner``         — exact O(n * k^2) dynamic program for
  *linear* computations (stage i fed by stage i-1 outputs and sources,
  results produced by the final stage).  A source consumed by several
  stages is priced exactly through a residency-augmented DP state (the
  holder set of each shared source), mirroring ``evaluate``'s residency
  tracking.  This is what makes per-layer-group LLM decode pipelines
  tractable at k > 2 tiers and n > 20 stages, where the lattice has
  k^n points.
* ``TreeDPPlanner``          — exact DP over branching *out-trees*
  (palm-detection fanning out to per-hand landmark branches): state =
  the tier of a stage, children combine by sum because the engine
  prices every inter-stage move independently when each item is
  consumed at most once.  General DAGs (join stages with several
  parents) fall back to a principled exact-cost local search: best
  uniform placement, then coordinate descent with full ``evaluate``
  pricing until 1-opt.

``auto_planner`` picks the cheapest applicable strategy for a given
lattice size (exhaustive -> chain DP -> tree DP -> single-crossing);
``PLANNERS`` exposes them by name for explicit override.  Conditional
stages (``Stage.exec_prob`` < 1) are priced at expected cost by every
planner, matching ``CostEngine.evaluate``'s expectation semantics.
"""

from __future__ import annotations

import itertools
from typing import Dict, FrozenSet, List, Optional, Tuple

from repro.core.costengine import CostEngine, PlanReport
from repro.core.stages import StagedComputation


class ExhaustivePlanner:
    """Argmin over the full tier^n plan lattice."""

    name = "exhaustive"

    def plan(self, comp: StagedComputation, engine: CostEngine) -> PlanReport:
        n = len(comp.stages)
        best: Optional[PlanReport] = None
        for placements in itertools.product(engine.placement_tiers(), repeat=n):
            rep = engine.evaluate(comp, placements)
            if best is None or rep.total_time < best.total_time:
                best = rep
        assert best is not None
        return best


class SingleCrossingPlanner:
    """home* remote* home* plans for each remote tier — O(n^2 * k).

    The all-home plan (the degenerate ``lo == hi`` window) is priced
    exactly once, up front: historically every empty window of every
    remote tier re-evaluated the identical plan — (k-1)·(n+1) redundant
    ``engine.evaluate`` calls per ``plan()`` that distorted
    ``topology_bench`` plans/sec without ever changing the argmin.
    """

    name = "single_crossing"

    def plan(self, comp: StagedComputation, engine: CostEngine) -> PlanReport:
        n = len(comp.stages)
        home = engine.topology.home
        remotes = [t for t in engine.placement_tiers() if t != home]
        # the one degenerate window: all stages at home
        best = engine.evaluate(comp, tuple(home for _ in range(n)))
        for remote in remotes:
            for lo in range(n):
                for hi in range(lo + 1, n + 1):
                    placements = tuple(
                        remote if lo <= i < hi else home for i in range(n)
                    )
                    rep = engine.evaluate(comp, placements)
                    if rep.total_time < best.total_time:
                        best = rep
        return best


class ChainDPPlanner:
    """Exact DP over linear chains: state = tier of the current stage.

    dp[i][t] = cost of stages 0..i with stage i on tier t, where each
    stage's term prices its envelope, compute, and source-item moves, and
    the transition prices moving the inter-stage activation t' -> t.  All
    terms come from the shared ``CostEngine`` scalar helpers, so the DP
    optimum matches exhaustive search wherever both apply.

    A source consumed by *several* stages (the tracker's ``h_prev``
    pattern) is handled exactly by augmenting the DP state with the
    holder set of each shared source: ``evaluate`` ships such an item
    once per new tier and serves later consumers from the cheapest
    holder, so the naive per-consumer transfer charge would overprice
    it.  With no shared sources the fast single-tier-state DP runs
    unchanged.  Conditional stages price at expected cost (terms scale
    by ``exec_prob``), matching ``evaluate``.
    """

    name = "chain_dp"

    @staticmethod
    def applicable(comp: StagedComputation) -> bool:
        """True iff the computation is a linear chain the DP prices
        exactly: stage i fed only by stage i-1 outputs and sources,
        every *stage output* consumed at most once (by the next stage),
        results produced by the final stage.  Sources may be consumed
        any number of times — the DP's residency-augmented state prices
        shared sources exactly (deciding admit-vs-reject by exactness
        against exhaustive: rejection was the wrong side)."""
        if not comp.stages:
            return False
        src_names = {i.name for i in comp.sources}
        consumed: Dict[str, int] = {}
        prev_outputs: set = set()
        for stage in comp.stages:
            for name in stage.inputs:
                consumed[name] = consumed.get(name, 0) + 1
                if name not in src_names and name not in prev_outputs:
                    return False
            prev_outputs = {o.name for o in stage.outputs}
        if any(
            v > 1 for name, v in consumed.items() if name not in src_names
        ):
            return False
        return set(comp.results) <= prev_outputs

    def plan(self, comp: StagedComputation, engine: CostEngine) -> PlanReport:
        if not self.applicable(comp):
            raise ValueError(
                f"computation {comp.name!r} is not a linear chain; use the "
                "tree, exhaustive or single-crossing planner"
            )
        topo = engine.topology
        tiers = engine.placement_tiers()
        stages = comp.stages
        n = len(stages)
        table = comp.item_table()
        src_names = {i.name for i in comp.sources}
        origin = {i.name: engine.resolve_origin(i) for i in comp.sources}
        consumed: Dict[str, int] = {}
        for s in stages:
            for name in s.inputs:
                consumed[name] = consumed.get(name, 0) + 1
        # sources consumed more than once need residency-set state
        shared = tuple(
            i.name for i in comp.sources if consumed.get(i.name, 0) > 1
        )
        # outputs of stage i-1 (chain feed of stage i)
        prev_out: List[set] = [set()] + [
            {o.name for o in s.outputs} for s in stages[:-1]
        ]

        def node_cost(i: int, t: str) -> float:
            """Envelope + compute + unshared-source moves of stage i at
            tier t, expectation-weighted (shared sources are priced in
            the transition, where the holder set lives)."""
            stage = stages[i]
            p = stage.exec_prob
            c = p * (
                engine.envelope_scalar(t) + engine.compute_time(stage, t)
            )
            for name in stage.inputs:
                if name in src_names and name not in shared:
                    nb = table[name].nbytes
                    o = origin[name]
                    if o == t:
                        c += p * engine.marshal_scalar(nb, t)
                    else:
                        c += p * engine.transfer_scalar(nb, o, t)
            return c

        def edge_cost(i: int, t_prev: str, t: str) -> float:
            p = stages[i].exec_prob
            c = 0.0
            for name in stages[i].inputs:
                if name in prev_out[i]:
                    nb = table[name].nbytes
                    if t_prev == t:
                        c += p * engine.marshal_scalar(nb, t)
                    else:
                        c += p * engine.transfer_scalar(nb, t_prev, t)
            return c

        def return_cost(t: str) -> float:
            if t == topo.home:
                return 0.0
            p = stages[-1].exec_prob
            # results ride the final RPC response home: no latency legs
            return sum(
                p
                * engine.transfer_scalar(
                    table[r].nbytes, t, topo.home, piggyback=True
                )
                for r in comp.results
            )

        if not shared:
            # fast path: the historical single-tier-state DP, unchanged
            dp = [{t: node_cost(0, t) for t in tiers}]
            parent: List[Dict[str, str]] = [{}]
            for i in range(1, n):
                row: Dict[str, float] = {}
                par: Dict[str, str] = {}
                for t in tiers:
                    base = node_cost(i, t)
                    best_c = None
                    best_p = None
                    for t_prev in tiers:
                        c = dp[i - 1][t_prev] + edge_cost(i, t_prev, t) + base
                        if best_c is None or c < best_c:
                            best_c = c
                            best_p = t_prev
                    row[t] = best_c
                    par[t] = best_p
                dp.append(row)
                parent.append(par)

            last = min(tiers, key=lambda t: dp[n - 1][t] + return_cost(t))
            placements = [last]
            for i in range(n - 1, 0, -1):
                placements.append(parent[i][placements[-1]])
            placements.reverse()
            return engine.evaluate(comp, tuple(placements))

        # --- residency-augmented DP for shared sources ------------------
        # State: (tier of stage i, holder-set tuple aligned with
        # `shared`).  Transitions replicate evaluate()'s residency walk:
        # a shared input already held at the stage's tier pays the JNI
        # marshal (wrapped home) or nothing; otherwise it ships from the
        # cheapest current holder and the tier joins the holder set.
        State = Tuple[str, Tuple[FrozenSet[str], ...]]

        def shared_cost_and_holders(
            i: int, t: str, holders: Tuple[FrozenSet[str], ...]
        ) -> Tuple[float, Tuple[FrozenSet[str], ...]]:
            p = stages[i].exec_prob
            c = 0.0
            hl = list(holders)
            for name in stages[i].inputs:
                if name not in shared:
                    continue
                idx = shared.index(name)
                nb = table[name].nbytes
                if t in hl[idx]:
                    c += p * engine.marshal_scalar(nb, t)
                else:
                    src = min(
                        sorted(hl[idx]),
                        key=lambda s: engine.transfer_scalar(nb, s, t),
                    )
                    c += p * engine.transfer_scalar(nb, src, t)
                    hl[idx] = hl[idx] | {t}
            return c, tuple(hl)

        init_holders = tuple(frozenset({origin[name]}) for name in shared)
        frontier: Dict[State, float] = {}
        parents: List[Dict[State, State]] = []
        par0: Dict[State, State] = {}
        for t in tiers:
            sc, hl = shared_cost_and_holders(0, t, init_holders)
            frontier[(t, hl)] = node_cost(0, t) + sc
        parents.append(par0)
        for i in range(1, n):
            nxt: Dict[State, float] = {}
            par: Dict[State, State] = {}
            for (t_prev, holders), cost_prev in frontier.items():
                for t in tiers:
                    sc, hl = shared_cost_and_holders(i, t, holders)
                    c = (
                        cost_prev
                        + edge_cost(i, t_prev, t)
                        + node_cost(i, t)
                        + sc
                    )
                    key: State = (t, hl)
                    if key not in nxt or c < nxt[key]:
                        nxt[key] = c
                        par[key] = (t_prev, holders)
            frontier = nxt
            parents.append(par)

        best_key = min(
            frontier, key=lambda k: frontier[k] + return_cost(k[0])
        )
        placements = [best_key[0]]
        key = best_key
        for i in range(n - 1, 0, -1):
            key = parents[i][key]
            placements.append(key[0])
        placements.reverse()
        return engine.evaluate(comp, tuple(placements))


class TreeDPPlanner:
    """Exact DP over out-trees; exact-cost local search on general DAGs.

    Domain of exactness (``applicable``): every item consumed at most
    once, every stage fed by at most one producing stage (an out-forest
    of branches), results pure sinks.  Under those conditions
    ``evaluate``'s residency tracking never shares an item between
    consumers, so the total plan cost decomposes into independent
    per-stage node terms plus one term per tree edge — children combine
    by *sum* because the engine prices each inter-stage move
    independently.  The DP state is the tier of a stage:

        cost[i][t] = node(i, t)
                   + sum over children c of min_tc(edge(i->c, t, tc)
                                                   + cost[c][tc])

    with node() = expected envelope + compute + source moves + result
    ship-home, and edge() the expected move of the consumed parent
    output (JNI marshal when colocated).  Roots minimize independently.
    O(n * k^2), exact bit-for-bit against exhaustive on its domain
    (property-tested on every lattice <= 512).

    A general DAG — a join stage consuming outputs of two different
    producers — couples parent tiers through the child's term; exact DP
    over trees no longer applies, so ``plan`` falls back to a principled
    exact-cost search: price every uniform placement, then coordinate
    descent (re-evaluate each stage at every tier, keep the argmin) with
    the full ``evaluate`` until a sweep makes no progress.  Monotone,
    exact pricing, 1-opt at convergence.
    """

    name = "tree_dp"

    _MAX_SWEEPS = 6  # DAG fallback: coordinate-descent sweep bound

    @staticmethod
    def applicable(comp: StagedComputation) -> bool:
        """Strict out-forest check — the domain where the DP is exact."""
        if not comp.stages:
            return False
        src_names = {i.name for i in comp.sources}
        produced: set = set()
        for s in comp.stages:
            for o in s.outputs:
                if o.name in produced or o.name in src_names:
                    return False  # ambiguous producer
                produced.add(o.name)
        consumed: Dict[str, int] = {}
        producer_stage = comp.producer_of()
        for s in comp.stages:
            parents = set()
            for name in s.inputs:
                consumed[name] = consumed.get(name, 0) + 1
                p = producer_stage.get(name)
                if p is not None:
                    parents.add(p)
            if len(parents) > 1:
                return False  # join stage: a DAG, not an out-tree
        if any(v > 1 for v in consumed.values()):
            return False  # shared item: residency would couple consumers
        for r in comp.results:
            if consumed.get(r, 0) > 0 and r in produced:
                return False  # result re-consumed: not a pure sink
            if r in src_names and consumed.get(r, 0) > 0:
                return False  # consumed passthrough source: holders grow
        return True

    @classmethod
    def dag_applicable(cls, comp: StagedComputation) -> bool:
        """The fallback's (much looser) domain: any non-empty stage DAG."""
        return bool(comp.stages)

    def plan(self, comp: StagedComputation, engine: CostEngine) -> PlanReport:
        if self.applicable(comp):
            return self._plan_tree(comp, engine)
        if self.dag_applicable(comp):
            return self._plan_dag(comp, engine)
        raise ValueError(
            f"computation {comp.name!r} has no stages to place"
        )

    # -- exact out-tree DP ----------------------------------------------

    def _plan_tree(
        self, comp: StagedComputation, engine: CostEngine
    ) -> PlanReport:
        topo = engine.topology
        tiers = engine.placement_tiers()
        stages = comp.stages
        n = len(stages)
        table = comp.item_table()
        src_names = {i.name for i in comp.sources}
        origin = {i.name: engine.resolve_origin(i) for i in comp.sources}
        results = set(comp.results)
        stage_idx = {s.name: i for i, s in enumerate(stages)}
        producer_stage = comp.producer_of()

        # children[i] = [(child index, consumed item names)], parent the
        # unique producing stage (applicable() guaranteed <= 1)
        children: List[List[Tuple[int, List[str]]]] = [[] for _ in range(n)]
        parent: List[Optional[int]] = [None] * n
        for ci, s in enumerate(stages):
            feeds: Dict[int, List[str]] = {}
            for name in s.inputs:
                p = producer_stage.get(name)
                if p is not None:
                    feeds.setdefault(stage_idx[p], []).append(name)
            for pi, names in feeds.items():
                parent[ci] = pi
                children[pi].append((ci, names))

        def node_cost(i: int, t: str) -> float:
            stage = stages[i]
            p = stage.exec_prob
            c = p * (
                engine.envelope_scalar(t) + engine.compute_time(stage, t)
            )
            for name in stage.inputs:
                if name in src_names:
                    nb = table[name].nbytes
                    o = origin[name]
                    if o == t:
                        c += p * engine.marshal_scalar(nb, t)
                    else:
                        c += p * engine.transfer_scalar(nb, o, t)
            # results this stage produces ship home from wherever it ran
            # (pure sinks: nothing else moves them first)
            if t != topo.home:
                for o in stage.outputs:
                    if o.name in results:
                        c += p * engine.transfer_scalar(
                            o.nbytes, t, topo.home, piggyback=True
                        )
            return c

        def edge_cost(names: List[str], ci: int, t_par: str, t: str) -> float:
            p = stages[ci].exec_prob
            c = 0.0
            for name in names:
                nb = table[name].nbytes
                if t_par == t:
                    c += p * engine.marshal_scalar(nb, t)
                else:
                    c += p * engine.transfer_scalar(nb, t_par, t)
            return c

        # leaf-up DP (stage order is topological: children after parents)
        cost: List[Dict[str, float]] = [{} for _ in range(n)]
        choice: List[Dict[str, Dict[int, str]]] = [{} for _ in range(n)]
        for i in range(n - 1, -1, -1):
            for t in tiers:
                c = node_cost(i, t)
                picks: Dict[int, str] = {}
                for ci, names in children[i]:
                    best_c = None
                    best_t = None
                    for tc in tiers:
                        cc = edge_cost(names, ci, t, tc) + cost[ci][tc]
                        if best_c is None or cc < best_c:
                            best_c = cc
                            best_t = tc
                    c += best_c
                    picks[ci] = best_t
                cost[i][t] = c
                choice[i][t] = picks

        placements: List[Optional[str]] = [None] * n
        for i in range(n):
            if parent[i] is None:  # each root minimizes independently
                placements[i] = min(tiers, key=lambda t: cost[i][t])
        for i in range(n):  # parents resolve before children (topological)
            t = placements[i]
            for ci, _names in children[i]:
                placements[ci] = choice[i][t][ci]
        return engine.evaluate(comp, tuple(placements))

    # -- general-DAG fallback: exact-cost coordinate descent -------------

    def _plan_dag(
        self, comp: StagedComputation, engine: CostEngine
    ) -> PlanReport:
        tiers = engine.placement_tiers()
        n = len(comp.stages)

        def descend(seed: PlanReport) -> PlanReport:
            best = seed
            for _ in range(self._MAX_SWEEPS):
                improved = False
                for i in range(n):
                    cur = best.placements[i]
                    for t in tiers:
                        if t == cur:
                            continue
                        cand = (
                            best.placements[:i]
                            + (t,)
                            + best.placements[i + 1 :]
                        )
                        rep = engine.evaluate(comp, cand)
                        if rep.total_time < best.total_time:
                            best = rep
                            improved = True
                if not improved:
                    break
            return best

        # descend from every uniform seed: different basins of the
        # placement landscape (all-home vs all-edge starts converge to
        # different 1-opt points on join-heavy DAGs)
        best: Optional[PlanReport] = None
        for t in tiers:
            rep = descend(engine.evaluate(comp, tuple(t for _ in range(n))))
            if best is None or rep.total_time < best.total_time:
                best = rep
        assert best is not None
        return best


PLANNERS = {
    p.name: p
    for p in (
        ExhaustivePlanner(),
        SingleCrossingPlanner(),
        ChainDPPlanner(),
        TreeDPPlanner(),
    )
}


# Above this many candidate plans a linear chain goes to the DP even
# inside the exhaustive budget — the DP is equally exact and O(n*k^2),
# while exhaustive evaluate() calls grow as k^n (3^12 is already ~a
# minute of planning).
_DP_PREFERRED_ABOVE = 512


def auto_planner(
    comp: StagedComputation, engine: CostEngine, max_candidates: int
):
    """Exhaustive while the lattice is tiny; exact DP for chains, then
    branching out-trees, as soon as exhaustive search would be slow; the
    single-crossing family as the general-case fallback."""
    k = len(engine.placement_tiers())
    n = len(comp.stages)
    lattice = k**n
    if lattice <= min(max_candidates, _DP_PREFERRED_ABOVE):
        return PLANNERS["exhaustive"]
    if ChainDPPlanner.applicable(comp):
        return PLANNERS["chain_dp"]
    if TreeDPPlanner.applicable(comp):
        return PLANNERS["tree_dp"]
    if lattice <= max_candidates:
        return PLANNERS["exhaustive"]
    return PLANNERS["single_crossing"]
