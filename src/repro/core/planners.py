"""Pluggable placement planners over the topology plan lattice.

Three strategies, all pricing candidates through the same
:class:`~repro.core.costengine.CostEngine` so they agree exactly:

* ``ExhaustivePlanner``      — every tier^n assignment; the oracle for
  small lattices (the paper's 4-stage pipeline is 2^4 = 16 plans).
* ``SingleCrossingPlanner``  — home-prefix / remote-middle / home-suffix
  plans per remote tier, O(n^2 * k); the optimal family for pipelines
  whose transfer costs are monotone along the chain.
* ``ChainDPPlanner``         — exact O(n * k^2) dynamic program for
  *linear* computations (each item consumed by at most one stage, each
  stage fed by its predecessor and/or sources).  This is what makes
  per-layer-group LLM decode pipelines tractable at k > 2 tiers and
  n > 20 stages, where the lattice has k^n points.

``auto_planner`` picks the cheapest applicable strategy for a given
lattice size; ``PLANNERS`` exposes them by name for explicit override.
"""

from __future__ import annotations

import itertools
from typing import Dict, List, Optional

from repro.core.costengine import CostEngine, PlanReport
from repro.core.stages import StagedComputation


class ExhaustivePlanner:
    """Argmin over the full tier^n plan lattice."""

    name = "exhaustive"

    def plan(self, comp: StagedComputation, engine: CostEngine) -> PlanReport:
        n = len(comp.stages)
        best: Optional[PlanReport] = None
        for placements in itertools.product(engine.placement_tiers(), repeat=n):
            rep = engine.evaluate(comp, placements)
            if best is None or rep.total_time < best.total_time:
                best = rep
        assert best is not None
        return best


class SingleCrossingPlanner:
    """home* remote* home* plans for each remote tier — O(n^2 * k)."""

    name = "single_crossing"

    def plan(self, comp: StagedComputation, engine: CostEngine) -> PlanReport:
        n = len(comp.stages)
        home = engine.topology.home
        remotes = [t for t in engine.placement_tiers() if t != home] or [home]
        best: Optional[PlanReport] = None
        for remote in remotes:
            for lo in range(n + 1):
                for hi in range(lo, n + 1):
                    placements = tuple(
                        remote if lo <= i < hi else home for i in range(n)
                    )
                    rep = engine.evaluate(comp, placements)
                    if best is None or rep.total_time < best.total_time:
                        best = rep
        assert best is not None
        return best


class ChainDPPlanner:
    """Exact DP over linear chains: state = tier of the current stage.

    dp[i][t] = cost of stages 0..i with stage i on tier t, where each
    stage's term prices its envelope, compute, and source-item moves, and
    the transition prices moving the inter-stage activation t' -> t.  All
    terms come from the shared ``CostEngine`` scalar helpers, so the DP
    optimum matches exhaustive search wherever both apply.
    """

    name = "chain_dp"

    @staticmethod
    def applicable(comp: StagedComputation) -> bool:
        """True iff the computation is a linear chain the DP prices exactly:
        every item consumed at most once, stage i fed only by stage i-1
        outputs and sources, results produced by the final stage."""
        if not comp.stages:
            return False
        src_names = {i.name for i in comp.sources}
        consumed: Dict[str, int] = {}
        prev_outputs: set = set()
        for stage in comp.stages:
            for name in stage.inputs:
                consumed[name] = consumed.get(name, 0) + 1
                if name not in src_names and name not in prev_outputs:
                    return False
            prev_outputs = {o.name for o in stage.outputs}
        if any(v > 1 for v in consumed.values()):
            return False
        return set(comp.results) <= prev_outputs

    def plan(self, comp: StagedComputation, engine: CostEngine) -> PlanReport:
        if not self.applicable(comp):
            raise ValueError(
                f"computation {comp.name!r} is not a linear chain; use the "
                "exhaustive or single-crossing planner"
            )
        topo = engine.topology
        tiers = engine.placement_tiers()
        stages = comp.stages
        n = len(stages)
        table = comp.item_table()
        src_names = {i.name for i in comp.sources}
        origin = {i.name: engine.resolve_origin(i) for i in comp.sources}
        # outputs of stage i-1 (chain feed of stage i)
        prev_out: List[set] = [set()] + [
            {o.name for o in s.outputs} for s in stages[:-1]
        ]

        def node_cost(i: int, t: str) -> float:
            stage = stages[i]
            c = engine.envelope_scalar(t) + engine.compute_time(stage, t)
            for name in stage.inputs:
                if name in src_names:
                    nb = table[name].nbytes
                    o = origin[name]
                    if o == t:
                        c += engine.marshal_scalar(nb, t)
                    else:
                        c += engine.transfer_scalar(nb, o, t)
            return c

        def edge_cost(i: int, t_prev: str, t: str) -> float:
            c = 0.0
            for name in stages[i].inputs:
                if name in prev_out[i]:
                    nb = table[name].nbytes
                    if t_prev == t:
                        c += engine.marshal_scalar(nb, t)
                    else:
                        c += engine.transfer_scalar(nb, t_prev, t)
            return c

        def return_cost(t: str) -> float:
            if t == topo.home:
                return 0.0
            # results ride the final RPC response home: no latency legs
            return sum(
                engine.transfer_scalar(table[r].nbytes, t, topo.home, piggyback=True)
                for r in comp.results
            )

        dp = [{t: node_cost(0, t) for t in tiers}]
        parent: List[Dict[str, str]] = [{}]
        for i in range(1, n):
            row: Dict[str, float] = {}
            par: Dict[str, str] = {}
            for t in tiers:
                base = node_cost(i, t)
                best_c = None
                best_p = None
                for t_prev in tiers:
                    c = dp[i - 1][t_prev] + edge_cost(i, t_prev, t) + base
                    if best_c is None or c < best_c:
                        best_c = c
                        best_p = t_prev
                row[t] = best_c
                par[t] = best_p
            dp.append(row)
            parent.append(par)

        last = min(tiers, key=lambda t: dp[n - 1][t] + return_cost(t))
        placements = [last]
        for i in range(n - 1, 0, -1):
            placements.append(parent[i][placements[-1]])
        placements.reverse()
        return engine.evaluate(comp, tuple(placements))


PLANNERS = {
    p.name: p
    for p in (ExhaustivePlanner(), SingleCrossingPlanner(), ChainDPPlanner())
}


# Above this many candidate plans a linear chain goes to the DP even
# inside the exhaustive budget — the DP is equally exact and O(n*k^2),
# while exhaustive evaluate() calls grow as k^n (3^12 is already ~a
# minute of planning).
_DP_PREFERRED_ABOVE = 512


def auto_planner(
    comp: StagedComputation, engine: CostEngine, max_candidates: int
):
    """Exhaustive while the lattice is tiny; exact DP for chains as soon
    as exhaustive search would be slow; the single-crossing family as
    the general-case fallback."""
    k = len(engine.placement_tiers())
    n = len(comp.stages)
    lattice = k**n
    if lattice <= min(max_candidates, _DP_PREFERRED_ABOVE):
        return PLANNERS["exhaustive"]
    if ChainDPPlanner.applicable(comp):
        return PLANNERS["chain_dp"]
    if lattice <= max_candidates:
        return PLANNERS["exhaustive"]
    return PLANNERS["single_crossing"]
