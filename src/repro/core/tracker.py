"""The 4-stage generative 3D hand tracker (paper §3.1, Fig. 2).

Per frame, the optimization happens in 4 consecutive steps, each an
offloadable unit (Multi-Step) or fused into one (Single-Step):

  1. ``preprocess`` — extract the bounding box B around the previous
     solution, mask the observed depth map.
  2. ``spawn``      — initialize the particle swarm around h_t ("particles
     are initialized around the solution of the previous frame").
  3. ``optimize``   — run the PSO generations; the population evaluation
     is the GPGPU-heavy part (Pallas kernel or vmapped reference).
  4. ``refine``     — select the global best, renormalize the quaternion,
     apply temporal smoothing; emit h_{t+1}.

The serial frame dependency (Fig. 3 category A) lives *outside* this
module: ``track_frame`` maps (h_t, frame) -> h_{t+1}, and whoever drives
it (examples/quickstart.py, sim/runtime.py) must wait for each frame's
result before submitting the next.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import handmodel, objective, pso
from repro.core.camera import Camera
from repro.core.stages import CLIENT, DataItem, Stage, StagedComputation


@dataclasses.dataclass(frozen=True)
class TrackerConfig:
    camera: Camera = dataclasses.field(default_factory=Camera)
    pso: pso.PSOConfig = dataclasses.field(default_factory=pso.PSOConfig)
    pos_range: float = 0.10  # search-box half width around h_t, meters
    quat_range: float = 0.25
    smoothing: float = 0.15  # exponential temporal smoothing on h
    bbox_half_width: float = 0.25  # meters around previous depth (B)
    use_kernel: bool = False  # route evaluation through the Pallas kernel


def _make_eval_fn(
    cfg: TrackerConfig, d_o: jnp.ndarray, mask: jnp.ndarray
) -> pso.EvalFn:
    if cfg.use_kernel:
        from repro.kernels import ops as kernel_ops

        rays = cfg.camera.rays_flat()

        def eval_fn(hs: jnp.ndarray) -> jnp.ndarray:
            spheres = jax.vmap(handmodel.pack_spheres)(hs)
            return kernel_ops.render_score(
                spheres, rays, d_o.reshape(-1), mask.reshape(-1)
            )

        return eval_fn

    def eval_fn(hs: jnp.ndarray) -> jnp.ndarray:
        return objective.batched_objective(hs, d_o, cfg.camera, mask)

    return eval_fn


# ---------------------------------------------------------------------------
# The four stages as standalone jittable functions
# ---------------------------------------------------------------------------


def stage_preprocess(
    cfg: TrackerConfig, h_prev: jnp.ndarray, depth: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Stage 1: ROI/bounding-box extraction. Returns (depth, mask)."""
    mask = objective.bounding_box_mask(depth, h_prev[2], cfg.bbox_half_width)
    return depth, mask


def stage_spawn(
    cfg: TrackerConfig, key: jax.Array, h_prev: jnp.ndarray,
    eval_fn: pso.EvalFn,
) -> Tuple[pso.SwarmState, jnp.ndarray, jnp.ndarray]:
    """Stage 2: swarm initialization around h_t. Returns (state, lo, hi)."""
    lo = handmodel.parameter_lower_bounds(h_prev, cfg.pos_range, cfg.quat_range)
    hi = handmodel.parameter_upper_bounds(h_prev, cfg.pos_range, cfg.quat_range)
    state = pso.init_swarm(key, h_prev, lo, hi, eval_fn, cfg.pso)
    return state, lo, hi


def stage_optimize(
    cfg: TrackerConfig,
    state: pso.SwarmState,
    lo: jnp.ndarray,
    hi: jnp.ndarray,
    eval_fn: pso.EvalFn,
) -> pso.SwarmState:
    """Stage 3: the PSO generations — the GPGPU-heavy step."""

    def body(_, st):
        return pso.swarm_step(
            st, lo, hi, eval_fn, cfg.pso,
            project_fn=handmodel.normalize_configuration,
        )

    return jax.lax.fori_loop(0, cfg.pso.num_generations, body, state)


def stage_refine(
    cfg: TrackerConfig, state: pso.SwarmState, h_prev: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Stage 4: decode the solution + temporal smoothing."""
    h = handmodel.normalize_configuration(state.global_best)
    h = (1.0 - cfg.smoothing) * h + cfg.smoothing * h_prev
    h = handmodel.normalize_configuration(h)
    return h, state.global_best_score


# ---------------------------------------------------------------------------
# Fused per-frame step (Single-Step granularity)
# ---------------------------------------------------------------------------


def make_track_frame(cfg: TrackerConfig) -> Callable:
    """Build the jitted (key, h_prev, depth) -> (h_next, score) step."""

    @jax.jit
    def track_frame(key: jax.Array, h_prev: jnp.ndarray, depth: jnp.ndarray):
        d_o, mask = stage_preprocess(cfg, h_prev, depth)
        eval_fn = _make_eval_fn(cfg, d_o, mask)
        state, lo, hi = stage_spawn(cfg, key, h_prev, eval_fn)
        state = stage_optimize(cfg, state, lo, hi, eval_fn)
        return stage_refine(cfg, state, h_prev)

    return track_frame


def make_track_frame_sharded(cfg: TrackerConfig, mesh, axis: str = "model"):
    """Distributed variant: the particle population is sharded over a mesh
    axis (the paper's GPGPU parallel axis mapped onto TPU devices)."""

    @jax.jit
    def track_frame(key: jax.Array, h_prev: jnp.ndarray, depth: jnp.ndarray):
        d_o, mask = stage_preprocess(cfg, h_prev, depth)
        base_eval = _make_eval_fn(cfg, d_o, mask)
        eval_fn = pso.sharded_eval(base_eval, mesh, axis)
        state, lo, hi = stage_spawn(cfg, key, h_prev, eval_fn)
        state = stage_optimize(cfg, state, lo, hi, eval_fn)
        return stage_refine(cfg, state, h_prev)

    return track_frame


class Tracker:
    """Stateful convenience wrapper holding h_t across frames."""

    def __init__(self, cfg: TrackerConfig, h0: Optional[jnp.ndarray] = None,
                 seed: int = 0):
        self.cfg = cfg
        self.h = h0 if h0 is not None else handmodel.default_pose()
        self.key = jax.random.PRNGKey(seed)
        self._step = make_track_frame(cfg)

    def step(self, depth: jnp.ndarray) -> Tuple[jnp.ndarray, float]:
        self.key, sub = jax.random.split(self.key)
        self.h, score = self._step(sub, self.h, depth)
        return self.h, float(score)


# ---------------------------------------------------------------------------
# Byte/FLOP-annotated staged description (for the offload engine)
# ---------------------------------------------------------------------------


def _eval_flops_per_generation(cfg: TrackerConfig) -> float:
    """Analytic FLOP count of one population evaluation.

    Per (particle, pixel, sphere): dot products + discriminant + sqrt
    ~= 14 fused ops; the min-reduction and scoring add ~3 per (particle,
    pixel). See kernels/render_score.py for the exact expression the
    kernel evaluates."""
    n = cfg.pso.num_particles
    p = cfg.camera.num_pixels
    s = handmodel.NUM_SPHERES
    fk_flops = n * 600.0 * 5  # forward kinematics per particle (tiny)
    return n * p * (s * 14.0 + 3.0) + fk_flops


def build_staged(
    cfg: TrackerConfig, frame_nbytes: Optional[int] = None
) -> StagedComputation:
    """The Fig. 2 pipeline with measured byte sizes and analytic FLOPs.

    ``frame_nbytes`` overrides the size of the sensor frame that crosses
    the network (the paper ships RGB + depth at sensor resolution while
    hypotheses are rendered at a reduced working resolution; see
    sim/hardware.py PAPER_FRAME_BYTES)."""
    cam = cfg.camera
    n, d = cfg.pso.num_particles, handmodel.NUM_PARAMS
    frame_bytes = (
        frame_nbytes if frame_nbytes is not None else cam.num_pixels * 4
    )
    # ROI items are at the tracker's *working* resolution regardless of
    # the sensor frame size that crosses the network.
    roi_bytes = cam.num_pixels * 4
    mask_bytes = cam.num_pixels  # bool mask
    h_bytes = d * 4
    swarm_bytes = (3 * n * d + 2 * n + d + 1 + 2) * 4  # SwarmState payload

    gens = cfg.pso.num_generations
    eval_flops = _eval_flops_per_generation(cfg)

    sources = (
        DataItem("frame_depth", frame_bytes, CLIENT),
        DataItem("h_prev", h_bytes, CLIENT),
        DataItem("rng_key", 8, CLIENT),
    )
    stages = (
        Stage(
            name="preprocess",
            flops=cam.num_pixels * 4.0,
            inputs=("frame_depth", "h_prev"),
            outputs=(
                DataItem("roi_depth", roi_bytes),
                DataItem("roi_mask", mask_bytes),
            ),
            parallel_fraction=0.5,
        ),
        Stage(
            name="spawn",
            # init includes one population evaluation (scores of gen 0)
            flops=n * d * 8.0 + eval_flops,
            inputs=("rng_key", "h_prev", "roi_depth", "roi_mask"),
            outputs=(DataItem("swarm_state", swarm_bytes),),
            parallel_fraction=0.95,
        ),
        Stage(
            name="optimize",
            flops=gens * (eval_flops + n * d * 12.0),
            inputs=("swarm_state", "roi_depth", "roi_mask"),
            outputs=(DataItem("swarm_final", swarm_bytes),),
            parallel_fraction=0.98,
        ),
        Stage(
            name="refine",
            flops=d * 30.0,
            inputs=("swarm_final", "h_prev"),
            outputs=(DataItem("h_next", h_bytes), DataItem("score", 4)),
            parallel_fraction=0.0,
        ),
    )
    comp = StagedComputation(
        name="hand_tracker_frame",
        sources=sources,
        stages=stages,
        results=("h_next", "score"),
    )
    comp.validate()
    return comp
