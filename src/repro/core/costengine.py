"""The unified cost engine: every cost in the system is computed here.

Historically the transfer/wrapper/compute arithmetic lived in three
places — ``offload.evaluate_plan``, ``net.transport.Transport`` and a
jitter-reconstruction hack in ``sim.runtime`` that divided latency back
out of an aggregate ``network_time``.  ``CostEngine`` owns all of it:

* :meth:`CostEngine.evaluate` prices a placement vector over any
  :class:`~repro.core.topology.Topology` with exact residency tracking,
  and records every latency leg it charges in ``PlanReport.legs`` so
  jitter resampling (``PlanReport.jittered_total``) is *exact* rather
  than reverse-engineered.
* The scalar helpers (:meth:`transfer_scalar`, :meth:`envelope_scalar`,
  :meth:`marshal_scalar`, :meth:`compute_time`) are the same arithmetic
  exposed piecewise for planners (the chain-DP planner prices DP
  transitions with them, guaranteeing agreement with ``evaluate``).
* The module-level ``wire_time`` / ``serialization_time`` /
  ``envelope_time`` primitives serve ``net.transport`` so the executed
  simulator charges the identical formulas.

Cost semantics (unchanged from the calibrated two-tier model):

  compute  : Amdahl split — parallel_fraction at tier.accel_flops, the
             rest at tier.scalar_flops — plus tier.dispatch_overhead.
  wrapper  : fixed per-call cost plus bytes / serialization bandwidth on
             both ends of every remote transfer; local wrapped calls
             cross the (faster) JNI marshal path instead.
  network  : every remote stage invocation pays a request/response
             envelope of 2 x latency per link leg on the home->tier
             path; payloads pay wire time per leg.  A payload whose
             source lies on the request path piggybacks (no extra
             latency); pulling data against the request direction is an
             explicit fetch costing one latency per leg.  Result items
             ride the final response home (no extra latency).  Item
             residency is tracked so a frame uploaded once is not
             re-sent.
  codec    : with a ``repro.codec.CodecModel`` armed, every payload the
             codec *applies to* (frame-sized items at a compressing
             operating point) ships its compressed byte estimate —
             serialization, wire time and uplink/downlink accounting
             all see codec-aware bytes — plus encode compute at the
             payload's source tier and decode compute at its
             destination (charged into ``compute_by_tier``, so a
             contended edge's decode work occupies its service slots in
             the fleet simulator; codec compute itself is not
             contention-inflated — it is microseconds against
             millisecond stages).  The identity codec never applies, so
             ``codec=None`` and the identity codec are bit-for-bit the
             same arithmetic.
  branches : a conditional stage (``Stage.exec_prob`` < 1) charges the
             *expected* value of every term it owns — compute, RPC
             envelope, input/output transfers, wire bytes — each
             multiplied by its exec_prob (and result ship-home by the
             producer's).  Latency legs record the probability as
             ``LatencyLeg.weight`` while keeping the link's unscaled
             latency/jitter, so jitter resampling and drift detection
             observe the real link and only total-time arithmetic is
             expectation-weighted.  ``exec_prob = 1`` everywhere is
             bit-for-bit the historical arithmetic (scaling by 1.0 is
             IEEE-exact).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.core.stages import CLIENT, DataItem, StagedComputation, Stage
from repro.core.topology import Link, Topology, WrapperModel, sample_latency


# ---------------------------------------------------------------------------
# leg-level primitives (shared with net.transport)
# ---------------------------------------------------------------------------


def wire_time(nbytes: int, links: Sequence[Link]) -> float:
    """Pure bandwidth time for a payload crossing the given legs."""
    t = 0.0
    for link in links:
        t += nbytes / link.bandwidth
    return t


def serialization_time(nbytes: int, wrapper: WrapperModel) -> float:
    """Serialize at the source + deserialize at the destination."""
    return 2 * (nbytes / wrapper.serialization_bandwidth)


def envelope_time(
    links: Sequence[Link], wrapper: Optional[WrapperModel] = None, rng=None
) -> float:
    """Request + response wire latency (optionally jitter-sampled) plus
    proxy/skeleton call overhead for one remote invocation."""
    t = 0.0
    for link in links:
        for _ in range(2):
            t += link.transfer_time(0, rng)
    if wrapper is not None:
        t += 2 * wrapper.call_overhead
    return t


# ---------------------------------------------------------------------------
# batch service model
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BatchServiceModel:
    """Service time of one *fused* accelerator launch over a batch.

    A tier that batches (``Tier.batching``) serves the concurrent
    requests it gathered as a single launch instead of time-slicing
    them.  Each item's solo service time already carries its own launch
    cost (``Tier.dispatch_overhead`` is inside ``compute_time``); fusing
    pays that once, plus:

    * ``launch_overhead`` — fixed extra bookkeeping of a multi-item
      launch (batch gather/scatter, ragged padding), charged only when
      the batch actually has more than one item, so a batch of one *is*
      the unbatched launch, bit for bit.
    * ``marginal_fraction`` — the fraction of its solo time each
      additional item adds.  Physically: the lone item leaves the
      accelerator's vector lanes underfilled, so co-scheduled items ride
      mostly-idle hardware; 1.0 degenerates to serial (no amortization),
      values < 1 make batch service time sublinear in batch size.

    Invariants (property-tested in tests/test_properties.py):
      ``batch_time(ts) >= max(ts)`` — a batch finishes no earlier than
      its largest member run alone;
      ``batch_time(ts) <= launch_overhead + sum(ts)`` — fusing never
      costs more than serializing the same launches (marginal <= 1);
      monotone in batch size.
    """

    launch_overhead: float = 0.0
    marginal_fraction: float = 0.35

    def __post_init__(self) -> None:
        if self.launch_overhead < 0.0:
            raise ValueError("launch_overhead must be >= 0")
        if not 0.0 <= self.marginal_fraction <= 1.0:
            raise ValueError("marginal_fraction must be in [0, 1]")

    def batch_time(self, item_times: Sequence[float]) -> float:
        """Fused service time for items with the given solo times."""
        if not item_times:
            return 0.0
        m = max(item_times)
        if len(item_times) == 1:
            return m
        rest = sum(item_times) - m
        return self.launch_overhead + m + self.marginal_fraction * rest

    def per_item_time(self, solo_time: float, batch_size: int) -> float:
        """Amortized share of a homogeneous batch (capacity planning)."""
        if batch_size <= 0:
            return 0.0
        return self.batch_time([solo_time] * batch_size) / batch_size

    @classmethod
    def from_tier(cls, tier) -> "BatchServiceModel":
        """The model a ``Tier`` declares via its flat batching fields."""
        return cls(
            launch_overhead=tier.batch_overhead,
            marginal_fraction=tier.batch_marginal,
        )

    @classmethod
    def from_roofline(
        cls,
        *,
        peak_flops: float,
        effective_flops: float,
        mem_bandwidth: float,
        flops_per_item: float,
        bytes_per_item: int,
        launch_overhead: float,
    ) -> "BatchServiceModel":
        """Calibrate the marginal fraction from roofline terms.

        ``effective_flops`` is the rate ONE client's swarm actually
        achieves (what a tier's ``accel_flops`` anchors: small
        populations leave the vector lanes underfilled — the v5e
        roofline table's single-stream utilization is ~8% of peak);
        ``peak_flops`` is the device ceiling.  A lone item therefore
        pays ``launch + flops/effective + bytes/bw`` end to end, while
        each *co-batched* item streams at the roofline proper —
        ``max(flops/peak, bytes/bw)`` — filling lanes the lone item
        leaves idle.  The marginal fraction is that ratio: roughly the
        lone item's utilization, which is exactly the amortization a
        fused launch buys back.
        """
        solo = (
            launch_overhead
            + flops_per_item / effective_flops
            + bytes_per_item / mem_bandwidth
        )
        marginal_t = max(flops_per_item / peak_flops, bytes_per_item / mem_bandwidth)
        marginal = marginal_t / solo if solo > 0 else 1.0
        return cls(
            launch_overhead=launch_overhead,
            marginal_fraction=min(1.0, marginal),
        )


# ---------------------------------------------------------------------------
# reports
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LatencyLeg:
    """One charged latency leg — the unit of exact jitter resampling.

    ``latency`` / ``jitter`` are the link's UNSCALED parameters — live
    lookups (drift detection, rate control) compare draws against them
    directly.  ``weight`` is the expected-cost multiplier of the leg
    (the ``exec_prob`` of the conditional stage that charged it; 1.0 for
    unconditional legs): total-time arithmetic applies ``weight`` to
    both the charged latency and any resampled draw, never to the
    stored parameters."""

    link: str
    latency: float
    jitter: float
    weight: float = 1.0


@dataclasses.dataclass(frozen=True)
class PlanReport:
    placements: Tuple[str, ...]
    total_time: float
    compute_time: float
    wrapper_time: float
    network_time: float
    uplink_bytes: int
    downlink_bytes: int
    legs: Tuple[LatencyLeg, ...] = ()
    # per-tier compute breakdown in first-visit order — the fleet
    # simulator (repro.cluster) charges the remote entries against a
    # contended server's service slots instead of a dedicated machine
    compute_by_tier: Tuple[Tuple[str, float], ...] = ()
    # span-attribution breakdown: (category, seconds) pairs partitioning
    # total_time by where the time is spent (compute_home/compute_remote,
    # encode/decode at each end, lat_up/lat_down, wire_up/wire_down,
    # wrapper) plus the pre-codec byte count shipped uplink
    # (raw_bytes_up).  Consumed by repro.cluster.telemetry; every entry
    # is accumulated in parallel with the existing totals so arming it
    # costs nothing and changes nothing.
    breakdown: Tuple[Tuple[str, float], ...] = ()
    # up/down direction of every recorded latency leg, index-aligned
    # with ``legs`` (True = downlink-direction hop relative to home)
    leg_down: Tuple[bool, ...] = ()
    # per-hop wire occupancy: (link name, is_downlink, wire seconds) for
    # every wire crossing this plan charges — what the fleet engines
    # offer to a SharedLink when the link names a shared medium (the
    # same ``wire_n / bandwidth`` terms as the wire_up/wire_down
    # breakdown, kept per link so contention can be charged per medium)
    wire_by_link: Tuple[Tuple[str, bool, float], ...] = ()

    @property
    def fps(self) -> float:
        return 1.0 / self.total_time if self.total_time > 0 else float("inf")

    def jittered_total(self, rng) -> float:
        """Resample every recorded latency leg; exact by construction."""
        if not self.legs:
            return self.total_time
        base = self.total_time
        for leg in self.legs:
            if leg.weight == 1.0:
                base -= leg.latency
                base += sample_latency(leg.latency, leg.jitter, rng)
            else:
                # probabilistic leg: the draw stays unscaled (it is a
                # property of the link), the expectation weight applies
                # in the total only
                base -= leg.weight * leg.latency
                base += leg.weight * sample_latency(
                    leg.latency, leg.jitter, rng
                )
        return base


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------


class CostEngine:
    """Prices placements of a ``StagedComputation`` over a ``Topology``.

    ``occupancy`` maps tier names to the number of *other* requests
    currently in flight at that tier.  A tier with ``capacity`` slots
    shared by q+1 concurrent requests serves each at rate
    ``capacity / (q+1)`` once oversubscribed (processor sharing — the
    virtualized-accelerator model), so the engine inflates that tier's
    service time by ``max(1, (q+1) / capacity)``.  A tier that declares
    ``batching=True`` replaces processor sharing entirely: the q other
    requests ride the *same fused launch*, so the predicted service time
    is ``BatchServiceModel.batch_time`` of q+1 identical items — fixed
    launch overhead plus sublinear per-item cost — instead of an
    inflation factor.  With no occupancy recorded (the default) every
    tier prices as a dedicated machine and the arithmetic is bit-for-bit
    the uncontended model, batching or not.
    """

    def __init__(
        self,
        topology: Topology,
        occupancy: Optional[Dict[str, int]] = None,
        codec=None,
        link_backlog: Optional[Dict[str, float]] = None,
    ):
        self.topology = topology
        self.occupancy: Dict[str, int] = dict(occupancy) if occupancy else {}
        # a repro.codec.CodecModel (or None): payload compression priced
        # into every transfer leg — see the module docstring
        self.codec = codec
        # live shared-medium backlog (medium name -> seconds of queue
        # delay a transmission due now would see): wire legs crossing a
        # link with that medium charge it on top of their wire time.
        # None / empty (the default) is the exact uncontended model —
        # this is a probe-side knob (fleet dispatch), never cached.
        self.link_backlog: Dict[str, float] = (
            dict(link_backlog) if link_backlog else {}
        )

    # -- small shared pieces ------------------------------------------------

    def placement_tiers(self) -> Tuple[str, ...]:
        """Tier names a stage may be placed on (home only when native)."""
        topo = self.topology
        return topo.tier_names() if topo.wrapped else (topo.home,)

    def resolve_origin(self, item: DataItem) -> str:
        """Map an item's declared origin onto a tier name; the legacy
        ``"client"`` literal aliases the topology's home tier."""
        if item.origin in self.topology.tiers:
            return item.origin
        if item.origin == CLIENT:
            return self.topology.home
        raise ValueError(
            f"item {item.name!r} originates at unknown tier {item.origin!r}"
        )

    def contention_factor(self, tier_name: str) -> float:
        """Service-time inflation under the recorded occupancy."""
        occ = self.occupancy.get(tier_name, 0)
        if occ <= 0:
            return 1.0
        cap = max(self.topology.tier(tier_name).capacity, 1)
        return max(1.0, (occ + 1) / cap)

    def compute_time(self, stage: Stage, tier_name: str) -> float:
        tier = self.topology.tier(tier_name)
        par = stage.flops * stage.parallel_fraction
        ser = stage.flops - par
        accel = tier.accel_flops if tier.has_accelerator else tier.scalar_flops
        base = par / accel + ser / tier.scalar_flops + tier.dispatch_overhead
        occ = self.occupancy.get(tier_name, 0)
        if tier.batching and occ > 0:
            # the q concurrent requests fuse into this one's launch: the
            # whole batch finishes together, so this request's service
            # time is the fused batch time, not a time-sliced share
            return BatchServiceModel.from_tier(tier).batch_time(
                [base] * (occ + 1)
            )
        return base * self.contention_factor(tier_name)

    def _piggybacks(self, src: str, dst: str) -> bool:
        """A payload rides the pending RPC request when its source lies on
        the home->dst path; anything else is an explicit fetch."""
        return src in self.topology.path_tiers(self.topology.home, dst)

    def _codec_terms(self, nbytes: int, src: str, dst: str):
        """``(wire_nbytes, encode_t, decode_t)`` of one payload transfer
        under the armed codec — ``(nbytes, 0.0, 0.0)`` with no codec or
        when it does not apply (tiny payloads, identity codec)."""
        codec = self.codec
        if codec is None or not codec.applies(nbytes):
            return nbytes, 0.0, 0.0
        return (
            codec.wire_nbytes(nbytes),
            codec.encode_time(nbytes, self.topology.tier(src)),
            codec.decode_time(nbytes, self.topology.tier(dst)),
        )

    # -- scalar costs (used by planners; same arithmetic as evaluate) -------

    def envelope_scalar(self, tier_name: str) -> float:
        topo = self.topology
        if not topo.wrapped:
            return 0.0
        if tier_name == topo.home:
            return topo.wrapper.call_overhead
        t = 2 * topo.wrapper.call_overhead
        for link in topo.path_links(topo.home, tier_name):
            t += 2 * link.latency
        return t

    def marshal_scalar(self, nbytes: int, tier_name: str) -> float:
        """JNI marshal of an already-resident input of a wrapped home call."""
        topo = self.topology
        if topo.wrapped and tier_name == topo.home:
            return nbytes / topo.wrapper.jni_bandwidth
        return 0.0

    def _wire_scalar(
        self, wire_nbytes: int, src: str, dst: str, piggy: bool
    ) -> float:
        """Latency/serialization/wire arithmetic on ALREADY-encoded
        bytes (codec-free; shared by transfer and migration pricing)."""
        topo = self.topology
        links = topo.path_links(src, dst)
        t = 0.0
        if not piggy:
            for link in links:
                t += link.latency
        t += serialization_time(wire_nbytes, topo.wrapper)
        t += wire_time(wire_nbytes, links)
        if self.link_backlog:
            for link in links:
                if link.medium:
                    t += self.link_backlog.get(link.medium, 0.0)
        return t

    def transfer_scalar(
        self,
        nbytes: int,
        src: str,
        dst: str,
        piggyback: Optional[bool] = None,
    ) -> float:
        piggy = self._piggybacks(src, dst) if piggyback is None else piggyback
        wire_n, enc_t, dec_t = self._codec_terms(nbytes, src, dst)
        t = self._wire_scalar(wire_n, src, dst, piggy)
        if enc_t > 0.0 or dec_t > 0.0:
            # codec compute rides the transfer total so planners pricing
            # DP transitions with this scalar agree with `evaluate`
            t += enc_t + dec_t
        return t

    def migration_time(self, nbytes: int, src: str, dst: str) -> float:
        """Price a live-migration state transfer like any other leg.

        Moving a client's warm tracker state (hand-model pose + PSO
        swarm payload) from ``src`` to ``dst`` is an explicit fetch
        across the path — one propagation latency per link leg,
        serialization on both ends, wire time per leg, exactly what
        ``transfer_scalar(..., piggyback=False)`` charges — plus, on a
        wrapped stack, the RPC envelope of the transfer call itself
        (proxy/skeleton overhead and the response leg's latency).
        ``src == dst`` is a no-op (state already there).

        With a codec armed the state ships at *keyframe* pricing
        (quantizer only): the destination holds no reference frame to
        delta against, so the amortized delta ratio would overpromise.
        """
        if src == dst:
            return 0.0
        topo = self.topology
        codec = self.codec
        if codec is not None and codec.state_applies(nbytes):
            wire_n = codec.state_wire_nbytes(nbytes)
            t = self._wire_scalar(wire_n, src, dst, piggy=False)
            t += codec.state_encode_time(nbytes, topo.tier(src))
            t += codec.state_decode_time(nbytes, topo.tier(dst))
        else:
            t = self._wire_scalar(nbytes, src, dst, piggy=False)
        if topo.wrapped:
            t += 2 * topo.wrapper.call_overhead
            for link in topo.path_links(src, dst):
                t += link.latency  # the envelope's response leg
        return t

    # -- exact plan evaluation ---------------------------------------------

    def evaluate(
        self, comp: StagedComputation, placements: Sequence[str]
    ) -> PlanReport:
        """Exact cost of one placement vector with residency tracking."""
        comp.validate()
        topo = self.topology
        if len(placements) != len(comp.stages):
            raise ValueError(
                f"{len(placements)} placements for {len(comp.stages)} stages"
            )
        for p in placements:
            if p not in topo.tiers:
                raise ValueError(f"unknown tier {p!r} in placements")
        if not topo.wrapped and any(p != topo.home for p in placements):
            raise ValueError(
                "native (unwrapped) execution cannot offload — the paper's "
                "C++ baseline runs purely locally"
            )

        table = comp.item_table()
        # residency[name] -> set of tiers currently holding the item
        residency: Dict[str, Set[str]] = {
            i.name: {self.resolve_origin(i)} for i in comp.sources
        }

        compute_t = 0.0
        wrapper_t = 0.0
        network_t = 0.0
        up_bytes = 0
        down_bytes = 0
        legs: List[LatencyLeg] = []
        compute_by_tier: Dict[str, float] = {}  # insertion = first-visit order
        bd: Dict[str, float] = {}  # span-attribution breakdown
        leg_down: List[bool] = []  # direction flag per entry of `legs`
        wire_links: List[Tuple[str, bool, float]] = []  # per-hop wire time

        def _bd(key: str, v: float) -> None:
            bd[key] = bd.get(key, 0.0) + v

        def _ship(
            nbytes: int,
            src: str,
            dst: str,
            piggyback: Optional[bool],
            scale: float = 1.0,
        ) -> None:
            """Payload cost: codec encode/decode (when armed) + fetch
            legs + serialize/deserialize + wire, all on codec-aware
            bytes.  ``scale`` is the expectation weight of the transfer
            (the consuming/producing stage's ``exec_prob``); every term
            — compute, latency, serialization, wire, byte counters — is
            charged at ``scale`` times its unconditional value.
            ``scale * x`` is IEEE-exact at 1.0, so unconditional
            pipelines price bit-for-bit as before."""
            nonlocal compute_t, wrapper_t, network_t, up_bytes, down_bytes
            links = topo.path_links(src, dst)
            # hop direction relative to home (see the byte-accounting
            # comment below); link k crosses hops[k] -> hops[k+1]
            hops = topo.path_tiers(src, dst)
            downs = [
                b in topo.path_tiers(a, topo.home)
                for a, b in zip(hops, hops[1:])
            ]
            piggy = self._piggybacks(src, dst) if piggyback is None else piggyback
            wire_n, enc_t, dec_t = self._codec_terms(nbytes, src, dst)
            if enc_t > 0.0:  # encode where the payload lives...
                enc_t = scale * enc_t
                compute_t += enc_t
                compute_by_tier[src] = compute_by_tier.get(src, 0.0) + enc_t
                _bd("encode_home" if src == topo.home else "encode_remote", enc_t)
            if dec_t > 0.0:  # ...decode where it lands (slot work there)
                dec_t = scale * dec_t
                compute_t += dec_t
                compute_by_tier[dst] = compute_by_tier.get(dst, 0.0) + dec_t
                _bd("decode_home" if dst == topo.home else "decode_remote", dec_t)
            if not piggy:
                for link, dwn in zip(links, downs):
                    network_t += scale * link.latency
                    legs.append(
                        LatencyLeg(
                            link.name, link.latency, link.jitter, scale
                        )
                    )
                    leg_down.append(dwn)
                    _bd("lat_down" if dwn else "lat_up", scale * link.latency)
            ser_t = scale * serialization_time(wire_n, topo.wrapper)
            wrapper_t += ser_t
            _bd("wrapper", ser_t)
            network_t += scale * wire_time(wire_n, links)
            for link, dwn in zip(links, downs):
                w = scale * (wire_n / link.bandwidth)
                _bd("wire_down" if dwn else "wire_up", w)
                wire_links.append((link.name, dwn, w))
                if self.link_backlog and link.medium:
                    # live shared-medium occupancy: this transmission
                    # queues behind the backlog already committed to
                    # the medium (dispatch probes price with this; the
                    # cached per-client plans never carry it)
                    network_t += scale * self.link_backlog.get(link.medium, 0.0)
            # byte accounting is per wire hop relative to home (a payload
            # crossing two legs is counted on each): a hop whose far end
            # lies on its near end's route home is downlink — this keeps
            # star leaf->leaf traffic (down to the hub, then up a spoke)
            # honest, where any whole-transfer label would be wrong.
            # Probabilistic transfers count expected bytes; the integer
            # fast path keeps unconditional counters exact ints.
            for dwn in downs:
                if dwn:
                    down_bytes += wire_n if scale == 1.0 else scale * wire_n
                else:
                    up_bytes += wire_n if scale == 1.0 else scale * wire_n
                    _bd("raw_bytes_up", scale * float(nbytes))

        def _best_source(holders: Set[str], dst: str, nbytes: int) -> str:
            if len(holders) == 1:
                return next(iter(holders))
            return min(
                sorted(holders),
                key=lambda s: self.transfer_scalar(nbytes, s, dst),
            )

        # item -> probability it materializes (sources exist always;
        # stage outputs inherit the producer's exec_prob) — result
        # ship-home transfers are weighted by the producer's probability
        item_prob: Dict[str, float] = {i.name: 1.0 for i in comp.sources}

        for stage, dst in zip(comp.stages, placements):
            p = stage.exec_prob
            if topo.wrapped:
                if dst != topo.home:
                    # RPC envelope: proxy + skeleton call costs, request +
                    # response wire latency on every leg of the route.
                    wrapper_t += p * (2 * topo.wrapper.call_overhead)
                    _bd("wrapper", p * (2 * topo.wrapper.call_overhead))
                    for link in topo.path_links(topo.home, dst):
                        network_t += p * (2 * link.latency)
                        legs.append(LatencyLeg(link.name, link.latency, link.jitter, p))
                        legs.append(LatencyLeg(link.name, link.latency, link.jitter, p))
                        leg_down.append(False)  # request leg, away from home
                        leg_down.append(True)  # response leg, back home
                        _bd("lat_up", p * link.latency)
                        _bd("lat_down", p * link.latency)
                else:
                    # Local wrapped invocation still crosses the JNI boundary.
                    wrapper_t += p * topo.wrapper.call_overhead
                    _bd("wrapper", p * topo.wrapper.call_overhead)
            # --- move inputs to `dst` (piggybacked on the invocation) ---
            for name in stage.inputs:
                holders = residency[name]
                if dst not in holders:
                    item = table[name]
                    src = _best_source(holders, dst, item.nbytes)
                    _ship(item.nbytes, src, dst, piggyback=None, scale=p)
                    holders.add(dst)
                elif topo.wrapped and dst == topo.home:
                    # Already-local input of a wrapped home call marshals
                    # across JNI once (fast path: pinned arrays).
                    marshal_t = p * (
                        table[name].nbytes / topo.wrapper.jni_bandwidth
                    )
                    wrapper_t += marshal_t
                    _bd("wrapper", marshal_t)
            # --- compute (expected: a p-probability branch does its work
            # on p of the frames) ---
            ct = p * self.compute_time(stage, dst)
            compute_t += ct
            compute_by_tier[dst] = compute_by_tier.get(dst, 0.0) + ct
            _bd("compute_home" if dst == topo.home else "compute_remote", ct)
            for o in stage.outputs:
                residency[o.name] = {dst}
                item_prob[o.name] = p

        # --- results must land back home. If the producing stage was
        # remote this is the RPC response payload (no extra envelope);
        # residency tracking keeps it exact either way.
        for rname in comp.results:
            holders = residency[rname]
            if topo.home not in holders:
                item = table[rname]
                src = _best_source(holders, topo.home, item.nbytes)
                _ship(
                    item.nbytes,
                    src,
                    topo.home,
                    piggyback=True,
                    scale=item_prob.get(rname, 1.0),
                )
                holders.add(topo.home)

        total = compute_t + wrapper_t + network_t

        def _count(x):
            # unconditional pipelines keep exact int byte counters; an
            # expected count that happens to be integral canonicalizes
            # back to int so reports stay comparable across arms
            if isinstance(x, int):
                return x
            return int(x) if float(x).is_integer() else x

        return PlanReport(
            placements=tuple(placements),
            total_time=total,
            compute_time=compute_t,
            wrapper_time=wrapper_t,
            network_time=network_t,
            uplink_bytes=_count(up_bytes),
            downlink_bytes=_count(down_bytes),
            legs=tuple(legs),
            compute_by_tier=tuple(compute_by_tier.items()),
            breakdown=tuple(bd.items()),
            leg_down=tuple(leg_down),
            wire_by_link=tuple(wire_links),
        )
