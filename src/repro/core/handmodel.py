"""27-DoF kinematic hand model (paper §3.1, "Hand model").

The hand configuration vector ``h`` has 27 kinematic parameters:

* ``h[0:3]``   — 3D location of the hand root (palm center), meters.
* ``h[3:7]``   — 3D orientation as a unit quaternion ``(w, x, y, z)``
  (the paper uses a quaternion "to avoid gimbal locks").
* ``h[7:27]``  — 20 bone angles encoding finger articulation, radians:
  4 per finger ``(abduction, mcp_flex, pip_flex, dip_flex)`` for the four
  fingers, and ``(tm_abd, tm_flex, mcp_flex, ip_flex)`` for the thumb.

The geometry follows the FORTH generative-tracker family (Oikonomidis et
al., BMVC 2011 — reference [8] of the paper): the hand is a union of
quadric primitives. We use spheres placed along each bone (a capsule
approximated by ``SPHERES_PER_BONE`` spheres) plus a palm slab of spheres,
because analytic sphere depth is pure FMA math — the TPU-idiomatic
equivalent of the paper's CUDA rasterizer (see DESIGN.md §2).

Everything here is pure JAX and differentiable (PSO does not need
gradients — the paper stresses that — but differentiability is free and
lets tests cross-check with gradient descent).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Parameter layout
# ---------------------------------------------------------------------------

NUM_PARAMS = 27
POS_SLICE = slice(0, 3)
QUAT_SLICE = slice(3, 7)
ANGLES_SLICE = slice(7, 27)

FINGER_NAMES = ("thumb", "index", "middle", "ring", "pinky")
ANGLES_PER_FINGER = 4

# Geometry constants (meters). Proportions of an average adult hand.
PALM_WIDTH = 0.085
PALM_LENGTH = 0.095
PALM_THICKNESS = 0.030

# Finger attachment points on the palm, in the hand local frame:
#   +x: thumb side (radial), +y: from wrist towards fingers, +z: out of the
#   back of the hand (towards the camera when the palm faces away).
_FINGER_BASES = (
    # thumb attaches low on the radial side
    (0.040, 0.005, -0.010),
    (0.032, 0.048, 0.0),   # index
    (0.010, 0.052, 0.0),   # middle
    (-0.012, 0.050, 0.0),  # ring
    (-0.033, 0.044, 0.0),  # pinky
)

# Per-finger bone lengths (proximal, middle, distal), meters.
_BONE_LENGTHS = (
    (0.046, 0.035, 0.028),  # thumb (metacarpal treated as proximal)
    (0.040, 0.026, 0.018),  # index
    (0.044, 0.029, 0.019),  # middle
    (0.041, 0.027, 0.018),  # ring
    (0.032, 0.021, 0.016),  # pinky
)

# Per-finger base radii, meters (tapers towards the tip).
_FINGER_RADII = (0.011, 0.009, 0.009, 0.0085, 0.0075)

# Resting direction of each finger in the palm frame (unit-ish vectors,
# normalized in code). The thumb points sideways+forward.
_FINGER_DIRS = (
    (0.8, 0.5, -0.2),
    (0.05, 1.0, 0.0),
    (0.0, 1.0, 0.0),
    (-0.05, 1.0, 0.0),
    (-0.12, 1.0, 0.0),
)

SPHERES_PER_BONE = 2
NUM_BONES_PER_FINGER = 3
# palm spheres: 3 columns x 3 rows
_PALM_GRID = (3, 3)
NUM_PALM_SPHERES = _PALM_GRID[0] * _PALM_GRID[1]
NUM_FINGER_SPHERES = (
    len(FINGER_NAMES) * NUM_BONES_PER_FINGER * SPHERES_PER_BONE
)
NUM_SPHERES_RAW = NUM_PALM_SPHERES + NUM_FINGER_SPHERES + len(FINGER_NAMES)
# pad to a multiple of 8 so kernel tiles stay aligned
NUM_SPHERES = ((NUM_SPHERES_RAW + 7) // 8) * 8

# Per-dimension articulation limits (radians), used both to clamp FK inputs
# and as PSO search bounds.
_ABD_LIMIT = 0.35
_FLEX_LO, _FLEX_HI = -0.26, 1.9


def angle_lower_bounds() -> jnp.ndarray:
    lo = []
    for _ in FINGER_NAMES:
        lo.extend([-_ABD_LIMIT, _FLEX_LO, _FLEX_LO, _FLEX_LO])
    return jnp.asarray(lo, dtype=jnp.float32)


def angle_upper_bounds() -> jnp.ndarray:
    hi = []
    for _ in FINGER_NAMES:
        hi.extend([_ABD_LIMIT, _FLEX_HI, _FLEX_HI, _FLEX_HI])
    return jnp.asarray(hi, dtype=jnp.float32)


# ---------------------------------------------------------------------------
# Quaternion utilities (w, x, y, z convention)
# ---------------------------------------------------------------------------


def quat_normalize(q: jnp.ndarray) -> jnp.ndarray:
    return q / (jnp.linalg.norm(q, axis=-1, keepdims=True) + 1e-12)


def quat_multiply(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    aw, ax, ay, az = a[..., 0], a[..., 1], a[..., 2], a[..., 3]
    bw, bx, by, bz = b[..., 0], b[..., 1], b[..., 2], b[..., 3]
    return jnp.stack(
        [
            aw * bw - ax * bx - ay * by - az * bz,
            aw * bx + ax * bw + ay * bz - az * by,
            aw * by - ax * bz + ay * bw + az * bx,
            aw * bz + ax * by - ay * bx + az * bw,
        ],
        axis=-1,
    )


def quat_rotate(q: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Rotate vector(s) v by unit quaternion(s) q."""
    w = q[..., 0:1]
    u = q[..., 1:4]
    # v' = v + 2 w (u x v) + 2 (u x (u x v))
    uv = jnp.cross(u, v)
    return v + 2.0 * (w * uv + jnp.cross(u, uv))


def quat_from_axis_angle(axis: jnp.ndarray, angle: jnp.ndarray) -> jnp.ndarray:
    axis = axis / (jnp.linalg.norm(axis, axis=-1, keepdims=True) + 1e-12)
    half = angle * 0.5
    s = jnp.sin(half)
    return jnp.concatenate(
        [jnp.cos(half)[..., None], axis * s[..., None]], axis=-1
    )


# ---------------------------------------------------------------------------
# Forward kinematics -> sphere primitives
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HandGeometry:
    """Static geometry description (non-traced constants)."""

    num_spheres: int = NUM_SPHERES
    palm_width: float = PALM_WIDTH
    palm_length: float = PALM_LENGTH


def _palm_spheres_local() -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Palm sphere centers + radii in the hand local frame.

    Built with numpy so the cached constants are real arrays even when
    the first call happens under a jit trace (a jnp build here would
    cache — and leak — tracers)."""
    import numpy as np

    xs = np.linspace(-PALM_WIDTH / 2 * 0.7, PALM_WIDTH / 2 * 0.7, _PALM_GRID[0])
    ys = np.linspace(-PALM_LENGTH / 2 * 0.55, PALM_LENGTH / 2 * 0.75, _PALM_GRID[1])
    gx, gy = np.meshgrid(xs, ys, indexing="ij")
    centers = np.stack(
        [gx.reshape(-1), gy.reshape(-1), np.zeros(NUM_PALM_SPHERES)], axis=-1
    )
    radii = np.full((NUM_PALM_SPHERES,), PALM_THICKNESS * 0.75)
    return centers.astype(np.float32), radii.astype(np.float32)


_PALM_CENTERS, _PALM_RADII = None, None  # lazily built (avoid import-time jax)


def _get_palm() -> Tuple[jnp.ndarray, jnp.ndarray]:
    """The cache holds NUMPY arrays; conversion happens per call site so
    a first call under a jit trace can never leak tracers into the
    cache (they would escape to later out-of-trace calls)."""
    global _PALM_CENTERS, _PALM_RADII
    if _PALM_CENTERS is None:
        _PALM_CENTERS, _PALM_RADII = _palm_spheres_local()
    return jnp.asarray(_PALM_CENTERS), jnp.asarray(_PALM_RADII)


def _finger_spheres(
    base: jnp.ndarray,
    rest_dir: jnp.ndarray,
    lengths: Tuple[float, float, float],
    radius: float,
    angles: jnp.ndarray,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """FK for one finger in the hand local frame.

    angles = (abduction, flex1, flex2, flex3). Flexion axis is the local +x
    (curling towards the palm, i.e. rotating the bone direction towards -z);
    abduction swings around +z.
    """
    rest_dir = rest_dir / jnp.linalg.norm(rest_dir)
    # Build the finger base frame: y' = rest_dir, z' = palm normal.
    # Flexion axis z' x dir so positive flexion curls towards the palm
    # (-z), matching anatomical convention.
    z_axis = jnp.asarray([0.0, 0.0, 1.0], dtype=jnp.float32)
    x_axis = jnp.cross(z_axis, rest_dir)
    x_axis = x_axis / (jnp.linalg.norm(x_axis) + 1e-12)

    q_abd = quat_from_axis_angle(z_axis, angles[0])
    q = q_abd
    centers = []
    radii = []
    pos = base
    direction = rest_dir
    for bone_idx in range(NUM_BONES_PER_FINGER):
        flex = angles[1 + bone_idx]
        q_flex = quat_from_axis_angle(x_axis, flex)
        q = quat_multiply(q, q_flex)
        direction = quat_rotate(quat_normalize(q), rest_dir)
        length = lengths[bone_idx]
        r = radius * (1.0 - 0.15 * bone_idx)
        for k in range(SPHERES_PER_BONE):
            frac = (k + 1.0) / SPHERES_PER_BONE
            centers.append(pos + direction * (length * frac))
            radii.append(r)
        pos = pos + direction * length
    # fingertip sphere
    centers.append(pos + direction * (radius * 0.5))
    radii.append(radius * 0.85)
    return jnp.stack(centers), jnp.asarray(radii, dtype=jnp.float32)


def hand_spheres_local(angles: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """All sphere primitives in the hand local frame.

    Args:
      angles: (20,) articulation angles.

    Returns:
      centers (NUM_SPHERES, 3), radii (NUM_SPHERES,) — zero-radius padding
      spheres at the end.
    """
    lo, hi = angle_lower_bounds(), angle_upper_bounds()
    angles = jnp.clip(angles, lo, hi)
    palm_c, palm_r = _get_palm()
    centers = [palm_c]
    radii = [palm_r]
    for f, name in enumerate(FINGER_NAMES):
        fa = angles[f * ANGLES_PER_FINGER : (f + 1) * ANGLES_PER_FINGER]
        c, r = _finger_spheres(
            jnp.asarray(_FINGER_BASES[f], dtype=jnp.float32),
            jnp.asarray(_FINGER_DIRS[f], dtype=jnp.float32),
            _BONE_LENGTHS[f],
            _FINGER_RADII[f],
            fa,
        )
        centers.append(c)
        radii.append(r)
    centers = jnp.concatenate(centers, axis=0)
    radii = jnp.concatenate(radii, axis=0)
    pad = NUM_SPHERES - centers.shape[0]
    if pad:
        centers = jnp.concatenate(
            [centers, jnp.zeros((pad, 3), dtype=jnp.float32)], axis=0
        )
        # zero radius => never hit
        radii = jnp.concatenate([radii, jnp.zeros((pad,), dtype=jnp.float32)])
    return centers, radii


def hand_spheres_world(h: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sphere primitives in camera/world coordinates for configuration h.

    Args:
      h: (27,) hand configuration.

    Returns:
      centers (NUM_SPHERES, 3) in camera frame, radii (NUM_SPHERES,).
    """
    pos = h[POS_SLICE]
    quat = quat_normalize(h[QUAT_SLICE])
    angles = h[ANGLES_SLICE]
    centers_l, radii = hand_spheres_local(angles)
    centers_w = quat_rotate(quat[None, :], centers_l) + pos[None, :]
    return centers_w, radii


def pack_spheres(h: jnp.ndarray) -> jnp.ndarray:
    """(NUM_SPHERES, 4) packed [cx, cy, cz, r] — the kernel input format."""
    c, r = hand_spheres_world(h)
    return jnp.concatenate([c, r[:, None]], axis=-1)


def default_pose(distance: float = 0.55) -> jnp.ndarray:
    """A neutral open hand facing the camera at `distance` meters."""
    h = jnp.zeros((NUM_PARAMS,), dtype=jnp.float32)
    h = h.at[2].set(distance)
    h = h.at[3].set(1.0)  # identity quaternion
    return h


def parameter_lower_bounds(center: jnp.ndarray, pos_range: float = 0.12,
                           quat_range: float = 0.25) -> jnp.ndarray:
    """PSO lower bounds: a box around `center` (the previous-frame solution).

    The paper: "particles are initialized around the solution of the
    previous frame. The space around that solution is made large enough to
    include the current frame estimation."
    """
    lo = jnp.concatenate([
        center[POS_SLICE] - pos_range,
        center[QUAT_SLICE] - quat_range,
        jnp.maximum(center[ANGLES_SLICE] - 0.6, angle_lower_bounds()),
    ])
    return lo


def parameter_upper_bounds(center: jnp.ndarray, pos_range: float = 0.12,
                           quat_range: float = 0.25) -> jnp.ndarray:
    hi = jnp.concatenate([
        center[POS_SLICE] + pos_range,
        center[QUAT_SLICE] + quat_range,
        jnp.minimum(center[ANGLES_SLICE] + 0.6, angle_upper_bounds()),
    ])
    return hi


def normalize_configuration(h: jnp.ndarray) -> jnp.ndarray:
    """Renormalize the quaternion block (PSO moves particles off the
    unit-quaternion manifold; this projects back)."""
    q = quat_normalize(h[..., QUAT_SLICE])
    return jnp.concatenate([h[..., POS_SLICE], q, h[..., ANGLES_SLICE]], axis=-1)
