"""Core library: the paper's contribution as composable JAX modules.

* ``handmodel``  — 27-DoF kinematic hand model -> sphere primitives.
* ``camera``     — pinhole RGBD camera, precomputed rays.
* ``objective``  — Eq. (2) clamped depth discrepancy E_D (+ rendering).
* ``pso``        — Particle Swarm Optimization (lax loops, shardable eval).
* ``tracker``    — the 4-stage per-frame pipeline (Fig. 2).
* ``stages``     — StagedComputation: byte/FLOP-annotated stage graphs.
* ``topology``   — Tier/Link/Topology: N-tier placement graphs.
* ``costengine`` — the unified cost engine (all transfer/wrapper/compute
  arithmetic; per-leg latency records for exact jitter resampling).
* ``planners``   — exhaustive / single-crossing / chain-DP placement.
* ``offload``    — placement policies Local/Forced/Auto + two-tier shim.
* ``wrapper``    — container ("JNI") overhead measurement/calibration.
"""

from repro.core import (  # noqa: F401
    camera,
    handmodel,
    objective,
    offload,
    pso,
    stages,
    tracker,
    wrapper,
)
