"""Staged computations with byte/FLOP annotations.

The paper's Fig. 2: the per-frame hand-tracking optimization consists of
four discrete steps that can be exposed to the offloading framework either
individually ("Multi-Step") or fused ("Single-Step"). This module gives
that structure a first-class representation the placement engine
(``core.offload``) can reason about: each stage declares its FLOPs and the
data items it consumes/produces, and each data item knows its size, so
plan cost (compute + serialization + network) is computable analytically.

The same abstraction describes an LLM ``serve_step`` (embed -> blocks ->
head) — see ``serving/edge.py`` — which is how the paper's technique
generalizes to the assigned architectures.

Branching pipelines (PR 9): dependencies between stages are declared
through the data items themselves — a stage may consume any item
produced by *any* earlier stage, not just its immediate predecessor, so
the stage list describes an arbitrary DAG in topological order (a
linear chain is the special case where every stage consumes its
predecessor's output).  Conditional branches carry an execution
probability: ``Stage.exec_prob`` is the probability the stage runs on a
given frame (a mediapipe-style re-detect branch fires only when
tracking is lost), and the cost engine prices every term of a
probabilistic stage — compute, envelope, input/output transfers, wire
bytes — by its *expected* value (term × exec_prob).  ``validate()``
enforces coherence: a stage can never run more often than the branch
that feeds it (``exec_prob`` ≤ min over producers of its inputs).
``linearized()`` strips the probabilities (every branch forced
unconditional) — the baseline a DAG-aware planner is benchmarked
against in ``fleet_bench --mixed``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

CLIENT = "client"
SERVER = "server"


@dataclasses.dataclass(frozen=True)
class DataItem:
    """A named datum flowing between stages.

    ``origin`` is where the item first materializes: CLIENT for sensor
    inputs (camera frames, the previous-frame solution h_t) and stage
    outputs get their producer's placement at plan-evaluation time.
    """

    name: str
    nbytes: int
    origin: str = CLIENT


@dataclasses.dataclass(frozen=True)
class Stage:
    """One offloadable step.

    flops: arithmetic cost of the stage (population evaluation dominates).
    parallel_fraction: the portion of ``flops`` that scales with the
      executing tier's accelerator (the GPGPU part); the rest runs at
      scalar speed. The paper's 100x GPGPU speedup claim only applies to
      the parallel fraction — Amdahl bookkeeping matters for Fig. 4.
    exec_prob: probability the stage executes on a given frame (1.0 =
      unconditional, the historical behavior).  The cost engine prices a
      conditional stage at its expected cost: compute, envelope, input
      and output transfers all scale by ``exec_prob``.  Appended after
      ``fn`` so existing positional constructors are untouched.
    """

    name: str
    flops: float
    inputs: Tuple[str, ...]
    outputs: Tuple[DataItem, ...]
    parallel_fraction: float = 1.0
    fn: Optional[Callable] = None  # the actual jittable callable, if bound
    exec_prob: float = 1.0


@dataclasses.dataclass(frozen=True)
class StagedComputation:
    """An ordered pipeline of stages with serial dependencies.

    ``results`` are item names that must reside at CLIENT when the pipeline
    finishes (the tracker must hand h_{t+1} back to the acquisition loop —
    paper Fig. 3 category A)."""

    name: str
    sources: Tuple[DataItem, ...]
    stages: Tuple[Stage, ...]
    results: Tuple[str, ...]

    def item_table(self) -> Dict[str, DataItem]:
        table: Dict[str, DataItem] = {i.name: i for i in self.sources}
        for s in self.stages:
            for o in s.outputs:
                table[o.name] = o
        return table

    def validate(self) -> None:
        known = {i.name for i in self.sources}
        # item -> probability it materializes (sources always exist)
        prob: Dict[str, float] = {i.name: 1.0 for i in self.sources}
        for s in self.stages:
            if not 0.0 < s.exec_prob <= 1.0:
                raise ValueError(
                    f"stage {s.name!r} exec_prob {s.exec_prob!r} "
                    "must be in (0, 1]"
                )
            for inp in s.inputs:
                if inp not in known:
                    raise ValueError(
                        f"stage {s.name!r} consumes unknown item {inp!r}"
                    )
                if s.exec_prob > prob[inp]:
                    # a branch cannot run more often than what feeds it
                    raise ValueError(
                        f"stage {s.name!r} exec_prob {s.exec_prob} exceeds "
                        f"the probability {prob[inp]} of its input {inp!r}"
                    )
            for o in s.outputs:
                known.add(o.name)
                prob[o.name] = s.exec_prob
        for r in self.results:
            if r not in known:
                raise ValueError(f"result item {r!r} never produced")

    # -- DAG structure helpers (PR 9) -----------------------------------

    def producer_of(self) -> Dict[str, str]:
        """Item name -> producing stage name (sources absent)."""
        out: Dict[str, str] = {}
        for s in self.stages:
            for o in s.outputs:
                out[o.name] = s.name
        return out

    def consumer_counts(self) -> Dict[str, int]:
        """Item name -> number of times any stage consumes it."""
        counts: Dict[str, int] = {}
        for s in self.stages:
            for inp in s.inputs:
                counts[inp] = counts.get(inp, 0) + 1
        return counts

    def stage_parents(self) -> Dict[str, Tuple[str, ...]]:
        """Stage name -> distinct producing stages of its non-source
        inputs, in first-appearance order — the stage-level dependency
        DAG implied by the item flow."""
        producer = self.producer_of()
        parents: Dict[str, Tuple[str, ...]] = {}
        for s in self.stages:
            seen: List[str] = []
            for inp in s.inputs:
                p = producer.get(inp)
                if p is not None and p not in seen:
                    seen.append(p)
            parents[s.name] = tuple(seen)
        return parents

    def linearized(self) -> "StagedComputation":
        """The forced-unconditional variant: every branch's
        ``exec_prob`` reset to 1.0, as if conditional stages executed on
        every frame.  This is the baseline a DAG-aware planner is
        measured against (``fleet_bench --mixed``); on an already
        unconditional computation it is the identity."""
        if all(s.exec_prob == 1.0 for s in self.stages):
            return self
        stages = tuple(
            dataclasses.replace(s, exec_prob=1.0) for s in self.stages
        )
        return StagedComputation(self.name, self.sources, stages, self.results)

    def fused(self, fused_name: str = "single_step") -> "StagedComputation":
        """Single-Step variant: all stages fused into one offloadable unit.

        Intermediate items disappear from the network-visible surface —
        exactly why the paper's Single-Step beats Multi-Step: only the
        sources go up and only the results come down.

        Conditional stages fuse at their *expected* cost (flops weighted
        by ``exec_prob``) — the fused unit always runs, but on an
        average frame only the expected fraction of each branch's work
        executes inside it.  A passthrough result (a source name listed
        in ``results``) is NOT re-emitted as a fused-stage output: it
        already resides at its origin, and re-producing it would charge
        a bogus ship-home from wherever the fused stage lands.  A
        zero-flops pipeline fuses with ``parallel_fraction = 0.0`` (no
        parallel work exists, so none may be claimed)."""
        if not self.stages:
            raise ValueError(f"cannot fuse {self.name!r}: no stages")
        self.validate()
        table = self.item_table()
        total_flops = sum(s.exec_prob * s.flops for s in self.stages)
        wsum = sum(
            s.exec_prob * s.flops * s.parallel_fraction for s in self.stages
        )
        pfrac = wsum / total_flops if total_flops else 0.0
        src_names = tuple(i.name for i in self.sources)
        outputs = tuple(
            table[r] for r in self.results if r not in set(src_names)
        )
        fused_stage = Stage(
            name=fused_name,
            flops=total_flops,
            inputs=src_names,
            outputs=outputs,
            parallel_fraction=pfrac,
        )
        return StagedComputation(
            name=f"{self.name}[fused]",
            sources=self.sources,
            stages=(fused_stage,),
            results=self.results,
        )

    def total_flops(self) -> float:
        return sum(s.flops for s in self.stages)


def pytree_nbytes(tree) -> int:
    """Byte size of a pytree of arrays/ShapeDtypeStructs — used to annotate
    stage boundaries from real jaxpr signatures."""
    import jax
    import numpy as np

    leaves = jax.tree_util.tree_leaves(tree)
    total = 0
    for leaf in leaves:
        shape = getattr(leaf, "shape", ())
        dtype = getattr(leaf, "dtype", None)
        if dtype is None:
            total += 8
        else:
            total += int(np.prod(shape, dtype=np.int64)) * np.dtype(dtype).itemsize
    return total


def flops_of_jaxpr(fn: Callable, *args) -> float:
    """Estimate FLOPs of ``fn(*args)`` via XLA's cost analysis on a CPU
    lowering. Used to annotate stages from their real implementations
    instead of hand-counted constants."""
    import jax

    try:
        compiled = jax.jit(fn).lower(*args).compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, list):  # older jax returns [dict]
            cost = cost[0]
        return float(cost.get("flops", 0.0))
    except Exception:
        return 0.0
