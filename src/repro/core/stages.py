"""Staged computations with byte/FLOP annotations.

The paper's Fig. 2: the per-frame hand-tracking optimization consists of
four discrete steps that can be exposed to the offloading framework either
individually ("Multi-Step") or fused ("Single-Step"). This module gives
that structure a first-class representation the placement engine
(``core.offload``) can reason about: each stage declares its FLOPs and the
data items it consumes/produces, and each data item knows its size, so
plan cost (compute + serialization + network) is computable analytically.

The same abstraction describes an LLM ``serve_step`` (embed -> blocks ->
head) — see ``serving/edge.py`` — which is how the paper's technique
generalizes to the assigned architectures.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

CLIENT = "client"
SERVER = "server"


@dataclasses.dataclass(frozen=True)
class DataItem:
    """A named datum flowing between stages.

    ``origin`` is where the item first materializes: CLIENT for sensor
    inputs (camera frames, the previous-frame solution h_t) and stage
    outputs get their producer's placement at plan-evaluation time.
    """

    name: str
    nbytes: int
    origin: str = CLIENT


@dataclasses.dataclass(frozen=True)
class Stage:
    """One offloadable step.

    flops: arithmetic cost of the stage (population evaluation dominates).
    parallel_fraction: the portion of ``flops`` that scales with the
      executing tier's accelerator (the GPGPU part); the rest runs at
      scalar speed. The paper's 100x GPGPU speedup claim only applies to
      the parallel fraction — Amdahl bookkeeping matters for Fig. 4.
    """

    name: str
    flops: float
    inputs: Tuple[str, ...]
    outputs: Tuple[DataItem, ...]
    parallel_fraction: float = 1.0
    fn: Optional[Callable] = None  # the actual jittable callable, if bound


@dataclasses.dataclass(frozen=True)
class StagedComputation:
    """An ordered pipeline of stages with serial dependencies.

    ``results`` are item names that must reside at CLIENT when the pipeline
    finishes (the tracker must hand h_{t+1} back to the acquisition loop —
    paper Fig. 3 category A)."""

    name: str
    sources: Tuple[DataItem, ...]
    stages: Tuple[Stage, ...]
    results: Tuple[str, ...]

    def item_table(self) -> Dict[str, DataItem]:
        table: Dict[str, DataItem] = {i.name: i for i in self.sources}
        for s in self.stages:
            for o in s.outputs:
                table[o.name] = o
        return table

    def validate(self) -> None:
        known = {i.name for i in self.sources}
        for s in self.stages:
            for inp in s.inputs:
                if inp not in known:
                    raise ValueError(
                        f"stage {s.name!r} consumes unknown item {inp!r}"
                    )
            for o in s.outputs:
                known.add(o.name)
        for r in self.results:
            if r not in known:
                raise ValueError(f"result item {r!r} never produced")

    def fused(self, fused_name: str = "single_step") -> "StagedComputation":
        """Single-Step variant: all stages fused into one offloadable unit.

        Intermediate items disappear from the network-visible surface —
        exactly why the paper's Single-Step beats Multi-Step: only the
        sources go up and only the results come down."""
        self.validate()
        table = self.item_table()
        total_flops = sum(s.flops for s in self.stages)
        wsum = sum(s.flops * s.parallel_fraction for s in self.stages)
        pfrac = wsum / total_flops if total_flops else 1.0
        outputs = tuple(table[r] for r in self.results)
        src_names = tuple(i.name for i in self.sources)
        fused_stage = Stage(
            name=fused_name,
            flops=total_flops,
            inputs=src_names,
            outputs=outputs,
            parallel_fraction=pfrac,
        )
        return StagedComputation(
            name=f"{self.name}[fused]",
            sources=self.sources,
            stages=(fused_stage,),
            results=self.results,
        )

    def total_flops(self) -> float:
        return sum(s.flops for s in self.stages)


def pytree_nbytes(tree) -> int:
    """Byte size of a pytree of arrays/ShapeDtypeStructs — used to annotate
    stage boundaries from real jaxpr signatures."""
    import jax
    import numpy as np

    leaves = jax.tree_util.tree_leaves(tree)
    total = 0
    for leaf in leaves:
        shape = getattr(leaf, "shape", ())
        dtype = getattr(leaf, "dtype", None)
        if dtype is None:
            total += 8
        else:
            total += int(np.prod(shape, dtype=np.int64)) * np.dtype(dtype).itemsize
    return total


def flops_of_jaxpr(fn: Callable, *args) -> float:
    """Estimate FLOPs of ``fn(*args)`` via XLA's cost analysis on a CPU
    lowering. Used to annotate stages from their real implementations
    instead of hand-counted constants."""
    import jax

    try:
        compiled = jax.jit(fn).lower(*args).compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, list):  # older jax returns [dict]
            cost = cost[0]
        return float(cost.get("flops", 0.0))
    except Exception:
        return 0.0
