"""Named multi-model workloads: branching stage-DAGs with distinct
compute/payload profiles.

Every real hand-tracking deployment this repo models after runs a
*family* of pipelines, not one: mediapipe-style trackers chain palm
detection into per-hand landmark models with a conditional re-detect
edge, gesture heads hang off the landmark features, and RGBD trackers
carry an order of magnitude more payload than RGB ones.  This registry
gives the fleet a vocabulary of such pipelines so `run_fleet` can admit
*mixed* traffic (``workloads=...`` cycles clients across them) and the
DAG-aware planner has real branching structure to exploit.

Each builder returns a fresh :class:`StagedComputation`:

* ``solo_landmark``  — RGB single-hand: detect -> landmark.  A linear
  chain (the ``chain_dp`` planner's domain), lightest compute.
* ``multi_hand``     — RGB two-hand out-tree: palm detection fans out
  to per-hand landmark branches (the second hand present on a fraction
  of frames) plus a rare, expensive full-frame re-detect branch.  The
  ``tree_dp`` planner's domain.
* ``full_gesture``   — landmark chain with a gesture-classifier branch
  riding the landmark features; the pose result ships home from the
  *middle* of the graph, which already breaks the chain planner.
* ``rgbd_tracking``  — the paper-style RGBD pipeline: heavy 537.6 kB
  depth frames, the previous pose consumed by two stages (residency
  sharing), and a rare global re-seed branch joining from an earlier
  stage output — a true DAG, the planners' general-case fallback.

Conditional branches are priced at expected cost through
``Stage.exec_prob`` (see ``core.costengine``); ``linearized()`` on any
of these forces every branch unconditional — the baseline arm of
``fleet_bench --mixed``.

Byte sizes: RGB frames are 320x240x3 (230,400 B), RGBD frames reuse the
paper's 537,600 B acquisition size, ROI crops are 128x128 patches.
FLOP counts are sized against ``sim.hardware.paper_staged`` (~22 GFLOP
per frame) so the same fleet stars saturate at comparable client
counts.
"""

from __future__ import annotations

from typing import Callable, Dict, Tuple

from repro.core.stages import CLIENT, DataItem, Stage, StagedComputation

# RGB camera frame: 320 x 240 x 3 channels
RGB_FRAME_BYTES = 320 * 240 * 3
# RGBD acquisition, the paper's wire size (320 x 240 x (3 + 2B depth))
RGBD_FRAME_BYTES = 537_600
# 128 x 128 x 3 ROI crop handed to a landmark model
ROI_BYTES = 128 * 128 * 3
# 21 landmarks x (x, y, z) float32 + handedness score
LANDMARKS_BYTES = 21 * 3 * 4 + 4

# branch execution probabilities (mediapipe-style tracking loop):
# the second hand is in frame well under half the time, re-detection
# fires only on tracking loss, the gesture head runs when a hand is
# confidently tracked
P_SECOND_HAND = 0.4
P_REDETECT = 0.12
P_GESTURE = 0.8
P_RESEED = 0.08


def solo_landmark() -> StagedComputation:
    """RGB single-hand landmark pipeline — a linear chain."""
    sources = (DataItem("frame", RGB_FRAME_BYTES, CLIENT),)
    stages = (
        Stage(
            name="detect",
            flops=2.6e9,
            inputs=("frame",),
            outputs=(DataItem("roi", ROI_BYTES),),
            parallel_fraction=0.96,
        ),
        Stage(
            name="landmark",
            flops=5.2e9,
            inputs=("roi",),
            outputs=(DataItem("lm", LANDMARKS_BYTES),),
            parallel_fraction=0.97,
        ),
    )
    return StagedComputation("solo_landmark", sources, stages, ("lm",))


def multi_hand() -> StagedComputation:
    """RGB two-hand out-tree: palm detect fans out per hand, plus a
    rare full-frame re-detect branch (fires on tracking loss)."""
    sources = (DataItem("frame", RGB_FRAME_BYTES, CLIENT),)
    stages = (
        Stage(
            name="palm_detect",
            flops=6.0e9,
            inputs=("frame",),
            outputs=(
                DataItem("roi_l", ROI_BYTES),
                DataItem("roi_r", ROI_BYTES),
                DataItem("det_map", 24 * 32 * 4),
            ),
            parallel_fraction=0.96,
        ),
        Stage(
            name="landmark_l",
            flops=4.4e9,
            inputs=("roi_l",),
            outputs=(DataItem("lm_l", LANDMARKS_BYTES),),
            parallel_fraction=0.97,
        ),
        Stage(
            name="landmark_r",
            flops=4.4e9,
            inputs=("roi_r",),
            outputs=(DataItem("lm_r", LANDMARKS_BYTES),),
            parallel_fraction=0.97,
            exec_prob=P_SECOND_HAND,
        ),
        Stage(
            name="redetect",
            flops=7.5e9,
            inputs=("det_map",),
            outputs=(DataItem("redet_box", 4 * 4),),
            parallel_fraction=0.95,
            exec_prob=P_REDETECT,
        ),
    )
    return StagedComputation(
        "multi_hand", sources, stages, ("lm_l", "lm_r", "redet_box")
    )


def full_gesture() -> StagedComputation:
    """Landmark chain with a gesture head riding the features; the pose
    result leaves the graph mid-chain (tree, not chain, territory)."""
    sources = (DataItem("frame", RGB_FRAME_BYTES, CLIENT),)
    stages = (
        Stage(
            name="detect",
            flops=2.6e9,
            inputs=("frame",),
            outputs=(DataItem("roi", ROI_BYTES),),
            parallel_fraction=0.96,
        ),
        Stage(
            name="landmark",
            flops=5.2e9,
            inputs=("roi",),
            outputs=(
                DataItem("lm", LANDMARKS_BYTES),
                DataItem("feat", 128 * 4),
            ),
            parallel_fraction=0.97,
        ),
        Stage(
            name="gesture",
            flops=3.2e9,
            inputs=("feat",),
            outputs=(DataItem("g_label", 16),),
            parallel_fraction=0.94,
            exec_prob=P_GESTURE,
        ),
    )
    return StagedComputation(
        "full_gesture", sources, stages, ("lm", "g_label")
    )


def rgbd_tracking() -> StagedComputation:
    """Paper-style RGBD pipeline: heavy frames, the previous pose
    consumed twice, a rare global re-seed joining from an early output
    — a general DAG (neither chain nor out-tree)."""
    sources = (
        DataItem("frame_rgbd", RGBD_FRAME_BYTES, CLIENT),
        DataItem("h_prev", 108, CLIENT),
    )
    stages = (
        Stage(
            name="preprocess",
            flops=1.4e8,
            inputs=("frame_rgbd", "h_prev"),
            outputs=(DataItem("roi_d", 96 * 96 * 2),),
            parallel_fraction=0.6,
        ),
        Stage(
            name="optimize",
            flops=9.5e9,
            inputs=("roi_d",),
            outputs=(DataItem("pose_raw", 21_368),),
            parallel_fraction=0.98,
        ),
        Stage(
            name="refine",
            flops=2.4e8,
            inputs=("pose_raw", "h_prev"),
            outputs=(DataItem("h_next", 108),),
            parallel_fraction=0.3,
        ),
        Stage(
            name="reseed",
            flops=6.0e9,
            inputs=("roi_d",),
            outputs=(DataItem("seed_box", 4 * 4),),
            parallel_fraction=0.95,
            exec_prob=P_RESEED,
        ),
    )
    return StagedComputation(
        "rgbd_tracking", sources, stages, ("h_next", "seed_box")
    )


# builder registry, insertion order = the default mixed-traffic cycle
WORKLOADS: Dict[str, Callable[[], StagedComputation]] = {
    "solo_landmark": solo_landmark,
    "multi_hand": multi_hand,
    "full_gesture": full_gesture,
    "rgbd_tracking": rgbd_tracking,
}

# workload name -> SLO class name (resolved by repro.cluster.slo, which
# owns the SLOClass definitions — kept as strings here so the core
# registry stays import-free of the cluster layer).  The tracking
# pipelines are *interactive*: a user's hand is on screen and the paper's
# real-time deadline applies.  The gesture head is *best-effort*
# analytics riding the same features — late labels degrade gracefully.
WORKLOAD_SLO: Dict[str, str] = {
    "solo_landmark": "interactive",
    "multi_hand": "interactive",
    "full_gesture": "best_effort",
    "rgbd_tracking": "interactive",
}


def workload_suite(
    names: Tuple[str, ...] = tuple(WORKLOADS),
) -> Tuple[StagedComputation, ...]:
    """Materialize (and validate) the named workloads, default all."""
    comps = tuple(WORKLOADS[n]() for n in names)
    for c in comps:
        c.validate()
    return comps
