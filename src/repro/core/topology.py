"""Topology graph for multi-tier offloading.

The paper's deployment is one weak client and one strong server joined by
a single link.  Production edge systems (AVEC, arXiv:2103.04930) span a
*hierarchy* — device -> edge -> cloud chains, or a device star-connected
to several edge servers.  This module models that shape directly:

* ``Tier``     — a compute endpoint (accelerator + scalar FLOP/s).
* ``Link``     — a network edge (bandwidth, latency, jitter).
* ``Topology`` — named tiers joined by links, with a designated ``home``
  tier where sensor data originates and results must land.  Placements
  are tier *names*, so the two-tier special case keeps the historical
  ``"client"`` / ``"server"`` literals via :meth:`Topology.two_tier`.

Routing between non-adjacent tiers follows the fewest-hop path (BFS),
computed once and cached; the cost engine (``core.costengine``) charges
per-leg wire/latency costs along it.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, Sequence, Tuple


def sample_latency(latency: float, jitter: float, rng=None) -> float:
    """One latency draw: Gaussian around ``latency`` when jittered."""
    if rng is None or jitter <= 0.0:
        return latency
    return max(0.0, float(rng.normal(latency, jitter)))


@dataclasses.dataclass(frozen=True)
class Tier:
    """A compute tier (the paper's "server" / "laptop", or a TPU pod).

    ``capacity`` is the number of requests the tier can serve concurrently
    at full speed (virtualized-accelerator slots, AVEC-style).  The paper's
    dedicated server is capacity 1 with a single client, so nothing queues;
    a shared edge box saturates once more than ``capacity`` clients hit it
    simultaneously, and the cost engine / fleet simulator charge queueing
    delay beyond that point.

    ``batching`` declares that the tier fuses compatible concurrent
    requests into one accelerator launch instead of time-slicing them:
    service time becomes *sublinear* in the number of co-served requests
    (``costengine.BatchServiceModel``) rather than processor-sharing
    inflated.  ``batch_overhead`` is the fixed extra cost of a fused
    multi-item launch (gather/scatter bookkeeping, seconds) and
    ``batch_marginal`` the fraction of an item's solo service time each
    *additional* batched item costs (1.0 = no amortization; the floats
    live here rather than a nested model object so the tier stays a flat
    hashable record the plan-cache fingerprint can consume directly).
    """

    name: str
    accel_flops: float  # effective accelerator FLOP/s for this workload
    scalar_flops: float  # serial/CPU FLOP/s (the non-parallel fraction)
    dispatch_overhead: float = 50e-6  # per-stage launch cost, seconds
    has_accelerator: bool = True
    capacity: int = 1  # concurrent service slots
    batching: bool = False  # fuse concurrent requests into one launch
    batch_overhead: float = 0.0  # fixed cost per fused multi-item launch
    batch_marginal: float = 0.35  # per-extra-item fraction of solo time


@dataclasses.dataclass(frozen=True)
class Link:
    """A network link between tiers.

    ``medium`` names the shared physical medium (cell sector, backhaul
    trunk) this link's wire legs contend on: every link carrying the
    same non-empty medium name shares ``medium_capacity`` concurrent
    transmission slots (``cluster.events.SharedLink``).  The empty
    string is a private spoke — the historical model and the exact
    off-switch — and ``medium_capacity == 0`` with a medium name is an
    unlimited shared medium: occupancy is *counted* but nothing ever
    queues, which must be bit-for-bit the private fleet (golden-tested).
    """

    name: str
    bandwidth: float  # bytes / second
    latency: float  # one-way, seconds
    jitter: float = 0.0  # stddev of latency, seconds (Wi-Fi interference)
    medium: str = ""  # shared-medium name ("" = private spoke)
    medium_capacity: int = 0  # concurrent transmissions (0 = unlimited)

    def transfer_time(self, nbytes: int, rng=None) -> float:
        """One-way payload time; pass ``rng`` to draw a jittered latency."""
        return sample_latency(self.latency, self.jitter, rng) + nbytes / self.bandwidth


@dataclasses.dataclass(frozen=True)
class WrapperModel:
    """Container ("JNI/JVM") overhead model — see core/wrapper.py for the
    calibration of these constants.

    Two distinct marshalling paths, matching the Java stack the paper
    uses: a *local* wrapped call crosses JNI with pinned/direct buffers
    (fast), while a *remote* call must push the payload through Java
    object-stream serialization (slow). Conflating the two cannot
    reconcile Fig. 4 (modest local wrapper tax) with Fig. 5 (~10 fps
    offloaded => tens of ms of serialization per frame)."""

    call_overhead: float = 1.2e-3  # fixed cost per wrapped method call
    serialization_bandwidth: float = 20e6  # remote path, bytes/s
    jni_bandwidth: float = 60e6  # local JNI marshal path, bytes/s

    def cost(self, nbytes: int) -> float:
        return self.call_overhead + nbytes / self.serialization_bandwidth


@dataclasses.dataclass
class Topology:
    """Named tiers joined by links, with a ``home`` tier.

    ``tiers`` maps *placement names* (the strings used in plans) to
    ``Tier`` specs; a tier's ``name`` field is its hardware identity and
    need not equal its placement name (the two-tier shim maps the
    calibrated "laptop_gf670m" tier to placement name "client").
    ``links`` keys are unordered tier-name pairs.
    """

    tiers: Mapping[str, Tier]
    links: Mapping[Tuple[str, str], Link]
    home: str = "client"
    wrapper: WrapperModel = dataclasses.field(default_factory=WrapperModel)
    wrapped: bool = True

    def __post_init__(self) -> None:
        if self.home not in self.tiers:
            raise ValueError(f"home tier {self.home!r} not in topology")
        self._adj: Dict[str, Dict[str, Link]] = {n: {} for n in self.tiers}
        for (a, b), link in self.links.items():
            if a not in self.tiers or b not in self.tiers:
                raise ValueError(f"link {link.name!r} joins unknown tier ({a}, {b})")
            self._adj[a][b] = link
            self._adj[b][a] = link
        self._paths: Dict[Tuple[str, str], Tuple[str, ...]] = {}
        # connectivity check (BFS from home)
        seen = {self.home}
        frontier = [self.home]
        while frontier:
            cur = frontier.pop()
            for nxt in self._adj[cur]:
                if nxt not in seen:
                    seen.add(nxt)
                    frontier.append(nxt)
        if seen != set(self.tiers):
            raise ValueError(f"topology is disconnected: {set(self.tiers) - seen}")

    # ------------------------------------------------------------------
    # routing
    # ------------------------------------------------------------------

    def path_tiers(self, src: str, dst: str) -> Tuple[str, ...]:
        """Tier names visited from src to dst inclusive (fewest hops)."""
        key = (src, dst)
        if key in self._paths:
            return self._paths[key]
        # BFS with deterministic neighbor order (insertion order of links)
        parent: Dict[str, str] = {src: src}
        frontier = [src]
        while frontier and dst not in parent:
            nxt_frontier = []
            for cur in frontier:
                for nxt in self._adj[cur]:
                    if nxt not in parent:
                        parent[nxt] = cur
                        nxt_frontier.append(nxt)
            frontier = nxt_frontier
        if dst not in parent:
            raise ValueError(f"no path {src!r} -> {dst!r}")
        path = [dst]
        while path[-1] != src:
            path.append(parent[path[-1]])
        tiers = tuple(reversed(path))
        self._paths[key] = tiers
        return tiers

    def path_links(self, src: str, dst: str) -> Tuple[Link, ...]:
        """The link legs crossed going from src to dst."""
        tiers = self.path_tiers(src, dst)
        return tuple(self._adj[a][b] for a, b in zip(tiers, tiers[1:]))

    def link_between(self, a: str, b: str) -> Link:
        return self._adj[a][b]

    # ------------------------------------------------------------------
    # queries
    # ------------------------------------------------------------------

    def tier(self, name: str) -> Tier:
        return self.tiers[name]

    def tier_names(self) -> Tuple[str, ...]:
        return tuple(self.tiers)

    def primary_remote(self) -> str:
        """Default FORCED target: the fastest non-home tier by effective
        speed (a tier without an accelerator computes at scalar rate)."""
        remotes = [n for n in self.tiers if n != self.home]
        if not remotes:
            return self.home

        def _effective(name: str) -> float:
            t = self.tiers[name]
            return t.accel_flops if t.has_accelerator else t.scalar_flops

        return max(remotes, key=_effective)

    # ------------------------------------------------------------------
    # constructors
    # ------------------------------------------------------------------

    @classmethod
    def two_tier(
        cls,
        client: Tier,
        server: Tier,
        link: Link,
        wrapper: Optional[WrapperModel] = None,
        wrapped: bool = True,
    ) -> "Topology":
        """The paper's shape; placements keep the client/server literals."""
        return cls(
            tiers={"client": client, "server": server},
            links={("client", "server"): link},
            home="client",
            wrapper=wrapper if wrapper is not None else WrapperModel(),
            wrapped=wrapped,
        )

    @classmethod
    def chain(
        cls,
        tiers: Sequence[Tuple[str, Tier]],
        links: Sequence[Link],
        home: Optional[str] = None,
        wrapper: Optional[WrapperModel] = None,
        wrapped: bool = True,
    ) -> "Topology":
        """A linear device -> edge -> ... -> cloud hierarchy."""
        if len(links) != len(tiers) - 1:
            raise ValueError("chain needs exactly len(tiers)-1 links")
        names = [n for n, _ in tiers]
        return cls(
            tiers=dict(tiers),
            links={
                (names[i], names[i + 1]): link for i, link in enumerate(links)
            },
            home=home if home is not None else names[0],
            wrapper=wrapper if wrapper is not None else WrapperModel(),
            wrapped=wrapped,
        )

    @classmethod
    def star(
        cls,
        hub: Tuple[str, Tier],
        spokes: Sequence[Tuple[str, Tier, Link]],
        wrapper: Optional[WrapperModel] = None,
        wrapped: bool = True,
    ) -> "Topology":
        """A home hub connected to several edge servers."""
        hub_name, hub_tier = hub
        tiers = {hub_name: hub_tier}
        links = {}
        for name, tier, link in spokes:
            tiers[name] = tier
            links[(hub_name, name)] = link
        return cls(
            tiers=tiers,
            links=links,
            home=hub_name,
            wrapper=wrapper if wrapper is not None else WrapperModel(),
            wrapped=wrapped,
        )
