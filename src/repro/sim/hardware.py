"""Device-tier models calibrated against the paper's measurements.

This container has no GTX 1080M, no GeForce 670M and no TPU, so absolute
tier throughputs are *calibrated anchors*, not measurements: we fix each
tier's effective FLOP/s so that the NATIVE (unwrapped, local) tracker hits
the paper's reported baseline framerates — server > 40 fps, laptop
~13 fps (Fig. 4) — for the paper-scale workload. Everything downstream
(wrapper overheads, Single- vs Multi-Step, Forced vs Auto, Ethernet vs
Wi-Fi) is then a *prediction* of the cost model, validated against the
paper's reported orderings in tests/test_paper_claims.py. The two fps
anchors are the only fitted quantities; see DESIGN.md §6.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core import pso, tracker
from repro.core.camera import Camera
from repro.core.offload import (
    BatchServiceModel,
    Environment,
    Link,
    Policy,
    Tier,
    Topology,
    WrapperModel,
)
from repro.core.stages import StagedComputation
from repro.core.wrapper import paper_wrapper
from repro.net import links

# ---------------------------------------------------------------------------
# The paper-scale workload
# ---------------------------------------------------------------------------

# Hypotheses are rendered/scored at a reduced working resolution; the
# sensor frame that crosses the network is 320x240 RGBD:
#   depth f32 320*240*4 + RGB24 320*240*3 = 537,600 bytes.
PAPER_FRAME_BYTES = 320 * 240 * 4 + 320 * 240 * 3

PAPER_TRACKER_CFG = tracker.TrackerConfig(
    camera=Camera(),  # 128x128 working resolution
    pso=pso.PSOConfig(num_particles=64, num_generations=30),
)

# The paper's reported native baselines (Fig. 4).
SERVER_NATIVE_FPS = 42.0
LAPTOP_NATIVE_FPS = 13.0


def paper_staged() -> StagedComputation:
    return tracker.build_staged(PAPER_TRACKER_CFG, frame_nbytes=PAPER_FRAME_BYTES)


def mixed_workloads(names=None) -> tuple:
    """The multi-model traffic mix for ``run_fleet(workloads=...)``:
    the validated registry pipelines from :mod:`repro.core.workloads`
    (solo landmark chain, two-hand out-tree, gesture tree, RGBD DAG),
    in registry order — the default cycle of ``fleet_bench --mixed``.
    ``names`` selects a subset (registry order is client order mod N)."""
    from repro.core.workloads import WORKLOADS, workload_suite

    return workload_suite(tuple(names) if names is not None else tuple(WORKLOADS))


def calibrate_tier(
    name: str,
    native_fps: float,
    comp: StagedComputation,
    scalar_flops: float = 40e9,
    dispatch_overhead: float = 80e-6,
) -> Tier:
    """Solve the tier's effective accelerator FLOP/s from its native fps.

    native loop time = sum_i [par_i/accel + ser_i/scalar + dispatch]
    =>  accel = (sum par_i) / (1/fps - sum(ser_i/scalar + dispatch))
    """
    par = sum(s.flops * s.parallel_fraction for s in comp.stages)
    fixed = sum(
        (s.flops * (1.0 - s.parallel_fraction)) / scalar_flops
        + dispatch_overhead
        for s in comp.stages
    )
    budget = 1.0 / native_fps - fixed
    if budget <= 0:
        raise ValueError(f"{name}: scalar fraction alone exceeds 1/fps")
    return Tier(
        name=name,
        accel_flops=par / budget,
        scalar_flops=scalar_flops,
        dispatch_overhead=dispatch_overhead,
    )


def paper_tiers() -> Dict[str, Tier]:
    comp = paper_staged()
    return {
        "server": calibrate_tier("server_gtx1080m", SERVER_NATIVE_FPS, comp),
        "laptop": calibrate_tier(
            "laptop_gf670m", LAPTOP_NATIVE_FPS, comp, scalar_flops=20e9
        ),
    }


# TPU v5e: 197 TFLOP/s bf16 peak; this VPU-bound f32 workload lands well
# below MXU peak — 8% effective is a conservative planning number.
TPU_V5E = Tier(
    name="tpu_v5e",
    accel_flops=197e12 * 0.08,
    scalar_flops=60e9,
    dispatch_overhead=20e-6,
)

# A GPU-less thin client (Raspberry-Pi-class): the *Forced* scenario's
# target device — "a machine without a GPU is possible to run the
# real-time 3D hand tracking with 1/3 of the desired framerate".
THIN_CLIENT_NO_GPU = Tier(
    name="thin_client",
    accel_flops=8e9,
    scalar_flops=8e9,
    dispatch_overhead=100e-6,
    has_accelerator=False,
)

# --- heterogeneous client classes (fleet-scale sweeps) ---------------------
#
# A large fleet is never uniform: the embedded-CNN hand-pose line of
# work runs the tracker on phone NPUs and Jetson-class boards, while the
# weakest devices are the paper's GPU-less thin clients.  These tiers
# ladder from "must offload everything" to "offloads only under a fast
# link"; a fleet mixing them exercises per-class planning (each class
# fingerprints into its own plan-cache entries) and class-aware dispatch.

# A phone-class NPU: enough for preprocessing, far from a full swarm.
PHONE_NPU = Tier(
    name="phone_npu",
    accel_flops=40e9,
    scalar_flops=12e9,
    dispatch_overhead=150e-6,
)

# A Jetson-class embedded GPU: runs the tracker locally below realtime.
EMBEDDED_GPU = Tier(
    name="embedded_gpu",
    accel_flops=120e9,
    scalar_flops=16e9,
    dispatch_overhead=60e-6,
)

# A laptop integrated GPU — the strongest client class; roughly the
# regime of the paper's laptop (local tracking at ~1/2 realtime).
LAPTOP_IGPU = Tier(
    name="laptop_igpu",
    accel_flops=300e9,
    scalar_flops=30e9,
    dispatch_overhead=50e-6,
)

# The default heterogeneous mix, weakest first; ``run_fleet`` assigns
# client c the class at index c % len(classes), so every class is
# uniformly represented at any fleet size.
CLIENT_CLASSES = (THIN_CLIENT_NO_GPU, PHONE_NPU, EMBEDDED_GPU, LAPTOP_IGPU)


def paper_environment(
    network: str = "gigabit_ethernet", wrapped: bool = True
) -> Environment:
    """laptop (client) -> server over the requested network."""
    tiers = paper_tiers()
    return Environment(
        client=tiers["laptop"],
        server=tiers["server"],
        link=links.ALL_LINKS[network],
        wrapper=paper_wrapper(),
        wrapped=wrapped,
    )


def edge_tpu_environment(client_tier: Tier = THIN_CLIENT_NO_GPU) -> Environment:
    """The production analogue: thin client -> TPU pod over 5G edge."""
    return Environment(
        client=client_tier,
        server=TPU_V5E,
        link=links.FIVE_G_EDGE,
        wrapper=WrapperModel(call_overhead=0.2e-3, serialization_bandwidth=2e9),
        wrapped=True,
    )


# A metro-edge GPU box (workstation-class card racked near the 5G base
# station): faster than any client, far slower than the cloud pod, one
# cheap hop away — the middle rung of the AVEC-style hierarchy.
EDGE_GPU = Tier(
    name="edge_gpu",
    accel_flops=9e12,
    scalar_flops=50e9,
    dispatch_overhead=30e-6,
)

# The roofline tables anchor single-stream utilization: one client's
# swarm (64 particles) fills ~8% of an accelerator's peak (the same
# discount TPU_V5E carries).  A tier's accel_flops is that *effective*
# single-stream rate; device peak is accel_flops / SINGLE_STREAM_UTIL,
# and batching's amortization is precisely the idle (1 - util) share.
SINGLE_STREAM_UTIL = 0.08


def edge_batch_model(
    tier: Tier = EDGE_GPU, comp: "StagedComputation" = None
) -> BatchServiceModel:
    """Batch service model for an edge tier, calibrated from the
    roofline tables (``repro.roofline.analysis`` per-chip constants)
    against the paper-scale per-frame workload: a lone swarm runs at the
    tier's effective rate, co-batched swarms stream at device peak with
    HBM bandwidth scaled by the same peak ratio."""
    from repro.roofline import analysis

    comp = comp if comp is not None else paper_staged()
    par = sum(s.flops * s.parallel_fraction for s in comp.stages)
    peak = tier.accel_flops / SINGLE_STREAM_UTIL
    mem_bw = analysis.HBM_BW * (peak / analysis.PEAK_FLOPS)
    return BatchServiceModel.from_roofline(
        peak_flops=peak,
        effective_flops=tier.accel_flops,
        mem_bandwidth=mem_bw,
        flops_per_item=par,
        bytes_per_item=PAPER_FRAME_BYTES,
        launch_overhead=tier.dispatch_overhead,
    )


# LPDDR-class memory bandwidth of a thin client (Raspberry-Pi grade):
# the encode side of the payload codec streams the frame through this.
CLIENT_MEM_BW = 10e9


def codec_point(
    quant_bits: int = 8,
    keyframe_interval: int = 8,
    change_density: float = 0.2,
    client_tier: Tier = THIN_CLIENT_NO_GPU,
    edge_tier: Tier = EDGE_GPU,
    entropy: bool = False,
):
    """Roofline-calibrated codec operating point for the paper frame.

    Encode runs on the thin client (its CPU rate against LPDDR
    bandwidth), decode on the edge GPU (HBM scaled by the same peak
    ratio as :func:`edge_batch_model`); both sides take the roofline
    max of the kernels' arithmetic and their streaming floor.  The
    defaults — 8-bit depth, keyframe every 8 frames, 20% tile change
    density — sit near the stock ``data.rgbd`` sequence's measured
    density (``codec.rate.calibrate_density_map``).

    ``entropy=True`` arms the v2 entropy stage (``codec.ref``'s
    per-tile width coding of the delta residuals): delta payloads
    shrink by a further ~0.55x — the measured ratio of the width coder
    on the stock sequence's sparse residual planes — at ~2 extra CPU
    ops per raw byte on each side (one max-reduce pass plus the
    shift/accumulate packing)."""
    from repro.codec.model import CodecModel, tier_codec_rate
    from repro.roofline import analysis

    peak = edge_tier.accel_flops / SINGLE_STREAM_UTIL
    edge_bw = analysis.HBM_BW * (peak / analysis.PEAK_FLOPS)
    client_rate = tier_codec_rate(client_tier)
    point = CodecModel.from_roofline(
        "delta_quant_v2" if entropy else "delta_quant",
        quant_bits=quant_bits,
        keyframe_interval=keyframe_interval,
        change_density=change_density,
        encode_flops=client_rate,
        encode_mem_bandwidth=CLIENT_MEM_BW,
        decode_flops=edge_tier.accel_flops,
        decode_mem_bandwidth=edge_bw,
    )
    if entropy:
        point = dataclasses.replace(
            point,
            entropy_coding=True,
            entropy_ratio=0.55,
            entropy_flops_per_byte=2.0,
        )
    return point


def fleet_star(
    num_edges: int = 2,
    edge_capacity: int = 4,
    client_tier: Tier = THIN_CLIENT_NO_GPU,
    base_link: Link = links.FIVE_G_EDGE,
    batching: bool = False,
    comp: "StagedComputation" = None,
) -> Topology:
    """The fleet-simulation shape: one thin-client vantage point star-
    connected to ``num_edges`` shared metro-edge GPU boxes.

    Each edge tier carries ``edge_capacity`` concurrent service slots
    (virtualized-accelerator sharing, AVEC-style); each spoke gets its
    own named link so drift can be injected per edge, with latency
    staggered a little per spoke so latency-weighted dispatch has a real
    gradient to exploit.  ``batching=True`` declares every edge a fused-
    launch tier, with its batch model roofline-calibrated against
    ``comp`` (default: the paper workload) — the cost engine then prices
    occupancy by batch amortization instead of processor sharing, and
    the fleet simulator serves it with a ``BatchingSlotServer``."""
    model = edge_batch_model(comp=comp) if batching else None
    spokes = []
    for i in range(num_edges):
        tier = dataclasses.replace(
            EDGE_GPU,
            name=f"{EDGE_GPU.name}_{i}",
            capacity=edge_capacity,
            batching=batching,
            batch_overhead=model.launch_overhead if batching else 0.0,
            batch_marginal=(
                model.marginal_fraction if batching else EDGE_GPU.batch_marginal
            ),
        )
        link = Link(
            name=f"{base_link.name}_{i}",
            bandwidth=base_link.bandwidth,
            latency=base_link.latency * (1.0 + 0.15 * i),
            jitter=base_link.jitter,
        )
        spokes.append((f"edge_{i}", tier, link))
    return Topology.star(
        ("client", client_tier),
        spokes,
        wrapper=WrapperModel(
            call_overhead=0.2e-3,
            serialization_bandwidth=2e9,
            jni_bandwidth=8e9,
        ),
    )


def shared_cell_star(
    num_edges: int = 2,
    edge_capacity: int = 4,
    client_tier: Tier = THIN_CLIENT_NO_GPU,
    base_link: Link = links.FIVE_G_EDGE,
    batching: bool = False,
    comp: "StagedComputation" = None,
    cell: str = "cell0",
    cell_capacity: int = 1,
) -> Topology:
    """A :func:`fleet_star` whose spokes share one radio medium.

    Topologically identical to ``fleet_star`` — same tiers, same
    per-spoke links, same staggered latencies — except every spoke
    declares ``medium=cell`` with ``cell_capacity`` concurrent
    transmissions: all clients' wire legs contend for the same 5G cell
    (or backhaul) instead of each owning a private pipe.
    ``cell_capacity=0`` is the unlimited off-switch — the fleet engines
    are then bit-for-bit the private-spoke ``fleet_star`` run (golden-
    tested in tests/test_contention.py)."""
    topo = fleet_star(
        num_edges=num_edges,
        edge_capacity=edge_capacity,
        client_tier=client_tier,
        base_link=base_link,
        batching=batching,
        comp=comp,
    )
    shared_links = {
        pair: dataclasses.replace(
            link, medium=cell, medium_capacity=cell_capacity
        )
        for pair, link in topo.links.items()
    }
    return Topology(
        tiers=dict(topo.tiers),
        links=shared_links,
        home=topo.home,
        wrapper=topo.wrapper,
        wrapped=topo.wrapped,
    )


def hetero_fleet_star(
    num_edges: int = 64,
    edge_capacity: int = 8,
    client_classes=CLIENT_CLASSES,
    base_link: Link = links.FIVE_G_EDGE,
    batching: bool = False,
):
    """A :func:`fleet_star` sized for 10k-client open-loop sweeps, plus
    the heterogeneous client-class mix to run against it.

    Returns ``(topo, client_classes)`` — pass the classes straight to
    ``run_fleet(client_classes=...)`` / ``capacity_sweep``.  The star's
    nominal home tier is the weakest class (the vantage-point hub);
    each client plans against its own class via the per-client home-
    tier substitution in ``dispatch.edge_subtopology``."""
    topo = fleet_star(
        num_edges=num_edges,
        edge_capacity=edge_capacity,
        client_tier=client_classes[0],
        base_link=base_link,
        batching=batching,
    )
    return topo, tuple(client_classes)


def doctor_star(
    num_edges: int = 3,
    edge_capacity: int = 2,
    cell: str = "cell0",
    cell_capacity: int = 2,
):
    """The canonical "fleet doctor" scenario: a heterogeneous 3-edge
    batching star whose spokes all share one 5G cell.

    This is :func:`hetero_fleet_star` (CI-sized) with every spoke
    declared ``medium=cell`` — the shape ``fleet_bench --doctor`` and
    the SLO fault-injection harness (``cluster.slo.FAULTS``) are tuned
    against: edges ``edge_0..2``, spokes ``5g_edge_0..2``, medium
    ``cell0``.  Returns ``(topo, client_classes)`` like
    ``hetero_fleet_star``."""
    topo, classes = hetero_fleet_star(
        num_edges=num_edges, edge_capacity=edge_capacity, batching=True
    )
    shared_links = {
        pair: dataclasses.replace(
            link, medium=cell, medium_capacity=cell_capacity
        )
        for pair, link in topo.links.items()
    }
    return (
        Topology(
            tiers=dict(topo.tiers),
            links=shared_links,
            home=topo.home,
            wrapper=topo.wrapper,
            wrapped=topo.wrapped,
        ),
        classes,
    )


def hotspot_star(
    num_edges: int = 3,
    edge_capacity: int = 2,
    weak_factor: float = 8.0,
    client_tier: Tier = THIN_CLIENT_NO_GPU,
    base_link: Link = links.GIGABIT_ETHERNET,
    batching: bool = False,
) -> Topology:
    """The asymmetric-load star: ``edge_0`` is a ``weak_factor``-slower
    box (an older card racked at that site), everything else matches
    :func:`fleet_star`.

    Load-blind dispatch (round-robin, join-the-shortest-queue) stripes
    clients evenly, so the weak edge saturates first — the hotspot — and
    its clients drop frames while the strong edges idle.  Static
    placement can only re-plan in place; live migration
    (``cluster.migration``) drains the hotspot toward the strong edges
    until the predicted per-frame times equalize.  The wired default
    link keeps the scenario service-bound (the regime where placement,
    not the network, is the binding constraint)."""
    topo = fleet_star(
        num_edges=num_edges,
        edge_capacity=edge_capacity,
        client_tier=client_tier,
        base_link=base_link,
        batching=batching,
    )
    weak = dataclasses.replace(
        topo.tier("edge_0"),
        name=f"{EDGE_GPU.name}_0_weak",
        accel_flops=EDGE_GPU.accel_flops / weak_factor,
    )
    tiers = dict(topo.tiers)
    tiers["edge_0"] = weak
    return Topology(
        tiers=tiers,
        links=dict(topo.links),
        home=topo.home,
        wrapper=topo.wrapper,
        wrapped=topo.wrapped,
    )


def three_tier_environment(device: Tier = THIN_CLIENT_NO_GPU) -> Topology:
    """device -> edge GPU -> cloud TPU chain (the multi-machine scaling
    the paper flags as future work).

    The plan lattice is 3^n, so AUTO routes long pipelines through the
    chain-DP planner; the interesting trade is that the edge tier costs
    one 5G hop while the cloud pod costs 5G + DCN but computes ~2x
    faster."""
    return Topology.chain(
        (("device", device), ("edge", EDGE_GPU), ("cloud", TPU_V5E)),
        (links.FIVE_G_EDGE, links.DCN),
        # datacenter-grade marshalling: the local staging path must stay
        # faster than remote serialization (zero-copy host buffers)
        wrapper=WrapperModel(
            call_overhead=0.2e-3,
            serialization_bandwidth=2e9,
            jni_bandwidth=8e9,
        ),
    )
