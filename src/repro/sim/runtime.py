"""The edge serving simulator: the paper's experiments, end to end.

Two fidelities:

* ``analytic_run`` — pure cost-model playback: per-frame loop times are
  drawn from the offload plan (resampling the exact latency legs the
  cost engine recorded, so link jitter is reproduced leg-for-leg), fed
  through the Fig. 3 frame-drop accounting. Generates Fig. 4 / Fig. 5.

* ``executed_run`` — *actually executes* the JAX tracker on a synthetic
  RGBD sequence while charging simulated time for network/wrapper legs.
  Tracker output is bit-exact w.r.t. local execution (the data never
  really leaves the host); the clock reflects the modeled deployment.
  This couples frame drops to tracking quality: dropped frames widen the
  inter-frame motion the PSO must cover, exactly the degradation path the
  paper describes.

Both fidelities accept either the two-tier ``Environment`` shim or a
full multi-tier ``Topology`` — placement and cost arithmetic live in
``core.costengine`` either way.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import handmodel, offload, tracker
from repro.core.offload import PlanReport, Policy, Topology
from repro.core.stages import StagedComputation
from repro.sim.clock import FrameLoop, LoopStats

EnvironmentLike = offload.EnvironmentLike


@dataclasses.dataclass
class SimResult:
    stats: LoopStats
    plan: PlanReport
    policy: Policy
    network: str
    granularity: str

    @property
    def fps(self) -> float:
        """Sustainable loop rate 1/loop_time — the paper's Fig. 4/5 metric
        (the server's native rate exceeds the camera's 30 Hz, so the
        figures report the loop rate, not camera-capped throughput)."""
        lt = self.stats.mean_loop_time
        return 1.0 / lt if lt > 0 else 0.0

    @property
    def camera_capped_fps(self) -> float:
        """Frames actually processed per second against a 30 Hz camera."""
        return self.stats.achieved_fps


def _network_name(env: EnvironmentLike) -> str:
    """Label for reports: the shim's link name, or the topology's links."""
    if isinstance(env, Topology):
        return "+".join(l.name for l in env.links.values())
    return env.link.name


def analytic_run(
    comp: StagedComputation,
    env: EnvironmentLike,
    policy: Policy,
    granularity: str = "single_step",
    num_frames: int = 300,
    seed: int = 0,
) -> SimResult:
    """Cost-model playback of one experimental configuration."""
    if granularity == "single_step":
        comp_used = comp.fused()
    elif granularity == "multi_step":
        comp_used = comp
    else:
        raise ValueError(granularity)
    rep = offload.plan(comp_used, env, policy)
    rng = np.random.default_rng(seed)
    loop = FrameLoop()
    stats = loop.run(
        lambda i, gap: rep.jittered_total(rng), num_frames
    )
    return SimResult(stats, rep, policy, _network_name(env), granularity)


@dataclasses.dataclass
class TrackingResult:
    sim: SimResult
    mean_pos_error: float  # meters, over processed frames
    mean_angle_error: float  # radians
    track_lost_frames: int  # frames with pos error > 5 cm


def executed_run(
    cfg: tracker.TrackerConfig,
    env: EnvironmentLike,
    policy: Policy,
    depth_frames: jnp.ndarray,  # (T, H, W) observed depth sequence
    truth: jnp.ndarray,  # (T, 27) ground-truth configurations
    granularity: str = "single_step",
    seed: int = 0,
    timing_comp: Optional[StagedComputation] = None,
) -> TrackingResult:
    """Execute the tracker under simulated deployment conditions.

    The frame-drop accounting decides *which* frames get processed; the
    tracker then really processes exactly those frames, so slow loops
    degrade quality through the physics of the sequence, not through a
    fudge factor.

    ``timing_comp`` lets the clock charge a different (e.g. paper-scale)
    workload than the one executed — examples run a reduced-resolution
    tracker for CPU tractability while the simulated deployment charges
    the full workload the tiers were calibrated against.
    """
    comp = timing_comp or tracker.build_staged(cfg)
    comp_used = comp.fused() if granularity == "single_step" else comp
    rep = offload.plan(comp_used, env, policy)
    rng = np.random.default_rng(seed)

    loop = FrameLoop()
    stats = loop.run(
        lambda i, gap: rep.jittered_total(rng),
        int(depth_frames.shape[0]),
    )

    step = tracker.make_track_frame(cfg)
    key = jax.random.PRNGKey(seed)
    h = truth[0]
    pos_errs: List[float] = []
    ang_errs: List[float] = []
    lost = 0
    for ev in stats.processed:
        key, sub = jax.random.split(key)
        h, _ = step(sub, h, depth_frames[ev.index])
        gt = truth[ev.index]
        pe = float(jnp.linalg.norm(h[:3] - gt[:3]))
        ae = float(jnp.mean(jnp.abs(h[7:] - gt[7:])))
        pos_errs.append(pe)
        ang_errs.append(ae)
        if pe > 0.05:
            lost += 1
    sim = SimResult(stats, rep, policy, _network_name(env), granularity)
    return TrackingResult(
        sim=sim,
        mean_pos_error=float(np.mean(pos_errs)) if pos_errs else float("nan"),
        mean_angle_error=float(np.mean(ang_errs)) if ang_errs else float("nan"),
        track_lost_frames=lost,
    )


def experiment_grid(
    comp: StagedComputation,
    environments: Dict[str, EnvironmentLike],
    policies: Tuple[Policy, ...] = (Policy.FORCED, Policy.AUTO),
    granularities: Tuple[str, ...] = ("single_step", "multi_step"),
    num_frames: int = 300,
) -> List[SimResult]:
    """The full Fig. 5 grid: networks x policies x granularities."""
    out = []
    for net_name, env in environments.items():
        for pol in policies:
            for gran in granularities:
                out.append(
                    analytic_run(comp, env, pol, gran, num_frames)
                )
    return out
