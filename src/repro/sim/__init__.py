"""Deployment simulation: hardware tiers, real-time clock, edge runtime."""

from repro.sim import clock, hardware, runtime  # noqa: F401
