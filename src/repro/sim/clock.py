"""Simulated real-time clock and frame-drop accounting (paper Fig. 3).

"A real-time framerate of 30 fps means that every frame acquired by a
camera has to be consumed/processed in less than 33 milliseconds" — and
because the tracker has a serial frame dependency (category A in Fig. 3),
a loop slower than the acquisition period forces frames to be *dropped*:
"for a hypothetical slower 150 ms processing loop time, the system must
skip processing two consecutive frames for each received frame".

``FrameLoop`` replays exactly that accounting: frames arrive on a fixed
period; the client is busy for each frame's loop time; frames that arrive
while busy are discarded except the most recent one (the tracker always
wants the freshest observation). It reports achieved fps, drop counts and
the *gap* distribution — the number of acquisition periods between
consecutively processed frames, which is what widens the PSO search space
and degrades tracking under slow loops.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, List, Optional

CAMERA_FPS = 30.0
FRAME_PERIOD = 1.0 / CAMERA_FPS
FRAME_BUDGET = FRAME_PERIOD  # the 33 ms real-time budget


@dataclasses.dataclass
class FrameEvent:
    index: int  # camera frame index
    arrival: float  # arrival wall-clock time
    start: float  # processing start
    finish: float  # processing finish
    gap: int  # camera periods since the previously processed frame


@dataclasses.dataclass
class LoopStats:
    processed: List[FrameEvent]
    total_frames: int
    duration: float

    @property
    def achieved_fps(self) -> float:
        if not self.processed or self.duration <= 0:
            return 0.0
        return len(self.processed) / self.duration

    @property
    def dropped(self) -> int:
        return self.total_frames - len(self.processed)

    @property
    def drop_rate(self) -> float:
        return self.dropped / max(self.total_frames, 1)

    @property
    def mean_gap(self) -> float:
        gaps = [e.gap for e in self.processed[1:]]
        return sum(gaps) / len(gaps) if gaps else 1.0

    def loop_times(self) -> List[float]:
        """Per-processed-frame loop times (finish - start).  A method
        rather than inline comprehensions at the call sites so array-
        backed stats (``fastfleet.ArrayLoopStats``) can compute them
        without materializing ``FrameEvent`` objects."""
        return [e.finish - e.start for e in self.processed]

    @property
    def mean_loop_time(self) -> float:
        times = self.loop_times()
        return sum(times) / len(times) if times else 0.0

    @property
    def realtime(self) -> bool:
        return self.mean_loop_time <= FRAME_BUDGET


class FrameLoop:
    """Drive a serially-dependent per-frame step against a 30 Hz camera.

    ``loop_time_fn(frame_index, gap) -> seconds`` supplies the processing
    time of each frame (from the offload cost model, possibly jittered;
    the ``gap`` argument lets callers model search-space widening after
    drops — a larger gap needs a larger optimization budget).
    """

    def __init__(self, camera_fps: float = CAMERA_FPS):
        self.period = 1.0 / camera_fps

    def run(
        self,
        loop_time_fn: Callable[[int, int], float],
        num_frames: int,
    ) -> LoopStats:
        events: List[FrameEvent] = []
        t = 0.0  # client free at time t
        last_processed = -1
        i = 0
        while i < num_frames:
            arrival = i * self.period
            start = max(arrival, t)
            # Frames arriving while busy are superseded: jump to the
            # newest frame available at `start`.
            newest = min(int(start / self.period), num_frames - 1)
            if newest > i:
                i = newest
                arrival = i * self.period
                start = max(arrival, t)
            gap = i - last_processed
            loop_time = loop_time_fn(i, gap)
            finish = start + loop_time
            events.append(FrameEvent(i, arrival, start, finish, gap))
            last_processed = i
            t = finish
            i += 1
        duration = events[-1].finish if events else 0.0
        return LoopStats(events, num_frames, duration)
