"""Plan caching and drift-triggered incremental re-planning.

RAPID (the paper's decision engine) re-evaluates its offloading decision
continuously; re-planning from scratch per client per frame is exactly
what does not scale to a fleet.  Two pieces fix that:

* :class:`PlanCache` — memoizes ``offload.plan`` results keyed by
  (stage signature, topology fingerprint, policy, planner).  Every
  client of the same hardware class talking to the same edge over the
  same link conditions shares one cached ``PlanReport`` — a fleet of N
  identical thin clients costs O(num_edges) plans, not O(N).  A hit
  returns the stored report object itself, so it is bit-identical by
  construction.

* :class:`DriftDetector` — per (client, link) rolling means of the leg
  latencies each request actually observed, compared against the leg
  latencies the client's plan charged.  When the observed mean deviates
  beyond ``threshold`` (relative), only that client re-plans — against
  the *current* link conditions, which changes the topology fingerprint
  and therefore misses into a fresh cache entry.  Unaffected clients
  keep hitting their existing plans.
"""

from __future__ import annotations

import collections
import dataclasses
import weakref
from typing import Deque, Dict, Optional, Tuple

from repro.core import offload
from repro.core.costengine import PlanReport
from repro.core.offload import Policy, Topology
from repro.core.stages import StagedComputation


def comp_signature(comp: StagedComputation) -> Tuple:
    """Hashable identity of a staged computation's cost-relevant fields."""
    return (
        comp.name,
        tuple((i.name, i.nbytes, i.origin) for i in comp.sources),
        tuple(
            (
                s.name,
                s.flops,
                s.parallel_fraction,
                s.inputs,
                tuple((o.name, o.nbytes, o.origin) for o in s.outputs),
                # appended LAST so positional consumers of older
                # signature tuples stay valid (see invalidate_link)
                s.exec_prob,
            )
            for s in comp.stages
        ),
        comp.results,
    )


# id-indexed memo for comp_signature: the fleet calls PlanCache.key with
# the SAME StagedComputation object millions of times (every replan,
# every migration probe), and walking the stage tuples each time
# dominates the lookup.  Keyed by id() with a weakref guard so a
# recycled id can never alias a dead computation's signature.
_SIG_MEMO: Dict[int, Tuple[object, Tuple]] = {}


def cached_comp_signature(comp: StagedComputation) -> Tuple:
    """``comp_signature`` with an id-indexed fast path for repeat calls
    on the same live object (the fleet hot loop's case)."""
    entry = _SIG_MEMO.get(id(comp))
    if entry is not None and entry[0]() is comp:
        return entry[1]
    sig = comp_signature(comp)
    try:
        ref = weakref.ref(comp)
    except TypeError:
        return sig
    _SIG_MEMO[id(comp)] = (ref, sig)
    return sig


def topology_fingerprint(topo: Topology) -> Tuple:
    """Hashable identity of everything the cost engine reads from a
    topology — tiers, links (including current latency/jitter), wrapper,
    home, wrapped.  Link drift changes the fingerprint, which is what
    makes re-planning after drift a cache *miss* by construction."""
    tiers = tuple(
        (
            pname,
            t.name,
            t.accel_flops,
            t.scalar_flops,
            t.dispatch_overhead,
            t.has_accelerator,
            t.capacity,
            t.batching,
            t.batch_overhead,
            t.batch_marginal,
        )
        for pname, t in topo.tiers.items()
    )
    links = tuple(
        # shared-medium fields ride at the END so positional consumers
        # (invalidate_link reads entry[2] == link name) stay valid
        (a, b, l.name, l.bandwidth, l.latency, l.jitter, l.medium,
         l.medium_capacity)
        for (a, b), l in topo.links.items()
    )
    w = topo.wrapper
    return (
        tiers,
        links,
        topo.home,
        topo.wrapped,
        (w.call_overhead, w.serialization_bandwidth, w.jni_bandwidth),
    )


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    invalidations: int = 0

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0


class PlanCache:
    """Memoized ``offload.plan`` keyed by computation + topology identity."""

    def __init__(self) -> None:
        self._plans: Dict[Tuple, PlanReport] = {}
        self.stats = CacheStats()
        # optional telemetry hook ``fn(kind, n=1)`` fired alongside
        # ``stats`` (kinds: "hit" / "miss" / "invalidation"); None (the
        # default) is a no-op — see repro.cluster.telemetry
        self.on_event = None

    def __len__(self) -> int:
        return len(self._plans)

    @staticmethod
    def key(
        comp: StagedComputation,
        topo: Topology,
        policy: Policy,
        planner: Optional[str] = None,
        codec=None,
    ) -> Tuple:
        # the codec operating point is part of a plan's identity: a
        # frozen flat-field CodecModel hashes directly, so clients at
        # the same point share one plan and a rate-controller switch is
        # a miss by construction
        return (
            cached_comp_signature(comp),
            topology_fingerprint(topo),
            policy.value,
            planner,
            codec,
        )

    def get_or_plan(
        self,
        comp: StagedComputation,
        topo: Topology,
        policy: Policy = Policy.AUTO,
        planner: Optional[str] = None,
        record_stats: bool = True,
        codec=None,
    ) -> Tuple[PlanReport, bool]:
        """Returns (report, was_hit).  A hit is the stored object itself.

        ``record_stats=False`` keeps the lookup out of ``stats`` (the
        plan is still cached on a miss): the migration controller scores
        every candidate edge once per considered frame, and counting
        those probes would drown the hit-rate signal that measures
        actual per-client planning work."""
        key = self.key(comp, topo, policy, planner, codec)
        cached = self._plans.get(key)
        if cached is not None:
            if record_stats:
                self.stats.hits += 1
                if self.on_event is not None:
                    self.on_event("hit")
            return cached, True
        rep = offload.plan(comp, topo, policy, planner=planner, codec=codec)
        self._plans[key] = rep
        if record_stats:
            self.stats.misses += 1
            if self.on_event is not None:
                self.on_event("miss")
        return rep, False

    def invalidate_link(self, link_name: str) -> int:
        """Drop every cached plan whose topology includes ``link_name``.
        Returns the number of entries removed (hygiene hook for central
        eviction; the drift path usually relies on fingerprint misses)."""
        doomed = [
            key
            for key in self._plans
            if any(entry[2] == link_name for entry in key[1][1])
        ]
        for key in doomed:
            del self._plans[key]
        self.stats.invalidations += len(doomed)
        if doomed and self.on_event is not None:
            self.on_event("invalidation", len(doomed))
        return len(doomed)


class DriftDetector:
    """Flags clients whose observed leg latencies left their plan behind.

    ``observe(client, plan, observed_legs)`` feeds one request's drawn
    per-leg latencies; returns True when, for some link, the rolling
    mean of at least ``min_samples`` draws deviates from the plan's
    charged latency by more than ``threshold`` (relative to the charged
    latency, with an absolute floor to keep zero-latency links sane).
    ``reset(client)`` clears the window after a re-plan so the fresh
    plan is judged on fresh evidence.
    """

    def __init__(
        self,
        threshold: float = 0.5,
        window: int = 16,
        min_samples: int = 8,
        abs_floor: float = 1e-4,
    ):
        self.threshold = threshold
        self.window = window
        self.min_samples = max(1, min_samples)
        self.abs_floor = abs_floor
        self._obs: Dict[Tuple[int, str], Deque[float]] = {}

    def observe(self, client: int, plan: PlanReport, observed) -> bool:
        predicted: Dict[str, float] = {}
        for leg in plan.legs:
            predicted.setdefault(leg.link, leg.latency)
        drifted = False
        for link, draw in observed:
            dq = self._obs.get((client, link))
            if dq is None:
                dq = collections.deque(maxlen=self.window)
                self._obs[(client, link)] = dq
            dq.append(draw)
            if len(dq) < self.min_samples:
                continue
            pred = predicted.get(link)
            if pred is None:
                continue
            mean = sum(dq) / len(dq)
            tol = max(self.threshold * pred, self.abs_floor)
            if abs(mean - pred) > tol:
                drifted = True
        return drifted

    def reset(self, client: int) -> None:
        for key in [k for k in self._obs if k[0] == client]:
            del self._obs[key]
