"""Fleet telemetry: span traces, a metrics registry, and attribution.

The fleet simulator reports aggregate fps / drop / p99, which says
nothing about *where* a millisecond of tail latency went — wire vs
queue wait vs batch gather vs compute vs edge-side decode vs migration
blackout.  This module is the opt-in observability layer for both event
engines (``run_fleet(telemetry=Telemetry())``):

* **Span traces** — every processed frame is decomposed into the spans
  of :data:`SPAN_ORDER`, derived from the exact quantities the engines
  already compute: the plan's cost breakdown
  (``PlanReport.breakdown``), the per-leg jitter draws, and the
  per-visit queue/batch timestamps the slot servers report.  The spans
  of a frame sum *exactly* (bit for bit, left-to-right) to its recorded
  loop time ``finish - start`` — a residual ``"other"`` span absorbs
  float-summation slack and is driven to an exact identity by a short
  fix-point iteration (:func:`exact_spans`).  Traces export as Chrome
  trace-event JSON (:meth:`Telemetry.export_chrome_trace`), viewable in
  Perfetto / ``chrome://tracing``.
* **Metrics registry** — counters, gauges, and fixed-log-bucket
  histograms (:class:`MetricsRegistry`), fed by hooks in ``PlanCache``
  (hit / miss / invalidation), the migration controller (considered /
  rejected-dwell / rejected-threshold / accepted), the codec rate
  controller (ladder transitions, compressed-vs-raw uplink bytes), and
  the slot servers (occupancy timelines, batch-size histograms).
* **Latency attribution** — :meth:`Telemetry.attribution` decomposes
  p50 / p99 loop time per span and per client class;
  ``fleet_bench --trace`` prints the table and gates on engine
  equivalence of the whole trace.

Both engines call the same hooks with bit-identical inputs (that is the
engine-equivalence contract PR 6 established), so an armed ``Telemetry``
records the identical trace on either engine — and ``telemetry=None``
leaves both engines bit-for-bit untouched (every hook site is behind an
``if tel is not None`` guard with no float or RNG side effects).

One ``Telemetry`` instance observes one run; reusing an instance across
runs accumulates counters/histograms (gauges overwrite) and concatenates
traces, which is occasionally useful but rarely what a report wants.
"""

from __future__ import annotations

import json
import math
from bisect import bisect_left
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "SPAN_ORDER",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Telemetry",
    "exact_spans",
]

# Per-frame spans in chronological (and fold) order.  The left-to-right
# float fold of a frame's span tuple equals its loop time exactly:
#   client      home-side work: home compute, home encode/decode, every
#               wrapper cost (envelope, serialization, JNI marshal)
#   uplink      charged uplink-direction propagation + wire time, plus
#               the jitter delta of every uplink-direction leg draw
#   queue-wait  FIFO admission delay (incl. throttle inflation) at
#               non-batching edges
#   batch-gather  gather-window dwell + fused-launch inflation at
#               batching edges
#   decode      edge-side codec work (payload decode + result encode)
#   compute     remote stage compute
#   downlink    downlink-direction propagation/wire + jitter deltas
#   other       float-summation residual (typically < 1 ulp of the
#               loop time; exactness guard, not a physical phase)
SPAN_ORDER: Tuple[str, ...] = (
    "client",
    "uplink",
    "queue-wait",
    "batch-gather",
    "decode",
    "compute",
    "downlink",
    "other",
)

_N_PARTS = len(SPAN_ORDER) - 1  # physical spans, excluding "other"


def exact_spans(parts: Sequence[float], loop: float) -> Tuple[float, ...]:
    """Append a residual so the left-to-right fold equals ``loop`` exactly.

    ``parts`` are the physical span estimates; their float sum differs
    from ``loop`` by accumulated rounding.  Setting
    ``other = loop - sum(parts)`` is usually already exact; when it is
    not, a Newton-style fix-point (``other += loop - fold``) converges
    in a step or two.  If some adversarial rounding pattern defeats
    even that, the degenerate-but-exact answer (everything in
    ``other``) keeps the invariant absolute.
    """
    s = 0.0
    for d in parts:
        s += d
    other = loop - s
    for _ in range(6):
        t = s + other  # == fold(parts + [other]) since fold(parts) == s
        if t == loop:
            return tuple(parts) + (other,)
        other += loop - t
    return (0.0,) * len(parts) + (loop,)


def _pctile(sorted_vals: Sequence[float], q: float) -> float:
    """Percentile by rank (same ceil-rank convention as FleetResult)."""
    if not sorted_vals:
        return 0.0
    n = len(sorted_vals)
    idx = min(n - 1, max(0, math.ceil(q * n) - 1))
    return sorted_vals[idx]


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


class Counter:
    """Monotonic count (ints or exact float byte totals)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, n=1) -> None:
        self.value += n


class Gauge:
    """Last-write-wins scalar."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v


class Histogram:
    """Fixed-log-bucket histogram: bucket k covers
    ``(lo * growth**(k-1), lo * growth**k]``; values <= ``lo`` (including
    zeros/negatives) land in bucket 0, values past the last bound in the
    overflow bucket.  Deterministic and allocation-light: one bisect per
    observation."""

    __slots__ = ("bounds", "counts", "count", "total", "vmin", "vmax")

    def __init__(self, lo: float = 1e-6, growth: float = 2.0, nbuckets: int = 40):
        if lo <= 0.0 or growth <= 1.0 or nbuckets < 2:
            raise ValueError("need lo > 0, growth > 1, nbuckets >= 2")
        self.bounds = [lo * growth**k for k in range(nbuckets)]
        self.counts = [0] * (nbuckets + 1)  # +1 overflow
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def observe(self, v: float) -> None:
        self.counts[bisect_left(self.bounds, v)] += 1
        self.count += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """Upper bucket bound at quantile ``q`` in [0, 1] (0 if empty)."""
        if not self.count:
            return 0.0
        rank = max(1, math.ceil(q * self.count))
        acc = 0
        for k, c in enumerate(self.counts):
            acc += c
            if acc >= rank:
                return self.bounds[min(k, len(self.bounds) - 1)]
        return self.bounds[-1]

    def snapshot(self) -> Dict[str, float]:
        return {
            "count": self.count,
            "mean": self.mean,
            "min": self.vmin if self.count else 0.0,
            "max": self.vmax if self.count else 0.0,
            "p50": self.percentile(0.50),
            "p99": self.percentile(0.99),
        }


class MetricsRegistry:
    """Name -> instrument, created on first touch."""

    __slots__ = ("counters", "gauges", "histograms")

    def __init__(self) -> None:
        self.counters: Dict[str, Counter] = {}
        self.gauges: Dict[str, Gauge] = {}
        self.histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges[name] = Gauge()
        return g

    def histogram(
        self, name: str, lo: float = 1e-6, growth: float = 2.0, nbuckets: int = 40
    ) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(lo, growth, nbuckets)
        return h

    def snapshot(self) -> Dict[str, Dict]:
        """Deterministic (sorted) dump of every instrument."""
        return {
            "counters": {k: self.counters[k].value for k in sorted(self.counters)},
            "gauges": {k: self.gauges[k].value for k in sorted(self.gauges)},
            "histograms": {
                k: self.histograms[k].snapshot() for k in sorted(self.histograms)
            },
        }


# ---------------------------------------------------------------------------
# the telemetry object
# ---------------------------------------------------------------------------


class Telemetry:
    """Per-run observability sink both fleet engines feed.

    Engine-facing hooks (called only when armed; every call site is
    guarded so ``telemetry=None`` stays bit-for-bit golden):

    * :meth:`attach` / :meth:`detach` — wire/unwire the ``PlanCache``
      event hook and the slot servers' ``telemetry`` attribute.
    * :meth:`register_clients` — client index -> hardware-class label.
    * :meth:`register_workloads` — client index -> workload name.
    * :meth:`visit_placed` — one edge-server admission of one visit.
    * :meth:`frame_done` — one processed frame; builds its span tuple.
    * :meth:`migration` — one accepted move (the blackout interval).
    * :meth:`occupancy_sample` / :meth:`wait_sample` /
      :meth:`batch_sample` — slot-server load / imposed queue wait at
      admission / fused-launch batch size.
    * :meth:`count` / :meth:`cache_event` — counter bumps.
    * :meth:`finish_run` — end-of-run rollup from the ``FleetResult``.

    Reporting: :meth:`export_chrome_trace`, :meth:`attribution`,
    :meth:`format_attribution_table`, :meth:`verify_exact`.
    """

    def __init__(self) -> None:
        self.metrics = MetricsRegistry()
        # (client, class, workload, edge, frame_idx, start, fin, spans)
        # per frame, in engine finish-event order
        self.frames: List[
            Tuple[int, str, str, str, int, float, float, Tuple[float, ...]]
        ] = []
        # (client, t0, duration, src_edge, dst_edge) per accepted move
        self.blackouts: List[Tuple[int, float, float, str, str]] = []
        # edge name -> [(t, in_flight at admission)]
        self.occupancy: Dict[str, List[Tuple[float, float]]] = {}
        # edge name -> [(t, queue wait imposed on the admission at t)]
        self.waits: Dict[str, List[Tuple[float, float]]] = {}
        self._client_class: Dict[int, str] = {}
        self._client_workload: Dict[int, str] = {}
        # client -> visits of the in-flight frame:
        # (is_batch, arrived, svc_start, svc_end, solo_service)
        self._pending: Dict[int, List[Tuple[bool, float, float, float, float]]] = {}
        # id(plan) -> (plan, per-plan span bases); plans are interned by
        # the PlanCache so this hits once per distinct plan
        self._plan_base: Dict[int, Tuple[object, Tuple[float, ...]]] = {}

    # -- wiring -------------------------------------------------------------

    def attach(self, cache=None, servers: Iterable = ()) -> None:
        if cache is not None:
            cache.on_event = self.cache_event
        for sv in servers:
            sv.telemetry = self

    def detach(self, cache=None, servers: Iterable = ()) -> None:
        if cache is not None and cache.on_event == self.cache_event:
            cache.on_event = None
        for sv in servers:
            sv.telemetry = None

    def register_clients(self, classes: Dict[int, str]) -> None:
        self._client_class.update(classes)

    def register_workloads(self, workloads: Dict[int, str]) -> None:
        """Client index -> workload (pipeline) name, for per-workload
        attribution; both engines register before the first frame."""
        self._client_workload.update(workloads)

    # -- engine hooks -------------------------------------------------------

    def count(self, name: str, n=1) -> None:
        self.metrics.counter(name).inc(n)

    def cache_event(self, kind: str, n=1) -> None:
        """PlanCache hook target (kind in hit / miss / invalidation)."""
        self.metrics.counter(f"plancache.{kind}").inc(n)

    def occupancy_sample(self, edge: str, t: float, load: float) -> None:
        samples = self.occupancy.get(edge)
        if samples is None:
            samples = self.occupancy[edge] = []
        samples.append((t, load))

    def wait_sample(self, edge: str, t: float, wait: float) -> None:
        """One admission's imposed queue wait at ``edge`` (seconds) —
        the per-edge localization signal the SLO doctor's root-cause
        attributor reads (``repro.cluster.slo``)."""
        samples = self.waits.get(edge)
        if samples is None:
            samples = self.waits[edge] = []
        samples.append((t, wait))

    def batch_sample(self, edge: str, size: int) -> None:
        self.metrics.histogram("batch.size", lo=1.0, growth=2.0, nbuckets=16).observe(
            size
        )
        self.metrics.histogram(
            f"batch.size.{edge}", lo=1.0, growth=2.0, nbuckets=16
        ).observe(size)

    def visit_placed(
        self,
        client: int,
        is_batch: bool,
        arrived: float,
        svc_start: float,
        svc_end: float,
        service: float,
    ) -> None:
        pend = self._pending.get(client)
        if pend is None:
            pend = self._pending[client] = []
        pend.append((is_batch, arrived, svc_start, svc_end, service))

    def migration(
        self, client: int, t0: float, duration: float, src: str, dst: str
    ) -> None:
        self.blackouts.append((client, t0, duration, src, dst))
        self.metrics.counter("migration.moves").inc()
        self.metrics.histogram("migration.blackout_s").observe(duration)

    def _bases(self, plan) -> Tuple[float, ...]:
        """Per-plan span bases (client, uplink, downlink, decode,
        compute, raw_up) from the cost-engine breakdown — cached per
        plan object since plans are cache-interned."""
        key = id(plan)
        hit = self._plan_base.get(key)
        if hit is not None:
            return hit[1]
        bd = dict(plan.breakdown)
        g = bd.get
        base = (
            # client: all home-side work incl. every wrapper cost
            g("compute_home", 0.0)
            + g("encode_home", 0.0)
            + g("decode_home", 0.0)
            + g("wrapper", 0.0),
            g("lat_up", 0.0) + g("wire_up", 0.0),  # uplink (charged)
            g("lat_down", 0.0) + g("wire_down", 0.0),  # downlink (charged)
            g("decode_remote", 0.0) + g("encode_remote", 0.0),  # edge codec
            g("compute_remote", 0.0),  # remote stage compute
            g("raw_bytes_up", 0.0),  # pre-codec uplink bytes
        )
        self._plan_base[key] = (plan, base)
        return base

    def frame_done(
        self,
        client: int,
        frame_idx: int,
        edge: str,
        start: float,
        fin: float,
        plan,
        draws: Tuple[float, ...],
        link_wait: float = 0.0,
    ) -> None:
        """Build the span tuple of one processed frame.

        ``draws`` are the frame's per-leg latency samples in
        ``plan.legs`` order (empty when the plan has no legs); both
        engines pass bit-identical floats, so the resulting spans are
        engine-independent by construction.  ``link_wait`` is the
        frame's shared-medium queue delay (contended cell / backhaul);
        it is attributed to the uplink span — that is where the client
        experiences it — and is 0.0 on private spokes.
        """
        client_b, up_b, down_b, dec_b, comp_b, raw_up = self._bases(plan)
        # jitter deltas: each leg's draw replaces its charged latency
        if draws:
            legs = plan.legs
            down_flags = plan.leg_down
            du = 0.0
            dd = 0.0
            for j, draw in enumerate(draws):
                delta = draw - legs[j].latency
                if legs[j].weight != 1.0:
                    # probabilistic leg: the loop total only felt the
                    # expectation-weighted share of this draw
                    delta = legs[j].weight * delta
                if down_flags[j]:
                    dd += delta
                else:
                    du += delta
            up = up_b + du
            down = down_b + dd
        else:
            up = up_b
            down = down_b
        if link_wait:
            up = up + link_wait
        # queue wait (FIFO, incl. throttle inflation) vs gather dwell +
        # fused-launch inflation (batching edges)
        q_w = 0.0
        g_w = 0.0
        pend = self._pending.pop(client, None)
        if pend:
            for is_batch, arrived, s0, s1, svc in pend:
                w = (s0 - arrived) + (s1 - (s0 + svc))
                if is_batch:
                    g_w += w
                else:
                    q_w += w
        loop = fin - start
        spans = exact_spans((client_b, up, q_w, g_w, dec_b, comp_b, down), loop)
        self.frames.append(
            (
                client,
                self._client_class.get(client, "?"),
                self._client_workload.get(client, "?"),
                edge,
                frame_idx,
                start,
                fin,
                spans,
            )
        )
        m = self.metrics
        m.histogram("frame.loop_s").observe(loop)
        for name, d in zip(SPAN_ORDER, spans):
            m.histogram(f"span.{name}_s").observe(d)
        m.counter("codec.uplink_wire_bytes").inc(plan.uplink_bytes)
        m.counter("codec.uplink_raw_bytes").inc(int(raw_up))

    def finish_run(self, result, rates: Optional[Sequence] = None) -> None:
        """End-of-run rollup: migration decision accounting, re-plan
        scope, codec ladder transitions, per-edge load gauges."""
        m = self.metrics
        mig = result.migration
        if mig is not None:
            m.counter("migration.considered").inc(mig.considered)
            m.counter("migration.rejected_dwell").inc(mig.rejected_dwell)
            m.counter("migration.rejected_threshold").inc(mig.rejected_threshold)
            m.counter("migration.accepted").inc(mig.count)
        replanned = 0
        replans = 0
        for c in result.clients:
            replans += c.replans
            if c.replans:
                replanned += 1
        m.counter("plan.replans").inc(replans)
        m.gauge("drift.clients_replanned").set(replanned)
        if rates:
            switches = 0
            for r in rates:
                if r is None:
                    continue
                switches += r.switches
                for _, old_bits, new_bits in r.transitions:
                    m.counter(f"codec.transition.q{old_bits}->q{new_bits}").inc()
            m.counter("codec.switches").inc(switches)
        for e in result.edges:
            m.gauge(f"edge.peak_load.{e.name}").set(e.peak_load)
            m.gauge(f"edge.busy_s.{e.name}").set(e.busy_time)
            m.gauge(f"edge.admitted.{e.name}").set(e.admitted)
        for lk in getattr(result, "links", ()) or ():
            m.gauge(f"link.busy_s.{lk.name}").set(lk.busy_time)
            m.gauge(f"link.admitted.{lk.name}").set(lk.admitted)
            m.gauge(f"link.contended.{lk.name}").set(lk.contended)
            m.gauge(f"link.total_wait_s.{lk.name}").set(lk.total_wait)

    # -- verification -------------------------------------------------------

    def verify_exact(self) -> int:
        """Assert every frame's span fold equals its loop time exactly;
        returns the number of frames checked."""
        for client, _cls, _wl, _edge, idx, start, fin, spans in self.frames:
            t = 0.0
            for d in spans:
                t += d
            if t != fin - start:
                raise AssertionError(
                    f"span sum {t!r} != loop {fin - start!r} "
                    f"(client {client}, frame {idx})"
                )
        return len(self.frames)

    # -- reporting ----------------------------------------------------------

    def export_chrome_trace(self, path: Optional[str] = None) -> Dict:
        """Chrome trace-event JSON (Perfetto / chrome://tracing).

        One track (tid) per client; each processed frame renders its
        spans as back-to-back complete ("X") events, each accepted
        migration as a ``migration-blackout`` event, and each edge's
        admission-time occupancy as a counter ("C") series.  Times are
        microseconds.  Spans with non-positive width (jitter deltas can
        drive a span slightly negative; "other" is a rounding residual)
        are kept in the data model but skipped for display.
        """
        events: List[Dict] = []
        events.append(
            {"name": "process_name", "ph": "M", "pid": 0, "args": {"name": "fleet"}}
        )
        for c in sorted(self._client_class):
            events.append(
                {
                    "name": "thread_name",
                    "ph": "M",
                    "pid": 0,
                    "tid": c,
                    "args": {"name": f"client {c} ({self._client_class[c]})"},
                }
            )
        for client, _cls, _wl, edge, idx, start, _fin, spans in self.frames:
            ts = start * 1e6
            for name, d in zip(SPAN_ORDER, spans):
                if d > 0.0:
                    events.append(
                        {
                            "name": name,
                            "ph": "X",
                            "ts": ts,
                            "dur": d * 1e6,
                            "pid": 0,
                            "tid": client,
                            "args": {"frame": idx, "edge": edge},
                        }
                    )
                    ts += d * 1e6
        for client, t0, dur, src, dst in self.blackouts:
            events.append(
                {
                    "name": "migration-blackout",
                    "ph": "X",
                    "ts": t0 * 1e6,
                    "dur": dur * 1e6,
                    "pid": 0,
                    "tid": client,
                    "args": {"src": src, "dst": dst},
                }
            )
        for edge in sorted(self.occupancy):
            for t, load in self.occupancy[edge]:
                events.append(
                    {
                        "name": f"occupancy {edge}",
                        "ph": "C",
                        "ts": t * 1e6,
                        "pid": 0,
                        "args": {"in_flight": load},
                    }
                )
        doc = {"traceEvents": events, "displayTimeUnit": "ms"}
        if path is not None:
            with open(path, "w") as fh:
                json.dump(doc, fh)
        return doc

    def attribution(self) -> Dict[str, Dict]:
        """Latency attribution per client class and per workload (plus
        ``"all"``).

        For each group: frame count, loop p50/p99, and per span its
        total share of loop time, mean, p50, p99, and its mean over the
        slowest 1% of frames (``tail_mean`` — where did the p99 go?).
        Workload groups are keyed ``wl:<name>`` to keep them disjoint
        from hardware-class labels; a dimension with a single distinct
        value collapses into ``"all"`` (which already tells its story).
        """
        groups: Dict[str, List[Tuple[float, Tuple[float, ...]]]] = {"all": []}
        cls_keys: List[str] = []
        wl_keys: List[str] = []
        for _c, cls, wl, _edge, _idx, start, fin, spans in self.frames:
            rec = (fin - start, spans)
            groups["all"].append(rec)
            if cls not in groups:
                cls_keys.append(cls)
            groups.setdefault(cls, []).append(rec)
            wk = f"wl:{wl}"
            if wk not in groups:
                wl_keys.append(wk)
            groups.setdefault(wk, []).append(rec)
        # a dimension with one distinct value duplicates "all" — drop it
        if len(cls_keys) == 1:
            del groups[cls_keys[0]]
        if len(wl_keys) == 1:
            del groups[wl_keys[0]]
        out: Dict[str, Dict] = {}
        for cls in sorted(groups, key=lambda k: (k != "all", k)):
            recs = groups[cls]
            loops = sorted(r[0] for r in recs)
            loop_total = sum(loops)
            p99 = _pctile(loops, 0.99)
            tail = [r for r in recs if r[0] >= p99] or recs
            spans_out = {}
            for k, name in enumerate(SPAN_ORDER):
                vals = sorted(r[1][k] for r in recs)
                total = sum(vals)
                spans_out[name] = {
                    "total_s": total,
                    "share": total / loop_total if loop_total else 0.0,
                    "mean_ms": 1e3 * total / len(vals) if vals else 0.0,
                    "p50_ms": 1e3 * _pctile(vals, 0.50),
                    "p99_ms": 1e3 * _pctile(vals, 0.99),
                    "tail_mean_ms": 1e3 * sum(r[1][k] for r in tail) / len(tail),
                }
            out[cls] = {
                "frames": len(recs),
                "loop_p50_ms": 1e3 * _pctile(loops, 0.50),
                "loop_p99_ms": 1e3 * p99,
                "spans": spans_out,
            }
        return out

    def format_attribution_table(self) -> str:
        """The ``fleet_bench --trace`` report as a plain-text table."""
        att = self.attribution()
        lines: List[str] = []
        for cls, rep in att.items():
            lines.append(
                f"== latency attribution [{cls}] — {rep['frames']} frames, "
                f"loop p50 {rep['loop_p50_ms']:.3f} ms / "
                f"p99 {rep['loop_p99_ms']:.3f} ms =="
            )
            lines.append(
                f"  {'span':<14}{'share':>8}{'mean_ms':>10}{'p50_ms':>10}"
                f"{'p99_ms':>10}{'tail_ms':>10}"
            )
            for name in SPAN_ORDER:
                s = rep["spans"][name]
                lines.append(
                    f"  {name:<14}{100 * s['share']:>7.2f}%{s['mean_ms']:>10.3f}"
                    f"{s['p50_ms']:>10.3f}{s['p99_ms']:>10.3f}"
                    f"{s['tail_mean_ms']:>10.3f}"
                )
        if self.blackouts:
            durs = [b[2] for b in self.blackouts]
            lines.append(
                f"  migration-blackout: {len(durs)} moves, "
                f"mean {1e3 * sum(durs) / len(durs):.3f} ms "
                f"(inter-frame: delays the next start, outside loop time)"
            )
        return "\n".join(lines)
