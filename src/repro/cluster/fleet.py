"""Fleet simulation: N thin clients vs a star of contended edge servers.

Each client replays the paper's deployment — a 30 fps camera, a serially
dependent per-frame tracker step, an offload plan priced by the cost
engine — but the edge servers are *shared*: every offloaded stage
occupies a FIFO service slot (``events.SlotServer``) for exactly the
compute time its plan charged to that tier, so queueing delay emerges
from the event interleaving instead of an averaged formula.

Per-request latency is exact: the plan's recorded latency legs are
re-drawn against current link conditions (``events.LinkTable``), which
with undrifted links is bit-identical — in value and rng consumption —
to ``PlanReport.jittered_total``, so a single client against a
capacity-1 edge reproduces ``sim.runtime.analytic_run`` frame-for-frame
(the golden test in tests/test_cluster.py).

The adaptive loop: plans come from a shared ``plancache.PlanCache``
(N identical clients cost O(num_edges) plans); each client's
``plancache.DriftDetector`` watches the leg latencies its requests
actually drew, and when they drift past the threshold only that client
re-plans, against the drifted link — a cache miss by fingerprint,
leaving every other client's cached plan untouched.  With a
``migration.MigrationConfig`` armed, re-planning escalates to
*re-dispatch*: the client can move to a different edge mid-run, paying
a priced pose + swarm state transfer, under dwell/improvement
hysteresis (see ``cluster/migration.py``).

Timing model per processed frame (documented approximation): all
non-service time — home compute, wrapper, uplink/downlink wire and
latency — is charged *before* the request reaches its first contended
tier; the request then holds one slot per remote tier, in placement
order, until that tier releases it (its solo compute share on FIFO
edges; the fused batch finish on batching edges).  Total frame latency
is therefore ``resampled plan total + sum of queue waits + batch
inflation``, which keeps the uncontended/unbatched case exactly the
analytic model while keeping recorded finishes consistent with the
event timeline under batching.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.cluster.dispatch import (
    DispatchContext,
    edge_subtopology,
    make_dispatch,
)
from repro.cluster.events import (
    AdaptiveWindow,
    BatchingSlotServer,
    EventQueue,
    LinkTable,
    SlotServer,
    build_media,
)
from repro.cluster.migration import (
    MigrationConfig,
    MigrationController,
    MigrationStats,
)
from repro.cluster.plancache import (
    DriftDetector,
    PlanCache,
    topology_fingerprint,
)
from repro.codec.rate import CodecConfig, RateController
from repro.core.costengine import BatchServiceModel, PlanReport
from repro.core.offload import Policy, Topology
from repro.core.stages import StagedComputation
from repro.sim.clock import CAMERA_FPS, FrameEvent, LoopStats


@dataclasses.dataclass(frozen=True)
class LinkDrift:
    """Inject new conditions on one link at a simulated time.

    ``latency``/``jitter`` take effect on every subsequent request draw
    (the per-leg resampling reads the live link table).  ``bandwidth``
    only enters through *re-planning*: wire time is baked into a plan's
    total, so a bandwidth change is invisible until something (e.g. a
    simultaneous latency drift) triggers a re-plan against the updated
    link — at which point the new plan prices the new bandwidth."""

    time: float
    link: str
    latency: Optional[float] = None
    jitter: Optional[float] = None
    bandwidth: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class ServiceDrift:
    """Inject a *service-side* slowdown on one edge at a simulated time
    (thermal throttling, a noisy co-tenant): every service admitted
    from ``time`` on runs ``factor`` times longer.

    Plans cannot see this — their compute terms price the tier's
    nominal rate — and neither can the link drift detector (nothing
    crossed the wire differently).  The inflation lands entirely in
    *measured waits*, which is exactly the signal the migration
    controller's ``wait_ewma_blend`` calibration tracks."""

    time: float
    edge: str
    factor: float


@dataclasses.dataclass
class ClientResult:
    client: int
    edge: str  # final edge assignment (migration moves it over time)
    stats: LoopStats
    plan: PlanReport
    replans: int
    # summed non-plan time over processed frames: queue wait, plus on
    # batching edges gather-window dwell and batch service inflation
    # (EdgeLoad.mean_wait counts only the pre-service part)
    total_wait: float
    migrations: int = 0  # mid-run re-dispatches this client made
    rate_changes: int = 0  # codec operating-point switches this client made
    codec: Optional[object] = None  # final CodecModel (None = raw payloads)

    @property
    def mean_wait(self) -> float:
        n = len(self.stats.processed)
        return self.total_wait / n if n else 0.0


@dataclasses.dataclass
class EdgeLoad:
    name: str
    capacity: int
    clients: int  # clients assigned at the END of the run (post-migration)
    admitted: int
    busy_time: float
    mean_wait: float
    # fused-launch accounting (0 / 0.0 on non-batching edges)
    batches: int = 0
    mean_batch_size: float = 0.0
    peak_load: int = 0  # max concurrent in-flight seen at an admission


@dataclasses.dataclass
class LinkLoad:
    """Occupancy counters of one shared transmission medium
    (``events.SharedLink``): how much wire time it carried and how much
    extra delay contention imposed.  Empty ``FleetResult.links`` means
    the topology declared no shared media (every spoke private)."""

    name: str
    capacity: int  # transmission slots (0 = unlimited)
    admitted: int  # transmissions offered
    contended: int  # transmissions that queued
    busy_time: float  # wire seconds carried
    total_wait: float  # extra delay imposed

    @property
    def mean_wait(self) -> float:
        return self.total_wait / self.admitted if self.admitted else 0.0


@dataclasses.dataclass
class FleetResult:
    clients: List[ClientResult]
    edges: List[EdgeLoad]
    cache: PlanCache
    num_frames: int
    duration: float
    migration: Optional[MigrationStats] = None  # set when migration is armed
    # discrete events the engine processed — the denominator of the
    # events/sec number `fleet_bench --events` reports, and a structural
    # invariant the vectorized engine reproduces exactly
    events: int = 0
    # per-medium occupancy counters, in topology declaration order —
    # byte-identical between engines (engine-equivalence audit)
    links: List[LinkLoad] = dataclasses.field(default_factory=list)

    @property
    def drop_rate(self) -> float:
        total = sum(c.stats.total_frames for c in self.clients)
        dropped = sum(c.stats.dropped for c in self.clients)
        return dropped / total if total else 0.0

    @property
    def mean_achieved_fps(self) -> float:
        if not self.clients:
            return 0.0
        return sum(c.stats.achieved_fps for c in self.clients) / len(self.clients)

    @property
    def mean_loop_time(self) -> float:
        times = self._loop_times()
        return sum(times) / len(times) if times else 0.0

    @property
    def p99_loop_time(self) -> float:
        return self.loop_time_percentile(0.99)

    @property
    def total_replans(self) -> int:
        return sum(c.replans for c in self.clients)

    @property
    def total_migrations(self) -> int:
        return self.migration.count if self.migration is not None else 0

    @property
    def total_rate_changes(self) -> int:
        return sum(c.rate_changes for c in self.clients)

    @property
    def mean_uplink_bytes(self) -> float:
        """Mean per-frame uplink payload across clients' final plans —
        the codec's wire-side footprint (raw frame bytes when off)."""
        if not self.clients:
            return 0.0
        return sum(c.plan.uplink_bytes for c in self.clients) / len(self.clients)

    def _loop_times(self) -> List[float]:
        out: List[float] = []
        for c in self.clients:
            out.extend(c.stats.loop_times())
        return out

    def loop_time_percentile(self, q: float) -> float:
        times = sorted(self._loop_times())
        if not times:
            return 0.0
        idx = min(len(times) - 1, max(0, math.ceil(q * len(times)) - 1))
        return times[idx]


def plan_media(plan: PlanReport, media_of) -> Tuple[tuple, tuple]:
    """Aggregate a plan's per-hop wire seconds onto shared media:
    ``(up, down)`` where each is ``((SharedLink, wire_seconds), ...)``
    per distinct medium the plan's wire legs cross in that direction.
    Empty tuples when the fleet declares no shared media — the private-
    spoke fast path both engines take.  Shared by both engines so the
    aggregation (and its float summation order) cannot diverge."""
    if not media_of:
        return (), ()
    up: Dict[str, list] = {}
    down: Dict[str, list] = {}
    for lname, dwn, w in plan.wire_by_link:
        med = media_of.get(lname)
        if med is None or w <= 0.0:
            continue
        acc = down if dwn else up
        slot = acc.get(med.name)
        if slot is None:
            acc[med.name] = [med, w]
        else:
            slot[1] += w
    return (
        tuple((m, s) for m, s in up.values()),
        tuple((m, s) for m, s in down.values()),
    )


class _Client:
    """One thin client's frame loop, replaying ``sim.clock.FrameLoop``'s
    exact drop/supersede arithmetic against the shared event clock."""

    def __init__(
        self,
        idx: int,
        rng,
        edge: str,
        plan: PlanReport,
        home: str,
        plan_fp,
        rate: Optional[RateController] = None,
        tier=None,
        media_of=None,
        comp: Optional[StagedComputation] = None,
    ):
        self.idx = idx
        self.rng = rng
        self.edge = edge
        self.home = home
        self.tier = tier  # own hardware class (hetero fleets; None = default)
        self.comp = comp  # own workload (mixed fleets; set by run_fleet)
        self.media_of = media_of  # link name -> SharedLink (shared media)
        self.med_wait = 0.0  # shared-medium delay of the in-flight frame
        self.set_plan(plan, plan_fp)
        self.events: List[FrameEvent] = []
        self.t_free = 0.0
        self.last_processed = -1
        self.next_i = 0
        self.replans = 0
        self.migrations = 0
        self.total_wait = 0.0
        self.drifted = False
        self.rate = rate  # per-client codec rate controller (or None)
        self.rate_dirty = False  # operating point changed: re-plan next frame
        self.frames_since_probe = 0
        # in-flight frame: (index, arrival, start, sampled_total, observed)
        self.pending: Optional[Tuple[int, float, float, float, tuple]] = None

    @property
    def codec_model(self):
        """The CodecModel this client's plans are priced under."""
        return self.rate.model if self.rate is not None else None

    def set_plan(self, plan: PlanReport, plan_fp) -> None:
        self.plan = plan
        self.plan_fp = plan_fp  # link conditions the plan was priced under
        self.visits: Tuple[Tuple[str, float], ...] = tuple(
            (tier, t) for tier, t in plan.compute_by_tier if tier != self.home
        )
        self.service_total = sum(t for _, t in self.visits)
        # the wire seconds this plan offers each shared medium per frame
        self.up_media, self.down_media = plan_media(plan, self.media_of)


def run_fleet(
    topo: Topology,
    comp: StagedComputation,
    num_clients: int,
    num_frames: int = 300,
    policy: Policy = Policy.AUTO,
    dispatch: str = "round_robin",
    granularity: str = "single_step",
    planner: Optional[str] = None,
    seed: int = 0,
    camera_fps: float = CAMERA_FPS,
    cache: Optional[PlanCache] = None,
    drifts: Sequence[Union[LinkDrift, ServiceDrift]] = (),
    drift_threshold: float = 0.5,
    drift_window: int = 16,
    drift_min_samples: int = 8,
    probe_every: int = 30,
    batching: Optional[bool] = None,
    gather_window: float = 2e-3,
    migration: Optional[MigrationConfig] = None,
    codec: Optional[CodecConfig] = None,
    engine: str = "object",
    client_classes: Optional[Sequence[object]] = None,
    adaptive_window: Optional[AdaptiveWindow] = None,
    telemetry=None,
    workloads: Optional[Sequence[StagedComputation]] = None,
    slo=None,
) -> FleetResult:
    """Simulate ``num_clients`` identical clients sharing ``topo``'s edges.

    ``topo`` must be a star: every non-home tier one link from home (the
    hub models any one client's vantage point; the edge tiers and their
    service slots are shared across all of them).  Client ``c`` draws
    its request latencies from ``default_rng(seed + c)``, so client 0 of
    a ``seed``-seeded fleet consumes randomness exactly like
    ``analytic_run(..., seed=seed)``.

    A client running a fully-local plan sends nothing over the wire, so
    it cannot *observe* its link recover; every ``probe_every``
    processed frames such a client pings its edge link (compares current
    conditions against the fingerprint its plan was priced under) and
    re-plans on any change — otherwise a drift-then-recover sequence
    would strand it on the slow local plan forever.

    Batching: an edge tier declaring ``batching=True`` is served by a
    :class:`~repro.cluster.events.BatchingSlotServer` — concurrent
    requests arriving within ``gather_window`` fuse into one launch with
    sublinear batch service time (``BatchServiceModel.from_tier``) —
    instead of a FIFO ``SlotServer``.  ``batching`` overrides the tiers'
    declarations fleet-wide (True forces fused serving on every edge,
    False forces plain FIFO); ``None`` respects each tier.  The trade:
    a wider gather window fuses more (cheaper service under load) but
    adds up to that much pre-service latency per frame.

    Migration: passing a :class:`~repro.cluster.migration
    .MigrationConfig` arms a ``MigrationController`` — at every frame
    finish (and immediately on detected link drift) the client's
    placement is reconsidered against live queue depths and open
    batches, gated by the config's dwell/improvement hysteresis.  A
    migrating client drains its just-finished frame, pays the priced
    pose + swarm state transfer before its next frame starts, and
    re-plans against the new edge through the shared plan cache.
    ``migration=None`` (default) is bit-for-bit the static fleet.

    Codec: passing a :class:`~repro.codec.rate.CodecConfig` arms a
    per-client :class:`~repro.codec.rate.RateController` — every plan
    is priced under the client's current codec operating point
    (compressed payload bytes, encode/decode compute at the endpoints;
    the CodecModel is part of the plan-cache key, so clients at the
    same point share one plan), and at every frame finish the
    controller feeds observed link pressure and scene motion to the
    rate loop; an operating-point switch re-plans the client before
    its next frame (``ClientResult.rate_changes``).  ``codec=None``
    (default) ships raw payloads; the identity codec
    (``codec.rate.identity_config()``) is the golden off-switch —
    event-for-event the raw fleet.

    Engine: ``engine="vector"`` runs the same simulation through the
    array-backed hot loop in :mod:`repro.cluster.fastfleet` — an order
    of magnitude faster at fleet scale, and event-for-event identical
    to the default ``"object"`` engine (property-tested in
    tests/test_engine_equivalence.py).

    Heterogeneity: ``client_classes`` is a sequence of client
    :class:`~repro.core.offload.Tier` records; client ``c`` plans (and
    is dispatched, migrated and priced) against its own hardware class
    ``client_classes[c % len(client_classes)]`` instead of the star's
    nominal home tier.  ``None`` (default) keeps the homogeneous fleet.

    Adaptive batching: ``adaptive_window`` (an
    :class:`~repro.cluster.events.AdaptiveWindow`) sizes each batching
    edge's gather window from its observed inter-arrival EWMA — idle
    edges stop paying the window as pure latency.  ``None`` (default)
    keeps the fixed window exactly.

    Mixed traffic: ``workloads`` is a sequence of
    :class:`~repro.core.stages.StagedComputation` records (e.g. the
    registry in :mod:`repro.core.workloads`); client ``c`` runs
    ``workloads[c % len(workloads)]`` instead of ``comp`` — it plans,
    dispatches, migrates, batches (fused launches only join under the
    same workload key) and re-plans against its own pipeline, on both
    engines event-for-event identically.  ``workloads=None`` (default)
    keeps the homogeneous fleet bit-for-bit, and ``workloads=(comp,)``
    is the golden off-switch — event-for-event the ``comp`` fleet.

    Telemetry: passing a :class:`~repro.cluster.telemetry.Telemetry`
    records per-frame span traces (exact loop-time decomposition,
    Chrome-trace exportable), a metrics registry (cache, migration,
    codec, server occupancy/batch instruments), and the inputs of the
    latency-attribution report.  Purely observational: both engines
    record the identical trace, and ``telemetry=None`` (default) is
    bit-for-bit the uninstrumented fleet.

    SLO monitoring: passing an :class:`~repro.cluster.slo.SLOMonitor`
    (``slo=SLOMonitor(...)``) arms *online* SLO tracking on top of the
    telemetry hooks — streaming windowed quantile/attainment estimators
    per (workload, SLO class), multi-window burn-rate alerting that
    opens :class:`~repro.cluster.slo.Incident` records mid-run, and a
    root-cause attributor that diffs each incident window's span
    profile against the rolling healthy baseline.  An ``SLOMonitor``
    *is* a ``Telemetry`` (same hooks, strictly more bookkeeping), so
    ``slo=`` and ``telemetry=`` are mutually exclusive; ``slo=None``
    (default) is bit-for-bit the unmonitored fleet on both engines.
    """
    if num_clients < 1:
        raise ValueError("need at least one client")
    if slo is not None:
        if telemetry is not None:
            raise ValueError(
                "pass either slo= or telemetry=, not both — an SLOMonitor "
                "is a Telemetry and records the full trace itself"
            )
        telemetry = slo
    if granularity == "single_step":
        _prep = lambda cmp: cmp.fused()  # noqa: E731
    elif granularity == "multi_step":
        _prep = lambda cmp: cmp  # noqa: E731
    else:
        raise ValueError(granularity)
    comp_used = _prep(comp)
    if workloads is not None and not workloads:
        raise ValueError("workloads must be non-empty when provided")
    workloads_used = (
        tuple(_prep(w) for w in workloads) if workloads is not None else None
    )

    edges = [n for n in topo.tier_names() if n != topo.home]
    if not edges:
        raise ValueError("fleet topology has no edge tiers")
    for e in edges:
        if len(topo.path_tiers(topo.home, e)) != 2:
            raise ValueError(
                f"fleet topology must be a star; tier {e!r} is not "
                "directly linked to home"
            )
    if batching is not None and any(
        topo.tier(e).batching != batching for e in edges
    ):
        # the override changes the tiers the cost engine prices, so it
        # must be baked into the topology (and its cache fingerprints)
        topo = Topology(
            tiers={
                name: (
                    dataclasses.replace(t, batching=batching)
                    if name != topo.home
                    else t
                )
                for name, t in topo.tiers.items()
            },
            links=dict(topo.links),
            home=topo.home,
            wrapper=topo.wrapper,
            wrapped=topo.wrapped,
        )

    if engine not in ("object", "vector"):
        raise ValueError(
            f"unknown engine {engine!r}; choose 'object' or 'vector'"
        )
    classes = tuple(client_classes) if client_classes else None
    if engine == "vector":
        from repro.cluster.fastfleet import run_fleet_vectorized

        return run_fleet_vectorized(
            topo=topo,
            comp_used=comp_used,
            edges=edges,
            num_clients=num_clients,
            num_frames=num_frames,
            policy=policy,
            dispatch=dispatch,
            planner=planner,
            seed=seed,
            camera_fps=camera_fps,
            cache=cache,
            drifts=drifts,
            drift_threshold=drift_threshold,
            drift_window=drift_window,
            drift_min_samples=drift_min_samples,
            probe_every=probe_every,
            gather_window=gather_window,
            adaptive_window=adaptive_window,
            migration=migration,
            codec=codec,
            client_classes=classes,
            telemetry=telemetry,
            workloads=workloads_used,
        )

    cache = cache if cache is not None else PlanCache()
    link_table = LinkTable(topo)
    # shared transmission media (cell/backhaul): one SharedLink per
    # declared medium, plus the link-name -> medium mapping clients use
    # to split their plans' wire legs.  Both stay empty on private-spoke
    # topologies, and every contention hook below is gated on that.
    media = build_media(topo)
    media_of = {
        link.name: media[link.medium]
        for link in topo.links.values()
        if link.medium
    }
    q = EventQueue()
    servers: Dict[str, object] = {}
    for e in edges:
        tier = topo.tier(e)
        if tier.batching:
            servers[e] = BatchingSlotServer(
                e,
                tier.capacity,
                queue=q,
                model=BatchServiceModel.from_tier(tier),
                gather_window=gather_window,
                adaptive=adaptive_window,
            )
        else:
            servers[e] = SlotServer(e, tier.capacity)
    tel = telemetry
    if tel is not None:
        # wire instrumentation before admission planning so the initial
        # cache misses are observed too (shared media carry the same
        # telemetry attribute as the slot servers)
        tel.attach(
            cache=cache, servers=list(servers.values()) + list(media.values())
        )
    detector = DriftDetector(
        threshold=drift_threshold,
        window=drift_window,
        min_samples=drift_min_samples,
    )
    period = 1.0 / camera_fps

    # every client's rate controller starts at the same deterministic
    # operating point, so admission-time dispatch prices with it too
    init_codec = RateController(codec).model if codec is not None else None
    ctx = DispatchContext(
        topo=topo,
        comp=comp_used,
        policy=policy,
        edges=edges,
        servers=servers,
        link_table=link_table,
        assignments={},
        codec=init_codec,
        media=media,
    )
    disp = make_dispatch(dispatch)
    nw = len(workloads_used) if workloads_used else 0
    clients: List[_Client] = []
    for c in range(num_clients):
        tier_c = classes[c % len(classes)] if classes else None
        comp_c = workloads_used[c % nw] if workloads_used else comp_used
        ctx.client_tier = tier_c
        ctx.comp = comp_c
        edge = disp.assign(c, ctx)
        ctx.assignments[edge] = ctx.assignments.get(edge, 0) + 1
        sub = edge_subtopology(topo, edge, link_table, client_tier=tier_c)
        rate = (
            RateController(codec, client_id=c) if codec is not None else None
        )
        plan, _ = cache.get_or_plan(
            comp_c,
            sub,
            policy,
            planner,
            codec=rate.model if rate is not None else None,
        )
        clients.append(
            _Client(
                c,
                np.random.default_rng(seed + c),
                edge,
                plan,
                topo.home,
                topology_fingerprint(sub),
                rate=rate,
                tier=tier_c,
                media_of=media_of,
                comp=comp_c,
            )
        )
    if tel is not None:
        home_cls = topo.tier(topo.home).name
        tel.register_clients(
            {
                c.idx: (c.tier.name if c.tier is not None else home_cls)
                for c in clients
            }
        )
        tel.register_workloads({c.idx: c.comp.name for c in clients})

    controller: Optional[MigrationController] = None
    if migration is not None:
        controller = MigrationController(
            migration,
            topo=topo,
            comp=comp_used,
            policy=policy,
            planner=planner,
            cache=cache,
            link_table=link_table,
            servers=servers,
            edges=edges,
            assignments=ctx.assignments,
            codec=init_codec,
            media=media,
        )

    # --- event handlers ---------------------------------------------------

    def replan(client: _Client, edge: str) -> None:
        """Re-plan ``client`` against ``edge`` under current link
        conditions AND its current codec operating point, resetting its
        adaptive-loop state (shared by the drift-replan, rate-switch
        and migration paths so they cannot diverge)."""
        sub = edge_subtopology(topo, edge, link_table, client_tier=client.tier)
        plan, _ = cache.get_or_plan(
            client.comp, sub, policy, planner, codec=client.codec_model
        )
        client.set_plan(plan, topology_fingerprint(sub))
        client.drifted = False
        client.rate_dirty = False
        client.frames_since_probe = 0
        detector.reset(client.idx)

    def start_frame(client: _Client) -> None:
        i = client.next_i
        if i >= num_frames:
            return
        if client.drifted or client.rate_dirty:
            if client.drifted:
                client.replans += 1
                if tel is not None:
                    tel.count("plan.replans.drift")
            elif tel is not None:
                tel.count("plan.replans.rate")
            replan(client, client.edge)
        arrival = i * period
        start = max(arrival, client.t_free)
        newest = min(int(start / period), num_frames - 1)
        if newest > i:
            i = newest
            arrival = i * period
            start = max(arrival, client.t_free)
        sampled, observed = link_table.sample_plan_latency(client.plan, client.rng)
        client.pending = (i, arrival, start, sampled, observed)
        client.med_wait = 0.0
        if client.visits:
            q.schedule(
                start + (sampled - client.service_total),
                lambda c=client: visit(c, 0, 0.0),
            )
        else:
            q.schedule(start + sampled, lambda c=client: finish(c, 0.0))

    def visit(
        client: _Client, vidx: int, wait_acc: float, up_paid: bool = False
    ) -> None:
        if vidx == 0 and client.up_media and not up_paid:
            # offer this frame's uplink wire time to its shared media at
            # the time it would have cleared them uncontended (this very
            # event).  A busy cell delays the request's arrival at the
            # edge: one rescheduled visit carrying the delay as wait.
            # An idle (or unlimited) medium returns a literal 0.0 and
            # the original path continues untouched — bit-for-bit the
            # private-spoke fleet.
            uw = 0.0
            for med, svc in client.up_media:
                uw += med.admit(q.now, svc)
            if uw > 0.0:
                client.med_wait += uw
                q.schedule(
                    q.now + uw,
                    lambda c=client, w=wait_acc + uw: visit(c, 0, w, True),
                )
                return
        tier, service = client.visits[vidx]
        arrived = q.now

        def placed(
            svc_start: float,
            svc_end: float,
            c=client,
            vidx=vidx,
            wait_acc=wait_acc,
            arrived=arrived,
            service=service,
        ) -> None:
            # wait has two parts: queue + gather-window dwell before the
            # slot (svc_start - arrived), and batch service inflation —
            # the member is occupied until the BATCH finish svc_end, not
            # its solo finish svc_start + service, and `finish` rebuilds
            # the frame time from the solo `sampled` total.  Kept as a
            # separate term (not folded into svc_start) because FIFO
            # serving and batches of one have svc_end == svc_start +
            # service by the same float ops, so the inflation is exactly
            # 0.0 and the zero-wait golden equivalences stay bit-for-bit.
            wait = wait_acc + (svc_start - arrived) + (
                svc_end - (svc_start + service)
            )
            if tel is not None:
                tel.visit_placed(
                    c.idx,
                    isinstance(servers[tier], BatchingSlotServer),
                    arrived,
                    svc_start,
                    svc_end,
                    service,
                )
            if vidx + 1 < len(c.visits):
                q.schedule(svc_end, lambda: visit(c, vidx + 1, wait))
            else:
                q.schedule(svc_end, lambda: finish(c, wait))

        # unbatched servers invoke `placed` synchronously (identical to
        # the historical admit-then-schedule path); batching servers
        # defer it to their gather-window close event
        servers[tier].submit(arrived, service, placed, key=client.comp.name)

    def finish(client: _Client, wait: float) -> None:
        i, arrival, start, sampled, observed = client.pending
        client.pending = None
        # canonical finish: waits appended after the resampled plan total,
        # so a zero-wait run is bit-identical to the analytic FrameLoop
        fin = (start + sampled) + wait
        if client.down_media or (client.up_media and not client.visits):
            # downlink legs (and uplink legs of visit-less plans, which
            # have no visit event to admit them at) clear their shared
            # media at the uncontended finish time; contention stretches
            # the frame synchronously.  mw == 0.0 leaves wait/fin exactly
            # untouched — the off-switch golden.
            mw = 0.0
            if not client.visits:
                for med, svc in client.up_media:
                    mw += med.admit(fin, svc)
            for med, svc in client.down_media:
                mw += med.admit(fin, svc)
            if mw > 0.0:
                client.med_wait += mw
                wait += mw
                fin += mw
        client.events.append(
            FrameEvent(i, arrival, start, fin, i - client.last_processed)
        )
        client.last_processed = i
        client.next_i = i + 1
        client.t_free = fin
        client.total_wait += wait
        if tel is not None:
            tel.frame_done(
                client.idx,
                i,
                client.edge,
                start,
                fin,
                client.plan,
                tuple(d for _, d in observed),
                link_wait=client.med_wait,
            )
        if observed:
            if detector.observe(client.idx, client.plan, observed):
                client.drifted = True
        else:
            # leg-less (fully local) plan: nothing crosses the wire, so
            # probe the link periodically to notice recovery/changes
            client.frames_since_probe += 1
            if client.frames_since_probe >= probe_every:
                client.frames_since_probe = 0
                sub = edge_subtopology(
                    topo, client.edge, link_table, client_tier=client.tier
                )
                if topology_fingerprint(sub) != client.plan_fp:
                    client.drifted = True
        if client.rate is not None:
            # feed the rate loop this frame's observed leg draws and
            # motion index; a switch re-plans (same codec-keyed cache)
            # before the next frame starts.  The controller's own
            # `switches` counter is the single source of truth.
            if (
                client.rate.observe(
                    i, observed, client.plan, cell_wait=client.med_wait
                )
                is not None
            ):
                client.rate_dirty = True
        if controller is not None and client.visits:
            # report the measured non-plan time to the predictor's
            # per-edge wait EWMA (read only when wait_ewma_blend > 0)
            controller.observe_wait(client.edge, wait, q.now)
        if controller is not None and client.next_i < num_frames:
            # the just-finished frame IS the drain: re-dispatch decisions
            # land only at frame boundaries, never with a frame in flight
            # (and never after the final frame — a client with nothing
            # left to serve must not record a phantom move)
            controller.frame_done(client.idx)
            move = controller.consider(
                client.idx,
                client.edge,
                q.now,
                # the warm state lives where the current plan computes:
                # the serving edge, or home for a fully-local plan
                state_src=(
                    client.visits[0][0] if client.visits else topo.home
                ),
                force=client.drifted,
                codec=client.codec_model,
                client_tier=client.tier,
                comp=client.comp,
            )
            if move is not None:
                target, mig_latency = move
                if tel is not None:
                    tel.migration(client.idx, fin, mig_latency, client.edge, target)
                client.edge = target
                client.migrations += 1
                # the state transfer blocks the client between frames;
                # the move is a re-dispatch, not a replan, so it counts
                # in ClientResult.migrations rather than replans
                client.t_free = fin + mig_latency
                replan(client, target)
        start_frame(client)

    for client in clients:
        q.schedule(0.0, lambda c=client: start_frame(c))
    for d in drifts:
        if isinstance(d, ServiceDrift):
            if d.edge not in servers:
                raise ValueError(f"ServiceDrift targets unknown edge {d.edge!r}")
            q.schedule(
                d.time,
                lambda d=d: setattr(servers[d.edge], "service_scale", d.factor),
            )
        else:
            q.schedule(
                d.time,
                lambda d=d: link_table.set(
                    d.link, latency=d.latency, jitter=d.jitter, bandwidth=d.bandwidth
                ),
            )
    q.run()

    client_results = []
    for client in clients:
        duration = client.events[-1].finish if client.events else 0.0
        client_results.append(
            ClientResult(
                client=client.idx,
                edge=client.edge,
                stats=LoopStats(client.events, num_frames, duration),
                plan=client.plan,
                replans=client.replans,
                total_wait=client.total_wait,
                migrations=client.migrations,
                rate_changes=(
                    client.rate.switches if client.rate is not None else 0
                ),
                codec=client.codec_model,
            )
        )
    edge_loads = [
        EdgeLoad(
            name=e,
            capacity=servers[e].capacity,
            clients=ctx.assignments.get(e, 0),
            admitted=servers[e].admitted,
            busy_time=servers[e].busy_time,
            mean_wait=servers[e].mean_wait,
            batches=servers[e].batches,
            mean_batch_size=servers[e].mean_batch_size,
            peak_load=servers[e].peak_load,
        )
        for e in edges
    ]
    result = FleetResult(
        clients=client_results,
        edges=edge_loads,
        cache=cache,
        num_frames=num_frames,
        duration=max((c.stats.duration for c in client_results), default=0.0),
        migration=controller.stats if controller is not None else None,
        events=q.processed,
        links=[
            LinkLoad(
                name=m.name,
                capacity=m.capacity,
                admitted=m.admitted,
                contended=m.contended,
                busy_time=m.busy_time,
                total_wait=m.total_wait,
            )
            for m in media.values()
        ],
    )
    if tel is not None:
        tel.finish_run(
            result,
            rates=(
                [c.rate for c in clients] if codec is not None else None
            ),
        )
        tel.detach(
            cache=cache, servers=list(servers.values()) + list(media.values())
        )
    return result


@dataclasses.dataclass
class SweepPoint:
    num_clients: int
    result: FleetResult

    @property
    def fps(self) -> float:
        return self.result.mean_achieved_fps

    @property
    def drop_rate(self) -> float:
        return self.result.drop_rate

    @property
    def p99(self) -> float:
        return self.result.p99_loop_time

    # migration stats surfaced per point (0 / 0.0 when migration is off)
    # so sweep reports never drop the controller's state between points
    @property
    def migrations(self) -> int:
        m = self.result.migration
        return m.count if m is not None else 0

    @property
    def mean_migration_latency(self) -> float:
        m = self.result.migration
        return m.mean_latency if m is not None else 0.0


def capacity_sweep(
    topo: Topology,
    comp: StagedComputation,
    client_counts: Sequence[int] = (1, 2, 4, 8, 16, 32),
    **kwargs,
) -> List[SweepPoint]:
    """The Fig. 3 accounting at fleet scale: clients vs achieved fps,
    drop rate and tail latency.  Each point is an independent seeded
    run, so adding clients never perturbs the smaller runs.

    One ``PlanCache`` is shared across every point (unless the caller
    passes their own): the sweep re-runs identical clients against
    identical link conditions, so point N's plans are point 1's cache
    hits — N identical clients cost O(num_edges) plans for the *whole*
    sweep, not per point (asserted in tests/test_cluster.py)."""
    if kwargs.get("cache") is None:
        kwargs["cache"] = PlanCache()
    return [
        SweepPoint(n, run_fleet(topo, comp, num_clients=n, **kwargs))
        for n in client_counts
    ]
