"""Fleet-scale simulation: many thin clients vs contended edge servers.

The paper proves one weak client + one strong server works; this package
asks how many such clients a shared pool of edge servers sustains.  See
``fleet.run_fleet`` / ``fleet.capacity_sweep`` for the front-end,
``events`` for the discrete-event engine, ``dispatch`` for edge
selection policies, ``plancache`` for plan caching with drift-triggered
incremental re-planning, ``migration`` for mid-run client re-dispatch
with hysteresis (live migration), and ``fastfleet`` for the vectorized
event engine (``run_fleet(engine="vector")``) that runs the same
simulation event-for-event at a multiple of the object engine's
throughput — the 10k-client sweep path, and ``telemetry`` for the
opt-in observability layer (per-frame span traces, metrics registry,
latency attribution) both engines feed identically; ``slo`` builds the
online SLO monitor + fault-injected root-cause doctor on top of it
(``run_fleet(slo=SLOMonitor(...))``).
"""

from repro.cluster.dispatch import (  # noqa: F401
    DISPATCH_POLICIES,
    edge_subtopology,
    make_dispatch,
)
from repro.cluster.events import (  # noqa: F401
    AdaptiveWindow,
    BatchingSlotServer,
    EventQueue,
    LinkTable,
    SlotServer,
)
from repro.cluster.fastfleet import (  # noqa: F401
    ArrayLoopStats,
    run_fleet_vectorized,
)
from repro.cluster.fleet import (  # noqa: F401
    ClientResult,
    FleetResult,
    LinkDrift,
    ServiceDrift,
    SweepPoint,
    capacity_sweep,
    run_fleet,
)
from repro.cluster.migration import (  # noqa: F401
    MigrationConfig,
    MigrationController,
    MigrationRecord,
    MigrationStats,
    tracker_state_nbytes,
)
from repro.cluster.plancache import (  # noqa: F401
    DriftDetector,
    PlanCache,
    comp_signature,
    topology_fingerprint,
)
from repro.cluster.slo import (  # noqa: F401
    BEST_EFFORT,
    DOCTOR_CLASSES,
    FAULTS,
    INTERACTIVE,
    SLO_CLASSES,
    FaultSpec,
    Incident,
    SLOClass,
    SLOMonitor,
    doctor_verdict,
    slo_of,
)
from repro.cluster.telemetry import (  # noqa: F401
    SPAN_ORDER,
    MetricsRegistry,
    Telemetry,
)
