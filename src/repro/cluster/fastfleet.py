"""Vectorized fleet engine: the discrete-event hot loop as flat arrays.

``fleet.run_fleet``'s object engine spends its time on pure-Python
object churn: a heapq of ``_Event`` dataclasses (whose generated
``__lt__`` dominates at depth), three closures allocated per frame, a
``FrameEvent`` dataclass per processed frame, per-leg scalar
``rng.normal`` draws and a drift-detector ring sum per leg per frame.
This module re-implements the *same simulation* — same control flow,
same tie-breaking, same servers, same seeded RNG streams — with the
churn removed:

* **Packed-payload event heap.**  Events are ``(time, seq, payload)``
  tuples on ``heapq``, where ``payload`` packs ``(id << 2) | kind`` into
  one int — no event objects, no ``__lt__`` dispatch, C-speed sifts.
  (A literal binary heap over preallocated NumPy arrays was measured
  ~8x *slower* per push/pop pair than C ``heapq`` at fleet depths —
  Python-level sift loops lose to the C implementation even counting
  tuple allocation — so "array-backed" here means the *state* lives in
  arrays while the ordering structure stays in C.)
* **Struct-of-arrays client state.**  Per-client scalars (frame
  counters, free times, accumulated waits, pending-frame slots) live in
  flat Python lists indexed by client id, reused every frame — the
  slab-allocation replacement for the object engine's per-frame tuple
  and ``FrameEvent`` allocations.  Processed-frame records append to
  per-client ``array('d')`` columns and materialize into ``FrameEvent``
  objects only if a caller actually reads ``stats.processed``
  (:class:`ArrayLoopStats`).
* **Inline FIFO admission.**  ``SlotServer.admit``'s slot-heap and
  stats arithmetic is inlined into the visit event over struct-of-
  arrays server state.  The slot and in-flight heaps *alias the
  server's own lists* (so ``MigrationController`` reads live load
  mid-run), while the scalar counters accumulate in flat lists and
  write back to the ``SlotServer`` objects after the loop.
  ``heapreplace`` substitutes for pop-then-push: both leave the same
  multiset of slot-free times, and a min-heap's pop sequence is a pure
  function of the multiset, so every admission sees the same ``free``
  value either way.  Batching servers keep their object path — fused
  launches are rare events, FIFO admissions are the hot path.
* **Block-drawn RNG.**  Each client keeps a buffer of *raw* standard
  normals (refilled via ``Generator.standard_normal(n)``, which
  consumes the stream exactly like n scalar draws) and transforms them
  lazily, a block of frames at a time, into per-leg latency draws with
  vectorized ``max(0, lat + jit * z)`` — bit-identical to the object
  engine's per-leg ``rng.normal(lat, jit)`` because NumPy computes
  exactly ``loc + scale * standard_normal()``.  Blocks invalidate on
  link-table mutation (``LinkTable.version``) or re-plan; unconsumed
  normals stay buffered so the stream position never diverges.
* **Precomputed drift decisions.**  The per-frame ``DriftDetector``
  ring sums are evaluated for the whole block at build time with a
  prefix-sum over [ring snapshot ++ block draws].  Prefix-sum means
  reassociate the float additions, so each decision carries a
  certainty margin ~1e-9 (about 100x the worst-case reassociation
  error at these window lengths, about 1e5x smaller than any physical
  latency signal): frames whose |deviation - tolerance| falls inside
  the margin are re-evaluated at finish time with the object engine's
  exact sequential-sum arithmetic.  Ring buffers themselves update
  lazily (``applied_upto``) — only at block boundaries, re-plans and
  exact re-evaluations — never per frame.
* **Cohort-batched admission.**  The t=0 cohort — one START event per
  client, always the same timestamp — is drained as a straight loop
  before entering the event loop (the heap never sees it), with
  sequence numbers reserved so everything scheduled during the cohort
  orders exactly as the object engine's heap would have ordered it.

What is deliberately NOT re-implemented: ``BatchingSlotServer``, the
``PlanCache``, the ``MigrationController`` and the ``RateController``
are the *same objects* the object engine uses, and the FIFO/detector
fast paths above are value-equivalent transformations of
``SlotServer.admit`` / ``DriftDetector.observe`` — semantics are
shared by construction or by float-op-order replication, not loosely
approximated.  The engines are asserted event-for-event identical
(results, stats, cache counters, event counts) in
tests/test_engine_equivalence.py.
"""

from __future__ import annotations

import gc
import heapq
from array import array
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.cluster.dispatch import (
    DispatchContext,
    edge_subtopology,
    make_dispatch,
)
from repro.cluster.events import (
    AdaptiveWindow,
    BatchingSlotServer,
    LinkTable,
    SlotServer,
    build_media,
)
from repro.cluster.migration import MigrationConfig, MigrationController
from repro.cluster.plancache import PlanCache, topology_fingerprint
from repro.codec.rate import CodecConfig, RateController
from repro.core.costengine import BatchServiceModel
from repro.core.offload import Policy, Topology
from repro.core.stages import StagedComputation
from repro.sim.clock import FRAME_BUDGET, FrameEvent

# event kinds, packed into the low bits of the payload int
_K_VISIT = 0
_K_FINISH = 1
_K_CALLBACK = 2  # deferred callable (batch-close events from the servers)
_K_DRIFT = 3
_KIND_BITS = 2
_KIND_MASK = (1 << _KIND_BITS) - 1

# max frames per transformed latency block (sampling amortization unit);
# small enough that 10k clients' live blocks stay tens of MB
_BLOCK = 128


class ArrayLoopStats:
    """``sim.clock.LoopStats`` over parallel arrays.

    Field-for-field the same observables (same float arithmetic), but
    the per-frame records live in ``array('d')``/``array('q')`` columns;
    ``FrameEvent`` objects are materialized only if ``processed`` is
    actually read.  Arrivals and gaps are not even recorded — they are
    pure functions of the frame indices (``i * period`` with the exact
    expression the engine used, and consecutive-index differences), so
    the hot loop appends three columns, not five.
    """

    __slots__ = (
        "_idx",
        "_start",
        "_finish",
        "_period",
        "total_frames",
        "duration",
        "_events",
    )

    def __init__(self, idx, start, finish, total_frames, period):
        self._idx = idx
        self._start = start
        self._finish = finish
        self._period = period
        self.total_frames = total_frames
        self.duration = finish[-1] if len(finish) else 0.0
        self._events: Optional[List[FrameEvent]] = None

    @property
    def processed(self) -> List[FrameEvent]:
        if self._events is None:
            period = self._period
            last = -1
            events = []
            for i, s, f in zip(self._idx, self._start, self._finish):
                events.append(FrameEvent(i, i * period, s, f, i - last))
                last = i
            self._events = events
        return self._events

    def loop_times(self) -> List[float]:
        return [f - s for s, f in zip(self._start, self._finish)]

    @property
    def achieved_fps(self) -> float:
        n = len(self._finish)
        if not n or self.duration <= 0:
            return 0.0
        return n / self.duration

    @property
    def dropped(self) -> int:
        return self.total_frames - len(self._finish)

    @property
    def drop_rate(self) -> float:
        return self.dropped / max(self.total_frames, 1)

    @property
    def mean_gap(self) -> float:
        idx = self._idx
        n = len(idx) - 1
        return (idx[-1] - idx[0]) / n if n > 0 else 1.0

    @property
    def mean_loop_time(self) -> float:
        times = self.loop_times()
        return sum(times) / len(times) if times else 0.0

    @property
    def realtime(self) -> bool:
        return self.mean_loop_time <= FRAME_BUDGET


class _ShimQueue:
    """The ``EventQueue`` facade handed to :class:`BatchingSlotServer`.

    The servers only ever call ``schedule(time, fn)`` (their gather-
    window close) and read ``now``; the shim turns each close into a
    packed ``_K_CALLBACK`` event on the engine's tuple heap, sharing the
    engine's sequence counter so batch closes order against frame
    events exactly as they do on the object engine's queue.
    """

    __slots__ = ("now", "heap", "seq", "cbs")

    def __init__(self) -> None:
        self.now = 0.0
        self.heap: List[Tuple[float, int, int]] = []
        self.seq = 0
        self.cbs: List[object] = []

    def schedule(self, time, fn) -> None:
        cbs = self.cbs
        heapq.heappush(
            self.heap,
            (
                time if time > self.now else self.now,
                self.seq,
                (len(cbs) << _KIND_BITS) | _K_CALLBACK,
            ),
        )
        self.seq += 1
        cbs.append(fn)


def run_fleet_vectorized(
    *,
    topo: Topology,
    comp_used: StagedComputation,
    edges: List[str],
    num_clients: int,
    num_frames: int,
    policy: Policy,
    dispatch: str,
    planner: Optional[str],
    seed: int,
    camera_fps: float,
    cache: Optional[PlanCache],
    drifts: Sequence[object],
    drift_threshold: float,
    drift_window: int,
    drift_min_samples: int,
    probe_every: int,
    gather_window: float,
    adaptive_window: Optional[AdaptiveWindow],
    migration: Optional[MigrationConfig],
    codec: Optional[CodecConfig],
    client_classes: Optional[Tuple[object, ...]],
    telemetry=None,
    workloads: Optional[Tuple[StagedComputation, ...]] = None,
) -> "FleetResult":
    """The vectorized twin of ``fleet.run_fleet``'s event loop.

    Called by ``run_fleet(engine="vector")`` with an already-normalized
    topology (batching override baked in) and computation; do not call
    directly.  Every schedule call, RNG draw and server interaction
    happens in the same order as the object engine's, so results are
    event-for-event identical.
    """
    # imported here: fleet.py imports this module lazily inside
    # run_fleet, so a top-level back-import would be cycle-prone
    from repro.cluster.fleet import (
        ClientResult,
        EdgeLoad,
        FleetResult,
        LinkLoad,
        ServiceDrift,
        plan_media,
    )

    N = num_clients
    cache = cache if cache is not None else PlanCache()
    link_table = LinkTable(topo)
    # shared media (contended cells / backhauls): one SharedLink per
    # distinct medium name; media_of maps link name -> SharedLink so
    # plan_media can resolve each plan's wire legs.  Empty on topologies
    # without shared media — every contention branch below is then dead.
    media = build_media(topo)
    media_of = {
        link.name: media[link.medium]
        for link in topo.links.values()
        if link.medium
    }
    q = _ShimQueue()
    heap = q.heap
    home = topo.home
    period = 1.0 / camera_fps
    last_frame = num_frames - 1
    min_samples = max(1, drift_min_samples)
    abs_floor = 1e-4  # DriftDetector's default (the fleet never overrides it)
    W = drift_window
    B = max(1, min(_BLOCK, num_frames))

    servers: Dict[str, object] = {}
    for e in edges:
        tier = topo.tier(e)
        if tier.batching:
            servers[e] = BatchingSlotServer(
                e,
                tier.capacity,
                queue=q,
                model=BatchServiceModel.from_tier(tier),
                gather_window=gather_window,
                adaptive=adaptive_window,
            )
        else:
            servers[e] = SlotServer(e, tier.capacity)
    edge_index = {e: i for i, e in enumerate(edges)}
    server_list = [servers[e] for e in edges]
    tel = telemetry
    if tel is not None:
        # wire instrumentation before admission planning (counts the
        # initial cache misses); batching servers report occupancy and
        # batch sizes through the shared events.py code — only the
        # inlined FIFO path below needs explicit hook calls
        tel.attach(cache=cache, servers=server_list + list(media.values()))

    # --- struct-of-arrays server state (FIFO fast path) -------------------
    # the heaps ALIAS the SlotServer's own lists (mid-run load() reads by
    # the migration controller stay live); scalar stats accumulate here
    # and write back after the loop
    n_edges = len(edges)
    srv_fifo = [type(sv) is SlotServer for sv in server_list]
    srv_slots = [sv._slots for sv in server_list]
    srv_fins = [sv._finishes for sv in server_list]
    srv_scale = [sv.service_scale for sv in server_list]
    adm_l = [0] * n_edges
    busy_l = [0.0] * n_edges
    twl = [0.0] * n_edges
    peak_l = [0] * n_edges

    # --- struct-of-arrays client state -----------------------------------
    edge_i = [0] * N  # index into `edges`
    tier_of: List[object] = [None] * N  # own hardware class (hetero)
    # own workload (mixed fleets: workloads[c % nw]; else comp_used) and
    # its batch key — fused launches only join under the same workload
    nw = len(workloads) if workloads else 0
    comp_of: List[StagedComputation] = [comp_used] * N
    key_of: List[str] = [comp_used.name] * N
    rngs: List[object] = [None] * N
    rates: Optional[List[object]] = [None] * N if codec is not None else None
    t_free = [0.0] * N
    next_i = [0] * N
    replans_n = [0] * N
    migr_n = [0] * N
    twait = [0.0] * N
    drifted = [False] * N
    rate_dirty = [False] * N
    probe_n = [0] * N
    wait_acc = [0.0] * N
    vidx = [0] * N
    # shared-medium state: (SharedLink, wire seconds) tuples per plan
    # direction, the per-frame medium delay, and whether the in-flight
    # frame's uplink already cleared its media (one admission per frame)
    up_media: List[tuple] = [()] * N
    down_media: List[tuple] = [()] * N
    med_wait = [0.0] * N
    up_paid = [False] * N
    # pending in-flight frame (the object engine's per-frame tuple, as
    # recycled slots)
    pend_i = [0] * N
    pend_start = [0.0] * N
    pend_sampled = [0.0] * N
    pend_pos = [0] * N  # row of the client's block the pending frame drew
    # plan-derived state
    plan_obj: List[object] = [None] * N
    plan_fp_l: List[object] = [None] * N
    # [(is_fifo, server_index, service, tier_name, server), ...]
    visits: List[list] = [[]] * N
    nvis = [0] * N
    has_legs = [False] * N
    service_total = [0.0] * N
    legs_meta: List[list] = [[]] * N  # [(link, leg_lat, leg_jit, weight), ...]
    leg_links: List[tuple] = [()] * N
    # detector link groups: [(link, predicted, leg_columns, tolerance), ...]
    link_groups: List[list] = [[]] * N
    # latency sampling blocks
    blk_t: List[list] = [[]] * N  # per-frame plan totals (python floats)
    blk_D: List[object] = [None] * N  # per-frame per-leg draws, (B, n_legs)
    blk_fl: List[list] = [[]] * N  # per-frame drift flag: 0 no, 1 yes, 2 exact
    blk_pos = [0] * N
    blk_nj = [0] * N
    blk_ver = [-1] * N
    zbuf: List[object] = [None] * N  # raw standard normals (np arrays)
    zpos = [0] * N
    # drift-detector rings: per client, link -> [buffer, next_overwrite];
    # maintained lazily — applied_upto[c] counts block rows already fed in
    rings: List[dict] = [None] * N
    applied_upto = [0] * N
    # processed-frame record columns (arrival/gap derive from the index)
    rec_i = [array("q") for _ in range(N)]
    rec_start = [array("d") for _ in range(N)]
    rec_fin = [array("d") for _ in range(N)]

    seq = 0  # mirrors q.seq; synced around object-path calls

    def _set_plan(c: int, plan, fp) -> None:
        plan_obj[c] = plan
        plan_fp_l[c] = fp
        vis = []
        for t, s in plan.compute_by_tier:
            if t != home:
                sv = servers[t]
                vis.append(
                    (type(sv) is SlotServer, edge_index[t], s, t, sv)
                )
        visits[c] = vis
        nvis[c] = len(vis)
        service_total[c] = sum(v[2] for v in vis)
        legs = [
            (leg.link, leg.latency, leg.jitter, leg.weight)
            for leg in plan.legs
        ]
        legs_meta[c] = legs
        has_legs[c] = bool(legs)
        leg_links[c] = tuple(ln for ln, _, _, _ in legs)
        up_media[c], down_media[c] = plan_media(plan, media_of)
        pred_map: Dict[str, float] = {}
        cols_map: Dict[str, list] = {}
        for j, (ln, lat, _, _) in enumerate(legs):
            pred_map.setdefault(ln, lat)
            cols_map.setdefault(ln, []).append(j)
        link_groups[c] = [
            (ln, pred_map[ln], cols, max(drift_threshold * pred_map[ln], abs_floor))
            for ln, cols in cols_map.items()
        ]
        blk_ver[c] = -1  # force a block rebuild at next sample

    def _apply_rings(c: int, upto: int) -> None:
        """Feed block rows [applied_upto, upto) into the detector rings
        (chronological per link, legs in plan order within a frame) —
        exactly the appends ``DriftDetector.observe`` would have done."""
        a = applied_upto[c]
        if a >= upto or W <= 0:
            applied_upto[c] = upto
            return
        D = blk_D[c]
        rc = rings[c]
        for ln, _pred, cols, _tol in link_groups[c]:
            if len(cols) == 1:
                vals = D[a:upto, cols[0]].tolist()
            else:
                vals = D[a:upto, cols].ravel().tolist()
            ring = rc.get(ln)
            if ring is None:
                rc[ln] = ring = [[], 0]
            buf = ring[0]
            if len(vals) >= W:
                buf[:] = vals[-W:]
                ring[1] = 0
            else:
                p = ring[1]
                for v in vals:
                    if len(buf) < W:
                        buf.append(v)
                    else:
                        buf[p] = v
                        p += 1
                        if p == W:
                            p = 0
                ring[1] = p
        applied_upto[c] = upto

    def _exact_observe(c: int, pos: int) -> bool:
        """Re-evaluate one frame's drift decision with the object
        engine's exact sequential-sum arithmetic (the fallback for
        block decisions inside the certainty margin)."""
        _apply_rings(c, pos)
        row = blk_D[c][pos]
        rc = rings[c]
        fired = False
        for ln, pred, cols, tol in link_groups[c]:
            ring = rc.get(ln)
            if ring is None:
                rc[ln] = ring = [[], 0]
            buf = ring[0]
            for j in cols:
                draw = float(row[j])
                if len(buf) < W:
                    buf.append(draw)
                    n = len(buf)
                    if n < min_samples:
                        continue
                    s = sum(buf)
                else:
                    p = ring[1]
                    buf[p] = draw
                    p += 1
                    if p == W:
                        p = 0
                    ring[1] = p
                    n = W
                    s = sum(buf[p:] + buf[:p])
                mean = s / n
                dev = mean - pred
                if dev < 0.0:
                    dev = -dev
                if dev > tol:
                    fired = True
        applied_upto[c] = pos + 1
        return fired

    def _build_block(c: int) -> None:
        """Transform the next B frames' latency draws in one shot and
        precompute their drift-detector decisions."""
        _apply_rings(c, blk_pos[c])  # drain the old block into the rings
        legs = legs_meta[c]
        resolved = []
        nj = 0
        for ln, leg_lat, leg_jit, w in legs:
            link = link_table.lookup(ln)
            if link is None:
                lat, jit = leg_lat, leg_jit
            else:
                lat, jit = link.latency, link.jitter
            resolved.append((lat, jit, leg_lat, w))
            if jit > 0.0:
                nj += 1
        total = plan_obj[c].total_time
        Z = None
        if nj:
            need = B * nj
            zb = zbuf[c]
            zp = zpos[c]
            avail = len(zb) - zp
            if avail < need:
                zb = np.concatenate(
                    (zb[zp:], rngs[c].standard_normal(need - avail))
                )
                zbuf[c] = zb
                zpos[c] = zp = 0
            Z = zb[zp : zp + need].reshape(B, nj)
        T = np.full(B, total)
        cols = []
        zc = 0
        for lat, jit, leg_lat, w in resolved:
            # exact float-op order of LinkTable.sample_plan_latency:
            # subtract the charged latency, add the draw, leg by leg.
            # A probability-weighted leg (conditional-branch pricing,
            # weight < 1.0) swaps w-scaled terms into the SAME slots;
            # the detector/telemetry columns stay the unscaled draws,
            # exactly like the object engine's `observed`.
            if jit > 0.0:
                col = np.maximum(0.0, lat + jit * Z[:, zc])
                zc += 1
            else:
                col = np.full(B, lat)
            if w == 1.0:
                T = T - leg_lat
                T = T + col
            else:
                T = T - w * leg_lat
                T = T + w * col
            cols.append(col)
        blk_t[c] = T.tolist()
        if cols:
            D = np.column_stack(cols)
            blk_D[c] = D
            if W > 0:
                cfire_any = None
                unc_any = None
                rc = rings[c]
                for ln, pred, lcols, tol in link_groups[c]:
                    k = len(lcols)
                    newv = D[:, lcols[0]] if k == 1 else D[:, lcols].ravel()
                    ring = rc.get(ln)
                    if ring is None or not ring[0]:
                        seqa = newv
                        r0 = 0
                    else:
                        buf, p = ring
                        snap = buf if len(buf) < W else buf[p:] + buf[:p]
                        r0 = len(snap)
                        seqa = np.concatenate((np.asarray(snap), newv))
                    cs = np.empty(len(seqa) + 1)
                    cs[0] = 0.0
                    np.cumsum(seqa, out=cs[1:])
                    idx_end = np.arange(r0 + 1, r0 + 1 + B * k)
                    n = np.minimum(W, idx_end)
                    means = (cs[idx_end] - cs[idx_end - n]) / n
                    valid = n >= min_samples
                    diff = np.abs(means - pred) - tol
                    # certainty margin: ~100x the worst-case float error
                    # of the prefix-sum reassociation; inside it, defer
                    # to _exact_observe's bit-exact arithmetic
                    amax = float(np.max(np.abs(seqa)))
                    margin = 1e-9 * (1.0 + tol + amax)
                    cfire = valid & (diff > margin)
                    unc = valid & (np.abs(diff) <= margin)
                    if k > 1:
                        cfire = cfire.reshape(B, k).any(axis=1)
                        unc = unc.reshape(B, k).any(axis=1)
                    cfire_any = (
                        cfire if cfire_any is None else (cfire_any | cfire)
                    )
                    unc_any = unc if unc_any is None else (unc_any | unc)
                blk_fl[c] = (cfire_any + 2 * (unc_any & ~cfire_any)).tolist()
            else:
                blk_fl[c] = [0] * B
        else:
            blk_D[c] = None
            blk_fl[c] = [0] * B
        blk_nj[c] = nj
        blk_ver[c] = link_table.version
        blk_pos[c] = 0
        applied_upto[c] = 0

    def start_frame(c: int, now: float, heappush=heapq.heappush) -> None:
        nonlocal seq
        i = next_i[c]
        if i >= num_frames:
            return
        if drifted[c] or rate_dirty[c]:
            if drifted[c]:
                replans_n[c] += 1
                if tel is not None:
                    tel.count("plan.replans.drift")
            elif tel is not None:
                tel.count("plan.replans.rate")
            _replan(c, edge_i[c])
        arrival = i * period
        tf = t_free[c]
        start = arrival if arrival >= tf else tf
        newest = int(start / period)
        if newest > last_frame:
            newest = last_frame
        if newest > i:
            i = newest
            arrival = i * period
            start = arrival if arrival >= tf else tf
        pos = blk_pos[c]
        if pos >= B or blk_ver[c] != link_table.version:
            _build_block(c)
            pos = 0
        sampled = blk_t[c][pos]
        blk_pos[c] = pos + 1
        zpos[c] += blk_nj[c]
        pend_i[c] = i
        pend_start[c] = start
        pend_sampled[c] = sampled
        pend_pos[c] = pos
        wait_acc[c] = 0.0
        med_wait[c] = 0.0
        up_paid[c] = False
        if nvis[c]:
            vidx[c] = 0
            tm = start + (sampled - service_total[c])
            heappush(
                heap,
                (tm if tm > now else now, seq, (c << _KIND_BITS) | _K_VISIT),
            )
        else:
            tm = start + sampled
            heappush(
                heap,
                (tm if tm > now else now, seq, (c << _KIND_BITS) | _K_FINISH),
            )
        seq += 1

    def _replan(c: int, ei: int) -> None:
        """Same sequence as the object engine's ``replan`` +
        ``DriftDetector.reset``: shared by drift, rate-switch and
        migration paths."""
        sub = edge_subtopology(
            topo, edges[ei], link_table, client_tier=tier_of[c]
        )
        plan, _ = cache.get_or_plan(
            comp_of[c],
            sub,
            policy,
            planner,
            codec=rates[c].model if rates is not None else None,
        )
        _set_plan(c, plan, topology_fingerprint(sub))
        drifted[c] = False
        rate_dirty[c] = False
        probe_n[c] = 0
        rings[c].clear()
        # pending rows belonged to the old plan; the detector reset
        # discards their evidence exactly like DriftDetector.reset
        applied_upto[c] = blk_pos[c]

    def _make_done(c: int, j: int, w_acc: float, arrived: float, service: float):
        """Per-member completion for batching servers — the vectorized
        twin of the object engine's ``placed`` closure (FIFO members
        never allocate one; their math is inlined at the visit event)."""

        def done(s_start: float, s_end: float) -> None:
            wait = w_acc + (s_start - arrived) + (s_end - (s_start + service))
            if tel is not None:
                tel.visit_placed(c, True, arrived, s_start, s_end, service)
            now = q.now
            if j + 1 < nvis[c]:
                vidx[c] = j + 1
                wait_acc[c] = wait
                kind = _K_VISIT
            else:
                wait_acc[c] = wait
                kind = _K_FINISH
            heapq.heappush(
                heap,
                (
                    s_end if s_end > now else now,
                    q.seq,
                    (c << _KIND_BITS) | kind,
                ),
            )
            q.seq += 1

        return done

    # --- admission (same call sequence as the object engine) --------------
    init_codec = RateController(codec).model if codec is not None else None
    ctx = DispatchContext(
        topo=topo,
        comp=comp_used,
        policy=policy,
        edges=edges,
        servers=servers,
        link_table=link_table,
        assignments={},
        codec=init_codec,
        media=media,
    )
    disp = make_dispatch(dispatch)
    # id-indexed admission memo: every client of one (edge, class,
    # workload) triple shares one plan/fingerprint; the object engine
    # re-derives them per client and counts a cache hit each time, so
    # the memo bumps the same counter to keep CacheStats identical
    admit_memo: Dict[Tuple, Tuple] = {}
    n_classes = len(client_classes) if client_classes else 0
    for c in range(N):
        tier_c = client_classes[c % n_classes] if n_classes else None
        tier_of[c] = tier_c
        comp_c = workloads[c % nw] if nw else comp_used
        comp_of[c] = comp_c
        key_of[c] = comp_c.name
        ctx.client_tier = tier_c
        ctx.comp = comp_c
        e = disp.assign(c, ctx)
        ctx.assignments[e] = ctx.assignments.get(e, 0) + 1
        rate = (
            RateController(codec, client_id=c) if codec is not None else None
        )
        if rates is not None:
            rates[c] = rate
        memo_key = (e, tier_c, c % nw if nw else 0)
        hit = admit_memo.get(memo_key)
        if hit is None:
            sub = edge_subtopology(topo, e, link_table, client_tier=tier_c)
            plan, _ = cache.get_or_plan(
                comp_c,
                sub,
                policy,
                planner,
                codec=rate.model if rate is not None else None,
            )
            fp = topology_fingerprint(sub)
            admit_memo[memo_key] = (plan, fp)
        else:
            plan, fp = hit
            cache.stats.hits += 1
            if cache.on_event is not None:
                cache.on_event("hit")
        edge_i[c] = edge_index[e]
        rngs[c] = np.random.default_rng(seed + c)
        zbuf[c] = np.empty(0)
        rings[c] = {}
        _set_plan(c, plan, fp)
    if tel is not None:
        home_cls = topo.tier(home).name
        tel.register_clients(
            {
                c: (tier_of[c].name if tier_of[c] is not None else home_cls)
                for c in range(N)
            }
        )
        tel.register_workloads({c: comp_of[c].name for c in range(N)})

    controller: Optional[MigrationController] = None
    if migration is not None:
        controller = MigrationController(
            migration,
            topo=topo,
            comp=comp_used,
            policy=policy,
            planner=planner,
            cache=cache,
            link_table=link_table,
            servers=servers,
            edges=edges,
            assignments=ctx.assignments,
            codec=init_codec,
            media=media,
        )

    # --- drift injections (sequence numbers follow the admission cohort's
    # reserved block, exactly as the object engine assigns them) ----------
    seq = N
    for di, d in enumerate(drifts):
        if isinstance(d, ServiceDrift) and d.edge not in servers:
            raise ValueError(f"ServiceDrift targets unknown edge {d.edge!r}")
        heapq.heappush(
            heap,
            (
                d.time if d.time > 0.0 else 0.0,
                seq,
                (di << _KIND_BITS) | _K_DRIFT,
            ),
        )
        seq += 1

    # probe-path fingerprint memo (local-plan clients ping their edge
    # link every `probe_every` frames; the fingerprint only changes when
    # the link table mutates, so key on its version)
    probe_fp: Dict[Tuple, object] = {}

    # --- the hot loop -----------------------------------------------------
    # drain the t=0 admission cohort without touching the heap (each
    # START was one scheduled+popped event on the object engine — the
    # reserved seq block and the processed count keep parity exact)
    processed = N
    for c in range(N):
        start_frame(c, 0.0)

    heappush = heapq.heappush
    heappop = heapq.heappop
    heapreplace = heapq.heapreplace
    # the loop allocates only tuples that die in order (heap events) and
    # bounded per-client buffers: cyclic collection finds nothing here,
    # but gen-0 passes would scan the whole SoA state every ~700 allocs
    gc_was_enabled = gc.isenabled()
    gc.disable()
    try:
        while heap:
            now, _sq, payload = heappop(heap)
            processed += 1
            kind = payload & _KIND_MASK
            c = payload >> _KIND_BITS
            if kind == _K_VISIT:
                if vidx[c] == 0 and up_media[c] and not up_paid[c]:
                    # shared-uplink admission — the object engine's
                    # visit() head, one reschedule when the cell queues
                    up_paid[c] = True
                    uw = 0.0
                    for med, svc in up_media[c]:
                        uw += med.admit(now, svc)
                    if uw > 0.0:
                        med_wait[c] += uw
                        wait_acc[c] += uw
                        heappush(
                            heap,
                            (now + uw, seq, (c << _KIND_BITS) | _K_VISIT),
                        )
                        seq += 1
                        continue
                vis = visits[c][vidx[c]]
                if vis[0]:  # FIFO SlotServer: admit inline over SoA state
                    si = vis[1]
                    service = vis[2]
                    scaled = service * srv_scale[si]
                    slots = srv_slots[si]
                    free = slots[0]
                    s_start = now if now >= free else free
                    s_end = s_start + scaled
                    heapreplace(slots, s_end)
                    fins = srv_fins[si]
                    heappush(fins, s_end)
                    adm_l[si] += 1
                    busy_l[si] += scaled
                    twl[si] += s_start - now
                    while fins and fins[0] <= now:
                        heappop(fins)
                    ld = len(fins)
                    if ld > peak_l[si]:
                        peak_l[si] = ld
                    if tel is not None:
                        # same order as SlotServer.admit + placed:
                        # occupancy sample, wait sample, visit record
                        tel.occupancy_sample(edges[si], now, ld)
                        tel.wait_sample(edges[si], now, s_start - now)
                        tel.visit_placed(c, False, now, s_start, s_end, service)
                    wait = (
                        wait_acc[c]
                        + (s_start - now)
                        + (s_end - (s_start + service))
                    )
                    j = vidx[c] + 1
                    if j < nvis[c]:
                        vidx[c] = j
                        nk = _K_VISIT
                    else:
                        nk = _K_FINISH
                    wait_acc[c] = wait
                    heappush(
                        heap,
                        (
                            s_end if s_end > now else now,
                            seq,
                            (c << _KIND_BITS) | nk,
                        ),
                    )
                    seq += 1
                else:
                    q.now = now
                    q.seq = seq
                    vis[4].submit(
                        now,
                        vis[2],
                        _make_done(c, vidx[c], wait_acc[c], now, vis[2]),
                        key=key_of[c],
                    )
                    seq = q.seq
            elif kind == _K_FINISH:
                i = pend_i[c]
                start = pend_start[c]
                wait = wait_acc[c]
                fin = (start + pend_sampled[c]) + wait
                if down_media[c] or (up_media[c] and not nvis[c]):
                    # downlink (and visit-less uplink) shared-medium
                    # admission — the object engine's finish() head
                    mw = 0.0
                    if not nvis[c]:
                        for med, svc in up_media[c]:
                            mw += med.admit(fin, svc)
                    for med, svc in down_media[c]:
                        mw += med.admit(fin, svc)
                    if mw > 0.0:
                        med_wait[c] += mw
                        wait += mw
                        fin += mw
                rec_i[c].append(i)
                rec_start[c].append(start)
                rec_fin[c].append(fin)
                next_i[c] = i + 1
                t_free[c] = fin
                twait[c] += wait
                if tel is not None:
                    tel.frame_done(
                        c,
                        i,
                        edges[edge_i[c]],
                        start,
                        fin,
                        plan_obj[c],
                        (
                            tuple(blk_D[c][pend_pos[c]].tolist())
                            if has_legs[c]
                            else ()
                        ),
                        link_wait=med_wait[c],
                    )
                if has_legs[c]:
                    fl = blk_fl[c][pend_pos[c]]
                    if fl:
                        if fl == 1 or _exact_observe(c, pend_pos[c]):
                            drifted[c] = True
                else:
                    pn = probe_n[c] + 1
                    if pn >= probe_every:
                        probe_n[c] = 0
                        pkey = (edge_i[c], tier_of[c], link_table.version)
                        fp = probe_fp.get(pkey)
                        if fp is None:
                            fp = topology_fingerprint(
                                edge_subtopology(
                                    topo,
                                    edges[edge_i[c]],
                                    link_table,
                                    client_tier=tier_of[c],
                                )
                            )
                            probe_fp[pkey] = fp
                        if fp != plan_fp_l[c]:
                            drifted[c] = True
                    else:
                        probe_n[c] = pn
                if rates is not None:
                    obs = (
                        tuple(zip(leg_links[c], blk_D[c][pend_pos[c]].tolist()))
                        if has_legs[c]
                        else ()
                    )
                    if (
                        rates[c].observe(
                            i, obs, plan_obj[c], cell_wait=med_wait[c]
                        )
                        is not None
                    ):
                        rate_dirty[c] = True
                if controller is not None:
                    if nvis[c]:
                        controller.observe_wait(edges[edge_i[c]], wait, now)
                    if next_i[c] < num_frames:
                        controller.frame_done(c)
                        move = controller.consider(
                            c,
                            edges[edge_i[c]],
                            now,
                            state_src=(
                                visits[c][0][3] if nvis[c] else home
                            ),
                            force=drifted[c],
                            codec=(
                                rates[c].model if rates is not None else None
                            ),
                            client_tier=tier_of[c],
                            comp=comp_of[c],
                        )
                        if move is not None:
                            target, mig_latency = move
                            if tel is not None:
                                tel.migration(
                                    c, fin, mig_latency, edges[edge_i[c]], target
                                )
                            edge_i[c] = edge_index[target]
                            migr_n[c] += 1
                            t_free[c] = fin + mig_latency
                            _replan(c, edge_i[c])
                start_frame(c, now)
            elif kind == _K_CALLBACK:
                q.now = now
                q.seq = seq
                cb = q.cbs[c]
                q.cbs[c] = None  # recycle: closed-over members can be GC'd
                cb()
                seq = q.seq
            else:  # _K_DRIFT
                d = drifts[c]
                if isinstance(d, ServiceDrift):
                    sv = servers[d.edge]
                    sv.service_scale = d.factor
                    srv_scale[edge_index[d.edge]] = d.factor
                else:
                    link_table.set(
                        d.link,
                        latency=d.latency,
                        jitter=d.jitter,
                        bandwidth=d.bandwidth,
                    )


    finally:
        if gc_was_enabled:
            gc.enable()
    # --- write the SoA stats back onto the FIFO SlotServer objects --------
    for si, sv in enumerate(server_list):
        if srv_fifo[si]:
            sv.admitted = adm_l[si]
            sv.busy_time = busy_l[si]
            sv.total_wait = twl[si]
            sv.peak_load = peak_l[si]

    # --- results ----------------------------------------------------------
    client_results = []
    for c in range(N):
        client_results.append(
            ClientResult(
                client=c,
                edge=edges[edge_i[c]],
                stats=ArrayLoopStats(
                    rec_i[c],
                    rec_start[c],
                    rec_fin[c],
                    num_frames,
                    period,
                ),
                plan=plan_obj[c],
                replans=replans_n[c],
                total_wait=twait[c],
                migrations=migr_n[c],
                rate_changes=(
                    rates[c].switches if rates is not None else 0
                ),
                codec=(rates[c].model if rates is not None else None),
            )
        )
    edge_loads = [
        EdgeLoad(
            name=e,
            capacity=servers[e].capacity,
            clients=ctx.assignments.get(e, 0),
            admitted=servers[e].admitted,
            busy_time=servers[e].busy_time,
            mean_wait=servers[e].mean_wait,
            batches=servers[e].batches,
            mean_batch_size=servers[e].mean_batch_size,
            peak_load=servers[e].peak_load,
        )
        for e in edges
    ]
    result = FleetResult(
        clients=client_results,
        edges=edge_loads,
        cache=cache,
        num_frames=num_frames,
        duration=max((c.stats.duration for c in client_results), default=0.0),
        migration=controller.stats if controller is not None else None,
        events=processed,
        links=[
            LinkLoad(
                name=m.name,
                capacity=m.capacity,
                admitted=m.admitted,
                contended=m.contended,
                busy_time=m.busy_time,
                total_wait=m.total_wait,
            )
            for m in media.values()
        ],
    )
    if tel is not None:
        tel.finish_run(
            result, rates=list(rates) if rates is not None else None
        )
        tel.detach(cache=cache, servers=server_list + list(media.values()))
    return result
