"""Dispatch policies: which edge box serves which client.

The fleet topology is a star — every client looks like the hub (the
paper's laptop), and several edge servers hang off it on their own
links.  Each client's *plan* is still the paper's two-machine problem:
home tier + one edge server; dispatch decides which edge that is.

Every policy runs in two regimes: once per client at admission (t=0),
and — when ``run_fleet(migration=...)`` arms the
:class:`~repro.cluster.migration.MigrationController` — again at every
mid-run re-dispatch consideration, where the live server state finally
differs from the assignment counts.

* ``round_robin``      — static striping, the baseline every serving
  stack starts with.
* ``least_queue``      — pick the edge with the fewest in-flight plus
  assigned requests (join-the-shortest-queue).  At admission (t=0) the
  live ``SlotServer`` load term is still zero and this reduces to
  assignment-count striping; at mid-run re-dispatch the in-flight term
  is real and the policy follows the actual queues.
* ``latency_weighted`` — price a plan against every edge with the
  occupancy-aware cost engine (queueing inflation from current
  assignments; on a ``batching`` tier that inflation is the sublinear
  ``BatchServiceModel`` amortization instead of processor sharing) and
  take the argmin predicted step latency.  This is the paper's RAPID
  "should I offload?" decision extended to "offload *where*?".
* ``batch_affinity``   — prefer the edge currently *gathering* the
  largest open batch *compatible with this client's computation*
  (joining a forming batch amortizes its launch and adds no extra
  queueing; a foreign-key batch is just queue ahead of us), then fall
  back to join-the-shortest-queue.
  Whenever no batch is open the policy reduces to ``least_queue``
  exactly — which covers non-batching edges and all admission-time
  placement.  As the migration controller's target policy it is *live*:
  a migrating client is steered toward the edge gathering an open batch
  under its computation key (tested in tests/test_migration.py).

All ties break on edge name, so every policy is deterministic.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Union

from repro.cluster.events import BatchingSlotServer, LinkTable, SlotServer
from repro.core import offload
from repro.core.offload import Policy, Topology
from repro.core.stages import StagedComputation


def edge_subtopology(
    topo: Topology,
    edge_name: str,
    link_table: Optional[LinkTable] = None,
    client_tier=None,
) -> Topology:
    """The two-tier view one client plans against: home + one edge.

    With ``link_table`` the link reflects current (possibly drifted)
    conditions, so re-planning calibrates against what the client will
    actually experience.  ``client_tier`` substitutes a heterogeneous
    client's own hardware for the star's default home tier (the hub
    models *any one* client's vantage point; a weaker client plans —
    and fingerprints — against its own silicon, so each hardware class
    misses into its own plan-cache entries by construction).
    """
    link = topo.link_between(topo.home, edge_name)
    if link_table is not None:
        link = link_table.get(link.name)
    home_tier = topo.tier(topo.home) if client_tier is None else client_tier
    return Topology(
        tiers={
            topo.home: home_tier,
            edge_name: topo.tier(edge_name),
        },
        links={(topo.home, edge_name): link},
        home=topo.home,
        wrapper=topo.wrapper,
        wrapped=topo.wrapped,
    )


@dataclasses.dataclass
class DispatchContext:
    """What a policy may look at when placing a client."""

    topo: Topology
    comp: StagedComputation
    policy: Policy
    edges: List[str]
    servers: Dict[str, Union[SlotServer, BatchingSlotServer]]
    link_table: LinkTable
    assignments: Dict[str, int]  # edge -> clients currently assigned
    now: float = 0.0
    codec: object = None  # CodecModel the fleet's clients ship under
    client_tier: object = None  # the asking client's own hardware (hetero)
    # medium name -> SharedLink: live occupancy of shared uplinks (cell /
    # backhaul).  None or empty when every spoke is private.
    media: Optional[Dict[str, object]] = None


class RoundRobinDispatch:
    name = "round_robin"

    def __init__(self) -> None:
        self._next = 0

    def assign(self, client_id: int, ctx: DispatchContext) -> str:
        edge = ctx.edges[self._next % len(ctx.edges)]
        self._next += 1
        return edge


class LeastQueueDispatch:
    name = "least_queue"

    def assign(self, client_id: int, ctx: DispatchContext) -> str:
        return min(
            ctx.edges,
            key=lambda e: (
                ctx.servers[e].load(ctx.now) + ctx.assignments.get(e, 0),
                e,
            ),
        )


class LatencyWeightedDispatch:
    name = "latency_weighted"

    def assign(self, client_id: int, ctx: DispatchContext) -> str:
        # live queue delay of each shared medium, priced onto any wire
        # leg that crosses it (probe-side only: the plan cache never
        # keys on backlog, and with no shared media this is None — the
        # exact historical probe)
        backlog = (
            {m: med.queue_delay(ctx.now) for m, med in ctx.media.items()}
            if ctx.media
            else None
        )

        def predicted(edge: str) -> float:
            sub = edge_subtopology(
                ctx.topo, edge, ctx.link_table, client_tier=ctx.client_tier
            )
            rep = offload.plan(
                ctx.comp,
                sub,
                ctx.policy,
                occupancy={edge: ctx.assignments.get(edge, 0)},
                codec=ctx.codec,
                link_backlog=backlog,
            )
            return rep.total_time

        return min(ctx.edges, key=lambda e: (predicted(e), e))


class BatchAffinityDispatch:
    """Join the edge gathering the largest open batch, else the
    shortest queue.  Open batches only exist while requests are in
    flight, so at ``run_fleet``'s t=0 admission-time placement this is
    ``least_queue``; as the migration controller's target policy the
    affinity term fires for real — migrating clients are steered toward
    edges with a forming batch under their computation key."""

    name = "batch_affinity"

    def assign(self, client_id: int, ctx: DispatchContext) -> str:
        # keyed by the computation this client would submit (run_fleet
        # submits key=comp.name): only a *compatible* open batch can be
        # joined; a foreign-key batch is just queue ahead of us
        return min(
            ctx.edges,
            key=lambda e: (
                -ctx.servers[e].open_batch_size(ctx.comp.name),
                ctx.servers[e].load(ctx.now) + ctx.assignments.get(e, 0),
                e,
            ),
        )


DISPATCH_POLICIES = {
    cls.name: cls
    for cls in (
        RoundRobinDispatch,
        LeastQueueDispatch,
        LatencyWeightedDispatch,
        BatchAffinityDispatch,
    )
}


def make_dispatch(name: str):
    if name not in DISPATCH_POLICIES:
        raise ValueError(
            f"unknown dispatch policy {name!r}; choose from "
            f"{sorted(DISPATCH_POLICIES)}"
        )
    return DISPATCH_POLICIES[name]()
