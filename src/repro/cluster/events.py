"""Discrete-event primitives for the fleet simulator.

The paper's experiment is one client against a dedicated server; the
fleet simulator replays N copies of that client against *shared* edge
servers, so per-request latency depends on who else is in the queue.
Three primitives make that exact and deterministic:

* :class:`EventQueue` — a time-ordered event heap.  Ties are broken by
  scheduling order (a monotone sequence number), so a run is a pure
  function of its inputs and seeds; there is no wall-clock anywhere.
* :class:`SlotServer` — a FIFO service resource with ``capacity``
  identical slots (the virtualized-accelerator model: an edge box that
  can serve ``capacity`` tracker requests concurrently at full speed).
  Because the event queue pops in time order, offering admissions at
  their arrival events yields exact FIFO-c queueing, not an averaged
  queueing formula.
* :class:`LinkTable` — the mutable ground-truth network conditions.
  Requests resample every :class:`~repro.core.costengine.LatencyLeg`
  the cost engine recorded for their plan against the *current* table,
  so per-request latencies are exact draws, and injected link drift
  makes observed legs deviate from the plan's predictions — the signal
  the plan cache's drift detector watches.

``LinkTable.sample_plan_latency`` intentionally replicates the exact
floating-point operation order of ``PlanReport.jittered_total`` so that
an undrifted single-client fleet reproduces ``sim.runtime.analytic_run``
bit-for-bit (asserted in tests/test_cluster.py).
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.costengine import PlanReport
from repro.core.topology import Link, Topology, sample_latency


@dataclasses.dataclass(order=True)
class _Event:
    time: float
    seq: int
    fn: Callable[[], None] = dataclasses.field(compare=False)


class EventQueue:
    """Deterministically ordered event heap with a simulated clock."""

    def __init__(self) -> None:
        self._heap: List[_Event] = []
        self._seq = 0
        self.now = 0.0

    def schedule(self, time: float, fn: Callable[[], None]) -> None:
        # clamp ulp-level rounding of canonical finish times (see
        # fleet.finish) so events never land microscopically in the past
        heapq.heappush(self._heap, _Event(max(time, self.now), self._seq, fn))
        self._seq += 1

    def run(self) -> None:
        while self._heap:
            ev = heapq.heappop(self._heap)
            self.now = ev.time
            ev.fn()


class SlotServer:
    """A FIFO resource with ``capacity`` identical service slots.

    Admissions MUST be offered in nondecreasing time order (the event
    queue guarantees this when callers admit at their arrival events);
    each admitted request occupies one slot for exactly its service
    time.  Tracks queue depth and utilization for dispatch policies and
    reports.
    """

    def __init__(self, name: str, capacity: int):
        self.name = name
        self.capacity = max(int(capacity), 1)
        self._slots = [0.0] * self.capacity  # slot free times (min-heap)
        heapq.heapify(self._slots)
        self._finishes: List[float] = []  # in-flight request finish times
        self.admitted = 0
        self.busy_time = 0.0
        self.total_wait = 0.0
        self._last_admit = float("-inf")

    def load(self, now: float) -> int:
        """Requests admitted but not yet finished at ``now``."""
        while self._finishes and self._finishes[0] <= now:
            heapq.heappop(self._finishes)
        return len(self._finishes)

    def admit(self, arrival: float, service: float) -> Tuple[float, float]:
        """Queue one request; returns (service_start, service_finish)."""
        if arrival < self._last_admit:
            raise ValueError(
                f"{self.name}: admissions out of order "
                f"({arrival} < {self._last_admit})"
            )
        self._last_admit = arrival
        free = heapq.heappop(self._slots)
        start = max(arrival, free)
        finish = start + service
        heapq.heappush(self._slots, finish)
        heapq.heappush(self._finishes, finish)
        self.admitted += 1
        self.busy_time += service
        self.total_wait += start - arrival
        return start, finish

    @property
    def mean_wait(self) -> float:
        return self.total_wait / self.admitted if self.admitted else 0.0


# one (link name, drawn latency) pair per plan leg — what a client
# actually observed, fed to the drift detector
ObservedLegs = Tuple[Tuple[str, float], ...]


class LinkTable:
    """Mutable ground-truth link conditions, seeded from a topology.

    Drift events overwrite entries in place; plan sampling and
    re-planning both read the current state, so a re-planned client is
    calibrated against the conditions it will actually experience.
    """

    def __init__(self, topo: Topology):
        self._links: Dict[str, Link] = {
            link.name: link for link in topo.links.values()
        }

    def get(self, name: str) -> Link:
        return self._links[name]

    def set(
        self,
        name: str,
        latency: Optional[float] = None,
        jitter: Optional[float] = None,
        bandwidth: Optional[float] = None,
    ) -> Link:
        old = self._links[name]
        new = Link(
            name=name,
            bandwidth=old.bandwidth if bandwidth is None else bandwidth,
            latency=old.latency if latency is None else latency,
            jitter=old.jitter if jitter is None else jitter,
        )
        self._links[name] = new
        return new

    def sample_plan_latency(
        self, plan: PlanReport, rng
    ) -> Tuple[float, ObservedLegs]:
        """One request's latency: the plan total with every recorded leg
        re-drawn from current conditions.

        Replicates ``PlanReport.jittered_total``'s float operation order
        (subtract the charged latency, add the draw, leg by leg), so
        with undrifted links the result — and the rng consumption — is
        bit-identical to the analytic simulator's.
        """
        t = plan.total_time
        observed: List[Tuple[str, float]] = []
        for leg in plan.legs:
            link = self._links.get(leg.link)
            if link is None:
                lat, jit = leg.latency, leg.jitter
            else:
                lat, jit = link.latency, link.jitter
            t -= leg.latency
            draw = sample_latency(lat, jit, rng)
            t += draw
            observed.append((leg.link, draw))
        return t, tuple(observed)
