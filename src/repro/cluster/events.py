"""Discrete-event primitives for the fleet simulator.

The paper's experiment is one client against a dedicated server; the
fleet simulator replays N copies of that client against *shared* edge
servers, so per-request latency depends on who else is in the queue.
Three primitives make that exact and deterministic:

* :class:`EventQueue` — a time-ordered event heap.  Ties are broken by
  scheduling order (a monotone sequence number), so a run is a pure
  function of its inputs and seeds; there is no wall-clock anywhere.
* :class:`SlotServer` — a FIFO service resource with ``capacity``
  identical slots (the virtualized-accelerator model: an edge box that
  can serve ``capacity`` tracker requests concurrently at full speed).
  Because the event queue pops in time order, offering admissions at
  their arrival events yields exact FIFO-c queueing, not an averaged
  queueing formula.
* :class:`BatchingSlotServer` — the fused-launch variant: compatible
  requests arriving within a ``gather_window`` accumulate into one
  batch, which then occupies a single slot for the
  :class:`~repro.core.costengine.BatchServiceModel` batch time (fixed
  launch overhead + sublinear per-item cost) and completes as a whole.
  A non-positive gather window degenerates to per-request batches of
  one served synchronously — exactly :class:`SlotServer`, event for
  event (the golden equivalence test in tests/test_batching.py).
* :class:`LinkTable` — the mutable ground-truth network conditions.
  Requests resample every :class:`~repro.core.costengine.LatencyLeg`
  the cost engine recorded for their plan against the *current* table,
  so per-request latencies are exact draws, and injected link drift
  makes observed legs deviate from the plan's predictions — the signal
  the plan cache's drift detector watches.

``LinkTable.sample_plan_latency`` intentionally replicates the exact
floating-point operation order of ``PlanReport.jittered_total`` so that
an undrifted single-client fleet reproduces ``sim.runtime.analytic_run``
bit-for-bit (asserted in tests/test_cluster.py).
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.costengine import BatchServiceModel, PlanReport
from repro.core.topology import Link, Topology, sample_latency


@dataclasses.dataclass(order=True)
class _Event:
    time: float
    seq: int
    fn: Callable[[], None] = dataclasses.field(compare=False)


class EventQueue:
    """Deterministically ordered event heap with a simulated clock.

    ``processed`` counts popped events — the denominator of the
    events/sec throughput number ``fleet_bench --events`` reports, and
    a structural invariant the vectorized engine must reproduce exactly
    (same event count, not just same results).
    """

    def __init__(self) -> None:
        self._heap: List[_Event] = []
        self._seq = 0
        self.now = 0.0
        self.processed = 0

    def schedule(self, time: float, fn: Callable[[], None]) -> None:
        # clamp ulp-level rounding of canonical finish times (see
        # fleet.finish) so events never land microscopically in the past
        heapq.heappush(self._heap, _Event(max(time, self.now), self._seq, fn))
        self._seq += 1

    def run(self) -> None:
        while self._heap:
            ev = heapq.heappop(self._heap)
            self.now = ev.time
            self.processed += 1
            ev.fn()


class SlotServer:
    """A FIFO resource with ``capacity`` identical service slots.

    Admissions MUST be offered in nondecreasing time order (the event
    queue guarantees this when callers admit at their arrival events);
    each admitted request occupies one slot for exactly its service
    time.  Tracks queue depth and utilization for dispatch policies and
    reports.
    """

    def __init__(self, name: str, capacity: int):
        self.name = name
        self.capacity = max(int(capacity), 1)
        self._slots = [0.0] * self.capacity  # slot free times (min-heap)
        heapq.heapify(self._slots)
        self._finishes: List[float] = []  # in-flight request finish times
        self.admitted = 0
        self.batches = 0  # uniform stats with BatchingSlotServer: never fuses
        self.busy_time = 0.0
        self.total_wait = 0.0
        self.peak_load = 0  # max concurrent in-flight seen at an admission
        self._last_admit = float("-inf")
        # optional repro.cluster.telemetry.Telemetry sink (occupancy
        # timeline samples, batch-size histograms); None is the golden
        # default
        self.telemetry = None
        # live service-time multiplier (thermal throttling injected by
        # fleet.ServiceDrift); 1.0 multiplies bit-exactly, so the
        # undrifted server is unchanged.  Plans never see this — only
        # measured waits do, which is what the migration controller's
        # wait-EWMA calibration exists to track.
        self.service_scale = 1.0

    def load(self, now: float) -> int:
        """Requests admitted but not yet finished at ``now``."""
        while self._finishes and self._finishes[0] <= now:
            heapq.heappop(self._finishes)
        return len(self._finishes)

    def admit(self, arrival: float, service: float) -> Tuple[float, float]:
        """Queue one request; returns (service_start, service_finish)."""
        if arrival < self._last_admit:
            raise ValueError(
                f"{self.name}: admissions out of order "
                f"({arrival} < {self._last_admit})"
            )
        self._last_admit = arrival
        service = service * self.service_scale
        free = heapq.heappop(self._slots)
        start = max(arrival, free)
        finish = start + service
        heapq.heappush(self._slots, finish)
        heapq.heappush(self._finishes, finish)
        self.admitted += 1
        self.busy_time += service
        self.total_wait += start - arrival
        ld = self.load(arrival)
        if ld > self.peak_load:
            self.peak_load = ld
        if self.telemetry is not None:
            self.telemetry.occupancy_sample(self.name, arrival, ld)
            self.telemetry.wait_sample(self.name, arrival, start - arrival)
        return start, finish

    @property
    def mean_wait(self) -> float:
        return self.total_wait / self.admitted if self.admitted else 0.0

    @property
    def mean_batch_size(self) -> float:
        return 0.0

    # --- uniform service API (shared with BatchingSlotServer) -----------

    def submit(
        self,
        arrival: float,
        service: float,
        done: Callable[[float, float], None],
        key=None,
    ) -> None:
        """Admit one request and invoke ``done(start, finish)``.

        Unbatched servers serve immediately, so the callback fires
        synchronously — callers schedule their continuation events from
        inside it, which keeps the event ordering identical to the
        historical ``admit``-then-schedule pattern.
        """
        del key  # no batching: compatibility is irrelevant
        start, finish = self.admit(arrival, service)
        done(start, finish)

    def open_batch_size(self, key=None) -> int:
        return 0


@dataclasses.dataclass(frozen=True)
class AdaptiveWindow:
    """Adaptive gather-window sizing for :class:`BatchingSlotServer`.

    A fixed gather window is pure added latency when an edge is idle:
    with one client per window there is nothing to fuse, yet every frame
    still dwells the full window before launch.  The adaptive policy
    sizes the window from a per-edge EWMA of observed inter-arrival
    times: when requests arrive densely (EWMA <= ``idle_factor`` x the
    configured window) fusing is profitable and the full window is
    kept; when arrivals are sparser than that, a newly opening batch
    serves immediately (window 0) — a batch of one, bit-for-bit the
    FIFO path — instead of paying the window as dead time.

    ``alpha`` — EWMA smoothing of each new inter-arrival sample.
    ``idle_factor`` — density threshold in units of the configured
    window (1.0: gather only while arrivals land inside one window).

    Joining an already-open batch is unaffected (its close event is
    scheduled); adaptivity only decides how long a *new* batch gathers.
    ``adaptive=None`` on the server is the exact off-switch: the fixed
    window is used unconditionally and no EWMA state is touched
    (golden-tested in tests/test_batching.py).
    """

    alpha: float = 0.25
    idle_factor: float = 1.0

    def __post_init__(self) -> None:
        if not 0.0 < self.alpha <= 1.0:
            raise ValueError("alpha must be in (0, 1]")
        if self.idle_factor <= 0.0:
            raise ValueError("idle_factor must be > 0")


class BatchingSlotServer:
    """A slot server that fuses compatible requests into batch launches.

    Requests arriving within ``gather_window`` of the first request of
    an open batch (per compatibility ``key``) accumulate; when the
    window closes the whole batch occupies ONE service slot for
    ``model.batch_time`` of its members' solo service times, and every
    member finishes at the batch finish — the event-level realization of
    the cost engine's batch-aware pricing.  Everything is scheduled on
    the shared :class:`EventQueue`, so runs remain pure functions of
    their inputs: batch closes fire in time order, members are served in
    arrival order, and no wall-clock exists anywhere.

    A non-positive ``gather_window`` serves each request synchronously
    as a batch of one — with ``batch_time([t]) == t`` by construction,
    that is bit-for-bit the FIFO :class:`SlotServer`.
    """

    def __init__(
        self,
        name: str,
        capacity: int,
        queue: EventQueue,
        model: Optional[BatchServiceModel] = None,
        gather_window: float = 0.0,
        adaptive: Optional[AdaptiveWindow] = None,
    ):
        self.name = name
        self.capacity = max(int(capacity), 1)
        self.model = model if model is not None else BatchServiceModel()
        self.gather_window = gather_window
        self.adaptive = adaptive
        self._ia_ewma: Optional[float] = None  # inter-arrival EWMA (adaptive)
        self._prev_arrival: Optional[float] = None
        self._queue = queue
        self._slots = [0.0] * self.capacity  # slot free times (min-heap)
        heapq.heapify(self._slots)
        self._finishes: List[float] = []  # in-flight request finish times
        # key -> gathering [(arrival, service, done), ...]
        self._open: Dict[object, List[Tuple[float, float, Callable]]] = {}
        self.admitted = 0
        self.batches = 0
        self.busy_time = 0.0
        self.total_wait = 0.0
        self.peak_load = 0  # max concurrent in-flight seen at an admission
        self._last_admit = float("-inf")
        # optional repro.cluster.telemetry.Telemetry sink (occupancy
        # timeline samples, batch-size histograms); None is the golden
        # default
        self.telemetry = None
        self.service_scale = 1.0  # same live throttle hook as SlotServer

    def load(self, now: float) -> int:
        """Requests admitted but not yet finished at ``now`` (both the
        gathering and the in-service ones)."""
        while self._finishes and self._finishes[0] <= now:
            heapq.heappop(self._finishes)
        gathering = sum(len(items) for items in self._open.values())
        return len(self._finishes) + gathering

    def open_batch_size(self, key=None) -> int:
        """Members of the currently gathering batch(es) — what a batch-
        affinity dispatcher wants to join."""
        if key is None:
            return sum(len(items) for items in self._open.values())
        return len(self._open.get(key, ()))

    def submit(
        self,
        arrival: float,
        service: float,
        done: Callable[[float, float], None],
        key=None,
    ) -> None:
        """Queue one request; ``done(service_start, service_finish)``
        fires when its batch is placed (synchronously for a zero
        window, at batch close otherwise)."""
        if arrival < self._last_admit:
            raise ValueError(
                f"{self.name}: admissions out of order "
                f"({arrival} < {self._last_admit})"
            )
        self._last_admit = arrival
        self.admitted += 1
        if self.adaptive is not None:
            # per-edge inter-arrival EWMA; fed on every admission, read
            # only when a NEW batch opens (joins are unaffected)
            if self._prev_arrival is not None:
                dt = arrival - self._prev_arrival
                a = self.adaptive.alpha
                self._ia_ewma = (
                    dt
                    if self._ia_ewma is None
                    else a * dt + (1.0 - a) * self._ia_ewma
                )
            self._prev_arrival = arrival
        # the throttle applies per ADMISSION (same semantics as
        # SlotServer): an item submitted before a ServiceDrift keeps
        # its nominal time even if its batch closes after the drift
        service = service * self.service_scale
        items = self._open.get(key) if self.gather_window > 0.0 else None
        if items is not None:
            items.append((arrival, service, done))
        else:
            window = self._effective_window()
            if window <= 0.0:
                self._serve(arrival, [(arrival, service, done)])
            else:
                self._open[key] = [(arrival, service, done)]
                self._queue.schedule(
                    arrival + window, lambda k=key: self._close(k)
                )
        ld = self.load(arrival)
        if ld > self.peak_load:
            self.peak_load = ld
        if self.telemetry is not None:
            self.telemetry.occupancy_sample(self.name, arrival, ld)

    def _effective_window(self) -> float:
        """Gather window for a batch opening now: the configured window,
        or 0 when adaptivity judges the edge too idle to fuse."""
        if self.adaptive is None or self._ia_ewma is None:
            return self.gather_window
        if self._ia_ewma <= self.adaptive.idle_factor * self.gather_window:
            return self.gather_window
        return 0.0

    def _close(self, key) -> None:
        self._serve(self._queue.now, self._open.pop(key))

    def _serve(
        self, ready: float, items: List[Tuple[float, float, Callable]]
    ) -> None:
        # member times were scaled at submit; the fused launch prices
        # them as-is (scale 1.0 is a bit-exact no-op throughout)
        batch_t = self.model.batch_time([svc for _, svc, _ in items])
        if self.telemetry is not None:
            self.telemetry.batch_sample(self.name, len(items))
        free = heapq.heappop(self._slots)
        start = max(ready, free)
        finish = start + batch_t
        heapq.heappush(self._slots, finish)
        self.batches += 1
        self.busy_time += batch_t
        for arrival, _, done in items:
            heapq.heappush(self._finishes, finish)
            self.total_wait += start - arrival
            if self.telemetry is not None:
                self.telemetry.wait_sample(self.name, arrival, start - arrival)
            done(start, finish)

    @property
    def mean_wait(self) -> float:
        return self.total_wait / self.admitted if self.admitted else 0.0

    @property
    def mean_batch_size(self) -> float:
        return self.admitted / self.batches if self.batches else 0.0


class SharedLink:
    """A contended shared transmission medium (cell sector / backhaul).

    The :class:`SlotServer` idea generalized to links: every wire leg
    crossing a link that names this medium occupies one of ``capacity``
    transmission slots for its full wire time.  Unlike a slot server,
    admissions are offered at each transmission's *uncontended
    completion time* (``due`` — the engines already charge the wire
    time inside the plan's sampled total), in event-pop order, and dues
    are NOT required to be monotone (downlink dues are finish times,
    which interleave).  :meth:`admit` returns the *extra* delay beyond
    ``due``:

    * a free slot can still complete the transmission by its due time
      (``free + service <= due``, i.e. the medium was idle when the
      transmission would have started) — the slot is held until ``due``
      and the extra delay is exactly ``0.0``, so the caller's untouched
      arithmetic path is bit-for-bit the private-spoke fleet;
    * otherwise the transmission serializes behind the queue: it
      completes at ``free + service`` and the difference is returned.

    ``capacity == 0`` is the *unlimited* medium: occupancy is counted
    (``admitted`` / ``busy_time``) but no slot state exists and the
    extra delay is always ``0.0`` — the off-switch golden in
    tests/test_contention.py.
    """

    def __init__(self, name: str, capacity: int = 0):
        self.name = name
        self.capacity = max(int(capacity), 0)
        self._slots = [0.0] * self.capacity  # slot free times (min-heap)
        heapq.heapify(self._slots)
        self.admitted = 0  # transmissions offered to the medium
        self.contended = 0  # transmissions that had to queue
        self.busy_time = 0.0  # total wire seconds carried
        self.total_wait = 0.0  # total extra delay imposed
        # optional repro.cluster.telemetry.Telemetry sink; None is the
        # golden default (hook sites guarded like the slot servers')
        self.telemetry = None

    def queue_delay(self, now: float) -> float:
        """Extra delay a transmission due now would see — the live
        occupancy signal dispatch and the migration predictor read."""
        if not self._slots:
            return 0.0
        free = self._slots[0]
        return free - now if free > now else 0.0

    def admit(self, due: float, service: float) -> float:
        """Offer one transmission of ``service`` wire seconds that
        would complete uncontended at ``due``; returns the extra delay
        (exactly ``0.0`` whenever the medium is uncontended)."""
        if service <= 0.0:
            return 0.0
        self.admitted += 1
        self.busy_time += service
        if not self._slots:  # unlimited: counted, never queued
            return 0.0
        free = self._slots[0]
        if free + service <= due:
            # idle slot: hold it through the transmission's own window
            # and return a literal 0.0 — no float round-trip via
            # (due - service) + service, which would not equal due
            heapq.heapreplace(self._slots, due)
            if self.telemetry is not None:
                self.telemetry.occupancy_sample(f"link.{self.name}", due, 0.0)
            return 0.0
        completion = free + service
        heapq.heapreplace(self._slots, completion)
        wait = completion - due
        self.contended += 1
        self.total_wait += wait
        if self.telemetry is not None:
            self.telemetry.occupancy_sample(f"link.{self.name}", due, wait)
        return wait

    @property
    def mean_wait(self) -> float:
        return self.total_wait / self.admitted if self.admitted else 0.0


def build_media(topo: Topology) -> Dict[str, SharedLink]:
    """One :class:`SharedLink` per distinct medium name the topology
    declares (insertion order; first declaration fixes the capacity).
    Empty on every private-spoke topology — the engines skip the whole
    contention path, which is what keeps it a zero-cost feature when
    off."""
    media: Dict[str, SharedLink] = {}
    for link in topo.links.values():
        if link.medium and link.medium not in media:
            media[link.medium] = SharedLink(link.medium, link.medium_capacity)
    return media


# one (link name, drawn latency) pair per plan leg — what a client
# actually observed, fed to the drift detector
ObservedLegs = Tuple[Tuple[str, float], ...]


class LinkTable:
    """Mutable ground-truth link conditions, seeded from a topology.

    Drift events overwrite entries in place; plan sampling and
    re-planning both read the current state, so a re-planned client is
    calibrated against the conditions it will actually experience.
    """

    def __init__(self, topo: Topology):
        self._links: Dict[str, Link] = {
            link.name: link for link in topo.links.values()
        }
        # bumped on every mutation: lets the vectorized engine's sampler
        # invalidate its pre-transformed latency blocks without
        # comparing Link values per frame
        self.version = 0

    def get(self, name: str) -> Link:
        return self._links[name]

    def lookup(self, name: str) -> Optional[Link]:
        """Like :meth:`get` but None for links outside the table (plan
        legs can reference links the fleet topology does not carry)."""
        return self._links.get(name)

    def set(
        self,
        name: str,
        latency: Optional[float] = None,
        jitter: Optional[float] = None,
        bandwidth: Optional[float] = None,
    ) -> Link:
        old = self._links[name]
        # dataclasses.replace-style reconstruction: drift only touches
        # the wire parameters, shared-medium membership is preserved
        new = Link(
            name=name,
            bandwidth=old.bandwidth if bandwidth is None else bandwidth,
            latency=old.latency if latency is None else latency,
            jitter=old.jitter if jitter is None else jitter,
            medium=old.medium,
            medium_capacity=old.medium_capacity,
        )
        self._links[name] = new
        self.version += 1
        return new

    def sample_plan_latency(
        self, plan: PlanReport, rng
    ) -> Tuple[float, ObservedLegs]:
        """One request's latency: the plan total with every recorded leg
        re-drawn from current conditions.

        Replicates ``PlanReport.jittered_total``'s float operation order
        (subtract the charged latency, add the draw, leg by leg), so
        with undrifted links the result — and the rng consumption — is
        bit-identical to the analytic simulator's.

        A probabilistic leg (``LatencyLeg.weight`` < 1, from a
        conditional branch) contributes ``weight * draw`` to the total
        while the *observed* draw stays unscaled — drift detection and
        rate control compare draws against live link parameters, which
        know nothing of branch probabilities.
        """
        t = plan.total_time
        observed: List[Tuple[str, float]] = []
        for leg in plan.legs:
            link = self._links.get(leg.link)
            if link is None:
                lat, jit = leg.latency, leg.jitter
            else:
                lat, jit = link.latency, link.jitter
            draw = sample_latency(lat, jit, rng)
            if leg.weight == 1.0:
                t -= leg.latency
                t += draw
            else:
                t -= leg.weight * leg.latency
                t += leg.weight * draw
            observed.append((leg.link, draw))
        return t, tuple(observed)
