"""Live migration: mid-run client re-dispatch with hysteresis.

The paper's client picks an offload target once and lives with it — the
exact thing it flags as "to be improved for achieving even better
performance".  AVEC-style virtualized edge accelerators only stay
utilized when clients can be *re-homed* as load shifts, so this module
closes the loop the fleet simulator left open: placement becomes
placement-over-time.

:class:`MigrationController` watches, per considered client,

* **per-edge load** — live slot-server queue depth (``load(now)``) and
  open-batch occupancy (``open_batch_size(key)``), the same signals the
  dispatch policies read; and
* **per-client link drift** — surfaced by the fleet's existing
  :class:`~repro.cluster.plancache.DriftDetector`: a drifted client is
  considered immediately (the dwell gate is waived via ``force=True``)
  because its link genuinely changed under it.

A re-dispatch decision has three parts, all deterministic:

1. **Target selection** (``target_policy``).  The default,
   ``"predicted"``, takes the argmin of the live predicted per-frame
   time over all edges — cached plan total (so a *slower* edge is worse
   even when its queue is short, which pure queue-count policies cannot
   see) plus the live queueing excess, minus a batch-affinity credit on
   edges gathering an open batch under the client's computation key:
   ``batch_affinity``'s steering, live.  Any dispatch policy name
   (``least_queue``, ``batch_affinity``, ...) can be used instead; the
   policies that reduced to striping at t=0 admission finally see real
   queue depths and forming batches here.
2. **Hysteresis** gates the move: the client must have *dwelled* at
   least ``min_dwell_frames`` processed frames on its current edge
   (unless drift-forced), and the predicted per-frame time on the
   target must beat the current edge's by more than
   ``improvement_threshold`` (relative).  Thresholds at infinity turn
   migration off exactly — the run is bit-for-bit the static fleet
   (golden-tested), and migration count is monotone non-increasing in
   the dwell (property-tested).
3. **State transfer** is priced like any other leg: the client's warm
   tracker state — hand-model pose + PSO swarm payload
   (:func:`tracker_state_nbytes`) — crosses from the tier that holds it
   (the old edge, or home for a fully-local plan) to the new edge via
   :meth:`~repro.core.costengine.CostEngine.migration_time` (RPC
   envelope + serialization + wire over the current, possibly drifted,
   links).  The fleet charges that latency to the client before its
   next frame, and re-plans it through the shared
   :class:`~repro.cluster.plancache.PlanCache`.

The *prediction* the hysteresis gate uses is the cached plan total for
the candidate edge, inflated by the cost engine's occupancy model for
the load ahead of us — committed clients (assignment counts) or live
queue depth, whichever is deeper; fused batch time on batching tiers —
minus a batch-affinity credit on edges gathering a compatible open
batch (joining skips part of the gather-window dwell a fresh batch
would pay).  Candidate scoring uses stats-neutral cache lookups so the
cache hit-rate keeps measuring actual per-client planning work.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.cluster.dispatch import (
    DISPATCH_POLICIES,
    DispatchContext,
    edge_subtopology,
    make_dispatch,
)
from repro.cluster.events import LinkTable
from repro.cluster.plancache import PlanCache
from repro.core.costengine import BatchServiceModel, CostEngine
from repro.core.offload import Policy, Topology
from repro.core.stages import StagedComputation


def tracker_state_nbytes(
    num_particles: int = 64, pose_dims: int = 27, dtype_bytes: int = 4
) -> int:
    """The warm per-client state a migration must ship.

    Hand-model pose (27 f32 — the 108-byte ``h_prev`` the staged
    computation carries) plus the PSO swarm payload: per-particle
    position, velocity and personal best, and the swarm's global best.
    Defaults match the paper-scale tracker (64 particles, 27-dim pose).
    """
    swarm = num_particles * 3 * pose_dims
    return dtype_bytes * (pose_dims + swarm + pose_dims)


DEFAULT_STATE_NBYTES = tracker_state_nbytes()


@dataclasses.dataclass(frozen=True)
class MigrationConfig:
    """Hysteresis knobs and state-payload size for live migration.

    ``min_dwell_frames`` — processed frames a client must sit on its
    current edge before a (non-drift-forced) move is considered; the
    flap brake.  ``improvement_threshold`` — relative predicted-latency
    improvement the target must clear (0.15 = "15% better or stay");
    ``float('inf')`` disables migration exactly.  ``state_nbytes`` —
    the migrating pose + swarm payload.  ``target_policy`` — how the
    candidate edge is picked: ``"predicted"`` (default) is the argmin
    of :meth:`MigrationController.predicted_frame_time` (live load +
    batch affinity + per-edge plan cost), or a load-aware
    ``dispatch.DISPATCH_POLICIES`` name to run that policy live
    (``round_robin`` is rejected: its blind rotation is meaningless as
    a re-dispatch target).  ``wait_ewma_blend`` / ``wait_ewma_alpha`` —
    predictor calibration against *measured* per-edge waits (see
    :meth:`MigrationController.observe_wait`); the default blend of 0
    is the exact historical model-only predictor.
    """

    min_dwell_frames: int = 30
    improvement_threshold: float = 0.15
    state_nbytes: int = DEFAULT_STATE_NBYTES
    target_policy: str = "predicted"
    # predictor calibration: blend a per-edge EWMA of *measured* frame
    # waits into the occupancy term.  Plan totals + live queue depth
    # cannot see an edge whose service times drifted (thermal
    # throttling: the same queue drains slower) — measured waits can.
    # ``wait_ewma_blend`` is the measured share (0 = pure model, the
    # exact historical predictor; 1 = pure measurement);
    # ``wait_ewma_alpha`` the EWMA smoothing of each new wait sample.
    wait_ewma_blend: float = 0.0
    wait_ewma_alpha: float = 0.25
    # measured evidence ages: the blend weight halves every this many
    # simulated seconds since an edge's last wait sample, so a stale
    # measurement (e.g. an evacuated edge whose throttle may have
    # ended) gradually hands the prediction back to the model instead
    # of repelling clients forever.  inf freezes evidence (no decay).
    wait_ewma_half_life: float = 3.0

    def __post_init__(self) -> None:
        if self.min_dwell_frames < 0:
            raise ValueError("min_dwell_frames must be >= 0")
        if self.improvement_threshold < 0.0:
            raise ValueError("improvement_threshold must be >= 0")
        if self.state_nbytes < 0:
            raise ValueError("state_nbytes must be >= 0")
        if not 0.0 <= self.wait_ewma_blend <= 1.0:
            raise ValueError("wait_ewma_blend must be in [0, 1]")
        if not 0.0 < self.wait_ewma_alpha <= 1.0:
            raise ValueError("wait_ewma_alpha must be in (0, 1]")
        if self.wait_ewma_half_life <= 0.0:
            raise ValueError("wait_ewma_half_life must be > 0")
        # round_robin's stateful rotation carries no load/latency signal:
        # as a live re-dispatch target it proposes edges blindly in cycle
        valid = {"predicted"} | (set(DISPATCH_POLICIES) - {"round_robin"})
        if self.target_policy not in valid:
            raise ValueError(
                f"target_policy {self.target_policy!r} not usable for "
                f"live re-dispatch; choose one of {sorted(valid)}"
            )


@dataclasses.dataclass(frozen=True)
class MigrationRecord:
    """One completed re-dispatch."""

    client: int
    time: float
    src: str  # edge assignment before the move
    dst: str  # edge assignment after the move
    state_src: str  # tier the warm state shipped from (old edge or home)
    nbytes: int
    latency: float  # priced state-transfer time charged to the client


@dataclasses.dataclass
class MigrationStats:
    """What the controller did — returned in ``FleetResult.migration``
    and surfaced per sweep point by ``capacity_sweep``."""

    records: List[MigrationRecord] = dataclasses.field(default_factory=list)
    considered: int = 0  # considerations that passed the dwell gate
    # decision accounting (telemetry): dwell-gated asks, and post-dwell
    # considerations that found no target clearing the improvement
    # threshold (staying put counts — the best target failed to beat
    # the current edge by the hysteresis margin).  accepted == count.
    rejected_dwell: int = 0
    rejected_threshold: int = 0

    @property
    def count(self) -> int:
        return len(self.records)

    @property
    def total_bytes(self) -> int:
        return sum(r.nbytes for r in self.records)

    @property
    def total_latency(self) -> float:
        return sum(r.latency for r in self.records)

    @property
    def mean_latency(self) -> float:
        return self.total_latency / self.count if self.count else 0.0

    def per_client(self) -> Dict[int, int]:
        counts: Dict[int, int] = {}
        for r in self.records:
            counts[r.client] = counts.get(r.client, 0) + 1
        return counts


class MigrationController:
    """Decides, at frame boundaries, whether a client moves edges.

    Shares the fleet's live objects — servers, link table, plan cache,
    assignment counts — so its observations are exactly what the event
    engine measures.  All methods are deterministic; ties in target
    selection break on edge name through the dispatch policies.
    """

    def __init__(
        self,
        config: MigrationConfig,
        topo: Topology,
        comp: StagedComputation,
        *,
        servers: Dict[str, object],
        policy: Policy = Policy.AUTO,
        planner: Optional[str] = None,
        cache: Optional[PlanCache] = None,
        link_table: Optional[LinkTable] = None,
        edges: Optional[List[str]] = None,
        assignments: Optional[Dict[str, int]] = None,
        codec=None,
        media: Optional[Dict[str, object]] = None,
    ):
        self.config = config
        self.topo = topo
        self.comp = comp
        self.policy = policy
        self.planner = planner
        self.cache = cache if cache is not None else PlanCache()
        self.link_table = link_table if link_table is not None else LinkTable(topo)
        self.servers = servers
        self.edges = list(edges) if edges is not None else [
            n for n in topo.tier_names() if n != topo.home
        ]
        self.assignments = (
            assignments
            if assignments is not None
            else {e: 0 for e in self.edges}
        )
        self.home = topo.home
        self.key = comp.name
        # the CodecModel candidate plans are priced under (the fleet
        # passes each client's live operating point per `consider`; this
        # is the fleet-level default for direct use)
        self.codec = codec
        # shared-medium occupancy (medium name -> SharedLink).  The
        # per-edge spoke medium is resolved once; its live queue_delay
        # joins the prediction OUTSIDE the scoring memo (occupancy is a
        # time-varying signal, never part of a plan's identity).  With
        # no shared media both are empty and the predictor is exact.
        self.media = media if media is not None else {}
        self._edge_medium = {
            e: self.media.get(
                topo.link_between(topo.home, e).medium
            )
            for e in self.edges
        }
        self._disp = (
            None
            if config.target_policy == "predicted"
            else make_dispatch(config.target_policy)
        )
        self._ctx = DispatchContext(
            topo=topo,
            comp=comp,
            policy=policy,
            edges=self.edges,
            servers=self.servers,
            link_table=self.link_table,
            assignments=self.assignments,
            media=self.media or None,
        )
        self._dwell: Dict[int, int] = {}
        # per-edge (EWMA, last-sample time) of measured per-frame waits
        # (queue + gather dwell + batch/throttle service inflation) —
        # the calibration signal `wait_ewma_blend` mixes into the
        # occupancy term, down-weighted as the evidence ages
        self._wait_ewma: Dict[str, Tuple[float, float]] = {}
        # scoring memo: (edge, current Link value) -> (plan, remote
        # service).  Post-dwell the controller scores every edge at
        # every frame finish; the inputs only change when a link drifts
        # (a drifted link is a NEW frozen Link value, so stale entries
        # can never be hit), so memoizing skips the subtopology build +
        # fingerprint + cache lookup on the hot stay-put path.
        self._scores: Dict[Tuple, Tuple] = {}
        self._batch_models = {
            e: BatchServiceModel.from_tier(topo.tier(e))
            for e in self.edges
            if topo.tier(e).batching
        }
        self.stats = MigrationStats()

    # -- dwell bookkeeping --------------------------------------------------

    def frame_done(self, client: int) -> None:
        """One processed frame of dwell on the client's current edge."""
        self._dwell[client] = self._dwell.get(client, 0) + 1

    def dwell(self, client: int) -> int:
        return self._dwell.get(client, 0)

    # -- measured-wait calibration -------------------------------------------

    def observe_wait(self, edge: str, wait: float, now: float = 0.0) -> None:
        """Feed one processed frame's measured non-plan time on
        ``edge`` (the fleet reports every frame finish).  Maintains the
        per-edge EWMA that ``wait_ewma_blend`` mixes into the
        predictor; with the blend at 0 the samples are recorded but
        never read, so the default predictor is bit-for-bit unchanged."""
        a = self.config.wait_ewma_alpha
        prev = self._wait_ewma.get(edge)
        self._wait_ewma[edge] = (
            wait if prev is None else a * wait + (1.0 - a) * prev[0],
            now,
        )

    def wait_ewma(self, edge: str) -> float:
        entry = self._wait_ewma.get(edge)
        return entry[0] if entry is not None else 0.0

    # -- prediction ---------------------------------------------------------

    def predicted_frame_time(
        self,
        edge: str,
        now: float,
        current: Optional[str] = None,
        codec=None,
        client_tier=None,
        comp: Optional[StagedComputation] = None,
    ) -> float:
        """What one frame would cost a client placed on ``edge`` now.

        Cached plan total under current link conditions — so a *slower*
        edge prices worse even with a short queue — inflated by the
        cost engine's occupancy model for the load ahead of us: the
        clients committed to the edge (assignment count, the smooth
        steady-state signal) or the requests actually in flight
        (``load(now)``, which dominates while a drained edge's queue is
        still emptying), whichever is deeper.  Pass ``current`` (the
        asking client's edge) so the mover does not count against
        itself.  Batching tiers price occupancy as the fused batch time
        of occ+1 items (the cost engine's model), and an edge gathering
        a compatible open batch earns a strict credit — joining it
        skips part of the gather-window dwell a fresh batch would pay —
        which is what steers migrating clients into forming batches.

        ``codec`` prices candidate plans at the asking client's codec
        operating point (compressed payloads change which edge wins on
        asymmetric links).  With ``wait_ewma_blend > 0`` the occupancy
        excess is blended with the edge's measured-wait EWMA — the
        calibration that catches *service-side* drift (a throttled edge
        serves the same queue slower; plan totals and queue depth alone
        mispredict it, tested in tests/test_migration.py)."""
        if comp is None:
            comp = self.comp
        link = self.link_table.get(
            self.topo.link_between(self.topo.home, edge).name
        )
        # client_tier joins the memo key: a heterogeneous fleet scores
        # each hardware class against its own plans (frozen Tier values
        # hash directly, like the frozen Link / CodecModel entries).
        # comp name too: a mixed fleet scores each workload against its
        # own plans (names are unique within a registry, and the cached
        # plan itself is still keyed on the full comp signature).
        memo_key = (edge, link, codec, client_tier, comp.name)
        cached = self._scores.get(memo_key)
        if cached is None:
            sub = edge_subtopology(
                self.topo, edge, self.link_table, client_tier=client_tier
            )
            plan, _ = self.cache.get_or_plan(
                comp,
                sub,
                self.policy,
                self.planner,
                record_stats=False,
                codec=codec,
            )
            service = sum(
                t for tier, t in plan.compute_by_tier if tier != self.home
            )
            self._scores[memo_key] = cached = (plan, service)
        plan, service = cached
        t = plan.total_time
        srv = self.servers[edge]
        if service > 0.0:
            cap = max(int(srv.capacity), 1)
            others = self.assignments.get(edge, 0) - (1 if edge == current else 0)
            occ = max(others, srv.load(now), 0)
            model = self._batch_models.get(edge)
            credit = 0.0
            if model is not None:
                # co-assigned clients ride the same fused launch: price
                # occupancy as the cost engine does — the batch time of
                # occ+1 items — not as processor sharing.  The summed
                # remote service is treated as ONE launch; a multi-stage
                # remote plan would pay the fixed batch overhead per
                # stage under the engine's per-stage pricing (the
                # processor-sharing branch below has no such gap: its
                # inflation factor is linear, so stage-wise and summed
                # inflation agree exactly)
                excess = model.batch_time([service] * (occ + 1)) - service
                if srv.open_batch_size(comp.name) > 0:
                    # a compatible batch is gathering RIGHT NOW: joining
                    # it skips ~half the gather-window dwell a fresh
                    # batch would pay — a small strict credit that
                    # breaks equal-load ties toward forming batches
                    credit = 0.5 * getattr(srv, "gather_window", 0.0)
            else:
                # contention_factor semantics: occ+1 requests, cap slots
                excess = service * max(0.0, (occ + 1) / cap - 1.0)
            blend = self.config.wait_ewma_blend
            measured = self._wait_ewma.get(edge)
            if blend > 0.0 and measured is not None:
                # the model term and the measured EWMA estimate the SAME
                # quantity (per-frame non-plan time); the blend decides
                # whose evidence to trust, down-weighted by the sample's
                # age so an edge nobody visits anymore (e.g. evacuated
                # after a throttle) hands the prediction back to the
                # model instead of repelling clients forever.  Guarded
                # so blend == 0 keeps the exact historical arithmetic.
                value, t_obs = measured
                age = max(0.0, now - t_obs)
                w = blend * 0.5 ** (age / self.config.wait_ewma_half_life)
                excess = (1.0 - w) * excess + w * value
            t += excess
            t -= credit
        med = self._edge_medium.get(edge)
        if med is not None:
            # live shared-uplink backlog on this edge's spoke: a
            # congested cell repels movers exactly like a deep queue.
            # Deliberately outside the scoring memo (occupancy is not
            # plan identity) and exactly 0.0 on an idle medium.
            t += med.queue_delay(now)
        return t

    # -- state-transfer pricing ---------------------------------------------

    def migration_time(
        self, state_src: str, dst: str, codec=None
    ) -> float:
        """Price the pose + swarm transfer over *current* link
        conditions (drifted links charge their drifted latency).  With
        a codec the state ships at the engine's keyframe pricing — the
        destination has no reference to delta against."""
        live = Topology(
            tiers=dict(self.topo.tiers),
            links={
                pair: self.link_table.get(link.name)
                for pair, link in self.topo.links.items()
            },
            home=self.topo.home,
            wrapper=self.topo.wrapper,
            wrapped=self.topo.wrapped,
        )
        engine = CostEngine(
            live, codec=codec if codec is not None else self.codec
        )
        return engine.migration_time(self.config.state_nbytes, state_src, dst)

    # -- the decision -------------------------------------------------------

    def consider(
        self,
        client: int,
        current: str,
        now: float,
        state_src: Optional[str] = None,
        force: bool = False,
        codec=None,
        client_tier=None,
        comp: Optional[StagedComputation] = None,
    ) -> Optional[Tuple[str, float]]:
        """Should ``client`` move off ``current``?  Returns ``(target,
        state_transfer_latency)`` and records the migration, or None.

        ``force=True`` (link drift) waives the dwell gate — the link
        changed under the client, so its placement is stale evidence —
        but never the improvement threshold: hysteresis still decides.
        ``codec`` is the asking client's live operating point: candidate
        plans and the state transfer are priced under it (None falls
        back to the controller's fleet-level default).  ``client_tier``
        is the asking client's own hardware class in a heterogeneous
        fleet: candidate plans are priced against it.  ``comp`` is the
        asking client's own workload in a mixed fleet: candidate plans,
        batch-affinity credits and the live dispatch policies all see
        the client's actual pipeline (None falls back to the
        controller's fleet-level default).
        """
        if codec is None:
            codec = self.codec
        if comp is None:
            comp = self.comp
        if not force and self._dwell.get(client, 0) < self.config.min_dwell_frames:
            self.stats.rejected_dwell += 1
            return None
        self.stats.considered += 1
        if self._disp is not None:
            # run the configured dispatch policy live; the mover itself
            # must not count against its own current edge, and the
            # policy must price candidates under the SAME codec the
            # hysteresis check uses (latency_weighted plans through it)
            self._ctx.now = now
            self._ctx.codec = codec
            self._ctx.client_tier = client_tier
            self._ctx.comp = comp
            orig = self.assignments.get(current, 0)
            self.assignments[current] = max(0, orig - 1)
            try:
                target = self._disp.assign(client, self._ctx)
            finally:
                self.assignments[current] = orig
            if target == current:
                self.stats.rejected_threshold += 1
                return None
            cur_t = self.predicted_frame_time(
                current, now, current, codec, client_tier, comp
            )
            new_t = self.predicted_frame_time(
                target, now, current, codec, client_tier, comp
            )
        else:
            times = {
                e: self.predicted_frame_time(
                    e, now, current, codec, client_tier, comp
                )
                for e in self.edges
            }
            target = min(self.edges, key=lambda e: (times[e], e))
            if target == current:
                self.stats.rejected_threshold += 1
                return None
            cur_t, new_t = times[current], times[target]
        # strict inequality, and (1 - inf) * cur_t == -inf: an infinite
        # threshold can never be cleared, which is the exact off-switch
        if not new_t < cur_t * (1.0 - self.config.improvement_threshold):
            self.stats.rejected_threshold += 1
            return None
        src = state_src if state_src is not None else self.home
        latency = self.migration_time(src, target, codec)
        self.stats.records.append(
            MigrationRecord(
                client=client,
                time=now,
                src=current,
                dst=target,
                state_src=src,
                nbytes=self.config.state_nbytes,
                latency=latency,
            )
        )
        self._dwell[client] = 0
        self.assignments[current] = max(0, self.assignments.get(current, 0) - 1)
        self.assignments[target] = self.assignments.get(target, 0) + 1
        return target, latency
