"""Online SLO monitoring + root-cause attribution (the "fleet doctor").

The fleet so far reports aggregate fps / drop / p99 *after* the run and
PR 7's span traces are post-hoc artifacts a human must read.  This
module closes the loop: the fleet watches its own SLOs **online** —
inside the event loop, on both engines, event-for-event identically —
and when a service-level objective burns down it opens a timestamped
:class:`Incident` and *explains* it by diffing the incident window's
span/metric profile against the rolling healthy baseline.

Pieces:

* :class:`SLOClass` — a deadline/attainment objective attached to each
  ``core/workloads.py`` registry entry via ``WORKLOAD_SLO`` (interactive
  AR landmark tracking vs best-effort gesture analytics).
* :class:`WindowedQuantile` — deterministic streaming quantile over the
  last ``window`` observations using the same fixed-log-bucket
  discretization as :class:`~repro.cluster.telemetry.Histogram`.
  Documented error bound (property-tested in tests/test_slo.py): for an
  exact sorted-window quantile ``v`` with ``lo < v <= top`` the estimate
  ``e`` satisfies ``v <= e <= v * growth``; values at or below ``lo``
  clamp to ``lo`` and values above the top bound clamp to it.
* :class:`BurnGauge` — streaming attainment over an SRE-style pair of
  windows (fast + slow).  The *burn rate* is the observed miss fraction
  divided by the error budget ``1 - target``; an incident opens when
  BOTH windows burn above their thresholds (fast catches the spike,
  slow filters blips) and closes with hysteresis when the fast window
  drops back under budget (burn < 1).  Dropped frames — holes in the
  per-client frame-index sequence — count as deadline misses, so a
  fault that *drops* frames (a migration flap's blackouts) breaches the
  SLO even though every processed frame's loop time looks healthy.
* :class:`SLOMonitor` — a :class:`~repro.cluster.telemetry.Telemetry`
  subclass (``run_fleet(slo=SLOMonitor())``): same hooks, same spans,
  plus the online estimators, incident lifecycle, and the root-cause
  attributor.  ``slo=None`` is bit-for-bit the unmonitored fleet
  (every hook site is already guarded); and because both engines call
  the hooks with bit-identical inputs in the same order, the incident
  log — causes, timestamps, report bytes — is engine-independent
  (gated in ``fleet_bench --doctor``).
* ``FAULTS`` — the fault-injection catalog validating the doctor *by
  construction*: each :class:`FaultSpec` names the drift schedule that
  induces it and the cause label the doctor must rank first.

Root-cause model: every processed frame's span tuple is folded into
per-category seconds (see :data:`CATEGORIES` — queue-wait and
batch-gather merge into ``queueing``, uplink and downlink into
``network``, the shared-medium delay is carved out as ``cell``),
migration blackouts become a ``blackout`` pseudo-category (seconds per
frame, charged to the inter-frame gap), and per-edge / per-medium wait
samples localize the winning category to a locus —
``queueing@edge_1``, ``cell@cell0``, ``network@edge_0``.  Scores are
*per-frame excess seconds* vs the healthy baseline, so categories
compete in one unit.
"""

from __future__ import annotations

import dataclasses
import json
import math
from bisect import bisect_left
from typing import Dict, List, Optional, Tuple

from repro.cluster.fleet import LinkDrift, ServiceDrift
from repro.cluster.migration import MigrationConfig
from repro.cluster.telemetry import SPAN_ORDER, Telemetry
from repro.core.workloads import WORKLOAD_SLO

__all__ = [
    "SLOClass",
    "INTERACTIVE",
    "BEST_EFFORT",
    "SLO_CLASSES",
    "slo_of",
    "WindowedQuantile",
    "BurnGauge",
    "Cause",
    "Incident",
    "SLOMonitor",
    "FaultSpec",
    "FAULTS",
    "DOCTOR_CLASSES",
    "doctor_verdict",
]


# ---------------------------------------------------------------------------
# SLO classes
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SLOClass:
    """A deadline/attainment objective for one traffic class.

    ``deadline_s`` — per-frame loop-time deadline.
    ``target`` — required fraction of frames meeting it (the error
    budget is ``1 - target``).
    ``window`` — slow attainment window, in frames (also the quantile
    estimator's window).  Until ``window`` frames arrive the slow ratio
    is taken over what has been seen — short CI runs must still alert.
    ``fast_window`` — spike-detection window, in frames; must not
    exceed ``window`` (the slow ring backs both sums).
    ``fast_burn`` / ``slow_burn`` — burn-rate thresholds (multiples of
    the error budget) both windows must exceed to open an incident.
    """

    name: str
    deadline_s: float
    target: float
    window: int = 256
    fast_window: int = 32
    fast_burn: float = 6.0
    slow_burn: float = 3.0

    def __post_init__(self) -> None:
        if not 0.0 < self.target < 1.0:
            raise ValueError("target must be in (0, 1)")
        if self.deadline_s <= 0.0:
            raise ValueError("deadline_s must be > 0")
        if not 1 <= self.fast_window <= self.window:
            raise ValueError("need 1 <= fast_window <= window")

    @property
    def budget(self) -> float:
        return 1.0 - self.target


# The paper's feasibility claim as an SLO: interactive hand tracking
# must hold camera-real-time deadlines; the gesture-analytics head is
# best-effort — late labels degrade gracefully, so its budget is wide.
INTERACTIVE = SLOClass("interactive", deadline_s=60e-3, target=0.95)
BEST_EFFORT = SLOClass("best_effort", deadline_s=120e-3, target=0.80)

SLO_CLASSES: Dict[str, SLOClass] = {
    c.name: c for c in (INTERACTIVE, BEST_EFFORT)
}


def slo_of(workload: str) -> SLOClass:
    """SLO class of a registry workload (interactive when unmapped —
    unknown pipelines get the strict deadline, not a free pass).

    Derived names — ``fused()`` / ``linearized()`` stamp a bracketed
    suffix on the pipeline name — resolve to their base workload's
    class: fusing a best-effort head does not promote it."""
    base = workload.split("[", 1)[0]
    return SLO_CLASSES[WORKLOAD_SLO.get(base, "interactive")]


# ---------------------------------------------------------------------------
# streaming estimators
# ---------------------------------------------------------------------------


class WindowedQuantile:
    """Deterministic streaming quantile over the last ``window`` values.

    Values are discretized into the telemetry histogram's fixed log
    buckets (``bisect_left``: bucket k covers
    ``(lo * growth**(k-1), lo * growth**k]``); a ring buffer of bucket
    indices retires the oldest observation exactly, so the estimate is
    a pure function of the last ``window`` inputs.

    Error bound (tests/test_slo.py property-tests it): with ``v`` the
    exact ceil-rank quantile of the sorted window,

    * ``lo < v <= bounds[-1]``  =>  ``v <= quantile(q) <= v * growth``
    * ``v <= lo``               =>  ``quantile(q) == lo``
    * ``v >  bounds[-1]``       =>  ``quantile(q) == bounds[-1]``

    The defaults cover 0.1 ms .. ~90 s at ``growth = 2**0.25`` (≤ 19%
    relative overestimate) — loop times live well inside that band.
    """

    __slots__ = ("bounds", "counts", "ring", "window", "n")

    def __init__(
        self,
        window: int,
        lo: float = 1e-4,
        growth: float = 2.0 ** 0.25,
        nbuckets: int = 80,
    ):
        if window < 1:
            raise ValueError("window must be >= 1")
        if lo <= 0.0 or growth <= 1.0 or nbuckets < 2:
            raise ValueError("need lo > 0, growth > 1, nbuckets >= 2")
        self.bounds = [lo * growth**k for k in range(nbuckets)]
        self.counts = [0] * (nbuckets + 1)  # +1 overflow
        self.ring = [0] * window
        self.window = window
        self.n = 0

    def observe(self, v: float) -> None:
        k = bisect_left(self.bounds, v)
        if k == len(self.bounds):  # overflow clamps to the top bucket
            k -= 1
        pos = self.n % self.window
        if self.n >= self.window:
            self.counts[self.ring[pos]] -= 1
        self.ring[pos] = k
        self.counts[k] += 1
        self.n += 1

    def quantile(self, q: float) -> float:
        """Upper bucket bound at ceil-rank quantile ``q`` (0 if empty)."""
        count = min(self.n, self.window)
        if not count:
            return 0.0
        rank = max(1, math.ceil(q * count))
        acc = 0
        for k, c in enumerate(self.counts):
            acc += c
            if acc >= rank:
                return self.bounds[k]
        return self.bounds[-1]


class BurnGauge:
    """Streaming SLO attainment over a fast + slow window pair.

    One ring of miss bits (1 = deadline missed or frame dropped) of
    length ``slo.window`` backs both running sums; the fast sum retires
    bits ``fast_window`` observations back.  Burn rate = miss fraction
    over the window divided by the error budget.  The slow ratio uses
    ``min(n, window)`` as its denominator so short runs still alert;
    the fast ratio requires a full fast window (no spike verdicts from
    a handful of frames).
    """

    __slots__ = ("slo", "ring", "n", "fast_sum", "slow_sum")

    def __init__(self, slo: SLOClass):
        self.slo = slo
        self.ring = [0] * slo.window
        self.n = 0
        self.fast_sum = 0
        self.slow_sum = 0

    def observe(self, miss: bool) -> None:
        w = self.slo.window
        fw = self.slo.fast_window
        pos = self.n % w
        if self.n >= w:
            self.slow_sum -= self.ring[pos]
        if self.n >= fw:
            self.fast_sum -= self.ring[(self.n - fw) % w]
        bit = 1 if miss else 0
        self.ring[pos] = bit
        self.slow_sum += bit
        self.fast_sum += bit
        self.n += 1

    @property
    def fast_ready(self) -> bool:
        return self.n >= self.slo.fast_window

    @property
    def fast_burn(self) -> float:
        fw = self.slo.fast_window
        if not self.n:
            return 0.0
        return (self.fast_sum / min(self.n, fw)) / self.slo.budget

    @property
    def slow_burn(self) -> float:
        if not self.n:
            return 0.0
        return (self.slow_sum / min(self.n, self.slo.window)) / self.slo.budget

    @property
    def alerting(self) -> bool:
        return (
            self.fast_ready
            and self.fast_burn >= self.slo.fast_burn
            and self.slow_burn >= self.slo.slow_burn
        )


# ---------------------------------------------------------------------------
# incidents + root-cause attribution
# ---------------------------------------------------------------------------

# attribution categories, folded from the span tuple so faults diagnose
# robustly: queue-wait and batch-gather merge into one ``queueing``
# category (FIFO and fused-launch edges present the same symptom), the
# uplink and downlink spans merge into ``network`` (a latency/jitter/
# bandwidth fault on a spoke inflates both directions — splitting them
# makes the winner a coin flip) minus the shared-medium queue delay,
# which becomes its own ``cell`` category (contention happens *on the
# medium*, not on a spoke), and migration blackouts become the
# ``blackout`` pseudo-category (downtime is inter-frame — invisible in
# loop spans, visible in drops).
CATEGORIES: Tuple[str, ...] = (
    "client",
    "network",
    "queueing",
    "decode",
    "compute",
    "cell",
    "blackout",
)

_N_CAT = len(CATEGORIES)

_I_CLIENT = SPAN_ORDER.index("client")
_I_UP = SPAN_ORDER.index("uplink")
_I_QW = SPAN_ORDER.index("queue-wait")
_I_BG = SPAN_ORDER.index("batch-gather")
_I_DEC = SPAN_ORDER.index("decode")
_I_COMP = SPAN_ORDER.index("compute")
_I_DOWN = SPAN_ORDER.index("downlink")


def _frame_categories(
    spans: Tuple[float, ...], link_wait: float
) -> Tuple[float, ...]:
    """Fold one frame's span tuple into per-category seconds.  The
    engines attribute the shared-medium wait to the uplink span
    (that is where the client feels it); here it is carved back out so
    ``network`` is pure wire/latency/jitter and ``cell`` is pure
    medium queueing."""
    return (
        spans[_I_CLIENT],
        spans[_I_UP] + spans[_I_DOWN] - link_wait,
        spans[_I_QW] + spans[_I_BG],
        spans[_I_DEC],
        spans[_I_COMP],
        link_wait,
        0.0,  # blackout: fed by the migration hook, not the spans
    )


class _Profile:
    """Accumulated per-category seconds + localization samples for one
    stretch of frames (the healthy baseline or one incident window)."""

    __slots__ = (
        "frames",
        "cat_s",
        "uplink_bytes",
        "edge_frames",
        "edge_cat_s",
        "edge_wait",
        "media_wait",
    )

    def __init__(self) -> None:
        self.frames = 0
        self.cat_s = [0.0] * _N_CAT
        self.uplink_bytes = 0
        # edge -> frame count / per-category seconds of frames served there
        self.edge_frames: Dict[str, int] = {}
        self.edge_cat_s: Dict[str, List[float]] = {}
        # edge -> [sum wait_s, samples] from the servers' wait hook
        self.edge_wait: Dict[str, List[float]] = {}
        # medium -> [sum wait_s, samples] from shared-link admissions
        self.media_wait: Dict[str, List[float]] = {}

    def add_frame(
        self,
        edge: str,
        spans: Tuple[float, ...],
        link_wait: float,
        uplink_bytes: int,
    ) -> None:
        self.frames += 1
        self.uplink_bytes += uplink_bytes
        cat = self.cat_s
        ecat = self.edge_cat_s.get(edge)
        if ecat is None:
            ecat = self.edge_cat_s[edge] = [0.0] * _N_CAT
            self.edge_frames[edge] = 0
        self.edge_frames[edge] += 1
        for c, d in enumerate(_frame_categories(spans, link_wait)):
            cat[c] += d
            ecat[c] += d

    def add_blackout(self, duration: float) -> None:
        self.cat_s[_N_CAT - 1] += duration

    def add_wait(self, edge: str, wait: float) -> None:
        rec = self.edge_wait.get(edge)
        if rec is None:
            rec = self.edge_wait[edge] = [0.0, 0.0]
        rec[0] += wait
        rec[1] += 1.0

    def add_media_wait(self, medium: str, wait: float) -> None:
        rec = self.media_wait.get(medium)
        if rec is None:
            rec = self.media_wait[medium] = [0.0, 0.0]
        rec[0] += wait
        rec[1] += 1.0

    def per_frame(self, c: int) -> float:
        return self.cat_s[c] / self.frames if self.frames else 0.0

    def edge_per_frame(self, edge: str, c: int) -> float:
        n = self.edge_frames.get(edge, 0)
        return self.edge_cat_s[edge][c] / n if n else 0.0

    def mean_wait(self, edge: str) -> float:
        rec = self.edge_wait.get(edge)
        return rec[0] / rec[1] if rec and rec[1] else 0.0

    def media_per_frame(self, medium: str) -> float:
        rec = self.media_wait.get(medium)
        return rec[0] / self.frames if rec and self.frames else 0.0

    def bytes_per_frame(self) -> float:
        return self.uplink_bytes / self.frames if self.frames else 0.0


@dataclasses.dataclass(frozen=True)
class Cause:
    """One ranked suspect: a category and (when localizable) a locus."""

    category: str
    locus: Optional[str]
    excess_s: float  # per-frame excess seconds vs the healthy baseline

    @property
    def label(self) -> str:
        return (
            f"{self.category}@{self.locus}" if self.locus else self.category
        )


@dataclasses.dataclass
class Incident:
    """One SLO breach: the burn-rate windows opened it, the attributor
    explains it at close."""

    workload: str
    slo: str
    t_open: float
    t_close: float = math.nan
    open_at_end: bool = False
    frames: int = 0  # processed frames inside the window
    misses: int = 0  # deadline misses + dropped frames inside it
    drops: int = 0  # the dropped-frame subset of ``misses``
    p99_est_s: float = 0.0  # streaming loop p99 estimate at close
    causes: Tuple[Cause, ...] = ()
    uplink_bytes_excess: float = 0.0  # bytes/frame vs baseline (signal,
    # not a ranked cause: bytes are not seconds)

    @property
    def top_cause(self) -> str:
        return self.causes[0].label if self.causes else "unknown"

    def summary(self) -> Dict:
        return {
            "workload": self.workload,
            "slo": self.slo,
            "t_open": self.t_open,
            "t_close": self.t_close,
            "open_at_end": self.open_at_end,
            "frames": self.frames,
            "misses": self.misses,
            "drops": self.drops,
            "p99_est_ms": 1e3 * self.p99_est_s,
            "causes": [
                {
                    "label": c.label,
                    "excess_ms_per_frame": 1e3 * c.excess_s,
                }
                for c in self.causes
            ],
            "uplink_bytes_excess_per_frame": self.uplink_bytes_excess,
        }


class _WorkloadState:
    """Per-workload online state: estimators, baseline, open incident."""

    __slots__ = (
        "slo",
        "quant",
        "burn",
        "baseline",
        "incident",
        "inc_profile",
    )

    def __init__(self, slo: SLOClass):
        self.slo = slo
        self.quant = WindowedQuantile(slo.window)
        self.burn = BurnGauge(slo)
        self.baseline = _Profile()
        self.incident: Optional[Incident] = None
        self.inc_profile: Optional[_Profile] = None


class SLOMonitor(Telemetry):
    """Online SLO monitor + fleet doctor (a drop-in Telemetry).

    ``run_fleet(slo=SLOMonitor())`` arms it on either engine; both call
    the hooks with bit-identical arguments in the same order, so the
    full incident log — open/close timestamps, ranked causes, report
    bytes — is engine-independent.

    ``classes`` overrides the workload -> :class:`SLOClass` mapping
    (default: ``core.workloads.WORKLOAD_SLO`` via :func:`slo_of`);
    workloads absent from the mapping get :data:`INTERACTIVE`.

    The attributor's localization rule: the winning category picks the
    edge with the largest per-frame excess of that category; for
    ``queueing`` the per-admission wait samples refine it (a throttled
    edge punishes exactly its own queue), and ``cell`` localizes to the
    shared medium with the largest queue-delay excess (wire legs
    contend *on the cell*, not at an edge).
    """

    def __init__(
        self,
        classes: Optional[Dict[str, SLOClass]] = None,
    ) -> None:
        super().__init__()
        self._classes = dict(classes) if classes else None
        self._wl: Dict[str, _WorkloadState] = {}
        self._last_idx: Dict[int, int] = {}
        self._last_t = 0.0
        self.incidents: List[Incident] = []

    # -- class resolution ---------------------------------------------------

    def _state(self, workload: str) -> _WorkloadState:
        st = self._wl.get(workload)
        if st is None:
            if self._classes is not None:
                # keys may be workload names (sharpest) or SLO class
                # names ("interactive") to retune a whole class at once
                slo = (
                    self._classes.get(workload)
                    or self._classes.get(slo_of(workload).name)
                    or slo_of(workload)
                )
            else:
                slo = slo_of(workload)
            st = self._wl[workload] = _WorkloadState(slo)
        return st

    # -- hook overrides (super() first: the trace must stay identical) -----

    def wait_sample(self, edge: str, t: float, wait: float) -> None:
        super().wait_sample(edge, t, wait)
        for st in self._wl.values():
            prof = st.inc_profile if st.incident is not None else st.baseline
            prof.add_wait(edge, wait)

    def occupancy_sample(self, edge: str, t: float, load: float) -> None:
        super().occupancy_sample(edge, t, load)
        if edge.startswith("link."):
            # shared-medium admissions report their imposed queue delay
            # as the sample value (0.0 when uncontended)
            medium = edge[5:]
            for st in self._wl.values():
                prof = (
                    st.inc_profile
                    if st.incident is not None
                    else st.baseline
                )
                prof.add_media_wait(medium, load)

    def migration(
        self, client: int, t0: float, duration: float, src: str, dst: str
    ) -> None:
        super().migration(client, t0, duration, src, dst)
        wl = self._client_workload.get(client, "?")
        st = self._state(wl)
        prof = st.inc_profile if st.incident is not None else st.baseline
        prof.add_blackout(duration)

    def frame_done(
        self,
        client: int,
        frame_idx: int,
        edge: str,
        start: float,
        fin: float,
        plan,
        draws: Tuple[float, ...],
        link_wait: float = 0.0,
    ) -> None:
        super().frame_done(
            client, frame_idx, edge, start, fin, plan, draws,
            link_wait=link_wait,
        )
        self._last_t = fin
        wl = self._client_workload.get(client, "?")
        st = self._state(wl)
        # dropped frames are holes in the per-client index sequence;
        # each is an SLO miss (the user saw no pose update) even though
        # no loop time exists for it
        last = self._last_idx.get(client, -1)
        self._last_idx[client] = frame_idx
        drops = frame_idx - last - 1
        for _ in range(drops):
            st.burn.observe(True)
            if st.incident is not None:
                st.incident.misses += 1
                st.incident.drops += 1
        loop = fin - start
        miss = loop > st.slo.deadline_s
        st.quant.observe(loop)
        st.burn.observe(miss)
        prof = st.inc_profile if st.incident is not None else st.baseline
        prof.add_frame(edge, self.frames[-1][7], link_wait, plan.uplink_bytes)
        if st.incident is not None:
            st.incident.frames += 1
            if miss:
                st.incident.misses += 1
            if st.burn.fast_burn < 1.0:  # hysteresis: budget restored
                self._close(wl, st, fin)
        elif st.burn.alerting:
            st.incident = Incident(
                workload=wl, slo=st.slo.name, t_open=fin
            )
            st.inc_profile = _Profile()

    def finish_run(self, result, rates=None) -> None:
        super().finish_run(result, rates)
        for wl in sorted(self._wl):
            st = self._wl[wl]
            if st.incident is not None:
                st.incident.open_at_end = True
                self._close(wl, st, self._last_t)

    # -- the doctor ---------------------------------------------------------

    def _close(self, wl: str, st: _WorkloadState, t: float) -> None:
        inc = st.incident
        prof = st.inc_profile
        st.incident = None
        st.inc_profile = None
        inc.t_close = t
        inc.p99_est_s = st.quant.quantile(0.99)
        inc.causes = self._attribute(st.baseline, prof)
        inc.uplink_bytes_excess = (
            prof.bytes_per_frame() - st.baseline.bytes_per_frame()
        )
        self.incidents.append(inc)

    def _attribute(
        self, base: _Profile, inc: _Profile
    ) -> Tuple[Cause, ...]:
        """Rank categories by per-frame excess seconds vs baseline and
        localize each to an edge/medium where a signal supports it."""
        causes: List[Cause] = []
        for c, name in enumerate(CATEGORIES):
            excess = inc.per_frame(c) - base.per_frame(c)
            if excess <= 0.0:
                continue
            causes.append(Cause(name, self._locus(c, name, base, inc), excess))
        causes.sort(key=lambda cs: (-cs.excess_s, cs.label))
        return tuple(causes)

    def _locus(
        self, c: int, name: str, base: _Profile, inc: _Profile
    ) -> Optional[str]:
        if name == "blackout":
            return None  # migration downtime has no single edge
        if name == "cell":
            # the contended medium with the largest per-frame queue
            # delay excess (the admissions' reported waits)
            best_m, best_mw = None, 0.0
            for m in sorted(inc.media_wait):
                mw = inc.media_per_frame(m) - base.media_per_frame(m)
                if mw > best_mw:
                    best_m, best_mw = m, mw
            return best_m
        if name == "queueing":
            # per-admission wait samples localize sharper than frame
            # placement (a throttled edge punishes exactly its queue)
            best_e, best_w = None, 0.0
            for e in sorted(inc.edge_wait):
                w = inc.mean_wait(e) - base.mean_wait(e)
                if w > best_w:
                    best_e, best_w = e, w
            if best_e is not None:
                return best_e
        best_e, best_x, second_x = None, 0.0, 0.0
        for e in sorted(inc.edge_frames):
            x = inc.edge_per_frame(e, c) - base.edge_per_frame(e, c)
            if x > best_x:
                best_e, best_x, second_x = e, x, best_x
            elif x > second_x:
                second_x = x
        if (
            name == "network"
            and second_x >= 0.35 * best_x
            and len(inc.media_wait) == 1
        ):
            # common-cause inference: wire time inflated on *every*
            # spoke, and all spokes ride one shared medium -> the cell
            # itself (not any single link) is the culprit
            return next(iter(inc.media_wait))
        return best_e

    # -- reporting ----------------------------------------------------------

    def attainment(self) -> Dict[str, Dict]:
        """Live per-workload SLO state (deterministic key order)."""
        out: Dict[str, Dict] = {}
        for wl in sorted(self._wl):
            st = self._wl[wl]
            out[wl] = {
                "slo": st.slo.name,
                "deadline_ms": 1e3 * st.slo.deadline_s,
                "target": st.slo.target,
                "observed": st.burn.n,
                "misses": st.burn.slow_sum,
                "fast_burn": st.burn.fast_burn,
                "slow_burn": st.burn.slow_burn,
                "p50_est_ms": 1e3 * st.quant.quantile(0.50),
                "p99_est_ms": 1e3 * st.quant.quantile(0.99),
                "incident_open": st.incident is not None,
            }
        return out

    def summary(self) -> Dict:
        """JSON-able doctor rollup (byte-stable across engines)."""
        return {
            "attainment": self.attainment(),
            "incidents": [i.summary() for i in self.incidents],
        }

    def summary_json(self) -> str:
        return json.dumps(self.summary(), sort_keys=True)

    def format_incident_report(self) -> str:
        """The doctor's verdict as a plain-text report."""
        lines: List[str] = []
        att = self.attainment()
        for wl, a in att.items():
            lines.append(
                f"== SLO [{wl} / {a['slo']}] deadline {a['deadline_ms']:.1f} ms "
                f"target {100 * a['target']:.0f}% — {a['observed']} observed, "
                f"{a['misses']} missed in window, "
                f"p99~{a['p99_est_ms']:.1f} ms =="
            )
        if not self.incidents:
            lines.append("no incidents: every SLO held within budget")
            return "\n".join(lines)
        for i, inc in enumerate(self.incidents):
            tail = " (open at end of run)" if inc.open_at_end else ""
            lines.append(
                f"incident {i}: [{inc.workload} / {inc.slo}] "
                f"t={inc.t_open:.3f}s -> {inc.t_close:.3f}s{tail} — "
                f"{inc.misses} misses ({inc.drops} drops) "
                f"over {inc.frames} frames"
            )
            for rank, cause in enumerate(inc.causes):
                lines.append(
                    f"  #{rank + 1} {cause.label}: "
                    f"+{1e3 * cause.excess_s:.3f} ms/frame vs baseline"
                )
            if inc.uplink_bytes_excess > 0.0:
                lines.append(
                    f"  signal: uplink "
                    f"+{inc.uplink_bytes_excess / 1e3:.1f} kB/frame vs baseline"
                )
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# fault-injection catalog (the doctor's by-construction validation)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One injectable fault and the verdict the doctor must reach.

    ``drifts`` are scheduled on the canonical doctor topology (a
    3-edge ``hetero_fleet_star`` over a shared cell — edges
    ``edge_0..2``, spokes ``5g_edge_0..2``, medium ``cell0``).
    ``migration`` overrides the fleet's migration config when the fault
    needs a pathological controller (the flap), else the bench default
    applies; ``disable_migration`` runs the fault with migration off
    entirely (a static-placement deployment — the lossy-link fault
    would otherwise be healed by draining the sick spoke, which is the
    *correct* adaptive response but leaves nothing to diagnose).
    ``expected`` is the cause label the doctor's verdict
    (:func:`doctor_verdict`) must match on both engines
    (`fleet_bench --doctor` gates on it).
    """

    name: str
    summary: str
    drifts: Tuple[object, ...]
    expected: str
    migration: Optional[MigrationConfig] = None
    disable_migration: bool = False


FAULTS: Dict[str, FaultSpec] = {
    "edge_throttle": FaultSpec(
        name="edge_throttle",
        summary="thermal throttle: edge_1 services inflate 8x mid-run "
        "(plan-invisible; lands in measured queueing)",
        drifts=(ServiceDrift(time=1.5, edge="edge_1", factor=8.0),),
        expected="queueing@edge_1",
    ),
    "cell_collapse": FaultSpec(
        name="cell_collapse",
        summary="cell collapse: every spoke of the shared cell degrades "
        "at once (bandwidth to a third, +25 ms radio latency) — wire "
        "time inflates on all edges, so the doctor's common-cause rule "
        "pins the shared medium, not any single spoke",
        drifts=tuple(
            LinkDrift(
                time=1.5,
                link=f"5g_edge_{i}",
                latency=0.025,
                bandwidth=20e6,
            )
            for i in range(3)
        ),
        expected="network@cell0",
    ),
    "lossy_keyframe": FaultSpec(
        name="lossy_keyframe",
        summary="lossy keyframe link: edge_0's spoke turns high-latency "
        "/ high-jitter (retransmitting keyframes); with placement "
        "pinned, the wire span inflates on that spoke alone",
        drifts=(
            LinkDrift(time=1.5, link="5g_edge_0", latency=0.030, jitter=0.015),
        ),
        expected="network@edge_0",
        disable_migration=True,
    ),
    "migration_flap": FaultSpec(
        name="migration_flap",
        summary="migration flap: a hair-trigger controller with a heavy "
        "tracker state (16 MB) chases an alternating throttle between "
        "edges; each move's state-transfer blackout drops frames",
        drifts=tuple(
            ServiceDrift(
                time=1.0 + 0.5 * k + 0.5 * phase,
                edge=f"edge_{k % 3}",
                factor=3.0 if phase == 0 else 1.0,
            )
            for k in range(14)
            for phase in (0, 1)
        ),
        expected="blackout",
        migration=MigrationConfig(
            min_dwell_frames=2,
            improvement_threshold=0.02,
            state_nbytes=16_000_000,
            wait_ewma_blend=1.0,
            wait_ewma_alpha=0.5,
            wait_ewma_half_life=0.5,
        ),
    ),
}

# SLO classes retuned for the doctor's scenario.  The canonical doctor
# fleet runs its camera at 12 fps (mixed workloads' healthy loops are
# 50-85 ms, so a 30 fps camera load-sheds *structurally* and every run
# looks sick); deadlines scale with the 83 ms frame period and the burn
# thresholds come down because a single-locus fault can only breach the
# fraction of a workload's clients parked on the sick edge (~1/2 here).
DOCTOR_CLASSES: Dict[str, SLOClass] = {
    "interactive": SLOClass(
        "interactive",
        deadline_s=100e-3,
        target=0.90,
        window=128,
        fast_window=24,
        fast_burn=4.0,
        slow_burn=2.0,
    ),
    "best_effort": SLOClass(
        "best_effort",
        deadline_s=200e-3,
        target=0.80,
        window=128,
        fast_window=24,
        fast_burn=4.0,
        slow_burn=2.0,
    ),
}


def doctor_verdict(
    monitor: "SLOMonitor",
) -> Tuple[Optional[str], Dict[str, float]]:
    """Aggregate a run's incidents into one ranked diagnosis.

    Each incident's causes are weighted by the incident's miss count
    (an incident that burned 250 frames of budget outranks a marginal
    one that opened on a transient), and excess seconds accumulate per
    cause label.  Returns ``(top_label_or_None, {label: score})`` —
    deterministic: ties break toward the lexicographically smallest
    label.
    """
    agg: Dict[str, float] = {}
    for inc in monitor.incidents:
        for cause in inc.causes:
            w = cause.excess_s * max(inc.misses, 1)
            agg[cause.label] = agg.get(cause.label, 0.0) + w
    if not agg:
        return None, agg
    top = max(sorted(agg), key=lambda k: agg[k])
    return top, agg
