import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo.

The two lines above MUST stay the first statements of this module (before
any jax import, direct or transitive): jax locks the device count at
first initialization, and the production meshes need 512 placeholder host
devices. Smoke tests and benchmarks do NOT import this module and see the
real single CPU device.

For each combination this script:
  1. builds the step function (train_step / prefill / serve_step per the
     shape's kind) with the sharding rules of sharding/specs.py,
  2. ``jax.jit(...).lower(**ShapeDtypeStructs).compile()`` under the
     production mesh — no arrays are ever materialized,
  3. records memory_analysis() (fits-per-chip proof), cost_analysis()
     (FLOPs / bytes) and the collective-byte census parsed from the
     optimized HLO (repro.roofline.analysis),
  4. writes one JSON per combo under experiments/dryrun/ (resumable).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
      [--mesh single|multi|both] [--out DIR] [--force]
"""

import argparse
import functools
import json
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import registry, shapes as shp
from repro.configs.base import ArchConfig
from repro.launch.mesh import make_production_mesh
from repro.models import transformer
from repro.optim import adamw
from repro.roofline import analysis
from repro.sharding import specs as sspecs


def _sds_tree(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree
    )


def build_train(cfg: ArchConfig, shape, mesh):
    opt_cfg = adamw.AdamWConfig()
    shard = sspecs.make_shard_fn(mesh)

    def train_step(params, opt_state, batch):
        def loss_wrap(p):
            loss, metrics = transformer.loss_fn(
                cfg, p, batch, shard=shard, remat=True
            )
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(loss_wrap, has_aux=True)(
            params
        )
        new_params, new_opt, opt_metrics = adamw.update(
            opt_cfg, grads, opt_state, params
        )
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return new_params, new_opt, metrics

    params_sds = transformer.param_shapes(cfg)
    opt_sds = jax.eval_shape(adamw.init, params_sds)
    batch_sds = shp.token_inputs(cfg, shape)

    p_specs = sspecs.param_specs(params_sds, mesh)
    # §Perf iteration 4: REPRO_ZERO1=1 shards AdamW moments over the data
    # axes (ZeRO-1) — replicated f32 moments otherwise dominate HBM.
    if os.environ.get("REPRO_ZERO1") == "1":
        m_specs = sspecs.zero1_specs(p_specs, params_sds, mesh)
    else:
        m_specs = p_specs
    o_specs = adamw.AdamWState(
        step=jax.sharding.PartitionSpec(),
        mu=m_specs,
        nu=m_specs,
    )
    b_specs = sspecs.input_specs_tree(batch_sds, mesh)
    in_shardings = (
        sspecs.named(p_specs, mesh),
        sspecs.named(o_specs, mesh),
        sspecs.named(b_specs, mesh),
    )
    fn = jax.jit(train_step, in_shardings=in_shardings, donate_argnums=(0, 1))
    return fn, (params_sds, opt_sds, batch_sds)


def build_prefill(cfg: ArchConfig, shape, mesh):
    shard = sspecs.make_shard_fn(mesh)
    batch_sds = shp.token_inputs(cfg, shape)
    max_len = shape.seq_len
    if cfg.modality == "vision":
        # the vision frontend prepends patch embeddings to the stream
        max_len += cfg.frontend_tokens

    def prefill_step(params, batch):
        logits, cache = transformer.prefill(
            cfg,
            params,
            batch["tokens"],
            max_len=max_len,
            positions=batch.get("positions"),
            frontend_embeds=batch.get("frontend_embeds"),
            encoder_tokens=batch.get("encoder_tokens"),
            shard=shard,
        )
        return logits, cache

    params_sds = transformer.param_shapes(cfg)
    p_specs = sspecs.param_specs(params_sds, mesh)
    b_specs = sspecs.input_specs_tree(batch_sds, mesh)
    fn = jax.jit(
        prefill_step,
        in_shardings=(sspecs.named(p_specs, mesh), sspecs.named(b_specs, mesh)),
    )
    return fn, (params_sds, batch_sds)


def build_decode(cfg: ArchConfig, shape, mesh):
    shard = sspecs.make_shard_fn(mesh)
    b = shape.global_batch
    max_len = shape.seq_len
    # §Perf iteration 3: REPRO_RING=1 switches sliding-window layers to
    # ring-buffer caches of length `window` (gemma3 long_500k hillclimb).
    ring = (
        os.environ.get("REPRO_RING") == "1"
        and cfg.num_heads > 0
        and any(w > 0 for w in cfg.layer_window_sizes())
    )

    def serve_step(params, cache, batch):
        return transformer.decode_step(
            cfg,
            params,
            cache,
            batch["tokens"],
            positions=batch.get("positions") if cfg.mrope else None,
            shard=shard,
        )

    params_sds = transformer.param_shapes(cfg)
    cache_sds = transformer.cache_shapes(cfg, b, max_len, ring=ring)
    batch_all = shp.token_inputs(cfg, shape)
    batch_sds = {"tokens": batch_all["tokens"]}
    if cfg.mrope:
        batch_sds["positions"] = batch_all["positions"]

    p_specs = sspecs.param_specs(params_sds, mesh)
    c_specs = sspecs.cache_specs(cache_sds, mesh)
    b_specs = sspecs.input_specs_tree(batch_sds, mesh)
    fn = jax.jit(
        serve_step,
        in_shardings=(
            sspecs.named(p_specs, mesh),
            sspecs.named(c_specs, mesh),
            sspecs.named(b_specs, mesh),
        ),
        donate_argnums=(1,),
    )
    return fn, (params_sds, cache_sds, batch_sds)


def _memory_stats(compiled) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    try:
        ma = compiled.memory_analysis()
        for attr in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "generated_code_size_in_bytes",
            "alias_size_in_bytes",
        ):
            v = getattr(ma, attr, None)
            if v is not None:
                out[attr] = int(v)
        # bytes per chip = args + temps (aliased buffers subtracted once)
        total = out.get("argument_size_in_bytes", 0) + out.get(
            "temp_size_in_bytes", 0
        ) - out.get("alias_size_in_bytes", 0)
        out["bytes_per_chip"] = int(total)
    except Exception as e:  # CPU backend may not implement everything
        out["error"] = repr(e)
    return out


def _cost_stats(compiled) -> Dict[str, float]:
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        return {k: float(v) for k, v in cost.items() if isinstance(v, (int, float))}
    except Exception as e:
        return {"error_": 0.0}


def run_one(
    arch: str, shape_name: str, multi_pod: bool, out_dir: str, force: bool = False
) -> Dict[str, Any]:
    cfg = registry.get(arch)
    shape = shp.ALL_SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    out_path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}.json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    record: Dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "status": "skipped",
    }
    if not shp.applicable(cfg, shape):
        record["reason"] = "long_500k skipped: pure full-attention arch"
        _write(out_path, record)
        return record

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        chips = int(len(mesh.devices.reshape(-1)))
        with mesh:
            if shape.kind == "train":
                fn, args_sds = build_train(cfg, shape, mesh)
            elif shape.kind == "prefill":
                fn, args_sds = build_prefill(cfg, shape, mesh)
            else:
                fn, args_sds = build_decode(cfg, shape, mesh)
            lowered = fn.lower(*args_sds)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            cost = _cost_stats(compiled)
            mem = _memory_stats(compiled)
            try:
                hlo = compiled.as_text()
            except Exception:
                hlo = lowered.as_text()
            report = analysis.analyze(
                cfg, shape, mesh_name, chips, cost, hlo, mem
            )
        record.update(
            status="ok",
            chips=chips,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            cost=cost,
            memory=mem,
            roofline=report.row(),
            hlo_bytes_len=len(hlo),
        )
    except Exception as e:
        record.update(status="error", error=repr(e), trace=traceback.format_exc())
    record["elapsed_s"] = round(time.time() - t0, 2)
    _write(out_path, record)
    return record


def _write(path: str, record: Dict) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(record, f, indent=1, default=str)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else registry.list_archs()
    shape_names = [args.shape] if args.shape else list(shp.ALL_SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_ok = n_err = n_skip = 0
    for arch in archs:
        for shape_name in shape_names:
            for multi in meshes:
                rec = run_one(arch, shape_name, multi, args.out, args.force)
                tag = rec["status"]
                if tag == "ok":
                    n_ok += 1
                    r = rec["roofline"]
                    print(
                        f"OK   {arch:22s} {shape_name:12s} {rec['mesh']:10s} "
                        f"compile={rec.get('compile_s', 0):7.1f}s "
                        f"dom={r['dominant']:10s} "
                        f"c={r['compute_s']:.2e} m={r['memory_s']:.2e} "
                        f"n={r['collective_s']:.2e}",
                        flush=True,
                    )
                elif tag == "skipped":
                    n_skip += 1
                    print(f"SKIP {arch:22s} {shape_name:12s} {rec['mesh']}", flush=True)
                else:
                    n_err += 1
                    print(
                        f"ERR  {arch:22s} {shape_name:12s} {rec['mesh']}: "
                        f"{rec['error'][:200]}",
                        flush=True,
                    )
    print(f"done: ok={n_ok} skipped={n_skip} errors={n_err}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
