"""Serving driver: batched generation on live devices.

Usage (reduced config on CPU):
  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
      --requests 8 --max-new 32
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import registry
from repro.models import transformer
from repro.serving.engine import Engine, Request


def run(
    arch: str,
    reduced: bool = True,
    num_requests: int = 8,
    prompt_len: int = 32,
    max_new: int = 32,
    temperature: float = 0.0,
    seed: int = 0,
):
    cfg = registry.get(arch)
    if reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(seed)
    params = transformer.init_params(cfg, key)
    rng = np.random.default_rng(seed)
    requests = [
        Request(
            uid=i,
            prompt=rng.integers(0, cfg.vocab_size, size=prompt_len).astype(
                np.int32
            ),
            max_new_tokens=max_new,
        )
        for i in range(num_requests)
    ]
    engine = Engine(cfg, params, max_len=prompt_len + max_new + 8,
                    temperature=temperature, seed=seed)
    t0 = time.time()
    completions = engine.generate(requests)
    dt = time.time() - t0
    total_new = sum(len(c.tokens) for c in completions)
    return {
        "arch": cfg.name,
        "requests": num_requests,
        "new_tokens": total_new,
        "seconds": dt,
        "tokens_per_second": total_new / dt,
        "sample": completions[0].tokens[:16].tolist(),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.list_archs())
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()
    out = run(
        args.arch,
        num_requests=args.requests,
        prompt_len=args.prompt_len,
        max_new=args.max_new,
        temperature=args.temperature,
    )
    print(json.dumps(out))


if __name__ == "__main__":
    main()
