"""Training driver.

Runs real steps on whatever devices exist (CPU smoke / reduced configs,
or a real TPU slice with the production mesh). The dry-run path for the
assigned full configs lives in launch/dryrun.py.

Usage (end-to-end example, ~100M model, a few hundred steps):
  PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --reduced \
      --steps 300 --batch 8 --seq 256
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import io as ckpt_io
from repro.configs import registry
from repro.data.tokens import TokenPipeline, TokenPipelineConfig
from repro.launch.mesh import make_host_mesh
from repro.models import transformer
from repro.optim import adamw
from repro.sharding import specs as sspecs


def build_train_step(cfg, opt_cfg, mesh, schedule):
    shard = sspecs.make_shard_fn(mesh) if mesh is not None else transformer._no_shard

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: transformer.loss_fn(cfg, p, batch, shard=shard, remat=True),
            has_aux=True,
        )(params)
        lr_scale = schedule(opt_state.step)
        params, opt_state, opt_metrics = adamw.update(
            opt_cfg, grads, opt_state, params, lr_scale
        )
        return params, opt_state, dict(metrics, loss=loss, **opt_metrics)

    return jax.jit(train_step, donate_argnums=(0, 1))


def run(
    arch: str,
    steps: int = 300,
    batch: int = 8,
    seq: int = 256,
    reduced: bool = True,
    lr: float = 3e-4,
    seed: int = 0,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 100,
    log_every: int = 10,
    big: bool = False,
) -> Dict:
    cfg = registry.get(arch)
    if reduced:
        cfg = cfg.reduced()
        if big:
            # ~100M-class variant for real accelerator hosts
            cfg = dataclasses.replace(
                cfg,
                num_layers=12,
                d_model=768,
                num_heads=12 if cfg.num_heads else 0,
                num_kv_heads=4 if cfg.num_heads else 0,
                head_dim=64 if cfg.num_heads else 0,
                d_ff=3072 if cfg.d_ff else 0,
                vocab_size=32768,
                max_seq_len=max(cfg.max_seq_len, seq),
            )
        else:
            cfg = dataclasses.replace(
                cfg,
                num_layers=max(cfg.num_layers, 4),
                d_model=max(cfg.d_model, 512) if cfg.d_model < 512 else cfg.d_model,
                vocab_size=max(cfg.vocab_size, 8192),
                max_seq_len=max(cfg.max_seq_len, seq),
            )
    key = jax.random.PRNGKey(seed)
    params = transformer.init_params(cfg, key)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))

    opt_cfg = adamw.AdamWConfig(lr=lr)
    opt_state = adamw.init(params)
    schedule = adamw.cosine_schedule(steps)
    step_fn = build_train_step(cfg, opt_cfg, None, schedule)

    pipe = iter(
        TokenPipeline(
            TokenPipelineConfig(
                vocab_size=cfg.vocab_size,
                seq_len=seq,
                global_batch=batch,
                seed=seed,
            )
        )
    )

    losses = []
    t0 = time.time()
    for step in range(steps):
        host_batch = next(pipe)
        batch_dev = {k: jnp.asarray(v) for k, v in host_batch.items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch_dev)
        if step % log_every == 0 or step == steps - 1:
            loss = float(metrics["loss"])
            losses.append((step, loss))
            tps = batch * seq * (step + 1) / (time.time() - t0)
            print(
                f"step {step:5d} loss {loss:7.4f} "
                f"grad_norm {float(metrics['grad_norm']):8.3f} tok/s {tps:9.0f}",
                flush=True,
            )
        if ckpt_dir and step and step % ckpt_every == 0:
            ckpt_io.save(ckpt_dir, step, {"params": params})

    first_loss, last_loss = losses[0][1], losses[-1][1]
    result = {
        "arch": cfg.name,
        "params": n_params,
        "steps": steps,
        "first_loss": first_loss,
        "final_loss": last_loss,
        "improved": last_loss < first_loss - 0.2,
        "losses": losses,
    }
    if ckpt_dir:
        ckpt_io.save(ckpt_dir, steps, {"params": params})
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.list_archs())
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    result = run(
        args.arch,
        steps=args.steps,
        batch=args.batch,
        seq=args.seq,
        lr=args.lr,
        reduced=args.reduced,
        ckpt_dir=args.ckpt_dir,
    )
    print(json.dumps({k: v for k, v in result.items() if k != "losses"}))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1)


if __name__ == "__main__":
    main()
