"""Launchers: production mesh, multi-pod dry-run, train/serve drivers.

NOTE: do not import repro.launch.dryrun from library code — it sets
XLA_FLAGS at import time (512 placeholder devices) by design.
"""

from repro.launch import mesh  # noqa: F401
