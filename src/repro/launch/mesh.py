"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — the dry-run must set XLA_FLAGS
before any jax initialization.

Mesh shapes (from the mandate):
  single-pod:  (16, 16)      axes ("data", "model")   = 256 chips
  multi-pod:   (2, 16, 16)   axes ("pod", "data", "model") = 512 chips

The ``pod`` axis doubles as the *edge tier* axis for the tiered-serving
experiments (serving/edge.py): client pod / server pod, with the offload
traffic crossing pods as DCN collectives.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import numpy as np

    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh needs {n} devices but only {len(devices)} exist — run "
            "under dryrun.py (it forces 512 host platform devices)"
        )
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_host_mesh(
    data: Optional[int] = None, model: Optional[int] = None
) -> Mesh:
    """A small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    if data is None or model is None:
        model = 1
        data = n
    assert data * model == n, (data, model, n)
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_device_count(multi_pod: bool) -> int:
    return 512 if multi_pod else 256
