"""Shared neural-net building blocks (pure JAX, explicit param pytrees).

Every module is a pair of functions: ``init_*(key, ...) -> params`` and
``apply`` (here usually inlined at call sites). Params are plain nested
dicts so they stay trivially compatible with jax.eval_shape (the dry-run
never materializes them), sharding-spec rules (sharding/specs.py matches
on dict paths), and checkpointing.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

DEFAULT_INIT_SCALE = 0.02


def _dense_init(key, shape, dtype, scale: Optional[float] = None):
    scale = DEFAULT_INIT_SCALE if scale is None else scale
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.zeros((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    """RMSNorm with (1 + scale) weighting (gemma convention; a zero-init
    scale is exactly standard RMSNorm at init)."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(x.dtype)


def init_layernorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(
        jnp.float32
    )
    return y.astype(x.dtype)


def init_norm(kind: str, d: int, dtype=jnp.float32):
    return init_rmsnorm(d, dtype) if kind == "rmsnorm" else init_layernorm(d, dtype)


def apply_norm(kind: str, params, x):
    return rmsnorm(params, x) if kind == "rmsnorm" else layernorm(params, x)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, kind: str, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {
            "w_gate": _dense_init(k1, (d_model, d_ff), dtype),
            "w_up": _dense_init(k2, (d_model, d_ff), dtype),
            "w_down": _dense_init(k3, (d_ff, d_model), dtype),
        }
    return {
        "w_up": _dense_init(k1, (d_model, d_ff), dtype),
        "w_down": _dense_init(k2, (d_ff, d_model), dtype),
    }


def mlp(params, x, kind: str):
    if kind == "swiglu":
        gate = jax.nn.silu(x @ params["w_gate"])
        return (gate * (x @ params["w_up"])) @ params["w_down"]
    if kind == "geglu":
        gate = jax.nn.gelu(x @ params["w_gate"], approximate=True)
        return (gate * (x @ params["w_up"])) @ params["w_down"]
    if kind == "gelu":
        return jax.nn.gelu(x @ params["w_up"], approximate=True) @ params["w_down"]
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------


def init_embedding(key, vocab: int, d: int, dtype=jnp.float32):
    return {"table": _dense_init(key, (vocab, d), dtype)}


def embed(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


def unembed(params, x):
    """Logits via the (possibly tied) embedding table."""
    return x @ params["table"].T


# ---------------------------------------------------------------------------
# Rotary position embeddings (NeoX half-split convention)
# ---------------------------------------------------------------------------


def rope_angles(positions: jnp.ndarray, head_dim: int, theta: float):
    """positions (...,) -> angles (..., head_dim//2) in f32."""
    half = head_dim // 2
    inv_freq = 1.0 / (
        theta ** (jnp.arange(0, half, dtype=jnp.float32) / half)
    )
    return positions.astype(jnp.float32)[..., None] * inv_freq


def apply_rope(x: jnp.ndarray, angles: jnp.ndarray) -> jnp.ndarray:
    """x (..., S, H, D); angles (..., S, D//2) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    ang = angles[..., None, :]  # add head axis
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1
    ).astype(x.dtype)


def mrope_angles(
    positions: jnp.ndarray,  # (3, ..., S) — temporal / height / width
    head_dim: int,
    theta: float,
    sections: Sequence[int],
) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE: the head_dim//2 frequency slots are
    partitioned into (t, h, w) sections; each section takes its angle from
    the corresponding position component. Text tokens pass identical
    components, which makes M-RoPE collapse to standard RoPE (Sec. 2.1 of
    arXiv:2409.12191)."""
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    ang = jax.vmap(lambda p: rope_angles(p, head_dim, theta))(positions)
    # ang: (3, ..., S, half); build a per-frequency selector
    sec_id = jnp.concatenate(
        [jnp.full((s,), i, dtype=jnp.int32) for i, s in enumerate(sections)]
    )
    return jnp.take_along_axis(
        jnp.moveaxis(ang, 0, -1),  # (..., S, half, 3)
        sec_id[(None,) * (ang.ndim - 2) + (slice(None), None)],
        axis=-1,
    )[..., 0]


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    """gemma-style logit soft-capping; identity when cap == 0."""
    if cap <= 0.0:
        return x
    return cap * jnp.tanh(x / cap)
