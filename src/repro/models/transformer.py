"""Model assembly: init / train forward / prefill / decode for all six
architecture families, built scan-over-layers so the lowered HLO stays
O(1) in depth (essential for the 512-device dry-run compiles).

Layer stacks are stored as *stacked* param pytrees (leading L axis) and
driven by ``jax.lax.scan``; per-layer heterogeneity (gemma3's local:global
pattern) rides along as scanned *data* (a (L,) window array), so one
layer graph serves every layer. The zamba2 hybrid uses a two-level scan:
outer over groups of ``shared_attn_every`` SSM layers, with the single
shared attention block (one set of weights, its own KV cache per
application) applied between groups.

The ``shard`` hook keeps this module mesh-agnostic: the launcher injects
``with_sharding_constraint`` calls keyed by logical names
(sharding/specs.py); unit tests pass the identity.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention, layers, moe, ssm

ShardFn = Callable[[jnp.ndarray, str], jnp.ndarray]


def _no_shard(x: jnp.ndarray, name: str) -> jnp.ndarray:
    return x


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


# ===========================================================================
# Parameter initialization
# ===========================================================================


def _init_decoder_layer(key, cfg: ArchConfig, dtype) -> Dict:
    """One decoder block (attention archs)."""
    k_attn, k_mlp, k_cross = jax.random.split(key, 3)
    p = {
        "attn_norm": layers.init_norm(cfg.norm, cfg.d_model, dtype),
        "attn": attention.init_attention(k_attn, cfg, dtype),
        "mlp_norm": layers.init_norm(cfg.norm, cfg.d_model, dtype),
    }
    if cfg.moe is not None:
        p["moe"] = moe.init_moe(k_mlp, cfg, dtype)
    else:
        p["mlp"] = layers.init_mlp(k_mlp, cfg.d_model, cfg.d_ff, cfg.mlp, dtype)
    if cfg.cross_attention:
        p["cross_norm"] = layers.init_norm(cfg.norm, cfg.d_model, dtype)
        p["cross"] = attention.init_cross_attention(k_cross, cfg, dtype)
    return p


def _init_encoder_layer(key, cfg: ArchConfig, dtype) -> Dict:
    k_attn, k_mlp = jax.random.split(key)
    return {
        "attn_norm": layers.init_norm(cfg.norm, cfg.d_model, dtype),
        "attn": attention.init_attention(k_attn, cfg, dtype),
        "mlp_norm": layers.init_norm(cfg.norm, cfg.d_model, dtype),
        "mlp": layers.init_mlp(k_mlp, cfg.d_model, cfg.d_ff, cfg.mlp, dtype),
    }


def _init_ssm_layer(key, cfg: ArchConfig, dtype) -> Dict:
    return {
        "norm": layers.init_norm(cfg.norm, cfg.d_model, dtype),
        "ssm": ssm.init_ssm_block(key, cfg, dtype),
    }


def _stack_init(per_layer_init, key, n: int):
    keys = jax.random.split(key, n)
    return jax.vmap(per_layer_init)(keys)


def init_params(cfg: ArchConfig, key: jax.Array) -> Dict:
    dtype = _dtype(cfg)
    k_embed, k_layers, k_shared, k_enc, k_head = jax.random.split(key, 5)
    params: Dict[str, Any] = {
        "embed": layers.init_embedding(k_embed, cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": layers.init_norm(cfg.norm, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {
            "w": layers._dense_init(k_head, (cfg.d_model, cfg.vocab_size), dtype)
        }

    if cfg.arch_type in ("dense", "moe", "vlm", "audio"):
        params["layers"] = _stack_init(
            lambda k: _init_decoder_layer(k, cfg, dtype), k_layers, cfg.num_layers
        )
    elif cfg.arch_type == "ssm":
        params["layers"] = _stack_init(
            lambda k: _init_ssm_layer(k, cfg, dtype), k_layers, cfg.num_layers
        )
    elif cfg.arch_type == "hybrid":
        params["layers"] = _stack_init(
            lambda k: _init_ssm_layer(k, cfg, dtype), k_layers, cfg.num_layers
        )
        # ONE shared attention block (zamba2): attention + its own MLP
        k_sa, k_sm = jax.random.split(k_shared)
        params["shared_attn"] = {
            "attn_norm": layers.init_norm(cfg.norm, cfg.d_model, dtype),
            "attn": attention.init_attention(k_sa, cfg, dtype),
            "mlp_norm": layers.init_norm(cfg.norm, cfg.d_model, dtype),
            "mlp": layers.init_mlp(k_sm, cfg.d_model, cfg.d_ff, cfg.mlp, dtype),
        }
    else:
        raise ValueError(cfg.arch_type)

    if cfg.encoder_layers:
        params["encoder"] = {
            "layers": _stack_init(
                lambda k: _init_encoder_layer(k, cfg, dtype),
                k_enc,
                cfg.encoder_layers,
            ),
            "final_norm": layers.init_norm(cfg.norm, cfg.d_model, dtype),
        }
    return params


def param_shapes(cfg: ArchConfig) -> Dict:
    """Parameter ShapeDtypeStructs without allocation (dry-run path)."""
    return jax.eval_shape(
        functools.partial(init_params, cfg), jax.random.PRNGKey(0)
    )


# ===========================================================================
# Embedding / head
# ===========================================================================


def _embed_tokens(cfg: ArchConfig, params, tokens: jnp.ndarray) -> jnp.ndarray:
    x = layers.embed(params["embed"], tokens)
    if cfg.scale_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def _lm_logits(cfg: ArchConfig, params, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.tie_embeddings:
        logits = layers.unembed(params["embed"], x)
    else:
        logits = x @ params["lm_head"]["w"]
    return layers.softcap(logits.astype(jnp.float32), cfg.logit_softcap)


# ===========================================================================
# Layer bodies (shared by train/prefill; decode versions further below)
# ===========================================================================


def _decoder_layer_fwd(
    cfg: ArchConfig,
    lp: Dict,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    window,
    memory: Optional[jnp.ndarray],
    shard: ShardFn,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (x_out, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = layers.apply_norm(cfg.norm, lp["attn_norm"], x)
    if cfg.attention == "mla":
        attn_out = attention.mla_forward(lp["attn"], cfg, h, positions)
    else:
        attn_out = attention.gqa_forward(
            lp["attn"], cfg, h, positions, window=window
        )
    x = x + shard(attn_out, "activation")
    if cfg.cross_attention and memory is not None:
        h = layers.apply_norm(cfg.norm, lp["cross_norm"], x)
        qpos = positions if positions.ndim == 1 else positions[0, 0]
        cross_out = attention.gqa_forward(
            lp["cross"], cfg, h, qpos, window=0, causal=False,
            kv_override=(memory, memory),
        )
        x = x + shard(cross_out, "activation")
    h = layers.apply_norm(cfg.norm, lp["mlp_norm"], x)
    if cfg.moe is not None:
        mlp_out, aux = moe.moe_forward(lp["moe"], cfg, h, shard=shard)
    else:
        mlp_out = layers.mlp(lp["mlp"], h, cfg.mlp)
    x = x + shard(mlp_out, "activation")
    return x, aux


def _ssm_layer_fwd(cfg, lp, x, h0, shard: ShardFn):
    h = layers.apply_norm(cfg.norm, lp["norm"], x)
    y, state = ssm.ssm_forward(lp["ssm"], cfg, h, h0)
    return x + shard(y, "activation"), state


def _shared_attn_fwd(cfg, sp, x, positions, shard: ShardFn):
    h = layers.apply_norm(cfg.norm, sp["attn_norm"], x)
    attn_out = attention.gqa_forward(sp["attn"], cfg, h, positions, window=0)
    x = x + shard(attn_out, "activation")
    h = layers.apply_norm(cfg.norm, sp["mlp_norm"], x)
    x = x + shard(layers.mlp(sp["mlp"], h, cfg.mlp), "activation")
    return x


# ===========================================================================
# Forward (train / prefill trunk): tokens -> final hidden states
# ===========================================================================


def _window_array(cfg: ArchConfig) -> jnp.ndarray:
    return jnp.asarray(cfg.layer_window_sizes(), jnp.int32)


def _run_encoder(cfg, params, enc_in, shard: ShardFn):
    """Bidirectional encoder over precomputed frame embeddings."""
    pos = jnp.arange(enc_in.shape[1], dtype=jnp.int32)

    def body(x, lp):
        h = layers.apply_norm(cfg.norm, lp["attn_norm"], x)
        a = attention.gqa_forward(lp["attn"], cfg, h, pos, window=0, causal=False)
        x = x + shard(a, "activation")
        h = layers.apply_norm(cfg.norm, lp["mlp_norm"], x)
        x = x + shard(layers.mlp(lp["mlp"], h, cfg.mlp), "activation")
        return x, None

    x, _ = jax.lax.scan(body, enc_in, params["encoder"]["layers"])
    return layers.apply_norm(
        cfg.norm, params["encoder"]["final_norm"], x
    )


def trunk(
    cfg: ArchConfig,
    params: Dict,
    tokens: jnp.ndarray,  # (B, S)
    *,
    positions: Optional[jnp.ndarray] = None,  # (S,) or mrope (3, B, S)
    frontend_embeds: Optional[jnp.ndarray] = None,  # (B, F, d)
    encoder_tokens: Optional[jnp.ndarray] = None,  # (B, F, d) audio frames
    shard: ShardFn = _no_shard,
    remat: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Embeds, runs the layer stack, final-norms. Returns (hidden, aux)."""
    b, s = tokens.shape
    x = _embed_tokens(cfg, params, tokens)
    memory = None
    if encoder_tokens is not None:
        memory = _run_encoder(cfg, params, encoder_tokens.astype(x.dtype), shard)
    if frontend_embeds is not None and encoder_tokens is None:
        # VLM / audio-LM: patch embeddings prepended to the text stream
        x = jnp.concatenate([frontend_embeds.astype(x.dtype), x], axis=1)
        s = x.shape[1]
    x = shard(x, "activation")

    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)
    elif cfg.mrope and frontend_embeds is not None:
        pass  # caller supplied full positions covering frontend + text

    aux_total = jnp.zeros((), jnp.float32)

    if cfg.arch_type in ("dense", "moe", "vlm", "audio"):
        windows = _window_array(cfg)

        def body(carry, xs):
            h, aux = carry
            lp, win = xs
            h, a = _decoder_layer_fwd(cfg, lp, h, positions, win, memory, shard)
            return (h, aux + a), None

        step = jax.checkpoint(body) if remat else body
        (x, aux_total), _ = jax.lax.scan(
            step, (x, aux_total), (params["layers"], windows)
        )

    elif cfg.arch_type == "ssm":

        def body(h, lp):
            h, _ = _ssm_layer_fwd(cfg, lp, h, None, shard)
            return h, None

        step = jax.checkpoint(body) if remat else body
        x, _ = jax.lax.scan(step, x, params["layers"])

    elif cfg.arch_type == "hybrid":
        k = cfg.shared_attn_every
        n_groups = cfg.num_layers // k
        stacked = jax.tree_util.tree_map(
            lambda a: a.reshape((n_groups, k) + a.shape[1:]), params["layers"]
        )
        sp = params["shared_attn"]

        def group_body(h, group_params):
            def inner(hh, lp):
                hh, _ = _ssm_layer_fwd(cfg, lp, hh, None, shard)
                return hh, None

            h, _ = jax.lax.scan(inner, h, group_params)
            h = _shared_attn_fwd(cfg, sp, h, positions, shard)
            return h, None

        step = jax.checkpoint(group_body) if remat else group_body
        x, _ = jax.lax.scan(step, x, stacked)
    else:
        raise ValueError(cfg.arch_type)

    return layers.apply_norm(cfg.norm, params["final_norm"], x), aux_total


def forward(cfg: ArchConfig, params: Dict, batch: Dict, *,
            shard: ShardFn = _no_shard, remat: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full forward to logits. batch keys per configs.shapes.token_inputs."""
    hidden, aux = trunk(
        cfg,
        params,
        batch["tokens"],
        positions=batch.get("positions"),
        frontend_embeds=batch.get("frontend_embeds"),
        encoder_tokens=batch.get("encoder_tokens"),
        shard=shard,
        remat=remat,
    )
    logits = _lm_logits(cfg, params, hidden)
    return shard(logits, "logits"), aux


def loss_fn(cfg: ArchConfig, params: Dict, batch: Dict, *,
            shard: ShardFn = _no_shard, remat: bool = True) -> Tuple[jnp.ndarray, Dict]:
    """Next-token cross entropy (+ MoE aux). Frontend tokens, if any, are
    excluded from the loss (they precede the text stream)."""
    logits, aux = forward(cfg, params, batch, shard=shard, remat=remat)
    targets = batch["targets"]
    n_text = targets.shape[1]
    logits = logits[:, -n_text:]  # drop frontend positions
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones_like(nll)
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    aux_w = cfg.moe.router_aux_weight if cfg.moe is not None else 0.0
    total = loss + aux_w * aux
    return total, {"ce_loss": loss, "aux_loss": aux}


# ===========================================================================
# KV / state caches
# ===========================================================================


class Cache(NamedTuple):
    """Decode-time state for every family (unused fields are None)."""

    position: jnp.ndarray  # (B,) next write position
    attn_k: Optional[jnp.ndarray] = None  # (L, B, T, KV, D)
    attn_v: Optional[jnp.ndarray] = None
    # pattern-ring mode (§Perf iteration 3): windowed layers keep ring
    # buffers of length `window`; attn_k/attn_v then hold only the global
    # layers' full-length caches.
    local_k: Optional[jnp.ndarray] = None  # (L_local, B, W, KV, D)
    local_v: Optional[jnp.ndarray] = None
    mla_c: Optional[jnp.ndarray] = None  # (L, B, T, R)
    mla_rope: Optional[jnp.ndarray] = None  # (L, B, T, P)
    ssm_conv_x: Optional[jnp.ndarray] = None  # (L, B, d_conv-1, d_inner)
    ssm_conv_bc: Optional[jnp.ndarray] = None  # (L, B, d_conv-1, 2GN)
    ssm_state: Optional[jnp.ndarray] = None  # (L, B, H, P, N)
    shared_k: Optional[jnp.ndarray] = None  # (G, B, T, KV, D) zamba2
    shared_v: Optional[jnp.ndarray] = None
    cross_k: Optional[jnp.ndarray] = None  # (L, B, F, KV, D) enc-dec
    cross_v: Optional[jnp.ndarray] = None


def _pattern_split(cfg: ArchConfig):
    """(local_layer_indices, global_layer_indices) per the window table."""
    wins = cfg.layer_window_sizes()
    local = [i for i, w in enumerate(wins) if w > 0]
    glob = [i for i, w in enumerate(wins) if w == 0]
    return local, glob


def init_cache(
    cfg: ArchConfig, batch: int, max_len: int, ring: bool = False
) -> Cache:
    dtype = _dtype(cfg)
    l = cfg.num_layers
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    pos = jnp.zeros((batch,), jnp.int32)
    if cfg.arch_type == "ssm":
        s = ssm.init_state(cfg, batch, dtype)
        return Cache(
            position=pos,
            ssm_conv_x=jnp.broadcast_to(s.conv_x, (l,) + s.conv_x.shape),
            ssm_conv_bc=jnp.broadcast_to(s.conv_bc, (l,) + s.conv_bc.shape),
            ssm_state=jnp.broadcast_to(s.ssd, (l,) + s.ssd.shape),
        )
    if cfg.arch_type == "hybrid":
        s = ssm.init_state(cfg, batch, dtype)
        g = cfg.num_layers // cfg.shared_attn_every
        return Cache(
            position=pos,
            ssm_conv_x=jnp.broadcast_to(s.conv_x, (l,) + s.conv_x.shape),
            ssm_conv_bc=jnp.broadcast_to(s.conv_bc, (l,) + s.conv_bc.shape),
            ssm_state=jnp.broadcast_to(s.ssd, (l,) + s.ssd.shape),
            shared_k=jnp.zeros((g, batch, max_len, kvh, hd), dtype),
            shared_v=jnp.zeros((g, batch, max_len, kvh, hd), dtype),
        )
    if cfg.attention == "mla":
        m = cfg.mla
        return Cache(
            position=pos,
            mla_c=jnp.zeros((l, batch, max_len, m.kv_lora_rank), dtype),
            mla_rope=jnp.zeros((l, batch, max_len, m.qk_rope_head_dim), dtype),
        )
    if ring and cfg.num_heads and any(w > 0 for w in cfg.layer_window_sizes()):
        local, glob = _pattern_split(cfg)
        w = min(cfg.sliding_window, max_len)
        cache = Cache(
            position=pos,
            local_k=jnp.zeros((len(local), batch, w, kvh, hd), dtype),
            local_v=jnp.zeros((len(local), batch, w, kvh, hd), dtype),
            attn_k=(
                jnp.zeros((len(glob), batch, max_len, kvh, hd), dtype)
                if glob else None
            ),
            attn_v=(
                jnp.zeros((len(glob), batch, max_len, kvh, hd), dtype)
                if glob else None
            ),
        )
        return cache
    cache = Cache(
        position=pos,
        attn_k=jnp.zeros((l, batch, max_len, kvh, hd), dtype),
        attn_v=jnp.zeros((l, batch, max_len, kvh, hd), dtype),
    )
    if cfg.cross_attention:
        f = cfg.frontend_tokens
        cache = cache._replace(
            cross_k=jnp.zeros((l, batch, f, kvh, hd), dtype),
            cross_v=jnp.zeros((l, batch, f, kvh, hd), dtype),
        )
    return cache


def cache_shapes(cfg: ArchConfig, batch: int, max_len: int, ring: bool = False):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len, ring))


# ===========================================================================
# Decode step
# ===========================================================================


def decode_step(
    cfg: ArchConfig,
    params: Dict,
    cache: Cache,
    tokens: jnp.ndarray,  # (B, 1)
    *,
    positions: Optional[jnp.ndarray] = None,  # mrope (3, B, 1)
    shard: ShardFn = _no_shard,
) -> Tuple[jnp.ndarray, Cache]:
    """One serving step: consume ONE token per sequence, emit logits for
    the next, update the cache in place (functionally). When the cache was
    built with ``ring=True`` (``local_k`` present), sliding-window layers
    use ring buffers of length `window` (§Perf iteration 3)."""
    b = tokens.shape[0]
    x = _embed_tokens(cfg, params, tokens)
    x = shard(x, "decode_activation")
    pos = cache.position  # (B,)
    mpos = positions if cfg.mrope else pos

    if cache.local_k is not None:
        return _decode_step_pattern_ring(cfg, params, cache, x, pos, shard)

    if cfg.arch_type in ("dense", "moe", "vlm", "audio"):
        windows = _window_array(cfg)

        def body(carry, xs):
            h = carry
            lp, win, kc, vc, cc, rc, xk, xv = xs
            hh = layers.apply_norm(cfg.norm, lp["attn_norm"], h)
            if cfg.attention == "mla":
                a, cc, rc = attention.mla_decode(lp["attn"], cfg, hh, cc, rc, pos)
            else:
                a, kc, vc = attention.gqa_decode(
                    lp["attn"], cfg, hh, kc, vc, mpos, window=win,
                    cache_pos=pos,
                )
            h = h + shard(a, "decode_activation")
            if cfg.cross_attention:
                hh = layers.apply_norm(cfg.norm, lp["cross_norm"], h)
                h = h + shard(
                    attention.gqa_cross_decode(lp["cross"], cfg, hh, xk, xv),
                    "decode_activation",
                )
            hh = layers.apply_norm(cfg.norm, lp["mlp_norm"], h)
            if cfg.moe is not None:
                m, _ = moe.moe_forward(lp["moe"], cfg, hh, shard=shard)
            else:
                m = layers.mlp(lp["mlp"], hh, cfg.mlp)
            h = h + shard(m, "decode_activation")
            return h, (kc, vc, cc, rc)

        l = cfg.num_layers
        dummy = jnp.zeros((l, 1, 1), _dtype(cfg))
        xs = (
            params["layers"],
            windows,
            cache.attn_k if cache.attn_k is not None else dummy,
            cache.attn_v if cache.attn_v is not None else dummy,
            cache.mla_c if cache.mla_c is not None else dummy,
            cache.mla_rope if cache.mla_rope is not None else dummy,
            cache.cross_k if cache.cross_k is not None else dummy,
            cache.cross_v if cache.cross_v is not None else dummy,
        )
        x, (nk, nv, nc, nr) = jax.lax.scan(body, x, xs)
        cache = cache._replace(
            attn_k=nk if cache.attn_k is not None else None,
            attn_v=nv if cache.attn_v is not None else None,
            mla_c=nc if cache.mla_c is not None else None,
            mla_rope=nr if cache.mla_rope is not None else None,
        )

    elif cfg.arch_type == "ssm":

        def body(h, xs):
            lp, cx, cbc, st = xs
            hh = layers.apply_norm(cfg.norm, lp["norm"], h)
            y, new = ssm.ssm_decode(
                lp["ssm"], cfg, hh, ssm.SSMState(cx, cbc, st)
            )
            return h + shard(y, "decode_activation"), (
                new.conv_x, new.conv_bc, new.ssd
            )

        x, (ncx, ncbc, nstate) = jax.lax.scan(
            body, x,
            (params["layers"], cache.ssm_conv_x, cache.ssm_conv_bc, cache.ssm_state),
        )
        cache = cache._replace(
            ssm_conv_x=ncx, ssm_conv_bc=ncbc, ssm_state=nstate
        )

    elif cfg.arch_type == "hybrid":
        k = cfg.shared_attn_every
        g = cfg.num_layers // k
        stacked = jax.tree_util.tree_map(
            lambda a: a.reshape((g, k) + a.shape[1:]), params["layers"]
        )
        conv_x_g = cache.ssm_conv_x.reshape((g, k) + cache.ssm_conv_x.shape[1:])
        conv_bc_g = cache.ssm_conv_bc.reshape((g, k) + cache.ssm_conv_bc.shape[1:])
        state_g = cache.ssm_state.reshape((g, k) + cache.ssm_state.shape[1:])
        sp = params["shared_attn"]

        def group_body(h, xs):
            gp, cxg, cbcg, st, sk, sv = xs

            def inner(hh, inner_xs):
                lp, cx1, cbc1, s1 = inner_xs
                hn = layers.apply_norm(cfg.norm, lp["norm"], hh)
                y, new = ssm.ssm_decode(
                    lp["ssm"], cfg, hn, ssm.SSMState(cx1, cbc1, s1)
                )
                return hh + shard(y, "decode_activation"), (
                    new.conv_x, new.conv_bc, new.ssd
                )

            h, (ncx, ncbc, nst) = jax.lax.scan(inner, h, (gp, cxg, cbcg, st))
            hh = layers.apply_norm(cfg.norm, sp["attn_norm"], h)
            a, sk, sv = attention.gqa_decode(sp["attn"], cfg, hh, sk, sv, pos, window=0)
            h = h + shard(a, "decode_activation")
            hh = layers.apply_norm(cfg.norm, sp["mlp_norm"], h)
            h = h + shard(layers.mlp(sp["mlp"], hh, cfg.mlp), "decode_activation")
            return h, (ncx, ncbc, nst, sk, sv)

        x, (ncx, ncbc, nstate, nsk, nsv) = jax.lax.scan(
            group_body, x,
            (stacked, conv_x_g, conv_bc_g, state_g, cache.shared_k, cache.shared_v),
        )
        cache = cache._replace(
            ssm_conv_x=ncx.reshape(cache.ssm_conv_x.shape),
            ssm_conv_bc=ncbc.reshape(cache.ssm_conv_bc.shape),
            ssm_state=nstate.reshape(cache.ssm_state.shape),
            shared_k=nsk,
            shared_v=nsv,
        )
    else:
        raise ValueError(cfg.arch_type)

    x = layers.apply_norm(cfg.norm, params["final_norm"], x)
    logits = _lm_logits(cfg, params, x)
    cache = cache._replace(position=cache.position + 1)
    return shard(logits, "decode_logits"), cache


def _decode_step_pattern_ring(
    cfg: ArchConfig, params: Dict, cache: Cache, x, pos, shard: ShardFn
) -> Tuple[jnp.ndarray, Cache]:
    """Decode with ring buffers on windowed layers.

    Layers are regrouped statically: local (windowed) layers run in scans
    over their ring caches; global layers (full caches) are interleaved at
    their original positions. For uniform-window archs (starcoder2,
    mixtral) there are no global layers and this is a single scan."""
    import numpy as np

    local_idx, glob_idx = _pattern_split(cfg)
    stacked = params["layers"]

    def take(tree, idx):
        arr = np.asarray(idx)
        return jax.tree_util.tree_map(lambda a: a[arr], tree)

    def run_local_scan(h, lp_stack, kc, vc):
        def body(hh, xs):
            lp, k1, v1 = xs
            hn = layers.apply_norm(cfg.norm, lp["attn_norm"], hh)
            a, k1, v1 = attention.gqa_decode(
                lp["attn"], cfg, hn, k1, v1, pos, window=0,
                cache_pos=pos, ring=True,
            )
            hh = hh + shard(a, "decode_activation")
            hn = layers.apply_norm(cfg.norm, lp["mlp_norm"], hh)
            if cfg.moe is not None:
                mo, _ = moe.moe_forward(lp["moe"], cfg, hn, shard=shard)
            else:
                mo = layers.mlp(lp["mlp"], hn, cfg.mlp)
            hh = hh + shard(mo, "decode_activation")
            return hh, (k1, v1)

        return jax.lax.scan(body, h, (lp_stack, kc, vc))

    def run_global_one(h, lp, kc, vc):
        hn = layers.apply_norm(cfg.norm, lp["attn_norm"], h)
        a, kc, vc = attention.gqa_decode(
            lp["attn"], cfg, hn, kc, vc, pos, window=0, cache_pos=pos,
        )
        h = h + shard(a, "decode_activation")
        hn = layers.apply_norm(cfg.norm, lp["mlp_norm"], h)
        if cfg.moe is not None:
            mo, _ = moe.moe_forward(lp["moe"], cfg, hn, shard=shard)
        else:
            mo = layers.mlp(lp["mlp"], hn, cfg.mlp)
        h = h + shard(mo, "decode_activation")
        return h, kc, vc

    # walk layers in original order as runs of locals broken by globals
    h = x
    new_local_k = []
    new_local_v = []
    new_glob_k = []
    new_glob_v = []
    li = 0  # cursor into local cache stack
    gi = 0
    i = 0
    nl = len(local_idx)
    while i < cfg.num_layers:
        # contiguous run of local layers
        run = 0
        while i + run < cfg.num_layers and (i + run) in set(local_idx):
            run += 1
        if run:
            sl = slice(li, li + run)
            idxs = list(range(i, i + run))
            h, (nk, nv) = run_local_scan(
                h, take(stacked, idxs),
                cache.local_k[li : li + run], cache.local_v[li : li + run],
            )
            new_local_k.append(nk)
            new_local_v.append(nv)
            li += run
            i += run
        if i < cfg.num_layers:  # a global layer
            lp = jax.tree_util.tree_map(lambda a: a[i], stacked)
            h, nk, nv = run_global_one(
                h, lp, cache.attn_k[gi], cache.attn_v[gi]
            )
            new_glob_k.append(nk[None])
            new_glob_v.append(nv[None])
            gi += 1
            i += 1

    cache = cache._replace(
        local_k=jnp.concatenate(new_local_k, axis=0),
        local_v=jnp.concatenate(new_local_v, axis=0),
        attn_k=jnp.concatenate(new_glob_k, axis=0) if new_glob_k else cache.attn_k,
        attn_v=jnp.concatenate(new_glob_v, axis=0) if new_glob_v else cache.attn_v,
        position=cache.position + 1,
    )
    h = layers.apply_norm(cfg.norm, params["final_norm"], h)
    logits = _lm_logits(cfg, params, h)
    return shard(logits, "decode_logits"), cache


# ===========================================================================
# Prefill: process a full prompt, return cache ready for decode
# ===========================================================================


def prefill(
    cfg: ArchConfig,
    params: Dict,
    tokens: jnp.ndarray,  # (B, S)
    max_len: int,
    *,
    positions: Optional[jnp.ndarray] = None,
    frontend_embeds: Optional[jnp.ndarray] = None,
    encoder_tokens: Optional[jnp.ndarray] = None,
    shard: ShardFn = _no_shard,
) -> Tuple[jnp.ndarray, Cache]:
    """Returns (last-position logits (B, V), populated cache)."""
    b, s = tokens.shape
    cache = init_cache(cfg, b, max_len)
    x = _embed_tokens(cfg, params, tokens)
    memory = None
    if encoder_tokens is not None:
        memory = _run_encoder(cfg, params, encoder_tokens.astype(x.dtype), shard)
    if frontend_embeds is not None and encoder_tokens is None:
        x = jnp.concatenate([frontend_embeds.astype(x.dtype), x], axis=1)
        s = x.shape[1]
    x = shard(x, "activation")
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)

    if cfg.arch_type in ("dense", "moe", "vlm", "audio"):
        windows = _window_array(cfg)

        def body(h, xs):
            lp, win = xs
            hh = layers.apply_norm(cfg.norm, lp["attn_norm"], h)
            if cfg.attention == "mla":
                a = attention.mla_forward(lp["attn"], cfg, hh, positions)
                c_kv, k_rope = attention.mla_prefill_cache(lp["attn"], cfg, hh, positions)
                new_kv = (c_kv, k_rope)
            else:
                a = attention.gqa_forward(lp["attn"], cfg, hh, positions, window=win)
                new_kv = attention.gqa_prefill_kv(lp["attn"], cfg, hh, positions)
            h = h + shard(a, "activation")
            ck = cv = None
            if cfg.cross_attention:
                hh = layers.apply_norm(cfg.norm, lp["cross_norm"], h)
                qpos = positions if positions.ndim == 1 else positions[0, 0]
                cr = attention.gqa_forward(
                    lp["cross"], cfg, hh, qpos, window=0, causal=False,
                    kv_override=(memory, memory),
                )
                h = h + shard(cr, "activation")
                kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
                f = memory.shape[1]
                ck = (memory @ lp["cross"]["w_k"]).reshape(b, f, kvh, hd)
                cv = (memory @ lp["cross"]["w_v"]).reshape(b, f, kvh, hd)
            hh = layers.apply_norm(cfg.norm, lp["mlp_norm"], h)
            if cfg.moe is not None:
                m, _ = moe.moe_forward(lp["moe"], cfg, hh, shard=shard)
            else:
                m = layers.mlp(lp["mlp"], hh, cfg.mlp)
            h = h + shard(m, "activation")
            return h, (new_kv, ck, cv)

        x, (new_kvs, cks, cvs) = jax.lax.scan(body, x, (params["layers"], windows))
        if cfg.attention == "mla":
            c_all, rope_all = new_kvs  # (L, B, S, R), (L, B, S, P)
            cache = cache._replace(
                mla_c=jax.lax.dynamic_update_slice(
                    cache.mla_c, c_all.astype(cache.mla_c.dtype), (0, 0, 0, 0)
                ),
                mla_rope=jax.lax.dynamic_update_slice(
                    cache.mla_rope, rope_all.astype(cache.mla_rope.dtype), (0, 0, 0, 0)
                ),
            )
        else:
            k_all, v_all = new_kvs  # (L, B, S, KV, D)
            cache = cache._replace(
                attn_k=jax.lax.dynamic_update_slice(
                    cache.attn_k, k_all.astype(cache.attn_k.dtype), (0,) * 5
                ),
                attn_v=jax.lax.dynamic_update_slice(
                    cache.attn_v, v_all.astype(cache.attn_v.dtype), (0,) * 5
                ),
            )
        if cfg.cross_attention:
            cache = cache._replace(
                cross_k=cks.astype(_dtype(cfg)), cross_v=cvs.astype(_dtype(cfg))
            )

    elif cfg.arch_type == "ssm":

        def body(h, lp):
            hh = layers.apply_norm(cfg.norm, lp["norm"], h)
            y, st = ssm.ssm_forward(lp["ssm"], cfg, hh)
            return h + shard(y, "activation"), (st.conv_x, st.conv_bc, st.ssd)

        x, (cxs, cbcs, states) = jax.lax.scan(body, x, params["layers"])
        cache = cache._replace(
            ssm_conv_x=cxs, ssm_conv_bc=cbcs, ssm_state=states
        )

    elif cfg.arch_type == "hybrid":
        k = cfg.shared_attn_every
        g = cfg.num_layers // k
        stacked = jax.tree_util.tree_map(
            lambda a: a.reshape((g, k) + a.shape[1:]), params["layers"]
        )
        sp = params["shared_attn"]

        def group_body(h, gp):
            def inner(hh, lp):
                hn = layers.apply_norm(cfg.norm, lp["norm"], hh)
                y, st = ssm.ssm_forward(lp["ssm"], cfg, hn)
                return hh + shard(y, "activation"), (
                    st.conv_x, st.conv_bc, st.ssd
                )

            h, (cxs, cbcs, states) = jax.lax.scan(inner, h, gp)
            hh = layers.apply_norm(cfg.norm, sp["attn_norm"], h)
            a = attention.gqa_forward(sp["attn"], cfg, hh, positions, window=0)
            sk, sv = attention.gqa_prefill_kv(sp["attn"], cfg, hh, positions)
            h = h + shard(a, "activation")
            hh = layers.apply_norm(cfg.norm, sp["mlp_norm"], h)
            h = h + shard(layers.mlp(sp["mlp"], hh, cfg.mlp), "activation")
            return h, (cxs, cbcs, states, sk, sv)

        x, (cxs, cbcs, states, sks, svs) = jax.lax.scan(group_body, x, stacked)
        cache = cache._replace(
            ssm_conv_x=cxs.reshape((cfg.num_layers,) + cxs.shape[2:]),
            ssm_conv_bc=cbcs.reshape((cfg.num_layers,) + cbcs.shape[2:]),
            ssm_state=states.reshape((cfg.num_layers,) + states.shape[2:]),
            shared_k=jax.lax.dynamic_update_slice(
                cache.shared_k, sks.astype(cache.shared_k.dtype), (0,) * 5
            ),
            shared_v=jax.lax.dynamic_update_slice(
                cache.shared_v, svs.astype(cache.shared_v.dtype), (0,) * 5
            ),
        )
    else:
        raise ValueError(cfg.arch_type)

    x = layers.apply_norm(cfg.norm, params["final_norm"], x)
    logits = _lm_logits(cfg, params, x[:, -1])
    cache = cache._replace(position=jnp.full((b,), s, jnp.int32))
    return logits, cache
