"""Attention: GQA/MQA, sliding windows, MLA, cross-attention, KV caches.

Three execution paths:

* ``attend_chunked`` — train/prefill. Memory-bounded online-softmax
  attention (a pure-JAX flash-attention analogue): lax.scan over query
  chunks with an inner scan over KV chunks carrying (max, denom, acc).
  Never materializes an (S, S) score matrix — prefill_32k would need
  4.3 GB per (batch, head) otherwise.
* ``attend_decode`` — serve_step. One query against a full cache; linear
  in cache length.
* MLA (MiniCPM3) — latent-compressed KV. Prefill materializes k/v from
  the latent; decode uses the *absorbed* form (W_uk folded into the
  query, W_uv folded into the output) so the cache holds only the 256-d
  latent + 32-d decoupled RoPE key per token.

Window masking is data-driven: ``window`` arrives as a traced int32 so a
single scanned layer graph serves both local and global layers (gemma3's
5:1 pattern) — window == 0 means full/global attention.
"""

from __future__ import annotations

import functools
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MLAConfig
from repro.models import layers

NEG_INF = -2.0e38

# §Perf iteration 5: cast softmax probabilities to bf16 before the PV
# matmul (f32 accumulation preserved via preferred_element_type). Halves
# the traffic of the largest chunked-attention intermediate; enabled by
# REPRO_BF16_ATTN=1 so baseline/optimized dry-runs stay distinguishable.
import os as _os
BF16_PROBS = _os.environ.get("REPRO_BF16_ATTN") == "1"


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ArchConfig, dtype=jnp.float32) -> Dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    if cfg.attention == "mla":
        m = cfg.mla
        k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
        qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
        return {
            "w_dq": layers._dense_init(k1, (d, m.q_lora_rank), dtype),
            "q_norm": layers.init_rmsnorm(m.q_lora_rank, dtype),
            "w_uq": layers._dense_init(k2, (m.q_lora_rank, h * qk_head), dtype),
            "w_dkv": layers._dense_init(
                k3, (d, m.kv_lora_rank + m.qk_rope_head_dim), dtype
            ),
            "kv_norm": layers.init_rmsnorm(m.kv_lora_rank, dtype),
            "w_uk": layers._dense_init(
                k4, (m.kv_lora_rank, h * m.qk_nope_head_dim), dtype
            ),
            "w_uv": layers._dense_init(
                k5, (m.kv_lora_rank, h * m.v_head_dim), dtype
            ),
            "w_o": layers._dense_init(k6, (h * m.v_head_dim, d), dtype),
        }
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "w_q": layers._dense_init(k1, (d, h * hd), dtype),
        "w_k": layers._dense_init(k2, (d, kv * hd), dtype),
        "w_v": layers._dense_init(k3, (d, kv * hd), dtype),
        "w_o": layers._dense_init(k4, (h * hd, d), dtype),
    }


def init_cross_attention(key, cfg: ArchConfig, dtype=jnp.float32) -> Dict:
    return init_attention(key, cfg, dtype)


# ---------------------------------------------------------------------------
# Chunked (flash-style) attention — train/prefill
# ---------------------------------------------------------------------------


def _window_mask(
    q_pos: jnp.ndarray, k_pos: jnp.ndarray, window, causal: bool
) -> jnp.ndarray:
    """(Q, K) boolean mask. window: traced int32, 0 => no window."""
    q = q_pos[:, None]
    k = k_pos[None, :]
    mask = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        mask &= k <= q
    win = jnp.asarray(window, jnp.int32)
    mask &= (win == 0) | (q - k < win)
    return mask


class _SoftmaxCarry(NamedTuple):
    m: jnp.ndarray  # running max      (B, H, Qc)
    denom: jnp.ndarray  # running sum  (B, H, Qc)
    acc: jnp.ndarray  # weighted accum (B, H, Qc, D)


def attend_chunked(
    q: jnp.ndarray,  # (B, S, H, D)
    k: jnp.ndarray,  # (B, T, KV, D)
    v: jnp.ndarray,  # (B, T, KV, D)
    *,
    q_positions: jnp.ndarray,  # (S,)
    k_positions: jnp.ndarray,  # (T,)
    window=0,
    causal: bool = True,
    q_chunk: int = 512,
    k_chunk: int = 1024,
    softcap_val: float = 0.0,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Online-softmax attention, O(q_chunk * k_chunk) live score memory.
    Supports distinct k and v head dims (MLA)."""
    b, s, h, d = q.shape
    t = k.shape[1]
    kvh = k.shape[2]
    dv = v.shape[3]
    assert h % kvh == 0
    groups = h // kvh
    scale = (d ** -0.5) if scale is None else scale

    q_chunk = min(q_chunk, s)
    k_chunk = min(k_chunk, t)
    # pad S/T to chunk multiples
    s_pad = -(-s // q_chunk) * q_chunk
    t_pad = -(-t // k_chunk) * k_chunk
    qp = jnp.pad(q, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, t_pad - t), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, t_pad - t), (0, 0), (0, 0)))
    qpos = jnp.pad(q_positions, (0, s_pad - s), constant_values=-1)
    kpos = jnp.pad(k_positions, (0, t_pad - t), constant_values=2**30)

    nq, nk = s_pad // q_chunk, t_pad // k_chunk
    # (nq, B, Qc, H, D) etc.
    q_ch = jnp.moveaxis(qp.reshape(b, nq, q_chunk, h, d), 1, 0)
    k_ch = jnp.moveaxis(kp.reshape(b, nk, k_chunk, kvh, d), 1, 0)
    v_ch = jnp.moveaxis(vp.reshape(b, nk, k_chunk, kvh, dv), 1, 0)
    qpos_ch = qpos.reshape(nq, q_chunk)
    kpos_ch = kpos.reshape(nk, k_chunk)

    def q_step(_, q_in):
        q_blk, qpos_blk = q_in  # (B, Qc, H, D), (Qc,)

        def kv_step(carry: _SoftmaxCarry, kv_in):
            k_blk, v_blk, kpos_blk = kv_in
            # scores: (B, H, Qc, Kc) via GQA head grouping
            qg = q_blk.reshape(b, q_chunk, kvh, groups, d)
            scores = jnp.einsum(
                "bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                k_blk.astype(jnp.float32),
            ) * scale
            scores = layers.softcap(scores, softcap_val)
            mask = _window_mask(qpos_blk, kpos_blk, window, causal)
            scores = jnp.where(mask[None, None, None], scores, NEG_INF)
            m_new = jnp.maximum(
                carry.m, jnp.max(scores, axis=-1).reshape(b, h, q_chunk)
            )
            alpha = jnp.exp(carry.m - m_new)
            p = jnp.exp(
                scores - m_new.reshape(b, kvh, groups, q_chunk)[..., None]
            )
            denom = carry.denom * alpha + jnp.sum(p, axis=-1).reshape(
                b, h, q_chunk
            )
            if BF16_PROBS:
                pv = jax.lax.dot_general(
                    p.astype(jnp.bfloat16),
                    v_blk.astype(jnp.bfloat16),
                    dimension_numbers=((((4,), (1,))), (((0, 1)), ((0, 2)))),
                    preferred_element_type=jnp.float32,
                )  # (B, KVH, G, Qc, Dv)
                pv = pv.reshape(b, h, q_chunk, dv)
            else:
                pv = jnp.einsum(
                    "bhgqk,bkhd->bhgqd", p, v_blk.astype(jnp.float32)
                ).reshape(b, h, q_chunk, dv)
            acc = carry.acc * alpha[..., None] + pv
            return _SoftmaxCarry(m_new, denom, acc), None

        init = _SoftmaxCarry(
            m=jnp.full((b, h, q_chunk), NEG_INF, jnp.float32),
            denom=jnp.zeros((b, h, q_chunk), jnp.float32),
            acc=jnp.zeros((b, h, q_chunk, dv), jnp.float32),
        )
        carry, _ = jax.lax.scan(kv_step, init, (k_ch, v_ch, kpos_ch))
        out = carry.acc / jnp.maximum(carry.denom[..., None], 1e-30)
        return None, out  # (B, H, Qc, D)

    _, outs = jax.lax.scan(q_step, None, (q_ch, qpos_ch))
    # (nq, B, H, Qc, Dv) -> (B, nq, Qc, H, Dv) -> (B, S, H, Dv)
    out = jnp.moveaxis(outs, 0, 1).transpose(0, 1, 3, 2, 4)
    out = out.reshape(b, s_pad, h, dv)[:, :s]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode attention — one token vs. cache
# ---------------------------------------------------------------------------


def attend_decode(
    q: jnp.ndarray,  # (B, 1, H, D)
    k_cache: jnp.ndarray,  # (B, T, KV, D)
    v_cache: jnp.ndarray,  # (B, T, KV, D)
    *,
    position: jnp.ndarray,  # (B,) current position (cache index just written)
    window=0,
    softcap_val: float = 0.0,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    b, _, h, d = q.shape
    t, kvh = k_cache.shape[1], k_cache.shape[2]
    groups = h // kvh
    scale = (d ** -0.5) if scale is None else scale

    qg = q.reshape(b, kvh, groups, d)
    scores = jnp.einsum(
        "bhgd,bkhd->bhgk", qg.astype(jnp.float32),
        k_cache.astype(jnp.float32),
    ) * scale
    scores = layers.softcap(scores, softcap_val)
    kpos = jnp.arange(t, dtype=jnp.int32)[None, :]  # (1, T)
    pos = position.astype(jnp.int32)[:, None]
    valid = kpos <= pos
    win = jnp.asarray(window, jnp.int32)
    valid &= (win == 0) | (pos - kpos < win)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, d).astype(q.dtype)


def attend_decode_ring(
    q: jnp.ndarray,  # (B, 1, H, D)
    k_cache: jnp.ndarray,  # (B, T, KV, D) ring buffer, T == window
    v_cache: jnp.ndarray,
    *,
    position: jnp.ndarray,  # (B,) absolute position just written
    softcap_val: float = 0.0,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Decode attention over a ring buffer: every stored entry is inside
    the window by construction; mask only unwritten warm-up slots."""
    b, _, h, d = q.shape
    t, kvh = k_cache.shape[1], k_cache.shape[2]
    groups = h // kvh
    scale = (d ** -0.5) if scale is None else scale
    qg = q.reshape(b, kvh, groups, d)
    scores = jnp.einsum(
        "bhgd,bkhd->bhgk", qg.astype(jnp.float32),
        k_cache.astype(jnp.float32),
    ) * scale
    scores = layers.softcap(scores, softcap_val)
    slots = jnp.arange(t, dtype=jnp.int32)[None, :]
    pos = position.astype(jnp.int32)[:, None]
    written = (slots <= pos) | (pos >= t)
    scores = jnp.where(written[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# Full GQA block apply (projections + rope + attention)
# ---------------------------------------------------------------------------


def gqa_forward(
    params: Dict,
    cfg: ArchConfig,
    x: jnp.ndarray,  # (B, S, d_model)
    positions: jnp.ndarray,  # (S,) or mrope (3, B, S)
    window=0,
    causal: bool = True,
    kv_override: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
) -> jnp.ndarray:
    """Train/prefill attention. kv_override supplies encoder memory for
    cross-attention (positions then index the memory)."""
    b, s, _ = x.shape
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = (x @ params["w_q"]).reshape(b, s, h, hd)
    if kv_override is None:
        k = (x @ params["w_k"]).reshape(b, s, kvh, hd)
        v = (x @ params["w_v"]).reshape(b, s, kvh, hd)
        if cfg.mrope:
            ang = layers.mrope_angles(
                positions, hd, cfg.rope_theta, cfg.mrope_sections
            )  # (B, S, hd//2)
            q = layers.apply_rope(q, ang)
            k = layers.apply_rope(k, ang)
            qpos = positions[0, 0] if positions.ndim == 3 else positions
        else:
            ang = layers.rope_angles(positions, hd, cfg.rope_theta)
            q = layers.apply_rope(q, ang)
            k = layers.apply_rope(k, ang)
            qpos = positions
        kpos = qpos
    else:
        mem = kv_override[0]
        t = mem.shape[1]
        k = (mem @ params["w_k"]).reshape(b, t, kvh, hd)
        v = (mem @ params["w_v"]).reshape(b, t, kvh, hd)
        qpos = positions
        kpos = jnp.arange(t, dtype=jnp.int32)
        causal = False
    out = attend_chunked(
        q, k, v,
        q_positions=qpos,
        k_positions=kpos,
        window=window,
        causal=causal,
        softcap_val=cfg.logit_softcap,
    )
    return out.reshape(b, s, h * hd) @ params["w_o"]


def gqa_prefill_kv(
    params: Dict, cfg: ArchConfig, x: jnp.ndarray, positions: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """K/V to store in the cache during prefill (rope already applied)."""
    b, s, _ = x.shape
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    k = (x @ params["w_k"]).reshape(b, s, kvh, hd)
    v = (x @ params["w_v"]).reshape(b, s, kvh, hd)
    if cfg.mrope:
        ang = layers.mrope_angles(positions, hd, cfg.rope_theta, cfg.mrope_sections)
    else:
        ang = layers.rope_angles(positions, hd, cfg.rope_theta)
    return layers.apply_rope(k, ang), v


def gqa_decode(
    params: Dict,
    cfg: ArchConfig,
    x: jnp.ndarray,  # (B, 1, d_model)
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    position: jnp.ndarray,  # rope position: (B,) or mrope (3, B, 1)
    window=0,
    cache_pos: Optional[jnp.ndarray] = None,  # (B,) cache write index
    ring: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One decode step. Returns (out, new_k_cache, new_v_cache).

    ``position`` drives the rotary embedding; ``cache_pos`` is the slot
    the new KV is written to and the causal/window horizon. They differ
    for M-RoPE (image patches share a temporal position but occupy
    distinct cache slots); for text decode they coincide."""
    b = x.shape[0]
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = (x @ params["w_q"]).reshape(b, 1, h, hd)
    k = (x @ params["w_k"]).reshape(b, 1, kvh, hd)
    v = (x @ params["w_v"]).reshape(b, 1, kvh, hd)
    if cfg.mrope:
        ang = layers.mrope_angles(
            position, hd, cfg.rope_theta, cfg.mrope_sections
        )  # (B, 1, hd//2)
        pos_scalar = position[0, :, 0] if cache_pos is None else cache_pos
    else:
        ang = layers.rope_angles(position[:, None], hd, cfg.rope_theta)
        pos_scalar = position if cache_pos is None else cache_pos
    q = layers.apply_rope(q, ang)
    k = layers.apply_rope(k, ang)
    if ring:
        # §Perf iteration 3: ring-buffer cache for sliding-window layers.
        # The cache holds exactly the last T positions (T == window);
        # contents are within-window by construction, so the only mask
        # needed is the warm-up one (slots not yet written).
        t_ring = k_cache.shape[1]
        slot = pos_scalar % t_ring
        k_cache = _cache_write(k_cache, k[:, 0], slot)
        v_cache = _cache_write(v_cache, v[:, 0], slot)
        out = attend_decode_ring(
            q, k_cache, v_cache,
            position=pos_scalar,
            softcap_val=cfg.logit_softcap,
        )
    else:
        # write at the cache slot (vmapped DUS over batch)
        k_cache = _cache_write(k_cache, k[:, 0], pos_scalar)
        v_cache = _cache_write(v_cache, v[:, 0], pos_scalar)
        out = attend_decode(
            q, k_cache, v_cache,
            position=pos_scalar,
            window=window,
            softcap_val=cfg.logit_softcap,
        )
    return out.reshape(b, 1, h * hd) @ params["w_o"], k_cache, v_cache


def _cache_write(cache: jnp.ndarray, new: jnp.ndarray, pos: jnp.ndarray):
    """cache (B, T, ...) <- new (B, ...) at per-batch positions (B,)."""

    def write_one(c, n, p):
        return jax.lax.dynamic_update_slice(
            c, n[None], (p,) + (0,) * (c.ndim - 1)
        )

    return jax.vmap(write_one)(cache, new, pos.astype(jnp.int32))


def gqa_cross_decode(
    params: Dict,
    cfg: ArchConfig,
    x: jnp.ndarray,  # (B, 1, d)
    mem_k: jnp.ndarray,  # precomputed encoder K (B, T, KV, D)
    mem_v: jnp.ndarray,
) -> jnp.ndarray:
    b = x.shape[0]
    h, hd = cfg.num_heads, cfg.resolved_head_dim
    q = (x @ params["w_q"]).reshape(b, 1, h, hd)
    t = mem_k.shape[1]
    out = attend_decode(
        q, mem_k, mem_v,
        position=jnp.full((b,), t - 1, jnp.int32),  # all memory visible
        window=0,
        softcap_val=cfg.logit_softcap,
    )
    return out.reshape(b, 1, h * hd) @ params["w_o"]


# ---------------------------------------------------------------------------
# MLA (Multi-head Latent Attention) — MiniCPM3
# ---------------------------------------------------------------------------


def mla_forward(
    params: Dict,
    cfg: ArchConfig,
    x: jnp.ndarray,
    positions: jnp.ndarray,
) -> jnp.ndarray:
    """Train/prefill MLA: materialize per-head k/v from the latent."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim

    q_lat = layers.rmsnorm(params["q_norm"], x @ params["w_dq"])
    q = (q_lat @ params["w_uq"]).reshape(b, s, h, qk_head)
    q_nope, q_rope = (
        q[..., : m.qk_nope_head_dim],
        q[..., m.qk_nope_head_dim :],
    )

    dkv = x @ params["w_dkv"]  # (B, S, kv_lora + rope)
    c_kv = layers.rmsnorm(params["kv_norm"], dkv[..., : m.kv_lora_rank])
    k_rope = dkv[..., m.kv_lora_rank :][:, :, None]  # (B, S, 1, rope_dim)

    ang = layers.rope_angles(positions, m.qk_rope_head_dim, cfg.rope_theta)
    q_rope = layers.apply_rope(q_rope, ang)
    k_rope = layers.apply_rope(k_rope, ang)

    k_nope = (c_kv @ params["w_uk"]).reshape(b, s, h, m.qk_nope_head_dim)
    v = (c_kv @ params["w_uv"]).reshape(b, s, h, m.v_head_dim)

    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, k_nope.shape[:-1] + (m.qk_rope_head_dim,))],
        axis=-1,
    )
    out = attend_chunked(
        q_full, k_full, v,
        q_positions=positions,
        k_positions=positions,
        window=0,
        causal=True,
        scale=qk_head ** -0.5,
    )
    return out.reshape(b, s, h * m.v_head_dim) @ params["w_o"]


def mla_prefill_cache(
    params: Dict, cfg: ArchConfig, x: jnp.ndarray, positions: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Latent cache entries: (c_kv (B,S,R), k_rope (B,S,rope))."""
    m = cfg.mla
    dkv = x @ params["w_dkv"]
    c_kv = layers.rmsnorm(params["kv_norm"], dkv[..., : m.kv_lora_rank])
    k_rope = dkv[..., m.kv_lora_rank :][:, :, None]
    ang = layers.rope_angles(positions, m.qk_rope_head_dim, cfg.rope_theta)
    return c_kv, layers.apply_rope(k_rope, ang)[:, :, 0]


def mla_decode(
    params: Dict,
    cfg: ArchConfig,
    x: jnp.ndarray,  # (B, 1, d)
    c_cache: jnp.ndarray,  # (B, T, R) latent cache
    rope_cache: jnp.ndarray,  # (B, T, rope_dim)
    position: jnp.ndarray,  # (B,)
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Absorbed-form decode: scores = q_nope W_uk^T . c  +  q_rope . k_rope.

    The cache stores ONLY (c_kv, k_rope): kv_lora_rank + qk_rope_head_dim
    = 288 floats/token for MiniCPM3 vs 2*40*64 = 5120 for the equivalent
    GQA cache — an 17.8x KV compression, which is exactly what makes MLA
    the best offload/serving case in DESIGN.md §Arch-applicability."""
    m = cfg.mla
    b = x.shape[0]
    h = cfg.num_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim

    q_lat = layers.rmsnorm(params["q_norm"], x @ params["w_dq"])
    q = (q_lat @ params["w_uq"]).reshape(b, 1, h, qk_head)
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim :]
    ang = layers.rope_angles(position[:, None], m.qk_rope_head_dim, cfg.rope_theta)
    q_rope = layers.apply_rope(q_rope, ang)[:, 0]  # (B, H, rope)

    dkv = x @ params["w_dkv"]
    c_new = layers.rmsnorm(params["kv_norm"], dkv[..., : m.kv_lora_rank])[:, 0]
    k_rope_new = layers.apply_rope(
        dkv[..., m.kv_lora_rank :][:, :, None], ang
    )[:, 0, 0]
    c_cache = _cache_write(c_cache, c_new, position)
    rope_cache = _cache_write(rope_cache, k_rope_new, position)

    # absorb W_uk into q: (B, H, nope) @ (R, H, nope)^T -> (B, H, R)
    w_uk = params["w_uk"].reshape(m.kv_lora_rank, h, m.qk_nope_head_dim)
    q_abs = jnp.einsum("bhn,rhn->bhr", q_nope[:, 0].astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    scores = jnp.einsum("bhr,btr->bht", q_abs, c_cache.astype(jnp.float32))
    scores += jnp.einsum(
        "bhp,btp->bht", q_rope.astype(jnp.float32),
        rope_cache.astype(jnp.float32),
    )
    scores *= qk_head ** -0.5
    t = c_cache.shape[1]
    valid = jnp.arange(t)[None] <= position[:, None]
    scores = jnp.where(valid[:, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    o_lat = jnp.einsum("bht,btr->bhr", p, c_cache.astype(jnp.float32))
    # absorb W_uv on the way out: (B, H, R) x (R, H, v) -> (B, H, v)
    w_uv = params["w_uv"].reshape(m.kv_lora_rank, h, m.v_head_dim)
    o = jnp.einsum("bhr,rhv->bhv", o_lat, w_uv.astype(jnp.float32))
    out = o.reshape(b, 1, h * m.v_head_dim).astype(x.dtype) @ params["w_o"]
    return out, c_cache, rope_cache
