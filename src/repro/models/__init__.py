"""Model substrate: layers, attention, SSM, MoE, transformer assembly."""

from repro.models import attention, layers, moe, multimodal, ssm, transformer  # noqa: F401
