"""Modality frontend STUBS (the one mandated carve-out).

[audio] and [vlm] architectures specify the transformer backbone only;
the real frontends (mel-spectrogram + conformer codec for seamless-m4t,
ViT + dynamic-resolution projector for qwen2-vl) are NOT implemented.
Instead these helpers produce correctly-shaped frame/patch embeddings:
ShapeDtypeStructs for the dry-run, deterministic pseudo-embeddings for
smoke tests, and M-RoPE position grids for qwen2-vl.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig


def frontend_spec(cfg: ArchConfig, batch: int) -> jax.ShapeDtypeStruct:
    """Shape of the precomputed embeddings the backbone consumes."""
    assert cfg.modality in ("audio", "vision"), cfg.modality
    return jax.ShapeDtypeStruct(
        (batch, cfg.frontend_tokens, cfg.d_model), jnp.dtype(cfg.dtype)
    )


def fake_frontend_embeds(
    cfg: ArchConfig, batch: int, seed: int = 0
) -> jnp.ndarray:
    """Deterministic stand-in embeddings (unit RMS like real encoders)."""
    rng = np.random.default_rng(seed)
    spec = frontend_spec(cfg, batch)
    x = rng.normal(0.0, 1.0, size=spec.shape).astype(np.float32)
    return jnp.asarray(x, dtype=spec.dtype)


def mrope_positions(
    batch: int,
    text_len: int,
    image_grid: Optional[Tuple[int, int]] = None,
    temporal_offset: int = 0,
) -> jnp.ndarray:
    """Qwen2-VL M-RoPE position ids, shape (3, B, S).

    Vision patches get (t=const, h=row, w=col); text tokens get equal
    (t, h, w) components continuing after the visual block — the layout
    of arXiv:2409.12191 §2.1.
    """
    parts = []
    if image_grid is not None:
        gh, gw = image_grid
        t = jnp.zeros((gh * gw,), jnp.int32) + temporal_offset
        h = jnp.repeat(jnp.arange(gh, dtype=jnp.int32), gw)
        w = jnp.tile(jnp.arange(gw, dtype=jnp.int32), gh)
        parts.append(jnp.stack([t, h, w]))
        start = temporal_offset + max(gh, gw)
    else:
        start = temporal_offset
    text = jnp.arange(start, start + text_len, dtype=jnp.int32)
    parts.append(jnp.broadcast_to(text, (3, text_len)))
    pos = jnp.concatenate(parts, axis=1)  # (3, S)
    return jnp.broadcast_to(pos[:, None, :], (3, batch, pos.shape[1]))
