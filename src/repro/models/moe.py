"""Mixture-of-Experts: top-k router + two dispatch implementations.

* ``impl="dense"`` — every expert runs on every token, outputs combined
  by gate weights. Exact (no token dropping), FLOP-inflated by E/k; used
  by the reduced smoke configs where E <= 4.

* ``impl="dropping"`` — GShard/Switch-style capacity-bounded dispatch,
  built with sort + scatter instead of the (tokens, E, C) one-hot einsum
  (which is memory-infeasible at qwen3's 128 experts). Tokens above an
  expert's capacity are dropped (their residual passes through — standard
  behaviour). The (E, C, d) dispatch buffer carries a sharding constraint
  so experts split over the 'model' mesh axis (expert parallelism) and
  XLA materializes the dispatch as the all-to-all the roofline pass then
  measures.

Router aux loss follows Switch Transformer: E * sum_e f_e * p_e, where
f_e is the fraction of tokens whose top-1 choice is e and p_e the mean
router probability of e.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MoEConfig
from repro.models import layers


def init_moe(key, cfg: ArchConfig, dtype=jnp.float32) -> Dict:
    m = cfg.moe
    d = cfg.d_model
    k_router, k_experts = jax.random.split(key)
    ks = jax.random.split(k_experts, 3)
    return {
        "router": layers._dense_init(k_router, (d, m.num_experts), dtype),
        # experts stacked on a leading E axis -> shardable over 'model'
        "w_gate": layers._dense_init(ks[0], (m.num_experts, d, m.d_ff), dtype),
        "w_up": layers._dense_init(ks[1], (m.num_experts, d, m.d_ff), dtype),
        "w_down": layers._dense_init(ks[2], (m.num_experts, m.d_ff, d), dtype),
    }


def _router(params, m: MoEConfig, x2d: jnp.ndarray):
    """x2d (T, d) -> (gates (T, k), idx (T, k), aux_loss)."""
    logits = x2d.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    gates, idx = jax.lax.top_k(probs, m.experts_per_token)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    # Switch-style load balance loss
    e = m.num_experts
    top1 = idx[:, 0]
    f = jnp.mean(jax.nn.one_hot(top1, e, dtype=jnp.float32), axis=0)
    p = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(f * p)
    return gates, idx, aux


def _expert_ffn(params, h: jnp.ndarray, kind: str) -> jnp.ndarray:
    """h (E, C, d) through per-expert gated MLPs -> (E, C, d)."""
    gate = jnp.einsum("ecd,edf->ecf", h, params["w_gate"])
    up = jnp.einsum("ecd,edf->ecf", h, params["w_up"])
    act = jax.nn.silu(gate) if kind == "swiglu" else jax.nn.gelu(gate, approximate=True)
    return jnp.einsum("ecf,efd->ecd", act * up, params["w_down"])


def moe_forward(
    params: Dict,
    cfg: ArchConfig,
    x: jnp.ndarray,  # (B, S, d)
    shard=None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y (B,S,d), aux_loss scalar). ``shard`` is the launcher's
    with_sharding_constraint hook — the dispatch buffer MUST be pinned to
    the batch sharding or GSPMD replicates it across the data axis
    (measured: +21 GiB/layer/device on mixtral train_4k)."""
    if shard is None:
        shard = lambda t, name: t
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    x2d = x.reshape(t, d)
    gates, idx, aux = _router(params, m, x2d)

    if m.impl == "dense":
        # (E, T, d) all-experts compute, exact combine
        h = jnp.einsum("td,edf->etf", x2d, params["w_gate"])
        up = jnp.einsum("td,edf->etf", x2d, params["w_up"])
        act = jax.nn.silu(h) if cfg.mlp == "swiglu" else jax.nn.gelu(h, approximate=True)
        y_all = jnp.einsum("etf,efd->etd", act * up, params["w_down"])  # (E,T,d)
        combine = jnp.zeros((t, m.num_experts), jnp.float32)
        combine = combine.at[
            jnp.arange(t)[:, None], idx
        ].add(gates)
        y = jnp.einsum("te,etd->td", combine.astype(x.dtype), y_all)
        return y.reshape(b, s, d), aux

    # ---- dropping / expert-parallel dispatch (batch-local) ----
    # §Perf iteration 2: the original implementation flattened (B, S) and
    # sorted GLOBALLY, which forced cross-data-shard sort/scatter
    # collectives (402 s of collective time per qwen3 train step). This
    # version keeps the batch dim leading and vmaps the sort/scatter per
    # row: with batch sharded over (pod, data), every dispatch index is
    # local to its shard; the only inter-shard traffic left is the
    # expert-output combine, which is O(B*S*d) instead of O(E*C*d*k).
    # Capacity is enforced per row (standard per-shard capacity
    # semantics; the smoke tests verify equality with `dense` whenever
    # the capacity factor is ample).
    k = m.experts_per_token
    e = m.num_experts
    sk = s * k
    capacity = max(1, int(-(-sk * m.capacity_factor // e)))  # ceil, static

    idx_rows = idx.reshape(b, sk)
    gate_rows = gates.reshape(b, sk)

    def dispatch_row(x_row, eid, gate):
        # x_row (S, d); eid/gate (S*k,)
        order = jnp.argsort(eid, stable=True)
        e_sorted = eid[order]
        tok_sorted = order // k
        gate_sorted = gate[order]
        counts = jnp.bincount(e_sorted, length=e)
        starts = jnp.concatenate(
            [jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]]
        )
        pos = jnp.arange(sk) - starts[e_sorted]
        keep = pos < capacity
        safe_pos = jnp.where(keep, pos, 0)
        rows = x_row[tok_sorted] * keep[:, None].astype(x_row.dtype)
        buf = jnp.zeros((e, capacity, d), x_row.dtype)
        buf = buf.at[e_sorted, safe_pos].add(rows)
        return buf, (e_sorted, safe_pos, keep, tok_sorted, gate_sorted)

    buf, meta = jax.vmap(dispatch_row)(x, idx_rows, gate_rows)  # (B,E,C,d)
    buf = shard(buf, "moe_buf")

    mesh = getattr(shard, "mesh", None)
    model_size = 1
    if mesh is not None:
        model_size = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)

    if mesh is not None and model_size > 1 and e % model_size == 0:
        # §Perf iteration 2b: expert-parallel compute + combine under
        # shard_map. Without it, the combine gather from the E-sharded
        # y_buf makes GSPMD all-gather the full (E, C, d) buffer per row
        # (~385 GB/step on qwen3 train_4k). Inside shard_map each model
        # shard processes ONLY its local experts and scatter-adds their
        # token outputs; the combine becomes a psum of (B, S, d).
        y = _expert_combine_shardmap(params, cfg, mesh, buf, meta, s, d, capacity)
        return shard(y, "activation"), aux

    gate_w = jnp.einsum("becd,edf->becf", buf, params["w_gate"])
    up = jnp.einsum("becd,edf->becf", buf, params["w_up"])
    act = (
        jax.nn.silu(gate_w)
        if cfg.mlp == "swiglu"
        else jax.nn.gelu(gate_w, approximate=True)
    )
    y_buf = jnp.einsum("becf,efd->becd", act * up, params["w_down"])
    y_buf = shard(y_buf, "moe_buf")

    def combine_row(y_b, meta_row):
        e_sorted, safe_pos, keep, tok_sorted, gate_sorted = meta_row
        rows = y_b[e_sorted, safe_pos] * (
            gate_sorted * keep.astype(jnp.float32)
        ).astype(y_b.dtype)[:, None]
        return jnp.zeros((s, d), y_b.dtype).at[tok_sorted].add(rows)

    y = jax.vmap(combine_row)(y_buf, meta)  # (B, S, d)
    return shard(y, "activation"), aux


def _expert_combine_shardmap(params, cfg, mesh, buf, meta, s, d, capacity):
    """Expert FFN + combine with experts sharded over 'model'.

    buf  (B, E, C, d) — batch over (pod, data), E over model.
    meta — per-row dispatch indices (replicated over model).
    Returns y (B, S, d) batch-sharded, replicated over model.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    baxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    e = cfg.moe.num_experts
    model_size = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    e_local = e // model_size
    kind = cfg.mlp

    def body(w_gate, w_up, w_down, buf_l, e_sorted, safe_pos, keep, tok_sorted, gate_sorted):
        # w_* (E_local, ...); buf_l (B_l, E_local, C, d); meta (B_l, S*k)
        shard_idx = jax.lax.axis_index("model")
        gate_w = jnp.einsum("becd,edf->becf", buf_l, w_gate)
        up = jnp.einsum("becd,edf->becf", buf_l, w_up)
        act = (
            jax.nn.silu(gate_w) if kind == "swiglu"
            else jax.nn.gelu(gate_w, approximate=True)
        )
        y_buf = jnp.einsum("becf,efd->becd", act * up, w_down)  # (B_l,E_l,C,d)

        def combine_row(y_b, es, sp, kp, tok, gw):
            local_e = es - shard_idx * e_local
            mine = (local_e >= 0) & (local_e < e_local) & kp
            le = jnp.clip(local_e, 0, e_local - 1)
            rows = y_b[le, sp] * (
                gw * mine.astype(jnp.float32)
            ).astype(y_b.dtype)[:, None]
            return jnp.zeros((s, d), y_b.dtype).at[tok].add(rows)

        y_part = jax.vmap(combine_row)(
            y_buf, e_sorted, safe_pos, keep, tok_sorted, gate_sorted
        )
        return jax.lax.psum(y_part, "model")

    e_sorted, safe_pos, keep, tok_sorted, gate_sorted = meta
    return shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P("model", None, None),  # w_gate
            P("model", None, None),  # w_up
            P("model", None, None),  # w_down
            P(baxes, "model", None, None),  # buf
            P(baxes, None),  # e_sorted
            P(baxes, None),  # safe_pos
            P(baxes, None),  # keep
            P(baxes, None),  # tok_sorted
            P(baxes, None),  # gate_sorted
        ),
        out_specs=P(baxes, None, None),
        check_rep=False,
    )(
        params["w_gate"], params["w_up"], params["w_down"], buf,
        e_sorted, safe_pos, keep, tok_sorted, gate_sorted,
    )
