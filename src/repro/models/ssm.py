"""Mamba2 / SSD (state-space duality) blocks [arXiv:2405.21060].

Training/prefill uses the chunked SSD algorithm (Listing 1 of the Mamba2
paper): within-chunk quadratic attention-like term + inter-chunk
recurrence on the (heads, head_dim, d_state) state, all in lax.scan /
einsum form so it shards cleanly.

Decode keeps the constant-size recurrent state:
    h <- h * exp(dt * A) + dt * (B outer x);   y = C . h + D * x
which is the property DESIGN.md highlights: the inter-step payload the
paper worries about (Fig. 3) is O(1) for SSMs.

Sharding note: the reference implementation packs [z, x, B, C, dt] into
one in_proj; we keep SEPARATE projections so the d_inner-sized tensors
(z, x) can shard over the 'model' axis Megatron-style while the small
B/C/dt projections stay replicated — a packed layout would put shard
boundaries mid-slice and force all-gathers every layer. The depthwise
conv is likewise split into an x-conv (sharded channels) and a bc-conv
(replicated); depthwise convs are per-channel independent, so the split
is mathematically identical to the packed original.
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, SSMConfig
from repro.models import layers


class SSMState(NamedTuple):
    conv_x: jnp.ndarray  # (B, d_conv - 1, d_inner)
    conv_bc: jnp.ndarray  # (B, d_conv - 1, 2 * G * N)
    ssd: jnp.ndarray  # (B, H, P, N) recurrent state (f32)


def _dims(cfg: ArchConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    bc_ch = 2 * s.n_groups * s.d_state
    return s, d_inner, n_heads, bc_ch


def init_ssm_block(key, cfg: ArchConfig, dtype=jnp.float32) -> Dict:
    s, d_inner, n_heads, bc_ch = _dims(cfg)
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    return {
        "w_z": layers._dense_init(ks[0], (d, d_inner), dtype),
        "w_x": layers._dense_init(ks[1], (d, d_inner), dtype),
        "w_bc": layers._dense_init(ks[2], (d, bc_ch), dtype),
        "w_dt": layers._dense_init(ks[3], (d, n_heads), dtype),
        "conv_x_w": layers._dense_init(ks[4], (s.d_conv, d_inner), dtype, scale=0.5),
        "conv_x_b": jnp.zeros((d_inner,), dtype),
        "conv_bc_w": layers._dense_init(ks[5], (s.d_conv, bc_ch), dtype, scale=0.5),
        "conv_bc_b": jnp.zeros((bc_ch,), dtype),
        # A in (-exp) parameterization: A = -exp(a_log), init near -1.
        "a_log": jnp.zeros((n_heads,), jnp.float32),
        "dt_bias": jnp.full((n_heads,), -2.0, jnp.float32),  # softplus ~ 0.12
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "norm": layers.init_rmsnorm(d_inner, dtype),
        "w_out": layers._dense_init(ks[0], (d_inner, d), dtype),
    }


def _causal_conv(w, bias, x: jnp.ndarray, d_conv: int) -> jnp.ndarray:
    """Depthwise causal conv over (B, S, C) + SiLU."""
    pad = jnp.pad(x, ((0, 0), (d_conv - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + x.shape[1]] * w[i][None, None] for i in range(d_conv)
    )
    return jax.nn.silu(out + bias[None, None])


def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """Stable segment-sum: out[..., i, j] = sum_{k=j+1..i} x[..., k],
    -inf above the diagonal (Mamba2 reference helper)."""
    t = x.shape[-1]
    x_cum = jnp.cumsum(x, axis=-1)
    diff = x_cum[..., :, None] - x_cum[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jnp.ndarray,  # (B, S, H, P)
    dt: jnp.ndarray,  # (B, S, H) — already softplus'd
    a: jnp.ndarray,  # (H,) negative decay rates
    b: jnp.ndarray,  # (B, S, G, N)
    c: jnp.ndarray,  # (B, S, G, N)
    chunk: int,
    h0: jnp.ndarray = None,  # (B, H, P, N) initial state
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan. Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    bs, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    rep = h // g

    x_f = x.astype(jnp.float32)
    dt_f = dt.astype(jnp.float32)
    da = dt_f * a[None, None, :]  # (B, S, H) log-decay per step
    xb = x_f * dt_f[..., None]  # fold dt into the input

    xc = xb.reshape(bs, nc, chunk, h, p)
    dac = da.reshape(bs, nc, chunk, h)
    bc = jnp.repeat(b, rep, axis=2).reshape(bs, nc, chunk, h, n).astype(jnp.float32)
    cc = jnp.repeat(c, rep, axis=2).reshape(bs, nc, chunk, h, n).astype(jnp.float32)

    # ---- intra-chunk (quadratic within chunk) ----
    l_mat = jnp.exp(_segsum(dac.transpose(0, 1, 3, 2)))  # (B, nc, H, T, T)
    scores = jnp.einsum("bzihn,bzjhn->bzhij", cc, bc)  # (B, nc, H, T, T)
    y_diag = jnp.einsum("bzhij,bzjhp->bzihp", scores * l_mat, xc)

    # ---- chunk states: decay-to-end weighted sum of inputs ----
    dac_cum = jnp.cumsum(dac, axis=2)
    decay_to_end = jnp.exp(dac_cum[:, :, -1:, :] - dac_cum)  # (B,nc,T,H)
    states = jnp.einsum(
        "bzthn,bzth,bzthp->bzhpn", bc, decay_to_end, xc
    )  # (B, nc, H, P, N)

    # ---- inter-chunk recurrence over chunk boundary states ----
    chunk_decay = jnp.exp(jnp.sum(dac, axis=2))  # (B, nc, H)

    def scan_fn(h_prev, inp):
        st, dec = inp  # (B,H,P,N), (B,H)
        h_new = h_prev * dec[..., None, None] + st
        return h_new, h_prev  # emit state *entering* the chunk

    init = (
        jnp.zeros((bs, h, p, n), jnp.float32)
        if h0 is None
        else h0.astype(jnp.float32)
    )
    final, h_in = jax.lax.scan(
        scan_fn,
        init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    h_in = h_in.transpose(1, 0, 2, 3, 4)  # (B, nc, H, P, N)

    # ---- contribution of the carried state to each position ----
    decay_from_start = jnp.exp(dac_cum)  # (B, nc, T, H)
    y_off = jnp.einsum("bzthn,bzhpn,bzth->bzthp", cc, h_in, decay_from_start)
    y = (y_diag + y_off).reshape(bs, s, h, p)
    return y.astype(x.dtype), final


def init_state(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> SSMState:
    s, d_inner, n_heads, bc_ch = _dims(cfg)
    return SSMState(
        conv_x=jnp.zeros((batch, s.d_conv - 1, d_inner), dtype),
        conv_bc=jnp.zeros((batch, s.d_conv - 1, bc_ch), dtype),
        ssd=jnp.zeros((batch, n_heads, s.head_dim, s.d_state), jnp.float32),
    )


def ssm_forward(
    params: Dict,
    cfg: ArchConfig,
    u: jnp.ndarray,  # (B, S, d_model)
    h0: jnp.ndarray = None,
) -> Tuple[jnp.ndarray, SSMState]:
    """Train/prefill pass. Returns (y (B,S,d_model), final SSMState) —
    the state hands off to ``ssm_decode`` for serving."""
    s, d_inner, n_heads, bc_ch = _dims(cfg)
    bsz, seq, _ = u.shape
    z = u @ params["w_z"]
    x_raw = u @ params["w_x"]
    bc_raw = u @ params["w_bc"]
    dt = u @ params["w_dt"]

    # conv windows for decode handoff: last (d_conv - 1) raw inputs
    def tail(arr, ch):
        pad_front = jnp.zeros((bsz, max(s.d_conv - 1 - seq, 0), ch), u.dtype)
        return jnp.concatenate([pad_front, arr], axis=1)[:, -(s.d_conv - 1):]

    conv_x_tail = tail(x_raw, d_inner)
    conv_bc_tail = tail(bc_raw, bc_ch)

    x = _causal_conv(params["conv_x_w"], params["conv_x_b"], x_raw, s.d_conv)
    bc = _causal_conv(params["conv_bc_w"], params["conv_bc_b"], bc_raw, s.d_conv)

    gn = s.n_groups * s.d_state
    x = x.reshape(bsz, seq, n_heads, s.head_dim)
    b = bc[..., :gn].reshape(bsz, seq, s.n_groups, s.d_state)
    c = bc[..., gn:].reshape(bsz, seq, s.n_groups, s.d_state)
    dt_act = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["a_log"])

    chunk = s.chunk_size
    pad = (-seq) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt_act = jnp.pad(dt_act, ((0, 0), (0, pad), (0, 0)))
    y, final = ssd_chunked(x, dt_act, a, b, c, chunk, h0)
    y = y[:, :seq]
    y = y + x[:, :seq] * params["d_skip"][None, None, :, None]
    y = y.reshape(bsz, seq, d_inner)
    y = layers.rmsnorm(params["norm"], y * jax.nn.silu(z)).astype(u.dtype)
    return y @ params["w_out"], SSMState(
        conv_x=conv_x_tail, conv_bc=conv_bc_tail, ssd=final
    )


def ssm_decode(
    params: Dict,
    cfg: ArchConfig,
    u: jnp.ndarray,  # (B, 1, d_model)
    state: SSMState,
) -> Tuple[jnp.ndarray, SSMState]:
    """One recurrent decode step with conv+SSD state update."""
    s, d_inner, n_heads, bc_ch = _dims(cfg)
    bsz = u.shape[0]
    z = u @ params["w_z"]
    x_new = (u @ params["w_x"])[:, 0]  # (B, d_inner)
    bc_new = (u @ params["w_bc"])[:, 0]
    dt = (u @ params["w_dt"])[:, 0]

    def conv_step(win_state, new, w, bias):
        window = jnp.concatenate([win_state, new[:, None]], axis=1)
        out = jnp.einsum("btc,tc->bc", window, w) + bias
        return jax.nn.silu(out), window[:, 1:]

    x1, new_conv_x = conv_step(
        state.conv_x, x_new, params["conv_x_w"], params["conv_x_b"]
    )
    bc1, new_conv_bc = conv_step(
        state.conv_bc, bc_new, params["conv_bc_w"], params["conv_bc_b"]
    )

    gn = s.n_groups * s.d_state
    x1 = x1.reshape(bsz, n_heads, s.head_dim)
    b1 = bc1[..., :gn].reshape(bsz, s.n_groups, s.d_state)
    c1 = bc1[..., gn:].reshape(bsz, s.n_groups, s.d_state)
    rep = n_heads // s.n_groups
    b1 = jnp.repeat(b1, rep, axis=1)  # (B, H, N)
    c1 = jnp.repeat(c1, rep, axis=1)

    dt_act = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["a_log"])
    decay = jnp.exp(dt_act * a[None])  # (B, H)

    x_in = x1.astype(jnp.float32) * dt_act[..., None]
    new_ssd = state.ssd * decay[..., None, None] + jnp.einsum(
        "bhp,bhn->bhpn", x_in, b1.astype(jnp.float32)
    )
    y = jnp.einsum("bhpn,bhn->bhp", new_ssd, c1.astype(jnp.float32))
    y = y + x1.astype(jnp.float32) * params["d_skip"][None, :, None]
    y = y.reshape(bsz, 1, d_inner).astype(u.dtype)
    y = layers.rmsnorm(params["norm"], y * jax.nn.silu(z)).astype(u.dtype)
    return y @ params["w_out"], SSMState(new_conv_x, new_conv_bc, new_ssd)
