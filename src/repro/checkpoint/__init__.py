"""Sharding-aware npz checkpointing."""

from repro.checkpoint import io  # noqa: F401
