"""Sharding-aware checkpointing (npz-based, no external deps).

Parameters/optimizer pytrees are flattened to ``path/to/leaf`` keys and
stored in a single compressed npz per step, plus a small JSON manifest
(step, tree structure, dtypes). On restore the arrays are device_put with
the caller's shardings — on the multi-host production mesh each host
would restore its shard slice; on this single-host container the put is
whole-array (the API shape is what matters for the dry-run).
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(e, "key", getattr(e, "idx", getattr(e, "name", e))))
            for e in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def save(directory: str, step: int, trees: Dict[str, Any]) -> str:
    """trees: e.g. {"params": ..., "opt_state": ...}. Returns the path."""
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"ckpt_{step:08d}")
    arrays: Dict[str, np.ndarray] = {}
    manifest = {"step": step, "trees": {}}
    for name, tree in trees.items():
        flat = _flatten(tree)
        manifest["trees"][name] = sorted(flat)
        for k, v in flat.items():
            arrays[f"{name}::{k}"] = v
    np.savez_compressed(path + ".npz", **arrays)
    with open(path + ".json", "w") as f:
        json.dump(manifest, f)
    return path + ".npz"


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(f[len("ckpt_") : -len(".json")])
        for f in os.listdir(directory)
        if f.startswith("ckpt_") and f.endswith(".json")
    ]
    return max(steps) if steps else None


def restore(
    directory: str,
    step: int,
    templates: Dict[str, Any],
    shardings: Optional[Dict[str, Any]] = None,
) -> Dict[str, Any]:
    """Restore trees matching ``templates`` structure. ``shardings``, when
    given, maps tree name -> sharding pytree for device placement."""
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    data = np.load(path)
    out = {}
    for name, template in templates.items():
        paths_and_leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        shard_tree = shardings.get(name) if shardings else None
        shard_leaves = (
            jax.tree_util.tree_leaves(
                shard_tree,
                is_leaf=lambda s: isinstance(s, jax.sharding.Sharding),
            )
            if shard_tree is not None
            else [None] * len(paths_and_leaves)
        )
        for (path_e, leaf), shard in zip(paths_and_leaves, shard_leaves):
            key = "/".join(
                str(getattr(e, "key", getattr(e, "idx", getattr(e, "name", e))))
                for e in path_e
            )
            arr = data[f"{name}::{key}"].astype(leaf.dtype)
            if shard is not None:
                arr = jax.device_put(arr, shard)
            leaves.append(arr)
        out[name] = jax.tree_util.tree_unflatten(treedef, leaves)
    return out
