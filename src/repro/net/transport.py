"""Simulated transport of pytrees across a link, with byte accounting.

``Transport`` moves real JAX pytrees between two logical endpoints while
charging simulated wall-clock time to a ``sim.clock.SimClock``. The data
actually moves (it is the same host), so executed simulations produce
*bit-exact tracker output* while the clock reflects the modeled network —
this is how sim/runtime.py runs the paper's experiments faithfully on one
machine.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import numpy as np

from repro.core.offload import Link, WrapperModel
from repro.core.stages import pytree_nbytes


@dataclasses.dataclass
class TransferRecord:
    nbytes: int
    seconds: float
    direction: str  # "up" | "down"


class Transport:
    """A link between client and server endpoints with an RNG for jitter."""

    def __init__(
        self,
        link: Link,
        wrapper: Optional[WrapperModel] = None,
        seed: int = 0,
    ):
        self.link = link
        self.wrapper = wrapper
        self.rng = np.random.default_rng(seed)
        self.log: list[TransferRecord] = []

    def rpc_envelope_time(self) -> float:
        """Request + response wire latency for one remote invocation."""
        t = 0.0
        for _ in range(2):
            t += max(
                0.0,
                float(self.rng.normal(self.link.latency, self.link.jitter))
                if self.link.jitter > 0
                else self.link.latency,
            )
        if self.wrapper is not None:
            t += 2 * self.wrapper.call_overhead
        return t

    def payload_time(self, tree: Any, direction: str = "up") -> float:
        """Time to ship a pytree payload (serialization + wire)."""
        nbytes = pytree_nbytes(tree)
        t = nbytes / self.link.bandwidth
        if self.wrapper is not None:
            t += 2 * nbytes / self.wrapper.serialization_bandwidth
        self.log.append(TransferRecord(nbytes, t, direction))
        return t

    @property
    def total_bytes(self) -> int:
        return sum(r.nbytes for r in self.log)

    @property
    def total_seconds(self) -> float:
        return sum(r.seconds for r in self.log)
