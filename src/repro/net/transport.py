"""Simulated transport of pytrees across a link, with byte accounting.

``Transport`` moves real JAX pytrees between two logical endpoints while
charging simulated wall-clock time to a ``sim.clock.SimClock``. The data
actually moves (it is the same host), so executed simulations produce
*bit-exact tracker output* while the clock reflects the modeled network —
this is how sim/runtime.py runs the paper's experiments faithfully on one
machine.

All arithmetic delegates to the leg-level primitives of
``core.costengine`` (the unified cost engine), so the executed path
charges exactly the formulas the analytic planner prices; the link's
jitter is drawn through ``Link.transfer_time(nbytes, rng)``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import numpy as np

from repro.core.costengine import envelope_time, serialization_time, wire_time
from repro.core.topology import Link, WrapperModel
from repro.core.stages import pytree_nbytes


@dataclasses.dataclass
class TransferRecord:
    nbytes: int
    seconds: float
    direction: str  # "up" | "down"


class Transport:
    """A link between client and server endpoints with an RNG for jitter."""

    def __init__(
        self,
        link: Link,
        wrapper: Optional[WrapperModel] = None,
        seed: int = 0,
    ):
        self.link = link
        self.wrapper = wrapper
        self.rng = np.random.default_rng(seed)
        self.log: list[TransferRecord] = []

    def rpc_envelope_time(self) -> float:
        """Request + response wire latency for one remote invocation."""
        return envelope_time((self.link,), self.wrapper, self.rng)

    def payload_time(self, tree: Any, direction: str = "up") -> float:
        """Time to ship a pytree payload (serialization + wire)."""
        nbytes = pytree_nbytes(tree)
        t = wire_time(nbytes, (self.link,))
        if self.wrapper is not None:
            t += serialization_time(nbytes, self.wrapper)
        self.log.append(TransferRecord(nbytes, t, direction))
        return t

    @property
    def total_bytes(self) -> int:
        return sum(r.nbytes for r in self.log)

    @property
    def total_seconds(self) -> float:
        return sum(r.seconds for r in self.log)
