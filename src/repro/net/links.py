"""Calibrated link models (paper §4.1 + TPU interconnect tiers).

The paper connects its two machines with (i) Gigabit Ethernet and (ii)
802.11 Wi-Fi, noting Wi-Fi "typically introduce[s] latency ranging from
10-60 ms" and substantially lower bandwidth. The TPU entries let the same
offload engine reason about intra-pod ICI and cross-pod DCN placement
(serving/edge.py) — that is the production analogue of laptop<->server —
and the 5G/DCN pair forms the legs of the device->edge->cloud chain
topology (sim.hardware.three_tier_environment).
"""

from __future__ import annotations

from repro.core.topology import Link

# Effective application-level throughput of GbE is ~117 MB/s (TCP).
GIGABIT_ETHERNET = Link(
    name="gigabit_ethernet", bandwidth=117e6, latency=0.3e-3, jitter=0.05e-3
)

# 802.11n in an interference-prone office: ~6 MB/s effective, 10-60 ms
# latency. We model latency 20 ms +/- 12 ms — the paper's stated range.
WIFI = Link(name="wifi_802.11", bandwidth=6e6, latency=20e-3, jitter=12e-3)

# TPU v5e inter-chip interconnect: ~50 GB/s per link, sub-microsecond.
ICI = Link(name="tpu_ici", bandwidth=50e9, latency=1e-6, jitter=0.0)

# Cross-pod data-center network: ~25 GB/s effective, ~10 us.
DCN = Link(name="dcn", bandwidth=25e9, latency=10e-6, jitter=2e-6)

# 5G edge (the paper's motivating future deployment): ~60 MB/s, 8 ms.
FIVE_G_EDGE = Link(name="5g_edge", bandwidth=60e6, latency=8e-3, jitter=3e-3)

ALL_LINKS = {
    link.name: link
    for link in (GIGABIT_ETHERNET, WIFI, ICI, DCN, FIVE_G_EDGE)
}
