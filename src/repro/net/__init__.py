"""Network substrate: calibrated link models + simulated transport."""

from repro.net import links, transport  # noqa: F401
