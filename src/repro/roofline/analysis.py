"""Roofline analysis from compiled dry-run artifacts (TPU v5e targets).

Three terms per (arch, shape, mesh), all in seconds. The compiled SPMD
module is a per-device program, so all byte/FLOP figures are PER DEVICE
and the terms divide by per-chip peaks only:

  compute    = dev_FLOPs  / PEAK_FLOPS
  memory     = dev_bytes  / HBM_BW
  collective = dev_coll_bytes / ICI_BW

Caveat discovered during bring-up (see EXPERIMENTS.md §Dry-run):
``compiled.cost_analysis()`` on XLA:CPU counts every while-loop body ONCE
— a 54-layer scan contributes a single layer — and is therefore useless
for scanned models. The numbers here come from ``hlo_cost.analyze_hlo``,
which walks the optimized HLO call graph and scales loop bodies by their
``known_trip_count``. FLOPs count dot ops exactly; memory bytes are an
HBM-traffic estimate (operands + outputs of materialized ops — an upper
bound that double-counts values consumed by several ops); collective
bytes sum per-device output shapes of the five collective op kinds. The
raw cost_analysis() dict is preserved in each dry-run JSON for reference.

Also reported: MODEL_FLOPS = 6·N·D (train) or 2·N·D (inference) with
N = active params, and the usefulness ratio MODEL_FLOPS / HLO_FLOPs.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

import numpy as np

# --- TPU v5e hardware constants (per chip) ---
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVE_KINDS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """'f32[128,256]' -> bytes. Tuples handled by caller via findall."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def _iter_computations(hlo: str):
    """Yield (computation_name, body_lines)."""
    cur_name, cur_lines = None, []
    for line in hlo.splitlines():
        stripped = line.strip()
        m = re.match(r"^(%?[\w\.\-]+)\s*(\([^)]*\))?\s*->.*{$", stripped)
        if stripped.endswith("{") and ("(" in stripped):
            if cur_name is not None:
                yield cur_name, cur_lines
            cur_name = stripped.split()[0].lstrip("%")
            cur_lines = []
        elif stripped == "}" or stripped.startswith("} "):
            if cur_name is not None:
                yield cur_name, cur_lines
                cur_name, cur_lines = None, []
        elif cur_name is not None:
            cur_lines.append(stripped)
    if cur_name is not None:
        yield cur_name, cur_lines


def _while_trip_counts(hlo: str) -> Dict[str, int]:
    """Map while-body computation name -> trip count.

    XLA annotates optimized while loops with
    ``backend_config={"known_trip_count":{"n":"54"}}`` (or exposes an
    induction-variable bound); fall back to 1 when unknown."""
    trips: Dict[str, int] = {}
    for m in re.finditer(
        r"while\([^)]*\).*?body=%?([\w\.\-]+).*?known_trip_count[^\d]*(\d+)",
        hlo,
    ):
        trips[m.group(1)] = int(m.group(2))
    # also catch trip_count in comments: while(...) /*trip_count=54*/
    for m in re.finditer(
        r"body=%?([\w\.\-]+)[^\n]*?trip_count[=\"':\s]+(\d+)", hlo
    ):
        trips.setdefault(m.group(1), int(m.group(2)))
    return trips


def collective_bytes(hlo: str) -> CollectiveStats:
    """Sum operand bytes of every collective op, scaling by loop trips."""
    trips = _while_trip_counts(hlo)
    by_kind: Dict[str, int] = {k: 0 for k in _COLLECTIVE_KINDS}
    for comp_name, lines in _iter_computations(hlo):
        scale = trips.get(comp_name, 1)
        for line in lines:
            for kind in _COLLECTIVE_KINDS:
                # match '= TYPE kind(' and fused variants 'kind-start('
                if re.search(rf"=\s*[^=]*\b{kind}(-start)?\(", line):
                    # operand shapes: the output shape annotation right
                    # after '=' covers bytes moved (per-device output)
                    m = re.match(r"^\S+\s*=\s*(\([^)]*\)|\S+)\s", line)
                    if m:
                        by_kind[kind] += _shape_bytes(m.group(1)) * scale
                    break
    return CollectiveStats(by_kind)


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_by_kind: Dict[str, int]
    model_flops: float
    bytes_per_chip: Optional[float] = None

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / PEAK_FLOPS  # per-device flops

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / HBM_BW  # per-device HBM traffic

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / ICI_BW  # per-device link traffic

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        total = self.hlo_flops * self.chips  # global compiled flops
        return self.model_flops / total if total else 0.0

    @property
    def step_time_s(self) -> float:
        """Roofline-model step latency: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def row(self) -> Dict:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "hlo_flops": self.hlo_flops,
            "hlo_bytes": self.hlo_bytes,
            "coll_bytes": self.coll_bytes,
            "coll_by_kind": self.coll_by_kind,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
            "bytes_per_chip": self.bytes_per_chip,
        }


def model_flops_for(cfg, shape) -> float:
    """6·N_active·D for training, 2·N_active·D for inference steps."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def analyze(
    cfg,
    shape,
    mesh_name: str,
    chips: int,
    cost: Dict[str, float],
    hlo_text: str,
    memory_stats: Optional[Dict] = None,
) -> RooflineReport:
    from repro.roofline import hlo_cost

    walked = hlo_cost.analyze_hlo(hlo_text)
    return RooflineReport(
        arch=cfg.name,
        shape=shape.name,
        mesh=mesh_name,
        chips=chips,
        hlo_flops=walked.flops,
        hlo_bytes=walked.mem_bytes,
        coll_bytes=walked.coll_bytes,
        coll_by_kind={k: int(v) for k, v in walked.coll_by_kind.items()},
        model_flops=model_flops_for(cfg, shape),
        bytes_per_chip=(memory_stats or {}).get("bytes_per_chip"),
    )
