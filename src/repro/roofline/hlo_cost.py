"""HLO cost model: FLOPs / memory / collective bytes with loop scaling.

``compiled.cost_analysis()`` on XLA:CPU reports *per-device* numbers and
counts each while-loop body ONCE — a 54-layer scan contributes one layer
of FLOPs. This walker parses the optimized HLO text, builds the call
graph (while bodies, fusions, calls, conditionals), scales every
computation by its loop trip count (``backend_config known_trip_count``),
and accumulates:

* flops            — 2 * prod(dot output dims) * contracted size, for
                     every dot; transcendental/elementwise ops are not
                     counted (they are not MXU work).
* collective bytes — output-shape bytes of all-gather / all-reduce /
                     reduce-scatter / all-to-all / collective-permute
                     (per-device bytes crossing links).
* memory bytes     — sum over materialized ops of (operand + output)
                     buffer bytes: an HBM-traffic estimate that treats
                     every fusion as one read of its inputs and one write
                     of its output (the roofline-relevant behaviour).

All numbers are PER DEVICE, matching the SPMD module the text describes.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "tuple": 0,
}

_COLLECTIVE_KINDS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# one array shape like f32[16,512,128]{2,1,0}
_ONE_SHAPE = re.compile(r"(\w+)\[([\d,]*)\](?:\{[\d,]*\})?")
# a computation definition header: %name (args) -> ret {
_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s+->\s+.*\{\s*$")
# an instruction: %name = <type> opcode(...)
_INSTR = re.compile(r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")


def _shape_dims_bytes(shape_str: str) -> Tuple[List[List[int]], int]:
    """All array shapes in a (possibly tuple) type. Returns (dims, bytes)."""
    dims_list = []
    total = 0
    for dtype, dims in _ONE_SHAPE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        dims_i = [int(d) for d in dims.split(",") if d] if dims else []
        n = 1
        for d in dims_i:
            n *= d
        dims_list.append(dims_i)
        total += n * _DTYPE_BYTES[dtype]
    return dims_list, total


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    mem_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVE_KINDS}
    )
    # (callee_name, multiplier, counts_memory): fusion/reducer bodies are
    # *descriptions* of one fused kernel — their dots count (MXU work) but
    # their internal elementwise ops are register-local, NOT HBM traffic.
    # The fusion call site already accounts one read of inputs + one write
    # of the output. while/call/conditional bodies execute for real and
    # count fully.
    callees: List[Tuple[str, float, bool]] = dataclasses.field(default_factory=list)


def _parse_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    lines: List[str] = []
    for raw in hlo.splitlines():
        line = raw.strip()
        if cur is None:
            m = _COMP_HEADER.match(line)
            if m:
                cur = m.group(1)
                lines = []
        else:
            if line.startswith("}"):
                comps[cur] = lines
                cur = None
            else:
                lines.append(line)
    return comps


def _trip_counts(hlo: str) -> Dict[str, int]:
    trips: Dict[str, int] = {}
    for m in re.finditer(
        r"body=%?([\w\.\-]+)[^\n]*?known_trip_count[^\d]*(\d+)", hlo
    ):
        trips[m.group(1)] = max(trips.get(m.group(1), 1), int(m.group(2)))
    return trips


def _analyze_computation(lines: List[str], trips: Dict[str, int]) -> CompCost:
    cost = CompCost()
    # symbol table: instr name -> output shape string
    symbols: Dict[str, str] = {}
    parsed = []
    for line in lines:
        m = _INSTR.match(line)
        if not m:
            continue
        name, out_type, opcode, rest = m.groups()
        symbols[name] = out_type
        parsed.append((name, out_type, opcode, rest, line))

    for name, out_type, opcode, rest, line in parsed:
        out_dims, out_bytes = _shape_dims_bytes(out_type)

        # --- callees ---
        if opcode == "while":
            body = re.search(r"body=%?([\w\.\-]+)", line)
            if body:
                mult = float(trips.get(body.group(1), 1))
                cost.callees.append((body.group(1), mult, True))
                cond = re.search(r"condition=%?([\w\.\-]+)", line)
                if cond:
                    cost.callees.append((cond.group(1), mult, True))
            continue
        if opcode == "call":
            for cal in re.finditer(r"to_apply=%?([\w\.\-]+)", line):
                cost.callees.append((cal.group(1), 1.0, True))
        elif opcode in ("fusion", "map", "reduce", "reduce-window", "sort",
                        "scatter", "select-and-scatter", "custom-call"):
            for cal in re.finditer(r"(?:calls|to_apply)=%?([\w\.\-]+)", line):
                cost.callees.append((cal.group(1), 1.0, False))
        if opcode == "conditional":
            for cal in re.finditer(r"(?:branch_computations=\{([^}]*)\}|true_computation=%?([\w\.\-]+)|false_computation=%?([\w\.\-]+))", line):
                for g in cal.groups():
                    if g:
                        for nm in re.findall(r"%?([\w\.\-]+)", g):
                            cost.callees.append((nm, 1.0, True))

        # --- collectives ---
        matched_coll = None
        for kind in _COLLECTIVE_KINDS:
            if opcode == kind or opcode == kind + "-start":
                matched_coll = kind
                break
        if matched_coll:
            cost.coll_bytes += out_bytes
            cost.coll_by_kind[matched_coll] += out_bytes
            cost.mem_bytes += 2 * out_bytes
            continue

        # --- dots ---
        if opcode == "dot":
            # operand names
            ops = re.findall(r"%([\w\.\-]+)", rest)
            lhs_shape = symbols.get(ops[0], "") if ops else ""
            lhs_dims_all, _ = _shape_dims_bytes(lhs_shape)
            lhs_dims = lhs_dims_all[0] if lhs_dims_all else []
            cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
            contract = 1
            if cdims and lhs_dims:
                for idx in cdims.group(1).split(","):
                    if idx and int(idx) < len(lhs_dims):
                        contract *= lhs_dims[int(idx)]
            out_elems = 1
            for ds in out_dims:
                for d in ds:
                    out_elems *= d
            cost.flops += 2.0 * out_elems * contract
            # dot reads both operands + writes output
            op_bytes = 0
            for op in ops[:2]:
                _, b = _shape_dims_bytes(symbols.get(op, ""))
                op_bytes += b
            cost.mem_bytes += out_bytes + op_bytes
            continue

        # --- generic memory traffic ---
        if opcode in ("parameter", "constant", "get-tuple-element", "tuple",
                      "bitcast", "after-all", "iota"):
            continue
        # read operands (those with known shapes) + write output
        op_bytes = 0
        for op in re.findall(r"%([\w\.\-]+)", rest)[:4]:
            _, b = _shape_dims_bytes(symbols.get(op, ""))
            op_bytes += b
        cost.mem_bytes += out_bytes + op_bytes

    return cost


@dataclasses.dataclass
class HLOCost:
    flops: float
    mem_bytes: float
    coll_bytes: float
    coll_by_kind: Dict[str, float]


def analyze_hlo(hlo: str, entry: Optional[str] = None) -> HLOCost:
    comps = _parse_computations(hlo)
    trips = _trip_counts(hlo)
    per_comp = {name: _analyze_computation(lines, trips) for name, lines in comps.items()}

    # entry computation: the one defined with ENTRY; find by name in text
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo, re.M)
    entry_name = entry or (m.group(1) if m else None)
    if entry_name is None or entry_name not in per_comp:
        # fall back: the computation with the most instructions
        entry_name = max(comps, key=lambda k: len(comps[k]))

    memo: Dict[str, HLOCost] = {}

    def total(name: str, depth: int = 0) -> HLOCost:
        if name in memo:
            return memo[name]
        c = per_comp.get(name)
        if c is None or depth > 50:
            return HLOCost(0.0, 0.0, 0.0, {k: 0.0 for k in _COLLECTIVE_KINDS})
        # mark visiting to break cycles
        memo[name] = HLOCost(0.0, 0.0, 0.0, {k: 0.0 for k in _COLLECTIVE_KINDS})
        flops, mem, coll = c.flops, c.mem_bytes, c.coll_bytes
        by_kind = dict(c.coll_by_kind)
        for callee, mult, counts_memory in c.callees:
            sub = total(callee, depth + 1)
            flops += mult * sub.flops
            if counts_memory:
                mem += mult * sub.mem_bytes
            coll += mult * sub.coll_bytes
            for k, v in sub.coll_by_kind.items():
                by_kind[k] = by_kind.get(k, 0.0) + mult * v
        out = HLOCost(flops, mem, coll, by_kind)
        memo[name] = out
        return out

    return total(entry_name)
