"""Roofline analysis of compiled dry-run artifacts."""

from repro.roofline import analysis  # noqa: F401
