"""Render the dry-run artifacts into the EXPERIMENTS.md tables."""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List


def load_records(dryrun_dir: str = "experiments/dryrun") -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def _fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x * 1e6:.1f}us"
    if x < 1.0:
        return f"{x * 1e3:.1f}ms"
    return f"{x:.2f}s"


def roofline_table(recs: List[Dict], mesh: str = "pod16x16") -> str:
    """Markdown §Roofline table for one mesh."""
    lines = [
        "| arch | shape | compute | memory | collective | dominant | "
        "model TFLOPs | useful ratio | HBM/chip |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in recs:
        if rec.get("status") != "ok" or rec.get("mesh") != mesh:
            continue
        r = rec["roofline"]
        mem = rec.get("memory", {})
        bpc = mem.get("bytes_per_chip")
        bpc_s = f"{bpc / 2**30:.2f}GiB" if bpc else "n/a"
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {_fmt_s(r['compute_s'])} | "
            f"{_fmt_s(r['memory_s'])} | {_fmt_s(r['collective_s'])} | "
            f"**{r['dominant']}** | {r['model_flops'] / 1e12:.1f} | "
            f"{r['useful_ratio']:.2f} | {bpc_s} |"
        )
    return "\n".join(lines)


def dryrun_table(recs: List[Dict]) -> str:
    """Markdown §Dry-run table: every combo x mesh with compile status."""
    lines = [
        "| arch | shape | mesh | status | chips | compile (s) | "
        "collective bytes/dev | dominant collective |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for rec in recs:
        status = rec.get("status")
        if status == "ok":
            r = rec["roofline"]
            kinds = r.get("coll_by_kind", {})
            dom_kind = max(kinds, key=kinds.get) if kinds else "-"
            lines.append(
                f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | ok | "
                f"{rec['chips']} | {rec.get('compile_s', 0):.1f} | "
                f"{r['coll_bytes']:.2e} | {dom_kind} |"
            )
        else:
            reason = rec.get("reason", rec.get("error", ""))[:60]
            lines.append(
                f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | "
                f"{status} | - | - | - | {reason} |"
            )
    return "\n".join(lines)


def summary(recs: List[Dict]) -> Dict[str, int]:
    out = {"ok": 0, "skipped": 0, "error": 0}
    for rec in recs:
        out[rec.get("status", "error")] = out.get(rec.get("status"), 0) + 1
    return out


if __name__ == "__main__":
    recs = load_records()
    print(summary(recs))
    print()
    print(roofline_table(recs))
