"""Analytic codec cost model — what the planner and fleet price with.

The kernels (``codec.kernels``) implement the wire format; this module
is its *cost-model twin*, the same split as
``costengine.BatchServiceModel`` vs the batched tracker kernels: a
frozen, hashable record the cost engine can price transfer legs with
and the plan cache can fingerprint.

A :class:`CodecModel` describes one operating point of the delta +
quantize pipeline:

* ``quant_bits`` — bits per depth sample on the wire (32 = raw f32,
  no quantizer);
* ``keyframe_interval`` — frames between keyframes; the frames in
  between ship only changed tiles (temporal delta);
* ``change_density`` — the *measured* fraction of tiles that change
  per delta frame (``codec.ref.change_density`` over a real sequence,
  or the rate controller's motion-driven estimate).

From these the model estimates compressed bytes
(:meth:`wire_nbytes`, amortized over one keyframe period) and prices
encode/decode compute per tier (:meth:`encode_time` /
:meth:`decode_time`) from per-byte costs calibrated against the
roofline tables (:meth:`from_roofline`) — encode runs where the
payload originates, decode where it lands, which is how
``core.costengine`` charges them.

:data:`IDENTITY` is the off-switch: its amortized ratio is 1.0, so it
never *applies* — every byte count and every charge is bit-for-bit the
raw path (golden-tested against ``codec=None`` in tests/test_codec.py).
"""

from __future__ import annotations

import dataclasses
import math

from repro.core.topology import Tier

BITS_RAW = 32


def tier_codec_rate(tier: Tier) -> float:
    """The FLOP rate codec work runs at on a tier — its accelerator
    when it has one (the kernels are Pallas launches), scalar CPU
    otherwise.  Shared with the roofline calibration in
    ``sim.hardware.codec_point`` so model and calibration cannot
    diverge."""
    return tier.accel_flops if tier.has_accelerator else tier.scalar_flops

# Arithmetic cost of the kernels, counted per RAW payload byte from the
# kernel bodies (all elementwise VPU work over f32 planes, 4 bytes per
# sample): delta encode does a subtract, abs, tile max-reduce, bitcast
# XOR and mask multiply (~5 ops/sample) plus the quantizer's clip,
# scale, round and shift/accumulate packing (~6 ops/sample) — ~11 ops
# per sample, ~3 per byte; decode inverts only the cheap half (XOR add
# back, unpack shift/mask, dequant multiply-add — ~6 ops/sample).
ENCODE_OPS_PER_BYTE = 3.0
DECODE_OPS_PER_BYTE = 1.5


@dataclasses.dataclass(frozen=True)
class CodecModel:
    """One codec operating point, priced analytically.

    Flat floats/ints only (like ``Tier``'s batching fields) so the plan
    cache can hash the whole record into its keys: two clients at the
    same operating point share one cached plan, and a rate-controller
    switch is a cache miss by construction.

    ``encode_flops_per_byte`` / ``decode_flops_per_byte`` convert raw
    payload bytes into tier-rate work; :meth:`from_roofline` calibrates
    them with a memory-bandwidth floor (the codec is elementwise, so on
    an accelerator it is bandwidth-bound: equivalent flops/byte can
    never fall below the tier's flops-to-bytes balance).
    ``min_payload_nbytes`` gates tiny payloads (pose vectors, result
    items): headers would dominate and nothing is saved.
    """

    name: str
    quant_bits: int = BITS_RAW
    keyframe_interval: int = 1
    change_density: float = 1.0
    header_nbytes: int = 0
    min_payload_nbytes: int = 4096
    encode_flops_per_byte: float = 0.0
    decode_flops_per_byte: float = 0.0
    # entropy stage over the delta residuals (codec.ref's zero-run /
    # significant-bit-width coding of the XOR residual words): shrinks
    # delta frames by `entropy_ratio` (measured on a real sequence) at
    # `entropy_flops_per_byte` extra CPU per raw byte on each side.
    # Off by default — the exact historical model.
    entropy_coding: bool = False
    entropy_ratio: float = 1.0
    entropy_flops_per_byte: float = 0.0

    def __post_init__(self) -> None:
        if not 1 <= self.quant_bits <= BITS_RAW:
            raise ValueError(f"quant_bits must be in [1, 32], got {self.quant_bits}")
        if self.keyframe_interval < 1:
            raise ValueError("keyframe_interval must be >= 1")
        if not 0.0 <= self.change_density <= 1.0:
            raise ValueError("change_density must be in [0, 1]")
        if self.header_nbytes < 0 or self.min_payload_nbytes < 0:
            raise ValueError("byte bounds must be >= 0")
        if self.encode_flops_per_byte < 0 or self.decode_flops_per_byte < 0:
            raise ValueError("flops-per-byte must be >= 0")
        if not 0.0 < self.entropy_ratio <= 1.0:
            raise ValueError("entropy_ratio must be in (0, 1]")
        if self.entropy_flops_per_byte < 0:
            raise ValueError("entropy_flops_per_byte must be >= 0")

    # -- compression ratios -------------------------------------------------

    @property
    def keyframe_ratio(self) -> float:
        """Wire bytes per raw byte of a keyframe (quantizer only)."""
        return self.quant_bits / BITS_RAW

    @property
    def delta_ratio(self) -> float:
        """Wire bytes per raw byte of a delta frame: only changed tiles
        ship, each at the quantized width — the composed quantized-delta
        format of ``codec.ref.encode_frame`` (codes delta'd in code
        space, NOT the 32-bit XOR residuals of the lossless f32 path),
        whose exact byte count matches this ratio (tested).  With the
        entropy stage armed, delta payloads shrink further by
        ``entropy_ratio`` (keyframes ship dense code words, which the
        width coder cannot touch — only residuals are sparse)."""
        if self.entropy_coding:
            return self.change_density * self.keyframe_ratio * self.entropy_ratio
        return self.change_density * self.keyframe_ratio

    @property
    def ratio(self) -> float:
        """Amortized wire ratio over one keyframe period: 1 keyframe +
        (K-1) delta frames."""
        k = self.keyframe_interval
        return (self.keyframe_ratio + (k - 1) * self.delta_ratio) / k

    # -- byte accounting ----------------------------------------------------

    def applies(self, nbytes: int) -> bool:
        """Whether this payload is transformed at all — False for tiny
        payloads and for any operating point that does not compress
        (the identity codec, by construction)."""
        return nbytes >= self.min_payload_nbytes and self.ratio < 1.0

    def wire_nbytes(self, nbytes: int) -> int:
        """Estimated bytes on the wire for a raw payload of ``nbytes``
        (amortized over a keyframe period); never exceeds the raw size
        and respects the raw + header bound by construction."""
        if not self.applies(nbytes):
            return nbytes
        return min(nbytes, self.header_nbytes + math.ceil(nbytes * self.ratio))

    def state_applies(self, nbytes: int) -> bool:
        """Whether a *stateful one-shot* transfer (live-migration pose +
        swarm payload) is transformed: the destination holds no
        reference frame, so only the quantizer can apply — never the
        delta ratio."""
        return nbytes >= self.min_payload_nbytes and self.keyframe_ratio < 1.0

    def state_wire_nbytes(self, nbytes: int) -> int:
        """Wire bytes for a one-shot state transfer: keyframe pricing
        (quantizer only), same raw-size clamp as :meth:`wire_nbytes`."""
        if not self.state_applies(nbytes):
            return nbytes
        return min(
            nbytes,
            self.header_nbytes + math.ceil(nbytes * self.keyframe_ratio),
        )

    # -- compute pricing ----------------------------------------------------

    def _tier_rate(self, tier: Tier) -> float:
        return tier_codec_rate(tier)

    def encode_time(self, nbytes: int, tier: Tier) -> float:
        """Seconds to encode ``nbytes`` of raw payload on ``tier`` —
        charged at the payload's source.  The entropy stage, when
        armed, adds its per-byte cost here (the coder runs over the
        residual plane after the quantizer)."""
        if not self.applies(nbytes):
            return 0.0
        fpb = self.encode_flops_per_byte
        if self.entropy_coding:
            fpb = fpb + self.entropy_flops_per_byte
        return fpb * nbytes / self._tier_rate(tier)

    def decode_time(self, nbytes: int, tier: Tier) -> float:
        """Seconds to decode back to the raw payload on ``tier`` —
        charged at the destination (on a contended edge this lands in
        ``compute_by_tier`` and therefore occupies a service slot)."""
        if not self.applies(nbytes):
            return 0.0
        fpb = self.decode_flops_per_byte
        if self.entropy_coding:
            fpb = fpb + self.entropy_flops_per_byte
        return fpb * nbytes / self._tier_rate(tier)

    def state_encode_time(self, nbytes: int, tier: Tier) -> float:
        """Encode cost of a one-shot state transfer (quantizer only)."""
        if not self.state_applies(nbytes):
            return 0.0
        return self.encode_flops_per_byte * nbytes / self._tier_rate(tier)

    def state_decode_time(self, nbytes: int, tier: Tier) -> float:
        """Decode cost of a one-shot state transfer (quantizer only)."""
        if not self.state_applies(nbytes):
            return 0.0
        return self.decode_flops_per_byte * nbytes / self._tier_rate(tier)

    # -- calibration --------------------------------------------------------

    @classmethod
    def from_roofline(
        cls,
        name: str,
        *,
        quant_bits: int,
        keyframe_interval: int,
        change_density: float,
        encode_flops: float,
        encode_mem_bandwidth: float,
        decode_flops: float,
        decode_mem_bandwidth: float,
        header_nbytes: int = 64,
        min_payload_nbytes: int = 4096,
    ) -> "CodecModel":
        """Calibrate per-byte compute from the roofline tables.

        ``encode_flops`` / ``decode_flops`` are the effective FLOP/s of
        the tier each side runs on (encode at the payload source,
        decode at the destination), ``*_mem_bandwidth`` their memory
        bandwidths.  The codec is elementwise, so each side's cost is
        the roofline max of its arithmetic (``*_OPS_PER_BYTE``) and its
        streaming floor — the flops-per-byte equivalent of moving every
        payload byte through memory at least once (``rate / mem_bw``).
        """
        enc_floor = encode_flops / encode_mem_bandwidth
        dec_floor = decode_flops / decode_mem_bandwidth
        return cls(
            name=name,
            quant_bits=quant_bits,
            keyframe_interval=keyframe_interval,
            change_density=change_density,
            header_nbytes=header_nbytes,
            min_payload_nbytes=min_payload_nbytes,
            encode_flops_per_byte=max(ENCODE_OPS_PER_BYTE, enc_floor),
            decode_flops_per_byte=max(DECODE_OPS_PER_BYTE, dec_floor),
        )


# The golden off-switch: ratio == 1.0, so `applies` is always False and
# every cost-engine path is bit-for-bit the raw path.
IDENTITY = CodecModel(name="identity")
