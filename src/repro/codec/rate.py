"""Rate control: per-client codec operating points in the fleet.

A fixed codec wastes the trade space: when the scene barely moves the
delta frames are nearly empty (ship fewer keyframes), and when the
link degrades the client should trade depth fidelity for headroom
(fewer quantizer bits) rather than drop frames.  The
:class:`RateController` closes that loop per client, deterministically,
from two signals the fleet already produces:

* **scene motion** — the frame-to-frame pose delta of the tracked
  hand (``motion_profile`` over a ``data.rgbd`` ground-truth
  trajectory; wrist translation is the component that actually drags
  tiles across the depth map).  Motion maps to an estimated tile
  change density through a linear model calibrated against measured
  densities (:func:`calibrate_density_map` renders the sequence and
  regresses; the defaults are its output for the stock sequence), and
  density picks the keyframe interval — long intervals only pay when
  deltas are sparse.
* **link pressure** — an EWMA of the relative excess of observed leg
  latencies over what the client's plan charged (the same draws the
  drift detector watches).  Sustained excess escalates down the
  ``bits_ladder``: coarser depth on the wire buys latency headroom.

Every operating-point switch is a re-plan through the shared
``PlanCache`` — the :class:`~repro.codec.model.CodecModel` is part of
the cache key, so clients at the same point share one plan and a
switch is a miss by construction.  Estimated densities snap to
``density_bins`` (ceiling) to keep the reachable key set small.

Hysteresis: a new point must survive ``min_dwell_frames`` since the
last switch, so jittery links cannot flap the codec frame to frame.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.codec.model import BITS_RAW, CodecModel, IDENTITY
from repro.codec.ref import PACKABLE_BITS

# Linear motion -> change-density map, calibrated by
# ``calibrate_density_map`` on the stock ``data.rgbd`` sequence
# (least squares of measured per-transition tile density on wrist
# translation magnitude).
DEFAULT_DENSITY_GAIN = 4.0
DEFAULT_DENSITY_FLOOR = 0.145


def motion_profile(truth) -> Tuple[float, ...]:
    """Per-transition wrist-translation magnitude |Δposition| of a
    (T, 27) ground-truth trajectory — the scene-motion signal."""
    import numpy as np

    t = np.asarray(truth)
    return tuple(
        float(x) for x in np.linalg.norm(np.diff(t[:, :3], axis=0), axis=1)
    )


def sequence_motion(seq_cfg=None) -> Tuple[float, ...]:
    """Motion profile of a ``data.rgbd`` sequence config (the stock
    "pre-recorded video" when none is given)."""
    from repro.data import rgbd

    cfg = seq_cfg if seq_cfg is not None else rgbd.SequenceConfig()
    return motion_profile(rgbd.truth_trajectory(cfg))


def calibrate_density_map(
    seq_cfg=None,
    *,
    threshold: float = 0.0,
    block_h: int = 8,
    block_w: int = 32,
) -> Tuple[float, float]:
    """Fit ``density ~= gain * motion + floor`` by least squares against
    densities measured by the reference delta encoder on the rendered
    sequence.  Returns ``(gain, floor)`` — the source of the module
    defaults."""
    import numpy as np

    from repro.codec import ref
    from repro.data import rgbd

    cfg = seq_cfg if seq_cfg is not None else rgbd.SequenceConfig(
        num_frames=60, noise_std=0.0
    )
    frames, truth = rgbd.render_sequence(cfg)
    dens = np.asarray(
        ref.change_density(
            frames, threshold=threshold, block_h=block_h, block_w=block_w
        )
    )
    motion = np.asarray(motion_profile(truth))
    a = np.stack([motion, np.ones_like(motion)], axis=1)
    (gain, floor), *_ = np.linalg.lstsq(a, dens, rcond=None)
    return float(gain), float(floor)


@dataclasses.dataclass(frozen=True)
class CodecConfig:
    """Fleet-level codec arming: the base operating point plus the rate
    controller's ladders and thresholds.

    ``adapt=False`` pins every client to ``base`` forever (the fixed-
    codec and identity/off-switch modes); ``adapt=True`` lets each
    client's :class:`RateController` walk the ladders.  ``base``
    supplies the calibrated per-byte costs, header and payload gate —
    the controller only swaps ``quant_bits`` / ``keyframe_interval`` /
    ``change_density``.
    """

    base: CodecModel
    adapt: bool = True
    # fine -> coarse wire width as link pressure grows
    bits_ladder: Tuple[int, ...] = (16, 8)
    # short -> long keyframe spacing as estimated density falls;
    # density above cuts[i] selects interval_ladder[i] (cuts descend)
    interval_ladder: Tuple[int, ...] = (1, 4, 8, 15)
    density_cuts: Tuple[float, ...] = (0.35, 0.17, 0.10)
    # estimated densities snap UP to these (bounds the plan-cache keys)
    density_bins: Tuple[float, ...] = (0.1, 0.2, 0.4, 1.0)
    pressure_threshold: float = 0.25
    pressure_alpha: float = 0.2
    min_dwell_frames: int = 15
    # per-frame scene motion (cycled when shorter than the run)
    motion: Tuple[float, ...] = ()
    density_gain: float = DEFAULT_DENSITY_GAIN
    density_floor: float = DEFAULT_DENSITY_FLOOR
    # -- shared-cell fairness -------------------------------------------
    # cell_threshold (seconds of smoothed shared-medium wait per ladder
    # step) arms the contention signal: a client queuing on a congested
    # cell escalates down the bits ladder even when its leg draws look
    # clean (medium waits are structurally invisible to the pressure
    # EWMA — they are queueing, not jitter).  inf = off, the exact
    # pressure-only controller.  The EWMA weights each wait sample by
    # the client's CURRENT wire ratio, so the heaviest payload on the
    # cell feels the most pressure and backs off first (self-balancing
    # fairness).  cell_stagger spreads per-client thresholds
    # (thr_i = cell_threshold * (1 + stagger * client_id)) so equal
    # clients shed in a deterministic order instead of oscillating in
    # lockstep.
    cell_threshold: float = float("inf")
    cell_alpha: float = 0.3
    cell_stagger: float = 0.0
    # -- keyframe loss / resync -----------------------------------------
    # resync_bound > 0 couples observed frame drops back into keyframe
    # spacing: when the smoothed drop signal exceeds drop_threshold the
    # keyframe interval is clamped to resync_bound, so a decoder that
    # lost a reference is guaranteed a fresh keyframe within that many
    # frames.  0 = off (exact historical ladder).
    resync_bound: int = 0
    drop_alpha: float = 0.3
    drop_threshold: float = 0.5

    def __post_init__(self) -> None:
        if not self.bits_ladder or not self.interval_ladder:
            raise ValueError("ladders must be non-empty")
        for b in self.bits_ladder:
            if b != BITS_RAW and b not in PACKABLE_BITS:
                raise ValueError(f"quantizer bits {b} not packable")
        if len(self.density_cuts) != len(self.interval_ladder) - 1:
            raise ValueError(
                "need exactly len(interval_ladder) - 1 density cuts"
            )
        if list(self.density_cuts) != sorted(self.density_cuts, reverse=True):
            raise ValueError("density_cuts must descend")
        if not self.density_bins or any(
            b <= 0 for b in self.density_bins
        ) or list(self.density_bins) != sorted(self.density_bins):
            raise ValueError("density_bins must be positive and ascending")
        if self.density_bins[-1] < 1.0:
            # the ceiling snap must always have a bin to land on — a
            # short ladder would silently snap high densities DOWN and
            # underprice the wire
            raise ValueError("density_bins must end at >= 1.0")
        if not 0.0 < self.pressure_alpha <= 1.0:
            raise ValueError("pressure_alpha must be in (0, 1]")
        if self.pressure_threshold <= 0.0:
            raise ValueError("pressure_threshold must be > 0")
        if self.min_dwell_frames < 0:
            raise ValueError("min_dwell_frames must be >= 0")
        if self.cell_threshold <= 0.0:
            raise ValueError("cell_threshold must be > 0 (inf = off)")
        if not 0.0 < self.cell_alpha <= 1.0:
            raise ValueError("cell_alpha must be in (0, 1]")
        if self.cell_stagger < 0.0:
            raise ValueError("cell_stagger must be >= 0")
        if self.resync_bound < 0:
            raise ValueError("resync_bound must be >= 0 (0 = off)")
        if not 0.0 < self.drop_alpha <= 1.0:
            raise ValueError("drop_alpha must be in (0, 1]")
        if self.drop_threshold <= 0.0:
            raise ValueError("drop_threshold must be > 0")


def identity_config() -> CodecConfig:
    """The golden off-switch: every client pinned to the identity
    codec — the fleet must be event-for-event the raw fleet."""
    return CodecConfig(base=IDENTITY, adapt=False)


class RateController:
    """One client's codec operating point over time (deterministic)."""

    def __init__(self, cfg: CodecConfig, client_id: int = 0):
        self.cfg = cfg
        self.client_id = client_id
        self._pressure = 0.0
        # shared-cell wait EWMA, weighted by the current wire ratio
        # (heaviest payload feels the most pressure — see CodecConfig)
        self._cell = 0.0
        # smoothed frame-drop signal: EWMA of (frame-index gap - 1)
        self._drop = 0.0
        self._last_idx: Optional[int] = None
        self._frames_since_switch = 0
        self.switches = 0
        # ladder-transition log, one (frame_idx, old_bits, new_bits)
        # per switch — consumed by repro.cluster.telemetry
        self.transitions: list = []
        self.model: CodecModel = (
            cfg.base if not cfg.adapt else self._operating_point(0)
        )

    # -- signal mapping -----------------------------------------------------

    def _motion_at(self, frame_idx: int) -> float:
        m = self.cfg.motion
        return m[frame_idx % len(m)] if m else 0.0

    def _density_at(self, frame_idx: int) -> float:
        c = self.cfg
        est = c.density_floor + c.density_gain * self._motion_at(frame_idx)
        return min(max(est, 0.0), 1.0)

    def _binned(self, density: float) -> float:
        for b in self.cfg.density_bins:
            if density <= b:
                return b
        return self.cfg.density_bins[-1]

    def _interval_for(self, density: float) -> int:
        c = self.cfg
        interval = c.interval_ladder[-1]
        for i, cut in enumerate(c.density_cuts):
            if density > cut:
                interval = c.interval_ladder[i]
                break
        if c.resync_bound > 0 and self._drop > c.drop_threshold:
            # a lossy stream needs fresh references: clamp keyframe
            # spacing so the decoder resyncs within the bound
            interval = min(interval, c.resync_bound)
        return interval

    def _bits_for(self) -> int:
        c = self.cfg
        idx = int(self._pressure / c.pressure_threshold)
        if c.cell_threshold != float("inf"):
            if self._cell > 0.0:
                thr = c.cell_threshold * (
                    1.0 + c.cell_stagger * self.client_id
                )
                idx += int(self._cell / thr)
            # AIMD asymmetry: escalating coarser is immediate (the cell
            # is congested NOW), but recovery toward finer bits moves
            # one rung per switch — a client that backs off stops
            # feeling the cell (its weighted samples shrink with its
            # ratio), so unbounded recovery would slam the whole cohort
            # back to the finest point in lockstep and flap the cell.
            cur = getattr(self, "model", None)
            if cur is not None and cur.quant_bits in c.bits_ladder:
                cur_idx = c.bits_ladder.index(cur.quant_bits)
                if idx < cur_idx:
                    idx = cur_idx - 1
        return c.bits_ladder[min(max(idx, 0), len(c.bits_ladder) - 1)]

    def _operating_point(self, frame_idx: int) -> CodecModel:
        density = self._density_at(frame_idx)
        return dataclasses.replace(
            self.cfg.base,
            quant_bits=self._bits_for(),
            keyframe_interval=self._interval_for(density),
            change_density=self._binned(density),
        )

    # -- the loop -----------------------------------------------------------

    def observe(
        self, frame_idx: int, observed, plan, cell_wait: float = 0.0
    ) -> Optional[CodecModel]:
        """Feed one processed frame's observed leg draws (the same
        tuples the drift detector sees) against the plan that charged
        them.  Returns the new :class:`CodecModel` when the operating
        point switches, else None.

        ``cell_wait`` is the frame's shared-medium queue delay (0.0 on
        private spokes): contention is queueing, not jitter, so it never
        reaches the leg draws — this side channel is the only way the
        controller can see a congested cell.  The sample is weighted by
        the client's current wire ratio before entering the cell EWMA,
        so heavier payloads back off first.
        """
        if not self.cfg.adapt:
            return None
        charged = sum(leg.latency for leg in plan.legs)
        if charged > 0.0 and observed:
            drawn = sum(draw for _, draw in observed)
            excess = max(drawn / charged - 1.0, 0.0)
            a = self.cfg.pressure_alpha
            self._pressure = a * excess + (1.0 - a) * self._pressure
        if self.cfg.cell_threshold != float("inf"):
            ca = self.cfg.cell_alpha
            sample = cell_wait * self.model.ratio
            self._cell = ca * sample + (1.0 - ca) * self._cell
        if self.cfg.resync_bound > 0:
            gap = (
                frame_idx - self._last_idx - 1
                if self._last_idx is not None
                else 0
            )
            self._last_idx = frame_idx
            da = self.cfg.drop_alpha
            self._drop = da * gap + (1.0 - da) * self._drop
        self._frames_since_switch += 1
        proposal = self._operating_point(frame_idx)
        if (
            proposal != self.model
            and self._frames_since_switch >= self.cfg.min_dwell_frames
        ):
            self.transitions.append(
                (frame_idx, self.model.quant_bits, proposal.quant_bits)
            )
            self.model = proposal
            self._frames_since_switch = 0
            self.switches += 1
            return proposal
        return None
