"""Reference implementations of the payload codec (pure jnp).

The wire format the Pallas kernels (``codec.kernels``) accelerate, in
plain ``jnp`` for oracle testing and host-side calibration:

* **Temporal delta with per-tile change masks.**  The frame plane is
  split into (block_h, block_w) tiles; a tile is *changed* when any of
  its pixels moved more than ``threshold`` (in value space) against the
  reference frame.  Changed tiles ship their residual, unchanged tiles
  ship nothing — depth maps of a slowly moving hand leave most tiles
  untouched (Kang et al., 2015), which is where the compression comes
  from.  The residual is the XOR of the f32 *bit patterns*: integer
  XOR is exactly invertible, so a changed tile reconstructs bit-for-bit
  (a float subtract would not — ``ref + (frame - ref)`` rounds), and at
  ``threshold == 0`` the whole roundtrip is lossless to the bit.
* **Uniform depth quantization + bit-packing.**  Depth values in
  [lo, hi] quantize to ``bits``-wide codes (round-to-nearest, so the
  reconstruction error is bounded by half a step — see
  :func:`quant_step`), and ``32 // bits`` adjacent codes pack into one
  int32 word along the lane axis.

Everything here is shape-strict (dimensions must divide the block) —
padding and rank plumbing live in the kernel wrappers, mirroring the
``kernels/ops.py`` split.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

DEFAULT_BLOCK_H = 8
DEFAULT_BLOCK_W = 128

# one i32 word packs 32 // bits codes; bits == 32 is the raw f32 path
# and never enters the quantizer (codes would overflow int32)
PACKABLE_BITS = (1, 2, 4, 8, 16)


def _check_blocks(h: int, w: int, block_h: int, block_w: int) -> None:
    if h % block_h or w % block_w:
        raise ValueError(
            f"frame ({h}, {w}) not divisible by tile ({block_h}, {block_w})"
        )


def _check_bits(bits: int) -> int:
    if bits not in PACKABLE_BITS:
        raise ValueError(
            f"quantizer bits must be one of {PACKABLE_BITS}, got {bits}"
        )
    return 32 // bits


# ---------------------------------------------------------------------------
# temporal delta
# ---------------------------------------------------------------------------


def delta_encode(
    frame: jnp.ndarray,  # (H, W) f32
    ref: jnp.ndarray,  # (H, W) f32 — the receiver's reconstruction
    *,
    threshold: float = 0.0,
    block_h: int = DEFAULT_BLOCK_H,
    block_w: int = DEFAULT_BLOCK_W,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns ``(delta_bits (H, W) i32, mask (H/bh, W/bw) f32)``.

    ``delta_bits`` is the XOR of the frame's and reference's bit
    patterns on changed tiles and zero elsewhere; ``mask`` is 1.0 on
    changed tiles.  Only masked tiles (plus the mask itself) cross the
    wire — :func:`encoded_nbytes_exact` counts them.
    """
    h, w = frame.shape
    _check_blocks(h, w, block_h, block_w)
    f = frame.astype(jnp.float32)
    r = ref.astype(jnp.float32)
    tiles = (h // block_h, block_h, w // block_w, block_w)
    vdiff = jnp.abs(f - r).reshape(tiles)
    mask = (vdiff.max(axis=(1, 3)) > threshold).astype(jnp.float32)
    xor = f.view(jnp.int32) ^ r.view(jnp.int32)
    keep = jnp.repeat(
        jnp.repeat(mask.astype(jnp.int32), block_h, axis=0), block_w, axis=1
    )
    return xor * keep, mask


def delta_decode(
    delta_bits: jnp.ndarray,  # (H, W) i32
    ref: jnp.ndarray,  # (H, W) f32
) -> jnp.ndarray:
    """Inverse of :func:`delta_encode`: changed tiles reconstruct
    bit-for-bit (XOR is exactly invertible), unchanged tiles fall back
    to the reference (error <= the encoder's threshold per pixel)."""
    return (ref.astype(jnp.float32).view(jnp.int32) ^ delta_bits).view(
        jnp.float32
    )


# ---------------------------------------------------------------------------
# uniform quantization + bit-packing
# ---------------------------------------------------------------------------


def quant_step(lo: float, hi: float, bits: int) -> float:
    """The advertised quantization step; roundtrip error is <= step/2
    for inputs inside [lo, hi] (round-to-nearest code assignment)."""
    levels = (1 << bits) - 1
    return (hi - lo) / levels if levels else hi - lo


def quantize_pack(
    depth: jnp.ndarray,  # (H, W) f32
    lo: float,
    hi: float,
    *,
    bits: int = 8,
    block_h: int = DEFAULT_BLOCK_H,
    block_w: int = DEFAULT_BLOCK_W,
) -> jnp.ndarray:
    """Quantize to ``bits``-wide codes and pack the lane axis:
    returns ``(H, W * bits / 32) i32`` words."""
    ratio = _check_bits(bits)
    h, w = depth.shape
    _check_blocks(h, w, block_h, block_w)
    step = quant_step(lo, hi, bits)
    x = jnp.clip(depth.astype(jnp.float32), lo, hi)
    codes = jnp.round((x - lo) / step).astype(jnp.int32)
    codes = jnp.clip(codes, 0, (1 << bits) - 1)
    shifts = (jnp.arange(ratio, dtype=jnp.int32) * bits).reshape(1, 1, ratio)
    grouped = codes.reshape(h, w // ratio, ratio)
    return jnp.sum(grouped << shifts, axis=-1).astype(jnp.int32)


def unpack_dequantize(
    words: jnp.ndarray,  # (H, W * bits / 32) i32
    lo: float,
    hi: float,
    *,
    bits: int = 8,
) -> jnp.ndarray:
    """Inverse of :func:`quantize_pack`: ``(H, W) f32`` reconstruction
    with per-pixel error <= :func:`quant_step`/2 inside [lo, hi]."""
    ratio = _check_bits(bits)
    step = quant_step(lo, hi, bits)
    h, wp = words.shape
    shifts = (jnp.arange(ratio, dtype=jnp.int32) * bits).reshape(1, 1, ratio)
    lanes = (words[:, :, None] >> shifts) & ((1 << bits) - 1)
    codes = lanes.reshape(h, wp * ratio)
    return lo + codes.astype(jnp.float32) * step


# ---------------------------------------------------------------------------
# the composed quantized-delta wire format
# ---------------------------------------------------------------------------


def encode_frame(
    frame: jnp.ndarray,  # (H, W) f32
    ref: jnp.ndarray,  # (H, W) f32 — receiver's *reconstructed* reference
    lo: float,
    hi: float,
    *,
    bits: int = 8,
    block_h: int = DEFAULT_BLOCK_H,
    block_w: int = DEFAULT_BLOCK_W,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """The composed delta+quantize wire format the analytic
    ``CodecModel`` prices: both planes quantize to ``bits``-wide codes,
    and a tile ships its *packed codes* iff any code changed — so a
    delta frame costs exactly ``change_density * bits/32`` of the raw
    f32 bytes, which is ``CodecModel.delta_ratio``.

    Returns ``(words, mask)``: the full packed-code plane (the receiver
    reads only masked tiles; :func:`encoded_nbytes_exact` with the same
    ``bits`` counts the wire bytes) and the per-tile change mask.  The
    mask comes from the value-space delta at threshold ``step/2``: two
    samples quantize to different codes only when their dequantized
    values differ by at least one step, so thresholding the
    *dequantized* planes at half a step reproduces the code-level
    change mask with the existing delta kernel.
    """
    words = quantize_pack(
        frame, lo, hi, bits=bits, block_h=block_h, block_w=block_w
    )
    recon = unpack_dequantize(words, lo, hi, bits=bits)
    ref_words = quantize_pack(
        ref, lo, hi, bits=bits, block_h=block_h, block_w=block_w
    )
    ref_recon = unpack_dequantize(ref_words, lo, hi, bits=bits)
    step = quant_step(lo, hi, bits)
    _, mask = delta_encode(
        recon, ref_recon, threshold=step / 2, block_h=block_h, block_w=block_w
    )
    return words, mask


def decode_frame(
    words: jnp.ndarray,  # packed codes of the masked tiles (full plane here)
    mask: jnp.ndarray,  # (tiles_h, tiles_w) change mask
    ref: jnp.ndarray,  # (H, W) f32 — receiver's reconstructed reference
    lo: float,
    hi: float,
    *,
    bits: int = 8,
    block_h: int = DEFAULT_BLOCK_H,
    block_w: int = DEFAULT_BLOCK_W,
) -> jnp.ndarray:
    """Inverse of :func:`encode_frame`: changed tiles dequantize their
    shipped codes (error <= step/2), unchanged tiles keep the
    reference — whose codes are identical, so the whole reconstruction
    is within step/2 of the source frame everywhere."""
    recon = unpack_dequantize(words, lo, hi, bits=bits)
    keep = jnp.repeat(
        jnp.repeat(mask, block_h, axis=0), block_w, axis=1
    )[: ref.shape[0], : ref.shape[1]]
    return jnp.where(keep > 0.0, recon, ref.astype(jnp.float32))


# ---------------------------------------------------------------------------
# entropy stage: per-tile significant-bit-width coding of residual words
# ---------------------------------------------------------------------------
#
# XOR residuals of a slowly changing depth map are mostly zero words with
# small values clustered where the hand moved; a general-purpose entropy
# coder is overkill, but per-tile width coding captures the same
# sparsity with one byte of side information per tile: each tile of
# `tile` words records the significant bit width of its max value, then
# packs every word's low `width` bits back to back.  An all-zero tile
# costs exactly one byte.  A leading flag byte selects raw fallback when
# width coding cannot win, which makes the hard bound
# ``encoded <= raw + 1`` hold on EVERY input (adversarial included) —
# the property the CodecModel's raw-size clamp assumes and
# tests/test_codec.py asserts.

ENTROPY_TILE = 64  # words per width-coded tile
_ENTROPY_RAW = 0  # flag byte: raw little-endian words follow
_ENTROPY_CODED = 1  # flag byte: width-coded tiles follow


def _as_uint32(words) -> np.ndarray:
    return np.ascontiguousarray(
        np.asarray(words, dtype=np.int32)
    ).view(np.uint32).ravel()


def entropy_encode_words(words, tile: int = ENTROPY_TILE) -> bytes:
    """Entropy-code a plane of residual words (any shape, int32).

    Returns ``flag byte + payload``: width-coded tiles when that wins,
    raw little-endian words otherwise.  Lossless by construction and
    never more than one byte (the flag) over the raw size.
    """
    if tile < 1:
        raise ValueError("tile must be >= 1")
    flat = _as_uint32(words)
    raw = flat.astype("<u4").tobytes()
    parts = [bytes([_ENTROPY_CODED])]
    coded_len = 1
    for s in range(0, len(flat), tile):
        chunk = flat[s : s + tile]
        width = int(chunk.max()).bit_length() if len(chunk) else 0
        parts.append(bytes([width]))
        coded_len += 1
        if width:
            acc = 0
            shift = 0
            for v in chunk.tolist():
                acc |= v << shift
                shift += width
            nb = (shift + 7) // 8
            parts.append(acc.to_bytes(nb, "little"))
            coded_len += nb
        if coded_len > len(raw):  # width coding already lost: bail early
            break
    if coded_len <= len(raw):
        return b"".join(parts)
    return bytes([_ENTROPY_RAW]) + raw


def entropy_decode_words(
    data: bytes, n: int, tile: int = ENTROPY_TILE
) -> np.ndarray:
    """Inverse of :func:`entropy_encode_words`: the ``n`` original
    residual words, bit-exact, as a flat int32 array."""
    if not data:
        raise ValueError("empty entropy stream")
    flag = data[0]
    body = data[1:]
    if flag == _ENTROPY_RAW:
        return np.frombuffer(body, dtype="<u4", count=n).view(np.int32).copy()
    if flag != _ENTROPY_CODED:
        raise ValueError(f"unknown entropy stream flag {flag}")
    out = np.zeros(n, dtype=np.uint32)
    pos = 0
    for s in range(0, n, tile):
        count = min(tile, n - s)
        width = body[pos]
        pos += 1
        if not width:
            continue
        nb = (count * width + 7) // 8
        acc = int.from_bytes(body[pos : pos + nb], "little")
        pos += nb
        lane_mask = (1 << width) - 1
        vals = [(acc >> (k * width)) & lane_mask for k in range(count)]
        out[s : s + count] = np.asarray(vals, dtype=np.uint32)
    return out.view(np.int32)


def entropy_encoded_nbytes(words, tile: int = ENTROPY_TILE) -> int:
    """Exact wire size of one entropy-coded residual plane (flag byte
    included) — what ``CodecModel.entropy_ratio`` is calibrated from."""
    return len(entropy_encode_words(words, tile))


# ---------------------------------------------------------------------------
# sequenced delta streams: keyframe loss and resync
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StreamPacket:
    """One wire packet of a sequenced delta stream.

    ``kind`` is "key" (self-contained) or "delta" (XOR residual against
    the reconstruction of packet ``ref_seq``); a decoder holding any
    other reference must refuse the packet rather than decode garbage.
    """

    seq: int
    kind: str
    ref_seq: int
    payload: object


class DeltaStreamEncoder:
    """Packetizes frames as keyframes + XOR deltas with loss-driven
    resync: after :meth:`report_loss`, a keyframe is forced within
    ``resync_bound`` packets, so a receiver that lost its reference is
    never stranded longer than the bound (fault-injection tested)."""

    def __init__(
        self,
        *,
        keyframe_interval: int = 8,
        resync_bound: int = 4,
        threshold: float = 0.0,
        block_h: int = DEFAULT_BLOCK_H,
        block_w: int = DEFAULT_BLOCK_W,
    ):
        if keyframe_interval < 1:
            raise ValueError("keyframe_interval must be >= 1")
        if resync_bound < 1:
            raise ValueError("resync_bound must be >= 1")
        self.keyframe_interval = keyframe_interval
        self.resync_bound = resync_bound
        self.threshold = threshold
        self.block_h = block_h
        self.block_w = block_w
        self._seq = 0
        self._ref: Optional[jnp.ndarray] = None
        self._since_key = 0
        # deltas still allowed before a loss report forces a keyframe
        self._deltas_left: Optional[int] = None
        self.forced_keyframes = 0

    def report_loss(self, lost_seq: int) -> None:
        """The transport noticed packet ``lost_seq`` never arrived: the
        receiver's reference chain is broken from there on, so at most
        ``resync_bound - 1`` more deltas may ship before a keyframe."""
        budget = self.resync_bound - 1
        if self._deltas_left is None or budget < self._deltas_left:
            self._deltas_left = budget

    def encode(self, frame: jnp.ndarray) -> StreamPacket:
        seq = self._seq
        self._seq += 1
        force = self._deltas_left is not None and self._deltas_left <= 0
        scheduled = (
            self._ref is None or self._since_key >= self.keyframe_interval - 1
        )
        if force or scheduled:
            if force and not scheduled:
                self.forced_keyframes += 1
            self._since_key = 0
            self._deltas_left = None
            self._ref = jnp.asarray(frame, dtype=jnp.float32)
            return StreamPacket(seq, "key", seq, self._ref)
        delta_bits, _ = delta_encode(
            frame,
            self._ref,
            threshold=self.threshold,
            block_h=self.block_h,
            block_w=self.block_w,
        )
        # the encoder tracks the RECEIVER's reconstruction (unchanged
        # tiles keep the old reference), not the source frame — the
        # closed-loop discipline that stops drift from accumulating
        self._ref = delta_decode(delta_bits, self._ref)
        self._since_key += 1
        if self._deltas_left is not None:
            self._deltas_left -= 1
        return StreamPacket(seq, "delta", seq - 1, delta_bits)


class DeltaStreamDecoder:
    """Receiver of a :class:`DeltaStreamEncoder` stream.

    ``decode`` returns the reconstructed frame, or None (a NACK) when a
    delta references a reconstruction this decoder does not hold — a
    stale or missing reference must never be decoded against."""

    def __init__(self) -> None:
        self._ref: Optional[jnp.ndarray] = None
        self._ref_seq = -1
        self.decoded = 0
        self.nacks = 0

    def decode(self, packet: StreamPacket) -> Optional[jnp.ndarray]:
        if packet.kind == "key":
            self._ref = jnp.asarray(packet.payload, dtype=jnp.float32)
            self._ref_seq = packet.seq
            self.decoded += 1
            return self._ref
        if self._ref is None or packet.ref_seq != self._ref_seq:
            self.nacks += 1
            return None
        self._ref = delta_decode(packet.payload, self._ref)
        self._ref_seq = packet.seq
        self.decoded += 1
        return self._ref


# ---------------------------------------------------------------------------
# exact wire-format accounting + calibration helpers
# ---------------------------------------------------------------------------


def encoded_nbytes_exact(
    mask: jnp.ndarray,  # (tiles_h, tiles_w) change mask from delta_encode
    *,
    bits: int = 32,
    block_h: int = DEFAULT_BLOCK_H,
    block_w: int = DEFAULT_BLOCK_W,
    header_nbytes: int = 0,
) -> int:
    """Exact encoded size of one delta frame: the changed tiles' payload
    at ``bits`` per sample, one bit per tile of change mask, plus the
    fixed header.  This is what the analytic ``CodecModel`` estimates
    via its measured change density."""
    changed = int(jnp.sum(mask > 0.0))
    tile_bits = block_h * block_w * bits
    mask_bits = int(mask.size)
    return header_nbytes + math.ceil((changed * tile_bits + mask_bits) / 8)


def change_density(
    frames: jnp.ndarray,  # (T, H, W) consecutive depth frames
    *,
    threshold: float = 0.0,
    block_h: int = DEFAULT_BLOCK_H,
    block_w: int = DEFAULT_BLOCK_W,
) -> jnp.ndarray:
    """Per-transition fraction of changed tiles, shape (T-1,).  The
    measured signal that drives ``CodecModel.change_density`` and the
    rate controller's motion -> density calibration."""
    h, w = frames.shape[1:]
    pad_h = -h % block_h
    pad_w = -w % block_w
    if pad_h or pad_w:
        frames = jnp.pad(frames, ((0, 0), (0, pad_h), (0, pad_w)))
    out = []
    for t in range(frames.shape[0] - 1):
        _, mask = delta_encode(
            frames[t + 1],
            frames[t],
            threshold=threshold,
            block_h=block_h,
            block_w=block_w,
        )
        out.append(mask.mean())
    return jnp.stack(out)
