"""Pallas TPU kernels: delta-frame RGBD payload codec.

The paper's bottom line is that the offloaded tracker is *payload
bound* — the RGBD frame crossing the network dominates the loop, and
"compressing the information flow" is its named future work.  These
kernels implement that compression on the accelerator so encode rides
the same device the tracker already uses:

* :func:`delta_encode` / :func:`delta_decode` — keyframe + per-tile
  temporal delta with change masks.  The grid tiles the frame plane;
  each program compares its (block_h, block_w) tile against the
  receiver's reference frame, flags it changed when any pixel moved
  more than ``threshold``, and emits the XOR of the f32 bit patterns
  for changed tiles (integer XOR inverts exactly, so changed tiles
  reconstruct bit-for-bit; ``threshold == 0`` makes the whole frame
  lossless to the bit).
* :func:`quantize_pack` / :func:`unpack_dequantize` — uniform depth
  quantization to ``bits``-wide codes (roundtrip error <= half a
  quantization step, see ``ref.quant_step``) with ``32 // bits``
  adjacent codes bit-packed per int32 word along the lane axis.

Batched variants grow a leading client axis exactly like PR 3's fused
tracker kernels: the Pallas grid extends to (B, tiles...) over
(1, block_h, block_w) tiles, and since every kernel body is
rank-agnostic tile math, the B = 1 slice is bit-for-bit the unbatched
kernel (golden test in tests/test_codec.py).  A ``path="vmap"``
fallback vmaps the unbatched call for comparison/debugging.

``codec.ref`` holds the pure-jnp oracles; wrappers here handle padding
to tile multiples and slicing back, mirroring ``kernels/ops.py``.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.codec.ref import (
    DEFAULT_BLOCK_H,
    DEFAULT_BLOCK_W,
    _check_bits,
    quant_step,
)

DEFAULT_INTERPRET = True  # CPU container; flip on real TPU.


def _pad_plane(x: jnp.ndarray, block_h: int, block_w: int) -> jnp.ndarray:
    """Zero-pad the trailing two axes up to tile multiples."""
    h, w = x.shape[-2:]
    pad_h = -h % block_h
    pad_w = -w % block_w
    if not pad_h and not pad_w:
        return x
    widths = [(0, 0)] * (x.ndim - 2) + [(0, pad_h), (0, pad_w)]
    return jnp.pad(x, widths)


# ---------------------------------------------------------------------------
# temporal delta
# ---------------------------------------------------------------------------


def _delta_encode_kernel(f_ref, r_ref, d_out, m_out, *, threshold: float):
    """Rank-agnostic tile body: serves the (BH, BW) unbatched tiles and
    the (1, BH, BW) batched tiles unchanged, so B=1 is bit-for-bit."""
    f = f_ref[...].astype(jnp.float32)
    r = r_ref[...].astype(jnp.float32)
    changed = (jnp.max(jnp.abs(f - r)) > threshold).astype(jnp.int32)
    xor = jax.lax.bitcast_convert_type(
        f, jnp.int32
    ) ^ jax.lax.bitcast_convert_type(r, jnp.int32)
    d_out[...] = xor * changed
    m_out[...] = jnp.full(m_out.shape, changed.astype(jnp.float32))


def _delta_decode_kernel(d_ref, r_ref, out_ref):
    bits = jax.lax.bitcast_convert_type(
        r_ref[...].astype(jnp.float32), jnp.int32
    ) ^ d_ref[...]
    out_ref[...] = jax.lax.bitcast_convert_type(bits, jnp.float32)


@functools.partial(
    jax.jit, static_argnames=("threshold", "block_h", "block_w", "interpret")
)
def delta_encode(
    frame: jnp.ndarray,  # (H, W) f32
    ref: jnp.ndarray,  # (H, W) f32
    *,
    threshold: float = 0.0,
    block_h: int = DEFAULT_BLOCK_H,
    block_w: int = DEFAULT_BLOCK_W,
    interpret: bool = DEFAULT_INTERPRET,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns ``(delta_bits (H, W) i32, mask f32)`` — matches
    ``ref.delta_encode`` on tile-aligned shapes.  Unaligned frames are
    zero-padded to tile multiples: the delta plane is cropped back to
    (H, W), while the mask covers the *padded* tile grid
    (ceil(H/bh), ceil(W/bw)) — pad-only tiles are zero in both planes
    and therefore never marked changed."""
    h, w = frame.shape
    f = _pad_plane(frame.astype(jnp.float32), block_h, block_w)
    r = _pad_plane(ref.astype(jnp.float32), block_h, block_w)
    hp, wp = f.shape
    grid = (hp // block_h, wp // block_w)
    tile = pl.BlockSpec((block_h, block_w), lambda i, j: (i, j))
    cell = pl.BlockSpec((1, 1), lambda i, j: (i, j))
    delta, mask = pl.pallas_call(
        functools.partial(_delta_encode_kernel, threshold=threshold),
        grid=grid,
        in_specs=[tile, tile],
        out_specs=[tile, cell],
        out_shape=[
            jax.ShapeDtypeStruct((hp, wp), jnp.int32),
            jax.ShapeDtypeStruct(grid, jnp.float32),
        ],
        interpret=interpret,
    )(f, r)
    return delta[:h, :w], mask


@functools.partial(
    jax.jit, static_argnames=("block_h", "block_w", "interpret")
)
def delta_decode(
    delta_bits: jnp.ndarray,  # (H, W) i32
    ref: jnp.ndarray,  # (H, W) f32
    *,
    block_h: int = DEFAULT_BLOCK_H,
    block_w: int = DEFAULT_BLOCK_W,
    interpret: bool = DEFAULT_INTERPRET,
) -> jnp.ndarray:
    """Reconstruct the frame: bit-exact on changed tiles, reference
    passthrough (error <= encode threshold) on unchanged ones."""
    h, w = delta_bits.shape
    d = _pad_plane(delta_bits, block_h, block_w)
    r = _pad_plane(ref.astype(jnp.float32), block_h, block_w)
    hp, wp = d.shape
    tile = pl.BlockSpec((block_h, block_w), lambda i, j: (i, j))
    out = pl.pallas_call(
        _delta_decode_kernel,
        grid=(hp // block_h, wp // block_w),
        in_specs=[tile, tile],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((hp, wp), jnp.float32),
        interpret=interpret,
    )(d, r)
    return out[:h, :w]


@functools.partial(
    jax.jit,
    static_argnames=("threshold", "block_h", "block_w", "interpret", "path"),
)
def delta_encode_batched(
    frames: jnp.ndarray,  # (B, H, W) f32
    refs: jnp.ndarray,  # (B, H, W) f32
    *,
    threshold: float = 0.0,
    block_h: int = DEFAULT_BLOCK_H,
    block_w: int = DEFAULT_BLOCK_W,
    interpret: bool = DEFAULT_INTERPRET,
    path: str = "grid",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """B clients' frames delta-encoded in ONE fused launch — the edge
    decodes/encodes batched exactly like it scores batched swarms.
    ``path="grid"`` extends the Pallas grid to (B, tiles_h, tiles_w);
    the tile body is shared with the unbatched kernel, so the B=1 slice
    is bit-for-bit ``delta_encode`` (mask over the padded tile grid,
    like the unbatched wrapper)."""
    if path == "vmap":
        fn = functools.partial(
            delta_encode,
            threshold=threshold,
            block_h=block_h,
            block_w=block_w,
            interpret=interpret,
        )
        return jax.vmap(fn)(frames, refs)
    if path != "grid":
        raise ValueError(f"unknown path {path!r}")
    b, h, w = frames.shape
    f = _pad_plane(frames.astype(jnp.float32), block_h, block_w)
    r = _pad_plane(refs.astype(jnp.float32), block_h, block_w)
    hp, wp = f.shape[1:]
    grid = (b, hp // block_h, wp // block_w)
    tile = pl.BlockSpec((1, block_h, block_w), lambda bi, i, j: (bi, i, j))
    cell = pl.BlockSpec((1, 1, 1), lambda bi, i, j: (bi, i, j))
    delta, mask = pl.pallas_call(
        functools.partial(_delta_encode_kernel, threshold=threshold),
        grid=grid,
        in_specs=[tile, tile],
        out_specs=[tile, cell],
        out_shape=[
            jax.ShapeDtypeStruct((b, hp, wp), jnp.int32),
            jax.ShapeDtypeStruct(grid, jnp.float32),
        ],
        interpret=interpret,
    )(f, r)
    return delta[:, :h, :w], mask


# ---------------------------------------------------------------------------
# entropy stage: per-tile significant-bit widths
# ---------------------------------------------------------------------------


def _sig_width_kernel(d_ref, w_out):
    """Significant-bit width of the tile's max |residual| word, read as
    uint32 — the side information ``ref.entropy_encode_words`` writes
    per tile.  ``(m >= 2**k)`` summed over k in [0, 32) counts exactly
    ``m.bit_length()`` without a loop-carried dependency (pure VPU
    compare + reduce, no integer log)."""
    words = d_ref[...].astype(jnp.uint32)
    m = jnp.max(words)
    thresholds = jnp.uint32(2) ** jnp.arange(32, dtype=jnp.uint32)
    width = jnp.sum((m >= thresholds).astype(jnp.int32))
    w_out[...] = jnp.full(w_out.shape, width, dtype=jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("block_h", "block_w", "interpret")
)
def significant_bit_widths(
    delta_bits: jnp.ndarray,  # (H, W) i32 XOR residual plane
    *,
    block_h: int = DEFAULT_BLOCK_H,
    block_w: int = DEFAULT_BLOCK_W,
    interpret: bool = DEFAULT_INTERPRET,
) -> jnp.ndarray:
    """Per-tile significant-bit widths of a residual plane:
    ``(ceil(H/bh), ceil(W/bw)) i32`` in [0, 32].  This is the entropy
    stage's device-side half — the coded size of each tile is
    ``ceil(tile_samples * width / 8) + 1`` bytes, so the host can price
    (and the byte packer emit) the stream without touching the full
    plane again.  Pad tiles are all-zero and report width 0."""
    d = _pad_plane(delta_bits.astype(jnp.int32), block_h, block_w)
    hp, wp = d.shape
    grid = (hp // block_h, wp // block_w)
    tile = pl.BlockSpec((block_h, block_w), lambda i, j: (i, j))
    cell = pl.BlockSpec((1, 1), lambda i, j: (i, j))
    return pl.pallas_call(
        _sig_width_kernel,
        grid=grid,
        in_specs=[tile],
        out_specs=cell,
        out_shape=jax.ShapeDtypeStruct(grid, jnp.int32),
        interpret=interpret,
    )(d)


@functools.partial(
    jax.jit, static_argnames=("block_h", "block_w", "interpret", "path")
)
def significant_bit_widths_batched(
    deltas: jnp.ndarray,  # (B, H, W) i32
    *,
    block_h: int = DEFAULT_BLOCK_H,
    block_w: int = DEFAULT_BLOCK_W,
    interpret: bool = DEFAULT_INTERPRET,
    path: str = "grid",
) -> jnp.ndarray:
    """B clients' residual planes width-scanned in one fused launch;
    the B=1 slice is bit-for-bit :func:`significant_bit_widths`."""
    if path == "vmap":
        fn = functools.partial(
            significant_bit_widths,
            block_h=block_h,
            block_w=block_w,
            interpret=interpret,
        )
        return jax.vmap(fn)(deltas)
    if path != "grid":
        raise ValueError(f"unknown path {path!r}")
    b = deltas.shape[0]
    d = _pad_plane(deltas.astype(jnp.int32), block_h, block_w)
    hp, wp = d.shape[1:]
    grid = (b, hp // block_h, wp // block_w)
    tile = pl.BlockSpec((1, block_h, block_w), lambda bi, i, j: (bi, i, j))
    cell = pl.BlockSpec((1, 1, 1), lambda bi, i, j: (bi, i, j))
    return pl.pallas_call(
        _sig_width_kernel,
        grid=grid,
        in_specs=[tile],
        out_specs=cell,
        out_shape=jax.ShapeDtypeStruct(grid, jnp.int32),
        interpret=interpret,
    )(d)


# ---------------------------------------------------------------------------
# quantize + pack
# ---------------------------------------------------------------------------


def _quantize_pack_kernel(
    x_ref, out_ref, *, lo: float, hi: float, bits: int, step: float
):
    ratio = 32 // bits
    x = jnp.clip(x_ref[...].astype(jnp.float32), lo, hi)
    codes = jnp.clip(
        jnp.round((x - lo) / step).astype(jnp.int32), 0, (1 << bits) - 1
    )
    shifts = jnp.arange(ratio, dtype=jnp.int32) * bits
    grouped = codes.reshape(
        codes.shape[:-1] + (codes.shape[-1] // ratio, ratio)
    )
    out_ref[...] = jnp.sum(grouped << shifts, axis=-1).astype(jnp.int32)


def _unpack_dequantize_kernel(
    w_ref, out_ref, *, lo: float, bits: int, step: float
):
    ratio = 32 // bits
    words = w_ref[...]
    shifts = jnp.arange(ratio, dtype=jnp.int32) * bits
    lanes = (words[..., None] >> shifts) & ((1 << bits) - 1)
    codes = lanes.reshape(words.shape[:-1] + (words.shape[-1] * ratio,))
    out_ref[...] = lo + codes.astype(jnp.float32) * step


@functools.partial(
    jax.jit,
    static_argnames=("lo", "hi", "bits", "block_h", "block_w", "interpret"),
)
def quantize_pack(
    depth: jnp.ndarray,  # (H, W) f32
    lo: float,
    hi: float,
    *,
    bits: int = 8,
    block_h: int = DEFAULT_BLOCK_H,
    block_w: int = DEFAULT_BLOCK_W,
    interpret: bool = DEFAULT_INTERPRET,
) -> jnp.ndarray:
    """Quantize depth to ``bits``-wide codes and bit-pack the lane axis
    into int32 words: returns ``(H, W * bits / 32) i32``.  Requires
    ``W`` divisible by ``32 // bits`` (depth planes are)."""
    ratio = _check_bits(bits)
    h, w = depth.shape
    if w % ratio:
        raise ValueError(f"width {w} not divisible by pack ratio {ratio}")
    x = _pad_plane(depth.astype(jnp.float32), block_h, block_w)
    hp, wp = x.shape
    step = quant_step(lo, hi, bits)
    tile = pl.BlockSpec((block_h, block_w), lambda i, j: (i, j))
    out_tile = pl.BlockSpec((block_h, block_w // ratio), lambda i, j: (i, j))
    words = pl.pallas_call(
        functools.partial(
            _quantize_pack_kernel, lo=lo, hi=hi, bits=bits, step=step
        ),
        grid=(hp // block_h, wp // block_w),
        in_specs=[tile],
        out_specs=out_tile,
        out_shape=jax.ShapeDtypeStruct((hp, wp // ratio), jnp.int32),
        interpret=interpret,
    )(x)
    return words[:h, : w // ratio]


@functools.partial(
    jax.jit,
    static_argnames=("lo", "hi", "bits", "block_h", "block_w", "interpret"),
)
def unpack_dequantize(
    words: jnp.ndarray,  # (H, W * bits / 32) i32
    lo: float,
    hi: float,
    *,
    bits: int = 8,
    block_h: int = DEFAULT_BLOCK_H,
    block_w: int = DEFAULT_BLOCK_W,
    interpret: bool = DEFAULT_INTERPRET,
) -> jnp.ndarray:
    """Inverse of :func:`quantize_pack`: ``(H, W) f32`` with per-pixel
    error <= ``ref.quant_step(lo, hi, bits) / 2`` inside [lo, hi]."""
    ratio = _check_bits(bits)
    h, wpk = words.shape
    step = quant_step(lo, hi, bits)
    pack_w = max(block_w // ratio, 1)
    x = _pad_plane(words, block_h, pack_w)
    hp, wpp = x.shape
    in_tile = pl.BlockSpec((block_h, pack_w), lambda i, j: (i, j))
    out_tile = pl.BlockSpec((block_h, pack_w * ratio), lambda i, j: (i, j))
    out = pl.pallas_call(
        functools.partial(
            _unpack_dequantize_kernel, lo=lo, bits=bits, step=step
        ),
        grid=(hp // block_h, wpp // pack_w),
        in_specs=[in_tile],
        out_specs=out_tile,
        out_shape=jax.ShapeDtypeStruct((hp, wpp * ratio), jnp.float32),
        interpret=interpret,
    )(x)
    return out[:h, : wpk * ratio]


@functools.partial(
    jax.jit,
    static_argnames=(
        "lo", "hi", "bits", "block_h", "block_w", "interpret", "path",
    ),
)
def quantize_pack_batched(
    depths: jnp.ndarray,  # (B, H, W) f32
    lo: float,
    hi: float,
    *,
    bits: int = 8,
    block_h: int = DEFAULT_BLOCK_H,
    block_w: int = DEFAULT_BLOCK_W,
    interpret: bool = DEFAULT_INTERPRET,
    path: str = "grid",
) -> jnp.ndarray:
    """Fused multi-client quantize+pack: ``(B, H, W * bits / 32) i32``;
    the B=1 slice is bit-for-bit :func:`quantize_pack`."""
    if path == "vmap":
        fn = functools.partial(
            quantize_pack,
            bits=bits,
            block_h=block_h,
            block_w=block_w,
            interpret=interpret,
        )
        return jax.vmap(lambda d: fn(d, lo, hi))(depths)
    if path != "grid":
        raise ValueError(f"unknown path {path!r}")
    ratio = _check_bits(bits)
    b, h, w = depths.shape
    if w % ratio:
        raise ValueError(f"width {w} not divisible by pack ratio {ratio}")
    x = _pad_plane(depths.astype(jnp.float32), block_h, block_w)
    hp, wp = x.shape[1:]
    step = quant_step(lo, hi, bits)
    tile = pl.BlockSpec((1, block_h, block_w), lambda bi, i, j: (bi, i, j))
    out_tile = pl.BlockSpec(
        (1, block_h, block_w // ratio), lambda bi, i, j: (bi, i, j)
    )
    words = pl.pallas_call(
        functools.partial(
            _quantize_pack_kernel, lo=lo, hi=hi, bits=bits, step=step
        ),
        grid=(b, hp // block_h, wp // block_w),
        in_specs=[tile],
        out_specs=out_tile,
        out_shape=jax.ShapeDtypeStruct((b, hp, wp // ratio), jnp.int32),
        interpret=interpret,
    )(x)
    return words[:, :h, : w // ratio]
