"""Payload codec: delta-frame RGBD compression for the offload loop.

The paper names "compressing the information flow" as the improvement
that matters once compute is offloaded — the RGBD frame crossing the
network dominates the loop.  This package spans the whole stack:

* ``codec.kernels`` — Pallas kernels (temporal delta with per-tile
  change masks, uniform depth quantization + bit-packing, batched
  variants sharing the fused-edge tile idiom);
* ``codec.ref`` — pure-jnp oracles and exact wire-format accounting;
* ``codec.model`` — the analytic :class:`~repro.codec.model.CodecModel`
  the cost engine prices transfer legs with (:data:`IDENTITY` is the
  bit-for-bit off-switch);
* ``codec.rate`` — per-client rate control in the fleet simulator
  (keyframe interval from scene motion, quantizer bits from link
  pressure, re-planning through the shared plan cache).
"""

from repro.codec.model import (  # noqa: F401
    BITS_RAW,
    CodecModel,
    IDENTITY,
)
from repro.codec.rate import (  # noqa: F401
    CodecConfig,
    RateController,
    calibrate_density_map,
    identity_config,
    motion_profile,
    sequence_motion,
)
