"""PartitionSpec rules for parameters, activations, inputs and caches.

Sharding philosophy (DESIGN.md §5):

* weights — Megatron tensor parallelism over the ``model`` axis: column-
  sharded up-projections (q/gate/up/w_x/w_z), row-sharded down-projections
  (o/down/w_out), vocab-sharded embeddings/head. MoE experts shard their
  leading E axis over ``model`` (expert parallelism).
* batch — over ``data`` (and ``pod`` when present): pure data parallelism;
  gradients all-reduce over those axes automatically.
* KV caches — batch over (pod, data); the sequence axis over ``model``
  (flash-decode style: each model shard owns a slice of the context and
  the softmax combines partial results), which works for every kv-head
  count including gemma's MQA kv=1 and scales to long_500k.
* anything whose dim is not divisible by the axis size falls back to
  replication — the rule table never produces an invalid spec.

All rules key on parameter-path *names*, so they apply equally to the
stacked (leading L axis) per-layer trees used by the scan assembly.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# (path-suffix name) -> spec for the LAST n dims of the array.
# None entries replicate that dim; axis names shard it.
_PARAM_RULES: Dict[str, Tuple[Optional[str], ...]] = {
    # embeddings / head
    "table": ("model", None),  # (V, d) vocab-sharded
    "w|lm_head": (None, "model"),
    # attention
    "w_q": (None, "model"),
    "w_k": (None, "model"),
    "w_v": (None, "model"),
    "w_o": ("model", None),
    # MLA
    "w_dq": (None, "model"),
    "w_uq": (None, "model"),
    "w_dkv": (None, None),  # latent stays replicated (it is the cache)
    "w_uk": (None, "model"),
    "w_uv": (None, "model"),
    # MLP
    "w_gate|mlp": (None, "model"),
    "w_up|mlp": (None, "model"),
    "w_down|mlp": ("model", None),
    # MoE (leading E axis -> expert parallelism)
    "router": (None, None),
    "w_gate|moe": ("model", None, None),
    "w_up|moe": ("model", None, None),
    "w_down|moe": ("model", None, None),
    # SSM
    "w_z": (None, "model"),
    "w_x": (None, "model"),
    "w_bc": (None, None),
    "w_dt": (None, None),
    "conv_x_w": (None, "model"),
    "conv_x_b": ("model",),
    "conv_bc_w": (None, None),
    "conv_bc_b": (None,),
    "a_log": (None,),
    "dt_bias": (None,),
    "d_skip": (None,),
    "w_out": ("model", None),
}


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for entry in path:
        if hasattr(entry, "key"):
            names.append(str(entry.key))
        elif hasattr(entry, "name"):
            names.append(str(entry.name))
    return tuple(names)


def _lookup_rule(names: Tuple[str, ...]) -> Optional[Tuple[Optional[str], ...]]:
    if not names:
        return None
    leaf = names[-1]
    context = set(names[:-1])
    # contextual rules first ("w_gate|moe" means leaf w_gate under a moe node)
    for key, rule in _PARAM_RULES.items():
        if "|" in key:
            leaf_name, ctx = key.split("|")
            if leaf == leaf_name and ctx in context:
                return rule
    return _PARAM_RULES.get(leaf)


def _respect_divisibility(
    spec: Tuple[Optional[str], ...], shape, axis_sizes: Dict[str, int]
) -> Tuple[Optional[str], ...]:
    out = []
    for dim, axis in zip(shape, spec):
        if axis is None:
            out.append(None)
        else:
            size = axis_sizes.get(axis, 1)
            out.append(axis if dim % size == 0 and dim >= size else None)
    return tuple(out)


def param_specs(params_tree: Any, mesh: Mesh) -> Any:
    """PartitionSpec pytree matching ``params_tree`` (arrays or SDS)."""
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    model_size = axis_sizes.get("model", 1)

    def spec_for(path, leaf):
        shape = leaf.shape
        names = _path_names(path)
        rule = _lookup_rule(names)
        if rule is None or len(shape) < len(rule):
            return P()
        # leading dims beyond the rule (the stacked L/G axes) replicate
        lead = (None,) * (len(shape) - len(rule))
        tail = _respect_divisibility(rule, shape[len(lead):], axis_sizes)
        # MoE fallback (§Perf iteration 1): when num_experts does not
        # divide the model axis (mixtral: E=8 on 16-way model), expert
        # parallelism over E is impossible and the bare rule silently
        # REPLICATED the experts — 256x redundant expert compute/memory.
        # Shard the per-expert d_ff dimension instead (Megatron within
        # expert): w_gate/w_up (E, d, f) -> (None, None, "model");
        # w_down (E, f, d) -> (None, "model", None).
        if (
            "moe" in set(names[:-1])
            and names[-1] in ("w_gate", "w_up", "w_down")
            and tail[0] is None
        ):
            ff_axis = 2 if names[-1] in ("w_gate", "w_up") else 1
            if shape[len(lead) + ff_axis] % model_size == 0:
                t = [None, None, None]
                t[ff_axis] = "model"
                tail = tuple(t)
        full = lead + tail
        if all(a is None for a in full):
            return P()
        return P(*full)

    return jax.tree_util.tree_map_with_path(spec_for, params_tree)


# ---------------------------------------------------------------------------
# Activations / inputs / caches
# ---------------------------------------------------------------------------


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def zero1_specs(p_specs: Any, params_tree: Any, mesh: Mesh) -> Any:
    """§Perf iteration 4 (ZeRO-1): optimizer-moment sharding.

    AdamW keeps two f32 moments per parameter; with params sharded only
    over `model`, the moments replicate over `data` and dominate training
    HBM (qwen3 train_4k: 62 GiB/chip). ZeRO-1 shards each moment's first
    `model`-free, data-divisible dimension over (pod, data); the update
    is elementwise so no extra collectives appear in the step — only the
    (already-required) gradient reduction changes shape from all-reduce
    to reduce-scatter + all-gather, which XLA derives automatically."""
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    baxes = batch_axes(mesh)
    total = int(np.prod([axis_sizes[a] for a in baxes])) if baxes else 1

    def upgrade(spec, leaf):
        dims = list(spec) + [None] * (len(leaf.shape) - len(spec))
        for i, (d, axis) in enumerate(zip(leaf.shape, dims)):
            if axis is None and d % total == 0 and d >= total:
                dims[i] = baxes
                return P(*dims)
        return spec

    flat_specs, treedef = jax.tree_util.tree_flatten(
        p_specs, is_leaf=lambda s: isinstance(s, P)
    )
    flat_leaves = treedef.flatten_up_to(params_tree)
    return treedef.unflatten(
        [upgrade(s, l) for s, l in zip(flat_specs, flat_leaves)]
    )


def _div(n: int, axes: Tuple[str, ...], axis_sizes: Dict[str, int]) -> bool:
    total = int(np.prod([axis_sizes[a] for a in axes])) if axes else 1
    return axes != () and n % total == 0 and n >= total


def input_specs_tree(inputs_tree: Any, mesh: Mesh) -> Any:
    """Shard the batch dim of every model input over (pod, data)."""
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    baxes = batch_axes(mesh)

    def spec_for(path, leaf):
        shape = leaf.shape
        names = _path_names(path)
        if names and names[-1] == "positions" and len(shape) == 3:
            # mrope (3, B, S)
            if _div(shape[1], baxes, axis_sizes):
                return P(None, baxes, None)
            return P()
        if not shape:
            return P()
        if _div(shape[0], baxes, axis_sizes):
            return P(*((baxes,) + (None,) * (len(shape) - 1)))
        return P()

    return jax.tree_util.tree_map_with_path(spec_for, inputs_tree)


def cache_specs(cache_tree: Any, mesh: Mesh) -> Any:
    """Decode-cache sharding: batch over (pod, data); the cache sequence
    axis over ``model`` (flash-decode); SSM states shard their head axis
    when divisible."""
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    baxes = batch_axes(mesh)

    def spec_for(path, leaf):
        shape = leaf.shape
        names = _path_names(path)
        leafname = names[-1] if names else ""
        if leafname == "position":
            return P()
        dims: list = [None] * len(shape)
        if leafname in ("attn_k", "attn_v", "shared_k", "shared_v",
                        "cross_k", "cross_v", "local_k", "local_v"):
            # (L_or_G, B, T, KV, D)
            if _div(shape[1], baxes, axis_sizes):
                dims[1] = baxes
            if shape[2] % axis_sizes.get("model", 1) == 0:
                dims[2] = "model"
        elif leafname in ("mla_c", "mla_rope"):
            # (L, B, T, R)
            if _div(shape[1], baxes, axis_sizes):
                dims[1] = baxes
            if shape[2] % axis_sizes.get("model", 1) == 0:
                dims[2] = "model"
        elif leafname in ("ssm_conv_x",):
            # (L, B, w, d_inner)
            if _div(shape[1], baxes, axis_sizes):
                dims[1] = baxes
            if shape[3] % axis_sizes.get("model", 1) == 0:
                dims[3] = "model"
        elif leafname in ("ssm_conv_bc",):
            if _div(shape[1], baxes, axis_sizes):
                dims[1] = baxes
        elif leafname == "ssm_state":
            # (L, B, H, P, N)
            if _div(shape[1], baxes, axis_sizes):
                dims[1] = baxes
            if shape[2] % axis_sizes.get("model", 1) == 0:
                dims[2] = "model"
        else:
            if shape and _div(shape[0], baxes, axis_sizes):
                dims[0] = baxes
        if all(d is None for d in dims):
            return P()
        return P(*dims)

    return jax.tree_util.tree_map_with_path(spec_for, cache_tree)


# ---------------------------------------------------------------------------
# The shard hook injected into model code
# ---------------------------------------------------------------------------

_ACTIVATION_RULES = {
    "activation": lambda b: P(b, None, None),
    "logits": lambda b: P(b, None, "model"),
    "decode_activation": lambda b: P(b, None, None),
    "decode_logits": lambda b: P(b, None, "model"),
    # MoE dispatch buffer (B, E, C, d): batch over (pod, data); experts
    # over model when divisible (expert parallelism) — checked at runtime
    # by make_shard_fn's divisibility guard.
    "moe_buf": lambda b: P(b, "model", None, None),
}


def make_shard_fn(mesh: Mesh):
    """Returns shard(x, name) applying with_sharding_constraint under the
    mesh; divisibility-checked so batch-1 decode just replicates."""
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    baxes = batch_axes(mesh)

    def shard(x, name):
        rule = _ACTIVATION_RULES.get(name)
        if rule is None or x.ndim < 2:
            return x
        spec = rule(baxes)
        dims = list(spec)
        # strip axes that do not divide
        for i, axis in enumerate(dims):
            if axis is None:
                continue
            axes = axis if isinstance(axis, tuple) else (axis,)
            total = int(np.prod([axis_sizes.get(a, 1) for a in axes]))
            if i >= x.ndim or x.shape[i] % total != 0 or x.shape[i] < total:
                dims[i] = None
        dims = dims[: x.ndim] + [None] * max(0, x.ndim - len(dims))
        if all(d is None for d in dims):
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*dims))
        )

    shard.mesh = mesh  # exposed for shard_map users (moe expert combine)
    return shard


def named(tree_specs: Any, mesh: Mesh) -> Any:
    """PartitionSpec pytree -> NamedSharding pytree."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda s: isinstance(s, P),
    )
