"""PartitionSpec rules for params, inputs, activations and caches."""

from repro.sharding import specs  # noqa: F401
