"""Pallas TPU kernel: fused particle-population render + E_D scoring.

This is the GPGPU hot spot the paper offloads: evaluating the PSO
population means rendering every particle's hand hypothesis to a depth
map and scoring it against the observation (Eq. 2). On CUDA the original
tracker rasterizes primitive meshes; on TPU we compute analytic sphere
depth per (particle, pixel, primitive) — dense FMA math with two
reductions (min over primitives, masked-sum over pixels), ideal for the
VPU/MXU with no scatter or z-buffer contention (DESIGN.md §2).

Tiling: grid = (N/BN particle tiles, P/BP pixel tiles). Each step loads
one particle tile's packed spheres (BN, S, 4), one pixel tile's rays
(BP, 3), observed depth and bbox mask (BP,), renders the (BN, BP) depth
tile via a min over S spheres, and accumulates the masked clamped-L1
partial sums into the output block (BN,) across the pixel-tile grid axis
(j == 0 initializes, j > 0 accumulates — the canonical Pallas reduction
pattern).

Edge batching: ``render_score_sums_batched`` adds a leading client axis
— grid (B, N/BN, P/BP) — so a whole gather-window's worth of client
swarms evaluates in one fused launch (the ``BatchingSlotServer`` event
the fleet simulator prices sublinearly).  Both kernels share the
``_score_tile`` math, and the batched grid keeps the pixel axis
innermost, so B=1 reproduces the unbatched kernel bit-for-bit.

VMEM budget at the default BN=8, BP=512, S=48, f32:
  spheres 8*48*4*4 B = 6 KiB, rays/depth/mask ~ 10 KiB,
  (BN, BP, S) intermediates ~= 3 * 8*512*48*4 B = 2.25 MiB  << 16 MiB.
The (BP, 3) x (BN*S, 3)^T dot-product is a skinny matmul; the bulk of the
work is VPU elementwise math over the (BN, BP, S) block, whose trailing
(BP, S) = (512, 48) axes map onto the (8, 128) vector lanes cleanly
(512 = 4*128, 48 = 6*8).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.camera import BACKGROUND_DEPTH
from repro.core.objective import CLAMP_T

DEFAULT_BLOCK_N = 8
DEFAULT_BLOCK_P = 512


def _score_tile(spheres, rays, d_o, msk, *, clamp_t, background):
    """Masked clamped-L1 partial sums of one (particle, pixel) tile.

    Shared between the unbatched and the batched (multi-client) kernels
    so the fused-batch math is the single-client math by construction.
    """
    c = spheres[:, :, :3]  # (BN, S, 3)
    r = spheres[:, :, 3]  # (BN, S)

    d2 = jnp.sum(rays * rays, axis=-1)  # (BP,)
    # dc[n, p, s] = <ray_p, center_{n,s}>  — skinny matmul on the MXU.
    dc = jax.lax.dot_general(
        rays,
        c,
        dimension_numbers=(((1,), (2,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (BP, BN, S)
    dc = jnp.transpose(dc, (1, 0, 2))  # (BN, BP, S)

    c2r2 = jnp.sum(c * c, axis=-1) - r * r  # (BN, S)
    disc = dc * dc - d2[None, :, None] * c2r2[:, None, :]  # (BN, BP, S)
    t = (dc - jnp.sqrt(jnp.maximum(disc, 0.0))) / d2[None, :, None]
    hit = (disc >= 0.0) & (t > 1e-4)
    t = jnp.where(hit, t, background)
    d_h = jnp.min(t, axis=-1)  # (BN, BP)

    err = jnp.minimum(jnp.abs(d_h - d_o[None, :]), clamp_t)
    return jnp.sum(err * msk[None, :], axis=-1)  # (BN,)


def _render_score_kernel(
    spheres_ref,  # (BN, S, 4) f32
    rays_ref,  # (BP, 3) f32
    depth_ref,  # (BP,) f32
    mask_ref,  # (BP,) f32 (0/1)
    out_ref,  # (BN,) f32 — masked clamped-L1 partial sums
    *,
    clamp_t: float,
    background: float,
):
    j = pl.program_id(1)
    partial = _score_tile(
        spheres_ref[...],
        rays_ref[...],
        depth_ref[...],
        mask_ref[...],
        clamp_t=clamp_t,
        background=background,
    )

    @pl.when(j == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(j != 0)
    def _acc():
        out_ref[...] = out_ref[...] + partial


def _render_score_batched_kernel(
    spheres_ref,  # (1, BN, S, 4) f32 — one client's particle tile
    rays_ref,  # (1, BP, 3) f32
    depth_ref,  # (1, BP) f32
    mask_ref,  # (1, BP) f32 (0/1)
    out_ref,  # (1, BN) f32
    *,
    clamp_t: float,
    background: float,
):
    j = pl.program_id(2)
    partial = _score_tile(
        spheres_ref[...][0],
        rays_ref[...][0],
        depth_ref[...][0],
        mask_ref[...][0],
        clamp_t=clamp_t,
        background=background,
    )

    @pl.when(j == 0)
    def _init():
        out_ref[...] = partial[None]

    @pl.when(j != 0)
    def _acc():
        out_ref[...] = out_ref[...] + partial[None]


def render_score_sums(
    spheres: jnp.ndarray,  # (N, S, 4)
    rays: jnp.ndarray,  # (P, 3)
    depth_obs: jnp.ndarray,  # (P,)
    mask: jnp.ndarray,  # (P,) float32 or bool
    *,
    block_n: int = DEFAULT_BLOCK_N,
    block_p: int = DEFAULT_BLOCK_P,
    clamp_t: float = CLAMP_T,
    background: float = BACKGROUND_DEPTH,
    interpret: bool = True,
) -> jnp.ndarray:
    """Unnormalized masked score sums per particle, shape (N,).

    Shapes must already be padded: N % block_n == 0, P % block_p == 0
    (``ops.render_score`` handles padding/normalization).
    ``interpret=True`` executes the kernel body in Python on CPU — this
    container has no TPU; on real hardware pass ``interpret=False``.
    """
    n, s, _ = spheres.shape
    p = rays.shape[0]
    assert n % block_n == 0, (n, block_n)
    assert p % block_p == 0, (p, block_p)
    mask = mask.astype(jnp.float32)

    grid = (n // block_n, p // block_p)
    kernel = functools.partial(
        _render_score_kernel, clamp_t=clamp_t, background=background
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, s, 4), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((block_p, 3), lambda i, j: (j, 0)),
            pl.BlockSpec((block_p,), lambda i, j: (j,)),
            pl.BlockSpec((block_p,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(spheres.astype(jnp.float32), rays.astype(jnp.float32),
      depth_obs.astype(jnp.float32), mask)


def render_score_sums_batched(
    spheres: jnp.ndarray,  # (B, N, S, 4) — one swarm per client
    rays: jnp.ndarray,  # (B, P, 3)
    depth_obs: jnp.ndarray,  # (B, P)
    mask: jnp.ndarray,  # (B, P) float32 or bool
    *,
    block_n: int = DEFAULT_BLOCK_N,
    block_p: int = DEFAULT_BLOCK_P,
    clamp_t: float = CLAMP_T,
    background: float = BACKGROUND_DEPTH,
    interpret: bool = True,
) -> jnp.ndarray:
    """Fused multi-client population evaluation: score sums, (B, N).

    One Pallas launch with grid (B, N/block_n, P/block_p) — B clients'
    swarms evaluate together, which is the edge-batching amortization
    the fleet simulator's ``BatchServiceModel`` prices.  The tile math
    is ``_score_tile``, shared with the unbatched kernel, and the grid
    iterates the pixel axis innermost, so each (client, particle-tile)
    accumulates partial sums in exactly the unbatched order: the B = 1
    case is bit-for-bit ``render_score_sums``.
    """
    bsz, n, s, _ = spheres.shape
    p = rays.shape[1]
    assert n % block_n == 0, (n, block_n)
    assert p % block_p == 0, (p, block_p)
    mask = mask.astype(jnp.float32)

    grid = (bsz, n // block_n, p // block_p)
    kernel = functools.partial(
        _render_score_batched_kernel, clamp_t=clamp_t, background=background
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_n, s, 4), lambda b, i, j: (b, i, 0, 0)),
            pl.BlockSpec((1, block_p, 3), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_p), lambda b, i, j: (b, j)),
            pl.BlockSpec((1, block_p), lambda b, i, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda b, i, j: (b, i)),
        out_shape=jax.ShapeDtypeStruct((bsz, n), jnp.float32),
        interpret=interpret,
    )(spheres.astype(jnp.float32), rays.astype(jnp.float32),
      depth_obs.astype(jnp.float32), mask)
