"""Pure-jnp oracle for the pso_update kernel (mirrors pso.swarm_step's
velocity/position math exactly)."""

from __future__ import annotations

import jax.numpy as jnp


def pso_update(
    x, v, pbest, gbest, r1, r2, lo, hi,
    *, inertia: float, cognitive: float, social: float, velocity_clip: float,
):
    x = x.astype(jnp.float32)
    v = v.astype(jnp.float32)
    vel = (
        inertia * v
        + cognitive * r1.astype(jnp.float32) * (pbest.astype(jnp.float32) - x)
        + social * r2.astype(jnp.float32) * (gbest[None].astype(jnp.float32) - x)
    )
    vmax = velocity_clip * (hi - lo)
    vel = jnp.clip(vel, -vmax[None], vmax[None])
    pos = jnp.clip(x + vel, lo[None], hi[None])
    return pos, vel


def pso_update_batched(
    x, v, pbest, gbest, r1, r2, lo, hi,
    *, inertia: float, cognitive: float, social: float, velocity_clip: float,
):
    """Batched oracle: x/v/pbest/r1/r2 (B, N, D), gbest (B, D), lo/hi
    (D,) or (B, D).  Same math as the unbatched oracle per swarm."""
    b, _, d = x.shape
    x = x.astype(jnp.float32)
    v = v.astype(jnp.float32)
    lo = jnp.broadcast_to(lo.astype(jnp.float32), (b, d))[:, None, :]
    hi = jnp.broadcast_to(hi.astype(jnp.float32), (b, d))[:, None, :]
    vel = (
        inertia * v
        + cognitive * r1.astype(jnp.float32) * (pbest.astype(jnp.float32) - x)
        + social * r2.astype(jnp.float32) * (gbest[:, None].astype(jnp.float32) - x)
    )
    vmax = velocity_clip * (hi - lo)
    vel = jnp.clip(vel, -vmax, vmax)
    pos = jnp.clip(x + vel, lo, hi)
    return pos, vel
