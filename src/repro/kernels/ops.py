"""Jit'd public wrapper around the render_score Pallas kernel.

Handles shape padding (particles to block_n, pixels to block_p), mask
normalization, and the interpret-mode switch. This is the drop-in
replacement for ``objective.batched_objective``'s vmapped evaluation —
the tracker selects it with ``TrackerConfig(use_kernel=True)``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.objective import CLAMP_T
from repro.kernels import render_score as _kernel

DEFAULT_INTERPRET = True  # CPU container; flip on real TPU.


def _pad_to(x: jnp.ndarray, size: int, axis: int, value=0.0) -> jnp.ndarray:
    pad = size - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(
    jax.jit,
    static_argnames=("block_n", "block_p", "clamp_t", "interpret"),
)
def render_score(
    spheres: jnp.ndarray,  # (N, S, 4)
    rays: jnp.ndarray,  # (P, 3)
    depth_obs: jnp.ndarray,  # (P,)
    mask: jnp.ndarray,  # (P,)
    *,
    block_n: int = _kernel.DEFAULT_BLOCK_N,
    block_p: int = _kernel.DEFAULT_BLOCK_P,
    clamp_t: float = CLAMP_T,
    interpret: bool = DEFAULT_INTERPRET,
) -> jnp.ndarray:
    """Normalized E_D per particle, shape (N,). Matches ref.render_score."""
    n, s, _ = spheres.shape
    p = rays.shape[0]
    n_pad = -(-n // block_n) * block_n
    p_pad = -(-p // block_p) * block_p

    spheres_p = _pad_to(spheres, n_pad, axis=0)
    # Padding rays must be well-formed directions (d_z = 1) so the kernel
    # never divides by |d|^2 = 0; their mask is 0 so they contribute
    # nothing to the score.
    if p_pad != p:
        pad_rays = jnp.zeros((p_pad - p, 3), dtype=rays.dtype).at[:, 2].set(1.0)
        rays_p = jnp.concatenate([rays, pad_rays], axis=0)
    else:
        rays_p = rays
    depth_p = _pad_to(depth_obs, p_pad, axis=0)
    mask_p = _pad_to(mask.astype(jnp.float32), p_pad, axis=0)

    sums = _kernel.render_score_sums(
        spheres_p,
        rays_p,
        depth_p,
        mask_p,
        block_n=block_n,
        block_p=block_p,
        clamp_t=clamp_t,
        interpret=interpret,
    )[:n]
    denom = jnp.maximum(jnp.sum(mask.astype(jnp.float32)), 1.0)
    return sums / denom
