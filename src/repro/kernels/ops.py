"""Jit'd public wrapper around the render_score Pallas kernel.

Handles shape padding (particles to block_n, pixels to block_p), mask
normalization, and the interpret-mode switch. This is the drop-in
replacement for ``objective.batched_objective``'s vmapped evaluation —
the tracker selects it with ``TrackerConfig(use_kernel=True)``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.objective import CLAMP_T
from repro.kernels import render_score as _kernel

DEFAULT_INTERPRET = True  # CPU container; flip on real TPU.


def _pad_to(x: jnp.ndarray, size: int, axis: int, value=0.0) -> jnp.ndarray:
    pad = size - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def _pad_render_inputs(spheres, rays, depth_obs, mask, block_n, block_p):
    """Pad particles/pixels to block multiples, rank-agnostically: the
    particle and pixel axes are located from the trailing dims, so the
    unbatched (N, …)/(P, …) and batched (B, N, …)/(B, P, …) wrappers
    share one copy of the padding rules."""
    n_axis = spheres.ndim - 3  # (…, N, S, 4)
    p_axis = rays.ndim - 2  # (…, P, 3)
    n_pad = -(-spheres.shape[n_axis] // block_n) * block_n
    p_pad = -(-rays.shape[p_axis] // block_p) * block_p

    spheres_p = _pad_to(spheres, n_pad, axis=n_axis)
    # Padding rays must be well-formed directions (d_z = 1) so the kernel
    # never divides by |d|^2 = 0; their mask is 0 so they score nothing.
    if p_pad != rays.shape[p_axis]:
        pad_shape = rays.shape[:p_axis] + (p_pad - rays.shape[p_axis], 3)
        pad_rays = jnp.zeros(pad_shape, dtype=rays.dtype).at[..., 2].set(1.0)
        rays_p = jnp.concatenate([rays, pad_rays], axis=p_axis)
    else:
        rays_p = rays
    depth_p = _pad_to(depth_obs, p_pad, axis=p_axis)
    mask_p = _pad_to(mask.astype(jnp.float32), p_pad, axis=p_axis)
    return spheres_p, rays_p, depth_p, mask_p


@functools.partial(
    jax.jit,
    static_argnames=("block_n", "block_p", "clamp_t", "interpret"),
)
def render_score(
    spheres: jnp.ndarray,  # (N, S, 4)
    rays: jnp.ndarray,  # (P, 3)
    depth_obs: jnp.ndarray,  # (P,)
    mask: jnp.ndarray,  # (P,)
    *,
    block_n: int = _kernel.DEFAULT_BLOCK_N,
    block_p: int = _kernel.DEFAULT_BLOCK_P,
    clamp_t: float = CLAMP_T,
    interpret: bool = DEFAULT_INTERPRET,
) -> jnp.ndarray:
    """Normalized E_D per particle, shape (N,). Matches ref.render_score."""
    n = spheres.shape[0]
    spheres_p, rays_p, depth_p, mask_p = _pad_render_inputs(
        spheres, rays, depth_obs, mask, block_n, block_p
    )
    sums = _kernel.render_score_sums(
        spheres_p,
        rays_p,
        depth_p,
        mask_p,
        block_n=block_n,
        block_p=block_p,
        clamp_t=clamp_t,
        interpret=interpret,
    )[:n]
    denom = jnp.maximum(jnp.sum(mask.astype(jnp.float32)), 1.0)
    return sums / denom


@functools.partial(
    jax.jit,
    static_argnames=("block_n", "block_p", "clamp_t", "interpret"),
)
def render_score_batched(
    spheres: jnp.ndarray,  # (B, N, S, 4)
    rays: jnp.ndarray,  # (B, P, 3)
    depth_obs: jnp.ndarray,  # (B, P)
    mask: jnp.ndarray,  # (B, P)
    *,
    block_n: int = _kernel.DEFAULT_BLOCK_N,
    block_p: int = _kernel.DEFAULT_BLOCK_P,
    clamp_t: float = CLAMP_T,
    interpret: bool = DEFAULT_INTERPRET,
) -> jnp.ndarray:
    """Normalized E_D per (client, particle), shape (B, N) — B clients'
    populations scored in ONE fused kernel launch (edge batching).

    Per-client normalization: each row divides by its own bbox pixel
    count, so every slice matches ``render_score`` on that client alone.
    """
    n = spheres.shape[1]
    spheres_p, rays_p, depth_p, mask_p = _pad_render_inputs(
        spheres, rays, depth_obs, mask, block_n, block_p
    )
    sums = _kernel.render_score_sums_batched(
        spheres_p,
        rays_p,
        depth_p,
        mask_p,
        block_n=block_n,
        block_p=block_p,
        clamp_t=clamp_t,
        interpret=interpret,
    )[:, :n]
    denom = jnp.maximum(
        jnp.sum(mask.astype(jnp.float32), axis=1, keepdims=True), 1.0
    )
    return sums / denom
