"""Pure-jnp oracle for the render_score kernel.

Re-derives the exact quantity the kernel computes from the reference
objective implementation in ``repro.core.objective`` — the tests assert
``ops.render_score`` (Pallas, interpret=True) == ``ref.render_score``
(pure jnp) across shape/dtype sweeps.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.camera import BACKGROUND_DEPTH
from repro.core.objective import CLAMP_T, sphere_depth


def render_score_sums(
    spheres: jnp.ndarray,  # (N, S, 4)
    rays: jnp.ndarray,  # (P, 3)
    depth_obs: jnp.ndarray,  # (P,)
    mask: jnp.ndarray,  # (P,)
    *,
    clamp_t: float = CLAMP_T,
    background: float = BACKGROUND_DEPTH,
) -> jnp.ndarray:
    """Unnormalized masked clamped-L1 sums per particle, shape (N,)."""
    del background  # sphere_depth uses the module constant

    mask = mask.astype(jnp.float32)

    def one(sph):
        d_h = sphere_depth(rays, sph)  # (P,)
        err = jnp.minimum(jnp.abs(d_h - depth_obs), clamp_t)
        return jnp.sum(err * mask)

    return jax.vmap(one)(spheres.astype(jnp.float32))


def render_score(
    spheres: jnp.ndarray,
    rays: jnp.ndarray,
    depth_obs: jnp.ndarray,
    mask: jnp.ndarray,
    *,
    clamp_t: float = CLAMP_T,
) -> jnp.ndarray:
    """Normalized E_D per particle (mean over bbox pixels), shape (N,)."""
    sums = render_score_sums(spheres, rays, depth_obs, mask, clamp_t=clamp_t)
    denom = jnp.maximum(jnp.sum(mask.astype(jnp.float32)), 1.0)
    return sums / denom
