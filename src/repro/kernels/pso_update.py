"""Pallas TPU kernel: fused PSO swarm update (velocity + position).

The second GPGPU component of the paper's per-frame loop (the first —
population evaluation — is kernels/render_score.py): the Clerc–Kennedy
update

    v' = w v + c1 r1 (pbest - x) + c2 r2 (gbest - x)
    v' = clip(v', -vclip*span, +vclip*span)
    x' = clip(x + v', lo, hi)

is pure elementwise VPU math over the (particles, dims) plane. Fusing it
keeps the whole swarm state in VMEM for one pass instead of ~8 HBM
round-trips of (N, D) intermediates.

Tiling: grid over particle tiles; each step loads (BN, D) blocks of
x/v/pbest/r1/r2 plus the broadcast (D,) rows (gbest, lo, hi). D = 27 is
padded to 32 by ops.py — within a lane-width of the (8, 128) vector
registers at the particle counts PSO uses.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 8


def _pso_update_kernel(
    x_ref, v_ref, pb_ref, r1_ref, r2_ref,  # (BN, D)
    gb_ref, lo_ref, hi_ref,  # (1, D) broadcast rows
    x_out_ref, v_out_ref,  # (BN, D)
    *,
    inertia: float,
    cognitive: float,
    social: float,
    velocity_clip: float,
):
    x = x_ref[...]
    v = v_ref[...]
    pb = pb_ref[...]
    r1 = r1_ref[...]
    r2 = r2_ref[...]
    gb = gb_ref[...]  # (1, D) broadcasts over particles
    lo = lo_ref[...]
    hi = hi_ref[...]

    vel = (
        inertia * v
        + cognitive * r1 * (pb - x)
        + social * r2 * (gb - x)
    )
    vmax = velocity_clip * (hi - lo)
    vel = jnp.clip(vel, -vmax, vmax)
    pos = jnp.clip(x + vel, lo, hi)
    x_out_ref[...] = pos
    v_out_ref[...] = vel


def pso_update(
    x: jnp.ndarray,  # (N, D) padded: N % block_n == 0
    v: jnp.ndarray,
    pbest: jnp.ndarray,
    gbest: jnp.ndarray,  # (D,)
    r1: jnp.ndarray,
    r2: jnp.ndarray,
    lo: jnp.ndarray,  # (D,)
    hi: jnp.ndarray,
    *,
    inertia: float,
    cognitive: float,
    social: float,
    velocity_clip: float,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = True,
):
    """Returns (new_positions, new_velocities), both (N, D) f32."""
    n, d = x.shape
    assert n % block_n == 0, (n, block_n)
    kernel = functools.partial(
        _pso_update_kernel,
        inertia=inertia,
        cognitive=cognitive,
        social=social,
        velocity_clip=velocity_clip,
    )
    row = lambda a: a.reshape(1, d).astype(jnp.float32)
    grid = (n // block_n,)
    tile = pl.BlockSpec((block_n, d), lambda i: (i, 0))
    brow = pl.BlockSpec((1, d), lambda i: (0, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[tile, tile, tile, tile, tile, brow, brow, brow],
        out_specs=[tile, tile],
        out_shape=[
            jax.ShapeDtypeStruct((n, d), jnp.float32),
            jax.ShapeDtypeStruct((n, d), jnp.float32),
        ],
        interpret=interpret,
    )(
        x.astype(jnp.float32), v.astype(jnp.float32),
        pbest.astype(jnp.float32), r1.astype(jnp.float32),
        r2.astype(jnp.float32), row(gbest), row(lo), row(hi),
    )
