"""Pallas TPU kernel: fused PSO swarm update (velocity + position).

The second GPGPU component of the paper's per-frame loop (the first —
population evaluation — is kernels/render_score.py): the Clerc–Kennedy
update

    v' = w v + c1 r1 (pbest - x) + c2 r2 (gbest - x)
    v' = clip(v', -vclip*span, +vclip*span)
    x' = clip(x + v', lo, hi)

is pure elementwise VPU math over the (particles, dims) plane. Fusing it
keeps the whole swarm state in VMEM for one pass instead of ~8 HBM
round-trips of (N, D) intermediates.

Tiling: grid over particle tiles; each step loads (BN, D) blocks of
x/v/pbest/r1/r2 plus the broadcast (D,) rows (gbest, lo, hi). D = 27 is
padded to 32 by ops.py — within a lane-width of the (8, 128) vector
registers at the particle counts PSO uses.

Edge batching: ``pso_update_batched`` grows a leading batch axis so B
clients' swarms update in ONE fused launch — the amortization the fleet
simulator's ``BatchingSlotServer`` models.  The fast path extends the
Pallas grid to (B, N/BN) over (1, BN, D) blocks; since the update is
pure elementwise math with row broadcasts, the *same* kernel body
serves both ranks, so the B = 1 slice is bit-for-bit the unbatched
kernel (golden test in tests/test_batching.py).  A ``path="vmap"``
fallback vmaps the unbatched call for comparison/debugging.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 8


def _pso_update_kernel(
    x_ref, v_ref, pb_ref, r1_ref, r2_ref,  # (BN, D)
    gb_ref, lo_ref, hi_ref,  # (1, D) broadcast rows
    x_out_ref, v_out_ref,  # (BN, D)
    *,
    inertia: float,
    cognitive: float,
    social: float,
    velocity_clip: float,
):
    x = x_ref[...]
    v = v_ref[...]
    pb = pb_ref[...]
    r1 = r1_ref[...]
    r2 = r2_ref[...]
    gb = gb_ref[...]  # (1, D) broadcasts over particles
    lo = lo_ref[...]
    hi = hi_ref[...]

    vel = (
        inertia * v
        + cognitive * r1 * (pb - x)
        + social * r2 * (gb - x)
    )
    vmax = velocity_clip * (hi - lo)
    vel = jnp.clip(vel, -vmax, vmax)
    pos = jnp.clip(x + vel, lo, hi)
    x_out_ref[...] = pos
    v_out_ref[...] = vel


def pso_update(
    x: jnp.ndarray,  # (N, D) padded: N % block_n == 0
    v: jnp.ndarray,
    pbest: jnp.ndarray,
    gbest: jnp.ndarray,  # (D,)
    r1: jnp.ndarray,
    r2: jnp.ndarray,
    lo: jnp.ndarray,  # (D,)
    hi: jnp.ndarray,
    *,
    inertia: float,
    cognitive: float,
    social: float,
    velocity_clip: float,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = True,
):
    """Returns (new_positions, new_velocities), both (N, D) f32."""
    n, d = x.shape
    assert n % block_n == 0, (n, block_n)
    kernel = functools.partial(
        _pso_update_kernel,
        inertia=inertia,
        cognitive=cognitive,
        social=social,
        velocity_clip=velocity_clip,
    )
    row = lambda a: a.reshape(1, d).astype(jnp.float32)
    grid = (n // block_n,)
    tile = pl.BlockSpec((block_n, d), lambda i: (i, 0))
    brow = pl.BlockSpec((1, d), lambda i: (0, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[tile, tile, tile, tile, tile, brow, brow, brow],
        out_specs=[tile, tile],
        out_shape=[
            jax.ShapeDtypeStruct((n, d), jnp.float32),
            jax.ShapeDtypeStruct((n, d), jnp.float32),
        ],
        interpret=interpret,
    )(
        x.astype(jnp.float32), v.astype(jnp.float32),
        pbest.astype(jnp.float32), r1.astype(jnp.float32),
        r2.astype(jnp.float32), row(gbest), row(lo), row(hi),
    )


def pso_update_batched(
    x: jnp.ndarray,  # (B, N, D) padded: N % block_n == 0
    v: jnp.ndarray,
    pbest: jnp.ndarray,
    gbest: jnp.ndarray,  # (B, D) — one global best per swarm
    r1: jnp.ndarray,
    r2: jnp.ndarray,
    lo: jnp.ndarray,  # (D,) or (B, D) — shared model bounds
    hi: jnp.ndarray,
    *,
    inertia: float,
    cognitive: float,
    social: float,
    velocity_clip: float,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = True,
    path: str = "grid",
):
    """Fused multi-swarm update: (new_positions, new_velocities), (B, N, D).

    ``path="grid"`` runs ONE Pallas launch with grid (B, N/block_n) —
    the edge-batching fast path; ``path="vmap"`` vmaps the unbatched
    kernel (one launch per swarm under interpret mode) as the
    reshape-free reference implementation.
    """
    b, n, d = x.shape
    assert n % block_n == 0, (n, block_n)
    brow_arr = lambda a: jnp.broadcast_to(
        a.astype(jnp.float32), (b, d)
    ).reshape(b, 1, d)
    if path == "vmap":
        fn = functools.partial(
            pso_update,
            inertia=inertia,
            cognitive=cognitive,
            social=social,
            velocity_clip=velocity_clip,
            block_n=block_n,
            interpret=interpret,
        )
        lo_b = jnp.broadcast_to(lo.astype(jnp.float32), (b, d))
        hi_b = jnp.broadcast_to(hi.astype(jnp.float32), (b, d))
        return jax.vmap(fn)(x, v, pbest, gbest, r1, r2, lo_b, hi_b)
    if path != "grid":
        raise ValueError(f"unknown path {path!r}")
    kernel = functools.partial(
        _pso_update_kernel,
        inertia=inertia,
        cognitive=cognitive,
        social=social,
        velocity_clip=velocity_clip,
    )
    grid = (b, n // block_n)
    # the kernel body is rank-agnostic elementwise math, so the batched
    # (1, BN, D) tiles reuse it unchanged — B=1 is the unbatched kernel
    tile = pl.BlockSpec((1, block_n, d), lambda bi, i: (bi, i, 0))
    brow = pl.BlockSpec((1, 1, d), lambda bi, i: (bi, 0, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[tile, tile, tile, tile, tile, brow, brow, brow],
        out_specs=[tile, tile],
        out_shape=[
            jax.ShapeDtypeStruct((b, n, d), jnp.float32),
            jax.ShapeDtypeStruct((b, n, d), jnp.float32),
        ],
        interpret=interpret,
    )(
        x.astype(jnp.float32), v.astype(jnp.float32),
        pbest.astype(jnp.float32), r1.astype(jnp.float32),
        r2.astype(jnp.float32),
        gbest.astype(jnp.float32).reshape(b, 1, d),
        brow_arr(lo), brow_arr(hi),
    )
