"""Pallas TPU kernels for the paper's GPGPU hot spots.

* ``render_score`` — fused particle render + E_D scoring (the population
  evaluation the paper offloads). ``ops`` is the jit'd wrapper, ``ref``
  the pure-jnp oracle.
* ``pso_update`` — fused Clerc-Kennedy swarm velocity/position update
  (``pso_ref`` oracle).

Both validate under interpret=True on this CPU container and target TPU
VMEM tiling via explicit BlockSpecs.
"""
