"""Pallas TPU kernels for the paper's GPGPU hot spots.

* ``render_score`` — fused particle render + E_D scoring (the population
  evaluation the paper offloads). ``ops`` is the jit'd wrapper, ``ref``
  the pure-jnp oracle.
* ``pso_update`` — fused Clerc-Kennedy swarm velocity/position update
  (``pso_ref`` oracle).

Both kernels also ship *batched* variants with a leading client axis
(``render_score_sums_batched`` / ``pso_update_batched``) — one fused
launch evaluates B clients' swarms, the edge-batching amortization the
fleet simulator (``repro.cluster``) prices with its
``BatchServiceModel``.  B=1 reproduces the unbatched kernels
bit-for-bit (tests/test_batching.py).

All validate under interpret=True on this CPU container and target TPU
VMEM tiling via explicit BlockSpecs.
"""
