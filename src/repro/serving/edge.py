"""Tiered edge serving for LLM decode — the paper's technique generalized.

An autoregressive decode step has the same structure as the tracker's
per-frame optimization: a serially-dependent step with a small recurrent
payload (the sampled token + per-step cache delta) and a heavy compute
core (the layer stack). This module builds the byte/FLOP-annotated
``StagedComputation`` of one decode step for any assigned architecture
and lets the Local/Forced/Auto policies place its stages across any
tier topology — the paper's thin client -> edge server (TPU pod) pair,
or a device -> edge GPU -> cloud TPU chain
(sim.hardware.three_tier_environment), exactly as the paper places the
hand tracker's four stages across laptop and server.

The per-arch state payload is where the assigned architectures differ
most interestingly (DESIGN.md §Arch-applicability):

* mamba2/zamba2  — O(1) recurrent state: the paper's future-work wish.
* minicpm3 (MLA) — 288 f/token cache delta vs 5120 for equivalent GQA.
* gemma (MQA)    — single KV head: smallest delta among GQA archs.
* mixtral/qwen3  — expert weights pin the heavy stage to the server.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from repro.configs.base import ArchConfig
from repro.core import offload
from repro.core.offload import EnvironmentLike, PlanReport, Policy
from repro.core.stages import CLIENT, DataItem, Stage, StagedComputation


def _bytes_per_param(cfg: ArchConfig) -> int:
    return 2 if cfg.dtype == "bfloat16" else 4


def decode_flops(cfg: ArchConfig, batch: int) -> float:
    """~2 * N_active FLOPs per token per sequence (matmul-dominated),
    plus attention's cache-linear term handled separately by caller."""
    return 2.0 * cfg.active_param_count() * batch


def cache_delta_bytes(cfg: ArchConfig, batch: int) -> int:
    """Bytes of per-step recurrent payload if the step crosses machines."""
    bpe = _bytes_per_param(cfg)
    if cfg.arch_type in ("ssm", "hybrid"):
        s = cfg.ssm
        d_inner = s.expand * cfg.d_model
        n_heads = d_inner // s.head_dim
        per_layer = (
            (s.d_conv - 1) * (d_inner + 2 * s.n_groups * s.d_state) * bpe
            + n_heads * s.head_dim * s.d_state * 4
        )
        total = cfg.num_layers * per_layer
        if cfg.arch_type == "hybrid":
            g = cfg.num_layers // cfg.shared_attn_every
            total += g * 2 * cfg.num_kv_heads * cfg.resolved_head_dim * bpe
        return int(total * batch)
    if cfg.attention == "mla":
        per_tok = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
        return int(cfg.num_layers * per_tok * bpe * batch)
    per_tok = 2 * cfg.num_kv_heads * cfg.resolved_head_dim
    return int(cfg.num_layers * per_tok * bpe * batch)


def build_decode_staged(
    cfg: ArchConfig, batch: int = 1, num_stage_groups: int = 4
) -> StagedComputation:
    """One decode step as `num_stage_groups` offloadable layer groups plus
    embed and head stages (the LLM analogue of the tracker's 4 steps)."""
    bpe = _bytes_per_param(cfg)
    d = cfg.d_model
    act_bytes = batch * d * bpe
    token_bytes = batch * 4
    layer_flops = decode_flops(cfg, batch) / max(num_stage_groups, 1)
    delta_bytes = cache_delta_bytes(cfg, batch) // max(num_stage_groups, 1)

    sources = (
        DataItem("token", token_bytes, CLIENT),
        DataItem("rng", 8, CLIENT),
    )
    stages: List[Stage] = [
        Stage(
            name="embed",
            flops=2.0 * batch * d,
            inputs=("token",),
            outputs=(DataItem("h_0", act_bytes),),
            parallel_fraction=0.5,
        )
    ]
    for g in range(num_stage_groups):
        # NOTE: each group's KV/state delta stays resident where the group
        # runs (residency tracking handles it); the hidden activation is
        # what crosses a placement boundary.
        stages.append(
            Stage(
                name=f"layers_{g}",
                flops=layer_flops,
                inputs=(f"h_{g}",),
                outputs=(DataItem(f"h_{g + 1}", act_bytes),),
                parallel_fraction=0.99,
            )
        )
    head_flops = 2.0 * batch * d * cfg.vocab_size
    stages.append(
        Stage(
            name="head_sample",
            flops=head_flops,
            inputs=(f"h_{num_stage_groups}", "rng"),
            outputs=(DataItem("next_token", token_bytes),),
            parallel_fraction=0.95,
        )
    )
    comp = StagedComputation(
        name=f"{cfg.name}_decode_step",
        sources=sources,
        stages=tuple(stages),
        results=("next_token",),
    )
    comp.validate()
    return comp


@dataclasses.dataclass
class EdgePlan:
    arch: str
    policy: Policy
    report: PlanReport
    tokens_per_second: float


def plan_decode(
    cfg: ArchConfig,
    env: EnvironmentLike,
    policy: Policy = Policy.AUTO,
    batch: int = 1,
    granularity: str = "single_step",
    num_stage_groups: int = 4,
) -> EdgePlan:
    """Place one decode step across the tiers of ``env`` (the two-tier
    ``Environment`` shim or a full ``Topology`` chain/star).

    ``num_stage_groups`` controls pipeline depth: the decode chain is a
    linear StagedComputation, so at depths where the plan lattice
    (k_tiers ** n_stages) outgrows exhaustive search AUTO switches to
    the exact O(n*k^2) chain-DP planner."""
    comp = build_decode_staged(cfg, batch, num_stage_groups)
    comp = comp.fused() if granularity == "single_step" else comp
    rep = offload.plan(comp, env, policy)
    return EdgePlan(
        arch=cfg.name,
        policy=policy,
        report=rep,
        tokens_per_second=batch / rep.total_time,
    )


def compare_archs(
    cfgs: List[ArchConfig], env: EnvironmentLike, batch: int = 1
) -> Dict[str, Dict[str, float]]:
    """Token rates for Local/Forced/Auto per arch — the LLM Fig. 5."""
    out = {}
    for cfg in cfgs:
        row = {}
        for pol in (Policy.LOCAL, Policy.FORCED, Policy.AUTO):
            try:
                row[pol.value] = plan_decode(cfg, env, pol, batch).tokens_per_second
            except ValueError:
                row[pol.value] = float("nan")
        row["state_bytes"] = float(cache_delta_bytes(cfg, 1))
        out[cfg.name] = row
    return out
