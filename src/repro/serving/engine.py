"""Batched serving engine: prefill + decode loop over request batches.

The serial dependency the paper analyzes for frames (Fig. 3 category A)
is exactly the autoregressive decode dependency: token t+1 cannot be
issued before token t returns. The engine therefore exposes the same
stage structure the hand tracker does, and ``serving/edge.py`` applies
the identical offload machinery to it.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import transformer


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (P,) int32
    max_new_tokens: int = 32


@dataclasses.dataclass
class Completion:
    uid: int
    tokens: np.ndarray  # (N,) generated ids
    prefill_len: int


def _pad_prompts(prompts: List[np.ndarray], pad_id: int = 0):
    maxlen = max(p.shape[0] for p in prompts)
    batch = np.full((len(prompts), maxlen), pad_id, np.int32)
    for i, p in enumerate(prompts):
        batch[i, maxlen - p.shape[0] :] = p  # left-pad: ends align
    return jnp.asarray(batch), maxlen


class Engine:
    """Static-batch serving engine (continuous batching is a planned
    extension; the dry-run's decode_32k shape models the steady state of
    a full 128-sequence batch)."""

    def __init__(
        self,
        cfg: ArchConfig,
        params,
        max_len: int = 512,
        temperature: float = 0.0,
        seed: int = 0,
    ):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)

        self._prefill = jax.jit(
            lambda p, toks: transformer.prefill(cfg, p, toks, max_len=max_len)
        )
        self._decode = jax.jit(
            lambda p, cache, toks: transformer.decode_step(cfg, p, cache, toks)
        )

    def _sample(self, logits: jnp.ndarray) -> jnp.ndarray:
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(
            sub, logits / self.temperature, axis=-1
        ).astype(jnp.int32)

    def generate(self, requests: List[Request]) -> List[Completion]:
        prompts = [r.prompt for r in requests]
        tokens, plen = _pad_prompts(prompts)
        logits, cache = self._prefill(self.params, tokens)
        steps = max(r.max_new_tokens for r in requests)
        out = []
        cur = self._sample(logits)
        generated = [cur]
        for _ in range(steps - 1):
            step_logits, cache = self._decode(
                self.params, cache, cur[:, None]
            )
            cur = self._sample(step_logits[:, 0])
            generated.append(cur)
        gen = np.asarray(jnp.stack(generated, axis=1))  # (B, steps)
        for i, r in enumerate(requests):
            out.append(
                Completion(
                    uid=r.uid,
                    tokens=gen[i, : r.max_new_tokens],
                    prefill_len=plen,
                )
            )
        return out
