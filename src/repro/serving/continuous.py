"""Continuous batching: requests join/leave a running decode batch.

The static ``Engine`` prefils one batch and decodes it to completion —
fine for benchmarking, wasteful for serving (short requests hold their
slot while long ones finish). This engine keeps a fixed number of decode
*slots*; whenever one frees, the next queued request is prefilled alone
and its cache rows are spliced into the batched cache at that slot
(every cache tensor carries batch at a fixed axis, and ``Cache.position``
is already per-sequence, so mixed-progress decoding works unchanged).

Serial-dependency note (paper Fig. 3B): the paper points out that
offloading architectures shine when requests are independent — "all
newly acquired frames could be submitted directly to the computing
resources without any stall". Continuous batching is exactly that
structure for LLM serving: across-request parallelism with per-request
serial decode.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import transformer
from repro.serving.engine import Completion, Request

# cache fields whose batch dim sits at axis 1 (leading axis is layers)
_BATCH_AXIS1 = (
    "attn_k", "attn_v", "mla_c", "mla_rope", "ssm_conv_x", "ssm_conv_bc",
    "ssm_state", "shared_k", "shared_v", "cross_k", "cross_v",
    "local_k", "local_v",
)


def _splice_slot(batch_cache, one_cache, slot: int):
    """Write a single-sequence cache into batch slot `slot`."""
    updates = {}
    for name in batch_cache._fields:
        big = getattr(batch_cache, name)
        small = getattr(one_cache, name)
        if big is None:
            continue
        if name == "position":
            updates[name] = big.at[slot].set(small[0])
        elif name in _BATCH_AXIS1:
            updates[name] = jax.lax.dynamic_update_slice(
                big, small.astype(big.dtype),
                (0, slot) + (0,) * (big.ndim - 2),
            )
    return batch_cache._replace(**updates)


@dataclasses.dataclass
class _Slot:
    uid: Optional[int] = None
    remaining: int = 0
    generated: Optional[List[int]] = None
    prefill_len: int = 0

    @property
    def free(self) -> bool:
        return self.uid is None


class ContinuousEngine:
    """Fixed-slot continuous batching engine (greedy decoding)."""

    def __init__(self, cfg: ArchConfig, params, num_slots: int = 4,
                 max_len: int = 512):
        self.cfg = cfg
        self.params = params
        self.num_slots = num_slots
        self.max_len = max_len
        self.queue: Deque[Request] = deque()
        self.slots = [_Slot() for _ in range(num_slots)]
        self.cache = transformer.init_cache(cfg, num_slots, max_len)
        self.next_tokens = jnp.zeros((num_slots, 1), jnp.int32)
        self.completions: List[Completion] = []

        self._prefill1 = jax.jit(
            lambda p, toks: transformer.prefill(cfg, p, toks, max_len=max_len)
        )
        self._decode = jax.jit(
            lambda p, cache, toks: transformer.decode_step(cfg, p, cache, toks)
        )

    # ------------------------------------------------------------------
    def submit(self, request: Request) -> None:
        self.queue.append(request)

    def _admit(self) -> None:
        for slot_idx, slot in enumerate(self.slots):
            if not slot.free or not self.queue:
                continue
            req = self.queue.popleft()
            toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
            logits, one_cache = self._prefill1(self.params, toks)
            first = int(jnp.argmax(logits[0]))
            self.cache = _splice_slot(self.cache, one_cache, slot_idx)
            self.next_tokens = self.next_tokens.at[slot_idx, 0].set(first)
            self.slots[slot_idx] = _Slot(
                uid=req.uid,
                remaining=req.max_new_tokens - 1,
                generated=[first],
                prefill_len=int(toks.shape[1]),
            )
            if self.slots[slot_idx].remaining == 0:
                self._finish(slot_idx)

    def _finish(self, slot_idx: int) -> None:
        slot = self.slots[slot_idx]
        self.completions.append(
            Completion(
                uid=slot.uid,
                tokens=np.asarray(slot.generated, np.int32),
                prefill_len=slot.prefill_len,
            )
        )
        self.slots[slot_idx] = _Slot()

    def step(self) -> int:
        """Admit + one decode step for every active slot. Returns the
        number of still-active slots."""
        self._admit()
        active = [i for i, s in enumerate(self.slots) if not s.free]
        if not active:
            return 0
        logits, self.cache = self._decode(
            self.params, self.cache, self.next_tokens
        )
        nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
        self.next_tokens = nxt[:, None]
        for i in active:
            slot = self.slots[i]
            slot.generated.append(int(nxt[i]))
            slot.remaining -= 1
            if slot.remaining <= 0:
                self._finish(i)
        return sum(0 if s.free else 1 for s in self.slots)

    def run_to_completion(self, max_steps: int = 10_000) -> List[Completion]:
        steps = 0
        while (self.queue or any(not s.free for s in self.slots)) and steps < max_steps:
            self.step()
            steps += 1
        out = sorted(self.completions, key=lambda c: c.uid)
        return out
