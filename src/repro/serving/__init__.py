"""Serving runtime: batched engine, continuous batching, tiered edge
placement."""

from repro.serving import continuous, edge, engine  # noqa: F401
