"""AdamW optimizer (pure JAX, pytree-native, sharding-transparent).

Moments live in f32 regardless of param dtype (bf16 training stability);
their sharding follows the parameter sharding one-to-one, so the
optimizer adds no collectives beyond the gradient all-reduce that data
parallelism already implies.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def init(params: Any) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree_util.tree_map(zeros, params),
        nu=jax.tree_util.tree_map(zeros, params),
    )


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def update(
    cfg: AdamWConfig,
    grads: Any,
    state: AdamWState,
    params: Any,
    lr_scale: jnp.ndarray = 1.0,
) -> Tuple[Any, AdamWState, dict]:
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * clip
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * g * g
        m_hat = m_new / bc1
        v_hat = v_new / bc2
        delta = m_hat / (jnp.sqrt(v_hat) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {"grad_norm": gnorm}


def cosine_schedule(
    base_steps: int, warmup: int = 100, floor: float = 0.1
) -> Callable[[jnp.ndarray], jnp.ndarray]:
    def scale(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / max(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) / max(base_steps - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return warm * cos

    return scale
