"""gemma3-4b — dense decoder with 5:1 local:global attention, 128k ctx.

[hf:google/gemma-3-1b-pt family, 4b point] 34L, d_model=2560, 8H (GQA
kv=4, head_dim=256), d_ff=10240 (GeGLU), vocab=262144. Attention pattern:
period 6 = five sliding-window (1024) layers then one global layer —
which is what qualifies it for long_500k (global layers are linear per
decoded token; local layers bound the cache).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-4b",
    arch_type="dense",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    source="hf:google/gemma-3-1b-pt",
    attention="gqa",
    rope_theta=1e6,
    sliding_window=1024,
    attn_pattern_period=6,
    global_layers_per_period=1,
    mlp="geglu",
    scale_embeddings=True,
    tie_embeddings=True,
    max_seq_len=524288,
)
