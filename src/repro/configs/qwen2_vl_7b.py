"""qwen2-vl-7b — VLM language backbone with M-RoPE.

[arXiv:2409.12191] 28L, d_model=3584, 28H (GQA kv=4), d_ff=18944,
vocab=152064. Multimodal rotary position embedding: head_dim=128 split
into (16, 24, 24) frequency sections carrying (temporal, height, width)
positions. The ViT/dynamic-resolution frontend is the mandated STUB —
input_specs() provides patch embeddings.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b",
    arch_type="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    source="arXiv:2409.12191",
    attention="gqa",
    mrope=True,
    mrope_sections=(16, 24, 24),
    rope_theta=1e6,
    mlp="swiglu",
    modality="vision",
    frontend_tokens=256,  # image patch embeddings per request
    max_seq_len=32768,
)
