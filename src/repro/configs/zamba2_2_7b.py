"""zamba2-2.7b — hybrid Mamba2 backbone + shared attention blocks.

[arXiv:2411.15242] 54 Mamba2 layers, d_model=2560; a single *shared*
transformer block (32H GQA kv=32, d_ff=10240) is applied every 6 SSM
layers, reusing one set of weights (the Zamba trick: attention quality at
~1/9th of the attention parameter cost). ssm_state=64.
"""

from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    arch_type="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    source="arXiv:2411.15242",
    attention="gqa",
    mlp="geglu",
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, chunk_size=64),
    shared_attn_every=6,
    max_seq_len=524288,
)
