"""mamba2-370m — pure SSM (state-space duality / SSD).

[arXiv:2405.21060] 48L, d_model=1024, attention-free, vocab=50280,
ssm_state=128. d_inner = 2*d_model = 2048, head_dim 64 => 32 SSD heads.
Constant-size recurrent state: the paper's future-work wish (no growing
inter-step payload) realized — see DESIGN.md §Arch-applicability.
"""

from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-370m",
    arch_type="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    source="arXiv:2405.21060",
    attention="none",
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk_size=64),
    tie_embeddings=True,
    max_seq_len=1048576,
)
