"""starcoder2-3b — dense code model, GQA + RoPE + 4k sliding window.

[arXiv:2402.19173] 30L, d_model=3072, 24H (GQA kv=2), d_ff=12288,
vocab=49152, layernorm + plain GeLU MLP, sliding_window=4096 on every
layer (which is what qualifies it for the long_500k decode shape).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-3b",
    arch_type="dense",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    d_ff=12288,
    vocab_size=49152,
    source="arXiv:2402.19173",
    attention="gqa",
    rope_theta=1e5,
    sliding_window=4096,
    mlp="gelu",
    norm="layernorm",
    max_seq_len=524288,
)
