"""The four assigned input shapes and per-(arch, shape) input specs.

``input_specs(cfg, shape, ...)`` returns ``jax.ShapeDtypeStruct`` pytrees
for every model input — weak-type-correct, shardable, with NO device
allocation — which is what the multi-pod dry-run lowers against.

Decode shapes lower ``serve_step`` (ONE new token against a KV cache of
``seq_len``), not ``train_step``; ``long_500k`` only applies to archs
whose ``supports_long_context()`` is True (DESIGN.md lists the skips).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = InputShape("train_4k", 4096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32768, 128, "decode")
LONG_500K = InputShape("long_500k", 524288, 1, "decode")

ALL_SHAPES = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}


def applicable(cfg: ArchConfig, shape: InputShape) -> bool:
    """Whether (arch, shape) is in the assigned 40-combo matrix minus the
    documented skips (long_500k for pure full-attention archs)."""
    if shape.name == "long_500k" and not cfg.supports_long_context():
        return False
    return True


def token_inputs(
    cfg: ArchConfig, shape: InputShape, dtype=jnp.int32
) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for the model inputs of one step."""
    b, s = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct

    if shape.kind == "train":
        specs = {
            "tokens": sds((b, s), dtype),
            "targets": sds((b, s), dtype),
            # 1.0 for real tokens; lets the loss mask padding.
            "loss_mask": sds((b, s), jnp.float32),
        }
        if cfg.mrope:
            # positions cover frontend embeddings + text stream
            specs["positions"] = sds((3, b, s + cfg.frontend_tokens), dtype)
        if cfg.modality in ("audio", "vision"):
            specs["frontend_embeds"] = sds(
                (b, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16
            )
        if cfg.encoder_layers:
            specs["encoder_tokens"] = sds(
                (b, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16
            )
            del specs["frontend_embeds"]
        return specs

    if shape.kind == "prefill":
        specs = {"tokens": sds((b, s), dtype)}
        if cfg.mrope:
            specs["positions"] = sds((3, b, s + cfg.frontend_tokens), dtype)
        if cfg.encoder_layers:
            specs["encoder_tokens"] = sds(
                (b, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16
            )
        elif cfg.modality in ("audio", "vision"):
            specs["frontend_embeds"] = sds(
                (b, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16
            )
        return specs

    # decode: one new token per sequence + the running position
    specs = {
        "tokens": sds((b, 1), dtype),
        "positions": sds((3, b, 1), dtype) if cfg.mrope else sds((b,), dtype),
    }
    return specs


def concrete_token_inputs(cfg: ArchConfig, shape: InputShape, seed: int = 0):
    """Small *materialized* inputs for smoke tests (reduced configs)."""
    rng = np.random.default_rng(seed)
    specs = token_inputs(cfg, shape)
    out = {}
    for k, s in specs.items():
        if np.issubdtype(s.dtype, np.integer):
            hi = max(cfg.vocab_size - 1, 2) if "token" in k else max(s.shape[-1], 2)
            out[k] = jnp.asarray(
                rng.integers(0, hi, size=s.shape), dtype=s.dtype
            )
        else:
            out[k] = jnp.asarray(
                rng.normal(0, 0.02, size=s.shape), dtype=s.dtype
            )
    return out
