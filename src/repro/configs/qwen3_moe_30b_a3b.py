"""qwen3-moe-30b-a3b — fine-grained sparse MoE, 128 experts top-8.

[hf:Qwen/Qwen3-30B-A3B] 48L, d_model=2048, 32H (GQA kv=4, head_dim=128),
per-expert d_ff=768, vocab=151936. 30B total / ~3B active parameters.
Full attention => long_500k skipped; the 128-way expert dispatch makes
this the most collective-bound assigned pair (see EXPERIMENTS.md).
"""

from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    arch_type="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=0,
    vocab_size=151936,
    source="hf:Qwen/Qwen3-30B-A3B",
    attention="gqa",
    rope_theta=1e6,
    mlp="swiglu",
    moe=MoEConfig(num_experts=128, experts_per_token=8, d_ff=768),
    max_seq_len=32768,
)
