"""Architecture registry — ``--arch <id>`` resolution."""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ArchConfig

_MODULES = {
    "zamba2-2.7b": "repro.configs.zamba2_2_7b",
    "minicpm3-4b": "repro.configs.minicpm3_4b",
    "seamless-m4t-large-v2": "repro.configs.seamless_m4t_large_v2",
    "mamba2-370m": "repro.configs.mamba2_370m",
    "qwen2-vl-7b": "repro.configs.qwen2_vl_7b",
    "starcoder2-3b": "repro.configs.starcoder2_3b",
    "gemma-2b": "repro.configs.gemma_2b",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
    "gemma3-4b": "repro.configs.gemma3_4b",
}


def list_archs() -> List[str]:
    return sorted(_MODULES)


def get(name: str) -> ArchConfig:
    if name.endswith("-reduced"):
        return get(name[: -len("-reduced")]).reduced()
    if name not in _MODULES:
        raise KeyError(
            f"unknown arch {name!r}; available: {', '.join(list_archs())}"
        )
    mod = importlib.import_module(_MODULES[name])
    return mod.CONFIG


def all_configs() -> Dict[str, ArchConfig]:
    return {name: get(name) for name in list_archs()}
