"""gemma-2b — dense decoder, GeGLU, head_dim=256, MQA.

[arXiv:2403.08295] 18L, d_model=2048, 8H with a SINGLE kv head (MQA),
head_dim=256 (so q/k/v are wider than d_model), d_ff=16384 (GeGLU),
vocab=256000, tied embeddings. Pure full attention => long_500k skipped.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma-2b",
    arch_type="dense",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    source="arXiv:2403.08295",
    attention="gqa",
    mlp="geglu",
    scale_embeddings=True,
    tie_embeddings=True,
    max_seq_len=8192,
)
