"""seamless-m4t-large-v2 — speech/text encoder-decoder backbone.

[arXiv:2308.11596] 24L encoder + 24L decoder, d_model=1024, 16H (kv=16),
d_ff=8192, vocab=256206. The modality frontend (mel-spectrogram +
conformer feature extractor) is the mandated STUB: input_specs() provides
precomputed frame embeddings; we implement the transformer backbone with
cross-attention decode.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    arch_type="audio",
    num_layers=24,
    encoder_layers=24,
    cross_attention=True,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    source="arXiv:2308.11596",
    attention="gqa",
    mlp="gelu",
    norm="layernorm",
    modality="audio",
    frontend_tokens=1024,  # encoded audio frames per utterance
    max_seq_len=4096,
)
