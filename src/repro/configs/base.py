"""Architecture configuration schema.

One ``ArchConfig`` instance per assigned architecture (see
``repro.configs.<id>``). The schema spans six architecture families
(dense / MoE / SSM / hybrid / audio enc-dec / VLM); fields irrelevant to
a family stay at their zero defaults.

``reduced()`` produces the mandated smoke variant (<=2 layers,
d_model <= 512, <= 4 experts) used by the per-arch CPU smoke tests; the
full configs are exercised only through the dry-run (ShapeDtypeStruct,
no allocation).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

FULL_ATTENTION = 0  # sliding_window value meaning "no window"


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention dims (DeepSeek-V2 style, as used by
    MiniCPM3): queries/keys factor through low-rank latents; RoPE is
    carried by decoupled per-head dims so the latent stays cacheable."""

    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD block hyperparameters."""

    d_state: int
    head_dim: int = 64
    n_groups: int = 1
    d_conv: int = 4
    chunk_size: int = 64
    expand: int = 2  # d_inner = expand * d_model


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    experts_per_token: int
    d_ff: int  # per-expert hidden dim
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01  # load-balance loss weight
    impl: str = "dropping"  # "dropping" (GShard-style) | "dense"


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int  # 0 => attention-free
    num_kv_heads: int
    d_ff: int  # dense-MLP hidden dim (0 for pure-SSM / pure-MoE)
    vocab_size: int
    head_dim: int = 0  # 0 => d_model // num_heads
    source: str = ""  # citation: arXiv id / model card

    # --- attention flavour ---
    attention: str = "gqa"  # gqa | mla | none
    rope_theta: float = 1e4
    sliding_window: int = FULL_ATTENTION  # applies to *windowed* layers
    # Layer-pattern period for mixed local/global attention. 0 = uniform.
    # gemma3: pattern period 6, one global layer per period (5:1).
    attn_pattern_period: int = 0
    global_layers_per_period: int = 0
    mrope: bool = False  # Qwen2-VL multimodal rotary (t/h/w sections)
    mrope_sections: Tuple[int, ...] = (16, 24, 24)
    mla: Optional[MLAConfig] = None
    logit_softcap: float = 0.0  # gemma-style attn/final softcapping

    # --- MLP flavour ---
    mlp: str = "swiglu"  # swiglu | geglu | gelu
    # --- MoE ---
    moe: Optional[MoEConfig] = None
    # --- SSM / hybrid ---
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): a shared attention block applied every
    # ``shared_attn_every`` SSM layers, reusing ONE set of weights.
    shared_attn_every: int = 0

    # --- encoder-decoder (seamless) ---
    encoder_layers: int = 0
    cross_attention: bool = False

    # --- modality frontend (stub: embeddings arrive precomputed) ---
    modality: str = "text"  # text | audio | vision
    frontend_tokens: int = 0  # embeddings prepended per request

    # --- misc ---
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    scale_embeddings: bool = False  # multiply embeddings by sqrt(d_model)
    tie_embeddings: bool = False
    max_seq_len: int = 131072
    dtype: str = "bfloat16"

    # ---------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.num_heads:
            return self.d_model // self.num_heads
        return 0

    @property
    def is_decoder_only(self) -> bool:
        return self.encoder_layers == 0

    def supports_long_context(self) -> bool:
        """True iff attention cost per decoded token is sub-quadratic in
        context (SSM/hybrid state or a bounded attention window on all
        non-global layers). Pure full-attention archs return False and
        long_500k is skipped for them (DESIGN.md §Arch-applicability)."""
        if self.arch_type in ("ssm", "hybrid"):
            return True
        if self.sliding_window != FULL_ATTENTION:
            return True
        return False

    def layer_window_sizes(self) -> Tuple[int, ...]:
        """Per-layer attention window (0 = full/global), honoring the
        local:global pattern. For uniform archs this is constant."""
        if self.num_heads == 0:
            return ()
        n = self.num_layers
        if self.attn_pattern_period <= 0:
            return (self.sliding_window,) * n
        period = self.attn_pattern_period
        n_global = self.global_layers_per_period
        out = []
        for i in range(n):
            # the last `n_global` layers of each period are global
            is_global = (i % period) >= (period - n_global)
            out.append(FULL_ATTENTION if is_global else self.sliding_window)
        return tuple(out)

    def param_count(self) -> int:
        """Analytic parameter count N (embedding included once)."""
        d = self.d_model
        hd = self.resolved_head_dim
        n_attn = 0
        n_mlp = 0
        n_ssm = 0
        attn_layers = self.num_layers if self.num_heads else 0
        ssm_layers = 0
        if self.arch_type == "hybrid":
            ssm_layers = self.num_layers
            attn_layers = 1  # one shared block
        elif self.arch_type == "ssm":
            ssm_layers = self.num_layers
            attn_layers = 0
        if attn_layers:
            if self.mla is not None:
                m = self.mla
                per = (
                    d * m.q_lora_rank
                    + m.q_lora_rank
                    * self.num_heads
                    * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                    + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    + m.kv_lora_rank
                    * self.num_heads
                    * (m.qk_nope_head_dim + m.v_head_dim)
                    + self.num_heads * m.v_head_dim * d
                )
            else:
                per = (
                    d * self.num_heads * hd  # Q
                    + 2 * d * self.num_kv_heads * hd  # K, V
                    + self.num_heads * hd * d  # O
                )
            if self.cross_attention:
                per *= 2  # self + cross attention in decoder blocks
            n_attn = attn_layers * per
        if self.moe is not None:
            n_mlp = self.num_layers * (
                self.moe.num_experts * 3 * d * self.moe.d_ff
                + d * self.moe.num_experts  # router
            )
        elif self.d_ff:
            mults = 3 if self.mlp in ("swiglu", "geglu") else 2
            # hybrid: the MLP lives only in the single shared block
            mlp_layers = 1 if self.arch_type == "hybrid" else self.num_layers
            n_mlp = mlp_layers * mults * d * self.d_ff
        if self.ssm is not None:
            s = self.ssm
            d_inner = s.expand * d
            n_heads_ssm = d_inner // s.head_dim
            per = (
                d * (2 * d_inner + 2 * s.n_groups * s.d_state + n_heads_ssm)
                + (d_inner + 2 * s.n_groups * s.d_state) * s.d_conv
                + d_inner * d  # out proj
                + 2 * n_heads_ssm  # A, D
            )
            n_ssm = ssm_layers * per
        n_embed = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        n_enc = 0
        if self.encoder_layers:
            per_enc = 4 * d * d + 2 * d * self.d_ff
            n_enc = self.encoder_layers * per_enc
        n_norms = (self.num_layers * 2 + 1) * d
        return int(n_attn + n_mlp + n_ssm + n_embed + n_enc + n_norms)

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: top-k experts only)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        moe_all = self.num_layers * self.moe.num_experts * 3 * self.d_model * self.moe.d_ff
        moe_active = (
            self.num_layers
            * self.moe.experts_per_token
            * 3
            * self.d_model
            * self.moe.d_ff
        )
        return int(full - moe_all + moe_active)

    # ---------------------------------------------------------------
    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: same family, tiny dims."""
        d_model = min(self.d_model, 256)
        num_heads = min(self.num_heads, 4) if self.num_heads else 0
        kv = min(self.num_kv_heads, max(1, num_heads // 2)) if num_heads else 0
        changes = dict(
            name=self.name + "-reduced",
            num_layers=2,
            d_model=d_model,
            num_heads=num_heads,
            num_kv_heads=kv,
            head_dim=64 if num_heads else 0,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            encoder_layers=min(self.encoder_layers, 2),
            max_seq_len=512,
            attn_pattern_period=2 if self.attn_pattern_period else 0,
            global_layers_per_period=1 if self.attn_pattern_period else 0,
            sliding_window=min(self.sliding_window, 64)
            if self.sliding_window
            else 0,
            shared_attn_every=2 if self.shared_attn_every else 0,
            frontend_tokens=min(self.frontend_tokens, 16),
            dtype="float32",
        )
        if self.mrope:
            # rescale the (t, h, w) frequency sections to the reduced
            # head_dim, preserving the 1:1.5:1.5 proportions
            half = 64 // 2
            scale = half / sum(self.mrope_sections)
            secs = [int(s * scale) for s in self.mrope_sections]
            secs[0] += half - sum(secs)
            changes["mrope_sections"] = tuple(secs)
        if self.moe is not None:
            changes["moe"] = dataclasses.replace(
                self.moe,
                num_experts=4,
                experts_per_token=min(2, self.moe.experts_per_token),
                d_ff=128,
                impl="dense",
            )
        if self.ssm is not None:
            changes["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, head_dim=32, chunk_size=32
            )
        if self.mla is not None:
            changes["mla"] = MLAConfig(
                q_lora_rank=64,
                kv_lora_rank=32,
                qk_nope_head_dim=32,
                qk_rope_head_dim=16,
                v_head_dim=32,
            )
        return dataclasses.replace(self, **changes)
