"""minicpm3-4b — dense decoder with Multi-head Latent Attention.

[hf:openbmb/MiniCPM3-4B] 62L, d_model=2560, 40 heads (kv=40), d_ff=6400,
vocab=73448. MLA compresses the KV cache into a 256-d latent (+32-d
decoupled RoPE key), the property DESIGN.md flags as the best offload
case: tiny per-step state crossing the network.
"""

from repro.configs.base import ArchConfig, MLAConfig

CONFIG = ArchConfig(
    name="minicpm3-4b",
    arch_type="dense",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    source="hf:openbmb/MiniCPM3-4B",
    attention="mla",
    mla=MLAConfig(
        q_lora_rank=768,
        kv_lora_rank=256,
        qk_nope_head_dim=64,
        qk_rope_head_dim=32,
        v_head_dim=64,
    ),
    mlp="swiglu",
    max_seq_len=32768,
)
