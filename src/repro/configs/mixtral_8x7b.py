"""mixtral-8x7b — sparse MoE decoder, 8 experts top-2, SWA.

[arXiv:2401.04088] 32L, d_model=4096, 32H (GQA kv=8), per-expert
d_ff=14336, vocab=32000, sliding window 4096 on all layers. Every MLP is
replaced by an 8-expert top-2 router — the expert-parallel all-to-all is
this arch's dominant collective.
"""

from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b",
    arch_type="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=0,
    vocab_size=32000,
    source="arXiv:2401.04088",
    attention="gqa",
    sliding_window=4096,
    mlp="swiglu",
    moe=MoEConfig(num_experts=8, experts_per_token=2, d_ff=14336),
    max_seq_len=524288,
)
