"""Architecture configs: 10 assigned architectures + input shapes."""

from repro.configs import registry, shapes  # noqa: F401
from repro.configs.base import ArchConfig  # noqa: F401
