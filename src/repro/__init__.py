"""repro — Edge-Offloaded Real-Time Generative Inference in JAX.

Reproduction + extension of "On the Feasibility of Real-Time 3D Hand
Tracking using Edge GPGPU Acceleration" (CS.DC 2018). See README.md and
DESIGN.md.

Subpackage map:
  core       the paper's contribution (tracker, PSO, offload engine)
  kernels    Pallas TPU kernel for the population evaluation hot spot
  net, sim   links, tiers, real-time clock, deployment simulator
  models     the six architecture families (scan-over-layers JAX)
  configs    10 assigned architectures + input shapes + registry
  sharding   PartitionSpec rules for the production meshes
  serving    batched / continuous engines, tiered edge placement
  optim, data, checkpoint   training substrate
  launch     meshes, multi-pod dry-run, train/serve drivers
  roofline   HLO cost walker + report generation

NOTE: importing this package never initializes jax device state; the
512-device override is exclusively repro.launch.dryrun's.
"""

__version__ = "1.0.0"
