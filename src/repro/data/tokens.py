"""Synthetic LM token pipeline.

Deterministic, seekable, shard-aware synthetic corpus: a mixture of
Zipfian unigrams and repeated n-gram motifs so a ~100M model trained a
few hundred steps shows a *visibly decreasing* loss (pure-uniform tokens
would bottom out at ln V immediately), which is what the end-to-end
training example validates.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenPipelineConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    motif_len: int = 8
    num_motifs: int = 64
    motif_prob: float = 0.5


class TokenPipeline:
    """Iterator of {tokens, targets, loss_mask} host batches."""

    def __init__(self, cfg: TokenPipelineConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # Zipfian unigram table (bounded resampling)
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self._probs = (ranks ** -cfg.zipf_a) / np.sum(ranks ** -cfg.zipf_a)
        self._motifs = rng.integers(
            0, v, size=(cfg.num_motifs, cfg.motif_len), dtype=np.int32
        )
        self._step = 0

    def _sample_batch(self, rng: np.random.Generator) -> np.ndarray:
        cfg = self.cfg
        b, s = cfg.global_batch, cfg.seq_len
        out = rng.choice(
            cfg.vocab_size, size=(b, s + 1), p=self._probs
        ).astype(np.int32)
        # overwrite random spans with motifs (predictable structure)
        n_spans = int(s * cfg.motif_prob / cfg.motif_len)
        for i in range(b):
            starts = rng.integers(0, s + 1 - cfg.motif_len, size=n_spans)
            picks = rng.integers(0, cfg.num_motifs, size=n_spans)
            for st, pk in zip(starts, picks):
                out[i, st : st + cfg.motif_len] = self._motifs[pk]
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(self.cfg.seed + 1000 + self._step)
        self._step += 1
        seq = self._sample_batch(rng)
        return {
            "tokens": seq[:, :-1],
            "targets": seq[:, 1:],
            "loss_mask": np.ones(
                (self.cfg.global_batch, self.cfg.seq_len), np.float32
            ),
        }
