"""Synthetic RGBD hand-motion sequences (the "pre-recorded video").

The paper evaluates against a pre-recorded sequence "depicting various
challenging hand movements" so that all runs see identical input. We
generate the analogous artifact: a deterministic ground-truth trajectory
of hand configurations (smooth position sweeps, wrist rotation, finger
curls, plus a configurable fast-motion burst), rendered to depth maps by
the same analytic sphere renderer the tracker uses. Ground truth being
known, tracking error is measurable exactly — something the paper could
not do with its real video.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import handmodel, objective
from repro.core.camera import Camera


@dataclasses.dataclass(frozen=True)
class SequenceConfig:
    num_frames: int = 90
    camera: Camera = dataclasses.field(default_factory=Camera)
    base_distance: float = 0.5  # meters from camera
    position_amplitude: float = 0.06
    rotation_amplitude: float = 0.5  # radians
    curl_amplitude: float = 0.9
    fast_burst: Tuple[int, int] = (40, 55)  # frame range with 3x velocity
    noise_std: float = 0.002  # depth sensor noise, meters
    seed: int = 0


def truth_trajectory(cfg: SequenceConfig) -> jnp.ndarray:
    """(T, 27) ground-truth hand configurations."""
    t = np.arange(cfg.num_frames, dtype=np.float64)
    # time warp: the fast burst advances phase 3x faster
    speed = np.ones_like(t)
    lo, hi = cfg.fast_burst
    speed[(t >= lo) & (t < hi)] = 3.0
    phase = np.cumsum(speed) / 30.0  # seconds at 30 fps

    hs = np.zeros((cfg.num_frames, handmodel.NUM_PARAMS), np.float32)
    hs[:, 0] = cfg.position_amplitude * np.sin(2 * np.pi * 0.35 * phase)
    hs[:, 1] = cfg.position_amplitude * 0.6 * np.sin(2 * np.pi * 0.23 * phase + 1.0)
    hs[:, 2] = cfg.base_distance + 0.04 * np.sin(2 * np.pi * 0.17 * phase)
    # wrist rotation as axis-angle -> quaternion around a wobbling axis
    ang = cfg.rotation_amplitude * np.sin(2 * np.pi * 0.3 * phase)
    axis = np.stack(
        [np.sin(0.7 * phase), np.cos(0.9 * phase), 0.4 * np.ones_like(phase)],
        axis=-1,
    )
    axis /= np.linalg.norm(axis, axis=-1, keepdims=True)
    hs[:, 3] = np.cos(ang / 2)
    hs[:, 4:7] = axis * np.sin(ang / 2)[:, None]
    # finger curls: staggered sinusoids per finger, flexion channels only
    for f in range(5):
        curl = 0.5 * cfg.curl_amplitude * (
            1 - np.cos(2 * np.pi * (0.4 + 0.05 * f) * phase + f)
        )
        base = 7 + 4 * f
        hs[:, base + 1] = curl * 0.9
        hs[:, base + 2] = curl
        hs[:, base + 3] = curl * 0.7
    return jnp.asarray(hs)


def render_sequence(cfg: SequenceConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (depth_frames (T, H, W), truth (T, 27))."""
    truth = truth_trajectory(cfg)
    render = jax.jit(
        lambda h: objective.render_depth(h, cfg.camera)
    )
    frames = jnp.stack([render(h) for h in truth])
    if cfg.noise_std > 0:
        rng = np.random.default_rng(cfg.seed)
        noise = rng.normal(0.0, cfg.noise_std, size=frames.shape)
        frames = frames + jnp.asarray(noise, frames.dtype)
    return frames, truth
