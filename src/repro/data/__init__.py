"""Data pipelines: synthetic RGBD sequences + LM token streams."""

from repro.data import rgbd, tokens  # noqa: F401
