"""LLM edge-decode planning table (the paper's technique generalized to
the assigned architectures): tokens/s per policy per arch."""

from __future__ import annotations

from repro.configs import registry
from repro.core.offload import Policy
from repro.serving import edge
from repro.sim import hardware


def bench() -> list:
    env = hardware.edge_tpu_environment()
    rows = []
    for arch in registry.list_archs():
        cfg = registry.get(arch)
        row = edge.compare_archs([cfg], env)[cfg.name]
        best = max(row["local"], row["forced"], row["auto"])
        rows.append((
            f"edge_llm/{arch}",
            1e6 / max(best, 1e-9),
            f"local_tps={row['local']:.2f};forced_tps={row['forced']:.2f};"
            f"auto_tps={row['auto']:.2f};state_kb={row['state_bytes'] / 1024:.1f}",
        ))
    return rows
