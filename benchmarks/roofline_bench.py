"""Roofline table from the dry-run artifacts (benchmark per paper-style
table: one row per (arch, shape, mesh))."""

from __future__ import annotations

import glob
import json
import os


def _rows_for(dir_path: str, tag: str) -> list:
    rows = []
    for path in sorted(glob.glob(os.path.join(dir_path, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            continue
        r = rec["roofline"]
        step = max(r["compute_s"], r["memory_s"], r["collective_s"])
        rows.append((
            f"roofline_{tag}/{rec['arch']}_{rec['shape']}_{rec['mesh']}",
            step * 1e6,
            f"dom={r['dominant']};compute_s={r['compute_s']:.3e};"
            f"memory_s={r['memory_s']:.3e};collective_s={r['collective_s']:.3e};"
            f"useful={r['useful_ratio']:.2f}",
        ))
    return rows


def bench() -> list:
    rows = _rows_for("experiments/dryrun", "baseline")
    rows += _rows_for("experiments/dryrun_opt", "optimized")
    if not rows:
        rows.append(("roofline/no_dryrun_artifacts", 0.0,
                     "run=python -m repro.launch.dryrun"))
    return rows
