"""Paper Fig. 3: serial frame dependency => frame drops vs loop time.

Sweeps the per-frame loop time through the regimes the figure draws
(faster than the 33 ms budget, at it, and the paper's hypothetical
150 ms), reporting achieved throughput, drop rate and the mean gap the
PSO search must cover.
"""

from __future__ import annotations

from repro.sim.clock import FRAME_PERIOD, FrameLoop


def bench() -> list:
    rows = []
    sweep_ms = [10, 25, 33.3, 50, 77, 100, 150, 200]
    loop = FrameLoop()
    for ms in sweep_ms:
        stats = loop.run(lambda i, gap: ms / 1e3, 300)
        note = ""
        if abs(ms - 150) < 1e-9:
            note = ";paper_fig3_example"
        elif ms <= FRAME_PERIOD * 1e3:
            note = ";realtime"
        rows.append((
            f"fig3/loop_{ms:g}ms",
            ms * 1e3,
            f"processed_fps={stats.achieved_fps:.1f};"
            f"drop_pct={stats.drop_rate * 100:.1f};"
            f"mean_gap={stats.mean_gap:.2f}{note}",
        ))
    return rows
