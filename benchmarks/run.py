"""Benchmark harness. One module per paper table/figure + framework
tables. Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run [--only substring]
"""

from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks import (
    calibrate,
    edge_llm,
    fig3_framedrop,
    fig4_overhead,
    fig5_network,
    fleet_bench,
    kernel_bench,
    pso_throughput,
    roofline_bench,
    topology_bench,
)
from benchmarks.common import emit

MODULES = [
    ("fig3", fig3_framedrop),
    ("fig4", fig4_overhead),
    ("fig5", fig5_network),
    ("pso", pso_throughput),
    ("kernel", kernel_bench),
    ("calibrate", calibrate),
    ("roofline", roofline_bench),
    ("edge_llm", edge_llm),
    ("topology", topology_bench),
    ("fleet", fleet_bench),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in MODULES:
        if args.only and args.only not in name:
            continue
        try:
            emit(mod.bench())
        except Exception:
            failures += 1
            print(f"{name}/ERROR,0,exception", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
