"""PSO population-evaluation throughput (the GPGPU claim direction).

The paper: 'a GPGPU implementation provides 100x speedup compared to a
serial implementation'. On this CPU container we demonstrate the same
*structure*: the vectorized (vmap/kernel) population evaluation vs a
serial per-particle Python loop, plus end-to-end PSO frames/s.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import handmodel, objective, pso, tracker
from repro.core.camera import Camera

from benchmarks.common import time_fn

CAM = Camera(width=64, height=64, fx=60.0, fy=60.0, cx=31.5, cy=31.5)


def bench() -> list:
    rows = []
    h0 = handmodel.default_pose(0.45)
    depth = objective.render_depth(h0, CAM)
    key = jax.random.PRNGKey(0)
    n = 64
    lo = handmodel.parameter_lower_bounds(h0)
    hi = handmodel.parameter_upper_bounds(h0)
    hs = lo + jax.random.uniform(key, (n, 27)) * (hi - lo)

    batched = jax.jit(lambda xs: objective.batched_objective(xs, depth, CAM))
    t_vec = time_fn(batched, hs)
    serial_one = jax.jit(lambda x: objective.objective(x, depth, CAM))
    t_one = time_fn(serial_one, hs[0])
    t_serial = t_one * n
    # NOTE: this container has 2 CPU cores — a vectorized population
    # cannot beat n x single-eval on wall time here (no data parallelism
    # to exploit). The paper's 100x claim is about GPGPU lanes; what we
    # check on CPU is that vectorization does not LOSE more than the
    # population-parallel structure gains on real accelerators.
    rows.append((
        "pso/population_eval_vectorized",
        t_vec * 1e6,
        f"particles_per_s={n / t_vec:.0f};"
        f"vec_vs_serial_cpu={t_serial / t_vec:.1f}x;"
        "accel_expectation=~100x_per_paper",
    ))

    cfg = tracker.TrackerConfig(
        camera=CAM, pso=pso.PSOConfig(num_particles=n, num_generations=20)
    )
    step = tracker.make_track_frame(cfg)
    t_frame = time_fn(step, key, h0, depth)
    rows.append((
        "pso/track_frame_cpu",
        t_frame * 1e6,
        f"fps={1 / t_frame:.1f};evals_per_s={n * 21 / t_frame:.0f}",
    ))
    return rows
