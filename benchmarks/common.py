"""Benchmark helpers: timing + CSV row emission + JSON artifacts."""

from __future__ import annotations

import json
import pathlib
import time
from typing import Callable, Iterable, Tuple

Row = Tuple[str, float, str]  # (name, us_per_call, derived)

# benchmark JSON artifacts land at the repo root as BENCH_<name>.json so
# CI runs (and humans diffing two checkouts) can compare machine-readable
# knees / events-per-second / p99 numbers instead of scraping CSV
REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def write_bench_json(name: str, payload: dict) -> pathlib.Path:
    """Dump ``payload`` to ``BENCH_<name>.json`` at the repo root."""
    path = REPO_ROOT / f"BENCH_{name}.json"
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    print(f"# wrote {path.name}")
    return path


def time_fn(fn: Callable, *args, repeats: int = 5, warmup: int = 2) -> float:
    """Median wall time of fn(*args) in seconds (block_until_ready-aware)."""
    for _ in range(warmup):
        out = fn(*args)
        _block(out)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        _block(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def _block(out):
    import jax

    for leaf in jax.tree_util.tree_leaves(out):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()


def emit(rows: Iterable[Row]) -> None:
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
