"""Benchmark helpers: timing + CSV row emission + JSON artifacts."""

from __future__ import annotations

import json
import pathlib
import subprocess
import time
from typing import Callable, Iterable, Tuple

Row = Tuple[str, float, str]  # (name, us_per_call, derived)

# benchmark JSON artifacts land at the repo root as BENCH_<name>.json so
# CI runs (and humans diffing two checkouts) can compare machine-readable
# knees / events-per-second / p99 numbers instead of scraping CSV
REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent

# bumped whenever the stamped envelope (not a specific bench's payload)
# changes shape; benchmarks/validate_bench.py checks it on every artifact
SCHEMA_VERSION = 1


def _git_rev() -> str:
    """Short git revision of the working tree, or "unknown" outside a
    checkout (artifact provenance only — never load-bearing)."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=REPO_ROOT,
            capture_output=True,
            text=True,
            timeout=10,
        )
        rev = out.stdout.strip()
        return rev if out.returncode == 0 and rev else "unknown"
    except Exception:
        return "unknown"


def write_bench_json(name: str, payload: dict) -> pathlib.Path:
    """Dump ``payload`` to ``BENCH_<name>.json`` at the repo root,
    stamped with the artifact schema version and the emitting git rev."""
    doc = dict(payload)
    doc["schema_version"] = SCHEMA_VERSION
    doc["git_rev"] = _git_rev()
    path = REPO_ROOT / f"BENCH_{name}.json"
    path.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
    print(f"# wrote {path.name}")
    return path


def time_fn(fn: Callable, *args, repeats: int = 5, warmup: int = 2) -> float:
    """Median wall time of fn(*args) in seconds (block_until_ready-aware)."""
    for _ in range(warmup):
        out = fn(*args)
        _block(out)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        _block(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def _block(out):
    import jax

    for leaf in jax.tree_util.tree_leaves(out):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()


def emit(rows: Iterable[Row]) -> None:
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
