"""render_score Pallas kernel vs jnp reference (interpret mode on CPU —
correctness-grade timing; on TPU flip ops.DEFAULT_INTERPRET)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import handmodel, objective
from repro.core.camera import Camera
from repro.kernels import ops, ref

try:
    from benchmarks.common import time_fn
except ModuleNotFoundError:  # run as a script: sys.path[0] is benchmarks/
    from common import time_fn


def bench() -> list:
    cam = Camera(width=64, height=64, fx=60.0, fy=60.0, cx=31.5, cy=31.5)
    n = 16
    hs = jnp.stack([handmodel.default_pose(0.4).at[0].add(0.01 * i) for i in range(n)])
    spheres = jax.vmap(handmodel.pack_spheres)(hs)
    rays = cam.rays_flat()
    d_o = objective.render_depth(hs[0], cam).reshape(-1)
    mask = d_o < 5.0

    rows = []
    work = n * rays.shape[0] * handmodel.NUM_SPHERES
    t_ref = time_fn(
        jax.jit(lambda s: ref.render_score(s, rays, d_o, mask)), spheres
    )
    rows.append((
        "kernel/render_score_ref",
        t_ref * 1e6,
        f"particle_px_sphere_per_s={work / t_ref:.2e}",
    ))
    t_k = time_fn(
        jax.jit(lambda s: ops.render_score(s, rays, d_o, mask)), spheres
    )
    rows.append((
        "kernel/render_score_pallas_interpret",
        t_k * 1e6,
        f"particle_px_sphere_per_s={work / t_k:.2e};interpret=True",
    ))

    # second kernel: fused swarm update
    from repro.kernels import pso_ref, pso_update as kmod

    np_, d = 32, 32
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 6)
    lo, hi = -jnp.ones((d,)), jnp.ones((d,))
    x = jax.random.uniform(ks[0], (np_, d), minval=-1, maxval=1)
    v = jax.random.normal(ks[1], (np_, d)) * 0.1
    pb = jax.random.uniform(ks[2], (np_, d), minval=-1, maxval=1)
    gb = pb[0]
    r1 = jax.random.uniform(ks[3], (np_, d))
    r2 = jax.random.uniform(ks[4], (np_, d))
    consts = dict(inertia=0.7298, cognitive=1.49618, social=1.49618,
                  velocity_clip=0.5)
    t_upd = time_fn(
        jax.jit(lambda *a: kmod.pso_update(*a, **consts)),
        x, v, pb, gb, r1, r2, lo, hi,
    )
    rows.append((
        "kernel/pso_update_pallas_interpret",
        t_upd * 1e6,
        f"particle_dims_per_s={np_ * d / t_upd:.2e};interpret=True",
    ))

    # edge batching: B clients' swarms in ONE fused launch vs B launches
    b = 4
    tile = lambda a: jnp.broadcast_to(a, (b,) + a.shape)
    t_fused = time_fn(
        jax.jit(lambda *a: kmod.pso_update_batched(*a, **consts)),
        tile(x), tile(v), tile(pb), tile(gb), tile(r1), tile(r2), lo, hi,
    )
    rows.append((
        f"kernel/pso_update_batched_b{b}_pallas_interpret",
        t_fused * 1e6,
        f"particle_dims_per_s={b * np_ * d / t_fused:.2e};"
        f"per_client_vs_solo={t_fused / (b * t_upd):.2f};interpret=True",
    ))

    # payload codec: delta-encode + quantize-pack one depth plane (the
    # uplink's per-frame encode work) and its exact wire footprint
    from repro.codec import kernels as ckern, ref as cref

    h, w = 240, 320
    prev = objective.render_depth(hs[0], Camera()).reshape(128, 128)
    frame = jnp.pad(prev + 0.001, ((0, h - 128), (0, w - 128)))
    prev = jnp.pad(prev, ((0, h - 128), (0, w - 128)))
    raw_bytes = frame.size * 4
    t_delta = time_fn(
        jax.jit(lambda f, r: ckern.delta_encode(f, r)[0]), frame, prev
    )
    _, mask = ckern.delta_encode(frame, prev)
    # the f32 XOR path ships 32-bit residuals (lossless); the quantized
    # wire width is priced by the model/ref.encode_frame, not here
    enc_bytes = cref.encoded_nbytes_exact(mask, bits=32, header_nbytes=64)
    rows.append((
        "kernel/codec_delta_encode_pallas_interpret",
        t_delta * 1e6,
        f"bytes_per_s={raw_bytes / t_delta:.2e};"
        f"wire_ratio={enc_bytes / raw_bytes:.3f};interpret=True",
    ))
    t_q = time_fn(
        jax.jit(lambda f: ckern.quantize_pack(f, 0.0, 2.0, bits=8)), frame
    )
    rows.append((
        "kernel/codec_quantize_pack_pallas_interpret",
        t_q * 1e6,
        f"bytes_per_s={raw_bytes / t_q:.2e};pack_ratio=0.25;interpret=True",
    ))
    return rows


def main() -> None:
    """Standalone entry: CSV to stdout + BENCH_kernel.json artifact.

    The JSON mirrors the CSV rows (name, us_per_call, the derived
    throughput string) so bench runs on two checkouts diff as data."""
    try:
        from benchmarks.common import emit, write_bench_json
    except ModuleNotFoundError:
        from common import emit, write_bench_json

    rows = bench()
    print("name,us_per_call,derived")
    emit(rows)
    write_bench_json(
        "kernel",
        {
            "rows": [
                {"name": n, "us_per_call": round(us, 2), "derived": d}
                for n, us, d in rows
            ]
        },
    )


if __name__ == "__main__":
    main()
